//! Seamless pipeline demo: the full four-module S-S path (speech →
//! conformer encoder → beam-search text decoder → NAR T2U → vocoder →
//! waveform), plus T-T text translation through the text encoder.

use mmserve::coordinator::seamless_pipe::{ReorderMode, SeamlessPipeline,
                                          SeamlessTask};
use mmserve::runtime::engine::Engine;

fn main() -> anyhow::Result<()> {
    let dir = mmserve::artifacts_dir().join("seamless");
    let engine = Engine::load(&dir)?;
    let pipe = SeamlessPipeline::new(&engine, ReorderMode::Fused)?;

    // synthetic utterance: 3 "phonemes" as chirps
    let wav: Vec<f32> = (0..160 * 48)
        .map(|i| {
            let t = i as f32 / 16000.0;
            let f = 200.0 + 150.0 * ((i / (160 * 16)) as f32);
            (2.0 * std::f32::consts::PI * f * t).sin() * 0.5
        })
        .collect();

    println!("S-S: translating a {:.1}s synthetic utterance …",
             wav.len() as f32 / 16000.0);
    let r = pipe.run(SeamlessTask::SpeechToSpeech, Some(&wav), None, 24)?;
    println!("  text tokens: {} | units: {} | waveform samples: {}",
             r.text_tokens.len(), r.units.len(), r.waveform.len());
    println!("  beam decode steps: {} | e2e {:.1} ms", r.decode_steps,
             r.e2e * 1e3);
    println!("  module times:");
    for (k, v) in r.times.entries() {
        println!("    {:<16} {:>7.2} ms", k, v * 1e3);
    }

    println!("\nT-T: translating text through the text encoder …");
    let r = pipe.run(SeamlessTask::TextToText,
                     None, Some("the quick brown fox"), 24)?;
    println!("  output tokens: {:?} → {:?}", r.text_tokens.len(), r.text);
    println!("  (random weights: the 'translation' is structural, not \
              semantic)");
    Ok(())
}
