//! End-to-end multimodal serving driver — the repo's E2E validation run
//! (recorded in EXPERIMENTS.md): starts the router with all four model
//! families, replays a mixed batch of real requests (text, image,
//! speech, user-history) through the full AOT/PJRT stack, and reports
//! latency + throughput per task.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_multimodal
//! ```

use std::time::Instant;

use mmserve::coordinator::opts::OptConfig;
use mmserve::coordinator::request::{Request, RequestInput, ResponseOutput,
                                    SamplingParams};
use mmserve::coordinator::seamless_pipe::ReorderMode;
use mmserve::coordinator::server::{collect_stats, Router, RouterConfig};
use mmserve::kvpool::KvPoolConfig;
use mmserve::models::{ModelKind, TaskKind};
use mmserve::substrate::metrics::Histogram;
use mmserve::substrate::rng::Rng;
use mmserve::substrate::table::Table;

fn main() -> anyhow::Result<()> {
    let dir = mmserve::artifacts_dir();
    println!("starting multimodal router (llama, chameleon, seamless, \
              hstu) …");
    let router = Router::start(&dir, RouterConfig {
        models: vec![ModelKind::Llama, ModelKind::Chameleon,
                     ModelKind::Seamless, ModelKind::Hstu],
        opt: OptConfig::baseline(),
        reorder: ReorderMode::Fused,
        batch: 4,
        prefill_budget: 0,
        chunk_prefill: 0,
        kv: KvPoolConfig::default(),
        tracer: None,
        ..RouterConfig::default()
    });

    let mut rng = Rng::new(11);
    let n_per_task = std::env::var("MMSERVE_E2E_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(6usize);

    // Build a mixed workload covering six of the paper's nine tasks.
    let mut requests: Vec<Request> = Vec::new();
    for i in 0..n_per_task {
        requests.push(Request::text(
            router.fresh_id(),
            TaskKind::TextToText,
            ["write a fizzbuzz", "reverse a linked list",
             "find the first repeated character in a string",
             "implement a queue with two stacks"][i % 4],
            16,
        ));
        let shade = 0.2 + 0.6 * rng.f64() as f32;
        requests.push(Request {
            id: router.fresh_id(),
            task: TaskKind::ImageToText,
            input: RequestInput::Image {
                pixels: vec![shade; 64 * 64],
                h: 64,
                w: 64,
            },
            max_new_tokens: 8,
            sampling: SamplingParams::greedy(),
        });
        requests.push(Request {
            id: router.fresh_id(),
            task: TaskKind::TextToImage,
            input: RequestInput::Text(
                "an upstairs living room with a sewing machine".into()),
            max_new_tokens: 64,
            sampling: SamplingParams { greedy: false, top_p: 0.9,
                                       temperature: 1.0, top_k: 0,
                                       seed: i as u64 },
        });
        let wav: Vec<f32> = (0..160 * (20 + i * 5))
            .map(|t| ((t as f32) * 0.02 * (1.0 + i as f32 * 0.1)).sin())
            .collect();
        requests.push(Request {
            id: router.fresh_id(),
            task: if i % 2 == 0 { TaskKind::SpeechToText }
                  else { TaskKind::SpeechToSpeech },
            input: RequestInput::Speech(wav),
            max_new_tokens: 16,
            sampling: SamplingParams::greedy(),
        });
        let history: Vec<i32> = (0..100 + i * 40)
            .map(|_| rng.range(0, 6000) as i32)
            .collect();
        requests.push(Request {
            id: router.fresh_id(),
            task: TaskKind::HistoryToAction,
            input: RequestInput::History(history),
            max_new_tokens: 0,
            sampling: SamplingParams::greedy(),
        });
    }

    println!("submitting {} requests across {} tasks …", requests.len(), 5);
    let t0 = Instant::now();
    let rxs: Vec<_> = requests
        .into_iter()
        .map(|r| (r.task, router.submit(r).unwrap()))
        .collect();
    let mut per_task: std::collections::BTreeMap<&str, Histogram> =
        Default::default();
    let mut responses = Vec::new();
    for (task, rx) in rxs {
        let resp = rx.recv()??;
        per_task
            .entry(task.notation())
            .or_default()
            .record(resp.e2e * 1e3);
        responses.push(resp);
    }
    let wall = t0.elapsed().as_secs_f64();

    let stats = collect_stats(&responses, wall);
    println!("\n== run summary ==\n{}", stats.report());

    let mut t = Table::new(&["task", "n", "p50 e2e (ms)", "p95 e2e (ms)",
                             "mean (ms)"]);
    for (task, h) in &per_task {
        t.row(&[
            task.to_string(),
            format!("{}", h.len()),
            format!("{:.1}", h.percentile(50.0)),
            format!("{:.1}", h.percentile(95.0)),
            format!("{:.1}", h.mean()),
        ]);
    }
    t.print();

    // show one output of each modality
    for resp in &responses {
        match (&resp.output, resp.task) {
            (ResponseOutput::Image(px), TaskKind::TextToImage) => {
                println!("T-I produced an 8×8 image, mean intensity \
                          {:.2} ({} contrastive decode steps)",
                         px.iter().sum::<f32>() / px.len() as f32,
                         resp.decode_steps);
                break;
            }
            _ => {}
        }
    }
    for resp in &responses {
        if let (ResponseOutput::Speech(wav), true) =
            (&resp.output, resp.task == TaskKind::SpeechToSpeech)
        {
            println!("S-S produced {} waveform samples (peak {:.2})",
                     wav.len(),
                     wav.iter().cloned().fold(0f32, |a, b| a.max(b.abs())));
            break;
        }
    }
    for resp in &responses {
        if let ResponseOutput::Actions { engagement, top_items } =
            &resp.output
        {
            println!("H-A ranked engagement tail {:?}, top items {:?}",
                     &engagement[..engagement.len().min(4)],
                     &top_items[..top_items.len().min(5)]);
            break;
        }
    }
    router.shutdown();
    println!("\nE2E validation complete: all layers (Pallas kernels → JAX \
              graphs → AOT HLO → PJRT → Rust coordinator) composed.");
    Ok(())
}
