//! Quickstart: load the tiny Llama artifacts, generate a few tokens.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use mmserve::coordinator::decoder_loop::{encode_prompt, DecoderSession};
use mmserve::coordinator::opts::OptConfig;
use mmserve::coordinator::request::SamplingParams;
use mmserve::models::tokenizer::TextTokenizer;
use mmserve::runtime::engine::Engine;

fn main() -> anyhow::Result<()> {
    let dir = mmserve::artifacts_dir().join("llama");
    println!("loading engine from {} …", dir.display());
    let engine = Engine::load(&dir)?;
    println!("model: {} ({} AOT stages)", engine.model(),
             engine.manifest.stages.len());

    let session = DecoderSession::new(&engine, OptConfig::baseline())?;
    let prompt = "fn quicksort(v: &mut Vec<i32>)";
    let ids = encode_prompt(prompt);
    println!("prompt: {prompt:?} → {} tokens", ids.len());

    let t0 = std::time::Instant::now();
    let result = session.generate(&ids, 24, &SamplingParams::greedy())?;
    let text = TextTokenizer::new().decode(&result.tokens);
    println!(
        "generated {} tokens in {:.1} ms (ttft {:.1} ms): {:?}",
        result.decode_steps,
        t0.elapsed().as_secs_f64() * 1e3,
        result.ttft * 1e3,
        text
    );
    println!("(tiny model with random weights — the text is gibberish by \
              construction; the serving mechanics are the point)");
    Ok(())
}
