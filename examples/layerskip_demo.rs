//! LayerSkip demo (§4.3): self-speculative decoding on the tiny Llama —
//! drafts from the first E layers, parallel verification, greedy
//! acceptance — with the output-equivalence check against plain
//! autoregressive greedy decoding.

use std::time::Instant;

use mmserve::coordinator::decoder_loop::{encode_prompt, DecoderSession};
use mmserve::coordinator::opts::OptConfig;
use mmserve::coordinator::request::SamplingParams;
use mmserve::runtime::engine::Engine;

fn main() -> anyhow::Result<()> {
    let dir = mmserve::artifacts_dir().join("llama");
    let engine = Engine::load(&dir)?;
    let sp = SamplingParams::greedy();

    let baseline = DecoderSession::new(&engine, OptConfig::baseline())?;
    let mut ls_opt = OptConfig::baseline();
    ls_opt.layerskip = true;
    let layerskip = DecoderSession::new(&engine, ls_opt)?;

    println!("prompt                          | base ms | ls ms | speedup \
              | acc/drafts | exact");
    let mut total_base = 0.0;
    let mut total_ls = 0.0;
    for prompt in ["def fibonacci(n):", "write a regex for emails",
                   "binary tree traversal in rust",
                   "SELECT users WHERE active"] {
        let ids = encode_prompt(prompt);
        // warm both paths once
        baseline.generate(&ids, 4, &sp)?;
        layerskip.generate(&ids, 4, &sp)?;

        let t0 = Instant::now();
        let rb = baseline.generate(&ids, 32, &sp)?;
        let tb = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let rl = layerskip.generate(&ids, 32, &sp)?;
        let tl = t0.elapsed().as_secs_f64();
        total_base += tb;
        total_ls += tl;
        println!(
            "{:<31} | {:>7.1} | {:>5.1} | {:>6.2}x | {:>4}/{:<6} | {}",
            &prompt[..prompt.len().min(31)],
            tb * 1e3,
            tl * 1e3,
            tb / tl,
            rl.accepted_drafts,
            rl.draft_rounds * 3,
            rb.tokens == rl.tokens,
        );
    }
    println!(
        "\noverall speedup: {:.2}x (paper: 1.58x geomean at paper scale; \
         greedy acceptance makes outputs exactly equal to the baseline)",
        total_base / total_ls
    );
    Ok(())
}
