//! Characterization demo: the paper's Figure-4 methodology applied to
//! the *real* tiny models — per-stage wall-time and idle-gap
//! attribution measured by the telemetry subsystem, side by side with
//! the A100 device-model projection.

use mmserve::coordinator::decoder_loop::DecoderSession;
use mmserve::coordinator::opts::OptConfig;
use mmserve::coordinator::request::SamplingParams;
use mmserve::perfmodel::breakdown::render;
use mmserve::perfmodel::device::A100;
use mmserve::perfmodel::levers::Levers;
use mmserve::perfmodel::standard_breakdown_rows;
use mmserve::runtime::engine::Engine;
use mmserve::telemetry::{Tracer, TraceReport};

fn main() -> anyhow::Result<()> {
    // --- real CPU: traced breakdown of a llama generation -------------
    let dir = mmserve::artifacts_dir().join("llama");
    let tracer = Tracer::off(); // off during compile/warmup
    let mut engine = Engine::load(&dir)?;
    engine.set_tracer(tracer.worker("llama"));
    let session = DecoderSession::new(&engine, OptConfig::baseline())?;
    let prompt: Vec<i32> = (2..30).collect();
    // warm (compile) then measure with tracing on
    session.generate(&prompt, 4, &SamplingParams::greedy())?;
    tracer.set_enabled(true);
    let r = session.generate(&prompt, 24, &SamplingParams::greedy())?;
    tracer.set_enabled(false);
    let trace = tracer.drain();

    println!("== real CPU (tiny llama): measured breakdown for a \
              24-token generation ==");
    let report = TraceReport::from_trace(&trace);
    println!("{}", report.render());
    println!("e2e: {:.2} ms, {} decode steps, ttft {:.2} ms\n",
             r.e2e * 1e3, r.decode_steps, r.ttft * 1e3);

    // --- device model: paper-scale Figure 4 ---------------------------
    println!("== device model (paper scale, A100, baseline) ==");
    println!("{}", render(&standard_breakdown_rows(&A100,
                                                   &Levers::baseline())));
    println!("== device model (paper scale, A100, Sys-Opt) ==");
    println!("{}", render(&standard_breakdown_rows(&A100,
                                                   &Levers::sys_opt())));
    Ok(())
}
