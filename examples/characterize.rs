//! Characterization demo: the paper's Figure-4 methodology applied to
//! the *real* tiny models — per-stage wall-time accounting from the
//! engine, side by side with the A100 device-model projection.

use mmserve::coordinator::decoder_loop::DecoderSession;
use mmserve::coordinator::opts::OptConfig;
use mmserve::coordinator::request::SamplingParams;
use mmserve::perfmodel::breakdown::render;
use mmserve::perfmodel::device::A100;
use mmserve::perfmodel::levers::Levers;
use mmserve::perfmodel::standard_breakdown_rows;
use mmserve::runtime::engine::Engine;

fn main() -> anyhow::Result<()> {
    // --- real CPU: stage-level breakdown of a llama generation --------
    let dir = mmserve::artifacts_dir().join("llama");
    let engine = Engine::load(&dir)?;
    let session = DecoderSession::new(&engine, OptConfig::baseline())?;
    let prompt: Vec<i32> = (2..30).collect();
    // warm (compile) then measure
    session.generate(&prompt, 4, &SamplingParams::greedy())?;
    engine.stage_times.borrow_mut();
    *engine.stage_times.borrow_mut() =
        mmserve::substrate::metrics::OpTimes::new();
    let r = session.generate(&prompt, 24, &SamplingParams::greedy())?;
    println!("== real CPU (tiny llama): stage wall-time for a 24-token \
              generation ==");
    let times = engine.stage_times.borrow();
    let total = times.total();
    for (stage, secs) in times.entries() {
        println!("  {:<20} {:>8.2} ms  ({:>4.1}%)", stage, secs * 1e3,
                 secs / total * 100.0);
    }
    println!("  e2e: {:.2} ms, {} decode steps, ttft {:.2} ms\n",
             r.e2e * 1e3, r.decode_steps, r.ttft * 1e3);

    // --- device model: paper-scale Figure 4 ---------------------------
    println!("== device model (paper scale, A100, baseline) ==");
    println!("{}", render(&standard_breakdown_rows(&A100,
                                                   &Levers::baseline())));
    println!("== device model (paper scale, A100, Sys-Opt) ==");
    println!("{}", render(&standard_breakdown_rows(&A100,
                                                   &Levers::sys_opt())));
    Ok(())
}
