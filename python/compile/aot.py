"""AOT compile path: lower every L2 stage to HLO text + write weights.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts

Per model this emits
    artifacts/<model>/<stage>.hlo.txt      one per stage variant
    artifacts/<model>/weights.bin          MMWB container (weights.py)
    artifacts/<model>/manifest.json        stage → file/weights/args/outputs
    artifacts/<model>/goldens.bin          input/output pairs for the Rust
                                           integration tests

Interchange is HLO **text**, not serialized HloModuleProto: jax ≥ 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

Stage-variant axes = the paper's optimization levers:
    attn:   naive (baseline)         | flash (SDPA / FlashAttention lever)
    linear: f32 (baseline)           | int8_weight_only | int8_dynamic
                                       (AutoQuant lever)
    eager per-op stages              (launch-overhead / CUDA-Graph lever:
                                      eager = many dispatches, graph = one)
    draft / verify stages            (LayerSkip lever)
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import weights as wio
from .configs import TINY, config_to_dict
from .models import hstu as hstu_m
from .models import llama as llama_m
from .models import seamless as seam_m

F32, I32, I8 = jnp.float32, jnp.int32, jnp.int8


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default ELIDES big
    # literals (e.g. RoPE cos/sin tables) as `{...}`, which the text
    # parser then silently re-materializes as zeros — numerically wrong
    # artifacts that only fail at golden-check time.
    text = comp.as_hlo_text(print_large_constants=True)
    assert "{...}" not in text, "elided constant survived printing"
    return text


def _dt(d):
    return {"float32": "f32", "int32": "i32", "int8": "i8"}[str(jnp.dtype(d))]


class ModelEmitter:
    """Collects stages for one model directory."""

    def __init__(self, name: str, out_dir: str, cfg):
        self.name = name
        self.dir = os.path.join(out_dir, name)
        os.makedirs(self.dir, exist_ok=True)
        self.cfg = cfg
        self.stages: Dict[str, dict] = {}
        self.weight_tensors: Dict[str, np.ndarray] = {}
        self.weight_order: List[str] = []
        self.goldens: Dict[str, np.ndarray] = {}

    def set_weights(self, tensors: Dict[str, np.ndarray],
                    order: List[str]) -> None:
        self.weight_tensors = tensors
        self.weight_order = list(order)

    def add_stage(self, stage_name: str, fn, weight_names: List[str],
                  args: List[tuple], outputs_meta: List[dict],
                  meta: dict, donate_args: tuple = ()) -> None:
        """Lower fn(*weights, *args) and record the manifest entry.

        args: list of (name, shape, dtype). ``donate_args``: indices
        into ``args`` whose buffers are donated (input_output_alias in
        the HLO) — the state tensors (KV caches) that the Rust runtime
        chains across steps update in place instead of copying."""
        t0 = time.time()
        w_specs = [spec(self.weight_tensors[n].shape,
                        self.weight_tensors[n].dtype) for n in weight_names]
        a_specs = [spec(s, d) for (_, s, d) in args]
        donate = tuple(len(weight_names) + i for i in donate_args)
        # keep_unused: the early-exit draft stage ignores layers ≥ E, but
        # the runtime contract is "weights in manifest order" — dropping
        # unused parameters would silently shift every later input.
        lowered = jax.jit(fn, keep_unused=True,
                          donate_argnums=donate).lower(*w_specs, *a_specs)
        text = to_hlo_text(lowered)
        fname = f"{stage_name}.hlo.txt"
        with open(os.path.join(self.dir, fname), "w") as f:
            f.write(text)
        self.stages[stage_name] = {
            "file": fname,
            "weights": weight_names,
            "args": [{"name": n, "shape": list(s), "dtype": _dt(d)}
                     for (n, s, d) in args],
            "outputs": outputs_meta,
            "meta": meta,
        }
        print(f"  [{self.name}] {stage_name}: {len(text)//1024} KiB "
              f"({time.time()-t0:.1f}s)", flush=True)

    def add_golden(self, tag: str, arrays: Dict[str, np.ndarray]) -> None:
        for k, v in arrays.items():
            a = np.asarray(v)
            if a.dtype == np.int64:
                a = a.astype(np.int32)
            if a.dtype == np.float64:
                a = a.astype(np.float32)
            self.goldens[f"{tag}.{k}"] = a

    def finish(self) -> None:
        wio.save(os.path.join(self.dir, "weights.bin"),
                 self.weight_tensors, self.weight_order)
        if self.goldens:
            wio.save(os.path.join(self.dir, "goldens.bin"),
                     self.goldens, sorted(self.goldens))
        manifest = {
            "model": self.name,
            "config": config_to_dict(self.cfg),
            "weights_file": "weights.bin",
            "weight_order": self.weight_order,
            "stages": self.stages,
        }
        with open(os.path.join(self.dir, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)


def _wrap(fn, names):
    """fn(params_dict, *args) → flat fn(*weights, *args)."""
    n = len(names)

    def flat(*xs):
        params = dict(zip(names, xs[:n]))
        return fn(params, *xs[n:])

    return flat


# ==========================================================================
# Llama / Chameleon
# ==========================================================================

def emit_decoder(name: str, out_dir: str, *, fast: bool, seed: int) -> None:
    cfg = TINY[name]
    em = ModelEmitter(name, out_dir, cfg)
    base = llama_m.init_params(cfg, seed=seed)
    quant = llama_m.quantize_params(base)
    tensors = {**base, **quant}
    base_names = [n for n, _ in llama_m.param_specs(cfg)]
    order = base_names + sorted(quant)
    em.set_weights(tensors, order)

    def quant_names():
        out = []
        for n in base_names:
            leaf = n.split(".")[-1]
            if leaf in llama_m.QUANTIZABLE:
                out += [n + ".q", n + ".scale"]
            else:
                out.append(n)
        return out

    L, H, S, Dh, V = (cfg.n_layers, cfg.n_heads, cfg.max_seq,
                      cfg.head_dim, cfg.vocab_size)

    def kvs(b):
        return (L, b, H, S, Dh)

    def kv_out(b):
        return [{"shape": list(kvs(b)), "dtype": "f32"},
                {"shape": list(kvs(b)), "dtype": "f32"}]

    # ---- prefill -----------------------------------------------------
    buckets = cfg.prefill_buckets if not fast else cfg.prefill_buckets[:1]
    for p in buckets:
        for attn in ("naive", "flash"):
            fn = llama_m.make_prefill(cfg, p, attn_impl=attn)
            sfx = "" if attn == "naive" else "_flash"
            em.add_stage(
                f"prefill_b{p}{sfx}", _wrap(fn, base_names), base_names,
                [("tokens", (1, p), I32), ("prompt_len", (1,), I32)],
                [{"shape": [1, V], "dtype": "f32"}] + kv_out(1),
                {"kind": "prefill", "bucket": p, "attn": attn,
                 "linear": "f32", "batch": 1})
    p = buckets[0]
    fn = llama_m.make_prefill(cfg, p, attn_impl="naive",
                              linear_mode="int8_weight_only")
    names = quant_names()
    em.add_stage(
        f"prefill_b{p}_int8wo", _wrap(fn, names), names,
        [("tokens", (1, p), I32), ("prompt_len", (1,), I32)],
        [{"shape": [1, V], "dtype": "f32"}] + kv_out(1),
        {"kind": "prefill", "bucket": p, "attn": "naive",
         "linear": "int8_weight_only", "batch": 1})

    # ---- decode ------------------------------------------------------
    batches = cfg.decode_batch_sizes if not fast else (1,)
    dec_variants = [("naive", "f32", ""), ("flash", "f32", "_flash"),
                    ("naive", "int8_weight_only", "_int8wo"),
                    ("naive", "int8_dynamic", "_int8dyn"),
                    ("flash", "int8_weight_only", "_flash_int8wo")]
    if fast:
        dec_variants = dec_variants[:2]
    for b in batches:
        for attn, lm, sfx in dec_variants:
            fn = llama_m.make_decode(cfg, b, attn_impl=attn, linear_mode=lm)
            names = base_names if lm == "f32" else quant_names()
            em.add_stage(
                f"decode_b{b}{sfx}", _wrap(fn, names), names,
                [("tokens", (b,), I32), ("positions", (b,), I32),
                 ("cache_k", kvs(b), F32), ("cache_v", kvs(b), F32)],
                [{"shape": [b, V], "dtype": "f32"}] + kv_out(b),
                {"kind": "decode", "batch": b, "attn": attn, "linear": lm},
                donate_args=(2, 3))

    # ---- kv_pack (continuous-batching admission) -----------------------
    for b in batches:
        if b == 1:
            continue
        fn = llama_m.make_kv_pack(cfg, b)
        em.add_stage(
            f"kv_pack_b{b}", fn, [],
            [("cache_k", kvs(b), F32), ("cache_v", kvs(b), F32),
             ("ck1", kvs(1), F32), ("cv1", kvs(1), F32),
             ("slot", (1,), I32)],
            kv_out(b),
            {"kind": "kv_pack", "batch": b}, donate_args=(0, 1))

    # ---- LayerSkip draft + verify -------------------------------------
    fn = llama_m.make_decode(cfg, 1, attn_impl="naive", early_exit=True)
    em.add_stage(
        "draft_b1", _wrap(fn, base_names), base_names,
        [("tokens", (1,), I32), ("positions", (1,), I32),
         ("cache_k", kvs(1), F32), ("cache_v", kvs(1), F32)],
        [{"shape": [1, V], "dtype": "f32"}] + kv_out(1),
        {"kind": "draft", "batch": 1,
         "early_exit_layer": cfg.early_exit_layer}, donate_args=(2, 3))
    K = cfg.verify_window
    fn = llama_m.make_verify(cfg, K, attn_impl="naive")
    em.add_stage(
        f"verify_k{K}", _wrap(fn, base_names), base_names,
        [("tokens", (1, K), I32), ("start_pos", (1,), I32),
         ("cache_k", kvs(1), F32), ("cache_v", kvs(1), F32)],
        [{"shape": [1, K, V], "dtype": "f32"}] + kv_out(1),
        {"kind": "verify", "window": K}, donate_args=(2, 3))

    # ---- eager per-op stages (launch-overhead baseline) ----------------
    d = cfg.d_model
    f = cfg.ffn_hidden
    eager = [
        ("eager_embed", llama_m.make_eager_embed(cfg), ["embed"],
         [("tokens", (1,), I32)],
         [{"shape": [1, d], "dtype": "f32"}]),
        ("eager_norm", llama_m.make_eager_norm(cfg), [],
         [("w", (d,), F32), ("x", (1, d), F32)],
         [{"shape": [1, d], "dtype": "f32"}]),
        ("eager_qkv", llama_m.make_eager_qkv(cfg), [],
         [("wq", (d, d), F32), ("wk", (d, d), F32), ("wv", (d, d), F32),
          ("x", (1, d), F32), ("positions", (1,), I32)],
         [{"shape": [1, H, 1, Dh], "dtype": "f32"}] * 3),
        ("eager_attn", llama_m.make_eager_attn_step(cfg), [],
         [("q", (1, H, 1, Dh), F32), ("k", (1, H, 1, Dh), F32),
          ("v", (1, H, 1, Dh), F32), ("positions", (1,), I32),
          ("ck", (1, H, S, Dh), F32), ("cv", (1, H, S, Dh), F32)],
         [{"shape": [1, d], "dtype": "f32"},
          {"shape": [1, H, S, Dh], "dtype": "f32"},
          {"shape": [1, H, S, Dh], "dtype": "f32"}]),
        ("eager_oproj", llama_m.make_eager_oproj(cfg), [],
         [("wo", (d, d), F32), ("attn_out", (1, d), F32),
          ("resid", (1, d), F32)],
         [{"shape": [1, d], "dtype": "f32"}]),
        ("eager_ffn", llama_m.make_eager_ffn(cfg), [],
         [("norm_w", (d,), F32), ("w_gate", (d, f), F32),
          ("w_up", (d, f), F32), ("w_down", (f, d), F32),
          ("x", (1, d), F32)],
         [{"shape": [1, d], "dtype": "f32"}]),
        ("eager_head", llama_m.make_eager_head(cfg), [],
         [("final_norm", (d,), F32), ("lm_head", (d, V), F32),
          ("x", (1, d), F32)],
         [{"shape": [1, V], "dtype": "f32"}]),
    ]
    # Eager fns take (*weights, *args) directly — no params-dict wrapper.
    for sname, efn, wnames, args, outs in eager:
        em.add_stage(sname, efn, wnames, args, outs, {"kind": "eager_op"})

    # ---- goldens -------------------------------------------------------
    rng = np.random.default_rng(seed + 100)
    p = buckets[0]
    toks = rng.integers(0, V, size=(1, p)).astype(np.int32)
    plen = np.array([p // 2 + 1], np.int32)
    pre = llama_m.make_prefill(cfg, p, attn_impl="naive")
    logits, ck, cv = jax.jit(pre)(base, toks, plen)
    em.add_golden(f"prefill_b{p}", {
        "in.tokens": toks, "in.prompt_len": plen,
        "out.logits": np.asarray(logits)})
    dec = llama_m.make_decode(cfg, 1, attn_impl="naive")
    dt = rng.integers(0, V, size=(1,)).astype(np.int32)
    dp = plen.copy()
    dl, _, _ = jax.jit(dec)(base, dt, dp, ck, cv)
    em.add_golden("decode_b1", {
        "in.tokens": dt, "in.positions": dp,
        "out.logits": np.asarray(dl)})
    em.finish()


# ==========================================================================
# Seamless
# ==========================================================================

def emit_seamless(out_dir: str, *, fast: bool, seed: int = 1) -> None:
    cfg = TINY["seamless"]
    em = ModelEmitter("seamless", out_dir, cfg)
    base = seam_m.init_params(cfg, seed=seed)
    order = [n for n, _ in seam_m.param_specs(cfg)]
    em.set_weights(base, order)

    d = cfg.d_model
    enc_names = [n for n in order if n.startswith("enc.")]
    dec_names = [n for n in order if n.startswith("dec.")]
    t2u_names = [n for n in order if n.startswith("t2u.")]
    voc_names = [n for n in order if n.startswith("voc.")]
    cross_names = [n for n in order
                   if ".cross.wk" in n or ".cross.wv" in n]

    tenc_names = [n for n in order if n.startswith("tenc.")]

    enc_buckets = cfg.encoder_buckets if not fast else \
        cfg.encoder_buckets[:1]
    for t in enc_buckets:
        # Text encoder sized to the same source length as this speech
        # bucket (tp tokens), so cross_kv/dec_step stages are shared.
        tp0 = t // cfg.enc_subsample
        fn = seam_m.make_text_encoder(cfg, tp0)
        em.add_stage(
            f"text_encoder_t{tp0}", _wrap(fn, tenc_names), tenc_names,
            [("tokens", (1, tp0), I32), ("text_len", (1,), I32)],
            [{"shape": [1, tp0, d], "dtype": "f32"},
             {"shape": [1], "dtype": "i32"}],
            {"kind": "text_encoder", "bucket": tp0, "out_len": tp0})
        tp = t // cfg.enc_subsample
        fn = seam_m.make_encoder(cfg, t)
        em.add_stage(
            f"encoder_t{t}", _wrap(fn, enc_names), enc_names,
            [("feats", (1, t, cfg.enc_feat_dim), F32),
             ("feat_len", (1,), I32)],
            [{"shape": [1, tp, d], "dtype": "f32"},
             {"shape": [1], "dtype": "i32"}],
            {"kind": "encoder", "bucket": t, "out_len": tp})
        fn = seam_m.make_cross_kv(cfg, tp)
        xshape = list(seam_m.cross_kv_shape(cfg, tp))
        em.add_stage(
            f"cross_kv_s{tp}", _wrap(fn, cross_names), cross_names,
            [("enc_out", (1, tp, d), F32)],
            [{"shape": xshape, "dtype": "f32"},
             {"shape": xshape, "dtype": "f32"}],
            {"kind": "cross_kv", "src_len": tp})
        beams_list = (1, cfg.beam_size) if not fast else (cfg.beam_size,)
        for bm in beams_list:
            fn = seam_m.make_dec_step(cfg, bm, tp)
            skv = list(seam_m.self_kv_shape(cfg, bm))
            em.add_stage(
                f"dec_step_b{bm}_s{tp}", _wrap(fn, dec_names), dec_names,
                [("tokens", (bm,), I32), ("positions", (bm,), I32),
                 ("self_ck", skv, F32), ("self_cv", skv, F32),
                 ("cross_k", xshape, F32), ("cross_v", xshape, F32),
                 ("enc_len", (1,), I32)],
                [{"shape": [bm, cfg.text_vocab], "dtype": "f32"},
                 {"shape": skv, "dtype": "f32"},
                 {"shape": skv, "dtype": "f32"}],
                {"kind": "dec_step", "beams": bm, "src_len": tp},
                donate_args=(2, 3))

    bm = cfg.beam_size
    skv = list(seam_m.self_kv_shape(cfg, bm))
    fn = seam_m.make_kv_reorder(cfg, bm)
    em.add_stage(
        f"kv_reorder_b{bm}", fn, [],
        [("self_ck", skv, F32), ("self_cv", skv, F32),
         ("beam_idx", (bm,), I32)],
        [{"shape": skv, "dtype": "f32"}, {"shape": skv, "dtype": "f32"}],
        {"kind": "kv_reorder", "beams": bm}, donate_args=(0, 1))

    t2u_buckets = (16, 32) if not fast else (16,)
    for tb in t2u_buckets:
        fn = seam_m.make_t2u(cfg, tb)
        ul = tb * cfg.t2u_upsample
        em.add_stage(
            f"t2u_t{tb}", _wrap(fn, t2u_names), t2u_names,
            [("tokens", (1, tb), I32), ("text_len", (1,), I32)],
            [{"shape": [1, ul, cfg.unit_vocab], "dtype": "f32"},
             {"shape": [1], "dtype": "i32"}],
            {"kind": "t2u", "bucket": tb, "upsample": cfg.t2u_upsample})
    voc_buckets = (64, 128) if not fast else (64,)
    r = cfg.voc_upsample ** cfg.voc_stages
    for ub in voc_buckets:
        fn = seam_m.make_vocoder(cfg, ub)
        em.add_stage(
            f"vocoder_u{ub}", _wrap(fn, voc_names), voc_names,
            [("units", (1, ub), I32)],
            [{"shape": [1, ub * r], "dtype": "f32"}],
            {"kind": "vocoder", "bucket": ub, "rate": r})

    rng = np.random.default_rng(seed + 100)
    t = enc_buckets[0]
    feats = rng.normal(0, 1, (1, t, cfg.enc_feat_dim)).astype(np.float32)
    flen = np.array([t - 8], np.int32)
    enc_out, enc_len = jax.jit(seam_m.make_encoder(cfg, t))(
        base, feats, flen)
    em.add_golden(f"encoder_t{t}", {
        "in.feats": feats, "in.feat_len": flen,
        "out.enc": np.asarray(enc_out),
        "out.len": np.asarray(enc_len).astype(np.int32)})
    em.finish()


# ==========================================================================
# HSTU
# ==========================================================================

def emit_hstu(out_dir: str, *, fast: bool, seed: int = 2) -> None:
    cfg = TINY["hstu"]
    em = ModelEmitter("hstu", out_dir, cfg)
    base = hstu_m.init_params(cfg, seed=seed)
    order = [n for n, _ in hstu_m.param_specs(cfg)]
    em.set_weights(base, order)

    combos = [(256, 1, "naive"), (256, 1, "fused"),
              (256, 8, "naive"), (256, 8, "fused"),
              (1024, 1, "naive"), (1024, 1, "fused")]
    if fast:
        combos = combos[:2]
    for s, b, impl in combos:
        fn = hstu_m.make_forward(cfg, s, b, attn_impl=impl)
        sfx = "" if impl == "naive" else "_fused"
        em.add_stage(
            f"forward_s{s}_b{b}{sfx}", _wrap(fn, order), order,
            [("item_ids", (b, s), I32), ("seq_len", (b,), I32)],
            [{"shape": [b, s, cfg.action_vocab], "dtype": "f32"},
             {"shape": [b, cfg.item_vocab], "dtype": "f32"}],
            {"kind": "forward", "seq": s, "batch": b, "attn": impl})

    rng = np.random.default_rng(seed + 100)
    s, b = combos[0][0], combos[0][1]
    ids = rng.integers(0, cfg.item_vocab, (b, s)).astype(np.int32)
    sl = np.array([s - 11] * b, np.int32)
    fn = jax.jit(hstu_m.make_forward(cfg, s, b, attn_impl="naive"))
    rank, retr = fn(base, ids, sl)
    em.add_golden(f"forward_s{s}_b{b}", {
        "in.item_ids": ids, "in.seq_len": sl,
        "out.rank": np.asarray(rank), "out.retrieval": np.asarray(retr)})
    em.finish()


# ==========================================================================

def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="llama,chameleon,seamless,hstu")
    ap.add_argument("--fast", action="store_true",
                    help="reduced stage set (CI smoke)")
    args = ap.parse_args()

    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)
    t0 = time.time()
    for m in args.models.split(","):
        m = m.strip()
        print(f"== emitting {m} ==", flush=True)
        if m == "llama":
            emit_decoder("llama", out_dir, fast=args.fast, seed=0)
        elif m == "chameleon":
            emit_decoder("chameleon", out_dir, fast=args.fast, seed=7)
        elif m == "seamless":
            emit_seamless(out_dir, fast=args.fast)
        elif m == "hstu":
            emit_hstu(out_dir, fast=args.fast)
        else:
            raise SystemExit(f"unknown model {m!r}")
    with open(os.path.join(out_dir, ".stamp"), "w") as f:
        f.write(f"{time.time()}\n")
    print(f"done in {time.time()-t0:.0f}s → {out_dir}")


if __name__ == "__main__":
    main()
