"""Facade re-exports: the L2 model zoo.

Kept for discoverability (`from compile import model`); the real
definitions live in ``compile.models.*`` and the stage lowering in
``compile.aot``.
"""

from .models.hstu import make_forward as make_hstu_forward  # noqa: F401
from .models.llama import (  # noqa: F401
    make_decode,
    make_prefill,
    make_verify,
)
from .models.seamless import (  # noqa: F401
    make_dec_step,
    make_encoder,
    make_t2u,
    make_vocoder,
)
