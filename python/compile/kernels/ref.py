"""Pure-jnp reference oracles for every Pallas kernel.

These are the "naive" implementations the paper's baseline uses (attention
that materializes the full N x N score matrix, straight f32 matmuls) and the
ground truth the Pallas kernels are validated against in pytest.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sdpa_ref(q, k, v, *, causal: bool = False, kv_len=None, scale=None):
    """Naive scaled dot-product attention.

    q: [B, H, Sq, D], k/v: [B, H, Sk, D].
    ``kv_len``: optional [B] int32 — only the first kv_len[b] KV positions
    are valid (static-cache decode). ``causal`` applies a causal mask
    aligned to the *end* of the valid KV region (standard for prefill).
    Materializes the [B, H, Sq, Sk] score tensor — this is the baseline the
    flash kernel avoids.
    """
    b, h, sq, d = q.shape
    sk = k.shape[2]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.array(d, dtype=q.dtype))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    neg = jnp.asarray(-1e30, dtype=scores.dtype)
    if causal:
        qi = jnp.arange(sq)[:, None]
        ki = jnp.arange(sk)[None, :]
        mask = ki <= qi + (sk - sq)
        scores = jnp.where(mask[None, None], scores, neg)
    if kv_len is not None:
        ki = jnp.arange(sk)[None, None, None, :]
        valid = ki < kv_len[:, None, None, None]
        scores = jnp.where(valid, scores, neg)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def hstu_attention_ref(q, k, v, rab, *, seq_len=None, window=None):
    """HSTU pointwise-normalized attention (paper §2.1.4).

    Spatial aggregation replaces softmax with a pointwise
    ``silu(QK^T + rab) / N`` weighting. q/k/v: [B, H, S, D];
    rab: [H, S, S] relative attention bias; ``seq_len``: optional [B]
    valid-length mask. Causal (sequential transduction). ``window``:
    optional sliding attention window (the paper's later-layer
    sequence-length cap, DESIGN.md §Substitutions).
    """
    b, h, s, d = q.shape
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(
        jnp.array(d, q.dtype)
    )
    scores = scores + rab[None]
    w = jax.nn.silu(scores)
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = (ki <= qi)[None, None]
    if window is not None:
        mask = jnp.logical_and(mask, (ki > qi - window)[None, None])
    if seq_len is not None:
        valid = (jnp.arange(s)[None, :] < seq_len[:, None])[:, None, None, :]
        mask = jnp.logical_and(mask, valid)
    w = jnp.where(mask, w, 0.0)
    # Pointwise normalization by the (masked) sequence length N.
    n = jnp.maximum(jnp.sum(mask.astype(q.dtype), axis=-1, keepdims=True), 1.0)
    w = w / n
    return jnp.einsum("bhqk,bhkd->bhqd", w, v)


def relative_bias_ref(table, s: int):
    """Bucketed relative attention bias: rab[h, i, j] = table[h, bucket(i-j)].

    ``table``: [H, n_buckets]. Causal distances i-j are clipped into
    [0, n_buckets).
    """
    n_buckets = table.shape[1]
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    dist = jnp.clip(i - j, 0, n_buckets - 1)
    return table[:, dist]  # [H, S, S]


def int8_weight_only_matmul_ref(x, w_q, w_scale):
    """x [M, K] f32 @ dequant(w_q [K, N] int8, w_scale [N]) — weight-only."""
    w = w_q.astype(jnp.float32) * w_scale[None, :]
    return x @ w


def int8_dynamic_matmul_ref(x, w_q, w_scale):
    """Dynamic activation quantization: per-row symmetric int8 on x, then
    integer-domain matmul rescaled back to f32."""
    amax = jnp.maximum(jnp.max(jnp.abs(x), axis=1, keepdims=True), 1e-8)
    x_scale = amax / 127.0
    x_q = jnp.clip(jnp.round(x / x_scale), -127, 127).astype(jnp.int8)
    acc = jnp.matmul(
        x_q.astype(jnp.int32), w_q.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    return acc.astype(jnp.float32) * x_scale * w_scale[None, :]


def quantize_weight(w, axis: int = 0):
    """Symmetric per-output-channel int8 quantization of w [K, N] → (q, scale[N])."""
    amax = jnp.maximum(jnp.max(jnp.abs(w), axis=axis), 1e-8)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(w / scale[None, :]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)
