"""Int8 quantized matmul Pallas kernels (the paper's AutoQuant lever, L1).

torchao's AutoQuant picks between *int8 weight-only* (memory-bound layers:
halve/quarter the bytes moved for weights) and *int8 dynamic* (compute-bound
layers: integer-domain GEMM) per linear layer. Both variants are
implemented here as tiled Pallas kernels so the Rust-side autoquant
calibration pass (rust/src/coordinator/autoquant.rs) can time real
executables per layer shape and pick the winner — the same decision
procedure AutoQuant automates.

Tiling: one program per (m-block, n-block); the K reduction streams
``block_k`` tiles through VMEM. The int8 weight tile is dequantized (or
kept integer for the dynamic variant) in VMEM — HBM traffic for weights is
1 byte/elem instead of 4, which is the entire point of the lever.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wo_kernel(x_ref, wq_ref, scale_ref, o_ref, *, block_k: int, kdim: int):
    """Weight-only: o = x @ (wq * scale)."""
    block_m = x_ref.shape[0]
    block_n = wq_ref.shape[1]
    acc0 = jnp.zeros((block_m, block_n), dtype=jnp.float32)
    n_kb = kdim // block_k

    def body(kb, acc):
        x_t = x_ref[:, pl.dslice(kb * block_k, block_k)].astype(jnp.float32)
        w_t = wq_ref[pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        return acc + x_t @ w_t

    acc = jax.lax.fori_loop(0, n_kb, body, acc0)
    o_ref[...] = (acc * scale_ref[0, :][None, :]).astype(o_ref.dtype)


def _dyn_kernel(x_ref, wq_ref, scale_ref, o_ref, *, block_k: int, kdim: int):
    """Dynamic: per-row int8 activation quant, integer accumulate, rescale."""
    block_m = x_ref.shape[0]
    block_n = wq_ref.shape[1]
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(x), axis=1, keepdims=True), 1e-8)
    x_scale = amax / 127.0
    x_q = jnp.clip(jnp.round(x / x_scale), -127, 127).astype(jnp.int32)

    acc0 = jnp.zeros((block_m, block_n), dtype=jnp.int32)
    n_kb = kdim // block_k

    def body(kb, acc):
        x_t = jax.lax.dynamic_slice(
            x_q, (0, kb * block_k), (block_m, block_k)
        )
        w_t = wq_ref[pl.dslice(kb * block_k, block_k), :].astype(jnp.int32)
        return acc + jax.lax.dot(
            x_t, w_t, preferred_element_type=jnp.int32
        )

    acc = jax.lax.fori_loop(0, n_kb, body, acc0)
    o_ref[...] = (
        acc.astype(jnp.float32) * x_scale * scale_ref[0, :][None, :]
    ).astype(o_ref.dtype)


def _tiled_call(kernel, x, w_q, w_scale, block_m, block_n, block_k,
                interpret):
    m, kdim = x.shape
    n = w_q.shape[1]
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, kdim)
    if m % block_m or n % block_n or kdim % block_k:
        raise ValueError(
            f"({m},{kdim},{n}) not divisible by ({block_m},{block_k},{block_n})"
        )
    grid = (m // block_m, n // block_n)
    fn = functools.partial(kernel, block_k=block_k, kdim=kdim)
    return pl.pallas_call(
        fn,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, kdim), lambda mi, ni: (mi, 0)),
            pl.BlockSpec((kdim, block_n), lambda mi, ni: (0, ni)),
            pl.BlockSpec((1, block_n), lambda mi, ni: (0, ni)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda mi, ni: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w_q, w_scale[None, :])


def int8_weight_only_matmul(x, w_q, w_scale, *, block_m: int = 64,
                            block_n: int = 128, block_k: int = 128,
                            interpret: bool = True):
    """x [M, K] f32 @ dequant(w_q [K, N] int8, w_scale [N]) → [M, N] f32."""
    return _tiled_call(_wo_kernel, x, w_q, w_scale, block_m, block_n,
                       block_k, interpret)


def int8_dynamic_matmul(x, w_q, w_scale, *, block_m: int = 64,
                        block_n: int = 128, block_k: int = 128,
                        interpret: bool = True):
    """Dynamic-activation int8 GEMM; matches ref.int8_dynamic_matmul_ref."""
    return _tiled_call(_dyn_kernel, x, w_q, w_scale, block_m, block_n,
                       block_k, interpret)
