"""Fused HSTU pointwise attention Pallas kernel (L1).

The paper (§4.1.1) reports that for HSTU the bottlenecks are (a) the
attention GEMMs and (b) *construction of the relative attention bias*,
which is memory-bound when materialized as an [H, S, S] tensor. Their fix
fuses relative-bias construction with the grouped GEMMs in one GPU kernel.

This kernel reproduces that fusion on the TPU model: one program per
(batch, head, q-block); KV tiles stream through VMEM and the bucketed
relative bias is *computed in-register* from the [H, n_buckets] table —
the [S, S] bias matrix never exists in memory. Weighting is HSTU's
pointwise-normalized ``silu(qk^T + rab) / N`` (no softmax → no online
max/denominator carry is even needed; the reduction is a plain sum).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hstu_kernel(
    seq_len_ref,   # [B] int32 valid lengths
    rab_table_ref,  # [1, n_buckets] bias table for this head
    q_ref,         # [1, 1, block_q, D]
    k_ref,         # [1, 1, S, D]
    v_ref,         # [1, 1, S, D]
    o_ref,         # [1, 1, block_q, D]
    *,
    block_k: int,
    s: int,
    n_buckets: int,
    scale: float,
    window: int,
):
    b = pl.program_id(0)
    qi = pl.program_id(2)
    block_q = q_ref.shape[2]
    d = q_ref.shape[3]

    q = q_ref[0, 0, :, :].astype(jnp.float32) * scale
    valid_len = seq_len_ref[b]
    table = rab_table_ref[0, :].astype(jnp.float32)  # [n_buckets]

    qpos = qi * block_q + jax.lax.iota(jnp.int32, block_q)
    acc0 = jnp.zeros((block_q, d), dtype=jnp.float32)
    n_kb = s // block_k

    def body(kb, acc):
        k_tile = k_ref[0, 0, pl.dslice(kb * block_k, block_k), :].astype(
            jnp.float32
        )
        v_tile = v_ref[0, 0, pl.dslice(kb * block_k, block_k), :].astype(
            jnp.float32
        )
        sc = q @ k_tile.T  # [block_q, block_k]

        kpos = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        # In-register relative bias: bucket(i - j) clipped causally.
        dist = jnp.clip(qpos[:, None] - kpos[None, :], 0, n_buckets - 1)
        sc = sc + table[dist]

        w = jax.nn.silu(sc)
        mask = jnp.logical_and(
            kpos[None, :] <= qpos[:, None],
            kpos[None, :] < valid_len,
        )
        if window > 0:
            mask = jnp.logical_and(mask, kpos[None, :] > qpos[:, None] - window)
        w = jnp.where(mask, w, 0.0)
        return acc + w @ v_tile

    acc = jax.lax.fori_loop(0, n_kb, body, acc0)
    # Pointwise normalization by the per-row count of valid causal
    # (windowed) key positions: |[lo, hi)| with lo = max(0, q-window+1),
    # hi = min(q+1, valid_len).
    lo = jnp.maximum(qpos - window + 1, 0) if window > 0 else \
        jnp.zeros_like(qpos)
    hi = jnp.minimum(qpos + 1, jnp.maximum(valid_len, 1))
    n = jnp.maximum(hi - lo, 1).astype(jnp.float32)
    o_ref[0, 0, :, :] = (acc / n[:, None]).astype(o_ref.dtype)


def hstu_attention(
    q,
    k,
    v,
    rab_table,
    *,
    seq_len=None,
    window=None,
    block_q: int = 64,
    block_k: int = 64,
    interpret: bool = True,
):
    """Fused HSTU spatial aggregation.

    q/k/v: [B, H, S, D]; rab_table: [H, n_buckets] bucketed bias table.
    ``seq_len``: [B] int32 valid lengths (defaults to S). ``window``:
    optional static sliding-window size (later-layer cap).
    Matches ``ref.hstu_attention_ref`` with
    ``rab = ref.relative_bias_ref(rab_table, S)``.
    """
    b, h, s, d = q.shape
    n_buckets = rab_table.shape[1]
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    if s % block_q or s % block_k:
        raise ValueError(f"S={s} not divisible by blocks ({block_q},{block_k})")
    if seq_len is None:
        seq_len = jnp.full((b,), s, dtype=jnp.int32)
    scale = 1.0 / (d ** 0.5)

    grid = (b, h, s // block_q)
    kernel = functools.partial(
        _hstu_kernel, block_k=block_k, s=s, n_buckets=n_buckets, scale=scale,
        window=int(window) if window else 0,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b,), lambda bi, hi, qi: (0,)),
            pl.BlockSpec((1, n_buckets), lambda bi, hi, qi: (hi, 0)),
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bi, hi, qi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bi, hi, qi: (bi, hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        interpret=interpret,
    )(seq_len.astype(jnp.int32), rab_table, q, k, v)
