"""Flash-style tiled attention Pallas kernel (the paper's SDPA lever, L1).

The paper accelerates attention with PyTorch SDPA / FlashAttention, whose
core idea is to never materialize the [Sq, Sk] score matrix: stream KV tiles
through fast on-chip memory while carrying an online-softmax running max and
denominator. On TPU the "fast on-chip memory" is VMEM and the tile schedule
is expressed with BlockSpecs instead of CUDA threadblocks (DESIGN.md
§Hardware-Adaptation).

Grid layout: one program per (batch, head, q-block); the kernel loops over
KV blocks with ``jax.lax.fori_loop``, so VMEM residency is
    q_tile [Bq, D] + k_tile/v_tile [Bk, D] + acc [Bq, D] + m/l [Bq]
independent of sequence length.

Lowered with ``interpret=True`` — CPU PJRT cannot execute Mosaic
custom-calls; real-TPU efficiency is estimated in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(
    kv_len_ref,   # [B] int32 valid KV lengths
    q_start_ref,  # [B] int32 absolute position of query row 0 (causal offset)
    q_ref,        # [1, 1, block_q, D]
    k_ref,        # [1, 1, Sk, D]   (full K for this (b, h); tiled in-loop)
    v_ref,        # [1, 1, Sk, D]
    o_ref,        # [1, 1, block_q, D]
    *,
    block_k: int,
    sk: int,
    causal: bool,
    scale: float,
):
    b = pl.program_id(0)
    qi = pl.program_id(2)
    block_q = q_ref.shape[2]
    d = q_ref.shape[3]

    q = q_ref[0, 0, :, :].astype(jnp.float32) * scale
    valid_len = kv_len_ref[b]
    q_start = q_start_ref[b]

    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, d), dtype=jnp.float32)

    n_kb = sk // block_k

    def body(kb, carry):
        m, l, acc = carry
        k_tile = k_ref[0, 0, pl.dslice(kb * block_k, block_k), :].astype(
            jnp.float32
        )
        v_tile = v_ref[0, 0, pl.dslice(kb * block_k, block_k), :].astype(
            jnp.float32
        )
        s = q @ k_tile.T  # [block_q, block_k]

        kpos = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = kpos[None, :] < valid_len
        if causal:
            # Query row r has absolute position q_start + qi*block_q + r
            # (q_start = 0 for prefill where Sq == Sk; q_start = pos for a
            # verify window sliding over a static KV cache).
            qpos = q_start + qi * block_q + jax.lax.iota(jnp.int32, block_q)
            mask = jnp.logical_and(mask, kpos[None, :] <= qpos[:, None])
        s = jnp.where(mask, s, NEG_INF)

        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + p @ v_tile
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, n_kb, body, (m0, l0, acc0))
    l = jnp.maximum(l, 1e-30)
    o_ref[0, 0, :, :] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q,
    k,
    v,
    *,
    kv_len=None,
    q_start=None,
    causal: bool = False,
    block_q: int = 64,
    block_k: int = 64,
    interpret: bool = True,
):
    """Tiled attention. q: [B, H, Sq, D], k/v: [B, H, Sk, D].

    ``kv_len``: [B] int32 number of valid KV entries (defaults to Sk).
    ``q_start``: [B] int32 absolute position of the first query row (only
    used when ``causal``; defaults to 0, the prefill case).
    Returns [B, H, Sq, D]. Shapes must tile evenly (pad upstream).
    """
    b, h, sq, d = q.shape
    sk = k.shape[2]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    if sq % block_q or sk % block_k:
        raise ValueError(f"shape ({sq},{sk}) not divisible by blocks "
                         f"({block_q},{block_k})")
    if kv_len is None:
        kv_len = jnp.full((b,), sk, dtype=jnp.int32)
    if q_start is None:
        q_start = jnp.zeros((b,), dtype=jnp.int32)
    scale = 1.0 / (d ** 0.5)

    grid = (b, h, sq // block_q)
    kernel = functools.partial(
        _flash_kernel, block_k=block_k, sk=sk, causal=causal, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b,), lambda bi, hi, qi: (0,)),
            pl.BlockSpec((b,), lambda bi, hi, qi: (0,)),
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, sk, d), lambda bi, hi, qi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, sk, d), lambda bi, hi, qi: (bi, hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), q_start.astype(jnp.int32), q, k, v)


def vmem_footprint_bytes(block_q: int, block_k: int, d: int,
                         dtype_bytes: int = 4) -> int:
    """Static VMEM footprint estimate for one kernel program (EXPERIMENTS.md
    §Perf L1): q tile, k/v tiles, accumulator, m/l carries, output tile."""
    q_t = block_q * d * dtype_bytes
    kv_t = 2 * block_k * d * dtype_bytes
    acc = block_q * d * 4
    carries = 2 * block_q * 4
    out = block_q * d * dtype_bytes
    return q_t + kv_t + acc + carries + out


def mxu_utilization_estimate(block_q: int, block_k: int, d: int) -> float:
    """Fraction of each 128x128 MXU issue that is useful work for the two
    kernel matmuls (qk^T and pV)."""
    def eff(m, n, kk):
        pad = lambda x: ((x + 127) // 128) * 128
        return (m * n * kk) / (pad(m) * pad(n) * pad(kk))
    return 0.5 * (eff(block_q, block_k, d) + eff(block_q, d, block_k))
