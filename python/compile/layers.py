"""Shared L2 building blocks: RMSNorm, RoPE, SwiGLU, linear variants.

Every function is pure jnp over explicit parameter arrays (no module
state) so stages can be lowered with weights as ordinary positional
inputs — the Rust runtime feeds them from artifacts/<model>/weights.bin
in manifest order.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import ref as kref
from .kernels.attention import flash_attention
from .kernels.quant import int8_dynamic_matmul, int8_weight_only_matmul


def rmsnorm(x, weight, eps: float = 1e-5):
    """Root-mean-square layer norm (paper: Chameleon/Llama use RMSNorm)."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * weight


def layernorm(x, weight, bias, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * weight + bias


def rope_tables(max_seq: int, head_dim: int, theta: float = 10000.0):
    """Precomputed rotary cos/sin tables [max_seq, head_dim/2]."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2) / head_dim))
    t = jnp.arange(max_seq, dtype=jnp.float32)
    freqs = jnp.outer(t, inv)
    return jnp.cos(freqs), jnp.sin(freqs)


def apply_rope(x, positions, cos_tab, sin_tab):
    """Rotary positional embedding. x: [B, H, S, D]; positions: [B, S]."""
    cos = cos_tab[positions][:, None]  # [B, 1, S, D/2]
    sin = sin_tab[positions][:, None]
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


# --------------------------------------------------------------------------
# Linear variants (f32 / int8 weight-only / int8 dynamic) — the AutoQuant
# lever. mode is baked at lowering time; each produces a distinct HLO stage.
# --------------------------------------------------------------------------

LINEAR_MODES = ("f32", "int8_weight_only", "int8_dynamic")


def linear(x, w, *, mode: str = "f32", w_scale=None, use_kernel: bool = True):
    """x [..., K] @ w.

    f32 mode: w is [K, N] f32. int8 modes: w is [K, N] int8 and ``w_scale``
    [N] f32 must be given. ``use_kernel`` routes int8 through the Pallas
    kernels (interpret mode); the plain-jnp path is the oracle.
    """
    if mode == "f32":
        return x @ w
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if mode == "int8_weight_only":
        fn = int8_weight_only_matmul if use_kernel else \
            kref.int8_weight_only_matmul_ref
    elif mode == "int8_dynamic":
        fn = int8_dynamic_matmul if use_kernel else \
            kref.int8_dynamic_matmul_ref
    else:
        raise ValueError(f"unknown linear mode {mode!r}")
    if use_kernel:
        # Pallas tiles must divide the problem shape exactly; pick the
        # largest power-of-two block that divides each dim.
        def blk(n, cap):
            b = 1
            while b * 2 <= cap and n % (b * 2) == 0:
                b *= 2
            return b
        m, kk = x2.shape
        n = w.shape[1]
        out = fn(x2, w, w_scale, block_m=blk(m, 64), block_n=blk(n, 128),
                 block_k=blk(kk, 128))
    else:
        out = fn(x2, w, w_scale)
    return out.reshape(*lead, w.shape[1])


def swiglu_ffn(x, w_gate, w_up, w_down, *, mode: str = "f32", scales=None):
    """SwiGLU feed-forward (paper: Chameleon/Llama use SwiGLU)."""
    if scales is None:
        scales = {}
    g = linear(x, w_gate, mode=mode, w_scale=scales.get("gate"))
    u = linear(x, w_up, mode=mode, w_scale=scales.get("up"))
    h = jax.nn.silu(g) * u
    return linear(h, w_down, mode=mode, w_scale=scales.get("down"))


# --------------------------------------------------------------------------
# Attention dispatch — the SDPA lever. "naive" materializes the score
# matrix (baseline); "flash" is the tiled Pallas kernel.
# --------------------------------------------------------------------------

ATTN_IMPLS = ("naive", "flash")


def attention(q, k, v, *, impl: str = "naive", causal: bool = False,
              kv_len=None, q_start=None):
    if impl == "flash":
        return flash_attention(q, k, v, causal=causal, kv_len=kv_len,
                               q_start=q_start)
    if impl == "naive":
        if causal and q_start is not None and q.shape[2] != k.shape[2]:
            # Offset-causal (verify window over a static cache): build the
            # mask explicitly.
            b, h, sq, d = q.shape
            sk = k.shape[2]
            scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(
                jnp.array(d, q.dtype))
            kpos = jnp.arange(sk)[None, None, None, :]
            qpos = q_start[:, None, None, None] + \
                jnp.arange(sq)[None, None, :, None]
            mask = kpos <= qpos
            if kv_len is not None:
                mask = jnp.logical_and(
                    mask, kpos < kv_len[:, None, None, None])
            scores = jnp.where(mask, scores, -1e30)
            p = jax.nn.softmax(scores, axis=-1)
            return jnp.einsum("bhqk,bhkd->bhqd", p, v)
        return kref.sdpa_ref(q, k, v, causal=causal, kv_len=kv_len)
    raise ValueError(f"unknown attention impl {impl!r}")


def update_kv_cache(cache_k, cache_v, new_k, new_v, positions):
    """Static-cache update (the CUDA-Graph-enabling trick, paper §4.1.2).

    cache_k/v: [B, H, max_seq, D]; new_k/v: [B, H, S, D];
    positions: [B] int32 start offsets per slot. vmap'd
    dynamic_update_slice keeps the lowered HLO fully shape-static.
    """
    def upd(c, n, p):
        return jax.lax.dynamic_update_slice(c, n, (0, p, 0))
    ck = jax.vmap(upd)(cache_k, new_k, positions)
    cv = jax.vmap(upd)(cache_v, new_v, positions)
    return ck, cv


def update_kv_cache_stacked(cache, new, positions, layer: int):
    """In-place-friendly update of a stacked [L, B, H, max_seq, D] cache.

    Writes only the [1, H, S_new, D] slab per batch element directly into
    the 5D tensor (no layer-slice extract/reinsert, which would copy the
    whole layer every step — the §Perf L2 fix). With the stage's
    input_output_alias donation, XLA performs this without copying the
    cache at all.
    """
    def upd(c, n, p):
        # c: [L, H, max_seq, D] (one batch element), n: [H, S_new, D]
        return jax.lax.dynamic_update_slice(
            c, n[None], (jnp.int32(layer), jnp.int32(0), p, jnp.int32(0)))
    return jax.vmap(upd, in_axes=(1, 0, 0), out_axes=1)(cache, new,
                                                        positions)
