"""Model configurations for the four multimodal model families.

Two tiers per family:

* ``tiny_*``  — architecture-faithful scaled-down configs that the Rust
  coordinator actually serves on the PJRT CPU client (real end-to-end
  latency/throughput numbers come from these).
* ``paper_*`` — the published dimensions (Code Llama 7B/34B, Chameleon
  7B/34B, Seamless M4T-large, HSTU-14L). These are never executed on CPU;
  they parameterize the analytical A100/H100 device model on the Rust side
  and are exported into the artifact manifests so both sides agree on the
  paper-scale operator walks.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional


@dataclass(frozen=True)
class DecoderConfig:
    """Decoder-only transformer (Llama / Chameleon family)."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    head_dim: int
    ffn_hidden: int          # SwiGLU hidden size
    vocab_size: int
    max_seq: int             # static KV-cache capacity
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # LayerSkip parameters
    early_exit_layer: int = 2   # draft uses layers [0, early_exit_layer)
    verify_window: int = 4      # draft tokens verified per verify pass
    # Graph-mode decode batch sizes compiled ahead of time.
    decode_batch_sizes: tuple = (1, 4)
    prefill_buckets: tuple = (32, 128)
    # Chameleon-specific: number of image tokens emitted by the (tiny)
    # image tokenizer; 0 for pure-text models.
    image_tokens: int = 0

    @property
    def kv_bytes_per_token(self) -> int:
        return self.n_layers * 2 * self.n_heads * self.head_dim * 4


@dataclass(frozen=True)
class SeamlessConfig:
    """Seamless M4T-style four-module pipeline."""

    name: str
    d_model: int
    # Conformer speech encoder
    enc_layers: int
    enc_feat_dim: int        # input filterbank feature dim (paper: 160)
    enc_subsample: int       # conv front-end subsampling factor
    conv_kernel: int         # depthwise conv kernel in conformer block
    # Autoregressive text decoder (the only AR module)
    dec_layers: int
    n_heads: int
    head_dim: int
    ffn_hidden: int
    text_vocab: int
    max_src: int             # encoder-output capacity (cross-attn length)
    max_tgt: int             # decoder static KV capacity
    beam_size: int
    # NAR text-to-unit
    t2u_layers: int
    t2u_upsample: int        # units per text token (fixed-ratio upsampler)
    unit_vocab: int
    # Vocoder (HiFi-GAN-style conv upsampler)
    voc_channels: int
    voc_stages: int
    voc_upsample: int        # per-stage upsampling factor
    norm_eps: float = 1e-5
    encoder_buckets: tuple = (64, 256)


@dataclass(frozen=True)
class HstuConfig:
    """HSTU generative-recommender stack (non-autoregressive)."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    head_dim: int
    item_vocab: int
    action_vocab: int        # engagement types for the ranking head
    max_seq: int
    # Paper §3.1: later layers cap the sequence length for speed.
    full_len_layers: int     # first k layers see the full sequence
    capped_len: int          # remaining layers see at most this many tokens
    rel_buckets: int = 32    # relative-attention-bias buckets
    norm_eps: float = 1e-5
    forward_buckets: tuple = (256, 1024)
    batch_sizes: tuple = (1, 8)


# --------------------------------------------------------------------------
# Tiny (CPU-served) configurations
# --------------------------------------------------------------------------

TINY_LLAMA = DecoderConfig(
    name="llama",
    n_layers=4,
    d_model=256,
    n_heads=8,
    head_dim=32,
    ffn_hidden=688,
    vocab_size=512,
    max_seq=512,
    early_exit_layer=2,
    verify_window=4,
    decode_batch_sizes=(1, 4),
    prefill_buckets=(32, 128),
)

# Chameleon shares the Llama-2 architecture (paper §2.1.2); the tiny image
# tokenizer emits an 8x8 grid = 64 image tokens (paper: 32x32 = 1024).
TINY_CHAMELEON = dataclasses.replace(
    TINY_LLAMA,
    name="chameleon",
    image_tokens=64,
    prefill_buckets=(32, 128),
)

TINY_SEAMLESS = SeamlessConfig(
    name="seamless",
    d_model=256,
    enc_layers=4,
    enc_feat_dim=80,
    enc_subsample=4,
    conv_kernel=7,
    dec_layers=4,
    n_heads=8,
    head_dim=32,
    ffn_hidden=688,
    text_vocab=512,
    max_src=128,
    max_tgt=128,
    beam_size=4,
    t2u_layers=2,
    t2u_upsample=4,
    unit_vocab=256,
    voc_channels=64,
    voc_stages=3,
    voc_upsample=2,
)

TINY_HSTU = HstuConfig(
    name="hstu",
    n_layers=4,
    d_model=256,
    n_heads=8,
    head_dim=32,
    item_vocab=6000,
    action_vocab=16,
    max_seq=1024,
    full_len_layers=1,
    capped_len=256,
    forward_buckets=(256, 1024),
    batch_sizes=(1, 8),
)

# --------------------------------------------------------------------------
# Paper-scale configurations (device-model only; exported to manifests)
# --------------------------------------------------------------------------

PAPER_LLAMA_7B = DecoderConfig(
    name="llama-7b", n_layers=32, d_model=4096, n_heads=32, head_dim=128,
    ffn_hidden=11008, vocab_size=32016, max_seq=16384,
    early_exit_layer=8, verify_window=8,
)
PAPER_LLAMA_34B = DecoderConfig(
    name="llama-34b", n_layers=48, d_model=8192, n_heads=64, head_dim=128,
    ffn_hidden=22016, vocab_size=32016, max_seq=16384,
    early_exit_layer=12, verify_window=8,
)
PAPER_CHAMELEON_7B = dataclasses.replace(
    PAPER_LLAMA_7B, name="chameleon-7b", vocab_size=65536, image_tokens=1024,
)
PAPER_CHAMELEON_34B = dataclasses.replace(
    PAPER_LLAMA_34B, name="chameleon-34b", vocab_size=65536, image_tokens=1024,
)
PAPER_SEAMLESS = SeamlessConfig(
    name="seamless-m4t-large",
    d_model=1024,
    enc_layers=24, enc_feat_dim=160, enc_subsample=2, conv_kernel=31,
    dec_layers=24, n_heads=16, head_dim=64, ffn_hidden=8192,
    text_vocab=256000, max_src=4096, max_tgt=1024, beam_size=5,
    t2u_layers=6, t2u_upsample=8, unit_vocab=10000,
    voc_channels=512, voc_stages=4, voc_upsample=4,
)
PAPER_HSTU = HstuConfig(
    name="hstu-14l",
    n_layers=14, d_model=512, n_heads=8, head_dim=64,
    item_vocab=6000, action_vocab=16, max_seq=8192,
    full_len_layers=3, capped_len=1024,
)

TINY = {
    "llama": TINY_LLAMA,
    "chameleon": TINY_CHAMELEON,
    "seamless": TINY_SEAMLESS,
    "hstu": TINY_HSTU,
}

PAPER = {
    "llama-7b": PAPER_LLAMA_7B,
    "llama-34b": PAPER_LLAMA_34B,
    "chameleon-7b": PAPER_CHAMELEON_7B,
    "chameleon-34b": PAPER_CHAMELEON_34B,
    "seamless-m4t-large": PAPER_SEAMLESS,
    "hstu-14l": PAPER_HSTU,
}


def config_to_dict(cfg) -> dict:
    d = dataclasses.asdict(cfg)
    for k, v in d.items():
        if isinstance(v, tuple):
            d[k] = list(v)
    d["kind"] = type(cfg).__name__
    return d
