"""weights.bin — the build-time → runtime parameter interchange format.

A deliberately simple little-endian container the Rust side
(rust/src/runtime/weights.rs) parses without external crates:

    magic   4 bytes  b"MMWB"
    version u32      1
    count   u32      number of tensors
    then per tensor:
      name_len u16, name utf-8 bytes
      dtype    u8   (0 = f32, 1 = i8, 2 = i32)
      ndim     u8
      dims     u32 * ndim
      nbytes   u64
      data     raw little-endian bytes

Tensor order in the file is the manifest's canonical weight order.
"""

from __future__ import annotations

import struct
from typing import Dict, List

import numpy as np

MAGIC = b"MMWB"
VERSION = 1
DTYPE_CODES = {"float32": 0, "int8": 1, "int32": 2}
CODE_DTYPES = {v: k for k, v in DTYPE_CODES.items()}


def save(path: str, tensors: Dict[str, np.ndarray],
         order: List[str]) -> None:
    assert set(order) == set(tensors), (
        sorted(set(order) ^ set(tensors)))
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(order)))
        for name in order:
            arr = np.ascontiguousarray(tensors[name])
            code = DTYPE_CODES[str(arr.dtype)]
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", code, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            raw = arr.tobytes()
            f.write(struct.pack("<Q", len(raw)))
            f.write(raw)


def load(path: str) -> Dict[str, np.ndarray]:
    """Round-trip reader (used by tests; Rust has its own parser)."""
    out: Dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad magic"
        version, count = struct.unpack("<II", f.read(8))
        assert version == VERSION
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode()
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim \
                else ()
            (nbytes,) = struct.unpack("<Q", f.read(8))
            data = f.read(nbytes)
            out[name] = np.frombuffer(
                data, dtype=CODE_DTYPES[code]).reshape(dims).copy()
    return out
