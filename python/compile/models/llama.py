"""Code Llama / Chameleon decoder (L2).

Decoder-only transformer with RMSNorm, RoPE and SwiGLU (paper §2.1.1,
§2.1.2 — Chameleon "largely follows Llama-2", so both families share this
module; they differ only in config and in how L3 drives decoding —
Chameleon T-I runs the decode graph twice per step for contrastive
decoding).

Stages lowered by aot.py (all shape-static, static KV cache):

* ``prefill_b{P}``   tokens[1,P], prompt_len[1] → last-token logits + KV
* ``decode_b{B}``    tokens[B], positions[B], KV → logits[B,V] + KV'
* ``draft_b1``       early-exit decode: first E layers + shared LM head
* ``verify_k{K}``    K-token window through the full model (LayerSkip)
* eager per-op stages (embed / norm / qkv+rope / attn_step / oproj /
  ffn / head) — the "one dispatch per operator" baseline that shows the
  paper's GPU-idle / launch-overhead effect (Obs #2).

KV cache layout: stacked ``[L, B, H, max_seq, Dh]`` for K and V.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import DecoderConfig
from ..kernels.ref import quantize_weight
from ..layers import (apply_rope, attention, linear, rmsnorm, rope_tables,
                      swiglu_ffn, update_kv_cache,
                      update_kv_cache_stacked)


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def param_specs(cfg: DecoderConfig):
    """Ordered (name, shape) list — the canonical weights.bin order."""
    d, f, v = cfg.d_model, cfg.ffn_hidden, cfg.vocab_size
    specs = [("embed", (v, d))]
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        specs += [
            (p + "attn_norm", (d,)),
            (p + "wq", (d, d)),
            (p + "wk", (d, d)),
            (p + "wv", (d, d)),
            (p + "wo", (d, d)),
            (p + "ffn_norm", (d,)),
            (p + "w_gate", (d, f)),
            (p + "w_up", (d, f)),
            (p + "w_down", (f, d)),
        ]
    specs += [("final_norm", (d,)), ("lm_head", (d, v))]
    return specs

QUANTIZABLE = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "lm_head")


def init_params(cfg: DecoderConfig, seed: int = 0,
                early_exit_friendly: bool = True) -> Dict[str, np.ndarray]:
    """Random weights, optionally "LayerSkip-finetuned" in structure.

    The paper's LayerSkip recipe (layer dropout + early-exit loss over
    50K iterations on 64 GPUs) trains the model so the first E layers
    already predict well. We cannot train, so we reproduce the
    *property* the recipe creates: with ``early_exit_friendly``, layers
    ≥ E get down-scaled output projections, making the truncated model
    agree with the full model often enough for speculative acceptance —
    the serving-side behaviour LayerSkip's training buys
    (DESIGN.md §Substitutions).
    """
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in param_specs(cfg):
        if name.endswith("norm"):
            params[name] = np.ones(shape, np.float32)
        else:
            std = 0.02 if name in ("embed", "lm_head") else \
                1.0 / np.sqrt(shape[0])
            params[name] = rng.normal(0, std, shape).astype(np.float32)
    if early_exit_friendly:
        for i in range(cfg.early_exit_layer, cfg.n_layers):
            for leaf in ("wo", "w_down"):
                params[f"layers.{i}.{leaf}"] *= 0.08
    return params


def quantize_params(params: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Int8 per-channel quantization of every linear weight (AutoQuant
    lever). Returns {name+".q": int8, name+".scale": f32} entries."""
    out = {}
    for name, w in params.items():
        base = name.split(".")[-1]
        if base in QUANTIZABLE and w.ndim == 2:
            q, s = quantize_weight(jnp.asarray(w))
            out[name + ".q"] = np.asarray(q)
            out[name + ".scale"] = np.asarray(s)
    return out


# --------------------------------------------------------------------------
# Forward pieces
# --------------------------------------------------------------------------

def _layer_weights(params, i, quant: bool):
    p = f"layers.{i}."
    if not quant:
        return {k: params[p + k] for k in
                ("attn_norm", "wq", "wk", "wv", "wo", "ffn_norm",
                 "w_gate", "w_up", "w_down")}
    w = {"attn_norm": params[p + "attn_norm"],
         "ffn_norm": params[p + "ffn_norm"]}
    for k in ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"):
        w[k] = params[p + k + ".q"]
        w[k + "_scale"] = params[p + k + ".scale"]
    return w


def _split_heads(x, n_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


def decoder_layer(cfg: DecoderConfig, w, x, positions, ck, cv, layer, *,
                  attn_impl: str, causal: bool, kv_len, q_start,
                  linear_mode: str = "f32"):
    """One transformer block writing into the stacked caches
    ck/cv [L, B, H, max_seq, Dh] at ``layer`` (small in-place
    dynamic-update-slice — the §Perf L2 hot-path fix).

    ``positions``: [B, S] absolute positions of the new tokens (for RoPE +
    cache writes, contiguous per sample). Returns (x', ck', cv')."""
    lm = linear_mode
    sc = (lambda k: w.get(k + "_scale")) if lm != "f32" else (lambda k: None)
    h = rmsnorm(x, w["attn_norm"], cfg.norm_eps)
    q = linear(h, w["wq"], mode=lm, w_scale=sc("wq"))
    k = linear(h, w["wk"], mode=lm, w_scale=sc("wk"))
    v = linear(h, w["wv"], mode=lm, w_scale=sc("wv"))
    q = _split_heads(q, cfg.n_heads, cfg.head_dim)
    k = _split_heads(k, cfg.n_heads, cfg.head_dim)
    v = _split_heads(v, cfg.n_heads, cfg.head_dim)
    cos, sin = rope_tables(cfg.max_seq, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, positions, cos, sin)
    k = apply_rope(k, positions, cos, sin)

    start = positions[:, 0]
    ck = update_kv_cache_stacked(ck, k, start, layer)
    cv = update_kv_cache_stacked(cv, v, start, layer)
    a = attention(q, ck[layer], cv[layer], impl=attn_impl, causal=causal,
                  kv_len=kv_len, q_start=q_start)
    x = x + linear(_merge_heads(a), w["wo"], mode=lm, w_scale=sc("wo"))

    h = rmsnorm(x, w["ffn_norm"], cfg.norm_eps)
    scales = {"gate": sc("w_gate"), "up": sc("w_up"), "down": sc("w_down")} \
        if lm != "f32" else None
    x = x + swiglu_ffn(h, w["w_gate"], w["w_up"], w["w_down"], mode=lm,
                       scales=scales)
    return x, ck, cv


def forward(cfg: DecoderConfig, params, tokens, positions, ck, cv, *,
            attn_impl: str, kv_len, q_start, causal: bool,
            n_layers=None, linear_mode: str = "f32"):
    """Run ``n_layers`` (default all) blocks. tokens: [B, S] int32;
    ck/cv: [L, B, H, max_seq, Dh]. Returns (hidden [B,S,D], ck', cv')."""
    quant = linear_mode != "f32"
    nl = cfg.n_layers if n_layers is None else n_layers
    x = params["embed"][tokens]
    for i in range(nl):
        w = _layer_weights(params, i, quant)
        x, ck, cv = decoder_layer(
            cfg, w, x, positions, ck, cv, i, attn_impl=attn_impl,
            causal=causal, kv_len=kv_len, q_start=q_start,
            linear_mode=linear_mode)
    return x, ck, cv


def lm_logits(cfg, params, x, *, linear_mode: str = "f32"):
    h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if linear_mode == "f32":
        return linear(h, params["lm_head"])
    return linear(h, params["lm_head.q"], mode=linear_mode,
                  w_scale=params["lm_head.scale"])


# --------------------------------------------------------------------------
# Stage builders (closures over param *names*; aot.py lowers them with
# weights as leading positional inputs)
# --------------------------------------------------------------------------

def kv_shape(cfg: DecoderConfig, batch: int):
    return (cfg.n_layers, batch, cfg.n_heads, cfg.max_seq, cfg.head_dim)


def make_prefill(cfg: DecoderConfig, prompt_bucket: int, *,
                 attn_impl: str = "naive", linear_mode: str = "f32"):
    """Returns fn(params, tokens[1,P], prompt_len[1]) →
    (logits[1,V], ck, cv). The prompt is right-padded to the bucket; the
    causal mask plus prompt_len-based gather make padding inert."""

    def fn(params, tokens, prompt_len):
        b = tokens.shape[0]
        ck = jnp.zeros(kv_shape(cfg, b), jnp.float32)
        cv = jnp.zeros(kv_shape(cfg, b), jnp.float32)
        positions = jnp.broadcast_to(
            jnp.arange(prompt_bucket, dtype=jnp.int32)[None], tokens.shape)
        # q_start=0: queries are start-aligned in the max_seq-wide static
        # cache (the end-aligned default of sdpa_ref would be wrong here).
        x, ck, cv = forward(
            cfg, params, tokens, positions, ck, cv, attn_impl=attn_impl,
            kv_len=prompt_len.astype(jnp.int32),
            q_start=jnp.zeros((b,), jnp.int32), causal=True,
            linear_mode=linear_mode)
        last = jnp.take_along_axis(
            x, (prompt_len.astype(jnp.int32) - 1)[:, None, None]
            .clip(0), axis=1)[:, 0]
        logits = lm_logits(cfg, params, last, linear_mode=linear_mode)
        return logits, ck, cv

    return fn


def make_decode(cfg: DecoderConfig, batch: int, *, attn_impl: str = "naive",
                linear_mode: str = "f32", n_layers=None,
                early_exit: bool = False):
    """Returns fn(params, tokens[B], positions[B], ck, cv) →
    (logits[B,V], ck', cv'). ``early_exit`` builds the LayerSkip draft
    stage: only the first E layers run, then the shared LM head."""
    nl = cfg.early_exit_layer if early_exit else n_layers

    def fn(params, tokens, positions, ck, cv):
        pos2 = positions.astype(jnp.int32)[:, None]
        x, ck, cv = forward(
            cfg, params, tokens[:, None], pos2, ck, cv,
            attn_impl=attn_impl, kv_len=positions.astype(jnp.int32) + 1,
            q_start=positions.astype(jnp.int32), causal=False,
            n_layers=nl, linear_mode=linear_mode)
        logits = lm_logits(cfg, params, x[:, 0], linear_mode=linear_mode)
        return logits, ck, cv

    return fn


def make_verify(cfg: DecoderConfig, window: int, *,
                attn_impl: str = "naive", linear_mode: str = "f32"):
    """LayerSkip verify stage: fn(params, tokens[1,K], start_pos[1], ck, cv)
    → (logits[1,K,V], ck', cv'). All K draft tokens go through the full
    model in one pass (the speculative-decoding amortization)."""

    def fn(params, tokens, start_pos, ck, cv):
        start = start_pos.astype(jnp.int32)
        positions = start[:, None] + jnp.arange(window, dtype=jnp.int32)[None]
        x, ck, cv = forward(
            cfg, params, tokens, positions, ck, cv, attn_impl=attn_impl,
            kv_len=start + window, q_start=start, causal=True,
            linear_mode=linear_mode)
        logits = lm_logits(cfg, params, x, linear_mode=linear_mode)
        return logits, ck, cv

    return fn


def make_kv_pack(cfg: DecoderConfig, batch: int):
    """Insert a freshly-prefilled single-slot cache into batch slot
    ``slot`` — the continuous-batching admission op.

    fn(ck[L,B,H,S,Dh], cv, ck1[L,1,H,S,Dh], cv1, slot[1]) → (ck', cv')."""

    def fn(ck, cv, ck1, cv1, slot):
        s = slot.astype(jnp.int32)[0]
        z = jnp.int32(0)
        ck = jax.lax.dynamic_update_slice(ck, ck1, (z, s, z, z, z))
        cv = jax.lax.dynamic_update_slice(cv, cv1, (z, s, z, z, z))
        return ck, cv

    return fn


# ---- Eager per-op stages (dispatch-overhead baseline) ---------------------

def make_eager_embed(cfg):
    return lambda embed, tokens: embed[tokens]


def make_eager_norm(cfg):
    return lambda w, x: rmsnorm(x, w, cfg.norm_eps)


def make_eager_qkv(cfg):
    """fn(wq, wk, wv, x[B,D], positions[B]) → q,k,v [B,H,1,Dh], rope'd."""

    def fn(wq, wk, wv, x, positions):
        b = x.shape[0]
        qkv = []
        for w in (wq, wk, wv):
            y = (x @ w).reshape(b, 1, cfg.n_heads, cfg.head_dim)
            qkv.append(y.transpose(0, 2, 1, 3))
        q, k, v = qkv
        cos, sin = rope_tables(cfg.max_seq, cfg.head_dim, cfg.rope_theta)
        pos2 = positions.astype(jnp.int32)[:, None]
        return apply_rope(q, pos2, cos, sin), \
            apply_rope(k, pos2, cos, sin), v

    return fn


def make_eager_attn_step(cfg, *, attn_impl: str = "naive"):
    """fn(q, k, v, positions[B], ck_l, cv_l [B,H,S,Dh]) →
    (attn_out[B,D], ck_l', cv_l') — one layer's cached attention."""

    def fn(q, k, v, positions, ck_l, cv_l):
        pos = positions.astype(jnp.int32)
        ck_l, cv_l = update_kv_cache(ck_l, cv_l, k, v, pos)
        a = attention(q, ck_l, cv_l, impl=attn_impl, kv_len=pos + 1,
                      q_start=pos, causal=False)
        return _merge_heads(a)[:, 0], ck_l, cv_l

    return fn


def make_eager_oproj(cfg):
    return lambda wo, attn_out, resid: resid + attn_out @ wo


def make_eager_ffn(cfg):
    def fn(norm_w, w_gate, w_up, w_down, x):
        h = rmsnorm(x, norm_w, cfg.norm_eps)
        return x + swiglu_ffn(h, w_gate, w_up, w_down)
    return fn


def make_eager_head(cfg):
    def fn(final_norm, lm_head, x):
        return rmsnorm(x, final_norm, cfg.norm_eps) @ lm_head
    return fn
