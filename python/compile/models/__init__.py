"""L2 model definitions for the four paper families."""
