"""Seamless M4T-style four-module pipeline (L2), paper §2.1.3.

* ``encoder_t{T}``  — Conformer-lite speech encoder (non-AR): conv
  subsampling front-end + blocks of (½FFN, MHSA, depthwise-conv, ½FFN).
* ``cross_kv``      — per-request projection of encoder output to each
  decoder layer's cross-attention K/V (computed once, reused every step).
* ``dec_step_b{B}`` — autoregressive text decoder step over B beams:
  self-attention with static KV cache + cross-attention + FFN. This is the
  *only* AR module (paper Table 1), which is why Seamless shows higher GPU
  utilization than Llama/Chameleon (Obs #2).
* ``kv_reorder_b{B}`` — beam-search KV gather, the operation that dominates
  Seamless inference in the paper (Obs #4). Lowered as its own stage so L3
  can execute it on-device (the paper's torch.compile'd ``copy_`` fix) or
  emulate the baseline host-side ``index_select`` copy.
* ``t2u_t{T}``      — non-autoregressive text-to-unit: fixed-ratio
  upsampling + bidirectional transformer.
* ``vocoder_u{U}``  — HiFi-GAN-flavoured conv upsampler producing a
  waveform from discrete units.

Text decoder uses LayerNorm + GELU (NLLB lineage), not RMSNorm/SwiGLU.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import SeamlessConfig
from ..layers import (attention, layernorm, update_kv_cache,
                      update_kv_cache_stacked)


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def param_specs(cfg: SeamlessConfig):
    d, f = cfg.d_model, cfg.ffn_hidden
    hs = cfg.n_heads * cfg.head_dim
    specs = []
    # Encoder front-end: stack `enc_subsample` frames → project to d.
    specs.append(("enc.frontend.w", (cfg.enc_feat_dim * cfg.enc_subsample, d)))
    specs.append(("enc.frontend.b", (d,)))
    for i in range(cfg.enc_layers):
        p = f"enc.layers.{i}."
        for ffn in ("ffn1", "ffn2"):
            specs += [
                (p + ffn + ".norm.w", (d,)), (p + ffn + ".norm.b", (d,)),
                (p + ffn + ".w1", (d, f)), (p + ffn + ".b1", (f,)),
                (p + ffn + ".w2", (f, d)), (p + ffn + ".b2", (d,)),
            ]
        specs += [
            (p + "attn.norm.w", (d,)), (p + "attn.norm.b", (d,)),
            (p + "attn.wq", (d, hs)), (p + "attn.wk", (d, hs)),
            (p + "attn.wv", (d, hs)), (p + "attn.wo", (hs, d)),
            (p + "conv.norm.w", (d,)), (p + "conv.norm.b", (d,)),
            (p + "conv.pw1", (d, 2 * d)),          # pointwise → GLU
            (p + "conv.dw", (cfg.conv_kernel, d)),  # depthwise
            (p + "conv.pw2", (d, d)),
            (p + "final.norm.w", (d,)), (p + "final.norm.b", (d,)),
        ]
    # Text decoder
    specs.append(("dec.embed", (cfg.text_vocab, d)))
    specs.append(("dec.pos_embed", (cfg.max_tgt, d)))
    for i in range(cfg.dec_layers):
        p = f"dec.layers.{i}."
        specs += [
            (p + "self.norm.w", (d,)), (p + "self.norm.b", (d,)),
            (p + "self.wq", (d, hs)), (p + "self.wk", (d, hs)),
            (p + "self.wv", (d, hs)), (p + "self.wo", (hs, d)),
            (p + "cross.norm.w", (d,)), (p + "cross.norm.b", (d,)),
            (p + "cross.wq", (d, hs)), (p + "cross.wk", (d, hs)),
            (p + "cross.wv", (d, hs)), (p + "cross.wo", (hs, d)),
            (p + "ffn.norm.w", (d,)), (p + "ffn.norm.b", (d,)),
            (p + "ffn.w1", (d, f)), (p + "ffn.b1", (f,)),
            (p + "ffn.w2", (f, d)), (p + "ffn.b2", (d,)),
        ]
    specs += [("dec.final.norm.w", (d,)), ("dec.final.norm.b", (d,)),
              ("dec.lm_head", (d, cfg.text_vocab))]
    # Text encoder (text-input tasks)
    specs.append(("tenc.embed", (cfg.text_vocab, d)))
    for i in range(cfg.t2u_layers):
        p = f"tenc.layers.{i}."
        specs += [
            (p + "attn.norm.w", (d,)), (p + "attn.norm.b", (d,)),
            (p + "attn.wq", (d, hs)), (p + "attn.wk", (d, hs)),
            (p + "attn.wv", (d, hs)), (p + "attn.wo", (hs, d)),
            (p + "ffn.norm.w", (d,)), (p + "ffn.norm.b", (d,)),
            (p + "ffn.w1", (d, f)), (p + "ffn.b1", (f,)),
            (p + "ffn.w2", (f, d)), (p + "ffn.b2", (d,)),
        ]
    specs += [("tenc.final.norm.w", (d,)), ("tenc.final.norm.b", (d,))]
    # NAR T2U
    specs.append(("t2u.embed", (cfg.text_vocab, d)))
    for i in range(cfg.t2u_layers):
        p = f"t2u.layers.{i}."
        specs += [
            (p + "attn.norm.w", (d,)), (p + "attn.norm.b", (d,)),
            (p + "attn.wq", (d, hs)), (p + "attn.wk", (d, hs)),
            (p + "attn.wv", (d, hs)), (p + "attn.wo", (hs, d)),
            (p + "ffn.norm.w", (d,)), (p + "ffn.norm.b", (d,)),
            (p + "ffn.w1", (d, f)), (p + "ffn.b1", (f,)),
            (p + "ffn.w2", (f, d)), (p + "ffn.b2", (d,)),
        ]
    specs.append(("t2u.head", (d, cfg.unit_vocab)))
    # Vocoder
    specs.append(("voc.embed", (cfg.unit_vocab, cfg.voc_channels)))
    ch = cfg.voc_channels
    for i in range(cfg.voc_stages):
        nxt = max(ch // 2, 8)
        specs += [(f"voc.stages.{i}.conv", (7, ch, nxt)),
                  (f"voc.stages.{i}.bias", (nxt,))]
        ch = nxt
    specs += [("voc.out.conv", (7, ch, 1)), ("voc.out.bias", (1,))]
    return specs


def init_params(cfg: SeamlessConfig, seed: int = 1) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in param_specs(cfg):
        if name.endswith("norm.w"):
            params[name] = np.ones(shape, np.float32)
        elif name.endswith((".b", ".b1", ".b2", ".bias", "norm.b")):
            params[name] = np.zeros(shape, np.float32)
        else:
            std = 0.02 if "embed" in name else 1.0 / np.sqrt(
                np.prod(shape[:-1]))
            params[name] = rng.normal(0, std, shape).astype(np.float32)
    return params


# --------------------------------------------------------------------------
# Encoder (conformer-lite)
# --------------------------------------------------------------------------

def _heads(x, cfg):
    b, s, _ = x.shape
    return x.reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)


def _merge(x):
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


def _ffn(p, params, x, eps):
    h = layernorm(x, params[p + ".norm.w"], params[p + ".norm.b"], eps)
    h = jax.nn.gelu(h @ params[p + ".w1"] + params[p + ".b1"])
    return h @ params[p + ".w2"] + params[p + ".b2"]


def _mhsa(p, params, cfg, x, *, mask_len=None, attn_impl="naive"):
    h = layernorm(x, params[p + ".norm.w"], params[p + ".norm.b"],
                  cfg.norm_eps)
    q = _heads(h @ params[p + ".wq"], cfg)
    k = _heads(h @ params[p + ".wk"], cfg)
    v = _heads(h @ params[p + ".wv"], cfg)
    a = attention(q, k, v, impl=attn_impl, kv_len=mask_len)
    return _merge(a) @ params[p + ".wo"]


def _conv_module(p, params, cfg, x, valid_len):
    """Conformer conv module: pointwise-GLU → depthwise → pointwise."""
    h = layernorm(x, params[p + ".norm.w"], params[p + ".norm.b"],
                  cfg.norm_eps)
    h = h @ params[p + ".pw1"]
    a, b = jnp.split(h, 2, axis=-1)
    h = a * jax.nn.sigmoid(b)  # GLU
    # Zero out padding so the depthwise conv does not smear it inward.
    s = h.shape[1]
    mask = (jnp.arange(s)[None, :] < valid_len[:, None])[..., None]
    h = jnp.where(mask, h, 0.0)
    # Depthwise conv along time, SAME padding.
    dw = params[p + ".dw"]  # [K, D]
    h = jax.lax.conv_general_dilated(
        h, dw[:, None, :],
        window_strides=(1,), padding="SAME",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=cfg.d_model,
    )
    h = jax.nn.silu(h)
    return h @ params[p + ".pw2"]


def make_encoder(cfg: SeamlessConfig, t_bucket: int, *,
                 attn_impl: str = "naive"):
    """fn(params, feats[1,T,F], feat_len[1]) → (enc_out[1,T',D], enc_len[1]).

    T must be a multiple of ``enc_subsample``; T' = T / enc_subsample.
    """
    sub = cfg.enc_subsample

    def fn(params, feats, feat_len):
        b, t, f = feats.shape
        x = feats.reshape(b, t // sub, f * sub)
        x = x @ params["enc.frontend.w"] + params["enc.frontend.b"]
        enc_len = (feat_len.astype(jnp.int32) + sub - 1) // sub
        for i in range(cfg.enc_layers):
            p = f"enc.layers.{i}."
            x = x + 0.5 * _ffn(p + "ffn1", params, x, cfg.norm_eps)
            x = x + _mhsa(p + "attn", params, cfg, x, mask_len=enc_len,
                          attn_impl=attn_impl)
            x = x + _conv_module(p + "conv", params, cfg, x, enc_len)
            x = x + 0.5 * _ffn(p + "ffn2", params, x, cfg.norm_eps)
            x = layernorm(x, params[p + "final.norm.w"],
                          params[p + "final.norm.b"], cfg.norm_eps)
        return x, enc_len

    return fn


def make_text_encoder(cfg: SeamlessConfig, t_bucket: int, *,
                      attn_impl: str = "naive"):
    """T2TT text encoder for text-input tasks (T-T, T-S).

    fn(params, tokens[1,T], text_len[1]) → (enc_out[1,T,D], enc_len[1])."""

    def fn(params, tokens, text_len):
        x = params["tenc.embed"][tokens]
        enc_len = text_len.astype(jnp.int32)
        for i in range(cfg.t2u_layers):  # same depth class as T2U
            p = f"tenc.layers.{i}."
            x = x + _mhsa(p + "attn", params, cfg, x, mask_len=enc_len,
                          attn_impl=attn_impl)
            x = x + _ffn(p + "ffn", params, x, cfg.norm_eps)
        x = layernorm(x, params["tenc.final.norm.w"],
                      params["tenc.final.norm.b"], cfg.norm_eps)
        return x, enc_len

    return fn


# --------------------------------------------------------------------------
# Text decoder (AR, beam-ready)
# --------------------------------------------------------------------------

def cross_kv_shape(cfg: SeamlessConfig, src_len: int):
    return (cfg.dec_layers, 1, cfg.n_heads, src_len, cfg.head_dim)


def self_kv_shape(cfg: SeamlessConfig, beams: int):
    return (cfg.dec_layers, beams, cfg.n_heads, cfg.max_tgt, cfg.head_dim)


def make_cross_kv(cfg: SeamlessConfig, src_len: int):
    """fn(params, enc_out[1,T',D]) → (cross_k, cross_v)
    [L, 1, H, T', Dh] — computed once per request."""

    def fn(params, enc_out):
        ks, vs = [], []
        for i in range(cfg.dec_layers):
            p = f"dec.layers.{i}.cross"
            ks.append(_heads(enc_out @ params[p + ".wk"], cfg))
            vs.append(_heads(enc_out @ params[p + ".wv"], cfg))
        return jnp.stack(ks), jnp.stack(vs)

    return fn


def make_dec_step(cfg: SeamlessConfig, beams: int, src_len: int, *,
                  attn_impl: str = "naive"):
    """One AR text-decoder step over B beams.

    fn(params, tokens[B], positions[B], self_ck, self_cv, cross_k, cross_v,
       enc_len[1]) → (logits[B,V], self_ck', self_cv')."""

    def fn(params, tokens, positions, self_ck, self_cv, cross_k, cross_v,
           enc_len):
        pos = positions.astype(jnp.int32)
        x = params["dec.embed"][tokens][:, None] + \
            params["dec.pos_embed"][pos][:, None]
        for i in range(cfg.dec_layers):
            p = f"dec.layers.{i}."
            # Self-attention over the static beam cache.
            h = layernorm(x, params[p + "self.norm.w"],
                          params[p + "self.norm.b"], cfg.norm_eps)
            q = _heads(h @ params[p + "self.wq"], cfg)
            k = _heads(h @ params[p + "self.wk"], cfg)
            v = _heads(h @ params[p + "self.wv"], cfg)
            self_ck = update_kv_cache_stacked(self_ck, k, pos, i)
            self_cv = update_kv_cache_stacked(self_cv, v, pos, i)
            a = attention(q, self_ck[i], self_cv[i], impl=attn_impl,
                          kv_len=pos + 1, q_start=pos, causal=False)
            x = x + _merge(a) @ params[p + "self.wo"]
            # Cross-attention to the (shared) encoder output.
            h = layernorm(x, params[p + "cross.norm.w"],
                          params[p + "cross.norm.b"], cfg.norm_eps)
            q = _heads(h @ params[p + "cross.wq"], cfg)
            ck_x = jnp.broadcast_to(
                cross_k[i], (beams,) + cross_k[i].shape[1:])
            cv_x = jnp.broadcast_to(
                cross_v[i], (beams,) + cross_v[i].shape[1:])
            mask_len = jnp.broadcast_to(enc_len.astype(jnp.int32), (beams,))
            a = attention(q, ck_x, cv_x, impl=attn_impl, kv_len=mask_len)
            x = x + _merge(a) @ params[p + "cross.wo"]
            x = x + _ffn(p + "ffn", params, x, cfg.norm_eps)
        x = layernorm(x, params["dec.final.norm.w"],
                      params["dec.final.norm.b"], cfg.norm_eps)
        logits = x[:, 0] @ params["dec.lm_head"]
        return logits, self_ck, self_cv

    return fn


def make_kv_reorder(cfg: SeamlessConfig, beams: int):
    """Beam-search cache reorder (Obs #4): gather beams of the self cache.

    fn(self_ck, self_cv, beam_idx[B]) → reordered (self_ck, self_cv)."""

    def fn(self_ck, self_cv, beam_idx):
        idx = beam_idx.astype(jnp.int32)
        return jnp.take(self_ck, idx, axis=1), \
            jnp.take(self_cv, idx, axis=1)

    return fn


# --------------------------------------------------------------------------
# NAR T2U + vocoder
# --------------------------------------------------------------------------

def make_t2u(cfg: SeamlessConfig, text_bucket: int, *,
             attn_impl: str = "naive"):
    """fn(params, text_tokens[1,T], text_len[1]) → unit logits
    [1, T*upsample, unit_vocab]. Fully parallel (NAR)."""
    u = cfg.t2u_upsample

    def fn(params, tokens, text_len):
        x = params["t2u.embed"][tokens]          # [1, T, D]
        x = jnp.repeat(x, u, axis=1)             # fixed-ratio upsample
        unit_len = text_len.astype(jnp.int32) * u
        for i in range(cfg.t2u_layers):
            p = f"t2u.layers.{i}."
            x = x + _mhsa(p + "attn", params, cfg, x, mask_len=unit_len,
                          attn_impl=attn_impl)
            x = x + _ffn(p + "ffn", params, x, cfg.norm_eps)
        return x @ params["t2u.head"], unit_len

    return fn


def make_vocoder(cfg: SeamlessConfig, unit_bucket: int):
    """fn(params, units[1,U]) → waveform [1, U * voc_upsample**stages]."""

    def fn(params, units):
        x = params["voc.embed"][units]  # [1, U, C]
        for i in range(cfg.voc_stages):
            x = jnp.repeat(x, cfg.voc_upsample, axis=1)
            w = params[f"voc.stages.{i}.conv"]  # [K, Cin, Cout]
            x = jax.lax.conv_general_dilated(
                x, w, window_strides=(1,), padding="SAME",
                dimension_numbers=("NWC", "WIO", "NWC"))
            x = jax.nn.leaky_relu(x + params[f"voc.stages.{i}.bias"], 0.1)
        w = params["voc.out.conv"]
        x = jax.lax.conv_general_dilated(
            x, w, window_strides=(1,), padding="SAME",
            dimension_numbers=("NWC", "WIO", "NWC"))
        x = jnp.tanh(x + params["voc.out.bias"])
        return x[..., 0]

    return fn
