"""HSTU generative recommender (L2), paper §2.1.4.

A stack of identical layers, each with three sub-layers:

* **Point-wise Projection** — one fused linear producing U, V, Q, K with a
  SiLU gate (replaces separate QKV + FFN-up projections of a standard
  Transformer, reducing matmul count).
* **Spatial Aggregation** — pointwise-normalized attention
  ``silu(QK^T + rab) / N`` with a bucketed relative attention bias
  (L1 kernel: ``kernels.hstu.hstu_attention`` fuses bias construction).
* **Pointwise Transformation** — norm(attn) gated by U, output linear,
  residual.

Non-autoregressive: one forward pass scores the whole user history
(Obs #1 — no decode loop, hence the paper's dramatically lower latency).
Later layers attend over a bounded window (the paper caps the sequence
length of the last 11 of 14 layers at 1024; we express the cap as a
sliding attention window so it composes with right-padded batches —
DESIGN.md §Substitutions).

Heads: ranking (engagement-type logits per position) and retrieval
(next-item logits at the last valid position, tied to the item embedding).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import HstuConfig
from ..kernels.hstu import hstu_attention
from ..kernels.ref import hstu_attention_ref, relative_bias_ref
from ..layers import rmsnorm


def param_specs(cfg: HstuConfig):
    d = cfg.d_model
    hs = cfg.n_heads * cfg.head_dim
    specs = [("item_embed", (cfg.item_vocab, d)),
             ("pos_embed", (cfg.max_seq, d))]
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        specs += [
            (p + "norm", (d,)),
            (p + "proj", (d, 3 * hs + d)),   # fused U(d) | V | Q | K
            (p + "rab_table", (cfg.n_heads, cfg.rel_buckets)),
            (p + "attn_norm", (cfg.head_dim,)),
            (p + "out", (hs, d)),
        ]
    specs += [("final_norm", (d,)),
              ("rank_head", (d, cfg.action_vocab)),
              ("rank_bias", (cfg.action_vocab,))]
    return specs


def init_params(cfg: HstuConfig, seed: int = 2) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in param_specs(cfg):
        if name.endswith("norm"):
            params[name] = np.ones(shape, np.float32)
        elif name.endswith("bias"):
            params[name] = np.zeros(shape, np.float32)
        elif name.endswith("rab_table"):
            params[name] = (rng.normal(0, 0.1, shape)).astype(np.float32)
        else:
            std = 0.02 if "embed" in name else 1.0 / np.sqrt(shape[0])
            params[name] = rng.normal(0, std, shape).astype(np.float32)
    return params


def _layer(cfg: HstuConfig, params, i: int, x, seq_len, *, attn_impl: str,
           window):
    """One HSTU layer. x: [B, S, D]; seq_len: [B] valid lengths."""
    p = f"layers.{i}."
    b, s, d = x.shape
    hs = cfg.n_heads * cfg.head_dim
    h = rmsnorm(x, params[p + "norm"], cfg.norm_eps)
    f = jax.nn.silu(h @ params[p + "proj"])
    u = f[..., :d]
    v, q, k = (t.reshape(b, s, cfg.n_heads, cfg.head_dim)
               .transpose(0, 2, 1, 3)
               for t in jnp.split(f[..., d:], 3, axis=-1))

    table = params[p + "rab_table"]
    if attn_impl == "fused":
        a = hstu_attention(q, k, v, table, seq_len=seq_len, window=window)
    else:
        rab = relative_bias_ref(table, s)
        a = hstu_attention_ref(q, k, v, rab, seq_len=seq_len, window=window)
    a = a.transpose(0, 2, 1, 3)
    a = rmsnorm(a, params[p + "attn_norm"], cfg.norm_eps)
    a = a.reshape(b, s, hs)
    # Element-wise gating by U (requires hs == d, true for all configs).
    return x + (a * u) @ params[p + "out"]


def make_forward(cfg: HstuConfig, seq_bucket: int, batch: int, *,
                 attn_impl: str = "naive"):
    """fn(params, item_ids[B,S], seq_len[B]) →
    (rank_logits[B,S,A], retrieval_logits[B,item_vocab])."""

    def fn(params, item_ids, seq_len):
        sl = seq_len.astype(jnp.int32)
        x = params["item_embed"][item_ids]
        x = x + params["pos_embed"][None, :seq_bucket]
        for i in range(cfg.n_layers):
            window = None if i < cfg.full_len_layers else cfg.capped_len
            x = _layer(cfg, params, i, x, sl, attn_impl=attn_impl,
                       window=window)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        rank = x @ params["rank_head"] + params["rank_bias"]
        last = jnp.take_along_axis(
            x, (sl - 1).clip(0)[:, None, None], axis=1)[:, 0]
        retrieval = last @ params["item_embed"].T
        return rank, retrieval

    return fn
