"""L2 decoder (Llama/Chameleon) semantics: prefill/decode equivalence,
static-KV correctness, LayerSkip draft/verify consistency, quant parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import TINY_LLAMA
from compile.models import llama as M

CFG = TINY_LLAMA


@pytest.fixture(scope="module")
def params():
    return {k: jnp.asarray(v) for k, v in M.init_params(CFG, seed=0).items()}


@pytest.fixture(scope="module")
def qparams(params):
    q = M.quantize_params({k: np.asarray(v) for k, v in params.items()})
    return {**params, **{k: jnp.asarray(v) for k, v in q.items()}}


def _greedy_rollout(params, prompt, steps, attn="naive"):
    """prefill + greedy decode loop — the canonical serving path."""
    bucket = 32
    toks = np.zeros((1, bucket), np.int32)
    toks[0, :len(prompt)] = prompt
    prefill = jax.jit(M.make_prefill(CFG, bucket, attn_impl=attn))
    decode = jax.jit(M.make_decode(CFG, 1, attn_impl=attn))
    logits, ck, cv = prefill(params, jnp.asarray(toks),
                             jnp.array([len(prompt)], jnp.int32))
    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.array([len(prompt)], jnp.int32)
    for _ in range(steps):
        out.append(int(tok[0]))
        logits, ck, cv = decode(params, tok, pos, ck, cv)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        pos = pos + 1
    return out, (ck, cv)


class TestPrefillDecode:
    def test_prefill_matches_stepwise_decode(self, params):
        """Prefilling N tokens == decoding them one-by-one: the static-KV
        incremental path must agree with the parallel path."""
        prompt = [3, 100, 7, 250, 42]
        bucket = 32
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :len(prompt)] = prompt
        prefill = jax.jit(M.make_prefill(CFG, bucket))
        decode = jax.jit(M.make_decode(CFG, 1))
        plogits, _, _ = prefill(params, jnp.asarray(toks),
                                jnp.array([len(prompt)], jnp.int32))
        # stepwise: feed tokens one at a time through decode
        L, H, S, Dh = CFG.n_layers, CFG.n_heads, CFG.max_seq, CFG.head_dim
        ck = jnp.zeros((L, 1, H, S, Dh))
        cv = jnp.zeros((L, 1, H, S, Dh))
        for i, t in enumerate(prompt):
            dlogits, ck, cv = decode(params, jnp.array([t], jnp.int32),
                                     jnp.array([i], jnp.int32), ck, cv)
        np.testing.assert_allclose(np.asarray(plogits), np.asarray(dlogits),
                                   atol=1e-4)

    def test_padding_is_inert(self, params):
        """Changing tokens beyond prompt_len must not change the logits."""
        bucket = 32
        prefill = jax.jit(M.make_prefill(CFG, bucket))
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :4] = [1, 2, 3, 4]
        l1, _, _ = prefill(params, jnp.asarray(toks),
                           jnp.array([4], jnp.int32))
        toks2 = toks.copy()
        toks2[0, 4:] = 499
        l2, _, _ = prefill(params, jnp.asarray(toks2),
                           jnp.array([4], jnp.int32))
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)

    def test_flash_and_naive_agree_end_to_end(self, params):
        o1, _ = _greedy_rollout(params, [5, 17, 300], 8, attn="naive")
        o2, _ = _greedy_rollout(params, [5, 17, 300], 8, attn="flash")
        assert o1 == o2

    def test_batch_decode_matches_single(self, params):
        """Slots of a B=4 decode batch behave exactly like B=1 decodes —
        the batcher correctness invariant."""
        L, H, S, Dh = CFG.n_layers, CFG.n_heads, CFG.max_seq, CFG.head_dim
        dec1 = jax.jit(M.make_decode(CFG, 1))
        dec4 = jax.jit(M.make_decode(CFG, 4))
        rng = np.random.default_rng(0)
        ck = jnp.asarray(rng.normal(size=(L, 4, H, S, Dh)), jnp.float32)
        cv = jnp.asarray(rng.normal(size=(L, 4, H, S, Dh)), jnp.float32)
        toks = jnp.array([9, 99, 199, 299], jnp.int32)
        pos = jnp.array([3, 17, 0, 50], jnp.int32)
        l4, _, _ = dec4(params, toks, pos, ck, cv)
        for b in range(4):
            l1, _, _ = dec1(params, toks[b:b+1], pos[b:b+1],
                            ck[:, b:b+1], cv[:, b:b+1])
            np.testing.assert_allclose(np.asarray(l4[b]), np.asarray(l1[0]),
                                       atol=1e-4)


class TestLayerSkip:
    def test_verify_matches_sequential_decode(self, params):
        """verify(K tokens) logits == K sequential decode steps' logits —
        the property that makes draft acceptance exact."""
        prompt = [10, 20, 30]
        _, (ck, cv) = _greedy_rollout(params, prompt, 0)
        K = CFG.verify_window
        draft_toks = jnp.array([[7, 8, 9, 11]], jnp.int32)
        verify = jax.jit(M.make_verify(CFG, K))
        vl, _, _ = verify(params, draft_toks,
                          jnp.array([len(prompt)], jnp.int32), ck, cv)
        decode = jax.jit(M.make_decode(CFG, 1))
        ck2, cv2 = ck, cv
        for i in range(K):
            dl, ck2, cv2 = decode(params, draft_toks[0, i:i+1],
                                  jnp.array([len(prompt) + i], jnp.int32),
                                  ck2, cv2)
            np.testing.assert_allclose(np.asarray(vl[0, i]),
                                       np.asarray(dl[0]), atol=1e-4)

    def test_draft_runs_fewer_layers(self, params):
        """Draft (early-exit) output differs from full decode (it skips
        layers) but has the same shape; and it matches a manual forward
        of the first E layers."""
        L, H, S, Dh = CFG.n_layers, CFG.n_heads, CFG.max_seq, CFG.head_dim
        ck = jnp.zeros((L, 1, H, S, Dh))
        cv = jnp.zeros((L, 1, H, S, Dh))
        draft = jax.jit(M.make_decode(CFG, 1, early_exit=True))
        full = jax.jit(M.make_decode(CFG, 1))
        t = jnp.array([42], jnp.int32)
        p = jnp.array([0], jnp.int32)
        dl, dck, _ = draft(params, t, p, ck, cv)
        fl, _, _ = full(params, t, p, ck, cv)
        assert dl.shape == fl.shape
        assert not np.allclose(np.asarray(dl), np.asarray(fl), atol=1e-3)
        # Draft must not touch layers >= E.
        e = CFG.early_exit_layer
        np.testing.assert_array_equal(np.asarray(dck[e:]),
                                      np.asarray(ck[e:]))


class TestQuantizedStages:
    def test_int8_weight_only_close_to_f32(self, qparams):
        dec = jax.jit(M.make_decode(CFG, 1))
        dec8 = jax.jit(M.make_decode(CFG, 1,
                                     linear_mode="int8_weight_only"))
        L, H, S, Dh = CFG.n_layers, CFG.n_heads, CFG.max_seq, CFG.head_dim
        ck = jnp.zeros((L, 1, H, S, Dh))
        cv = jnp.zeros((L, 1, H, S, Dh))
        t = jnp.array([7], jnp.int32)
        p = jnp.array([0], jnp.int32)
        lf, _, _ = dec(qparams, t, p, ck, cv)
        l8, _, _ = dec8(qparams, t, p, ck, cv)
        # top-1 prediction preserved under weight-only quantization
        assert int(jnp.argmax(lf)) == int(jnp.argmax(l8))
        rel = float(jnp.mean(jnp.abs(lf - l8)) / jnp.mean(jnp.abs(lf)))
        assert rel < 0.05
