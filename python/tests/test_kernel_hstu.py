"""HSTU fused pointwise-attention kernel vs oracle (paper §4.1.1:
fused relative-bias construction + grouped GEMMs)."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.hstu import hstu_attention

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("kernels")


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def _case(seed, b=2, h=4, s=128, d=32, nb=16):
    rng = np.random.default_rng(seed)
    q, k, v = (_rand(rng, b, h, s, d) for _ in range(3))
    table = _rand(rng, h, nb) * 0.1
    return q, k, v, table


class TestHstuKernel:
    @pytest.mark.parametrize("s", [64, 128, 256])
    def test_full_length(self, s):
        q, k, v, table = _case(s, s=s)
        out = hstu_attention(q, k, v, table)
        rab = ref.relative_bias_ref(table, s)
        want = ref.hstu_attention_ref(q, k, v, rab)
        np.testing.assert_allclose(out, want, atol=5e-6)

    def test_masked_lengths(self):
        q, k, v, table = _case(7)
        sl = jnp.array([40, 128], jnp.int32)
        out = hstu_attention(q, k, v, table, seq_len=sl)
        rab = ref.relative_bias_ref(table, 128)
        want = ref.hstu_attention_ref(q, k, v, rab, seq_len=sl)
        np.testing.assert_allclose(out, want, atol=5e-6)

    @pytest.mark.parametrize("window", [32, 64, 100])
    def test_sliding_window_cap(self, window):
        """The later-layer sequence cap (DESIGN.md §Substitutions)."""
        q, k, v, table = _case(11, s=256)
        sl = jnp.array([200, 256], jnp.int32)
        out = hstu_attention(q, k, v, table, seq_len=sl, window=window)
        rab = ref.relative_bias_ref(table, 256)
        want = ref.hstu_attention_ref(q, k, v, rab, seq_len=sl,
                                      window=window)
        np.testing.assert_allclose(out, want, atol=5e-6)

    def test_bias_actually_applied(self):
        """A large bias on one head must change that head only."""
        q, k, v, table = _case(13)
        t2 = table.at[1].add(5.0)
        o1 = np.asarray(hstu_attention(q, k, v, table))
        o2 = np.asarray(hstu_attention(q, k, v, t2))
        assert np.allclose(o1[:, 0], o2[:, 0], atol=1e-6)
        assert not np.allclose(o1[:, 1], o2[:, 1], atol=1e-3)

    def test_pointwise_normalization_scale(self):
        """With k·q ≈ 0 and bias b, silu(b)/N weighting means doubling the
        valid history halves nothing — weights stay bounded by silu(b)."""
        b, h, s, d = 1, 1, 64, 16
        q = jnp.zeros((b, h, s, d))
        k = jnp.zeros((b, h, s, d))
        v = jnp.ones((b, h, s, d))
        table = jnp.full((h, 8), 1.0)
        out = np.asarray(hstu_attention(q, k, v, table))
        # every row: silu(1)*count/count = silu(1)
        silu1 = 1.0 / (1.0 + np.exp(-1.0))
        np.testing.assert_allclose(out[0, 0, :, 0], silu1, atol=1e-5)


@hypothesis.given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    blocks=st.integers(1, 3),
    nb=st.sampled_from([4, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hstu_hypothesis(b, h, blocks, nb, seed):
    s = 64 * blocks
    rng = np.random.default_rng(seed)
    q, k, v = (_rand(rng, b, h, s, 16) for _ in range(3))
    table = _rand(rng, h, nb) * 0.2
    sl = jnp.asarray(rng.integers(1, s + 1, size=(b,)), jnp.int32)
    out = hstu_attention(q, k, v, table, seq_len=sl)
    rab = ref.relative_bias_ref(table, s)
    want = ref.hstu_attention_ref(q, k, v, rab, seq_len=sl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)
