"""weights.bin round-trip + manifest/artifact integrity.

The artifact-integrity tests run only if `make artifacts` has produced
the artifacts/ tree (skipped otherwise so pytest works pre-build)."""

import json
import os

import numpy as np
import pytest

from compile import weights as wio
from compile.configs import TINY
from compile.models import hstu as hstu_m
from compile.models import llama as llama_m
from compile.models import seamless as seam_m

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestWeightsFormat:
    def test_roundtrip(self, tmp_path):
        rng = np.random.default_rng(0)
        tensors = {
            "a": rng.normal(size=(3, 4)).astype(np.float32),
            "b": rng.integers(-127, 128, (8,)).astype(np.int8),
            "c": rng.integers(0, 100, (2, 2, 2)).astype(np.int32),
            "scalar": np.float32(3.5).reshape(()),
        }
        p = str(tmp_path / "w.bin")
        wio.save(p, tensors, ["a", "b", "c", "scalar"])
        back = wio.load(p)
        assert set(back) == set(tensors)
        for k in tensors:
            np.testing.assert_array_equal(back[k], tensors[k])
            assert back[k].dtype == tensors[k].dtype

    def test_order_mismatch_rejected(self, tmp_path):
        with pytest.raises(AssertionError):
            wio.save(str(tmp_path / "w.bin"),
                     {"a": np.zeros(1, np.float32)}, ["a", "b"])


def _manifest(model):
    path = os.path.join(ART, model, "manifest.json")
    if not os.path.exists(path):
        pytest.skip(f"artifacts for {model} not built")
    with open(path) as f:
        return json.load(f)


PARAM_SPECS = {
    "llama": lambda: llama_m.param_specs(TINY["llama"]),
    "chameleon": lambda: llama_m.param_specs(TINY["chameleon"]),
    "seamless": lambda: seam_m.param_specs(TINY["seamless"]),
    "hstu": lambda: hstu_m.param_specs(TINY["hstu"]),
}


@pytest.mark.parametrize("model", ["llama", "chameleon", "seamless", "hstu"])
class TestArtifacts:
    def test_every_stage_file_exists(self, model):
        man = _manifest(model)
        for name, st in man["stages"].items():
            f = os.path.join(ART, model, st["file"])
            assert os.path.exists(f), f"{name}: missing {st['file']}"
            with open(f) as fh:
                head = fh.read(200)
            assert "HloModule" in head, f"{name}: not HLO text"

    def test_weights_match_manifest_order(self, model):
        man = _manifest(model)
        w = wio.load(os.path.join(ART, model, man["weights_file"]))
        assert list(w) == man["weight_order"]

    def test_stage_weights_are_known(self, model):
        man = _manifest(model)
        known = set(man["weight_order"])
        for name, st in man["stages"].items():
            missing = set(st["weights"]) - known
            assert not missing, f"{name}: unknown weights {missing}"

    def test_base_param_shapes(self, model):
        man = _manifest(model)
        w = wio.load(os.path.join(ART, model, man["weights_file"]))
        for name, shape in PARAM_SPECS[model]():
            assert w[name].shape == tuple(shape), name
