"""Seamless pipeline semantics: encoder masking, beam-cache reorder,
cross-attention consistency, NAR module shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import TINY_SEAMLESS
from compile.models import seamless as M

CFG = TINY_SEAMLESS


@pytest.fixture(scope="module")
def params():
    return {k: jnp.asarray(v) for k, v in M.init_params(CFG).items()}


def _encode(params, t=64, valid=None):
    rng = np.random.default_rng(0)
    feats = jnp.asarray(rng.normal(size=(1, t, CFG.enc_feat_dim)),
                        jnp.float32)
    flen = jnp.array([valid or t], jnp.int32)
    enc = jax.jit(M.make_encoder(CFG, t))
    return feats, flen, *enc(params, feats, flen)


class TestEncoder:
    def test_shapes(self, params):
        _, _, enc_out, enc_len = _encode(params, 64)
        assert enc_out.shape == (1, 64 // CFG.enc_subsample, CFG.d_model)
        assert int(enc_len[0]) == 64 // CFG.enc_subsample

    def test_padding_inert_on_valid_prefix(self, params):
        """Garbage in padded frames must not leak into valid encoder
        positions (attention + conv masking)."""
        rng = np.random.default_rng(1)
        t, valid = 64, 40
        base = rng.normal(size=(1, t, CFG.enc_feat_dim)).astype(np.float32)
        noisy = base.copy()
        noisy[0, valid:] = 1000.0
        enc = jax.jit(M.make_encoder(CFG, t))
        flen = jnp.array([valid], jnp.int32)
        o1, l1 = enc(params, jnp.asarray(base), flen)
        o2, l2 = enc(params, jnp.asarray(noisy), flen)
        vp = int(l1[0])
        np.testing.assert_allclose(np.asarray(o1)[:, :vp],
                                   np.asarray(o2)[:, :vp], atol=1e-3)


class TestDecoder:
    def test_beam1_vs_beamN_consistency(self, params):
        """With identical caches per beam, every beam of dec_step_bN
        produces the b1 logits."""
        _, _, enc_out, enc_len = _encode(params, 64)
        tp = enc_out.shape[1]
        ckv = jax.jit(M.make_cross_kv(CFG, tp))
        xk, xv = ckv({k: params[k] for k in params}, enc_out)
        bm = CFG.beam_size
        d1 = jax.jit(M.make_dec_step(CFG, 1, tp))
        dn = jax.jit(M.make_dec_step(CFG, bm, tp))
        s1 = jnp.zeros(M.self_kv_shape(CFG, 1))
        sn = jnp.zeros(M.self_kv_shape(CFG, bm))
        tok1 = jnp.array([5], jnp.int32)
        tokn = jnp.full((bm,), 5, jnp.int32)
        pos1 = jnp.array([0], jnp.int32)
        posn = jnp.zeros((bm,), jnp.int32)
        l1, _, _ = d1(params, tok1, pos1, s1, s1, xk, xv, enc_len)
        ln, _, _ = dn(params, tokn, posn, sn, sn, xk, xv, enc_len)
        for b in range(bm):
            np.testing.assert_allclose(np.asarray(ln[b]), np.asarray(l1[0]),
                                       atol=1e-4)

    def test_kv_reorder_is_permutation(self, params):
        """Reorder(idx) then reading beam b equals reading idx[b] before —
        the beam-search invariant (paper Obs #4)."""
        bm = CFG.beam_size
        rng = np.random.default_rng(3)
        shape = M.self_kv_shape(CFG, bm)
        ck = jnp.asarray(rng.normal(size=shape), jnp.float32)
        cv = jnp.asarray(rng.normal(size=shape), jnp.float32)
        idx = jnp.array([2, 0, 3, 1], jnp.int32)
        ro = jax.jit(M.make_kv_reorder(CFG, bm))
        rk, rv = ro(ck, cv, idx)
        for b in range(bm):
            np.testing.assert_array_equal(np.asarray(rk[:, b]),
                                          np.asarray(ck[:, int(idx[b])]))
            np.testing.assert_array_equal(np.asarray(rv[:, b]),
                                          np.asarray(cv[:, int(idx[b])]))

    def test_enc_len_masks_cross_attention(self, params):
        """Shortening enc_len changes logits (cross-attn actually reads
        the mask); corrupting encoder output beyond enc_len does not."""
        _, _, enc_out, enc_len = _encode(params, 64)
        tp = enc_out.shape[1]
        ckv = jax.jit(M.make_cross_kv(CFG, tp))
        d1 = jax.jit(M.make_dec_step(CFG, 1, tp))
        s1 = jnp.zeros(M.self_kv_shape(CFG, 1))
        tok = jnp.array([5], jnp.int32)
        pos = jnp.array([0], jnp.int32)
        xk, xv = ckv(params, enc_out)
        short = jnp.array([tp // 2], jnp.int32)
        la, _, _ = d1(params, tok, pos, s1, s1, xk, xv, enc_len)
        lb, _, _ = d1(params, tok, pos, s1, s1, xk, xv, short)
        assert not np.allclose(np.asarray(la), np.asarray(lb), atol=1e-4)
        # corrupt beyond short — must be inert
        enc2 = enc_out.at[:, tp // 2:].set(99.0)
        xk2, xv2 = ckv(params, enc2)
        lc, _, _ = d1(params, tok, pos, s1, s1, xk2, xv2, short)
        np.testing.assert_allclose(np.asarray(lb), np.asarray(lc), atol=1e-4)


class TestNarModules:
    def test_t2u_shapes_and_upsample(self, params):
        t2u = jax.jit(M.make_t2u(CFG, 16))
        toks = jnp.arange(16, dtype=jnp.int32)[None]
        logits, ulen = t2u(params, toks, jnp.array([10], jnp.int32))
        assert logits.shape == (1, 16 * CFG.t2u_upsample, CFG.unit_vocab)
        assert int(ulen[0]) == 10 * CFG.t2u_upsample

    def test_vocoder_output_range(self, params):
        voc = jax.jit(M.make_vocoder(CFG, 64))
        units = jnp.asarray(
            np.random.default_rng(5).integers(0, CFG.unit_vocab, (1, 64)),
            jnp.int32)
        wav = voc(params, units)
        r = CFG.voc_upsample ** CFG.voc_stages
        assert wav.shape == (1, 64 * r)
        assert float(jnp.max(jnp.abs(wav))) <= 1.0  # tanh-bounded
