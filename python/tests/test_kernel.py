"""Core kernel-vs-oracle correctness: flash attention (SDPA lever).

Hypothesis sweeps shapes/dtypes per the repo testing strategy
(DESIGN.md §7); deterministic cases pin the paper-relevant
configurations (prefill causal, static-cache decode, verify window).
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.attention import (flash_attention,
                                       mxu_utilization_estimate,
                                       vmem_footprint_bytes)

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("kernels")


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


class TestFlashPrefill:
    @pytest.mark.parametrize("s", [64, 128, 256])
    @pytest.mark.parametrize("d", [32, 64])
    def test_causal_matches_ref(self, s, d):
        rng = np.random.default_rng(s * d)
        q, k, v = (_rand(rng, 2, 4, s, d) for _ in range(3))
        out = flash_attention(q, k, v, causal=True)
        want = ref.sdpa_ref(q, k, v, causal=True)
        np.testing.assert_allclose(out, want, atol=2e-5)

    def test_non_causal_full(self):
        rng = np.random.default_rng(0)
        q, k, v = (_rand(rng, 1, 2, 128, 32) for _ in range(3))
        out = flash_attention(q, k, v)
        want = ref.sdpa_ref(q, k, v)
        np.testing.assert_allclose(out, want, atol=2e-5)

    def test_padded_prefill_prefix_only(self):
        """Rows within the prompt must be unaffected by the padding rows —
        the invariant the right-padded prefill bucket relies on."""
        rng = np.random.default_rng(1)
        q, k, v = (_rand(rng, 1, 2, 128, 32) for _ in range(3))
        kv_len = jnp.array([77], jnp.int32)
        out = flash_attention(q, k, v, causal=True, kv_len=kv_len)
        # reference computed on the unpadded slice
        want = ref.sdpa_ref(q[:, :, :77], k[:, :, :77], v[:, :, :77],
                            causal=True)
        np.testing.assert_allclose(out[:, :, :77], want, atol=2e-5)


class TestFlashDecode:
    def test_decode_step(self):
        rng = np.random.default_rng(2)
        q = _rand(rng, 3, 4, 1, 32)
        k, v = (_rand(rng, 3, 4, 256, 32) for _ in range(2))
        kv_len = jnp.array([1, 100, 256], jnp.int32)
        out = flash_attention(q, k, v, kv_len=kv_len)
        want = ref.sdpa_ref(q, k, v, kv_len=kv_len)
        np.testing.assert_allclose(out, want, atol=2e-5)

    def test_verify_window_offset_causal(self):
        rng = np.random.default_rng(3)
        kwin = 4
        q = _rand(rng, 2, 4, kwin, 32)
        k, v = (_rand(rng, 2, 4, 128, 32) for _ in range(2))
        start = jnp.array([10, 60], jnp.int32)
        out = flash_attention(q, k, v, kv_len=start + kwin, q_start=start,
                              causal=True)
        # manual oracle
        sc = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(32)
        kpos = jnp.arange(128)
        qpos = start[:, None, None, None] + \
            jnp.arange(kwin)[None, None, :, None]
        mask = (kpos[None, None, None, :] <= qpos) & \
            (kpos[None, None, None, :] < (start + kwin)[:, None, None, None])
        sc = jnp.where(mask, sc, -1e30)
        want = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(sc, -1), v)
        np.testing.assert_allclose(out, want, atol=2e-5)

    def test_kv_len_one(self):
        """Single valid KV entry: attention must return exactly v[0]."""
        rng = np.random.default_rng(4)
        q = _rand(rng, 1, 2, 1, 32)
        k, v = (_rand(rng, 1, 2, 64, 32) for _ in range(2))
        out = flash_attention(q, k, v, kv_len=jnp.array([1], jnp.int32))
        np.testing.assert_allclose(out[0, :, 0], v[0, :, 0], atol=2e-5)


@hypothesis.given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    sq_blocks=st.integers(1, 3),
    d=st.sampled_from([16, 32, 64]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_hypothesis(b, h, sq_blocks, d, causal, seed):
    """Property sweep: arbitrary (B, H, S, D) grids match the oracle."""
    s = 64 * sq_blocks
    rng = np.random.default_rng(seed)
    q, k, v = (_rand(rng, b, h, s, d) for _ in range(3))
    kv_len = jnp.asarray(rng.integers(1, s + 1, size=(b,)), jnp.int32)
    out = flash_attention(q, k, v, causal=causal, kv_len=kv_len)
    want = ref.sdpa_ref(q, k, v, causal=causal, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=5e-5)


class TestKernelPerfEstimates:
    def test_vmem_footprint_within_budget(self):
        """Paper-scale shapes fit comfortably in 16 MiB of VMEM (the
        EXPERIMENTS.md §Perf L1 target)."""
        assert vmem_footprint_bytes(128, 128, 128) < 16 * 2**20
        assert vmem_footprint_bytes(256, 256, 128) < 16 * 2**20

    def test_mxu_utilization_full_tiles(self):
        assert mxu_utilization_estimate(128, 128, 128) == 1.0
        assert mxu_utilization_estimate(64, 64, 32) < 1.0
