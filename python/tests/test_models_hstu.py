"""HSTU stack semantics: causality, fused-vs-naive parity, head shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import TINY_HSTU
from compile.models import hstu as M

CFG = TINY_HSTU


@pytest.fixture(scope="module")
def params():
    return {k: jnp.asarray(v) for k, v in M.init_params(CFG).items()}


def _inputs(seed, b=2, s=256, maxlen=None):
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, CFG.item_vocab, (b, s)), jnp.int32)
    sl = jnp.asarray(rng.integers(s // 2, (maxlen or s) + 1, (b,)),
                     jnp.int32)
    return ids, sl


class TestForward:
    def test_shapes(self, params):
        ids, sl = _inputs(0)
        fwd = jax.jit(M.make_forward(CFG, 256, 2))
        rank, retr = fwd(params, ids, sl)
        assert rank.shape == (2, 256, CFG.action_vocab)
        assert retr.shape == (2, CFG.item_vocab)

    def test_fused_matches_naive(self, params):
        """The fused Pallas kernel path is numerically the naive path —
        the paper's 'same principle, fused kernel' claim (§4.1.1)."""
        ids, sl = _inputs(1)
        naive = jax.jit(M.make_forward(CFG, 256, 2, attn_impl="naive"))
        fused = jax.jit(M.make_forward(CFG, 256, 2, attn_impl="fused"))
        r1, v1 = naive(params, ids, sl)
        r2, v2 = fused(params, ids, sl)
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r2),
                                   atol=5e-4)
        np.testing.assert_allclose(np.asarray(v1), np.asarray(v2),
                                   atol=5e-4)

    def test_causality(self, params):
        """Changing item t must not change rank logits at positions < t
        (sequential transduction is causal)."""
        ids, _ = _inputs(2, b=1)
        sl = jnp.array([256], jnp.int32)
        fwd = jax.jit(M.make_forward(CFG, 256, 1))
        r1, _ = fwd(params, ids, sl)
        ids2 = ids.at[0, 200].set((int(ids[0, 200]) + 1) % CFG.item_vocab)
        r2, _ = fwd(params, ids2, sl)
        np.testing.assert_allclose(np.asarray(r1)[:, :200],
                                   np.asarray(r2)[:, :200], atol=1e-4)
        assert not np.allclose(np.asarray(r1)[:, 200:],
                               np.asarray(r2)[:, 200:], atol=1e-4)

    def test_retrieval_reads_last_valid_position(self, params):
        """Corrupting items beyond seq_len must not change retrieval."""
        ids, _ = _inputs(3, b=1)
        sl = jnp.array([100], jnp.int32)
        fwd = jax.jit(M.make_forward(CFG, 256, 1))
        _, v1 = fwd(params, ids, sl)
        ids2 = ids.at[0, 150:].set(0)
        _, v2 = fwd(params, ids2, sl)
        np.testing.assert_allclose(np.asarray(v1), np.asarray(v2),
                                   atol=1e-4)

    def test_batch_independence(self, params):
        """Each batch row is independent (no cross-sample leakage)."""
        ids, sl = _inputs(4, b=2)
        fwd2 = jax.jit(M.make_forward(CFG, 256, 2))
        fwd1 = jax.jit(M.make_forward(CFG, 256, 1))
        r2, v2 = fwd2(params, ids, sl)
        for b in range(2):
            r1, v1 = fwd1(params, ids[b:b+1], sl[b:b+1])
            np.testing.assert_allclose(np.asarray(r2)[b], np.asarray(r1)[0],
                                       atol=1e-4)
            np.testing.assert_allclose(np.asarray(v2)[b], np.asarray(v1)[0],
                                       atol=1e-4)


class TestWindowCap:
    def test_later_layers_are_windowed(self, params):
        """With the cap, distant history beyond the window affects output
        only through the first (full-length) layers; a model whose
        full_len_layers == n_layers must differ."""
        import dataclasses
        ids, _ = _inputs(5, b=1, s=1024)
        sl = jnp.array([1024], jnp.int32)
        capped = jax.jit(M.make_forward(CFG, 1024, 1))
        nocap_cfg = dataclasses.replace(CFG, full_len_layers=CFG.n_layers)
        nocap = jax.jit(M.make_forward(nocap_cfg, 1024, 1))
        r1, _ = capped(params, ids, sl)
        r2, _ = nocap(params, ids, sl)
        # early positions (< window) identical; late positions differ
        w = CFG.capped_len
        np.testing.assert_allclose(np.asarray(r1)[:, :w // 2],
                                   np.asarray(r2)[:, :w // 2], atol=1e-4)
        assert not np.allclose(np.asarray(r1)[:, -64:],
                               np.asarray(r2)[:, -64:], atol=1e-4)
