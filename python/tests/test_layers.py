"""L2 building-block semantics: norms, RoPE, linear modes, and the
in-place stacked KV update (the §Perf L2 hot-path op)."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers as L

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("kernels")


class TestNorms:
    def test_rmsnorm_unit_scale(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(0, 3, (4, 64)), jnp.float32)
        y = L.rmsnorm(x, jnp.ones(64))
        rms = jnp.sqrt(jnp.mean(jnp.square(y), -1))
        np.testing.assert_allclose(rms, 1.0, atol=1e-3)

    def test_layernorm_zero_mean_unit_var(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(5, 2, (4, 64)), jnp.float32)
        y = L.layernorm(x, jnp.ones(64), jnp.zeros(64))
        np.testing.assert_allclose(jnp.mean(y, -1), 0.0, atol=1e-4)
        np.testing.assert_allclose(jnp.var(y, -1), 1.0, atol=1e-2)


class TestRope:
    def test_rotation_preserves_norm(self):
        cos, sin = L.rope_tables(128, 32)
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(size=(1, 2, 8, 32)), jnp.float32)
        pos = jnp.arange(8, dtype=jnp.int32)[None]
        y = L.apply_rope(x, pos, cos, sin)
        np.testing.assert_allclose(
            jnp.linalg.norm(x, axis=-1), jnp.linalg.norm(y, axis=-1),
            atol=1e-4)

    def test_relative_property(self):
        """<rope(q,i), rope(k,j)> depends only on i-j (the RoPE point)."""
        cos, sin = L.rope_tables(256, 32)
        rng = np.random.default_rng(3)
        q = jnp.asarray(rng.normal(size=(1, 1, 1, 32)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 1, 1, 32)), jnp.float32)

        def dot_at(i, j):
            qi = L.apply_rope(q, jnp.array([[i]], jnp.int32), cos, sin)
            kj = L.apply_rope(k, jnp.array([[j]], jnp.int32), cos, sin)
            return float(jnp.sum(qi * kj))

        assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-4
        assert abs(dot_at(5, 3) - dot_at(5, 4)) > 1e-6

    def test_position_zero_is_identity(self):
        cos, sin = L.rope_tables(16, 32)
        x = jnp.ones((1, 1, 1, 32))
        y = L.apply_rope(x, jnp.zeros((1, 1), jnp.int32), cos, sin)
        np.testing.assert_allclose(x, y, atol=1e-6)


class TestStackedKvUpdate:
    @hypothesis.given(
        lidx=st.integers(0, 3),
        b=st.integers(1, 3),
        s_new=st.sampled_from([1, 4]),
        seed=st.integers(0, 10_000),
    )
    def test_matches_per_layer_update(self, lidx, b, s_new, seed):
        """The direct 5D write equals the reference extract→update→
        reinsert formulation everywhere."""
        rng = np.random.default_rng(seed)
        L_, H, S, D = 4, 2, 32, 8
        cache = jnp.asarray(rng.normal(size=(L_, b, H, S, D)), jnp.float32)
        new = jnp.asarray(rng.normal(size=(b, H, s_new, D)), jnp.float32)
        pos = jnp.asarray(rng.integers(0, S - s_new + 1, b), jnp.int32)
        got = L.update_kv_cache_stacked(cache, new, pos, lidx)
        ref_layer, _ = L.update_kv_cache(cache[lidx], cache[lidx], new, new,
                                         pos)
        want = cache.at[lidx].set(ref_layer)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=0)

    def test_other_layers_untouched(self):
        cache = jnp.zeros((4, 1, 2, 16, 8))
        new = jnp.ones((1, 2, 1, 8))
        out = L.update_kv_cache_stacked(cache, new,
                                        jnp.array([3], jnp.int32), 2)
        assert float(jnp.sum(jnp.abs(out[0]))) == 0.0
        assert float(jnp.sum(jnp.abs(out[1]))) == 0.0
        assert float(jnp.sum(jnp.abs(out[3]))) == 0.0
        assert float(jnp.sum(out[2, 0, :, 3])) == 16.0


class TestLinearModes:
    @pytest.mark.parametrize("mode", ["int8_weight_only", "int8_dynamic"])
    def test_kernel_and_ref_paths_agree(self, mode):
        from compile.kernels.ref import quantize_weight
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.normal(size=(8, 128)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(128, 256)), jnp.float32)
        wq, ws = quantize_weight(w)
        a = L.linear(x, wq, mode=mode, w_scale=ws, use_kernel=True)
        b = L.linear(x, wq, mode=mode, w_scale=ws, use_kernel=False)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-3)

    def test_unknown_mode_rejected(self):
        with pytest.raises(ValueError):
            L.linear(jnp.zeros((2, 4)), jnp.zeros((4, 4)), mode="int4")


class TestEarlyExitFriendlyInit:
    def test_late_layers_downscaled(self):
        from compile.configs import TINY_LLAMA
        from compile.models import llama as M
        p_friendly = M.init_params(TINY_LLAMA, 0, early_exit_friendly=True)
        p_plain = M.init_params(TINY_LLAMA, 0, early_exit_friendly=False)
        e = TINY_LLAMA.early_exit_layer
        # early layers identical
        np.testing.assert_array_equal(p_friendly["layers.0.wo"],
                                      p_plain["layers.0.wo"])
        # late layers scaled down
        r = np.abs(p_friendly[f"layers.{e}.wo"]).mean() / \
            np.abs(p_plain[f"layers.{e}.wo"]).mean()
        assert r < 0.1
