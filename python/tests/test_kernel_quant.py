"""Int8 matmul kernels vs oracles (AutoQuant lever)."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.quant import int8_dynamic_matmul, int8_weight_only_matmul

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("kernels")


def _case(seed, m=64, k=256, n=512):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    wq, ws = ref.quantize_weight(w)
    return x, w, wq, ws


class TestWeightOnly:
    @pytest.mark.parametrize("shape", [(64, 256, 512), (8, 128, 128),
                                       (128, 512, 256)])
    def test_matches_ref(self, shape):
        x, _, wq, ws = _case(sum(shape), *shape)
        out = int8_weight_only_matmul(x, wq, ws)
        want = ref.int8_weight_only_matmul_ref(x, wq, ws)
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-4)

    def test_close_to_f32(self):
        """Quantization error stays small relative to the f32 product."""
        x, w, wq, ws = _case(3)
        out = np.asarray(int8_weight_only_matmul(x, wq, ws))
        exact = np.asarray(x @ w)
        rel = np.abs(out - exact).mean() / np.abs(exact).mean()
        assert rel < 0.01


class TestDynamic:
    @pytest.mark.parametrize("shape", [(64, 256, 512), (16, 128, 256)])
    def test_matches_ref(self, shape):
        x, _, wq, ws = _case(sum(shape) + 1, *shape)
        out = int8_dynamic_matmul(x, wq, ws)
        want = ref.int8_dynamic_matmul_ref(x, wq, ws)
        np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-3)

    def test_row_scale_invariance(self):
        """Scaling an activation row scales its output row (dynamic
        per-row quantization must track magnitude)."""
        x, _, wq, ws = _case(9, m=8)
        x2 = x.at[3].multiply(100.0)
        o1 = np.asarray(int8_dynamic_matmul(x, wq, ws))
        o2 = np.asarray(int8_dynamic_matmul(x2, wq, ws))
        np.testing.assert_allclose(o2[3], o1[3] * 100.0, rtol=2e-2,
                                   atol=1e-2)


class TestQuantizeWeight:
    def test_roundtrip_error_bounded(self):
        _, w, wq, ws = _case(5)
        deq = np.asarray(wq, np.float32) * np.asarray(ws)[None, :]
        err = np.abs(deq - np.asarray(w))
        # symmetric int8: max error ≤ scale/2 per channel
        assert (err <= np.asarray(ws)[None, :] * 0.5 + 1e-7).all()

    def test_int8_range(self):
        _, _, wq, _ = _case(6)
        assert int(jnp.max(jnp.abs(wq.astype(jnp.int32)))) <= 127


@hypothesis.given(
    m=st.sampled_from([1, 8, 64]),
    k=st.sampled_from([128, 256]),
    n=st.sampled_from([128, 512]),
    dynamic=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_quant_hypothesis(m, k, n, dynamic, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    wq, ws = ref.quantize_weight(w)
    bm = 1 if m == 1 else 8
    if dynamic:
        out = int8_dynamic_matmul(x, wq, ws, block_m=bm)
        want = ref.int8_dynamic_matmul_ref(x, wq, ws)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-4, atol=1e-3)
    else:
        out = int8_weight_only_matmul(x, wq, ws, block_m=bm)
        want = ref.int8_weight_only_matmul_ref(x, wq, ws)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)
