use xla::{ArrayElement, Result};

#[test]
fn add_op() -> Result<()> {
    let client = xla::PjRtClient::cpu()?;
    let builder = xla::XlaBuilder::new("test");
    let cst42 = builder.constant_r0(42f32)?;
    let cst43 = builder.constant_r1c(43f32, 2)?;
    let sum = (cst42 + &cst43)?;
    let computation = sum.build()?;
    let result = client.compile(&computation)?;
    let result = result.execute::<xla::Literal>(&[])?;
    let result = result[0][0].to_literal_sync()?;
    assert_eq!(result.element_count(), 2);
    assert_eq!(result.array_shape()?, xla::ArrayShape::new::<f32>(vec![2]));
    assert_eq!(result.get_first_element::<f32>()?, 85.);
    assert_eq!(result.to_vec::<f32>()?, [85., 85.]);
    Ok(())
}

#[test]
fn sum_op() -> Result<()> {
    let client = xla::PjRtClient::cpu()?;
    let builder = xla::XlaBuilder::new("test");
    let x = builder.parameter(0, f32::TY, &[2], "x")?;
    let sum = x.reduce_sum(&[], false)?.build()?.compile(&client)?;
    let input = xla::Literal::vec1(&[4.2f32, 1.337f32]);
    let result = sum.execute::<xla::Literal>(&[input])?;
    let result = result[0][0].to_literal_sync()?;
    assert_eq!(result.to_vec::<f32>()?, [4.2, 1.337]);

    let builder = xla::XlaBuilder::new("test");
    let x = builder.parameter(0, f32::TY, &[-2], "x")?;
    let sum = x.reduce_sum(&[0], false)?.build()?.compile(&client)?;
    let input = xla::Literal::vec1(&[4.2f32, 1.337f32]);
    let result = sum.execute::<xla::Literal>(&[input])?;
    let result = result[0][0].to_literal_sync()?;
    assert_eq!(result.to_vec::<f32>()?, [5.5369997]);
    // Dimensions got reduced.
    assert_eq!(result.array_shape()?.dims(), []);

    let builder = xla::XlaBuilder::new("test");
    let x = builder.parameter(0, f32::TY, &[-2], "x")?;
    let sum = x.reduce_sum(&[0], true)?.build()?.compile(&client)?;
    let input = xla::Literal::vec1(&[4.2f32, 1.337f32]);
    let result = sum.execute::<xla::Literal>(&[input])?;
    let result = result[0][0].to_literal_sync()?;
    assert_eq!(result.to_vec::<f32>()?, [5.5369997]);
    // keep_dims = true in this case.
    assert_eq!(result.array_shape()?.dims(), [1]);
    Ok(())
}

#[test]
fn mean_op() -> Result<()> {
    let client = xla::PjRtClient::cpu()?;
    let builder = xla::XlaBuilder::new("test");
    let x = builder.parameter(0, f32::TY, &[-2], "x")?;
    let sum = x.reduce_mean(&[0], false)?.build()?.compile(&client)?;
    let input = xla::Literal::vec1(&[4.2f32, 1.337f32]);
    let result = sum.execute::<xla::Literal>(&[input])?;
    let result = result[0][0].to_literal_sync()?;
    assert_eq!(result.to_vec::<f32>()?, [2.7684999]);
    // Dimensions got reduced.
    assert_eq!(result.array_shape()?.dims(), []);
    Ok(())
}

#[test]
fn tuple_op() -> Result<()> {
    let client = xla::PjRtClient::cpu()?;
    let builder = xla::XlaBuilder::new("test");
    let x = builder.parameter(0, f32::TY, &[-1], "x")?;
    let y = builder.parameter(1, f32::TY, &[2], "x")?;
    let tuple = builder.tuple(&[x, y])?.build()?.compile(&client)?;
    let x = xla::Literal::scalar(3.1f32);
    let y = xla::Literal::vec1(&[4.2f32, 1.337f32]);
    let result = tuple.execute::<xla::Literal>(&[x, y])?;
    let result = result[0][0].to_literal_sync()?;
    assert_eq!(result.shape()?.tuple_size(), Some(2));
    let mut result = result;
    let result = result.decompose_tuple()?;
    assert_eq!(result[1].to_vec::<f32>()?, [4.2, 1.337]);
    assert_eq!(result[0].to_vec::<f32>()?, [3.1]);
    Ok(())
}

#[test]
fn tuple_literal() -> Result<()> {
    let x = xla::Literal::scalar(3.1f32);
    let y = xla::Literal::vec1(&[4.2f32, 1.337f32]);
    let result = xla::Literal::tuple(vec![x, y]);
    assert_eq!(result.shape()?.tuple_size(), Some(2));
    let mut result = result;
    let result = result.decompose_tuple()?;
    assert_eq!(result[1].to_vec::<f32>()?, [4.2, 1.337]);
    assert_eq!(result[0].to_vec::<f32>()?, [3.1]);
    Ok(())
}
