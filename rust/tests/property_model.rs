//! Cross-module property tests: device-model monotonicity, lever
//! soundness, and workload-generator invariants over randomized task
//! specifications (mini-proptest, DESIGN.md §7).

use mmserve::perfmodel::configs::{CHAMELEON_34B, HSTU_14L, LLAMA_34B,
                                  LLAMA_7B, SEAMLESS_M4T};
use mmserve::perfmodel::device::{A100, H100};
use mmserve::perfmodel::latency::{task_cost, TaskSpec};
use mmserve::perfmodel::levers::Levers;
use mmserve::perfmodel::roofline;
use mmserve::substrate::prop::prop_check;
use mmserve::substrate::rng::Rng;
use mmserve::workload::TABLE2;

fn random_decoder_spec(r: &mut Rng) -> TaskSpec {
    let cfg = if r.f64() < 0.5 { &LLAMA_7B } else { &LLAMA_34B };
    TaskSpec::Decoder {
        cfg,
        batch: r.usize(1, 17),
        prompt_len: r.usize(8, 2048),
        decode_steps: r.usize(1, 1024),
        decodes_per_step: 1 + r.usize(0, 2),
    }
}

#[test]
fn prop_h100_never_slower_than_a100() {
    prop_check(
        60,
        1,
        |r| (r.usize(0, 1_000_000), 0usize),
        |&(seed, _)| {
            let mut r = Rng::new(seed as u64);
            let spec = random_decoder_spec(&mut r);
            let a = task_cost(&spec, &A100, &Levers::baseline()).total;
            let h = task_cost(&spec, &H100, &Levers::baseline()).total;
            if h <= a * 1.0001 {
                Ok(())
            } else {
                Err(format!("H100 {h} > A100 {a}"))
            }
        },
    );
}

#[test]
fn prop_levers_never_hurt_at_paper_scale() {
    // The DM lever ladder is monotone: each added lever reduces (or
    // holds) latency for every random decoder workload.
    prop_check(
        60,
        2,
        |r| (r.usize(0, 1_000_000), 0usize),
        |&(seed, _)| {
            let mut r = Rng::new(seed as u64);
            let spec = random_decoder_spec(&mut r);
            let ladder = [
                Levers::baseline(),
                Levers::sdpa(),
                Levers::sdpa_compile(),
                Levers::sys_opt(),
            ];
            let mut prev = f64::INFINITY;
            for lv in ladder {
                let t = task_cost(&spec, &A100, &lv).total;
                if t > prev * 1.0001 {
                    return Err(format!("{} regressed: {t} > {prev}",
                                       lv.label()));
                }
                prev = t;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_roofline_points_under_roof() {
    prop_check(
        60,
        3,
        |r| (r.usize(0, 1_000_000), 0usize),
        |&(seed, _)| {
            let mut r = Rng::new(seed as u64);
            let spec = random_decoder_spec(&mut r);
            for lv in [Levers::baseline(), Levers::sys_opt()] {
                let p = roofline::point("x", &spec, &A100, &lv);
                if p.roof_frac > 1.0 + 1e-9 {
                    return Err(format!("above roof: {}", p.roof_frac));
                }
                if !(p.intensity.is_finite() && p.perf.is_finite()) {
                    return Err("non-finite point".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_more_decode_steps_more_latency() {
    prop_check(
        60,
        4,
        |r| (r.usize(1, 512), r.usize(1, 512)),
        |&(s1, s2)| {
            let (lo, hi) = (s1.min(s2), s1.max(s2).max(s1 + 1));
            let mk = |steps| TaskSpec::Decoder {
                cfg: &CHAMELEON_34B,
                batch: 1,
                prompt_len: 64,
                decode_steps: steps,
                decodes_per_step: 1,
            };
            let a = task_cost(&mk(lo), &A100, &Levers::baseline()).total;
            let b = task_cost(&mk(hi), &A100, &Levers::baseline()).total;
            if b >= a {
                Ok(())
            } else {
                Err(format!("steps {hi} cheaper than {lo}"))
            }
        },
    );
}

#[test]
fn prop_workload_samples_within_bounds_and_positive_cost() {
    prop_check(
        40,
        5,
        |r| (r.usize(0, 1_000_000), 0usize),
        |&(seed, _)| {
            for w in &TABLE2 {
                let xs = mmserve::workload::sample_workload(w, 20,
                                                            seed as u64);
                for s in xs {
                    if s.input_len < w.input.min || s.input_len > w.input.max
                    {
                        return Err(format!(
                            "{}: input {} outside [{}, {}]",
                            w.dataset, s.input_len, w.input.min, w.input.max
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_seamless_and_hstu_costs_finite_and_ordered() {
    prop_check(
        40,
        6,
        |r| (r.usize(32, 2048), r.usize(4, 128)),
        |&(src, steps)| {
            let st = TaskSpec::Seamless {
                cfg: &SEAMLESS_M4T,
                src_len: src,
                text_steps: steps,
                speech_out: false,
                reorder_fused: false,
                speech_in: true,
            };
            let c = task_cost(&st, &A100, &Levers::baseline());
            if !(c.total.is_finite() && c.total > 0.0) {
                return Err("bad seamless cost".into());
            }
            let h1 = TaskSpec::Hstu { cfg: &HSTU_14L, batch: 1, seq: src };
            let h2 = TaskSpec::Hstu { cfg: &HSTU_14L, batch: 2, seq: src };
            let t1 = task_cost(&h1, &A100, &Levers::baseline()).total;
            let t2 = task_cost(&h2, &A100, &Levers::baseline()).total;
            if t2 + 1e-12 < t1 {
                return Err("hstu batch 2 cheaper than batch 1".into());
            }
            let _ = &LLAMA_34B;
            Ok(())
        },
    );
}
