//! Integration: the full coordinator serving paths over real artifacts —
//! continuous batching, contrastive image generation, the Seamless
//! pipeline, HSTU, LayerSkip equivalence, and beam-reorder discipline
//! equivalence.

use mmserve::coordinator::decoder_loop::{encode_prompt, DecoderSession};
use mmserve::coordinator::opts::OptConfig;
use mmserve::coordinator::request::{Request, RequestInput, ResponseOutput,
                                    SamplingParams};
use mmserve::coordinator::seamless_pipe::{ReorderMode, SeamlessPipeline,
                                          SeamlessTask};
use mmserve::coordinator::server::{Router, RouterConfig};
use mmserve::kvpool::replay::{replay, ReplayConfig};
use mmserve::kvpool::KvPoolConfig;
use mmserve::models::tokenizer::{IMG_BASE, IMG_TOKENS};
use mmserve::models::{ModelKind, TaskKind};
use mmserve::routing::replay::{routing_replay, KillSpec,
                               RoutingReplayConfig};
use mmserve::routing::RoutingPolicy;
use mmserve::runtime::engine::Engine;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = mmserve::artifacts_dir();
    if dir.join("llama").join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("artifacts not built — skipping");
        None
    }
}

#[test]
fn batched_router_serves_text_requests() {
    let Some(dir) = artifacts() else { return };
    let router = Router::start(&dir, RouterConfig {
        models: vec![ModelKind::Llama],
        opt: OptConfig::baseline(),
        reorder: ReorderMode::Fused,
        batch: 4,
        prefill_budget: 0,
        chunk_prefill: 0,
        kv: KvPoolConfig::default(),
        tracer: None,
        ..RouterConfig::default()
    });
    let mut rxs = vec![];
    for i in 0..7 {
        let mut req = Request::text(router.fresh_id(), TaskKind::TextToText,
                                    "hello world", 6 + i % 3);
        req.sampling = SamplingParams::greedy();
        rxs.push((req.id, req.max_new_tokens, router.submit(req).unwrap()));
    }
    for (id, max_new, rx) in rxs {
        let r = rx.recv().unwrap().expect("response");
        assert_eq!(r.id, id);
        assert!(r.decode_steps <= max_new);
        assert!(r.decode_steps > 0);
        assert!(matches!(r.output, ResponseOutput::Text(_)));
    }
    router.shutdown();
}

/// Replicated workers must move *where* a request runs, never change
/// *what* it decodes: greedy outputs across 2 replicas match the
/// single-worker stream under every routing policy.
#[test]
fn replicated_router_preserves_greedy_outputs() {
    let Some(dir) = artifacts() else { return };
    let prompts =
        ["hello world", "hello world", "sort an array", "hello world"];
    let run = |replicas: usize, policy: RoutingPolicy| -> Vec<Vec<i32>> {
        let router = Router::start(&dir, RouterConfig {
            models: vec![ModelKind::Llama],
            batch: 4,
            replicas,
            policy,
            ..RouterConfig::default()
        });
        let rxs: Vec<_> = prompts
            .iter()
            .map(|p| {
                let mut req = Request::text(router.fresh_id(),
                                            TaskKind::TextToText, p, 6);
                req.sampling = SamplingParams::greedy();
                router.submit(req).unwrap()
            })
            .collect();
        let out = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().expect("response").tokens)
            .collect();
        router.shutdown();
        out
    };
    let single = run(1, RoutingPolicy::PrefixAffinity);
    for policy in RoutingPolicy::ALL {
        assert_eq!(run(2, policy), single,
                   "{policy} changed greedy outputs");
    }
}

/// Satellite (deviceless, runs without artifacts): kill a replica
/// mid-workload in the routing replay — every request still completes
/// on the survivors and the decoded streams are exactly the no-kill
/// streams under every policy and shard count (seeded, deterministic).
#[test]
fn routing_failover_with_sharded_snapshots_drops_nothing() {
    for shards in [1usize, 2] {
        let base = ReplayConfig {
            tenants: 2,
            shards,
            ..ReplayConfig::default()
        };
        let healthy = routing_replay(
            &RoutingReplayConfig {
                base: base.clone(),
                replicas: 2,
                ..RoutingReplayConfig::default()
            },
            RoutingPolicy::PrefixAffinity,
        );
        let crashed_cfg = RoutingReplayConfig {
            base: base.clone(),
            replicas: 2,
            kill: Some(KillSpec { replica: 0, after_delivered: 24 }),
            ..RoutingReplayConfig::default()
        };
        let crashed =
            routing_replay(&crashed_cfg, RoutingPolicy::PrefixAffinity);
        assert_eq!(crashed.completed, base.requests,
                   "shards={shards}: no request dropped by the crash");
        assert_eq!(crashed.dropped, 0, "shards={shards}");
        assert_eq!(crashed.outputs, healthy.outputs,
                   "shards={shards}: fail-over must not change tokens");
        // Determinism: the crash replay is exactly reproducible.
        let again =
            routing_replay(&crashed_cfg, RoutingPolicy::PrefixAffinity);
        assert_eq!(again.outputs, crashed.outputs);
        assert_eq!(again.routed, crashed.routed);
        assert_eq!(again.sim_time, crashed.sim_time);
    }
}

/// Acceptance criterion (deviceless): the `--shards 1` replay is
/// bit-identical to the monolithic default — outputs, pool counters,
/// clock — and splitting the budget keeps every request servable with
/// the same streams.
#[test]
fn shards_one_is_monolithic_and_sharding_preserves_streams() {
    let mono = replay(&ReplayConfig::default(), true);
    let one = replay(
        &ReplayConfig { shards: 1, ..ReplayConfig::default() },
        true,
    );
    assert_eq!(one.outputs, mono.outputs);
    assert_eq!(one.sim_time, mono.sim_time);
    assert_eq!(one.decode_ticks, mono.decode_ticks);
    assert_eq!(one.stats.blocks_allocated, mono.stats.blocks_allocated);
    assert_eq!(one.stats.prefix_hits, mono.stats.prefix_hits);
    assert_eq!(one.stats.preemptions, mono.stats.preemptions);
    let two = replay(
        &ReplayConfig { shards: 2, ..ReplayConfig::default() },
        true,
    );
    assert_eq!(two.completed, mono.completed);
    assert_eq!(two.dropped, 0);
    assert_eq!(two.outputs, mono.outputs,
               "page placement must never change decoded tokens");
}

/// Replicated *and* sharded serving over real artifacts: splitting
/// each worker's KV page budget across device arenas must not change
/// greedy outputs vs the monolithic single-worker stream.
#[test]
fn sharded_router_preserves_greedy_outputs() {
    let Some(dir) = artifacts() else { return };
    let prompts =
        ["hello world", "hello world", "sort an array", "hello world"];
    let run = |replicas: usize, shards: usize| -> Vec<Vec<i32>> {
        let router = Router::start(&dir, RouterConfig {
            models: vec![ModelKind::Llama],
            batch: 4,
            replicas,
            kv: KvPoolConfig { shards, ..KvPoolConfig::default() },
            ..RouterConfig::default()
        });
        let rxs: Vec<_> = prompts
            .iter()
            .map(|p| {
                let mut req = Request::text(router.fresh_id(),
                                            TaskKind::TextToText, p, 6);
                req.sampling = SamplingParams::greedy();
                router.submit(req).unwrap()
            })
            .collect();
        let out = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().expect("response").tokens)
            .collect();
        router.shutdown();
        out
    };
    let single = run(1, 1);
    assert_eq!(run(1, 2), single, "sharding changed greedy outputs");
    assert_eq!(run(2, 2), single,
               "replicas + shards changed greedy outputs");
}

#[test]
fn batched_results_match_single_stream() {
    // Continuous batching must not change greedy outputs vs bs=1.
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir.join("llama")).unwrap();
    let session = DecoderSession::new(&engine, OptConfig::baseline())
        .unwrap();
    let prompts = ["alpha beta", "the function returns", "zzz"];
    let mut singles = vec![];
    for p in prompts {
        let ids = encode_prompt(p);
        singles.push(
            session.generate(&ids, 8, &SamplingParams::greedy()).unwrap()
                .tokens,
        );
    }
    let router = Router::start(&dir, RouterConfig {
        models: vec![ModelKind::Llama],
        opt: OptConfig::baseline(),
        reorder: ReorderMode::Fused,
        batch: 4,
        prefill_budget: 0,
        chunk_prefill: 0,
        kv: KvPoolConfig::default(),
        tracer: None,
        ..RouterConfig::default()
    });
    let rxs: Vec<_> = prompts
        .iter()
        .map(|p| {
            let mut req = Request::text(router.fresh_id(),
                                        TaskKind::TextToText, p, 8);
            req.sampling = SamplingParams::greedy();
            router.submit(req).unwrap()
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv().unwrap().unwrap();
        assert_eq!(r.tokens, singles[i], "prompt {i} diverged in batch");
    }
    router.shutdown();
}

#[test]
fn chunked_prefill_router_matches_single_stream() {
    // Chunked prefill (tentpole): long prompts are admitted in
    // budget-sized chunks — first chunk via the bucketed prefill +
    // pack, continuation tokens appended incrementally through the
    // batched decode graph. Greedy outputs must match the bs=1 path.
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir.join("llama")).unwrap();
    let session = DecoderSession::new(&engine, OptConfig::baseline())
        .unwrap();
    let long = "the quick brown fox jumps over the lazy dog again and \
                again while the scheduler feeds the prompt in chunks";
    let prompts = [long, "short one", "alpha beta gamma delta"];
    let mut singles = vec![];
    for p in prompts {
        let ids = encode_prompt(p);
        singles.push(
            session.generate(&ids, 8, &SamplingParams::greedy()).unwrap()
                .tokens,
        );
    }
    let router = Router::start(&dir, RouterConfig {
        models: vec![ModelKind::Llama],
        opt: OptConfig::baseline(),
        reorder: ReorderMode::Fused,
        batch: 4,
        prefill_budget: 0,
        chunk_prefill: 8, // forces multi-chunk admission for all three
        kv: KvPoolConfig::default(),
        tracer: None,
        ..RouterConfig::default()
    });
    let rxs: Vec<_> = prompts
        .iter()
        .map(|p| {
            let mut req = Request::text(router.fresh_id(),
                                        TaskKind::TextToText, p, 8);
            req.sampling = SamplingParams::greedy();
            router.submit(req).unwrap()
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv().unwrap().unwrap();
        assert_eq!(r.tokens, singles[i],
                   "prompt {i} diverged under chunked prefill");
        assert!(r.decode_steps > 0);
    }
    router.shutdown();
}

#[test]
fn layerskip_greedy_equals_baseline_greedy() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir.join("llama")).unwrap();
    let base = DecoderSession::new(&engine, OptConfig::baseline()).unwrap();
    let mut o = OptConfig::baseline();
    o.layerskip = true;
    let ls = DecoderSession::new(&engine, o).unwrap();
    for p in ["speculate on this", "fn main() {"] {
        let ids = encode_prompt(p);
        let sp = SamplingParams::greedy();
        let rb = base.generate(&ids, 20, &sp).unwrap();
        let rl = ls.generate(&ids, 20, &sp).unwrap();
        let n = rb.tokens.len().min(rl.tokens.len());
        assert_eq!(rb.tokens[..n], rl.tokens[..n],
                   "greedy layerskip must match baseline ({p})");
        assert!(rl.draft_rounds > 0);
    }
}

#[test]
fn eager_and_graph_agree() {
    // The per-op dispatch pipeline computes the same function as the
    // fused graph (Obs #2 is about *time*, not values).
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir.join("llama")).unwrap();
    let graph = DecoderSession::new(&engine, OptConfig::baseline()).unwrap();
    let eager = DecoderSession::new(&engine, OptConfig::eager_baseline())
        .unwrap();
    let ids = encode_prompt("compare modes");
    let sp = SamplingParams::greedy();
    let rg = graph.generate(&ids, 10, &sp).unwrap();
    let re = eager.generate(&ids, 10, &sp).unwrap();
    let n = rg.tokens.len().min(re.tokens.len());
    assert_eq!(rg.tokens[..n], re.tokens[..n]);
}

#[test]
fn contrastive_image_generation_emits_image_tokens() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir.join("chameleon")).unwrap();
    let session = DecoderSession::new(&engine, OptConfig::baseline())
        .unwrap();
    let ids = encode_prompt("a red square");
    let r = session
        .generate_image(&ids, IMG_TOKENS, &SamplingParams::greedy())
        .unwrap();
    assert_eq!(r.tokens.len(), IMG_TOKENS);
    assert!(r.tokens.iter().all(|&t| {
        t >= IMG_BASE && t < IMG_BASE + IMG_TOKENS as i32
    }));
}

#[test]
fn seamless_reorder_disciplines_agree() {
    // HostCopy (baseline index_select) and Fused (device gather) are two
    // implementations of the same reorder — beams must match exactly.
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir.join("seamless")).unwrap();
    let wav: Vec<f32> = (0..160 * 32).map(|i| (i as f32 * 0.05).sin())
        .collect();
    let host = SeamlessPipeline::new(&engine, ReorderMode::HostCopy)
        .unwrap()
        .run(SeamlessTask::SpeechToText, Some(&wav), None, 16)
        .unwrap();
    let fused = SeamlessPipeline::new(&engine, ReorderMode::Fused)
        .unwrap()
        .run(SeamlessTask::SpeechToText, Some(&wav), None, 16)
        .unwrap();
    assert_eq!(host.text_tokens, fused.text_tokens);
}

#[test]
fn seamless_speech_tail_produces_waveform() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir.join("seamless")).unwrap();
    let pipe = SeamlessPipeline::new(&engine, ReorderMode::Fused).unwrap();
    let r = pipe
        .run(SeamlessTask::TextToSpeech, None, Some("hello there"), 12)
        .unwrap();
    assert!(!r.units.is_empty());
    assert_eq!(r.waveform.len(), r.units.len() * pipe.dims.voc_rate);
    assert!(r.waveform.iter().all(|v| v.abs() <= 1.0));
}

#[test]
fn hstu_router_returns_actions() {
    let Some(dir) = artifacts() else { return };
    let router = Router::start(&dir, RouterConfig {
        models: vec![ModelKind::Hstu],
        opt: OptConfig::baseline(),
        reorder: ReorderMode::Fused,
        batch: 1,
        prefill_budget: 0,
        chunk_prefill: 0,
        kv: KvPoolConfig::default(),
        tracer: None,
        ..RouterConfig::default()
    });
    let history: Vec<i32> = (0..150).map(|i| (i * 13) % 6000).collect();
    let req = Request {
        id: router.fresh_id(),
        task: TaskKind::HistoryToAction,
        input: RequestInput::History(history),
        max_new_tokens: 0,
        sampling: SamplingParams::greedy(),
    };
    let r = router.call(req).unwrap();
    let ResponseOutput::Actions { engagement, top_items } = r.output else {
        panic!("expected actions");
    };
    assert!(!engagement.is_empty());
    assert_eq!(top_items.len(), 10);
    assert!(top_items.iter().all(|&i| (0..6000).contains(&i)));
    assert_eq!(r.decode_steps, 0, "HSTU is non-autoregressive (Obs #1)");
    router.shutdown();
}
