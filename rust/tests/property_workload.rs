//! Property suite for the open-loop workload engine and the elastic
//! autoscaler (mini-proptest, `PROPTEST_CASES=512` in CI):
//!
//! * Poisson arrival gaps have the exponential signature: empirical
//!   inter-arrival mean within tolerance of `1/rate` and coefficient
//!   of variation near 1,
//! * the Zipf tenant sampler reproduces the rank-frequency law: the
//!   log-log slope of rank counts tracks `-s`, and the workload
//!   generator's multi-tenant head dominates its tail,
//! * burst/flash-crowd episodes are strictly contained in their
//!   configured windows — every Burst-phase arrival lies inside a
//!   window, every injected extra inside *its* window, and no
//!   Base/Peak arrival lies inside any,
//! * same seed ⇒ bit-identical arrival streams (timestamps compared
//!   by `to_bits`, payloads token-for-token),
//! * same seed + config ⇒ bit-identical autoscaled fleet replays:
//!   scale-event timeline, per-request outputs, routing counts, pool
//!   counters and both clocks agree across two runs — including runs
//!   mixing `--autoscale` with `--mix` and `--shards`.

use mmserve::kvpool::replay::{generate_workload, MixSpec,
                              ReplayConfig};
use mmserve::routing::autoscale::{autoscale_replay, AutoscaleSpec,
                                  AutoscaleReplayConfig};
use mmserve::routing::RoutingPolicy;
use mmserve::substrate::prop::prop_check;
use mmserve::substrate::rng::Rng;
use mmserve::workload::arrivals::{generate_arrivals, zipf_cdf,
                                  zipf_pick, ArrivalPhase,
                                  ArrivalSpec, BurstSpec, RateCurve};

/// An open-loop config with a raw [`ArrivalSpec`] (no string round
/// trip — the parser has its own unit tests).
fn open_cfg(requests: usize, tenants: usize, seed: u64,
            spec: ArrivalSpec) -> ReplayConfig {
    ReplayConfig {
        requests,
        tenants,
        seed,
        arrivals: Some(spec),
        ..ReplayConfig::default()
    }
}

/// Poisson arrivals: the gap stream must look exponential — mean
/// `1/rate` and CV ≈ 1 (a drifting or clumping generator fails one or
/// both).
#[test]
fn prop_poisson_interarrival_mean_and_cv() {
    prop_check(
        60,
        0x90A1_55E1,
        |r: &mut Rng| (r.usize(5, 40), r.range(0, 1 << 32)),
        |&(rate_decis, seed)| {
            let rate = rate_decis as f64 / 10.0;
            let spec = ArrivalSpec {
                curve: RateCurve::Poisson { rate },
                bursts: vec![],
                followup_percent: 0,
                think_mean: 25.0,
                zipf_s: 0.0,
            };
            let cfg = open_cfg(512, 1, seed, spec);
            let arr = generate_arrivals(&cfg);
            let gaps: Vec<f64> = arr
                .windows(2)
                .map(|w| w[1].at - w[0].at)
                .collect();
            let n = gaps.len() as f64;
            let mean = gaps.iter().sum::<f64>() / n;
            let want = 1.0 / rate;
            if (mean - want).abs() > 0.25 * want {
                return Err(format!(
                    "rate {rate}: mean gap {mean:.4}, want \
                     {want:.4} ± 25%"
                ));
            }
            let var = gaps.iter()
                .map(|g| (g - mean).powi(2))
                .sum::<f64>() / n;
            let cv = var.sqrt() / mean;
            if !(0.7..=1.3).contains(&cv) {
                return Err(format!(
                    "rate {rate}: CV {cv:.3} outside [0.7, 1.3] — \
                     not exponential"
                ));
            }
            Ok(())
        },
    );
}

/// Zipf rank-frequency: the sampler's log-log slope over ranks tracks
/// `-s`, and the workload generator's multi-tenant head beats its
/// tail.
#[test]
fn prop_zipf_rank_frequency_slope() {
    prop_check(
        60,
        0x21FF_A0B3,
        |r: &mut Rng| {
            ((r.usize(4, 9), r.usize(10, 17)), r.range(0, 1 << 32))
        },
        |&((tenants, s_decis), seed)| {
            let s = s_decis as f64 / 10.0;
            // Direct sampler check: 5000 inverse-CDF draws.
            let cdf = zipf_cdf(tenants, s);
            let mut rng = Rng::new(seed);
            let mut counts = vec![0usize; tenants];
            for _ in 0..5000 {
                counts[zipf_pick(&cdf, rng.f64())] += 1;
            }
            // Least-squares slope of ln(count) on ln(rank+1) over
            // non-empty ranks.
            let pts: Vec<(f64, f64)> = counts
                .iter()
                .enumerate()
                .filter(|&(_, &c)| c > 0)
                .map(|(k, &c)| {
                    ((k as f64 + 1.0).ln(), (c as f64).ln())
                })
                .collect();
            if pts.len() < 3 {
                return Err(format!(
                    "s {s}: only {} non-empty ranks", pts.len()
                ));
            }
            let m = pts.len() as f64;
            let (sx, sy): (f64, f64) = pts.iter()
                .fold((0.0, 0.0), |(a, b), &(x, y)| (a + x, b + y));
            let (sxx, sxy): (f64, f64) = pts.iter().fold(
                (0.0, 0.0),
                |(a, b), &(x, y)| (a + x * x, b + x * y),
            );
            let slope = (m * sxy - sx * sy) / (m * sxx - sx * sx);
            if (slope + s).abs() > 0.35 {
                return Err(format!(
                    "s {s}: rank-frequency slope {slope:.3}, want \
                     ≈ {:.3}", -s
                ));
            }
            // End to end: the generator's tenant draw uses the same
            // sampler — its most popular tenant must dominate the
            // least popular.
            let spec = ArrivalSpec {
                curve: RateCurve::Poisson { rate: 1.0 },
                bursts: vec![],
                followup_percent: 0,
                think_mean: 25.0,
                zipf_s: s,
            };
            let cfg = open_cfg(300, tenants, seed, spec);
            let mut wc = vec![0usize; tenants];
            for r in generate_workload(&cfg) {
                wc[r.tenant] += 1;
            }
            if wc[0] <= wc[tenants - 1] {
                return Err(format!(
                    "s {s}: workload head {} ≤ tail {}", wc[0],
                    wc[tenants - 1]
                ));
            }
            Ok(())
        },
    );
}

/// Burst episodes are strictly contained: Burst-phase ⟺ inside a
/// window, and injected extras (ids above the base range) land inside
/// windows only.
#[test]
fn prop_burst_arrivals_contained() {
    prop_check(
        60,
        0xB0B5_7CA7,
        |r: &mut Rng| {
            // (window start decis, window len decis, mult, second
            // window gap decis), seed
            ((r.usize(0, 300), r.usize(50, 200)),
             (r.usize(2, 6), r.range(0, 1 << 32)))
        },
        |&((at_d, len_d), (mult, seed))| {
            let b1 = BurstSpec {
                at: at_d as f64 / 10.0,
                len: len_d as f64 / 10.0,
                mult: mult as f64,
            };
            // A second, disjoint window after the first.
            let b2 = BurstSpec {
                at: b1.at + b1.len + 7.0,
                len: 5.0,
                mult: mult as f64,
            };
            let spec = ArrivalSpec {
                curve: RateCurve::Diurnal {
                    base: 0.4,
                    peak: 1.2,
                    period: 90.0,
                },
                bursts: vec![b1, b2],
                followup_percent: 20,
                think_mean: 10.0,
                zipf_s: 1.1,
            };
            let cfg = open_cfg(64, 2, seed, spec);
            let arr = generate_arrivals(&cfg);
            let inside =
                |t: f64| b1.contains(t) || b2.contains(t);
            for a in &arr {
                let burst_phase = a.phase == ArrivalPhase::Burst;
                if burst_phase != inside(a.at) {
                    return Err(format!(
                        "id {} at {:.3}: phase {:?} vs windows \
                         [{:.1},{:.1}) [{:.1},{:.1})",
                        a.req.id, a.at, a.phase, b1.at,
                        b1.at + b1.len, b2.at, b2.at + b2.len
                    ));
                }
                // Injected extras carry ids above the base range and
                // never above the follow-up space.
                let injected = a.req.id > cfg.requests as u64
                    && a.followup_of.is_none();
                if injected && !inside(a.at) {
                    return Err(format!(
                        "injected id {} escaped its window (at \
                         {:.3})", a.req.id, a.at
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Same seed ⇒ the same stream, bit for bit; timestamps compared via
/// `f64::to_bits`, payloads token-for-token.
#[test]
fn prop_same_seed_bitidentical_stream() {
    prop_check(
        60,
        0x5EED_5EED,
        |r: &mut Rng| (r.range(0, 1 << 32), r.usize(16, 96)),
        |&(seed, requests)| {
            let spec = ArrivalSpec {
                curve: RateCurve::Diurnal {
                    base: 0.3,
                    peak: 1.1,
                    period: 120.0,
                },
                bursts: vec![BurstSpec {
                    at: 30.0,
                    len: 20.0,
                    mult: 4.0,
                }],
                followup_percent: 25,
                think_mean: 15.0,
                zipf_s: 1.2,
            };
            let cfg = open_cfg(requests, 3, seed, spec);
            let a = generate_arrivals(&cfg);
            let b = generate_arrivals(&cfg);
            if a.len() != b.len() {
                return Err(format!(
                    "stream lengths differ: {} vs {}", a.len(),
                    b.len()
                ));
            }
            for (x, y) in a.iter().zip(&b) {
                if x.at.to_bits() != y.at.to_bits()
                    || x.req.id != y.req.id
                    || x.req.tokens != y.req.tokens
                    || x.req.decode != y.req.decode
                    || x.req.tenant != y.req.tenant
                    || x.phase != y.phase
                    || x.followup_of != y.followup_of
                {
                    return Err(format!(
                        "stream diverged at id {} / {}", x.req.id,
                        y.req.id
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Autoscaler determinism: same seed + config ⇒ bit-identical
/// scale-event timeline, per-request outputs, routing counts, pool
/// counters and clocks — including `--mix` and `--shards` runs.
#[test]
fn prop_autoscale_determinism() {
    prop_check(
        24,
        0xAC57_0CA1,
        |r: &mut Rng| {
            ((r.range(0, 1 << 32), r.usize(1, 4)),
             (r.usize(0, 2), r.usize(0, 3)))
        },
        |&((seed, shards), (mixed, policy_idx))| {
            let spec = ArrivalSpec {
                curve: RateCurve::Diurnal {
                    base: 0.3,
                    peak: 1.0,
                    period: 100.0,
                },
                bursts: vec![BurstSpec {
                    at: 20.0,
                    len: 15.0,
                    mult: 3.0,
                }],
                followup_percent: 20,
                think_mean: 10.0,
                zipf_s: 1.1,
            };
            let mut base = open_cfg(40, 2, seed, spec);
            base.shards = shards;
            if mixed == 1 {
                base.mix = Some(MixSpec {
                    seamless_percent: 20,
                    hstu_percent: 20,
                    beam: 3,
                });
            }
            let cfg = AutoscaleReplayConfig {
                base,
                policy: RoutingPolicy::ALL[policy_idx],
                replicas: 1,
                autoscale: Some(AutoscaleSpec::new(1, 3)),
                drain: None,
                kill: None,
            };
            let a = autoscale_replay(&cfg);
            let b = autoscale_replay(&cfg);
            if format!("{:?}", a.events) != format!("{:?}", b.events)
            {
                return Err(format!(
                    "scale timelines diverged:\n{:?}\n{:?}",
                    a.events, b.events
                ));
            }
            if a.outputs != b.outputs {
                return Err("per-request outputs diverged".into());
            }
            if a.routed != b.routed {
                return Err(format!(
                    "routing counts diverged: {:?} vs {:?}", a.routed,
                    b.routed
                ));
            }
            if format!("{:?}", a.fleet) != format!("{:?}", b.fleet) {
                return Err("fleet pool counters diverged".into());
            }
            if a.sim_time.to_bits() != b.sim_time.to_bits()
                || a.replica_seconds.to_bits()
                    != b.replica_seconds.to_bits()
            {
                return Err(format!(
                    "clocks diverged: sim {} vs {}, replica-s {} vs \
                     {}",
                    a.sim_time, b.sim_time, a.replica_seconds,
                    b.replica_seconds
                ));
            }
            if a.completed != b.completed || a.dropped != b.dropped {
                return Err("completion counters diverged".into());
            }
            if a.completed != a.arrivals || a.dropped != 0 {
                return Err(format!(
                    "autoscaled run must serve every arrival: \
                     completed {} of {}, dropped {}",
                    a.completed, a.arrivals, a.dropped
                ));
            }
            Ok(())
        },
    );
}
