//! Integration: the telemetry subsystem over the real serving path —
//! span coverage of a generation, idle-gap attribution buckets,
//! Chrome-trace export validity, and the zero-cost disabled mode.

use std::time::Instant;

use mmserve::coordinator::decoder_loop::{encode_prompt, DecoderSession};
use mmserve::coordinator::opts::OptConfig;
use mmserve::coordinator::request::{Request, SamplingParams};
use mmserve::coordinator::seamless_pipe::ReorderMode;
use mmserve::coordinator::server::{Router, RouterConfig};
use mmserve::kvpool::KvPoolConfig;
use mmserve::models::{ModelKind, TaskKind};
use mmserve::runtime::engine::Engine;
use mmserve::substrate::json::Json;
use mmserve::telemetry::attribution::GAP_CATEGORIES;
use mmserve::telemetry::chrome_trace;
use mmserve::telemetry::tracer::{Cat, Tracer};
use mmserve::telemetry::{Aggregate, Attribution, Timeline};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = mmserve::artifacts_dir();
    if dir.join("llama").join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("artifacts not built — skipping");
        None
    }
}

/// Acceptance: spans cover ≥ 95% of a generation's wall time, and the
/// idle-gap attribution reports all four paper buckets.
#[test]
fn traced_generation_coverage_and_attribution() {
    let Some(dir) = artifacts() else { return };
    let tracer = Tracer::off();
    let mut engine = Engine::load(&dir.join("llama")).unwrap();
    engine.set_tracer(tracer.worker("llama"));
    let session =
        DecoderSession::new(&engine, OptConfig::baseline()).unwrap();
    let prompt = encode_prompt("trace coverage check");
    // warm up (compiles) untraced, then measure
    session.generate(&prompt, 4, &SamplingParams::greedy()).unwrap();
    tracer.set_enabled(true);
    let t0 = Instant::now();
    let r = session.generate(&prompt, 24, &SamplingParams::greedy()).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    tracer.set_enabled(false);
    let trace = tracer.drain();
    assert!(r.decode_steps > 0);
    assert!(!trace.is_empty());

    // Span union must cover ≥95% of the traced window, and the traced
    // window itself must be essentially the whole generate() call.
    assert!(trace.coverage() >= 0.95,
            "span coverage {:.3} < 0.95", trace.coverage());
    assert!(trace.wall() >= 0.90 * wall,
            "trace window {:.6}s vs wall {:.6}s", trace.wall(), wall);

    // Execute spans exist and the attribution splits the non-execute
    // time into (at least) scheduling/sampling/tokenization/sync.
    let attr = Attribution::from_trace(&trace);
    assert!(attr.execute > 0.0);
    for key in ["Scheduling", "Sampling", "Tokenization", "Sync"] {
        assert!(attr.gaps.entries().any(|(k, _)| k == key),
                "missing bucket {key}");
    }
    assert!((attr.execute + attr.idle_total() - attr.wall).abs()
                < 1e-9 * attr.wall.max(1.0),
            "execute + idle must equal the dispatch window");
    // Host sampling happens between dispatches in the bs=1 loop.
    assert!(attr.gaps.get("Sampling") > 0.0);

    // The aggregation layer reproduces the old per-stage accounting.
    let agg = Aggregate::from_trace(&trace);
    assert!(agg.per_stage.entries().any(|(k, _)| k.starts_with("decode")));
    assert!(agg.per_category.get("Execute") > 0.0);
    assert_eq!(agg.ttft_ms.len(), 0, "no request ids on a bare session");

    // Per-step timeline: one tick per decode step.
    let tl = Timeline::from_trace(&trace);
    assert_eq!(tl.len(), r.decode_steps, "one tick per decode step");
}

/// Acceptance: a traced router run exports valid Chrome-trace JSON.
#[test]
fn traced_router_run_exports_chrome_json() {
    let Some(dir) = artifacts() else { return };
    let tracer = Tracer::new();
    let router = Router::start(&dir, RouterConfig {
        models: vec![ModelKind::Llama],
        opt: OptConfig::baseline(),
        reorder: ReorderMode::Fused,
        batch: 4,
        prefill_budget: 0,
        chunk_prefill: 0,
        kv: KvPoolConfig::default(),
        tracer: Some(tracer.clone()),
        ..RouterConfig::default()
    });
    let mut rxs = vec![];
    for i in 0..5 {
        let mut req = Request::text(router.fresh_id(), TaskKind::TextToText,
                                    "hello telemetry", 6 + i % 3);
        req.sampling = SamplingParams::greedy();
        rxs.push((req.id, router.submit(req).unwrap()));
    }
    let mut ids = vec![];
    for (id, rx) in rxs {
        let resp = rx.recv().unwrap().expect("response");
        assert_eq!(resp.id, id);
        ids.push(id);
    }
    router.shutdown();
    let trace = tracer.drain();
    assert!(!trace.is_empty());

    // Every request id shows up in the trace (tokenize/prefill spans).
    let traced = trace.request_ids();
    for id in ids {
        assert!(traced.contains(&id), "request {id} missing from trace");
    }
    // Scheduler spans are tick-tagged — the timeline reconstructs.
    assert!(trace.spans.iter().any(|s| s.cat == Cat::Schedule));
    assert!(!Timeline::from_trace(&trace).is_empty());

    // Chrome-trace export: parses back, one X event per span with
    // microsecond timestamps, plus thread-name metadata.
    let path = std::env::temp_dir().join("mmserve_itest_trace.json");
    chrome_trace::write(&path, &trace).unwrap();
    let body = std::fs::read_to_string(&path).unwrap();
    let parsed = Json::parse(&body).unwrap();
    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    let xs: Vec<_> = events
        .iter()
        .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
        .collect();
    assert_eq!(xs.len(), trace.len());
    for e in xs.iter().take(50) {
        assert!(e.get("ts").unwrap().as_f64().unwrap() >= 0.0);
        assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
        assert!(e.get("name").unwrap().as_str().is_some());
    }
    assert!(events.iter().any(|e| {
        e.get("ph").and_then(|p| p.as_str()) == Some("M")
    }));
    let _ = std::fs::remove_file(&path);
}

/// Acceptance: tracing disabled records zero spans end to end, so the
/// serving path carries no instrumentation cost.
#[test]
fn disabled_tracer_records_zero_spans_end_to_end() {
    let Some(dir) = artifacts() else { return };
    let tracer = Tracer::off();
    let router = Router::start(&dir, RouterConfig {
        models: vec![ModelKind::Llama],
        opt: OptConfig::baseline(),
        reorder: ReorderMode::Fused,
        batch: 4,
        prefill_budget: 0,
        chunk_prefill: 0,
        kv: KvPoolConfig::default(),
        tracer: Some(tracer.clone()),
        ..RouterConfig::default()
    });
    let rx = router
        .submit(Request::text(router.fresh_id(), TaskKind::TextToText,
                              "quiet run", 8))
        .unwrap();
    rx.recv().unwrap().unwrap();
    router.shutdown();
    assert_eq!(tracer.drain().len(), 0,
               "disabled tracing must record zero spans");
}

/// The attribution buckets are stable API: every bucket (including
/// the kvpool `KvCapacity` and chunked-prefill `PrefillStall` ones)
/// is always present.
#[test]
fn attribution_buckets_cover_paper_categories() {
    let attr = Attribution::from_trace(&mmserve::telemetry::Trace::default());
    for key in GAP_CATEGORIES {
        assert!(attr.gaps.entries().any(|(k, _)| k == key), "{key}");
    }
    for key in ["Scheduling", "Sampling", "Tokenization", "Sync"] {
        assert!(GAP_CATEGORIES.contains(&key));
    }
}
