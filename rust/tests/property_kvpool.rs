//! Property suite for the sharded KV page pool (mini-proptest,
//! `PROPTEST_CASES=512` in CI):
//!
//! * one-shard [`ShardedBlockPool`] bisimulates the monolithic
//!   [`BlockPool`] op for op — the `--shards 1` bit-identity the
//!   acceptance criterion demands,
//! * random admit/extend/advance/release/preempt interleavings over a
//!   sharded [`KvPool`] never exceed a shard's arena, never leak or
//!   double-free pages, keep every refcount equal to its table
//!   references, and never leave a table pointing at a freed
//!   `(device, page)`,
//! * chunked-prefill exhaustion (`KvPool::extend`) is a structured
//!   error that rewinds the position — requeueable, never a panic.

use mmserve::kvpool::{BlockPool, KvError, KvPool, PageState,
                      PreemptMode, ShardedBlockPool};
use mmserve::substrate::prop::prop_check;
use mmserve::substrate::rng::Rng;

/// Reference model of one page's lifecycle for the bisimulation walk.
#[derive(Clone, Copy, PartialEq, Debug)]
enum Model {
    Free,
    Live(usize),
    Cached,
}

/// Drive the same operation stream through a one-shard
/// `ShardedBlockPool` and a monolithic `BlockPool`; every return value
/// and every page's (state, refs) must match at every step.
#[test]
fn prop_single_shard_bisimulates_monolithic_blockpool() {
    const PAGES: usize = 6;
    prop_check(
        150,
        0x5a4d,
        |r: &mut Rng| {
            let n = r.usize(1, 120);
            (0..n).map(|_| r.usize(0, 10_000)).collect::<Vec<usize>>()
        },
        |ops| {
            let mut sharded = ShardedBlockPool::new(PAGES, 4, 1);
            let mut mono = BlockPool::new(PAGES, 4);
            let mut model = [Model::Free; PAGES];
            let pick = |model: &[Model; PAGES], x: usize,
                        want: fn(&Model) -> bool| {
                let hits: Vec<usize> = (0..PAGES)
                    .filter(|&p| want(&model[p]))
                    .collect();
                if hits.is_empty() {
                    None
                } else {
                    Some(hits[x % hits.len()])
                }
            };
            for &x in ops {
                let op = x % 6;
                let arg = x / 6;
                match op {
                    0 => {
                        let a = sharded.alloc();
                        let b = mono.alloc();
                        if a != b {
                            return Err(format!(
                                "alloc diverged: {a:?} vs {b:?}"
                            ));
                        }
                        if let Some(p) = a {
                            model[p] = Model::Live(1);
                        }
                    }
                    1 => {
                        if let Some(p) = pick(&model, arg, |m| {
                            matches!(m, Model::Live(r) if *r > 0)
                        }) {
                            sharded.retain(p);
                            mono.retain(p);
                            let Model::Live(r) = model[p] else {
                                unreachable!()
                            };
                            model[p] = Model::Live(r + 1);
                        }
                    }
                    2 => {
                        if let Some(p) = pick(&model, arg, |m| {
                            matches!(m, Model::Live(r) if *r > 0)
                        }) {
                            let a = sharded.release(p);
                            let b = mono.release(p);
                            if a != b {
                                return Err(format!(
                                    "release diverged: {a} vs {b}"
                                ));
                            }
                            if a == 0 {
                                // Settle the zero-ref page both ways.
                                if arg % 2 == 0 {
                                    sharded.free_page(p);
                                    mono.free_page(p);
                                    model[p] = Model::Free;
                                } else {
                                    sharded.park_cached(p);
                                    mono.park_cached(p);
                                    model[p] = Model::Cached;
                                }
                            } else {
                                model[p] = Model::Live(a);
                            }
                        }
                    }
                    3 => {
                        if let Some(p) = pick(&model, arg, |m| {
                            matches!(m, Model::Cached)
                        }) {
                            sharded.unpark(p);
                            mono.unpark(p);
                            model[p] = Model::Live(1);
                        }
                    }
                    4 => {
                        if let Some(p) = pick(&model, arg, |m| {
                            matches!(m, Model::Cached)
                        }) {
                            sharded.evict_cached(p);
                            mono.evict_cached(p);
                            model[p] = Model::Free;
                        }
                    }
                    _ => {
                        // Preference must be a no-op with one shard.
                        let a = sharded.alloc_prefer(Some(0));
                        let b = mono.alloc();
                        if a != b {
                            return Err(format!(
                                "alloc_prefer diverged: {a:?} vs {b:?}"
                            ));
                        }
                        if let Some(p) = a {
                            model[p] = Model::Live(1);
                        }
                    }
                }
                // Full-state bisimulation check after every op.
                for p in 0..PAGES {
                    if sharded.state(p) != mono.state(p) {
                        return Err(format!(
                            "page {p}: state {:?} vs {:?}",
                            sharded.state(p),
                            mono.state(p)
                        ));
                    }
                    if sharded.refs(p) != mono.refs(p) {
                        return Err(format!(
                            "page {p}: refs {} vs {}",
                            sharded.refs(p),
                            mono.refs(p)
                        ));
                    }
                }
                if sharded.free_count() != mono.free_count()
                    || sharded.cached_count() != mono.cached_count()
                    || sharded.live_count() != mono.live_count()
                {
                    return Err("counters diverged".into());
                }
                sharded
                    .check_conservation()
                    .map_err(|e| format!("sharded: {e}"))?;
                mono.check_conservation()
                    .map_err(|e| format!("mono: {e}"))?;
            }
            Ok(())
        },
    );
}

/// Random admit/advance/extend/rewind/release/preempt interleavings
/// over pools split 1–4 ways: per-shard arenas are never exceeded
/// (conservation holds inside every arena), refcounts balance across
/// alloc/free/COW, and no block table ever references a non-Live
/// `(device, page)`.
#[test]
fn prop_sharded_pool_invariants_under_interleavings() {
    prop_check(
        120,
        0xd1ce,
        |r: &mut Rng| {
            let shards = r.usize(1, 5);
            let n = r.usize(1, 80);
            let ops: Vec<usize> =
                (0..n).map(|_| r.usize(0, 4000)).collect();
            (ops, shards)
        },
        |(ops, shards)| {
            let shards = (*shards).clamp(1, 4);
            let mut pool = KvPool::with_shards(24, 4, 64, shards);
            let mut next_id = 0u64;
            let mut live: Vec<u64> = Vec::new();
            // Shared stems exercise cross-shard prefix sharing; stem 2
            // is a strict prefix of stem 0.
            let stems: [Vec<i32>; 3] = [
                (0..12).collect(),
                (100..112).collect(),
                (0..8).collect(),
            ];
            let check = |pool: &KvPool| -> Result<(), String> {
                pool.check_invariants()?;
                // Per-shard budgets: every arena accounts for exactly
                // its own pages (live + cached + free == arena size).
                let views = pool.shard_views();
                if views.len() != shards {
                    return Err(format!(
                        "{} shard views for {shards} shards",
                        views.len()
                    ));
                }
                let total: usize =
                    views.iter().map(|v| v.total_pages).sum();
                if total != pool.total_pages() {
                    return Err("arenas do not tile the budget".into());
                }
                for v in &views {
                    if v.free_pages + v.live_pages + v.cached_pages
                        != v.total_pages
                    {
                        return Err(format!(
                            "shard {} over/under budget: {v:?}",
                            v.shard
                        ));
                    }
                }
                Ok(())
            };
            for &x in ops {
                let op = x % 10;
                let p = x / 10;
                match op {
                    0..=2 => {
                        next_id += 1;
                        let mut toks = stems[p % 3].clone();
                        toks.extend((0..p % 5).map(|j| {
                            1000 + next_id as i32 + j as i32
                        }));
                        if pool.alloc(next_id, &toks).is_ok() {
                            live.push(next_id);
                        }
                    }
                    3 | 4 => {
                        if !live.is_empty() {
                            let id = live[p % live.len()];
                            let _ = pool.advance(id, (p % 50) as i32);
                        }
                    }
                    5 => {
                        // Chunked extend: success or a structured
                        // error that rewinds — never a panic.
                        if !live.is_empty() {
                            let id = live[p % live.len()];
                            let before = pool.pos(id).unwrap();
                            let chunk: Vec<i32> =
                                (0..1 + p % 9).map(|j| j as i32).collect();
                            match pool.extend(id, &chunk) {
                                Ok(pos) => {
                                    if pos != before + chunk.len() {
                                        return Err(format!(
                                            "extend pos {pos} != {}",
                                            before + chunk.len()
                                        ));
                                    }
                                }
                                Err(KvError::CapacityExhausted {
                                    ..
                                })
                                | Err(KvError::MaxSeq { .. }) => {
                                    let after = pool.pos(id).unwrap();
                                    if after != before {
                                        return Err(format!(
                                            "failed extend moved pos \
                                             {before} -> {after}"
                                        ));
                                    }
                                }
                                Err(e) => {
                                    return Err(format!(
                                        "unstructured extend error: {e}"
                                    ))
                                }
                            }
                        }
                    }
                    6 => {
                        if !live.is_empty() {
                            let id = live[p % live.len()];
                            let pos = pool.pos(id).unwrap();
                            let _ = pool
                                .rewind_to(id, pos.saturating_sub(p % 3));
                        }
                    }
                    7 => {
                        if !live.is_empty() {
                            let id = live.remove(p % live.len());
                            pool.release(id).map_err(|e| e.to_string())?;
                        }
                    }
                    8 => {
                        let mode = if p % 2 == 0 {
                            PreemptMode::Recompute
                        } else {
                            PreemptMode::SwapOut
                        };
                        if let Some(pre) = pool.preempt(mode) {
                            live.retain(|&r| r != pre.request);
                        }
                    }
                    _ => {
                        // Shard-targeted preemption at random shards.
                        if let Some(pre) = pool.preempt_on_shard(
                            PreemptMode::Recompute,
                            p % shards,
                        ) {
                            live.retain(|&r| r != pre.request);
                        }
                    }
                }
                check(&pool)?;
                // No table may reference a freed (device, page).
                for &id in &live {
                    let Some(t) = pool.table(id) else {
                        return Err(format!("live id {id} lost its table"));
                    };
                    for &pg in t.pages() {
                        if pool.page_state(pg) != PageState::Live {
                            return Err(format!(
                                "request {id} references {:?} page {pg} \
                                 on shard {}",
                                pool.page_state(pg),
                                pool.shard_of(pg)
                            ));
                        }
                    }
                }
            }
            for id in live.drain(..) {
                pool.release(id).map_err(|e| e.to_string())?;
            }
            check(&pool)?;
            if pool.live_pages() != 0 {
                return Err(format!(
                    "live pages after drain: {}",
                    pool.live_pages()
                ));
            }
            Ok(())
        },
    );
}

/// Chunked-prefill page claims on brutally small sharded pools: an
/// extend the budget cannot cover surfaces `CapacityExhausted` (or the
/// sequence cap), rewinds cleanly, and the pool keeps serving smaller
/// work afterwards — the requeue contract of the serving loop.
#[test]
fn prop_extend_exhaustion_is_structured_and_recoverable() {
    prop_check(
        150,
        0xfeed5,
        |r: &mut Rng| {
            let pages = r.usize(2, 7);
            let shards = r.usize(1, 4);
            let chunk = r.usize(1, 40);
            (vec![pages, shards], chunk)
        },
        |(dims, chunk)| {
            if dims.len() < 2 || *chunk == 0 {
                return Ok(()); // shrink artifacts
            }
            let (pages, shards) = (dims[0].max(2), dims[1].max(1));
            let mut pool = KvPool::with_shards(pages, 4, 64, shards);
            pool.alloc(1, &[1, 2, 3]).unwrap(); // 1 page
            let chunk_toks: Vec<i32> =
                (0..*chunk as i32).map(|j| 10 + j).collect();
            let before = pool.pos(1).unwrap();
            match pool.extend(1, &chunk_toks) {
                Ok(pos) => {
                    if pos != before + chunk_toks.len() {
                        return Err("wrong extend position".into());
                    }
                }
                Err(KvError::CapacityExhausted { needed, available }) => {
                    if needed == 0 {
                        return Err("exhaustion with zero need".into());
                    }
                    // `available` is a point-in-time report; the
                    // position contract is the hard part:
                    let _ = available;
                    if pool.pos(1).unwrap() != before {
                        return Err("failed extend moved the position"
                            .into());
                    }
                }
                Err(KvError::MaxSeq { .. }) => {}
                Err(e) => {
                    return Err(format!("unstructured error: {e}"));
                }
            }
            pool.check_invariants()?;
            // The pool still serves work sized to what is left (the
            // requeue path re-admits exactly like this).
            pool.release(1).map_err(|e| e.to_string())?;
            pool.check_invariants()?;
            let mut small = KvPool::with_shards(pages, 4, 64, shards);
            small.alloc(2, &[9, 9, 9]).map_err(|e| e.to_string())?;
            Ok(())
        },
    );
}
