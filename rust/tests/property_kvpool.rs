//! Property suite for the sharded KV page pool (mini-proptest,
//! `PROPTEST_CASES=512` in CI):
//!
//! * one-shard [`ShardedBlockPool`] bisimulates the monolithic
//!   [`BlockPool`] op for op — the `--shards 1` bit-identity the
//!   acceptance criterion demands,
//! * random admit/extend/advance/release/preempt interleavings over a
//!   sharded [`KvPool`] never exceed a shard's arena, never leak or
//!   double-free pages, keep every refcount equal to its table
//!   references, and never leave a table pointing at a freed
//!   `(device, page)`,
//! * chunked-prefill exhaustion (`KvPool::extend`) is a structured
//!   error that rewinds the position — requeueable, never a panic,
//! * a zero-cost fabric with disaggregation off is bit-identical to
//!   running without a fabric at all (outputs, routing order,
//!   `PoolStats` counters, sim clock) — the priced-fabric lever is
//!   purely additive,
//! * host swap buffers conserve bytes: everything reserved by a
//!   swap-out is released by resume, discard, end-of-run drain, or a
//!   replica crash (`KillSpec`) — no leaked buffers.

use mmserve::kvpool::replay::{replay, ReplayConfig};
use mmserve::kvpool::{BlockPool, KvError, KvPool, PageState,
                      PreemptMode, ShardedBlockPool};
use mmserve::perfmodel::fabric::FabricSpec;
use mmserve::routing::replay::{routing_replay, KillSpec,
                               RoutingReplayConfig};
use mmserve::routing::RoutingPolicy;
use mmserve::substrate::prop::prop_check;
use mmserve::substrate::rng::Rng;

/// Reference model of one page's lifecycle for the bisimulation walk.
#[derive(Clone, Copy, PartialEq, Debug)]
enum Model {
    Free,
    Live(usize),
    Cached,
}

/// Drive the same operation stream through a one-shard
/// `ShardedBlockPool` and a monolithic `BlockPool`; every return value
/// and every page's (state, refs) must match at every step.
#[test]
fn prop_single_shard_bisimulates_monolithic_blockpool() {
    const PAGES: usize = 6;
    prop_check(
        150,
        0x5a4d,
        |r: &mut Rng| {
            let n = r.usize(1, 120);
            (0..n).map(|_| r.usize(0, 10_000)).collect::<Vec<usize>>()
        },
        |ops| {
            let mut sharded = ShardedBlockPool::new(PAGES, 4, 1);
            let mut mono = BlockPool::new(PAGES, 4);
            let mut model = [Model::Free; PAGES];
            let pick = |model: &[Model; PAGES], x: usize,
                        want: fn(&Model) -> bool| {
                let hits: Vec<usize> = (0..PAGES)
                    .filter(|&p| want(&model[p]))
                    .collect();
                if hits.is_empty() {
                    None
                } else {
                    Some(hits[x % hits.len()])
                }
            };
            for &x in ops {
                let op = x % 6;
                let arg = x / 6;
                match op {
                    0 => {
                        let a = sharded.alloc();
                        let b = mono.alloc();
                        if a != b {
                            return Err(format!(
                                "alloc diverged: {a:?} vs {b:?}"
                            ));
                        }
                        if let Some(p) = a {
                            model[p] = Model::Live(1);
                        }
                    }
                    1 => {
                        if let Some(p) = pick(&model, arg, |m| {
                            matches!(m, Model::Live(r) if *r > 0)
                        }) {
                            sharded.retain(p);
                            mono.retain(p);
                            let Model::Live(r) = model[p] else {
                                unreachable!()
                            };
                            model[p] = Model::Live(r + 1);
                        }
                    }
                    2 => {
                        if let Some(p) = pick(&model, arg, |m| {
                            matches!(m, Model::Live(r) if *r > 0)
                        }) {
                            let a = sharded.release(p);
                            let b = mono.release(p);
                            if a != b {
                                return Err(format!(
                                    "release diverged: {a} vs {b}"
                                ));
                            }
                            if a == 0 {
                                // Settle the zero-ref page both ways.
                                if arg % 2 == 0 {
                                    sharded.free_page(p);
                                    mono.free_page(p);
                                    model[p] = Model::Free;
                                } else {
                                    sharded.park_cached(p);
                                    mono.park_cached(p);
                                    model[p] = Model::Cached;
                                }
                            } else {
                                model[p] = Model::Live(a);
                            }
                        }
                    }
                    3 => {
                        if let Some(p) = pick(&model, arg, |m| {
                            matches!(m, Model::Cached)
                        }) {
                            sharded.unpark(p);
                            mono.unpark(p);
                            model[p] = Model::Live(1);
                        }
                    }
                    4 => {
                        if let Some(p) = pick(&model, arg, |m| {
                            matches!(m, Model::Cached)
                        }) {
                            sharded.evict_cached(p);
                            mono.evict_cached(p);
                            model[p] = Model::Free;
                        }
                    }
                    _ => {
                        // Preference must be a no-op with one shard.
                        let a = sharded.alloc_prefer(Some(0));
                        let b = mono.alloc();
                        if a != b {
                            return Err(format!(
                                "alloc_prefer diverged: {a:?} vs {b:?}"
                            ));
                        }
                        if let Some(p) = a {
                            model[p] = Model::Live(1);
                        }
                    }
                }
                // Full-state bisimulation check after every op.
                for p in 0..PAGES {
                    if sharded.state(p) != mono.state(p) {
                        return Err(format!(
                            "page {p}: state {:?} vs {:?}",
                            sharded.state(p),
                            mono.state(p)
                        ));
                    }
                    if sharded.refs(p) != mono.refs(p) {
                        return Err(format!(
                            "page {p}: refs {} vs {}",
                            sharded.refs(p),
                            mono.refs(p)
                        ));
                    }
                }
                if sharded.free_count() != mono.free_count()
                    || sharded.cached_count() != mono.cached_count()
                    || sharded.live_count() != mono.live_count()
                {
                    return Err("counters diverged".into());
                }
                sharded
                    .check_conservation()
                    .map_err(|e| format!("sharded: {e}"))?;
                mono.check_conservation()
                    .map_err(|e| format!("mono: {e}"))?;
            }
            Ok(())
        },
    );
}

/// Random admit/advance/extend/rewind/release/preempt interleavings
/// over pools split 1–4 ways: per-shard arenas are never exceeded
/// (conservation holds inside every arena), refcounts balance across
/// alloc/free/COW, and no block table ever references a non-Live
/// `(device, page)`.
#[test]
fn prop_sharded_pool_invariants_under_interleavings() {
    prop_check(
        120,
        0xd1ce,
        |r: &mut Rng| {
            let shards = r.usize(1, 5);
            let n = r.usize(1, 80);
            let ops: Vec<usize> =
                (0..n).map(|_| r.usize(0, 4000)).collect();
            (ops, shards)
        },
        |(ops, shards)| {
            let shards = (*shards).clamp(1, 4);
            let mut pool = KvPool::with_shards(24, 4, 64, shards);
            let mut next_id = 0u64;
            let mut live: Vec<u64> = Vec::new();
            // Shared stems exercise cross-shard prefix sharing; stem 2
            // is a strict prefix of stem 0.
            let stems: [Vec<i32>; 3] = [
                (0..12).collect(),
                (100..112).collect(),
                (0..8).collect(),
            ];
            let check = |pool: &KvPool| -> Result<(), String> {
                pool.check_invariants()?;
                // Per-shard budgets: every arena accounts for exactly
                // its own pages (live + cached + free == arena size).
                let views = pool.shard_views();
                if views.len() != shards {
                    return Err(format!(
                        "{} shard views for {shards} shards",
                        views.len()
                    ));
                }
                let total: usize =
                    views.iter().map(|v| v.total_pages).sum();
                if total != pool.total_pages() {
                    return Err("arenas do not tile the budget".into());
                }
                for v in &views {
                    if v.free_pages + v.live_pages + v.cached_pages
                        != v.total_pages
                    {
                        return Err(format!(
                            "shard {} over/under budget: {v:?}",
                            v.shard
                        ));
                    }
                }
                Ok(())
            };
            for &x in ops {
                let op = x % 10;
                let p = x / 10;
                match op {
                    0..=2 => {
                        next_id += 1;
                        let mut toks = stems[p % 3].clone();
                        toks.extend((0..p % 5).map(|j| {
                            1000 + next_id as i32 + j as i32
                        }));
                        if pool.alloc(next_id, &toks).is_ok() {
                            live.push(next_id);
                        }
                    }
                    3 | 4 => {
                        if !live.is_empty() {
                            let id = live[p % live.len()];
                            let _ = pool.advance(id, (p % 50) as i32);
                        }
                    }
                    5 => {
                        // Chunked extend: success or a structured
                        // error that rewinds — never a panic.
                        if !live.is_empty() {
                            let id = live[p % live.len()];
                            let before = pool.pos(id).unwrap();
                            let chunk: Vec<i32> =
                                (0..1 + p % 9).map(|j| j as i32).collect();
                            match pool.extend(id, &chunk) {
                                Ok(pos) => {
                                    if pos != before + chunk.len() {
                                        return Err(format!(
                                            "extend pos {pos} != {}",
                                            before + chunk.len()
                                        ));
                                    }
                                }
                                Err(KvError::CapacityExhausted {
                                    ..
                                })
                                | Err(KvError::MaxSeq { .. }) => {
                                    let after = pool.pos(id).unwrap();
                                    if after != before {
                                        return Err(format!(
                                            "failed extend moved pos \
                                             {before} -> {after}"
                                        ));
                                    }
                                }
                                Err(e) => {
                                    return Err(format!(
                                        "unstructured extend error: {e}"
                                    ))
                                }
                            }
                        }
                    }
                    6 => {
                        if !live.is_empty() {
                            let id = live[p % live.len()];
                            let pos = pool.pos(id).unwrap();
                            let _ = pool
                                .rewind_to(id, pos.saturating_sub(p % 3));
                        }
                    }
                    7 => {
                        if !live.is_empty() {
                            let id = live.remove(p % live.len());
                            pool.release(id).map_err(|e| e.to_string())?;
                        }
                    }
                    8 => {
                        let mode = if p % 2 == 0 {
                            PreemptMode::Recompute
                        } else {
                            PreemptMode::SwapOut
                        };
                        if let Some(pre) = pool.preempt(mode) {
                            live.retain(|&r| r != pre.request);
                        }
                    }
                    _ => {
                        // Shard-targeted preemption at random shards.
                        if let Some(pre) = pool.preempt_on_shard(
                            PreemptMode::Recompute,
                            p % shards,
                        ) {
                            live.retain(|&r| r != pre.request);
                        }
                    }
                }
                check(&pool)?;
                // No table may reference a freed (device, page).
                for &id in &live {
                    let Some(t) = pool.table(id) else {
                        return Err(format!("live id {id} lost its table"));
                    };
                    for &pg in t.pages() {
                        if pool.page_state(pg) != PageState::Live {
                            return Err(format!(
                                "request {id} references {:?} page {pg} \
                                 on shard {}",
                                pool.page_state(pg),
                                pool.shard_of(pg)
                            ));
                        }
                    }
                }
            }
            for id in live.drain(..) {
                pool.release(id).map_err(|e| e.to_string())?;
            }
            check(&pool)?;
            if pool.live_pages() != 0 {
                return Err(format!(
                    "live pages after drain: {}",
                    pool.live_pages()
                ));
            }
            Ok(())
        },
    );
}

/// Chunked-prefill page claims on brutally small sharded pools: an
/// extend the budget cannot cover surfaces `CapacityExhausted` (or the
/// sequence cap), rewinds cleanly, and the pool keeps serving smaller
/// work afterwards — the requeue contract of the serving loop.
#[test]
fn prop_extend_exhaustion_is_structured_and_recoverable() {
    prop_check(
        150,
        0xfeed5,
        |r: &mut Rng| {
            let pages = r.usize(2, 7);
            let shards = r.usize(1, 4);
            let chunk = r.usize(1, 40);
            (vec![pages, shards], chunk)
        },
        |(dims, chunk)| {
            if dims.len() < 2 || *chunk == 0 {
                return Ok(()); // shrink artifacts
            }
            let (pages, shards) = (dims[0].max(2), dims[1].max(1));
            let mut pool = KvPool::with_shards(pages, 4, 64, shards);
            pool.alloc(1, &[1, 2, 3]).unwrap(); // 1 page
            let chunk_toks: Vec<i32> =
                (0..*chunk as i32).map(|j| 10 + j).collect();
            let before = pool.pos(1).unwrap();
            match pool.extend(1, &chunk_toks) {
                Ok(pos) => {
                    if pos != before + chunk_toks.len() {
                        return Err("wrong extend position".into());
                    }
                }
                Err(KvError::CapacityExhausted { needed, available }) => {
                    if needed == 0 {
                        return Err("exhaustion with zero need".into());
                    }
                    // `available` is a point-in-time report; the
                    // position contract is the hard part:
                    let _ = available;
                    if pool.pos(1).unwrap() != before {
                        return Err("failed extend moved the position"
                            .into());
                    }
                }
                Err(KvError::MaxSeq { .. }) => {}
                Err(e) => {
                    return Err(format!("unstructured error: {e}"));
                }
            }
            pool.check_invariants()?;
            // The pool still serves work sized to what is left (the
            // requeue path re-admits exactly like this).
            pool.release(1).map_err(|e| e.to_string())?;
            pool.check_invariants()?;
            let mut small = KvPool::with_shards(pages, 4, 64, shards);
            small.alloc(2, &[9, 9, 9]).map_err(|e| e.to_string())?;
            Ok(())
        },
    );
}

/// Bisimulation guard for the priced-fabric lever: a zero-cost fabric
/// with `disaggregate` off must be bit-identical to today's behavior —
/// same token outputs, same routing order, same `PoolStats` counters,
/// same simulated clock — on both the single-worker and the fleet
/// replay, across random workload/pool/shard/replica shapes.
#[test]
fn prop_zero_cost_fabric_and_disaggregate_off_bisimulate_legacy() {
    prop_check(
        64,
        0xfab0,
        |r: &mut Rng| {
            vec![
                r.usize(4, 33),     // requests
                r.usize(16, 65),    // page budget
                r.usize(2, 13),     // batch slots
                r.usize(0, 3),      // page-size selector
                r.usize(0, 2),      // chunked admission?
                r.usize(0, 2),      // shards selector
                r.usize(1, 4),      // replicas
                r.usize(0, 10_000), // workload seed
            ]
        },
        |knobs| {
            if knobs.len() < 8 {
                return Ok(()); // shrink artifacts
            }
            let base = ReplayConfig {
                requests: knobs[0].clamp(1, 32),
                total_pages: knobs[1].clamp(8, 64),
                batch_slots: knobs[2].clamp(1, 12),
                page_size: [4, 8, 16][knobs[3] % 3],
                chunk_prefill: if knobs[4] % 2 == 1 { 8 } else { 0 },
                shards: (knobs[5] % 2) + 1,
                seed: knobs[7] as u64,
                ..ReplayConfig::default()
            };
            let zeroed = ReplayConfig {
                fabric: Some(FabricSpec::zero_cost()),
                ..base.clone()
            };
            let legacy = replay(&base, true);
            let zero = replay(&zeroed, true);
            if zero.outputs != legacy.outputs {
                return Err("single-worker outputs diverged".into());
            }
            if zero.sim_time != legacy.sim_time {
                return Err(format!(
                    "sim clock diverged: {} vs {}",
                    zero.sim_time, legacy.sim_time
                ));
            }
            if zero.stats != legacy.stats {
                return Err(format!(
                    "PoolStats diverged:\n  zero:   {:?}\n  legacy: {:?}",
                    zero.stats, legacy.stats
                ));
            }
            if zero.transfer_bytes != 0 || zero.transfer_time != 0.0 {
                return Err(format!(
                    "zero-cost fabric moved priced bytes: {} / {}",
                    zero.transfer_bytes, zero.transfer_time
                ));
            }
            // Fleet plane: same guard over replicas + routing.
            let replicas = knobs[6].clamp(1, 3);
            let fleet_legacy = routing_replay(
                &RoutingReplayConfig {
                    base: base.clone(),
                    replicas,
                    ..RoutingReplayConfig::default()
                },
                RoutingPolicy::PrefixAffinity,
            );
            let fleet_zero = routing_replay(
                &RoutingReplayConfig {
                    base: zeroed,
                    replicas,
                    ..RoutingReplayConfig::default()
                },
                RoutingPolicy::PrefixAffinity,
            );
            if fleet_zero.outputs != fleet_legacy.outputs {
                return Err("fleet outputs diverged".into());
            }
            if fleet_zero.routed != fleet_legacy.routed {
                return Err(format!(
                    "routing order diverged: {:?} vs {:?}",
                    fleet_zero.routed, fleet_legacy.routed
                ));
            }
            if fleet_zero.sim_time != fleet_legacy.sim_time {
                return Err("fleet sim clock diverged".into());
            }
            if fleet_zero.fleet != fleet_legacy.fleet {
                return Err(format!(
                    "fleet PoolStats diverged:\n  zero:   {:?}\n  \
                     legacy: {:?}",
                    fleet_zero.fleet, fleet_legacy.fleet
                ));
            }
            Ok(())
        },
    );
}

/// Host-buffer conservation: with a paper-priced fabric forcing real
/// swap decisions, every byte reserved in the host swap pool is
/// released again — by swap-in resume, discard, the end-of-run drain,
/// or a mid-run replica crash (`KillSpec`) that kills a worker while
/// it holds swapped requests.
#[test]
fn prop_host_buffer_bytes_conserve_across_swap_and_failover() {
    prop_check(
        48,
        0xb0f5,
        |r: &mut Rng| {
            vec![
                r.usize(8, 25),     // requests
                r.usize(24, 49),    // page budget (tight: forces preempt)
                r.usize(6, 13),     // batch slots
                r.usize(0, 10_000), // workload seed
                r.usize(2, 4),      // replicas
                r.usize(0, 2),      // crash a replica?
                r.usize(1, 12),     // kill placement
            ]
        },
        |knobs| {
            if knobs.len() < 7 {
                return Ok(()); // shrink artifacts
            }
            let base = ReplayConfig {
                requests: knobs[0].clamp(4, 24),
                total_pages: knobs[1].clamp(16, 48),
                batch_slots: knobs[2].clamp(4, 12),
                long_percent: 50,
                seed: knobs[3] as u64,
                fabric: Some(FabricSpec::paper(524_288.0)),
                ..ReplayConfig::default()
            };
            let one = replay(&base, true);
            if one.stats.host_bytes_reserved
                != one.stats.host_bytes_released
            {
                return Err(format!(
                    "single-worker leak: reserved {} != released {} \
                     ({} swap / {} recompute decisions)",
                    one.stats.host_bytes_reserved,
                    one.stats.host_bytes_released,
                    one.stats.swap_decisions,
                    one.stats.recompute_decisions
                ));
            }
            let replicas = knobs[4].clamp(2, 3);
            let kill = (knobs[5] % 2 == 1).then(|| KillSpec {
                replica: knobs[6] % replicas,
                after_delivered: 1 + knobs[6] % base.requests,
            });
            let fleet = routing_replay(
                &RoutingReplayConfig {
                    base,
                    replicas,
                    kill,
                    ..RoutingReplayConfig::default()
                },
                RoutingPolicy::LeastLoaded,
            );
            if fleet.fleet.host_bytes_reserved
                != fleet.fleet.host_bytes_released
            {
                return Err(format!(
                    "fleet leak (kill {kill:?}): reserved {} != \
                     released {}",
                    fleet.fleet.host_bytes_reserved,
                    fleet.fleet.host_bytes_released
                ));
            }
            Ok(())
        },
    );
}
