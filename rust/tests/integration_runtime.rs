//! Integration: the Rust PJRT runtime executes the AOT artifacts and
//! reproduces the numbers the Python/JAX side computed at build time
//! (goldens.bin), proving the L1/L2/L3 layers compose.
//!
//! Requires `make artifacts` (skips cleanly otherwise).

use mmserve::runtime::engine::{Arg, Engine};
use mmserve::runtime::tensor::{DType, Tensor};
use mmserve::runtime::weights::WeightsFile;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = mmserve::artifacts_dir();
    if dir.join("llama").join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("artifacts not built — skipping");
        None
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[test]
fn llama_prefill_matches_python_golden() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir.join("llama")).unwrap();
    let goldens = WeightsFile::load(&dir.join("llama/goldens.bin")).unwrap();
    let toks = goldens.get("prefill_b32.in.tokens").unwrap();
    let plen = goldens.get("prefill_b32.in.prompt_len").unwrap();
    let want = goldens.get("prefill_b32.out.logits").unwrap();
    let outs = engine.run_host("prefill_b32", &[toks, plen]).unwrap();
    let got = outs[0].as_f32().unwrap();
    let diff = max_abs_diff(&got, &want.as_f32().unwrap());
    assert!(diff < 2e-4, "prefill logits diverge: {diff}");
}

#[test]
fn llama_decode_matches_python_golden() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir.join("llama")).unwrap();
    let goldens = WeightsFile::load(&dir.join("llama/goldens.bin")).unwrap();
    // golden decode ran on the KV from the golden prefill
    let toks = goldens.get("prefill_b32.in.tokens").unwrap();
    let plen = goldens.get("prefill_b32.in.prompt_len").unwrap();
    let pre = engine.stage("prefill_b32").unwrap();
    let outs = engine
        .run(&pre, &[Arg::Host(toks), Arg::Host(plen)])
        .unwrap();
    let (ck, cv) = (&outs[1], &outs[2]);
    let dt = goldens.get("decode_b1.in.tokens").unwrap();
    let dp = goldens.get("decode_b1.in.positions").unwrap();
    let want = goldens.get("decode_b1.out.logits").unwrap();
    let dec = engine.stage("decode_b1").unwrap();
    let outs = engine
        .run(&dec, &[Arg::Host(dt), Arg::Host(dp), Arg::Dev(ck),
                     Arg::Dev(cv)])
        .unwrap();
    let got = engine.download(&outs[0]).unwrap().as_f32().unwrap();
    let diff = max_abs_diff(&got, &want.as_f32().unwrap());
    assert!(diff < 2e-4, "decode logits diverge: {diff}");
}

#[test]
fn seamless_encoder_matches_python_golden() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir.join("seamless")).unwrap();
    let g = WeightsFile::load(&dir.join("seamless/goldens.bin")).unwrap();
    let feats = g.get("encoder_t64.in.feats").unwrap();
    let flen = g.get("encoder_t64.in.feat_len").unwrap();
    let want = g.get("encoder_t64.out.enc").unwrap();
    let outs = engine.run_host("encoder_t64", &[feats, flen]).unwrap();
    let got = outs[0].as_f32().unwrap();
    let diff = max_abs_diff(&got, &want.as_f32().unwrap());
    assert!(diff < 5e-4, "encoder output diverges: {diff}");
    assert_eq!(outs[1].as_i32().unwrap(),
               g.get("encoder_t64.out.len").unwrap().as_i32().unwrap());
}

#[test]
fn hstu_forward_matches_python_golden() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir.join("hstu")).unwrap();
    let g = WeightsFile::load(&dir.join("hstu/goldens.bin")).unwrap();
    let ids = g.get("forward_s256_b1.in.item_ids").unwrap();
    let sl = g.get("forward_s256_b1.in.seq_len").unwrap();
    let outs = engine.run_host("forward_s256_b1", &[ids, sl]).unwrap();
    let rank_want = g.get("forward_s256_b1.out.rank").unwrap().as_f32()
        .unwrap();
    let retr_want = g.get("forward_s256_b1.out.retrieval").unwrap()
        .as_f32().unwrap();
    assert!(max_abs_diff(&outs[0].as_f32().unwrap(), &rank_want) < 5e-4);
    assert!(max_abs_diff(&outs[1].as_f32().unwrap(), &retr_want) < 5e-3);
}

#[test]
fn hstu_fused_kernel_stage_matches_naive_stage() {
    // The Pallas fused kernel, AOT-lowered and run from Rust, agrees
    // with the naive stage — the §4.1.1 "same principle, fused kernel"
    // claim at the artifact level.
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir.join("hstu")).unwrap();
    let g = WeightsFile::load(&dir.join("hstu/goldens.bin")).unwrap();
    let ids = g.get("forward_s256_b1.in.item_ids").unwrap();
    let sl = g.get("forward_s256_b1.in.seq_len").unwrap();
    let naive = engine.run_host("forward_s256_b1", &[ids, sl]).unwrap();
    let fused =
        engine.run_host("forward_s256_b1_fused", &[ids, sl]).unwrap();
    let d = max_abs_diff(&naive[0].as_f32().unwrap(),
                         &fused[0].as_f32().unwrap());
    assert!(d < 2e-3, "fused vs naive rank logits: {d}");
}

#[test]
fn decode_chain_stays_on_device() {
    // KV buffers chain across steps without host round-trips; positions
    // advance and logits change step to step.
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir.join("llama")).unwrap();
    let dims =
        mmserve::coordinator::decoder_loop::DecoderDims::from_engine(&engine)
            .unwrap();
    let zero = Tensor::zeros(DType::F32, &dims.kv_shape(1));
    let mut ck = engine.upload(&zero).unwrap();
    let mut cv = engine.upload(&zero).unwrap();
    let dec = engine.stage("decode_b1").unwrap();
    let mut last: Option<Vec<f32>> = None;
    for pos in 0..8 {
        let t = Tensor::from_i32(&[1], &[(pos % 7 + 2) as i32]);
        let p = Tensor::from_i32(&[1], &[pos as i32]);
        let outs = engine
            .run(&dec, &[Arg::Host(&t), Arg::Host(&p), Arg::Dev(&ck),
                         Arg::Dev(&cv)])
            .unwrap();
        let mut it = outs.into_iter();
        let logits = engine.download(&it.next().unwrap()).unwrap()
            .as_f32().unwrap();
        ck = it.next().unwrap();
        cv = it.next().unwrap();
        if let Some(prev) = &last {
            assert!(max_abs_diff(prev, &logits) > 1e-6,
                    "logits must evolve with context");
        }
        last = Some(logits);
    }
}

#[test]
fn chameleon_manifest_loads_and_serves_decode() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir.join("chameleon")).unwrap();
    assert_eq!(engine.model(), "chameleon");
    let dims =
        mmserve::coordinator::decoder_loop::DecoderDims::from_engine(&engine)
            .unwrap();
    let zero = Tensor::zeros(DType::F32, &dims.kv_shape(1));
    let ck = engine.upload(&zero).unwrap();
    let cv = engine.upload(&zero).unwrap();
    let dec = engine.stage("decode_b1").unwrap();
    let t = Tensor::from_i32(&[1], &[5]);
    let p = Tensor::from_i32(&[1], &[0]);
    let outs = engine
        .run(&dec, &[Arg::Host(&t), Arg::Host(&p), Arg::Dev(&ck),
                     Arg::Dev(&cv)])
        .unwrap();
    let logits = engine.download(&outs[0]).unwrap();
    assert_eq!(logits.shape, vec![1, dims.vocab]);
}
