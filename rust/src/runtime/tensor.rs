//! Host tensor type at the runtime boundary.
//!
//! Deliberately minimal: shape + dtype + contiguous little-endian bytes.
//! Conversions to/from `xla::Literal` live in `engine`.

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I8,
    I32,
}

impl DType {
    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 => 1,
        }
    }
    pub fn from_code(code: u8) -> Result<Self> {
        Ok(match code {
            0 => DType::F32,
            1 => DType::I8,
            2 => DType::I32,
            c => bail!("unknown dtype code {c}"),
        })
    }
    pub fn from_name(name: &str) -> Result<Self> {
        Ok(match name {
            "f32" => DType::F32,
            "i8" => DType::I8,
            "i32" => DType::I32,
            n => bail!("unknown dtype name {n:?}"),
        })
    }
}

/// A host-resident dense tensor.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn new(dtype: DType, shape: Vec<usize>, data: Vec<u8>) -> Result<Self> {
        let want = shape.iter().product::<usize>() * dtype.size();
        if data.len() != want {
            bail!(
                "tensor data {} bytes but shape {:?} x {:?} needs {}",
                data.len(),
                shape,
                dtype,
                want
            );
        }
        Ok(Tensor { dtype, shape, data })
    }

    pub fn from_f32(shape: &[usize], vals: &[f32]) -> Self {
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor::new(DType::F32, shape.to_vec(), data).expect("shape/f32")
    }

    pub fn from_i32(shape: &[usize], vals: &[i32]) -> Self {
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor::new(DType::I32, shape.to_vec(), data).expect("shape/i32")
    }

    pub fn scalar_i32(v: i32) -> Self {
        Tensor::from_i32(&[1], &[v])
    }

    pub fn zeros(dtype: DType, shape: &[usize]) -> Self {
        let n = shape.iter().product::<usize>() * dtype.size();
        Tensor { dtype, shape: to_vec(shape), data: vec![0u8; n] }
    }

    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            bail!("not f32: {:?}", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            bail!("not i32: {:?}", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

fn to_vec(s: &[usize]) -> Vec<usize> {
    s.to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let t = Tensor::from_f32(&[2, 2], &[1.0, -2.5, 3.0, 0.0]);
        assert_eq!(t.elems(), 4);
        assert_eq!(t.as_f32().unwrap(), vec![1.0, -2.5, 3.0, 0.0]);
        assert!(t.as_i32().is_err());
    }

    #[test]
    fn size_checked() {
        assert!(Tensor::new(DType::F32, vec![3], vec![0u8; 11]).is_err());
        assert!(Tensor::new(DType::I8, vec![3], vec![0u8; 3]).is_ok());
    }

    #[test]
    fn zeros() {
        let t = Tensor::zeros(DType::I32, &[4, 2]);
        assert_eq!(t.as_i32().unwrap(), vec![0; 8]);
    }
}
