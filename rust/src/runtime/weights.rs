//! Reader for the MMWB weights container (`python/compile/weights.py`).
//!
//! Format (little-endian):
//! ```text
//! magic   4B  b"MMWB"
//! version u32 (1)
//! count   u32
//! per tensor: name_len u16, name, dtype u8, ndim u8, dims u32*ndim,
//!             nbytes u64, raw data
//! ```

use std::collections::HashMap;
use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::tensor::{DType, Tensor};

pub const MAGIC: &[u8; 4] = b"MMWB";
pub const VERSION: u32 = 1;

/// Named tensors in file order.
#[derive(Debug, Default)]
pub struct WeightsFile {
    pub order: Vec<String>,
    pub tensors: HashMap<String, Tensor>,
}

impl WeightsFile {
    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        Self::parse(&buf).with_context(|| format!("parse {}", path.display()))
    }

    pub fn parse(buf: &[u8]) -> Result<Self> {
        let mut c = Cursor { b: buf, i: 0 };
        if c.bytes(4)? != MAGIC {
            bail!("bad magic");
        }
        let version = c.u32()?;
        if version != VERSION {
            bail!("unsupported version {version}");
        }
        let count = c.u32()? as usize;
        let mut out = WeightsFile::default();
        for _ in 0..count {
            let nlen = c.u16()? as usize;
            let name = String::from_utf8(c.bytes(nlen)?.to_vec())
                .context("tensor name utf8")?;
            let dtype = DType::from_code(c.u8()?)?;
            let ndim = c.u8()? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(c.u32()? as usize);
            }
            let nbytes = c.u64()? as usize;
            let data = c.bytes(nbytes)?.to_vec();
            let t = Tensor::new(dtype, shape, data)
                .with_context(|| format!("tensor {name}"))?;
            out.order.push(name.clone());
            if out.tensors.insert(name.clone(), t).is_some() {
                bail!("duplicate tensor {name}");
            }
        }
        if c.i != buf.len() {
            bail!("{} trailing bytes", buf.len() - c.i);
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("missing weight {name:?}"))
    }
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated at {}+{}", self.i, n);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        let b = self.bytes(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }
    fn u32(&mut self) -> Result<u32> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn u64(&mut self) -> Result<u64> {
        let b = self.bytes(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(entries: &[(&str, DType, &[usize], &[u8])]) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(MAGIC);
        b.extend_from_slice(&VERSION.to_le_bytes());
        b.extend_from_slice(&(entries.len() as u32).to_le_bytes());
        for (name, dt, shape, data) in entries {
            b.extend_from_slice(&(name.len() as u16).to_le_bytes());
            b.extend_from_slice(name.as_bytes());
            b.push(match dt {
                DType::F32 => 0,
                DType::I8 => 1,
                DType::I32 => 2,
            });
            b.push(shape.len() as u8);
            for d in *shape {
                b.extend_from_slice(&(*d as u32).to_le_bytes());
            }
            b.extend_from_slice(&(data.len() as u64).to_le_bytes());
            b.extend_from_slice(data);
        }
        b
    }

    #[test]
    fn parses_two_tensors() {
        let f32_data = [1f32, 2., 3., 4.]
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect::<Vec<_>>();
        let buf = mk(&[
            ("a.w", DType::F32, &[2, 2], &f32_data),
            ("b", DType::I8, &[3], &[1u8, 2, 3]),
        ]);
        let w = WeightsFile::parse(&buf).unwrap();
        assert_eq!(w.order, vec!["a.w", "b"]);
        assert_eq!(w.get("a.w").unwrap().as_f32().unwrap()[3], 4.0);
        assert_eq!(w.get("b").unwrap().shape, vec![3]);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(WeightsFile::parse(b"NOPE").is_err());
    }

    #[test]
    fn rejects_truncation_and_trailing() {
        let buf = mk(&[("x", DType::I8, &[2], &[1, 2])]);
        assert!(WeightsFile::parse(&buf[..buf.len() - 1]).is_err());
        let mut b2 = buf.clone();
        b2.push(0);
        assert!(WeightsFile::parse(&b2).is_err());
    }

    #[test]
    fn rejects_shape_data_mismatch() {
        let buf = mk(&[("x", DType::F32, &[2], &[0u8; 4])]); // needs 8
        assert!(WeightsFile::parse(&buf).is_err());
    }
}
