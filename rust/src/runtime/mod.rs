//! PJRT runtime: load AOT artifacts, compile once, execute from the
//! serving hot path with device-resident buffers.
//!
//! * [`weights`] — parser for the `weights.bin` MMWB container written by
//!   `python/compile/weights.py` (also reads `goldens.bin`).
//! * [`manifest`] — typed view of `manifest.json` (stages, arg specs).
//! * [`tensor`] — host-side tensor (shape + dtype + bytes) used at the
//!   runtime boundary.
//! * [`engine`] — the PJRT engine: `HloModuleProto::from_text_file` →
//!   `client.compile` at load, `execute_b` over device buffers per step.
//!   The patched `xla` crate returns one buffer per output-tuple leaf so
//!   KV caches chain across steps without host round-trips (the
//!   CUDA-Graph-style static-buffer discipline, paper §4.1.2).

pub mod engine;
pub mod manifest;
pub mod tensor;
pub mod weights;

pub use engine::{Engine, StageHandle};
pub use manifest::{Manifest, StageSpec};
pub use tensor::{DType, Tensor};
