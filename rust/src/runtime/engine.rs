//! The PJRT engine: compile-once, execute-many.
//!
//! One `Engine` per model directory. Weights are uploaded to the device
//! once at load; stages are compiled lazily on first use and cached.
//! Stage outputs are `PjRtBuffer`s (one per output-tuple leaf, thanks to
//! the `untuple_result` patch in `third_party_xla`), so state tensors
//! (KV caches) chain across decode steps without host round-trips —
//! the same static-buffer discipline that enables CUDA Graphs in the
//! paper (§4.1.2).
//!
//! `Engine` is deliberately `!Send`: PJRT handles are raw pointers. The
//! coordinator gives each model its own engine thread.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{ElementType, HloModuleProto, PjRtBuffer, PjRtClient,
          PjRtLoadedExecutable, XlaComputation};

use crate::telemetry::tracer::{Cat, WorkerTracer};

use super::manifest::{Manifest, StageSpec};
use super::tensor::{DType, Tensor};
use super::weights::WeightsFile;

fn elem_type(dt: DType) -> ElementType {
    match dt {
        DType::F32 => ElementType::F32,
        DType::I8 => ElementType::S8,
        DType::I32 => ElementType::S32,
    }
}

/// A stage input: host tensor (uploaded per call) or device buffer.
pub enum Arg<'a> {
    Host(&'a Tensor),
    Dev(&'a PjRtBuffer),
}

/// Compiled stage + its spec.
#[derive(Clone)]
pub struct StageHandle {
    pub spec: StageSpec,
    exe: Rc<PjRtLoadedExecutable>,
}

/// Engine statistics (compile times, per-stage dispatch counts).
#[derive(Default, Debug, Clone)]
pub struct EngineStats {
    pub compiles: usize,
    pub compile_secs: f64,
    pub dispatches: u64,
    pub dispatch_secs: f64,
}

pub struct Engine {
    pub manifest: Manifest,
    client: PjRtClient,
    weights: WeightsFile,
    weight_bufs: RefCell<HashMap<String, Rc<PjRtBuffer>>>,
    execs: RefCell<HashMap<String, StageHandle>>,
    pub stats: RefCell<EngineStats>,
    /// Telemetry recorder; `None` (the default) costs nothing on the
    /// dispatch path. Spans cover compile / upload / execute / download
    /// and inherit the worker's current request id and scheduler tick.
    tracer: Option<WorkerTracer>,
}

impl Engine {
    /// Load manifest + weights for `artifacts/<model>`; creates a PJRT
    /// CPU client.
    pub fn load(dir: &Path) -> Result<Self> {
        let client = PjRtClient::cpu()?;
        Self::load_with_client(dir, client)
    }

    /// Share one PJRT client across engines (one process-wide CPU device).
    pub fn load_with_client(dir: &Path, client: PjRtClient) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let weights = WeightsFile::load(&dir.join(&manifest.weights_file))?;
        for name in &manifest.weight_order {
            if !weights.tensors.contains_key(name) {
                bail!("weights.bin missing {name:?}");
            }
        }
        Ok(Engine {
            manifest,
            client,
            weights,
            weight_bufs: RefCell::new(HashMap::new()),
            execs: RefCell::new(HashMap::new()),
            stats: RefCell::new(EngineStats::default()),
            tracer: None,
        })
    }

    /// Attach a telemetry recorder: every subsequent compile, host
    /// transfer and PJRT execute is recorded as a span.
    pub fn set_tracer(&mut self, tracer: WorkerTracer) {
        self.tracer = Some(tracer);
    }

    /// The attached telemetry recorder, if any.
    pub fn tracer(&self) -> Option<&WorkerTracer> {
        self.tracer.as_ref()
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    pub fn model(&self) -> &str {
        &self.manifest.model
    }

    /// Host copy of a weight tensor (used by tests / eager planning).
    pub fn weight_host(&self, name: &str) -> Result<&Tensor> {
        self.weights.get(name)
    }

    /// Device buffer for a weight (uploaded once, cached).
    pub fn weight_buf(&self, name: &str) -> Result<Rc<PjRtBuffer>> {
        if let Some(b) = self.weight_bufs.borrow().get(name) {
            return Ok(b.clone());
        }
        let t = self.weights.get(name)?;
        let buf = Rc::new(self.upload(t)?);
        self.weight_bufs
            .borrow_mut()
            .insert(name.to_string(), buf.clone());
        Ok(buf)
    }

    /// Upload a host tensor to the device.
    pub fn upload(&self, t: &Tensor) -> Result<PjRtBuffer> {
        let _span = self.tracer.as_ref().map(|w| w.span(Cat::Upload,
                                                        "upload"));
        self.client
            .buffer_from_host_raw_bytes(elem_type(t.dtype), &t.data,
                                        &t.shape, None)
            .context("upload")
    }

    /// Download a device buffer to a host tensor.
    pub fn download(&self, b: &PjRtBuffer) -> Result<Tensor> {
        let _span = self.tracer.as_ref().map(|w| w.span(Cat::Download,
                                                        "download"));
        let lit = b.to_literal_sync()?;
        let shape = lit.array_shape()?;
        let dims: Vec<usize> =
            shape.dims().iter().map(|d| *d as usize).collect();
        let dt = match shape.ty() {
            ElementType::F32 => DType::F32,
            ElementType::S8 => DType::I8,
            ElementType::S32 => DType::I32,
            other => bail!("unsupported download type {other:?}"),
        };
        let mut data = vec![0u8; lit.size_bytes()];
        match dt {
            DType::F32 => {
                let v = lit.to_vec::<f32>()?;
                data.clear();
                for x in v {
                    data.extend_from_slice(&x.to_le_bytes());
                }
            }
            DType::I32 => {
                let v = lit.to_vec::<i32>()?;
                data.clear();
                for x in v {
                    data.extend_from_slice(&x.to_le_bytes());
                }
            }
            DType::I8 => {
                let v = lit.to_vec::<i8>()?;
                data = v.into_iter().map(|x| x as u8).collect();
            }
        }
        Tensor::new(dt, dims, data)
    }

    /// Compile (or fetch the cached) executable for a stage.
    pub fn stage(&self, name: &str) -> Result<StageHandle> {
        if let Some(h) = self.execs.borrow().get(name) {
            return Ok(h.clone());
        }
        let spec = self.manifest.stage(name)?.clone();
        let path = self.manifest.dir.join(&spec.file);
        let _span = self.tracer.as_ref().map(|w| w.span(Cat::Compile, name));
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("path utf8")?,
        )
        .with_context(|| format!("load {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile stage {name}"))?;
        {
            let mut st = self.stats.borrow_mut();
            st.compiles += 1;
            st.compile_secs += t0.elapsed().as_secs_f64();
        }
        let h = StageHandle { spec, exe: Rc::new(exe) };
        self.execs.borrow_mut().insert(name.to_string(), h.clone());
        Ok(h)
    }

    /// Whether a stage exists in the manifest.
    pub fn has_stage(&self, name: &str) -> bool {
        self.manifest.stages.contains_key(name)
    }

    /// Execute a stage: weights (from cache) are prepended, then `args`.
    /// Returns one `PjRtBuffer` per declared output.
    pub fn run(&self, h: &StageHandle, args: &[Arg]) -> Result<Vec<PjRtBuffer>> {
        if args.len() != h.spec.args.len() {
            bail!(
                "stage {}: {} args given, {} expected",
                h.spec.name,
                args.len(),
                h.spec.args.len()
            );
        }
        // Upload host args first (two-pass so references stay stable).
        let mut uploads: Vec<Option<PjRtBuffer>> =
            Vec::with_capacity(args.len());
        for (i, a) in args.iter().enumerate() {
            match a {
                Arg::Dev(_) => uploads.push(None),
                Arg::Host(t) => {
                    let spec = &h.spec.args[i];
                    if t.shape != spec.shape || t.dtype != spec.dtype {
                        bail!(
                            "stage {} arg {} ({}): got {:?} {:?}, want {:?} {:?}",
                            h.spec.name, i, spec.name, t.dtype, t.shape,
                            spec.dtype, spec.shape
                        );
                    }
                    uploads.push(Some(self.upload(t)?));
                }
            }
        }
        // Assemble the full input list as device-buffer references.
        let mut owned: Vec<Rc<PjRtBuffer>> = Vec::new();
        for w in &h.spec.weights {
            owned.push(self.weight_buf(w)?);
        }
        let mut ptrs: Vec<&PjRtBuffer> =
            Vec::with_capacity(owned.len() + args.len());
        for o in &owned {
            ptrs.push(o);
        }
        for (i, a) in args.iter().enumerate() {
            match a {
                Arg::Dev(b) => ptrs.push(b),
                Arg::Host(_) => ptrs.push(uploads[i].as_ref().unwrap()),
            }
        }
        let span = self.tracer.as_ref().map(|w| w.span(Cat::Execute,
                                                       &h.spec.name));
        let t0 = Instant::now();
        let mut res = h.exe.execute_b(&ptrs)?;
        let dt = t0.elapsed().as_secs_f64();
        drop(span);
        {
            let mut st = self.stats.borrow_mut();
            st.dispatches += 1;
            st.dispatch_secs += dt;
        }
        if res.is_empty() || res[0].len() != h.spec.outputs.len() {
            bail!(
                "stage {}: got {} outputs, manifest says {}",
                h.spec.name,
                res.first().map(|r| r.len()).unwrap_or(0),
                h.spec.outputs.len()
            );
        }
        Ok(res.remove(0))
    }

    /// Convenience: run with host tensors only, download all outputs.
    pub fn run_host(&self, stage: &str, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        let h = self.stage(stage)?;
        let dev_args: Vec<Arg> = args.iter().map(|t| Arg::Host(t)).collect();
        let outs = self.run(&h, &dev_args)?;
        outs.iter().map(|b| self.download(b)).collect()
    }

    /// Pre-compile a set of stages (startup warm; returns total seconds).
    pub fn warm(&self, stages: &[&str]) -> Result<f64> {
        let t0 = Instant::now();
        for s in stages {
            self.stage(s)?;
        }
        Ok(t0.elapsed().as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elem_type_mapping() {
        assert_eq!(elem_type(DType::F32), ElementType::F32);
        assert_eq!(elem_type(DType::I8), ElementType::S8);
        assert_eq!(elem_type(DType::I32), ElementType::S32);
    }
}
