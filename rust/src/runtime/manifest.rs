//! Typed view of `artifacts/<model>/manifest.json`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::substrate::json::Json;

use super::tensor::DType;

/// One runtime argument of a stage (non-weight input).
#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl ArgSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One output of a stage.
#[derive(Debug, Clone)]
pub struct OutSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// A lowered stage: HLO file + input contract.
#[derive(Debug, Clone)]
pub struct StageSpec {
    pub name: String,
    pub file: String,
    /// Weight names passed (in order) before the runtime args.
    pub weights: Vec<String>,
    pub args: Vec<ArgSpec>,
    pub outputs: Vec<OutSpec>,
    /// Free-form metadata from the emitter (kind, bucket, attn, linear…).
    pub meta: HashMap<String, String>,
}

impl StageSpec {
    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).map(|s| s.as_str())
    }
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|s| s.parse().ok())
    }
}

/// Parsed manifest for one model directory.
#[derive(Debug)]
pub struct Manifest {
    pub model: String,
    pub dir: PathBuf,
    pub weights_file: String,
    pub weight_order: Vec<String>,
    pub stages: HashMap<String, StageSpec>,
    /// Raw config object from the emitter (tiny-config dims).
    pub config: Json,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        Self::from_json(dir, &j)
    }

    pub fn from_json(dir: &Path, j: &Json) -> Result<Self> {
        let model = j
            .get("model")
            .and_then(Json::as_str)
            .context("manifest.model")?
            .to_string();
        let weights_file = j
            .get("weights_file")
            .and_then(Json::as_str)
            .context("manifest.weights_file")?
            .to_string();
        let weight_order = j
            .get("weight_order")
            .and_then(Json::as_arr)
            .context("manifest.weight_order")?
            .iter()
            .map(|v| v.as_str().map(str::to_string))
            .collect::<Option<Vec<_>>>()
            .context("weight_order strings")?;
        let mut stages = HashMap::new();
        let stage_obj = j
            .get("stages")
            .and_then(Json::obj_entries)
            .context("manifest.stages")?;
        for (name, sj) in stage_obj {
            stages.insert(name.clone(), parse_stage(name, sj)?);
        }
        let config = j.get("config").cloned().unwrap_or(Json::Null);
        Ok(Manifest {
            model,
            dir: dir.to_path_buf(),
            weights_file,
            weight_order,
            stages,
            config,
        })
    }

    pub fn stage(&self, name: &str) -> Result<&StageSpec> {
        self.stages
            .get(name)
            .with_context(|| format!("model {}: no stage {name:?}", self.model))
    }

    pub fn stage_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.stages.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// Stages whose meta.kind matches.
    pub fn stages_of_kind(&self, kind: &str) -> Vec<&StageSpec> {
        let mut v: Vec<&StageSpec> = self
            .stages
            .values()
            .filter(|s| s.meta_str("kind") == Some(kind))
            .collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    /// Config integer field (tiny-config dims).
    pub fn cfg_usize(&self, key: &str) -> Result<usize> {
        self.config
            .get(key)
            .and_then(Json::as_usize)
            .with_context(|| format!("config.{key}"))
    }
}

fn parse_stage(name: &str, j: &Json) -> Result<StageSpec> {
    let file = j
        .get("file")
        .and_then(Json::as_str)
        .with_context(|| format!("{name}.file"))?
        .to_string();
    let weights = j
        .get("weights")
        .and_then(Json::as_arr)
        .with_context(|| format!("{name}.weights"))?
        .iter()
        .map(|v| v.as_str().map(str::to_string))
        .collect::<Option<Vec<_>>>()
        .with_context(|| format!("{name}.weights strings"))?;
    let mut args = Vec::new();
    for aj in j
        .get("args")
        .and_then(Json::as_arr)
        .with_context(|| format!("{name}.args"))?
    {
        args.push(ArgSpec {
            name: aj
                .get("name")
                .and_then(Json::as_str)
                .context("arg.name")?
                .to_string(),
            shape: shape_of(aj.get("shape").context("arg.shape")?)?,
            dtype: DType::from_name(
                aj.get("dtype").and_then(Json::as_str).context("arg.dtype")?,
            )?,
        });
    }
    let mut outputs = Vec::new();
    for oj in j
        .get("outputs")
        .and_then(Json::as_arr)
        .with_context(|| format!("{name}.outputs"))?
    {
        outputs.push(OutSpec {
            shape: shape_of(oj.get("shape").context("out.shape")?)?,
            dtype: DType::from_name(
                oj.get("dtype").and_then(Json::as_str).context("out.dtype")?,
            )?,
        });
    }
    let mut meta = HashMap::new();
    if let Some(entries) = j.get("meta").and_then(Json::obj_entries) {
        for (k, v) in entries {
            let vs = match v {
                Json::Str(s) => s.clone(),
                Json::Num(n) => {
                    if n.fract() == 0.0 {
                        format!("{}", *n as i64)
                    } else {
                        format!("{n}")
                    }
                }
                Json::Bool(b) => b.to_string(),
                other => other.to_string(),
            };
            meta.insert(k.clone(), vs);
        }
    }
    Ok(StageSpec { name: name.to_string(), file, weights, args, outputs, meta })
}

fn shape_of(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .context("shape array")?
        .iter()
        .map(|v| v.as_usize().context("shape int"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "model": "llama",
      "weights_file": "weights.bin",
      "weight_order": ["embed", "final_norm"],
      "config": {"d_model": 256, "n_layers": 4},
      "stages": {
        "decode_b1": {
          "file": "decode_b1.hlo.txt",
          "weights": ["embed", "final_norm"],
          "args": [
            {"name": "tokens", "shape": [1], "dtype": "i32"},
            {"name": "cache_k", "shape": [4,1,8,512,32], "dtype": "f32"}
          ],
          "outputs": [{"shape": [1, 512], "dtype": "f32"}],
          "meta": {"kind": "decode", "batch": 1, "attn": "naive"}
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let j = Json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(Path::new("/tmp/x"), &j).unwrap();
        assert_eq!(m.model, "llama");
        assert_eq!(m.weight_order.len(), 2);
        let s = m.stage("decode_b1").unwrap();
        assert_eq!(s.args[1].shape, vec![4, 1, 8, 512, 32]);
        assert_eq!(s.meta_usize("batch"), Some(1));
        assert_eq!(s.meta_str("attn"), Some("naive"));
        assert_eq!(m.cfg_usize("d_model").unwrap(), 256);
        assert_eq!(m.stages_of_kind("decode").len(), 1);
        assert!(m.stage("nope").is_err());
    }
}
