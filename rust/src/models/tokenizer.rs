//! Modality front-ends: text tokenizer, image tokenizer, speech
//! featurizer.
//!
//! The paper's models use BPE (text), a learned VQ image tokenizer
//! (1024 tokens per image), and 80-dim filterbank features (speech).
//! These are substrate components we rebuild at tiny scale: a
//! deterministic byte-bigram text tokenizer over the tiny 512-entry
//! vocab, an 8×8-patch mean-quantizing image tokenizer (64 tokens per
//! image, the scaled analogue of Chameleon's 32×32 grid), and a framed
//! log-energy filterbank-style speech featurizer.

use crate::runtime::tensor::Tensor;

/// Vocab layout for the tiny decoder models (vocab_size = 512):
///   [0]           BOS
///   [1]           EOS
///   [2..258)      byte tokens (256)
///   [258..322)    image tokens (64) — Chameleon only
///   [322..512)    merged bigram tokens
pub const BOS: i32 = 0;
pub const EOS: i32 = 1;
pub const BYTE_BASE: i32 = 2;
pub const IMG_BASE: i32 = 258;
pub const IMG_TOKENS: usize = 64;
pub const BIGRAM_BASE: i32 = 322;
pub const VOCAB: usize = 512;

/// Characters allowed in merge pairs — frequency-ordered letters plus
/// space. 14 × 14 = 196 candidate pairs; the first 190 become merges.
const MERGE_CHARS: &[u8] = b"etaoinshrdlu c";

/// Deterministic byte-level tokenizer with a fixed bigram merge table —
/// a stand-in for BPE with identical interface properties (variable-rate
/// compression, exactly reversible decode).
pub struct TextTokenizer {
    /// pair → merged token id.
    merges: std::collections::HashMap<(u8, u8), i32>,
    /// merged token id − BIGRAM_BASE → pair.
    pairs: Vec<(u8, u8)>,
}

impl Default for TextTokenizer {
    fn default() -> Self {
        Self::new()
    }
}

impl TextTokenizer {
    pub fn new() -> Self {
        let n_bigrams = (VOCAB as i32 - BIGRAM_BASE) as usize;
        let mut merges = std::collections::HashMap::new();
        let mut pairs = Vec::with_capacity(n_bigrams);
        'outer: for &a in MERGE_CHARS {
            for &b in MERGE_CHARS {
                if pairs.len() == n_bigrams {
                    break 'outer;
                }
                merges.insert((a, b), BIGRAM_BASE + pairs.len() as i32);
                pairs.push((a, b));
            }
        }
        TextTokenizer { merges, pairs }
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        let bytes = text.as_bytes();
        let mut out = Vec::with_capacity(bytes.len() / 2 + 1);
        let mut i = 0;
        while i < bytes.len() {
            if i + 1 < bytes.len() {
                if let Some(&id) = self.merges.get(&(bytes[i], bytes[i + 1]))
                {
                    out.push(id);
                    i += 2;
                    continue;
                }
            }
            out.push(BYTE_BASE + bytes[i] as i32);
            i += 1;
        }
        out
    }

    /// Decode token ids back to text. Unknown/image ids map to U+FFFD.
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            if id == BOS || id == EOS {
                continue;
            } else if (BYTE_BASE..IMG_BASE).contains(&id) {
                bytes.push((id - BYTE_BASE) as u8);
            } else if id >= BIGRAM_BASE && (id as usize) < VOCAB {
                let (a, b) = self.pairs[(id - BIGRAM_BASE) as usize];
                bytes.push(a);
                bytes.push(b);
            } else {
                bytes.extend_from_slice("\u{fffd}".as_bytes());
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

/// Image tokenizer: quantize 8×8 patch means of a grayscale image into
/// the 64 image-token slots (the tiny analogue of Chameleon's
/// Make-A-Scene VQ tokenizer producing a fixed-length token grid).
pub struct ImageTokenizer;

impl ImageTokenizer {
    /// `pixels`: HxW grayscale in [0,1], H and W multiples of 8.
    /// Returns exactly [`IMG_TOKENS`] tokens.
    pub fn encode(pixels: &[f32], h: usize, w: usize) -> Vec<i32> {
        assert_eq!(pixels.len(), h * w, "pixel count");
        let gh = 8;
        let gw = 8;
        let ph = (h / gh).max(1);
        let pw = (w / gw).max(1);
        let mut out = Vec::with_capacity(IMG_TOKENS);
        for gy in 0..gh {
            for gx in 0..gw {
                let mut sum = 0.0f32;
                let mut n = 0usize;
                for y in gy * ph..((gy + 1) * ph).min(h) {
                    for x in gx * pw..((gx + 1) * pw).min(w) {
                        sum += pixels[y * w + x];
                        n += 1;
                    }
                }
                let mean = if n > 0 { sum / n as f32 } else { 0.0 };
                let q = ((mean.clamp(0.0, 1.0)) * 63.0).round() as i32;
                out.push(IMG_BASE + q);
            }
        }
        out
    }

    /// Decode image tokens back to an 8×8 grayscale thumbnail.
    pub fn decode(tokens: &[i32]) -> Vec<f32> {
        tokens
            .iter()
            .map(|&t| ((t - IMG_BASE).clamp(0, 63) as f32) / 63.0)
            .collect()
    }
}

/// Speech featurizer: frame a waveform into 80-dim log-energy features
/// (the tiny analogue of the paper's 80-dim filterbanks at 100 Hz).
pub struct SpeechFeaturizer {
    pub frame: usize,
    pub n_mels: usize,
}

impl Default for SpeechFeaturizer {
    fn default() -> Self {
        SpeechFeaturizer { frame: 160, n_mels: 80 }
    }
}

impl SpeechFeaturizer {
    /// waveform → [n_frames, n_mels] features as a Tensor [1, T, 80].
    /// T is padded up to `pad_to` frames (0 ⇒ no padding).
    pub fn featurize(&self, wav: &[f32], pad_to: usize) -> (Tensor, usize) {
        let n_frames = (wav.len() / self.frame).max(1);
        let t = if pad_to > 0 { pad_to } else { n_frames };
        let mut feats = vec![0f32; t * self.n_mels];
        for f in 0..n_frames.min(t) {
            let seg = &wav[f * self.frame..
                ((f + 1) * self.frame).min(wav.len())];
            // banded log-energies: split the frame into n_mels bands
            for m in 0..self.n_mels {
                let lo = m * seg.len() / self.n_mels;
                let hi = ((m + 1) * seg.len() / self.n_mels).max(lo + 1);
                let e: f32 = seg[lo..hi.min(seg.len())]
                    .iter()
                    .map(|x| x * x)
                    .sum();
                feats[f * self.n_mels + m] =
                    (e / (hi - lo) as f32 + 1e-6).ln();
            }
        }
        (
            Tensor::from_f32(&[1, t, self.n_mels], &feats),
            n_frames.min(t),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn text_roundtrip() {
        let tk = TextTokenizer::new();
        for s in ["hello world", "fn main() { return 42; }", "über-café"] {
            let ids = tk.encode(s);
            assert!(!ids.is_empty());
            assert!(ids.iter().all(|&i| (0..VOCAB as i32).contains(&i)));
            assert_eq!(tk.decode(&ids), s, "roundtrip {s:?}");
        }
    }

    #[test]
    fn text_compresses() {
        let tk = TextTokenizer::new();
        let s = "the quick brown fox jumps over the lazy dog";
        let ids = tk.encode(s);
        assert!(ids.len() < s.len(), "{} !< {}", ids.len(), s.len());
    }

    #[test]
    fn image_tokens_fixed_length_and_range() {
        let px = vec![0.5f32; 64 * 64];
        let ids = ImageTokenizer::encode(&px, 64, 64);
        assert_eq!(ids.len(), IMG_TOKENS);
        assert!(ids.iter().all(|&i| {
            (IMG_BASE..IMG_BASE + IMG_TOKENS as i32).contains(&i)
        }));
        // uniform 0.5 image → all tokens equal
        assert!(ids.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn image_decode_inverts_quantization() {
        let px: Vec<f32> = (0..64 * 64).map(|i| (i % 64) as f32 / 63.0)
            .collect();
        let ids = ImageTokenizer::encode(&px, 64, 64);
        let back = ImageTokenizer::decode(&ids);
        assert_eq!(back.len(), IMG_TOKENS);
        assert!(back.iter().all(|v| (0.0..=1.0).contains(v)));
    }

    #[test]
    fn speech_features_shape_and_padding() {
        let sf = SpeechFeaturizer::default();
        let wav: Vec<f32> = (0..160 * 10).map(|i| (i as f32 * 0.01).sin())
            .collect();
        let (t, n) = sf.featurize(&wav, 64);
        assert_eq!(t.shape, vec![1, 64, 80]);
        assert_eq!(n, 10);
        // louder signal ⇒ larger energy in frame 0
        let quiet: Vec<f32> = wav.iter().map(|x| x * 0.1).collect();
        let (tq, _) = sf.featurize(&quiet, 64);
        let a = t.as_f32().unwrap();
        let b = tq.as_f32().unwrap();
        assert!(a[0] > b[0]);
    }
}
