//! Model & task registry — the paper's Table 1 as code.
//!
//! Four model families × nine tasks, each task declaring its input and
//! output modalities. The registry is what the router validates requests
//! against and what the workload generators and the device model key on.

pub mod tokenizer;

use std::fmt;

/// The four model families characterized by the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelKind {
    /// Code Llama — text-based LLM (autoregressive).
    Llama,
    /// Chameleon — early-fusion text+image generation (autoregressive).
    Chameleon,
    /// Seamless M4T — speech/text translation (only the text decoder is
    /// autoregressive).
    Seamless,
    /// HSTU — generative DLRM (non-autoregressive).
    Hstu,
}

impl ModelKind {
    pub fn dir_name(self) -> &'static str {
        match self {
            ModelKind::Llama => "llama",
            ModelKind::Chameleon => "chameleon",
            ModelKind::Seamless => "seamless",
            ModelKind::Hstu => "hstu",
        }
    }
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "llama" => ModelKind::Llama,
            "chameleon" => ModelKind::Chameleon,
            "seamless" => ModelKind::Seamless,
            "hstu" => ModelKind::Hstu,
            _ => return None,
        })
    }
    pub fn all() -> [ModelKind; 4] {
        [ModelKind::Llama, ModelKind::Chameleon, ModelKind::Seamless,
         ModelKind::Hstu]
    }
    /// Paper Table 1 "Auto-regressive" column.
    pub fn autoregressive(self) -> Autoregressive {
        match self {
            ModelKind::Llama | ModelKind::Chameleon => Autoregressive::Full,
            ModelKind::Seamless => Autoregressive::TextDecoderOnly,
            ModelKind::Hstu => Autoregressive::No,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Autoregressive {
    Full,
    TextDecoderOnly,
    No,
}

/// Input/output modalities (Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Modality {
    Text,
    Image,
    Speech,
    UserHistory,
    Action,
}

/// The nine tasks characterized in the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    /// Llama: code completion / instruction (T-T).
    TextToText,
    /// Chameleon image captioning (I-T).
    ImageToText,
    /// Chameleon image generation (T-I) — contrastive decoding, 1024
    /// image tokens.
    TextToImage,
    /// Chameleon VQA (IT-T).
    ImageTextToText,
    /// Seamless S-S.
    SpeechToSpeech,
    /// Seamless S-T.
    SpeechToText,
    /// Seamless T-T translation.
    TextToTextTrans,
    /// Seamless T-S.
    TextToSpeech,
    /// HSTU ranking + retrieval (H-A).
    HistoryToAction,
}

impl TaskKind {
    pub fn notation(self) -> &'static str {
        match self {
            TaskKind::TextToText => "T-T",
            TaskKind::ImageToText => "I-T",
            TaskKind::TextToImage => "T-I",
            TaskKind::ImageTextToText => "IT-T",
            TaskKind::SpeechToSpeech => "S-S",
            TaskKind::SpeechToText => "S-T",
            TaskKind::TextToTextTrans => "T-T(tr)",
            TaskKind::TextToSpeech => "T-S",
            TaskKind::HistoryToAction => "H-A",
        }
    }

    pub fn model(self) -> ModelKind {
        match self {
            TaskKind::TextToText => ModelKind::Llama,
            TaskKind::ImageToText
            | TaskKind::TextToImage
            | TaskKind::ImageTextToText => ModelKind::Chameleon,
            TaskKind::SpeechToSpeech
            | TaskKind::SpeechToText
            | TaskKind::TextToTextTrans
            | TaskKind::TextToSpeech => ModelKind::Seamless,
            TaskKind::HistoryToAction => ModelKind::Hstu,
        }
    }

    pub fn input_modalities(self) -> &'static [Modality] {
        match self {
            TaskKind::TextToText | TaskKind::TextToImage
            | TaskKind::TextToTextTrans | TaskKind::TextToSpeech => {
                &[Modality::Text]
            }
            TaskKind::ImageToText => &[Modality::Image],
            TaskKind::ImageTextToText => &[Modality::Image, Modality::Text],
            TaskKind::SpeechToSpeech | TaskKind::SpeechToText => {
                &[Modality::Speech]
            }
            TaskKind::HistoryToAction => &[Modality::UserHistory],
        }
    }

    pub fn output_modality(self) -> Modality {
        match self {
            TaskKind::TextToText
            | TaskKind::ImageToText
            | TaskKind::ImageTextToText
            | TaskKind::SpeechToText
            | TaskKind::TextToTextTrans => Modality::Text,
            TaskKind::TextToImage => Modality::Image,
            TaskKind::SpeechToSpeech | TaskKind::TextToSpeech => {
                Modality::Speech
            }
            TaskKind::HistoryToAction => Modality::Action,
        }
    }

    /// Chameleon T-I decodes twice per step (contrastive decoding).
    pub fn decodes_per_step(self) -> usize {
        if self == TaskKind::TextToImage {
            2
        } else {
            1
        }
    }

    pub fn all() -> [TaskKind; 9] {
        [
            TaskKind::TextToText,
            TaskKind::ImageToText,
            TaskKind::TextToImage,
            TaskKind::ImageTextToText,
            TaskKind::SpeechToSpeech,
            TaskKind::SpeechToText,
            TaskKind::TextToTextTrans,
            TaskKind::TextToSpeech,
            TaskKind::HistoryToAction,
        ]
    }

    pub fn parse(s: &str) -> Option<Self> {
        Self::all().into_iter().find(|t| {
            t.notation().eq_ignore_ascii_case(s)
        })
    }
}

impl fmt::Display for TaskKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.notation())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_task_model_mapping() {
        assert_eq!(TaskKind::TextToText.model(), ModelKind::Llama);
        assert_eq!(TaskKind::TextToImage.model(), ModelKind::Chameleon);
        assert_eq!(TaskKind::SpeechToSpeech.model(), ModelKind::Seamless);
        assert_eq!(TaskKind::HistoryToAction.model(), ModelKind::Hstu);
    }

    #[test]
    fn autoregressive_column() {
        assert_eq!(ModelKind::Llama.autoregressive(), Autoregressive::Full);
        assert_eq!(
            ModelKind::Seamless.autoregressive(),
            Autoregressive::TextDecoderOnly
        );
        assert_eq!(ModelKind::Hstu.autoregressive(), Autoregressive::No);
    }

    #[test]
    fn contrastive_decode_only_ti() {
        for t in TaskKind::all() {
            let want = if t == TaskKind::TextToImage { 2 } else { 1 };
            assert_eq!(t.decodes_per_step(), want, "{t}");
        }
    }

    #[test]
    fn parse_roundtrip() {
        for t in TaskKind::all() {
            assert_eq!(TaskKind::parse(t.notation()), Some(t));
        }
        assert_eq!(TaskKind::parse("nope"), None);
        for m in ModelKind::all() {
            assert_eq!(ModelKind::parse(m.dir_name()), Some(m));
        }
    }

    #[test]
    fn modalities_match_table1() {
        assert_eq!(
            TaskKind::ImageTextToText.input_modalities(),
            &[Modality::Image, Modality::Text]
        );
        assert_eq!(TaskKind::TextToImage.output_modality(), Modality::Image);
        assert_eq!(
            TaskKind::HistoryToAction.output_modality(),
            Modality::Action
        );
    }
}
