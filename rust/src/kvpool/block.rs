//! Ref-counted page allocator over a fixed page budget.
//!
//! A page holds `page_size` tokens of KV for one sequence position
//! range. Pages move between three states:
//!
//! * **Free** — on the free list, content undefined.
//! * **Live** — referenced by ≥ 1 block table (refcount counts tables).
//! * **Cached** — refcount 0 but retained by the prefix cache so a
//!   future request with the same prefix can reuse it; evictable.
//!
//! The pool itself knows nothing about hashes or tables — it only
//! enforces the state machine and the conservation invariant
//! `free + live + cached == total` that the property tests check.

/// Index of a page inside the pool's budget.
pub type PageId = usize;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PageState {
    Free,
    Live,
    Cached,
}

#[derive(Debug, Clone)]
struct Page {
    state: PageState,
    /// Number of block tables referencing the page (0 unless Live).
    refs: usize,
}

/// Fixed-budget page allocator with free-list reuse.
#[derive(Debug, Clone)]
pub struct BlockPool {
    pages: Vec<Page>,
    /// LIFO free list seeded in reverse so the lowest index pops first.
    free: Vec<PageId>,
    page_size: usize,
}

impl BlockPool {
    pub fn new(total_pages: usize, page_size: usize) -> Self {
        assert!(page_size > 0, "page_size must be positive");
        BlockPool {
            pages: vec![
                Page { state: PageState::Free, refs: 0 };
                total_pages
            ],
            free: (0..total_pages).rev().collect(),
            page_size,
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }
    pub fn total(&self) -> usize {
        self.pages.len()
    }
    pub fn free_count(&self) -> usize {
        self.free.len()
    }
    pub fn live_count(&self) -> usize {
        self.pages
            .iter()
            .filter(|p| p.state == PageState::Live)
            .count()
    }
    pub fn cached_count(&self) -> usize {
        self.pages
            .iter()
            .filter(|p| p.state == PageState::Cached)
            .count()
    }

    pub fn state(&self, id: PageId) -> PageState {
        self.pages[id].state
    }
    pub fn refs(&self, id: PageId) -> usize {
        self.pages[id].refs
    }

    /// Claim a free page (refcount 1). `None` when the free list is
    /// empty — the caller decides whether to evict a cached page.
    pub fn alloc(&mut self) -> Option<PageId> {
        let id = self.free.pop()?;
        debug_assert_eq!(self.pages[id].state, PageState::Free);
        self.pages[id] = Page { state: PageState::Live, refs: 1 };
        Some(id)
    }

    /// Add one reference to a live page (prefix sharing).
    pub fn retain(&mut self, id: PageId) {
        debug_assert_eq!(self.pages[id].state, PageState::Live);
        self.pages[id].refs += 1;
    }

    /// Drop one reference; returns the remaining count. A page at zero
    /// stays Live until the caller parks or frees it.
    pub fn release(&mut self, id: PageId) -> usize {
        let p = &mut self.pages[id];
        debug_assert_eq!(p.state, PageState::Live);
        debug_assert!(p.refs > 0, "release of zero-ref page {id}");
        p.refs -= 1;
        p.refs
    }

    /// Return a zero-ref live page to the free list.
    pub fn free_page(&mut self, id: PageId) {
        let p = &mut self.pages[id];
        debug_assert_eq!(p.state, PageState::Live);
        debug_assert_eq!(p.refs, 0, "freeing referenced page {id}");
        p.state = PageState::Free;
        self.free.push(id);
    }

    /// Park a zero-ref live page as a cached prefix (evictable).
    pub fn park_cached(&mut self, id: PageId) {
        let p = &mut self.pages[id];
        debug_assert_eq!(p.state, PageState::Live);
        debug_assert_eq!(p.refs, 0, "caching referenced page {id}");
        p.state = PageState::Cached;
    }

    /// Revive a cached page for a new table (refcount 1).
    pub fn unpark(&mut self, id: PageId) {
        let p = &mut self.pages[id];
        debug_assert_eq!(p.state, PageState::Cached);
        p.state = PageState::Live;
        p.refs = 1;
    }

    /// Evict a cached page back to the free list.
    pub fn evict_cached(&mut self, id: PageId) {
        let p = &mut self.pages[id];
        debug_assert_eq!(p.state, PageState::Cached);
        p.state = PageState::Free;
        p.refs = 0;
        self.free.push(id);
    }

    /// Conservation check: every page is in exactly one state and the
    /// state counts add up to the budget.
    pub fn check_conservation(&self) -> Result<(), String> {
        let free = self.free_count();
        let live = self.live_count();
        let cached = self.cached_count();
        if free + live + cached != self.total() {
            return Err(format!(
                "page leak: free {free} + live {live} + cached {cached} \
                 != total {}",
                self.total()
            ));
        }
        for (i, p) in self.pages.iter().enumerate() {
            match p.state {
                PageState::Free | PageState::Cached => {
                    if p.refs != 0 {
                        return Err(format!(
                            "page {i} {:?} with refs {}", p.state, p.refs
                        ));
                    }
                }
                PageState::Live => {
                    // refs 0 is a transient mid-release state; a settled
                    // pool must not hold zero-ref live pages.
                    if p.refs == 0 {
                        return Err(format!("page {i} live with refs 0"));
                    }
                }
            }
        }
        let on_free_list = self.free.iter().filter(|&&id| {
            self.pages[id].state == PageState::Free
        });
        if on_free_list.count() != self.free.len() {
            return Err("free list holds a non-free page".into());
        }
        Ok(())
    }

    /// Convenience for error reporting: pages obtainable right now.
    pub fn available(&self, cached_evictable: usize) -> usize {
        self.free_count() + cached_evictable
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_cycle_reuses_lowest_first() {
        let mut bp = BlockPool::new(3, 16);
        assert_eq!(bp.alloc(), Some(0));
        assert_eq!(bp.alloc(), Some(1));
        assert_eq!(bp.alloc(), Some(2));
        assert_eq!(bp.alloc(), None);
        assert_eq!(bp.release(1), 0);
        bp.free_page(1);
        assert_eq!(bp.alloc(), Some(1));
        bp.check_conservation().unwrap();
    }

    #[test]
    fn retain_release_counts() {
        let mut bp = BlockPool::new(2, 8);
        let p = bp.alloc().unwrap();
        bp.retain(p);
        bp.retain(p);
        assert_eq!(bp.refs(p), 3);
        assert_eq!(bp.release(p), 2);
        assert_eq!(bp.release(p), 1);
        assert_eq!(bp.release(p), 0);
        bp.free_page(p);
        assert_eq!(bp.state(p), PageState::Free);
        bp.check_conservation().unwrap();
    }

    #[test]
    fn cached_park_unpark_evict() {
        let mut bp = BlockPool::new(2, 8);
        let p = bp.alloc().unwrap();
        bp.release(p);
        bp.park_cached(p);
        assert_eq!(bp.state(p), PageState::Cached);
        assert_eq!(bp.cached_count(), 1);
        bp.check_conservation().unwrap();
        bp.unpark(p);
        assert_eq!(bp.refs(p), 1);
        bp.release(p);
        bp.park_cached(p);
        bp.evict_cached(p);
        assert_eq!(bp.state(p), PageState::Free);
        assert_eq!(bp.free_count(), 2);
        bp.check_conservation().unwrap();
    }
}
