//! Host-side swap buffers: byte-accounted staging for swapped-out KV.
//!
//! `PreemptMode::SwapOut` used to ledger a victim as a bare
//! `(tokens, prompt_len)` entry — host memory was implicitly infinite
//! and free. This pool makes the host side real: every swap-out
//! *reserves* a buffer sized by the fabric's KV geometry
//! (`tokens × kv_bytes_per_token`), every swap-in or crash teardown
//! *releases* it, and a reservation the capacity cannot cover fails —
//! which is what forces the preemption policy to fall back to
//! recompute and turns the swap-vs-recompute mix into a measurable
//! decision instead of a hardcoded branch.
//!
//! Conservation contract (the property suite drives this): at every
//! point, `reserved_bytes == Σ outstanding buffer bytes` and
//! `total_reserved == total_released + reserved_bytes`. After a drain
//! (replica crash) or a full resume cycle, reserved bytes return to
//! zero with `total_reserved == total_released` — no buffer leaks,
//! ever, including for victims killed mid-swap by `KillSpec`.

use std::collections::HashMap;

/// One swapped-out sequence staged in host memory.
#[derive(Debug, Clone)]
pub struct HostBuffer {
    pub request: u64,
    /// Full token history (prompt + generated) at swap-out time.
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// KV bytes the buffer pins (0 when no fabric prices geometry).
    pub bytes: u64,
}

/// The byte-budgeted pool of host swap buffers.
#[derive(Debug, Clone, Default)]
pub struct HostBufferPool {
    /// Capacity in bytes; 0 = unbounded (the legacy ledger behavior).
    capacity: u64,
    reserved: u64,
    total_reserved: u64,
    total_released: u64,
    buffers: HashMap<u64, HostBuffer>,
}

impl HostBufferPool {
    /// Unbounded pool — reservation never fails (legacy semantics).
    pub fn unbounded() -> Self {
        HostBufferPool::default()
    }

    pub fn with_capacity(capacity: u64) -> Self {
        HostBufferPool { capacity, ..HostBufferPool::default() }
    }

    /// Re-budget the pool (attaching a fabric). Outstanding buffers
    /// are honored even if they exceed the new capacity.
    pub fn set_capacity(&mut self, capacity: u64) {
        self.capacity = capacity;
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }
    /// Bytes currently pinned by outstanding buffers.
    pub fn reserved_bytes(&self) -> u64 {
        self.reserved
    }
    /// Lifetime bytes ever reserved (monotone).
    pub fn total_reserved(&self) -> u64 {
        self.total_reserved
    }
    /// Lifetime bytes ever released (monotone).
    pub fn total_released(&self) -> u64 {
        self.total_released
    }
    /// Outstanding swapped-out sequences.
    pub fn len(&self) -> usize {
        self.buffers.len()
    }
    pub fn is_empty(&self) -> bool {
        self.buffers.is_empty()
    }

    pub fn contains(&self, request: u64) -> bool {
        self.buffers.contains_key(&request)
    }

    pub fn get(&self, request: u64) -> Option<&HostBuffer> {
        self.buffers.get(&request)
    }

    /// Would a `bytes`-sized reservation fit right now?
    pub fn can_reserve(&self, bytes: u64) -> bool {
        self.capacity == 0 || self.reserved + bytes <= self.capacity
    }

    /// Stage a swapped-out sequence. Fails (buffer not taken) when the
    /// capacity cannot cover it or the request is already staged.
    pub fn reserve(&mut self, request: u64, tokens: Vec<i32>,
                   prompt_len: usize, bytes: u64) -> Result<(), ()> {
        if !self.can_reserve(bytes) || self.buffers.contains_key(&request)
        {
            return Err(());
        }
        self.reserved += bytes;
        self.total_reserved += bytes;
        self.buffers
            .insert(request, HostBuffer { request, tokens, prompt_len,
                                          bytes });
        Ok(())
    }

    /// Release a buffer (successful swap-in, or the request was
    /// dropped): the bytes return to the budget.
    pub fn release(&mut self, request: u64) -> Option<HostBuffer> {
        let buf = self.buffers.remove(&request)?;
        self.reserved -= buf.bytes;
        self.total_released += buf.bytes;
        Some(buf)
    }

    /// Crash teardown: release every outstanding buffer (a dead
    /// replica's host memory goes back to the budget; its requests are
    /// re-routed from the prompt, not from the buffer). Returns the
    /// freed bytes.
    pub fn drain(&mut self) -> u64 {
        let freed = self.reserved;
        self.buffers.clear();
        self.total_released += freed;
        self.reserved = 0;
        freed
    }

    /// The conservation invariants described in the module doc.
    pub fn check_conservation(&self) -> Result<(), String> {
        let outstanding: u64 =
            self.buffers.values().map(|b| b.bytes).sum();
        if outstanding != self.reserved {
            return Err(format!(
                "host buffers: reserved {} != outstanding {}",
                self.reserved, outstanding
            ));
        }
        if self.total_reserved != self.total_released + self.reserved {
            return Err(format!(
                "host buffers: reserved-ever {} != released-ever {} + \
                 outstanding {}",
                self.total_reserved, self.total_released, self.reserved
            ));
        }
        if self.capacity > 0 && self.reserved > self.capacity {
            // set_capacity may shrink under outstanding buffers; new
            // reservations must still be refused then.
            if self.can_reserve(1) {
                return Err(format!(
                    "host buffers: over capacity ({} > {}) yet still \
                     reserving",
                    self.reserved, self.capacity
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reserve_release_conserves_bytes() {
        let mut h = HostBufferPool::with_capacity(100);
        assert!(h.reserve(1, vec![1, 2], 2, 60).is_ok());
        assert!(h.contains(1));
        assert_eq!(h.reserved_bytes(), 60);
        assert!(h.reserve(1, vec![9], 1, 1).is_err(), "duplicate");
        assert!(!h.can_reserve(41));
        assert!(h.reserve(2, vec![3], 1, 41).is_err(), "over capacity");
        assert!(h.reserve(2, vec![3], 1, 40).is_ok());
        h.check_conservation().unwrap();
        let buf = h.release(1).unwrap();
        assert_eq!(buf.tokens, vec![1, 2]);
        assert_eq!(buf.bytes, 60);
        assert_eq!(h.reserved_bytes(), 40);
        assert_eq!(h.total_reserved(), 100);
        assert_eq!(h.total_released(), 60);
        assert!(h.release(1).is_none());
        h.check_conservation().unwrap();
    }

    #[test]
    fn unbounded_pool_never_refuses() {
        let mut h = HostBufferPool::unbounded();
        assert!(h.can_reserve(u64::MAX / 2));
        assert!(h.reserve(7, vec![], 0, 1 << 40).is_ok());
        h.check_conservation().unwrap();
    }

    #[test]
    fn drain_releases_everything() {
        let mut h = HostBufferPool::with_capacity(100);
        h.reserve(1, vec![1], 1, 30).unwrap();
        h.reserve(2, vec![2], 1, 50).unwrap();
        assert_eq!(h.drain(), 80);
        assert!(h.is_empty());
        assert_eq!(h.reserved_bytes(), 0);
        assert_eq!(h.total_reserved(), h.total_released());
        h.check_conservation().unwrap();
        // The budget is whole again.
        assert!(h.reserve(3, vec![3], 1, 100).is_ok());
        h.check_conservation().unwrap();
    }
}
