//! Hash-based prefix cache: content-addressed full blocks with an LRU
//! over the zero-ref (evictable) ones.
//!
//! Each *full* block of a sequence gets a chain hash
//! `h[i] = fnv(h[i-1], tokens in block i)`, so equal hashes imply an
//! identical token prefix up to that block boundary. The cache maps
//! hash → page; a hit lets a new request reference the page instead of
//! recomputing its KV (the shared-system-prompt win the replay
//! measures). Pages whose last table releases them are *parked* rather
//! than freed and queue here in LRU order until capacity pressure
//! evicts them.

use std::collections::HashMap;

use super::block::PageId;

/// FNV-1a over a hash chain + token block (stable, dependency-free).
pub fn chain_hash(prev: u64, tokens: &[i32]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET ^ prev.wrapping_mul(PRIME);
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    }
    h
}

/// Chain hashes for every full `page_size` block of `tokens`.
pub fn block_hashes(tokens: &[i32], page_size: usize) -> Vec<u64> {
    let full = tokens.len() / page_size.max(1);
    let mut out = Vec::with_capacity(full);
    let mut prev = 0u64;
    for i in 0..full {
        prev = chain_hash(prev, &tokens[i * page_size..(i + 1) * page_size]);
        out.push(prev);
    }
    out
}

/// hash → page map plus the LRU of zero-ref cached pages.
#[derive(Debug, Clone, Default)]
pub struct PrefixCache {
    by_hash: HashMap<u64, PageId>,
    by_page: HashMap<PageId, u64>,
    /// Zero-ref cached pages, least-recently-used first. Scale is the
    /// page budget, so the O(n) removals below are fine.
    lru: Vec<PageId>,
}

impl PrefixCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.by_hash.len()
    }
    pub fn is_empty(&self) -> bool {
        self.by_hash.is_empty()
    }
    /// Pages reclaimable by LRU eviction right now.
    pub fn evictable(&self) -> usize {
        self.lru.len()
    }

    pub fn lookup(&self, hash: u64) -> Option<PageId> {
        self.by_hash.get(&hash).copied()
    }

    pub fn contains_page(&self, page: PageId) -> bool {
        self.by_page.contains_key(&page)
    }

    /// Register a page's content hash. First writer wins: an existing
    /// entry for the hash keeps its canonical page.
    pub fn insert(&mut self, hash: u64, page: PageId) {
        if self.by_hash.contains_key(&hash) || self.by_page.contains_key(&page)
        {
            return;
        }
        self.by_hash.insert(hash, page);
        self.by_page.insert(page, hash);
    }

    /// The page's last reference went away: queue it for LRU reuse.
    /// Returns false (caller should free) when the page has no hash
    /// entry — nothing could ever look it up again.
    pub fn park(&mut self, page: PageId) -> bool {
        if !self.by_page.contains_key(&page) {
            return false;
        }
        debug_assert!(!self.lru.contains(&page), "page {page} parked twice");
        self.lru.push(page);
        true
    }

    /// A cached (zero-ref) page got a cache hit: pull it off the LRU.
    pub fn reuse(&mut self, page: PageId) {
        self.lru.retain(|&p| p != page);
    }

    /// Reclaim the least-recently-used cached page, dropping its hash
    /// entry. The caller returns the page to the free list.
    pub fn evict_lru(&mut self) -> Option<PageId> {
        if self.lru.is_empty() {
            return None;
        }
        let page = self.lru.remove(0);
        if let Some(h) = self.by_page.remove(&page) {
            self.by_hash.remove(&h);
        }
        Some(page)
    }

    /// Drop the hash entry for a page whose content is diverging
    /// (in-place overwrite by its sole owner).
    pub fn invalidate(&mut self, page: PageId) {
        if let Some(h) = self.by_page.remove(&page) {
            self.by_hash.remove(&h);
        }
        self.lru.retain(|&p| p != page);
    }

    /// Pages currently parked on the LRU (oldest first) — test hook.
    pub fn lru_pages(&self) -> &[PageId] {
        &self.lru
    }

    /// All resident block hashes (live shared pages and parked cached
    /// ones alike) — the payload of the routing prefix snapshot.
    pub fn hashes(&self) -> impl Iterator<Item = u64> + '_ {
        self.by_hash.keys().copied()
    }

    /// Resident `(hash, page)` pairs — the sharded snapshot buckets
    /// these by the page's owning device.
    pub fn entries(&self) -> impl Iterator<Item = (u64, PageId)> + '_ {
        self.by_hash.iter().map(|(&h, &p)| (h, p))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_hash_is_prefix_sensitive() {
        let a = block_hashes(&[1, 2, 3, 4, 5, 6, 7, 8], 4);
        let b = block_hashes(&[1, 2, 3, 4, 9, 9, 9, 9], 4);
        assert_eq!(a.len(), 2);
        assert_eq!(a[0], b[0], "identical first block, identical hash");
        assert_ne!(a[1], b[1], "divergent second block");
        // Same tokens, different position in the chain → different hash.
        let c = block_hashes(&[5, 6, 7, 8, 5, 6, 7, 8], 4);
        assert_ne!(c[0], c[1]);
    }

    #[test]
    fn partial_blocks_are_not_hashed() {
        assert!(block_hashes(&[1, 2, 3], 4).is_empty());
        assert_eq!(block_hashes(&[1, 2, 3, 4, 5], 4).len(), 1);
    }

    #[test]
    fn insert_lookup_park_evict() {
        let mut c = PrefixCache::new();
        c.insert(10, 0);
        c.insert(20, 1);
        assert_eq!(c.lookup(10), Some(0));
        assert_eq!(c.evictable(), 0);
        assert!(c.park(0));
        assert!(c.park(1));
        assert!(!c.park(5), "unhashed page is not cacheable");
        assert_eq!(c.evictable(), 2);
        // Reuse pulls a page out of LRU but keeps its hash entry.
        c.reuse(0);
        assert_eq!(c.evictable(), 1);
        assert_eq!(c.lookup(10), Some(0));
        // Eviction drops the oldest remaining entry entirely.
        assert_eq!(c.evict_lru(), Some(1));
        assert_eq!(c.lookup(20), None);
        assert_eq!(c.evict_lru(), None);
    }

    #[test]
    fn first_writer_wins_and_invalidate_clears() {
        let mut c = PrefixCache::new();
        c.insert(10, 0);
        c.insert(10, 1); // same hash, later page: ignored
        assert_eq!(c.lookup(10), Some(0));
        c.insert(30, 0); // same page, second hash: ignored
        assert_eq!(c.lookup(30), None);
        c.invalidate(0);
        assert_eq!(c.lookup(10), None);
        assert!(!c.contains_page(0));
    }
}
