//! Paged KV-cache pool: block-granular capacity management with prefix
//! sharing, capacity-aware admission, and preemption.
//!
//! The paper's Table 3 shows KV-cache capacity is what bounds the
//! achievable decode batch — the single biggest lever on the GPU idle
//! time of Obs #2. The dense `[L, B, H, max_seq, Dh]` reservation of
//! `coordinator::kv::KvSlots` pins a worst-case sequence per slot, so a
//! 30-token chat request blocks as much memory as a max-length
//! document. This subsystem manages the same capacity at *page*
//! granularity (vLLM-style paged attention, cf. arXiv:2407.09111):
//!
//! * [`block`] — [`BlockPool`]: a fixed budget of ref-counted pages
//!   with free-list reuse; every page is Free, Live, or Cached.
//! * [`hostbuf`] — [`HostBufferPool`]: byte-accounted host staging for
//!   swapped-out sequences; sized by the priced transfer fabric
//!   (`crate::perfmodel::fabric`), conserved across swap-out / resume /
//!   crash teardown.
//! * [`shard`] — [`ShardedBlockPool`]: the budget split across `D`
//!   simulated device arenas (global page id = `(device, page)` via
//!   [`shard::ShardedBlockPool::locate`]); block tables span shards,
//!   growth prefers a sequence's home arena and spills when it runs
//!   dry — the capacity half of tensor-parallel serving. One shard is
//!   the monolithic pool, bit for bit.
//! * [`table`] — [`BlockTable`]: one request's token-position → page
//!   mapping, plus the token history that makes blocks hashable.
//! * [`prefix`] — [`PrefixCache`]: chain-hash → page map with an LRU
//!   over zero-ref cached pages; full blocks are shared across
//!   requests (copy-on-write on divergence).
//! * [`pool`] — [`KvPool`]: the manager tying the three together:
//!   alloc / advance / rewind / release / preempt, the capacity view
//!   the batcher admits against, and the pool counters (prefix hit
//!   rate, block churn, evictions, preemptions, capacity waits).
//! * [`replay`] — a deterministic workload replay that drives the pool
//!   (or the dense slot baseline) through a request mix and reports
//!   mean batch occupancy — the `mmserve kv` engine. Its `SimWorker`
//!   is also the unit the replica-routing replay
//!   (`crate::routing::replay`) runs in fleets.
//!
//! The pool additionally answers cheap read-only *prefix probes*
//! (`KvPool::probe_prefix`, resident hashes via
//! `KvPool::resident_hashes`) — the signal the router's
//! prefix-affinity policy uses to steer same-prefix requests to the
//! replica whose cache is already warm.
//!
//! Scope: the pool is the *logical* capacity layer. The compiled decode
//! graphs keep their dense per-slot caches (`KvSlots` stays the
//! slot view layered on top — see `coordinator::kv::PagedKvSlots`);
//! pages meter admission, growth, sharing, and preemption exactly as a
//! device-side paged allocator would, which is what the Table-3
//! accounting and the batcher need. Device-side paged attention kernels
//! are a recorded follow-on (ROADMAP).

pub mod block;
pub mod hostbuf;
pub mod pool;
pub mod prefix;
pub mod replay;
pub mod shard;
pub mod table;

pub use block::{BlockPool, PageId, PageState};
pub use hostbuf::{HostBuffer, HostBufferPool};
pub use pool::{AllocOutcome, CapacityView, KvPool, KvPoolConfig,
               PageBudget, PoolStats, Preempted, PreemptMode};
pub use prefix::PrefixCache;
pub use shard::{ShardId, ShardView, ShardedBlockPool};
pub use table::BlockTable;

/// Default tokens per KV page (vLLM's default block size).
pub const DEFAULT_PAGE_SIZE: usize = 16;

/// Pages needed to hold `tokens` tokens at `page_size` granularity.
pub fn pages_for(tokens: usize, page_size: usize) -> usize {
    let ps = page_size.max(1);
    (tokens + ps - 1) / ps
}

/// Structured error vocabulary shared by the paged pool and the dense
/// slot manager — callers match on variants instead of error strings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvError {
    /// The pool cannot supply `needed` pages (free + evictable-cached
    /// < needed). The caller should preempt or queue.
    CapacityExhausted { needed: usize, available: usize },
    /// All batch slots are live (dense slot view).
    NoFreeSlot,
    /// The request already holds a table / slot.
    DuplicateRequest(u64),
    /// No table / slot is registered for the request.
    UnknownRequest(u64),
    /// Slot index outside the batch.
    UnknownSlot(usize),
    /// Operation on a slot that is not live.
    SlotFree(usize),
    /// Position would reach or pass the sequence capacity.
    MaxSeq { pos: usize, max_seq: usize },
    /// Rewind target is ahead of the current position.
    RewindForward { from: usize, to: usize },
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::CapacityExhausted { needed, available } => write!(
                f,
                "kv capacity exhausted: need {needed} pages, \
                 {available} available"
            ),
            KvError::NoFreeSlot => write!(f, "no free slot"),
            KvError::DuplicateRequest(r) => {
                write!(f, "request {r} already has a kv allocation")
            }
            KvError::UnknownRequest(r) => {
                write!(f, "request {r} has no kv allocation")
            }
            KvError::UnknownSlot(s) => write!(f, "slot {s} out of range"),
            KvError::SlotFree(s) => write!(f, "slot {s} is free"),
            KvError::MaxSeq { pos, max_seq } => {
                write!(f, "position {pos} reaches max_seq {max_seq}")
            }
            KvError::RewindForward { from, to } => {
                write!(f, "rewind forward ({to} > {from})")
            }
        }
    }
}

impl std::error::Error for KvError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pages_for_rounds_up() {
        assert_eq!(pages_for(0, 16), 0);
        assert_eq!(pages_for(1, 16), 1);
        assert_eq!(pages_for(16, 16), 1);
        assert_eq!(pages_for(17, 16), 2);
        assert_eq!(pages_for(5, 1), 5);
    }

    #[test]
    fn errors_render_and_compare() {
        let e = KvError::CapacityExhausted { needed: 3, available: 1 };
        assert!(e.to_string().contains("need 3"));
        assert_eq!(e, KvError::CapacityExhausted { needed: 3, available: 1 });
        assert_ne!(e, KvError::NoFreeSlot);
        // KvError converts into anyhow::Error via `?` in worker code.
        let any: anyhow::Error = KvError::NoFreeSlot.into();
        assert!(any.downcast_ref::<KvError>().is_some());
    }
}
