//! The pool manager: block tables over a shared page budget, prefix
//! sharing with copy-on-write, LRU eviction, capacity-aware admission
//! views, and preemption.
//!
//! Lifecycle of a request's KV:
//!
//! ```text
//! alloc(tokens)   — match the longest cached full-block prefix
//!                   (retain shared pages), claim fresh pages for the
//!                   rest (evicting LRU cached prefixes under
//!                   pressure), register fresh full blocks for future
//!                   sharing.
//! advance(token)  — append one decode position: new page on a block
//!                   boundary, copy-on-write fork before overwriting a
//!                   shared page, cache invalidation when the sole
//!                   owner diverges from a cached block.
//! fork(child)     — beam split: the child table references every one
//!                   of the parent's pages (refcount bump, zero KV
//!                   copied); divergence pays one COW page at the
//!                   first overwritten shared block. Beam reorder is
//!                   fork + prune, not a cache gather.
//! rewind_to(pos)  — LayerSkip rollback; pages are kept (overwrite
//!                   semantics, like the dense slot view).
//! release_discard — prune a dead beam: drop refs *without* publishing
//!                   its blocks, so an abandoned hypothesis leaves the
//!                   prefix cache exactly as it found it.
//! release()       — register finished full blocks, then drop refs;
//!                   zero-ref hashed pages park on the cache LRU,
//!                   the rest return to the free list.
//! preempt(mode)   — evict the latest-admitted sequence when decode
//!                   outgrows the pool: Recompute drops its pages and
//!                   the caller re-prefills on readmission; SwapOut
//!                   additionally ledgers the sequence for
//!                   `resume_swapped` (host-side copy accounting).
//! ```

use std::collections::HashMap;

use crate::perfmodel::fabric::{FabricSpec, LinkKind};
use crate::substrate::table::Table;

use super::block::{PageId, PageState};
use super::hostbuf::HostBufferPool;
use super::prefix::{block_hashes, PrefixCache};
use super::shard::{ShardId, ShardView, ShardedBlockPool};
use super::table::BlockTable;
use super::{pages_for, KvError, DEFAULT_PAGE_SIZE};

/// Pool sizing knobs carried by `RouterConfig`.
#[derive(Debug, Clone, Copy)]
pub struct KvPoolConfig {
    /// Tokens per page. 0 disables paging (dense slot admission only).
    pub page_size: usize,
    /// Total page budget. 0 = dense-equivalent: `batch` full sequences.
    pub total_pages: usize,
    /// Simulated device arenas the budget is split across (`--shards`;
    /// 1 = the monolithic single-arena pool, bit-identical to the
    /// pre-shard behavior).
    pub shards: usize,
}

impl Default for KvPoolConfig {
    fn default() -> Self {
        KvPoolConfig {
            page_size: DEFAULT_PAGE_SIZE,
            total_pages: 0,
            shards: 1,
        }
    }
}

impl KvPoolConfig {
    pub fn enabled(&self) -> bool {
        self.page_size > 0
    }

    /// Resolve the page budget for a decode batch of `batch` slots.
    pub fn resolve_pages(&self, batch: usize, max_seq: usize) -> usize {
        if self.total_pages > 0 {
            self.total_pages
        } else {
            batch * pages_for(max_seq, self.page_size)
        }
    }
}

/// Counters the telemetry report and `mmserve kv` print.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PoolStats {
    pub prefix_lookups: u64,
    pub prefix_hits: u64,
    pub prefix_hit_tokens: u64,
    pub blocks_allocated: u64,
    pub blocks_freed: u64,
    pub evictions: u64,
    pub cow_forks: u64,
    /// Beam splits served as block-table forks (refcount bumps) — the
    /// pages a dense beam reorder would have copied are shared instead.
    pub beam_forks: u64,
    pub preemptions: u64,
    pub swapped_out_tokens: u64,
    /// Scheduler ticks where admission was blocked on KV capacity —
    /// the counter behind the `KvCapacity` idle-attribution bucket.
    pub capacity_wait_ticks: u64,
    pub seqs_admitted: u64,
    /// Fresh-page claims per device shard (index = shard id) — the
    /// per-shard occupancy counters the telemetry/report path and the
    /// routing snapshot surface. Sized to the pool's shard count at
    /// construction (length 1 for a monolithic pool; empty only for a
    /// default-constructed stats block, e.g. the dense baseline).
    pub shard_allocated: Vec<u64>,
    /// Fresh pages that could not be placed on the preferred (home)
    /// shard and spilled to another arena — the cross-device traffic a
    /// real TP allocator would pay a gather for.
    pub shard_spills: u64,
    /// KV bytes those spills move over the intra-node link (0 without
    /// a priced fabric) — what sizes the explain spill band.
    pub spill_bytes: u64,
    /// Cost-aware preemptions that chose the host swap path.
    pub swap_decisions: u64,
    /// Cost-aware preemptions that chose to drop-and-recompute
    /// (including swap fallbacks the host budget refused).
    pub recompute_decisions: u64,
    /// Host swap-buffer bytes reserved / released (mirrors the
    /// [`super::hostbuf::HostBufferPool`] lifetime counters so fleet
    /// aggregation is a plain merge).
    pub host_bytes_reserved: u64,
    pub host_bytes_released: u64,
}

impl PoolStats {
    /// Fraction of full-block lookups served from the prefix cache.
    pub fn hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            return 0.0;
        }
        self.prefix_hits as f64 / self.prefix_lookups as f64
    }

    /// Page alloc + free traffic (the hot-path cost the bench tracks).
    pub fn block_churn(&self) -> u64 {
        self.blocks_allocated + self.blocks_freed
    }

    /// Fold another worker's counters into this one. Fleet-wide rates
    /// must be computed from *summed* numerators and denominators —
    /// averaging per-worker `hit_rate()` values weights an idle worker
    /// the same as a busy one (the `mmserve kv` labeling bug).
    pub fn merge(&mut self, other: &PoolStats) {
        self.prefix_lookups += other.prefix_lookups;
        self.prefix_hits += other.prefix_hits;
        self.prefix_hit_tokens += other.prefix_hit_tokens;
        self.blocks_allocated += other.blocks_allocated;
        self.blocks_freed += other.blocks_freed;
        self.evictions += other.evictions;
        self.cow_forks += other.cow_forks;
        self.beam_forks += other.beam_forks;
        self.preemptions += other.preemptions;
        self.swapped_out_tokens += other.swapped_out_tokens;
        self.capacity_wait_ticks += other.capacity_wait_ticks;
        self.seqs_admitted += other.seqs_admitted;
        if self.shard_allocated.len() < other.shard_allocated.len() {
            self.shard_allocated.resize(other.shard_allocated.len(), 0);
        }
        for (i, v) in other.shard_allocated.iter().enumerate() {
            self.shard_allocated[i] += v;
        }
        self.shard_spills += other.shard_spills;
        self.spill_bytes += other.spill_bytes;
        self.swap_decisions += other.swap_decisions;
        self.recompute_decisions += other.recompute_decisions;
        self.host_bytes_reserved += other.host_bytes_reserved;
        self.host_bytes_released += other.host_bytes_released;
    }

    /// Aggregate per-worker counters into one fleet-wide view.
    pub fn aggregate<'a, I>(stats: I) -> PoolStats
    where
        I: IntoIterator<Item = &'a PoolStats>,
    {
        let mut out = PoolStats::default();
        for s in stats {
            out.merge(s);
        }
        out
    }

    pub fn render(&self) -> String {
        let mut t = Table::new(&["counter", "value"]);
        t.row(&["prefix lookups".into(), self.prefix_lookups.to_string()]);
        t.row(&["prefix hits".into(), self.prefix_hits.to_string()]);
        t.row(&[
            "prefix hit rate".into(),
            format!("{:.1}%", self.hit_rate() * 100.0),
        ]);
        t.row(&[
            "prefix hit tokens".into(),
            self.prefix_hit_tokens.to_string(),
        ]);
        t.row(&["blocks allocated".into(), self.blocks_allocated.to_string()]);
        t.row(&["blocks freed".into(), self.blocks_freed.to_string()]);
        t.row(&["block churn".into(), self.block_churn().to_string()]);
        t.row(&["evictions (LRU)".into(), self.evictions.to_string()]);
        t.row(&["copy-on-write forks".into(), self.cow_forks.to_string()]);
        // Beam-search pools only: absent from chat-only runs so the
        // legacy table stays verbatim.
        if self.beam_forks > 0 {
            t.row(&["beam forks".into(), self.beam_forks.to_string()]);
        }
        t.row(&["preemptions".into(), self.preemptions.to_string()]);
        t.row(&[
            "swapped-out tokens".into(),
            self.swapped_out_tokens.to_string(),
        ]);
        t.row(&[
            "capacity-wait ticks".into(),
            self.capacity_wait_ticks.to_string(),
        ]);
        t.row(&["sequences admitted".into(), self.seqs_admitted.to_string()]);
        if self.shard_allocated.len() > 1 {
            t.row(&[
                "page allocs per shard".into(),
                self.shard_allocated
                    .iter()
                    .map(|c| c.to_string())
                    .collect::<Vec<_>>()
                    .join("/"),
            ]);
            t.row(&[
                "shard spills".into(),
                self.shard_spills.to_string(),
            ]);
        }
        // Priced-fabric counters: only rendered once a fabric has
        // actually charged something, so unpriced runs keep the
        // legacy table verbatim.
        if self.swap_decisions + self.recompute_decisions > 0 {
            t.row(&[
                "swap / recompute decisions".into(),
                format!("{}/{}", self.swap_decisions,
                        self.recompute_decisions),
            ]);
        }
        if self.host_bytes_reserved > 0 {
            t.row(&[
                "host swap bytes (reserved/released)".into(),
                format!("{}/{}", self.host_bytes_reserved,
                        self.host_bytes_released),
            ]);
        }
        if self.spill_bytes > 0 {
            t.row(&["shard spill bytes".into(),
                    self.spill_bytes.to_string()]);
        }
        t.render()
    }
}

/// What to do with a preemption victim's KV.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreemptMode {
    /// Drop the pages; the scheduler re-prefills prompt + generated
    /// tokens on readmission (compute pays, no transfer).
    Recompute,
    /// Drop the pages but ledger the sequence host-side; `resume_swapped`
    /// reallocates it (transfer pays, no recompute).
    SwapOut,
}

/// A preempted sequence, returned to the scheduler for requeueing.
#[derive(Debug, Clone)]
pub struct Preempted {
    pub request: u64,
    /// Full token history (prompt + generated) at preemption time.
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    pub mode: PreemptMode,
}

/// Page-budget half of a capacity view (absent in dense mode).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PageBudget {
    pub page_size: usize,
    /// Free pages plus evictable cached pages. For a sharded pool this
    /// is the *sum of per-shard headroom* ([`KvPool::shard_views`]):
    /// pages spill across arenas, so the aggregate is exactly what the
    /// tick planner can gate chunks against.
    pub available_pages: usize,
    /// Growth watermark: one lookahead page per live sequence, so
    /// admission stays optimistic and preemption handles the tail.
    pub reserved_growth: usize,
    /// Device arenas behind the budget (1 = monolithic).
    pub shards: usize,
}

/// What the batcher admits against each tick: slots (the compiled
/// graph's fixed batch) plus, when paging is on, the page budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CapacityView {
    pub free_slots: usize,
    pub live_slots: usize,
    pub pages: Option<PageBudget>,
}

impl CapacityView {
    /// Slot-only view — the dense `KvSlots` admission of the seed.
    pub fn dense(free_slots: usize, live_slots: usize) -> Self {
        CapacityView { free_slots, live_slots, pages: None }
    }

    /// Pages a `prompt_len` admission claims, worst-case (no sharing,
    /// +1 position for the first decode token).
    pub fn pages_needed(&self, prompt_len: usize) -> usize {
        match &self.pages {
            Some(p) => pages_for(prompt_len + 1, p.page_size),
            None => 0,
        }
    }
}

/// The paged KV-cache pool.
///
/// # Examples
///
/// The full page lifecycle — allocation, a beam-style fork that shares
/// every page, the copy-on-write split the fork pays at its first
/// divergence, and the two release flavors (publish vs discard):
///
/// ```
/// use mmserve::kvpool::KvPool;
///
/// let mut pool = KvPool::new(8, 4, 64); // 8 pages of 4 tokens
/// let out = pool.alloc(0, &[10, 11, 12, 13, 14])?;
/// assert_eq!(out.pages, 2); // 5 tokens → 2 pages
///
/// // A beam hypothesis forks the table: every page is shared, no
/// // copy happens yet.
/// assert_eq!(pool.fork(0, 1)?, 2);
/// assert_eq!(pool.live_pages(), 2);
///
/// // The hypothesis diverges inside the shared tail page: exactly
/// // one page is copy-on-write split.
/// pool.advance(1, 42)?;
/// assert_eq!(pool.live_pages(), 3);
/// assert_eq!(pool.stats.cow_forks, 1);
///
/// // Pruning the hypothesis frees only its private page; releasing
/// // the root publishes its full pages into the prefix cache.
/// pool.release_discard(1)?;
/// assert_eq!(pool.live_pages(), 2);
/// pool.release(0)?;
/// assert!(pool.check_invariants().is_ok());
/// # Ok::<(), mmserve::kvpool::KvError>(())
/// ```
#[derive(Debug, Clone)]
pub struct KvPool {
    blocks: ShardedBlockPool,
    cache: PrefixCache,
    tables: HashMap<u64, BlockTable>,
    /// Swapped-out sequences awaiting `resume_swapped`, staged in
    /// byte-accounted host buffers.
    host: HostBufferPool,
    /// Transfer pricing for spills / swaps; `None` (and the zero-cost
    /// spec) reproduce the unpriced legacy decisions bit for bit.
    fabric: Option<FabricSpec>,
    max_seq: usize,
    next_seq: u64,
    pub stats: PoolStats,
}

/// Outcome of one allocation (the admission-side sharing report).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AllocOutcome {
    pub pages: usize,
    pub shared_pages: usize,
    pub shared_tokens: usize,
}

impl KvPool {
    pub fn new(total_pages: usize, page_size: usize, max_seq: usize) -> Self {
        KvPool::with_shards(total_pages, page_size, max_seq, 1)
    }

    /// Pool with its page budget split across `shards` device arenas
    /// (`shards == 1` is the monolithic pool, bit for bit).
    pub fn with_shards(total_pages: usize, page_size: usize,
                       max_seq: usize, shards: usize) -> Self {
        KvPool {
            blocks: ShardedBlockPool::new(total_pages, page_size, shards),
            cache: PrefixCache::new(),
            tables: HashMap::new(),
            host: HostBufferPool::unbounded(),
            fabric: None,
            max_seq,
            next_seq: 0,
            stats: PoolStats {
                shard_allocated: vec![0; shards.max(1)],
                ..PoolStats::default()
            },
        }
    }

    /// Pool for a `batch`-slot decode graph under `cfg`.
    pub fn for_batch(batch: usize, max_seq: usize, cfg: KvPoolConfig)
                     -> Self {
        KvPool::with_shards(cfg.resolve_pages(batch, max_seq),
                            cfg.page_size, max_seq, cfg.shards.max(1))
    }

    /// Pool sized for a single dense sequence (the bs=1 decode loops).
    pub fn solo(max_seq: usize) -> Self {
        KvPool::new(pages_for(max_seq, DEFAULT_PAGE_SIZE),
                    DEFAULT_PAGE_SIZE, max_seq)
    }

    pub fn page_size(&self) -> usize {
        self.blocks.page_size()
    }
    pub fn max_seq(&self) -> usize {
        self.max_seq
    }
    pub fn total_pages(&self) -> usize {
        self.blocks.total()
    }
    pub fn free_pages(&self) -> usize {
        self.blocks.free_count()
    }
    pub fn live_pages(&self) -> usize {
        self.blocks.live_count()
    }
    pub fn cached_pages(&self) -> usize {
        self.blocks.cached_count()
    }
    pub fn live_seqs(&self) -> usize {
        self.tables.len()
    }
    /// Device arenas the page budget is split across (1 = monolithic).
    pub fn shards(&self) -> usize {
        self.blocks.shards()
    }
    /// Shard owning a global page id.
    pub fn shard_of(&self, pid: PageId) -> ShardId {
        self.blocks.shard_of(pid)
    }
    /// Lifecycle state of a page (test/report hook — block tables must
    /// only ever reference `Live` pages).
    pub fn page_state(&self, pid: PageId) -> PageState {
        self.blocks.state(pid)
    }

    /// Attach a priced transfer fabric: from here on spills are
    /// byte-costed, swap-outs reserve real host buffers against the
    /// fabric's capacity, and [`KvPool::preempt_auto`] trades swap
    /// against recompute by modeled nanoseconds. The zero-cost fabric
    /// ties every comparison, and ties break toward the legacy
    /// behavior — bit-identical to an unpriced pool.
    pub fn set_fabric(&mut self, fabric: FabricSpec) {
        self.host.set_capacity(fabric.host_capacity_bytes);
        self.fabric = Some(fabric);
    }

    pub fn fabric(&self) -> Option<&FabricSpec> {
        self.fabric.as_ref()
    }

    /// The host swap-buffer pool (byte accounting + conservation).
    pub fn host_buffers(&self) -> &HostBufferPool {
        &self.host
    }

    /// Is `request` staged host-side awaiting [`KvPool::resume_swapped`]?
    pub fn has_swapped(&self, request: u64) -> bool {
        self.host.contains(request)
    }

    /// Tokens a swapped-out request would resume with.
    pub fn swapped_tokens(&self, request: u64) -> Option<usize> {
        self.host.get(request).map(|b| b.tokens.len())
    }

    /// Crash teardown: release every host buffer this pool holds (a
    /// dead replica's swapped requests are re-routed from their
    /// prompts; the bytes must return to the budget, not leak).
    pub fn drain_host_buffers(&mut self) -> u64 {
        let freed = self.host.drain();
        self.stats.host_bytes_released += freed;
        freed
    }

    /// Per-shard capacity counters — the per-shard `CapacityView`s the
    /// worker republishes (occupancy telemetry, routing snapshot, the
    /// `mmserve kv` shard table). Their summed headroom is exactly the
    /// aggregate `available_pages` admission gates on.
    pub fn shard_views(&self) -> Vec<ShardView> {
        self.blocks.views()
    }

    /// The shard a sequence's decode growth prefers: the arena of its
    /// final mapped page (`None` for an unknown or pageless request).
    pub fn growth_shard(&self, request: u64) -> Option<ShardId> {
        self.tables
            .get(&request)
            .and_then(|t| t.last_page())
            .map(|p| self.blocks.shard_of(p))
    }

    pub fn has_table(&self, request: u64) -> bool {
        self.tables.contains_key(&request)
    }

    pub fn table(&self, request: u64) -> Option<&BlockTable> {
        self.tables.get(&request)
    }

    /// Fill position of a live sequence.
    pub fn pos(&self, request: u64) -> Result<usize, KvError> {
        self.tables
            .get(&request)
            .map(|t| t.pos())
            .ok_or(KvError::UnknownRequest(request))
    }

    /// Admit a sequence: share the longest cached full-block prefix,
    /// claim fresh pages for the rest. Rolls back cleanly (no page
    /// leak) when the budget cannot cover the remainder.
    pub fn alloc(&mut self, request: u64, tokens: &[i32])
                 -> Result<AllocOutcome, KvError> {
        if self.tables.contains_key(&request) {
            return Err(KvError::DuplicateRequest(request));
        }
        let n = tokens.len();
        if n >= self.max_seq {
            return Err(KvError::MaxSeq { pos: n, max_seq: self.max_seq });
        }
        let ps = self.blocks.page_size();
        let total_blocks = pages_for(n, ps);
        let hashes = block_hashes(tokens, ps);

        // Phase 1: longest cached prefix (stops at the first miss —
        // chain hashes make any later match impossible anyway).
        let mut pages: Vec<PageId> = Vec::with_capacity(total_blocks);
        let mut shared = 0usize;
        for &h in &hashes {
            self.stats.prefix_lookups += 1;
            let Some(pid) = self.cache.lookup(h) else { break };
            match self.blocks.state(pid) {
                PageState::Live => self.blocks.retain(pid),
                PageState::Cached => {
                    self.cache.reuse(pid);
                    self.blocks.unpark(pid);
                }
                PageState::Free => {
                    unreachable!("cached hash maps to a free page")
                }
            }
            pages.push(pid);
            shared += 1;
            self.stats.prefix_hits += 1;
        }
        self.stats.prefix_hit_tokens += (shared * ps) as u64;

        // Phase 2: fresh pages for the remainder. The home shard is
        // wherever the shared prefix already sits (or the emptiest
        // arena for a cold prompt); each claimed page becomes the next
        // one's preference so a sequence stays co-located until its
        // arena runs dry and the claim spills.
        let mut prefer = pages.last().map(|&p| self.blocks.shard_of(p));
        for i in shared..total_blocks {
            match self.grab_page(prefer) {
                Some(pid) => {
                    prefer = Some(self.blocks.shard_of(pid));
                    if i < hashes.len() {
                        // Full prompt block: publish for future sharing.
                        self.cache.insert(hashes[i], pid);
                    }
                    pages.push(pid);
                }
                None => {
                    let needed = total_blocks - pages.len();
                    let available =
                        self.blocks.available(self.cache.evictable());
                    // Roll back: shared pages return to the cache LRU,
                    // fresh ones to the free list.
                    for (idx, &pid) in pages.iter().enumerate() {
                        self.release_page_ref(pid, idx < shared);
                    }
                    return Err(KvError::CapacityExhausted {
                        needed,
                        available,
                    });
                }
            }
        }

        self.stats.seqs_admitted += 1;
        let seq = self.next_seq;
        self.next_seq += 1;
        self.tables.insert(
            request,
            BlockTable::new(request, tokens.to_vec(), pages, seq, shared),
        );
        Ok(AllocOutcome {
            pages: total_blocks,
            shared_pages: shared,
            shared_tokens: shared * ps,
        })
    }

    /// Append one decode token: grow onto a new page at a block
    /// boundary; fork (copy-on-write) before overwriting a shared
    /// page; invalidate the cache entry when a sole owner diverges.
    pub fn advance(&mut self, request: u64, token: i32)
                   -> Result<usize, KvError> {
        let ps = self.blocks.page_size();
        let (pos, cur_page) = {
            let t = self
                .tables
                .get(&request)
                .ok_or(KvError::UnknownRequest(request))?;
            (t.pos(), t.page_at(t.pos() / ps))
        };
        if pos + 1 >= self.max_seq {
            return Err(KvError::MaxSeq { pos, max_seq: self.max_seq });
        }
        let block_idx = pos / ps;
        match cur_page {
            None => {
                // Grow onto the sequence's home shard (its last page's
                // arena), spilling when that arena is dry.
                let prefer = self
                    .tables
                    .get(&request)
                    .and_then(|t| t.last_page())
                    .map(|p| self.blocks.shard_of(p));
                let pid = self.grab_page(prefer).ok_or(
                    KvError::CapacityExhausted { needed: 1, available: 0 },
                )?;
                self.tables.get_mut(&request).unwrap().push_page(pid);
            }
            Some(pid) => {
                if self.blocks.refs(pid) > 1 {
                    // Shared page about to be overwritten: fork. The
                    // device-side analogue is a page copy; here the
                    // table's own token history is the content. The
                    // fork prefers the original's shard (the copy a
                    // real allocator would keep device-local).
                    let prefer = Some(self.blocks.shard_of(pid));
                    let fresh = self.grab_page(prefer).ok_or(
                        KvError::CapacityExhausted {
                            needed: 1,
                            available: 0,
                        },
                    )?;
                    self.blocks.release(pid); // refs > 1 ⇒ stays live
                    self.tables
                        .get_mut(&request)
                        .unwrap()
                        .remap(block_idx, fresh);
                    self.stats.cow_forks += 1;
                } else if self.cache.contains_page(pid) {
                    // Sole owner diverging from the published content.
                    self.cache.invalidate(pid);
                }
            }
        }
        let t = self.tables.get_mut(&request).unwrap();
        t.push_token(token);
        Ok(t.pos())
    }

    /// Chunked-prefill append: extend a live sequence by a whole chunk
    /// of tokens, claiming pages block by block. All-or-nothing at the
    /// position level: on failure the fill position rewinds to where
    /// it was (pages the partial extension claimed stay mapped —
    /// overwrite semantics, exactly like a LayerSkip rewind — and are
    /// reclaimed at release/preemption). Returns the new position.
    pub fn extend(&mut self, request: u64, tokens: &[i32])
                  -> Result<usize, KvError> {
        let start = self.pos(request)?;
        for &t in tokens {
            if let Err(e) = self.advance(request, t) {
                let _ = self.rewind_to(request, start);
                return Err(e);
            }
        }
        Ok(start + tokens.len())
    }

    /// LayerSkip rollback: lower the fill position, keep the pages.
    pub fn rewind_to(&mut self, request: u64, new_pos: usize)
                     -> Result<(), KvError> {
        let t = self
            .tables
            .get_mut(&request)
            .ok_or(KvError::UnknownRequest(request))?;
        let from = t.pos();
        if new_pos > from {
            return Err(KvError::RewindForward { from, to: new_pos });
        }
        t.rewind_to(new_pos);
        Ok(())
    }

    /// Finish a sequence: publish its full blocks, then drop refs.
    pub fn release(&mut self, request: u64) -> Result<(), KvError> {
        let t = self
            .tables
            .remove(&request)
            .ok_or(KvError::UnknownRequest(request))?;
        self.finish_table(t);
        Ok(())
    }

    /// Beam split: admit `child` as a block-table fork of `parent` —
    /// every parent page gains one reference, zero KV is copied. The
    /// whole parent history counts as the child's shared prefix, so
    /// the first divergent append pays exactly one copy-on-write page
    /// (the paper's Obs #4 fix expressed in pages: beam reorder is a
    /// refcount bump, not a cache gather). Returns the page count
    /// shared. The fork inherits the parent's fill position; use
    /// [`KvPool::rewind_to`] on the child to re-split from an earlier
    /// position.
    pub fn fork(&mut self, parent: u64, child: u64)
                -> Result<usize, KvError> {
        if self.tables.contains_key(&child) {
            return Err(KvError::DuplicateRequest(child));
        }
        let (tokens, pages, prompt_len) = {
            let t = self
                .tables
                .get(&parent)
                .ok_or(KvError::UnknownRequest(parent))?;
            (t.tokens().to_vec(), t.pages().to_vec(), t.prompt_len)
        };
        for &pid in &pages {
            // Every table-mapped page is Live (the table invariant),
            // so the bump can never resurrect a cached page.
            self.blocks.retain(pid);
        }
        let shared = pages.len();
        let seq = self.next_seq;
        self.next_seq += 1;
        let mut t = BlockTable::new(child, tokens, pages, seq, shared);
        t.prompt_len = prompt_len;
        self.tables.insert(child, t);
        self.stats.beam_forks += 1;
        Ok(shared)
    }

    /// Prune a dead beam: drop the table's page references *without*
    /// publishing its blocks to the prefix cache. Pages the fork
    /// still shares with a live sibling keep their references; COW
    /// pages the dead hypothesis claimed for itself were never hashed,
    /// so they return straight to the free list — the cache is left
    /// bit-identical to its pre-fork state (the property test's
    /// contract). Finished *winning* beams go through
    /// [`KvPool::release`], which does publish.
    pub fn release_discard(&mut self, request: u64) -> Result<(), KvError> {
        let t = self
            .tables
            .remove(&request)
            .ok_or(KvError::UnknownRequest(request))?;
        let (pages, _tokens, _prompt_len) = t.into_parts();
        for &pid in &pages {
            // `cacheable: true` parks a page that already has a hash
            // entry (a prefix block another request published) instead
            // of invalidating it — discarding must not shrink the
            // cache. Unhashed pages fail `park` and free.
            self.release_page_ref(pid, true);
        }
        Ok(())
    }

    /// Evict the latest-admitted live sequence to relieve pressure.
    /// Its full blocks stay cached (evictable), so a prompt resume hits
    /// the prefix cache when pressure has eased.
    pub fn preempt(&mut self, mode: PreemptMode) -> Option<Preempted> {
        let victim = self.tables.values().max_by_key(|t| t.seq)?.request;
        self.evict_seq(victim, mode)
    }

    /// Shard-aware victim selection: evict the latest-admitted
    /// sequence holding at least one page on `shard`, so the freed
    /// capacity lands on the arena the grower prefers (its next claim
    /// stays device-local instead of spilling). Falls back to the
    /// global latest-first rule when no sequence touches the shard.
    /// With one shard this is exactly [`KvPool::preempt`].
    pub fn preempt_on_shard(&mut self, mode: PreemptMode, shard: ShardId)
                            -> Option<Preempted> {
        let blocks = &self.blocks;
        let victim = self
            .tables
            .values()
            .filter(|t| {
                t.pages().iter().any(|&p| blocks.shard_of(p) == shard)
            })
            .max_by_key(|t| t.seq)
            .or_else(|| self.tables.values().max_by_key(|t| t.seq))
            .map(|t| t.request)?;
        self.evict_seq(victim, mode)
    }

    /// Cost-aware preemption: choose the victim *and* the mode by
    /// modeled eviction cost. Each live sequence is priced at
    /// `min(swap round-trip over the host link, recompute)` — a swap
    /// the host budget cannot stage prices as unswappable — and the
    /// cheapest eviction wins, tie-breaking to the latest admission
    /// (the legacy victim rule). The winner swaps out only when its
    /// swap is *strictly* cheaper than its recompute, so the zero-cost
    /// fabric (all ties) reproduces `preempt(Recompute)` /
    /// `preempt_on_shard(Recompute, s)` bit for bit — as does a pool
    /// with no fabric at all.
    pub fn preempt_auto(&mut self, prefer: Option<ShardId>)
                        -> Option<Preempted> {
        let fabric = match self.fabric {
            Some(f) if !f.is_free() => f,
            _ => {
                return match prefer {
                    Some(s) if self.blocks.shards() > 1 => {
                        self.preempt_on_shard(PreemptMode::Recompute, s)
                    }
                    _ => self.preempt(PreemptMode::Recompute),
                };
            }
        };
        // Same candidate set as the unpriced rules: holders of the
        // pressured shard when one is named (global fallback when
        // nobody touches it), everyone otherwise.
        let blocks = &self.blocks;
        let on_shard = |t: &&BlockTable| match prefer {
            Some(s) => {
                t.pages().iter().any(|&p| blocks.shard_of(p) == s)
            }
            None => false,
        };
        let holders: Vec<&BlockTable> =
            self.tables.values().filter(on_shard).collect();
        let set: Vec<&BlockTable> = if holders.is_empty() {
            self.tables.values().collect()
        } else {
            holders
        };
        // cost, admission seq, request, mode of the best victim.
        let mut best: Option<(f64, u64, u64, PreemptMode)> = None;
        for t in set {
            let len = t.tokens().len();
            let bytes = fabric.bytes_for_tokens(len);
            let swap = if self.host.can_reserve(bytes) {
                2.0 * fabric.swap_cost(len)
            } else {
                f64::INFINITY
            };
            let recompute = fabric.recompute_cost(len);
            let (cost, mode) = if swap < recompute {
                (swap, PreemptMode::SwapOut)
            } else {
                (recompute, PreemptMode::Recompute)
            };
            let better = match best {
                None => true,
                Some((bc, bseq, _, _)) => {
                    cost < bc || (cost == bc && t.seq > bseq)
                }
            };
            if better {
                best = Some((cost, t.seq, t.request, mode));
            }
        }
        let (_, _, victim, mode) = best?;
        match mode {
            PreemptMode::SwapOut => self.stats.swap_decisions += 1,
            PreemptMode::Recompute => self.stats.recompute_decisions += 1,
        }
        self.evict_seq(victim, mode)
    }

    /// Shared preemption teardown: remove the victim's table, park its
    /// full blocks, stage it in a host buffer when swapping out. A
    /// swap the host budget refuses degrades to Recompute (the caller
    /// reads the actual mode off the returned [`Preempted`]).
    fn evict_seq(&mut self, victim: u64, mode: PreemptMode)
                 -> Option<Preempted> {
        let t = self.tables.remove(&victim)?;
        let tokens = t.tokens().to_vec();
        let prompt_len = t.prompt_len;
        self.finish_table(t);
        self.stats.preemptions += 1;
        let mut mode = mode;
        if mode == PreemptMode::SwapOut {
            let bytes = self
                .fabric
                .map_or(0, |f| f.bytes_for_tokens(tokens.len()));
            if self
                .host
                .reserve(victim, tokens.clone(), prompt_len, bytes)
                .is_ok()
            {
                self.stats.swapped_out_tokens += tokens.len() as u64;
                self.stats.host_bytes_reserved += bytes;
            } else {
                mode = PreemptMode::Recompute;
            }
        }
        Some(Preempted { request: victim, tokens, prompt_len, mode })
    }

    /// Bring a swapped-out sequence back (the swap-in): reallocates its
    /// pages, sharing whatever prefix blocks survived in the cache, and
    /// releases the host buffer. On failure the buffer stays staged.
    pub fn resume_swapped(&mut self, request: u64)
                          -> Result<AllocOutcome, KvError> {
        let (tokens, prompt_len) = self
            .host
            .get(request)
            .map(|b| (b.tokens.clone(), b.prompt_len))
            .ok_or(KvError::UnknownRequest(request))?;
        let out = self.alloc(request, &tokens)?;
        self.tables.get_mut(&request).unwrap().prompt_len = prompt_len;
        let buf = self.host.release(request).expect("buffer just peeked");
        self.stats.host_bytes_released += buf.bytes;
        Ok(out)
    }

    /// Abandon a staged swap (the caller decided to recompute after
    /// all — e.g. a wedged swap-in, or a mid-prefill victim whose
    /// suffix the buffer cannot restore): the bytes return to the
    /// budget and the token history is handed back for requeueing.
    pub fn discard_swapped(&mut self, request: u64)
                           -> Option<(Vec<i32>, usize)> {
        let buf = self.host.release(request)?;
        self.stats.host_bytes_released += buf.bytes;
        Some((buf.tokens, buf.prompt_len))
    }

    /// The admission view for this tick: slots plus page budget. The
    /// page headroom is the per-shard headroom summed — pages spill
    /// across arenas, so the sum is exactly what a tick plan can be
    /// granted (`available_pages == Σ shard_views().headroom()`).
    pub fn capacity_view(&self, free_slots: usize, live_slots: usize)
                         -> CapacityView {
        CapacityView {
            free_slots,
            live_slots,
            pages: Some(PageBudget {
                page_size: self.blocks.page_size(),
                available_pages: self
                    .blocks
                    .available(self.cache.evictable()),
                reserved_growth: self.tables.len(),
                shards: self.blocks.shards(),
            }),
        }
    }

    /// Note one scheduler tick spent blocked on KV capacity.
    pub fn note_capacity_wait(&mut self) {
        self.stats.capacity_wait_ticks += 1;
    }

    /// Cheap read-only routing probe: how many leading full blocks of
    /// `tokens` are resident (live or cached) right now. Does not
    /// touch the LRU, the refcounts, or the prefix-hit counters — an
    /// admission may still miss if eviction races the probe. Defined
    /// as the block count of [`KvPool::probe_prefix_shards`] so the
    /// scalar and shard-set probes can never disagree.
    pub fn probe_prefix(&self, tokens: &[i32]) -> usize {
        self.probe_prefix_shards(tokens).0
    }

    /// The resident block-hash set — the payload a worker publishes
    /// into its routing [`crate::routing::PrefixSnapshot`] each tick.
    pub fn resident_hashes(&self) -> std::collections::HashSet<u64> {
        self.cache.hashes().collect()
    }

    /// Resident block hashes bucketed by the owning device — the
    /// per-shard halves of the routing snapshot. The union over shards
    /// equals [`KvPool::resident_hashes`].
    pub fn resident_hashes_by_shard(
        &self,
    ) -> Vec<std::collections::HashSet<u64>> {
        let mut out =
            vec![std::collections::HashSet::new(); self.blocks.shards()];
        for (h, pid) in self.cache.entries() {
            out[self.blocks.shard_of(pid)].insert(h);
        }
        out
    }

    /// Shard-set probe: like [`KvPool::probe_prefix`], but also counts
    /// the distinct device arenas holding the matched blocks — the
    /// spread a router uses to prefer a replica whose warm prefix is
    /// concentrated on fewer devices. Read-only, like `probe_prefix`.
    pub fn probe_prefix_shards(&self, tokens: &[i32]) -> (usize, usize) {
        let ps = self.blocks.page_size();
        let mut n = 0;
        let mut shards = std::collections::HashSet::new();
        for h in block_hashes(tokens, ps) {
            let Some(pid) = self.cache.lookup(h) else { break };
            shards.insert(self.blocks.shard_of(pid));
            n += 1;
        }
        (n, shards.len())
    }

    // ---- internals -------------------------------------------------

    /// Free page (preferring `prefer`'s arena, spilling when dry),
    /// else evict the LRU cached prefix, else None.
    ///
    /// With a priced fabric the home-shard choice becomes a cost
    /// decision: when the home arena is dry and a cross-shard spill
    /// would cost a strictly positive gather, a cached page *on the
    /// home shard* is evicted first so the claim stays device-local.
    /// The zero-cost fabric prices the gather at 0, skipping that
    /// branch — the legacy spill-before-evict order, bit for bit.
    fn grab_page(&mut self, prefer: Option<ShardId>) -> Option<PageId> {
        if let Some(s) = prefer {
            if let Some(pid) = self.blocks.alloc_on(s) {
                self.stats.blocks_allocated += 1;
                self.note_shard_alloc(pid, prefer);
                return Some(pid);
            }
            if self.spill_gather_cost() > 0.0 {
                let home_victim = self
                    .cache
                    .lru_pages()
                    .iter()
                    .copied()
                    .find(|&p| self.blocks.shard_of(p) == s);
                if let Some(victim) = home_victim {
                    self.cache.invalidate(victim);
                    self.blocks.evict_cached(victim);
                    self.stats.evictions += 1;
                    let pid = self
                        .blocks
                        .alloc_on(s)
                        .expect("home page just evicted");
                    self.stats.blocks_allocated += 1;
                    self.note_shard_alloc(pid, prefer);
                    return Some(pid);
                }
            }
        }
        if let Some(pid) = self.blocks.alloc_prefer(prefer) {
            self.stats.blocks_allocated += 1;
            self.note_shard_alloc(pid, prefer);
            return Some(pid);
        }
        let victim = self.cache.evict_lru()?;
        self.blocks.evict_cached(victim);
        self.stats.evictions += 1;
        let pid = self
            .blocks
            .alloc_prefer(prefer)
            .expect("page just evicted");
        self.stats.blocks_allocated += 1;
        self.note_shard_alloc(pid, prefer);
        Some(pid)
    }

    /// Modeled cost (sim units) of gathering one spilled page over the
    /// intra-node link — 0 without a fabric.
    fn spill_gather_cost(&self) -> f64 {
        self.fabric.map_or(0.0, |f| {
            f.transfer_cost(
                LinkKind::NvLink,
                f.bytes_for_pages(1, self.blocks.page_size()),
            )
        })
    }

    /// Per-shard occupancy counters: where the fresh page landed, and
    /// whether the claim spilled off its preferred arena.
    /// (`shard_allocated` is sized at construction, so this is two
    /// plain increments on the allocation hot path.)
    fn note_shard_alloc(&mut self, pid: PageId, prefer: Option<ShardId>) {
        let s = self.blocks.shard_of(pid);
        self.stats.shard_allocated[s] += 1;
        if let Some(p) = prefer {
            if p != s {
                self.stats.shard_spills += 1;
                // Priced fabric: the spilled page's KV will be
                // gathered over the intra-node link — count the bytes
                // (0 without a fabric, so legacy counters are
                // untouched).
                self.stats.spill_bytes += self.fabric.map_or(0, |f| {
                    f.bytes_for_pages(1, self.blocks.page_size())
                });
            }
        }
    }

    /// Drop one table reference; a zero-ref page parks on the cache
    /// LRU when `cacheable` and it has a hash entry, else frees.
    fn release_page_ref(&mut self, pid: PageId, cacheable: bool) {
        if self.blocks.release(pid) == 0 {
            if cacheable && self.cache.park(pid) {
                self.blocks.park_cached(pid);
            } else {
                self.cache.invalidate(pid);
                self.blocks.free_page(pid);
                self.stats.blocks_freed += 1;
            }
        }
    }

    fn finish_table(&mut self, t: BlockTable) {
        let ps = self.blocks.page_size();
        let (pages, tokens, _prompt_len) = t.into_parts();
        // Publish completed full blocks (decode-filled ones included)
        // so the next same-prefix request shares them.
        let hashes = block_hashes(&tokens, ps);
        for (i, &h) in hashes.iter().enumerate() {
            if i < pages.len() {
                self.cache.insert(h, pages[i]);
            }
        }
        let full = tokens.len() / ps;
        for (i, &pid) in pages.iter().enumerate() {
            self.release_page_ref(pid, i < full);
        }
    }

    /// The conservation + refcount invariants the property tests walk:
    /// `free + live + cached == total`, every page's refcount equals
    /// the number of block tables referencing it, and the cache LRU is
    /// exactly the set of Cached pages.
    pub fn check_invariants(&self) -> Result<(), String> {
        self.blocks.check_conservation()?;
        let mut expected: HashMap<PageId, usize> = HashMap::new();
        for t in self.tables.values() {
            for &pid in t.pages() {
                *expected.entry(pid).or_insert(0) += 1;
            }
        }
        for pid in 0..self.blocks.total() {
            let want = expected.get(&pid).copied().unwrap_or(0);
            let got = self.blocks.refs(pid);
            if want != got {
                return Err(format!(
                    "page {pid}: refcount {got} != {want} table refs"
                ));
            }
            let state = self.blocks.state(pid);
            if state == PageState::Live && want == 0 {
                return Err(format!("page {pid} live but unreferenced"));
            }
            if state != PageState::Live && want > 0 {
                return Err(format!(
                    "page {pid} {state:?} but referenced by {want} tables"
                ));
            }
        }
        for &pid in self.cache.lru_pages() {
            if self.blocks.state(pid) != PageState::Cached {
                return Err(format!("LRU page {pid} not Cached"));
            }
        }
        if self.cache.evictable() != self.blocks.cached_count() {
            return Err(format!(
                "cached mismatch: LRU {} vs pool {}",
                self.cache.evictable(),
                self.blocks.cached_count()
            ));
        }
        self.host.check_conservation()?;
        // Shard views must tile the aggregate the planner gates on:
        // summed per-shard headroom == the capacity view's pages.
        let shard_headroom: usize =
            self.shard_views().iter().map(|v| v.headroom()).sum();
        if shard_headroom != self.blocks.available(self.cache.evictable()) {
            return Err(format!(
                "per-shard headroom {} != aggregate available {}",
                shard_headroom,
                self.blocks.available(self.cache.evictable())
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prop::prop_check;
    use crate::substrate::rng::Rng;

    #[test]
    fn alloc_advance_release_roundtrip() {
        let mut p = KvPool::new(8, 4, 64);
        let out = p.alloc(1, &[1, 2, 3, 4, 5]).unwrap();
        assert_eq!(out.pages, 2);
        assert_eq!(out.shared_pages, 0);
        assert_eq!(p.pos(1).unwrap(), 5);
        assert_eq!(p.live_pages(), 2);
        // advance within the partial page, then onto a new page
        for tok in 6..=9 {
            p.advance(1, tok).unwrap();
        }
        assert_eq!(p.pos(1).unwrap(), 9);
        assert_eq!(p.table(1).unwrap().num_pages(), 3);
        p.release(1).unwrap();
        assert_eq!(p.live_pages(), 0);
        // full blocks [1..4] and [5..8] stay cached, partial one freed
        assert_eq!(p.cached_pages(), 2);
        assert_eq!(p.free_pages(), 6);
        p.check_invariants().unwrap();
    }

    #[test]
    fn shared_prefix_is_refcounted_not_copied() {
        let mut p = KvPool::new(16, 4, 64);
        let sys: Vec<i32> = (0..8).collect(); // two full blocks
        let mut a = sys.clone();
        a.extend([100, 101]);
        let mut b = sys.clone();
        b.extend([200]);
        p.alloc(1, &a).unwrap();
        let out = p.alloc(2, &b).unwrap();
        assert_eq!(out.shared_pages, 2, "system prompt blocks shared");
        assert_eq!(out.shared_tokens, 8);
        // 3 pages for a (2 full + partial) + only 1 fresh for b
        assert_eq!(p.live_pages(), 4);
        let pa = p.table(1).unwrap().pages().to_vec();
        let pb = p.table(2).unwrap().pages().to_vec();
        assert_eq!(pa[..2], pb[..2], "same physical prefix pages");
        p.check_invariants().unwrap();
        p.release(1).unwrap();
        // shared pages still live under b's reference
        assert!(p.live_pages() >= 3);
        p.release(2).unwrap();
        assert_eq!(p.live_pages(), 0);
        p.check_invariants().unwrap();
    }

    #[test]
    fn released_prefix_rehits_from_cache() {
        let mut p = KvPool::new(8, 4, 64);
        p.alloc(1, &[1, 2, 3, 4, 9]).unwrap();
        p.release(1).unwrap();
        assert_eq!(p.cached_pages(), 1);
        let out = p.alloc(2, &[1, 2, 3, 4, 7]).unwrap();
        assert_eq!(out.shared_pages, 1, "cached block revived");
        assert_eq!(p.cached_pages(), 0);
        p.check_invariants().unwrap();
    }

    #[test]
    fn capacity_exhausted_alloc_rolls_back() {
        let mut p = KvPool::new(3, 4, 64);
        p.alloc(1, &[1, 2, 3, 4, 5, 6, 7, 8]).unwrap(); // 2 pages
        let err = p.alloc(2, &[9; 10]).unwrap_err(); // needs 3
        assert!(matches!(err, KvError::CapacityExhausted { .. }), "{err}");
        assert_eq!(p.free_pages(), 1, "partial grab fully rolled back");
        assert!(!p.has_table(2));
        p.check_invariants().unwrap();
        // A fitting request still goes through afterwards.
        p.alloc(3, &[9, 9, 9]).unwrap();
        p.check_invariants().unwrap();
    }

    #[test]
    fn cow_fork_on_shared_page_overwrite() {
        let mut p = KvPool::new(16, 4, 64);
        let sys: Vec<i32> = (0..8).collect();
        p.alloc(1, &sys).unwrap();
        p.alloc(2, &sys).unwrap(); // shares both blocks
        assert_eq!(p.live_pages(), 2);
        // Request 2 rewinds into the shared second block and overwrites.
        p.rewind_to(2, 6).unwrap();
        p.advance(2, 42).unwrap();
        assert_eq!(p.stats.cow_forks, 1);
        assert_eq!(p.live_pages(), 3, "fork claimed a fresh page");
        let pa = p.table(1).unwrap().pages().to_vec();
        let pb = p.table(2).unwrap().pages().to_vec();
        assert_eq!(pa[0], pb[0]);
        assert_ne!(pa[1], pb[1], "diverged block remapped");
        assert_eq!(p.pos(1).unwrap(), 8, "sharer unaffected");
        p.check_invariants().unwrap();
    }

    #[test]
    fn lru_eviction_frees_oldest_cached_prefix() {
        let mut p = KvPool::new(2, 4, 64);
        p.alloc(1, &[1, 2, 3, 4]).unwrap();
        p.release(1).unwrap(); // block cached
        p.alloc(2, &[5, 6, 7, 8]).unwrap();
        p.release(2).unwrap(); // second block cached
        assert_eq!(p.cached_pages(), 2);
        // A new 2-page request must evict both cached prefixes.
        p.alloc(3, &[9, 9, 9, 9, 9]).unwrap();
        assert_eq!(p.stats.evictions, 2);
        assert_eq!(p.cached_pages(), 0);
        p.check_invariants().unwrap();
    }

    #[test]
    fn preempt_picks_latest_admission_and_resume_rehits() {
        let mut p = KvPool::new(8, 4, 64);
        p.alloc(10, &[1, 2, 3, 4]).unwrap();
        p.alloc(11, &[5, 6, 7, 8]).unwrap();
        let pre = p.preempt(PreemptMode::SwapOut).unwrap();
        assert_eq!(pre.request, 11, "latest admission is the victim");
        assert_eq!(pre.tokens, vec![5, 6, 7, 8]);
        assert!(!p.has_table(11));
        assert_eq!(p.stats.preemptions, 1);
        p.check_invariants().unwrap();
        // Swap-in reallocates; the full block survived in the cache.
        let out = p.resume_swapped(11).unwrap();
        assert_eq!(out.shared_pages, 1);
        assert_eq!(p.pos(11).unwrap(), 4);
        p.check_invariants().unwrap();
        assert!(p.resume_swapped(99).is_err());
    }

    /// Chunked prefill appends whole chunks through the block table,
    /// claiming pages at block boundaries; a chunk the budget cannot
    /// cover rewinds the position (no token half-applied).
    #[test]
    fn extend_appends_chunks_and_rewinds_on_capacity() {
        let mut p = KvPool::new(3, 4, 64);
        p.alloc(1, &[1, 2, 3]).unwrap(); // 1 page
        assert_eq!(p.extend(1, &[4, 5, 6, 7, 8]).unwrap(), 8);
        assert_eq!(p.pos(1).unwrap(), 8);
        assert_eq!(p.table(1).unwrap().num_pages(), 2);
        p.check_invariants().unwrap();
        // Extending by 9 needs pages beyond the 3-page budget: the
        // position must rewind to 8 (claimed pages stay mapped,
        // overwrite semantics — reclaimed at release).
        let err = p.extend(1, &[9; 9]).unwrap_err();
        assert!(matches!(err, KvError::CapacityExhausted { .. }), "{err}");
        assert_eq!(p.pos(1).unwrap(), 8, "position rewound");
        p.check_invariants().unwrap();
        // A fitting chunk still goes through afterwards.
        assert_eq!(p.extend(1, &[9, 9]).unwrap(), 10);
        assert_eq!(p.extend(99, &[1]).unwrap_err(),
                   KvError::UnknownRequest(99));
        p.release(1).unwrap();
        p.check_invariants().unwrap();
    }

    #[test]
    fn advance_errors_at_max_seq_and_when_pool_is_dry() {
        let mut p = KvPool::new(2, 4, 8);
        p.alloc(1, &[1, 2, 3, 4, 5, 6]).unwrap();
        p.advance(1, 7).unwrap(); // pos 7
        let err = p.advance(1, 8).unwrap_err();
        assert_eq!(err, KvError::MaxSeq { pos: 7, max_seq: 8 });
        // Dry pool: a second sequence can't grow past its pages.
        let mut p = KvPool::new(2, 2, 64);
        p.alloc(1, &[1, 2, 3]).unwrap(); // both pages
        p.advance(1, 4).unwrap(); // fills page 2 in place
        let err = p.advance(1, 5).unwrap_err();
        assert!(matches!(err, KvError::CapacityExhausted { .. }), "{err}");
        p.check_invariants().unwrap();
    }

    #[test]
    fn probe_prefix_sees_live_and_cached_blocks_without_mutating() {
        let mut p = KvPool::new(8, 4, 64);
        let sys: Vec<i32> = (0..8).collect(); // two full blocks
        let mut a = sys.clone();
        a.extend([100, 101]);
        p.alloc(1, &a).unwrap();
        let lookups_before = p.stats.prefix_lookups;
        // Live pages probe positively; the unique tail block misses.
        assert_eq!(p.probe_prefix(&sys), 2);
        let mut other = sys.clone();
        other.extend([7, 7, 7, 7]);
        assert_eq!(p.probe_prefix(&other), 2, "chain stops at the miss");
        assert_eq!(p.probe_prefix(&[9, 9, 9, 9]), 0);
        assert_eq!(p.stats.prefix_lookups, lookups_before,
                   "probe is not a lookup");
        // Released full blocks stay probeable from the cache LRU.
        p.release(1).unwrap();
        assert_eq!(p.probe_prefix(&sys), 2);
        assert_eq!(p.resident_hashes().len(), 2);
        p.check_invariants().unwrap();
    }

    #[test]
    fn aggregate_sums_counters_not_rates() {
        let a = PoolStats {
            prefix_lookups: 100,
            prefix_hits: 90,
            preemptions: 1,
            ..PoolStats::default()
        };
        let b = PoolStats {
            prefix_lookups: 10,
            prefix_hits: 0,
            evictions: 3,
            ..PoolStats::default()
        };
        let fleet = PoolStats::aggregate([&a, &b]);
        assert_eq!(fleet.prefix_lookups, 110);
        assert_eq!(fleet.prefix_hits, 90);
        assert_eq!(fleet.preemptions, 1);
        assert_eq!(fleet.evictions, 3);
        // 90/110, NOT the mean of 0.9 and 0.0.
        assert!((fleet.hit_rate() - 90.0 / 110.0).abs() < 1e-12);
    }

    #[test]
    fn capacity_view_reports_budget_and_watermark() {
        let mut p = KvPool::new(8, 4, 64);
        p.alloc(1, &[1, 2, 3, 4, 5]).unwrap(); // 2 pages
        let v = p.capacity_view(3, 1);
        let b = v.pages.unwrap();
        assert_eq!(b.available_pages, 6);
        assert_eq!(b.reserved_growth, 1);
        assert_eq!(b.shards, 1, "monolithic pool is one arena");
        assert_eq!(v.pages_needed(8), 3, "8+1 tokens → 3 pages");
        let d = CapacityView::dense(3, 1);
        assert_eq!(d.pages_needed(1000), 0);
    }

    /// Tentpole: a sharded pool's fresh pages land on the sequence's
    /// home arena and spill to the emptiest other shard when it runs
    /// dry — the block table spans shards, the aggregate budget stays
    /// fully admissible, and the spill is counted.
    #[test]
    fn sharded_alloc_prefers_home_and_spills() {
        let mut p = KvPool::with_shards(4, 4, 64, 2); // arenas {0,1},{2,3}
        assert_eq!(p.shards(), 2);
        let out = p.alloc(1, &[7; 12]).unwrap(); // 3 pages
        assert_eq!(out.pages, 3);
        let pages = p.table(1).unwrap().pages().to_vec();
        assert_eq!(pages, vec![0, 1, 2], "two home pages + one spill");
        assert_eq!(p.shard_of(pages[0]), 0);
        assert_eq!(p.shard_of(pages[2]), 1, "table spans shards");
        assert_eq!(p.stats.shard_spills, 1);
        assert_eq!(p.stats.shard_allocated, vec![2, 1]);
        let views = p.shard_views();
        assert_eq!(views[0].live_pages, 2);
        assert_eq!(views[0].free_pages, 0);
        assert_eq!(views[1].live_pages, 1);
        assert_eq!(views[1].free_pages, 1);
        assert_eq!(p.growth_shard(1), Some(1), "tail page's arena");
        p.check_invariants().unwrap();
        p.release(1).unwrap();
        p.check_invariants().unwrap();
    }

    /// Shard-aware preemption: the victim is the latest admission
    /// *holding pages on the pressured shard*, so the freed capacity
    /// lands where the grower wants it; a shard nobody touches falls
    /// back to the global latest-first rule.
    #[test]
    fn sharded_preempt_targets_the_holding_sequence() {
        let mut p = KvPool::with_shards(8, 4, 64, 2); // {0..4}, {4..8}
        p.alloc(1, &[1; 13]).unwrap(); // 4 pages, fills shard 0
        p.alloc(2, &[2; 5]).unwrap(); // 2 pages on shard 1
        assert!(p.table(1).unwrap().pages().iter()
            .all(|&pg| p.shard_of(pg) == 0));
        assert!(p.table(2).unwrap().pages().iter()
            .all(|&pg| p.shard_of(pg) == 1));
        // Pressure on shard 0: request 1 is its only holder, so it is
        // the victim even though request 2 was admitted later.
        let pre = p
            .preempt_on_shard(PreemptMode::Recompute, 0)
            .unwrap();
        assert_eq!(pre.request, 1);
        p.check_invariants().unwrap();
        // Nobody holds shard-0 pages now: falls back to global latest.
        let pre = p
            .preempt_on_shard(PreemptMode::Recompute, 0)
            .unwrap();
        assert_eq!(pre.request, 2, "fallback is the global rule");
        assert_eq!(p.live_seqs(), 0);
        p.check_invariants().unwrap();
    }

    /// Prefix sharing crosses shard boundaries: a resumed prompt
    /// shares cached blocks wherever they sit, the per-shard resident
    /// sets bucket the hashes by device, and the shard-set probe
    /// reports both the match length and its device spread.
    #[test]
    fn sharded_prefix_sharing_and_probe_span_shards() {
        let mut p = KvPool::with_shards(8, 4, 64, 2);
        let sys: Vec<i32> = (0..16).collect(); // 4 full blocks
        p.alloc(1, &sys).unwrap(); // 4 pages, all shard 0
        assert_eq!(p.probe_prefix_shards(&sys), (4, 1));
        p.release(1).unwrap(); // blocks parked cached on shard 0
        let mut long = sys.clone();
        long.extend(100..108); // 6 full blocks total
        p.alloc(2, &long).unwrap();
        // 4 shared (shard 0) + 2 fresh spilled onto shard 1.
        assert_eq!(p.probe_prefix_shards(&long), (6, 2));
        let by_shard = p.resident_hashes_by_shard();
        assert_eq!(by_shard.len(), 2);
        assert_eq!(by_shard[0].len(), 4);
        assert_eq!(by_shard[1].len(), 2);
        let union: std::collections::HashSet<u64> = by_shard
            .iter()
            .flat_map(|s| s.iter().copied())
            .collect();
        assert_eq!(union, p.resident_hashes());
        // The capacity view's headroom is the per-shard sum.
        let b = p.capacity_view(1, 1).pages.unwrap();
        assert_eq!(b.shards, 2);
        assert_eq!(
            b.available_pages,
            p.shard_views().iter().map(|v| v.headroom()).sum::<usize>()
        );
        p.check_invariants().unwrap();
    }

    /// Tentpole: with a priced fabric, preemption trades the swap
    /// round-trip against recompute by modeled nanoseconds — at 7B
    /// geometry the PCIe copy wins, until the host budget runs out and
    /// the decision degrades to recompute. The mix is counted.
    #[test]
    fn priced_preempt_auto_swaps_until_host_budget_refuses() {
        use crate::perfmodel::fabric::FabricSpec;
        let mut p = KvPool::new(8, 4, 64);
        let mut f = FabricSpec::paper(524_288.0); // Llama-7B B/token
        f.host_capacity_bytes = 3 << 20; // fits one 4-token victim
        p.set_fabric(f);
        p.alloc(1, &[1, 2, 3, 4]).unwrap();
        p.alloc(2, &[5, 6, 7, 8]).unwrap();
        let pre = p.preempt_auto(None).unwrap();
        assert_eq!(pre.request, 2, "equal cost → latest admission");
        assert_eq!(pre.mode, PreemptMode::SwapOut, "PCIe beats recompute");
        assert!(p.has_swapped(2));
        assert_eq!(p.host_buffers().reserved_bytes(), 4 * 524_288);
        p.check_invariants().unwrap();
        // The second victim no longer fits host-side: recompute.
        let pre = p.preempt_auto(None).unwrap();
        assert_eq!(pre.request, 1);
        assert_eq!(pre.mode, PreemptMode::Recompute);
        assert!(!p.has_swapped(1));
        assert_eq!(p.stats.swap_decisions, 1);
        assert_eq!(p.stats.recompute_decisions, 1);
        // Swap-in releases the buffer; lifetime bytes balance.
        p.resume_swapped(2).unwrap();
        assert_eq!(p.host_buffers().reserved_bytes(), 0);
        assert_eq!(p.stats.host_bytes_reserved,
                   p.stats.host_bytes_released);
        p.check_invariants().unwrap();
    }

    /// Bisimulation: the zero-cost fabric ties every comparison, and
    /// ties resolve to the legacy rule — same victim, Recompute mode,
    /// and no priced-decision counters ticking.
    #[test]
    fn zero_cost_fabric_preempts_exactly_like_no_fabric() {
        use crate::perfmodel::fabric::FabricSpec;
        let mut a = KvPool::new(8, 4, 64);
        let mut b = KvPool::new(8, 4, 64);
        b.set_fabric(FabricSpec::zero_cost());
        for p in [&mut a, &mut b] {
            p.alloc(10, &[1, 2, 3, 4]).unwrap();
            p.alloc(11, &[5, 6, 7, 8, 9]).unwrap();
        }
        let pa = a.preempt_auto(None).unwrap();
        let pb = b.preempt_auto(None).unwrap();
        assert_eq!(pa.request, pb.request);
        assert_eq!(pa.mode, PreemptMode::Recompute);
        assert_eq!(pb.mode, PreemptMode::Recompute);
        assert_eq!(a.stats.preemptions, b.stats.preemptions);
        assert_eq!(b.stats.swap_decisions, 0);
        assert_eq!(b.stats.recompute_decisions, 0,
                   "a free fabric makes no priced decision");
        assert_eq!(b.host_buffers().total_reserved(), 0);
        a.check_invariants().unwrap();
        b.check_invariants().unwrap();
    }

    /// Priced home-shard growth: when the home arena is dry and a
    /// spill would cost a real gather, the pool evicts a cached page
    /// *on the home shard* instead — the claim stays device-local.
    /// The unpriced pool spills, as before.
    #[test]
    fn priced_growth_evicts_home_cached_page_instead_of_spilling() {
        use crate::perfmodel::fabric::FabricSpec;
        let run = |fabric: Option<FabricSpec>| {
            let mut p = KvPool::with_shards(4, 4, 64, 2); // {0,1},{2,3}
            if let Some(f) = fabric {
                p.set_fabric(f);
            }
            p.alloc(1, &[1, 2, 3, 4]).unwrap(); // page 0 on shard 0
            p.release(1).unwrap(); // full block parks cached
            p.alloc(2, &[9, 9, 9]).unwrap(); // most-free → shard 1
            p.alloc(3, &[8, 8, 8]).unwrap(); // tie → shard 0 (page 1)
            p.advance(3, 7).unwrap(); // fills page 1 in place
            p.advance(3, 7).unwrap(); // needs a page; home shard 0 dry
            p.check_invariants().unwrap();
            p
        };
        let priced = run(Some(FabricSpec::paper(524_288.0)));
        let pages = priced.table(3).unwrap().pages().to_vec();
        assert_eq!(priced.shard_of(pages[1]), 0, "stayed device-local");
        assert_eq!(priced.stats.shard_spills, 0);
        assert_eq!(priced.stats.evictions, 1, "home cached page evicted");
        let legacy = run(None);
        let pages = legacy.table(3).unwrap().pages().to_vec();
        assert_eq!(legacy.shard_of(pages[1]), 1, "unpriced claim spills");
        assert_eq!(legacy.stats.shard_spills, 1);
        assert_eq!(legacy.stats.evictions, 0);
        assert!(legacy.stats.spill_bytes == 0
                    && priced.stats.spill_bytes == 0);
    }

    /// Crash teardown: draining the host buffers releases every byte
    /// (no leak when a replica dies holding swapped requests) and the
    /// drained sequences are gone for good.
    #[test]
    fn drain_host_buffers_releases_swapped_bytes() {
        use crate::perfmodel::fabric::FabricSpec;
        let mut p = KvPool::new(8, 4, 64);
        p.set_fabric(FabricSpec::paper(524_288.0));
        p.alloc(1, &[1; 5]).unwrap();
        p.alloc(2, &[2; 5]).unwrap();
        let pre = p.preempt_auto(None).unwrap();
        assert_eq!(pre.mode, PreemptMode::SwapOut);
        assert_eq!(p.host_buffers().len(), 1);
        assert_eq!(p.swapped_tokens(pre.request), Some(5));
        let freed = p.drain_host_buffers();
        assert_eq!(freed, 5 * 524_288);
        assert!(p.host_buffers().is_empty());
        assert_eq!(p.stats.host_bytes_reserved,
                   p.stats.host_bytes_released);
        assert!(p.resume_swapped(pre.request).is_err(),
                "drained buffer is gone");
        p.check_invariants().unwrap();
    }

    /// Satellite: random alloc/fork/advance/evict/preempt walks never
    /// leak pages (`free + live + cached == total`), never double-free,
    /// and keep every shared page's refcount equal to the number of
    /// block tables referencing it.
    #[test]
    fn prop_pool_walk_conserves_pages_and_refcounts() {
        prop_check(
            120,
            7,
            |r: &mut Rng| {
                let n = r.usize(1, 80);
                (0..n).map(|_| r.usize(0, 4000)).collect::<Vec<usize>>()
            },
            |ops| {
                let mut pool = KvPool::new(24, 4, 64);
                let mut next_id = 0u64;
                let mut live: Vec<u64> = Vec::new();
                // Shared stems exercise the prefix cache; stem 2 is a
                // strict prefix of stem 0 (partial chain overlap).
                let stems: [Vec<i32>; 3] = [
                    (0..12).collect(),
                    (100..112).collect(),
                    (0..8).collect(),
                ];
                for &x in ops {
                    let op = x % 8;
                    let p = x / 8;
                    match op {
                        0..=2 => {
                            next_id += 1;
                            let mut toks = stems[p % 3].clone();
                            toks.extend(
                                (0..p % 5)
                                    .map(|j| 1000 + next_id as i32 + j as i32),
                            );
                            if pool.alloc(next_id, &toks).is_ok() {
                                live.push(next_id);
                            }
                        }
                        3 | 4 => {
                            if !live.is_empty() {
                                let id = live[p % live.len()];
                                let _ = pool.advance(id, (p % 50) as i32);
                            }
                        }
                        5 => {
                            if !live.is_empty() {
                                let id = live[p % live.len()];
                                let pos = pool.pos(id).unwrap();
                                let _ = pool.rewind_to(
                                    id,
                                    pos.saturating_sub(p % 3),
                                );
                            }
                        }
                        6 => {
                            if !live.is_empty() {
                                let id = live.remove(p % live.len());
                                pool.release(id)
                                    .map_err(|e| e.to_string())?;
                            }
                        }
                        _ => {
                            let mode = if p % 2 == 0 {
                                PreemptMode::Recompute
                            } else {
                                PreemptMode::SwapOut
                            };
                            if let Some(pre) = pool.preempt(mode) {
                                live.retain(|&r| r != pre.request);
                            }
                        }
                    }
                    pool.check_invariants()?;
                }
                for id in live.drain(..) {
                    pool.release(id).map_err(|e| e.to_string())?;
                }
                pool.check_invariants()?;
                if pool.live_pages() != 0 {
                    return Err(format!(
                        "live pages after drain: {}",
                        pool.live_pages()
                    ));
                }
                Ok(())
            },
        );
    }

    /// Tentpole: a beam split is a refcount bump. The child shares
    /// every parent page; the first divergent token pays exactly one
    /// COW page; the parent's history is untouched.
    #[test]
    fn fork_shares_pages_and_first_divergence_pays_one_cow() {
        let mut p = KvPool::new(16, 4, 64);
        p.alloc(1, &[1, 2, 3, 4, 5, 6]).unwrap(); // 2 pages
        let shared = p.fork(1, 2).unwrap();
        assert_eq!(shared, 2);
        assert_eq!(p.stats.beam_forks, 1);
        assert_eq!(p.live_pages(), 2, "fork copied nothing");
        assert_eq!(p.table(2).unwrap().pages(), p.table(1).unwrap().pages());
        assert_eq!(p.pos(2).unwrap(), 6, "fill position inherited");
        // Divergent appends: each beam overwrites the shared partial
        // page → one COW fork each, then in-place growth.
        p.advance(1, 70).unwrap();
        p.advance(2, 80).unwrap();
        assert_eq!(p.stats.cow_forks, 1, "second writer owns its page");
        assert_ne!(p.table(1).unwrap().pages()[1],
                   p.table(2).unwrap().pages()[1]);
        assert_eq!(p.table(1).unwrap().pages()[0],
                   p.table(2).unwrap().pages()[0],
                   "full shared block still shared");
        // Double-fork and unknown-parent errors.
        assert_eq!(p.fork(1, 2).unwrap_err(), KvError::DuplicateRequest(2));
        assert_eq!(p.fork(99, 3).unwrap_err(), KvError::UnknownRequest(99));
        p.check_invariants().unwrap();
        p.release(1).unwrap();
        p.release(2).unwrap();
        assert_eq!(p.live_pages(), 0);
        p.check_invariants().unwrap();
    }

    /// Pruning a dead beam must not publish its blocks: the cache ends
    /// bit-identical to the pre-fork state even though the dead
    /// hypothesis filled whole blocks of its own.
    #[test]
    fn release_discard_leaves_cache_bit_identical() {
        let mut p = KvPool::new(16, 4, 64);
        // A released request seeds the cache with two hashed blocks.
        p.alloc(9, &[50, 51, 52, 53, 54, 55, 56, 57]).unwrap();
        p.release(9).unwrap();
        p.alloc(1, &[50, 51, 52, 53, 54, 55, 56, 57]).unwrap();
        let cache_before: std::collections::BTreeMap<u64, PageId> =
            p.cache.entries().collect();
        let lru_before = p.cache.lru_pages().to_vec();
        let live_before = p.live_pages();
        p.fork(1, 2).unwrap();
        // The dead beam diverges across a whole fresh block …
        for t in 0..6 {
            p.advance(2, 200 + t).unwrap();
        }
        // … rewinds (LayerSkip machinery), grows again, then dies.
        p.rewind_to(2, 9).unwrap();
        p.advance(2, 300).unwrap();
        p.release_discard(2).unwrap();
        let cache_after: std::collections::BTreeMap<u64, PageId> =
            p.cache.entries().collect();
        assert_eq!(cache_before, cache_after, "no block published");
        assert_eq!(p.cache.lru_pages(), &lru_before[..]);
        assert_eq!(p.live_pages(), live_before, "COW pages all freed");
        assert_eq!(p.pos(1).unwrap(), 8, "survivor untouched");
        p.check_invariants().unwrap();
        assert_eq!(p.release_discard(7).unwrap_err(),
                   KvError::UnknownRequest(7));
        p.release(1).unwrap();
        p.check_invariants().unwrap();
    }

    /// Satellite: random beam fork/advance/rewind/prune walks conserve
    /// page refcounts (every page's count equals its table references,
    /// `free + live + cached == total`) and leave the prefix cache —
    /// hash map *and* LRU order — bit-identical to the pre-fork state
    /// once every forked beam is pruned.
    #[test]
    fn prop_beam_fork_prune_conserves_refcounts_and_cache() {
        prop_check(
            96,
            0xbea8,
            |r: &mut Rng| {
                let prompt_len = r.usize(1, 20);
                let n = r.usize(1, 40);
                let ops =
                    (0..n).map(|_| r.usize(0, 4000)).collect::<Vec<_>>();
                (prompt_len, ops)
            },
            |(prompt_len, ops)| {
                let mut pool = KvPool::new(48, 4, 64);
                // Seed the cache the way serving does: a finished
                // request publishes its full blocks.
                let stem: Vec<i32> = (0..16).collect();
                pool.alloc(98, &stem).map_err(|e| e.to_string())?;
                pool.release(98).map_err(|e| e.to_string())?;
                let prompt: Vec<i32> =
                    stem.iter().copied().take(*prompt_len).collect();
                pool.alloc(0, &prompt).map_err(|e| e.to_string())?;
                let root_pos = pool.pos(0).unwrap();
                let cache_before: std::collections::BTreeMap<u64, PageId> =
                    pool.cache.entries().collect();
                let lru_before = pool.cache.lru_pages().to_vec();
                let live_before = pool.live_pages();
                let mut beams: Vec<u64> = Vec::new();
                let mut next = 1u64;
                for &x in ops {
                    match x % 4 {
                        0 => {
                            // Fork off the root or a live beam.
                            let parents = beams.len() + 1;
                            let parent = match (x / 4) % parents {
                                0 => 0,
                                i => beams[i - 1],
                            };
                            if pool.fork(parent, next).is_ok() {
                                beams.push(next);
                                next += 1;
                            }
                        }
                        1 | 2 => {
                            if !beams.is_empty() {
                                let id = beams[(x / 4) % beams.len()];
                                let tok = 500 + (x % 97) as i32;
                                let _ = pool.advance(id, tok);
                            }
                        }
                        _ => {
                            if !beams.is_empty() {
                                let id = beams[(x / 4) % beams.len()];
                                let pos = pool.pos(id).unwrap();
                                let back = (x / 7) % 6;
                                let to = pos
                                    .saturating_sub(back)
                                    .max(root_pos.min(pos));
                                let _ = pool.rewind_to(id, to);
                            }
                        }
                    }
                    pool.check_invariants()?;
                }
                // Prune every hypothesis; the root survives.
                for id in beams.drain(..) {
                    pool.release_discard(id).map_err(|e| e.to_string())?;
                    pool.check_invariants()?;
                }
                let cache_after: std::collections::BTreeMap<u64, PageId> =
                    pool.cache.entries().collect();
                if cache_before != cache_after {
                    return Err(format!(
                        "cache changed: {} entries → {}",
                        cache_before.len(),
                        cache_after.len()
                    ));
                }
                if pool.cache.lru_pages() != &lru_before[..] {
                    return Err("cache LRU order changed".into());
                }
                if pool.live_pages() != live_before {
                    return Err(format!(
                        "live pages {} != pre-fork {}",
                        pool.live_pages(),
                        live_before
                    ));
                }
                if pool.pos(0).unwrap() != root_pos {
                    return Err("root position moved".into());
                }
                pool.release(0).map_err(|e| e.to_string())?;
                pool.check_invariants()?;
                Ok(())
            },
        );
    }
}
