//! Deterministic workload replay: paged pool vs. dense slots under the
//! same page budget, with whole-prompt or chunked prefill admission.
//!
//! Drives a mixed request stream (short-chat-heavy, shared system
//! prompt, a long-document tail) through the real scheduling path —
//! the unified [`Scheduler`] over a [`PagedKvSlots`] view — one
//! scheduler tick per batched decode step, exactly like the serving
//! loop but without a device. The dense baseline gets the *same byte
//! budget* expressed as worst-case slots (`pages · page_size /
//! max_seq`); the paged run gets it as pages. The difference in
//! sustained batch occupancy is the paper's Table-3 capacity lever.
//!
//! A simulated clock prices each tick at one decode dispatch plus the
//! prefill tokens the tick actually fed ([`SIM_DECODE_COST`] +
//! tokens × [`SIM_PREFILL_TOKEN_COST`]), which makes the
//! prefill/decode-interference effect measurable without hardware:
//! whole-prompt admission stacks entire prompts into single ticks
//! (huge TBT outliers for the requests already decoding), while
//! `chunk_prefill` bounds any tick's prefill work by the chunk budget
//! — the replay reports mean/p99 TBT and p99 TTFT for both.
//!
//! The whole simulation of one worker lives in [`SimWorker`] so the
//! replica-routing replay (`crate::routing::replay`) can run N of
//! them in lockstep under a routing policy; [`replay`] is the
//! single-worker driver those semantics are defined by.
//!
//! With a [`MixSpec`] the stream becomes a *mixed fleet*: a slice of
//! the requests are Seamless (beam search — every decode tick forks
//! and prunes sibling hypotheses through the pool's block-table COW
//! machinery, the paper's Obs #4 fix expressed in pages) and a slice
//! are HSTU (one-shot scoring — the whole request is prefill, zero
//! decode ticks, Obs #1). One scheduler ticks all three families side
//! by side, and the result carries per-modality TTFT/TBT plus
//! busy/idle attribution ([`FamilyStats`], `mmserve kv --mix`).

use std::collections::{HashMap, HashSet};

use crate::coordinator::batcher::QueuedRequest;
use crate::coordinator::kv::PagedKvSlots;
use crate::perfmodel::fabric::{FabricSpec, LinkKind};
use crate::sched::{SchedConfig, Scheduler};
use crate::substrate::metrics::Histogram;
use crate::substrate::rng::Rng;
use crate::substrate::table::Table;
use crate::telemetry::ledger::{RequestLedger, TickCharges};
use crate::telemetry::live::{FlightRecorder, LiveMetrics,
                             WorkerSampler};
use crate::workload::arrivals::{generate_arrivals, zipf_cdf,
                                zipf_pick, ArrivalSpec};

use super::{KvError, KvPoolConfig, PoolStats, PreemptMode};

/// Simulated cost of one batched decode dispatch (arbitrary units).
pub const SIM_DECODE_COST: f64 = 1.0;
/// Simulated cost of prefilling one prompt token.
pub const SIM_PREFILL_TOKEN_COST: f64 = 0.05;

/// Pool-request id space for transient beam-hypothesis forks — far
/// above any replayed request id, so ghosts can never collide with
/// real work.
const GHOST_BASE: u64 = 1 << 48;

/// Model family of one simulated request. The mixed-fleet replay
/// serves all three through the same scheduler and pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash,
         Default)]
pub enum SimFamily {
    /// Autoregressive chat decode (the legacy replay's only family).
    #[default]
    Chat,
    /// Beam-searched translation: every decode tick forks and prunes
    /// sibling hypotheses through the pool's block-table fork/prune
    /// machinery — beam reorder as page refcounts, never a KV copy.
    Seamless,
    /// One-shot recommendation scoring: the whole request is prefill
    /// and it completes at its first token — zero decode ticks.
    Hstu,
}

impl SimFamily {
    /// Stable lowercase label (CLI selector, sketch/ledger cohort).
    pub fn label(&self) -> &'static str {
        match self {
            SimFamily::Chat => "chat",
            SimFamily::Seamless => "seamless",
            SimFamily::Hstu => "hstu",
        }
    }
}

/// Mixed-fleet selector: what fraction of the request stream each
/// non-chat family gets (the rest stay chat), plus the beam width
/// Seamless requests fork per decode tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MixSpec {
    /// Percent of requests served as Seamless.
    pub seamless_percent: usize,
    /// Percent of requests served as HSTU.
    pub hstu_percent: usize,
    /// Sibling hypotheses per Seamless decode tick (≤ 1 = no forks).
    pub beam: usize,
}

impl MixSpec {
    /// Parse a `--mix` selector like `"seamless:25,hstu:25"`.
    pub fn parse(spec: &str, beam: usize) -> Result<MixSpec, String> {
        let mut m = MixSpec {
            seamless_percent: 0,
            hstu_percent: 0,
            beam: beam.clamp(1, 32),
        };
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (fam, pct) = part.split_once(':').ok_or_else(|| {
                format!("mix part {part:?}: want family:percent")
            })?;
            let pct: usize = pct.trim().parse().map_err(|_| {
                format!("mix part {part:?}: bad percent")
            })?;
            match fam.trim() {
                "seamless" => m.seamless_percent = pct,
                "hstu" => m.hstu_percent = pct,
                // Chat is the remainder; naming it is allowed but its
                // share is implied.
                "chat" => {}
                other => {
                    return Err(format!(
                        "unknown family {other:?} \
                         (want seamless|hstu|chat)"
                    ))
                }
            }
        }
        if m.seamless_percent + m.hstu_percent > 100 {
            return Err(format!(
                "mix percentages exceed 100 (seamless {} + hstu {})",
                m.seamless_percent, m.hstu_percent
            ));
        }
        Ok(m)
    }
}

/// The replayed request mix (defaults: short-chat-heavy with a shared
/// system prompt — the regime where paging pays most).
#[derive(Debug, Clone)]
pub struct ReplayConfig {
    pub requests: usize,
    /// Shared system-prompt length (tokens) prefixed to every prompt.
    pub system_prompt_len: usize,
    /// Distinct shared system prompts ("tenants"): each request draws
    /// one uniformly. 1 (the default) keeps the single shared prompt —
    /// and, deliberately, the exact RNG stream of earlier replays.
    /// More tenants is the regime where prefix-affinity routing pays:
    /// round-robin makes every replica cache every tenant's prefix.
    pub tenants: usize,
    /// Unique prompt-suffix length range for short chats (inclusive).
    pub short_prompt: (usize, usize),
    pub short_decode: (usize, usize),
    /// Long-document tail of the mix.
    pub long_prompt: (usize, usize),
    pub long_decode: (usize, usize),
    /// Percent of requests drawn from the long ranges.
    pub long_percent: usize,
    pub page_size: usize,
    /// The shared capacity budget, in pages.
    pub total_pages: usize,
    /// Device arenas the budget is split across (`--shards`; 1 = the
    /// monolithic pool, bit-identical to the pre-shard replay).
    pub shards: usize,
    /// Decode-graph batch for the paged run (the dense run's slot count
    /// is derived from the page budget instead).
    pub batch_slots: usize,
    pub max_seq: usize,
    pub prefill_budget: usize,
    /// Chunked prefill: max new prompt tokens per tick (0 = whole).
    pub chunk_prefill: usize,
    pub seed: u64,
    /// Priced transfer fabric: swap-outs reserve byte-accounted host
    /// buffers, preemption trades swap against recompute by modeled
    /// nanoseconds, and disaggregated handoffs pay the inter-replica
    /// link. `None` (the default) is the unpriced legacy replay, bit
    /// for bit; so is `Some(FabricSpec::zero_cost())`.
    pub fabric: Option<FabricSpec>,
    /// Mixed-fleet mode: a slice of the stream served as Seamless
    /// (beam-forking) and HSTU (zero-decode) requests. `None` (the
    /// default) is the pure-chat replay — and, like `tenants: 1`,
    /// deliberately keeps the historical RNG stream bit-identical.
    pub mix: Option<MixSpec>,
    /// Open-loop arrival process (`--arrivals`): requests carry
    /// timestamps from a rate curve instead of all queueing at t = 0,
    /// multi-tenant draws become Zipf-popular, and a slice of the
    /// stream re-arrives as warm-prefix conversation follow-ups.
    /// `None` (the default) is the closed-loop replay — and, like
    /// `mix: None`, keeps the historical RNG stream bit-identical.
    pub arrivals: Option<ArrivalSpec>,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        ReplayConfig {
            requests: 64,
            system_prompt_len: 48,
            tenants: 1,
            short_prompt: (4, 24),
            short_decode: (8, 32),
            long_prompt: (64, 160),
            long_decode: (32, 96),
            long_percent: 20,
            page_size: 16,
            total_pages: 96,
            shards: 1,
            batch_slots: 16,
            max_seq: 512,
            prefill_budget: 0,
            chunk_prefill: 0,
            seed: 7,
            fabric: None,
            mix: None,
            arrivals: None,
        }
    }
}

impl ReplayConfig {
    /// Worst-case slots the dense baseline gets from the same budget.
    pub fn dense_slots(&self) -> usize {
        (self.total_pages * self.page_size / self.max_seq).max(1)
    }
}

/// One request of the generated workload.
#[derive(Debug, Clone)]
pub struct SimRequest {
    pub id: u64,
    /// Full prompt: the tenant's shared system prefix + unique tail.
    pub tokens: Vec<i32>,
    /// Decode steps to run (0 for one-shot HSTU scoring).
    pub decode: usize,
    /// Tenant index (which shared system prompt it carries).
    pub tenant: usize,
    /// Model family (always `Chat` without a [`MixSpec`]).
    pub family: SimFamily,
}

/// The deterministic request mix for `cfg` (same seed → same
/// workload, byte for byte — the routing comparison and the CI perf
/// gate both depend on that).
pub fn generate_workload(cfg: &ReplayConfig) -> Vec<SimRequest> {
    let mut rng = Rng::new(cfg.seed);
    let tenants = cfg.tenants.max(1);
    // Tenant t's shared prefix; t = 0 reproduces the historical
    // single-prompt stream exactly.
    let sys: Vec<Vec<i32>> = (0..tenants)
        .map(|t| {
            (0..cfg.system_prompt_len)
                .map(|i| ((i + t * 101) % 200) as i32)
                .collect()
        })
        .collect();
    // Open-loop multi-tenant replays draw tenants by Zipf popularity
    // (a few shared prompts dominate, the fleet-scale shape); the
    // closed-loop replay keeps the uniform draw — and its RNG stream.
    let zipf = match &cfg.arrivals {
        Some(spec) if tenants > 1 && spec.zipf_s > 0.0 => {
            Some(zipf_cdf(tenants, spec.zipf_s))
        }
        _ => None,
    };
    let mut out = Vec::with_capacity(cfg.requests);
    for i in 0..cfg.requests {
        let id = i as u64 + 1;
        let long = rng.usize(0, 100) < cfg.long_percent;
        let (pr, dr) = if long {
            (cfg.long_prompt, cfg.long_decode)
        } else {
            (cfg.short_prompt, cfg.short_decode)
        };
        let extra = rng.usize(pr.0, pr.1 + 1);
        let decode = rng.usize(dr.0, dr.1 + 1).max(1);
        // Only drawn in multi-tenant mode so the single-tenant RNG
        // stream (and every replay built on it) stays bit-identical.
        let tenant = if tenants > 1 {
            match &zipf {
                Some(cdf) => zipf_pick(cdf, rng.f64()),
                None => rng.usize(0, tenants),
            }
        } else {
            0
        };
        // Same protection: the family roll happens only with a mix
        // configured, so `mix: None` replays the historical stream.
        let family = match &cfg.mix {
            Some(m) => {
                let roll = rng.usize(0, 100);
                if roll < m.seamless_percent {
                    SimFamily::Seamless
                } else if roll < m.seamless_percent + m.hstu_percent {
                    SimFamily::Hstu
                } else {
                    SimFamily::Chat
                }
            }
            None => SimFamily::Chat,
        };
        // One-shot scoring owes no decode ticks: its first token is
        // its result.
        let decode = if family == SimFamily::Hstu { 0 } else { decode };
        let mut tokens = sys[tenant].clone();
        tokens.extend((0..extra).map(|_| rng.range(300, 800) as i32));
        out.push(SimRequest { id, tokens, decode, tenant, family });
    }
    out
}

/// A worker's place in a disaggregated fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimRole {
    /// Prefill and decode share the worker (classic serving).
    #[default]
    Colocated,
    /// Prefill-only: each finished prompt ships its KV pages over the
    /// inter-replica link to a decode worker instead of decoding.
    Prefill,
    /// Decode-only: admits shipped KV (paying the priced transfer on
    /// its clock) and never runs prefill compute.
    Decode,
}

/// One finished prefill in flight from a prefill worker to a decode
/// worker: the KV pages' token history, the remaining decode budget,
/// and the latency the request accumulated before shipping.
#[derive(Debug, Clone)]
pub struct SimHandoff {
    pub id: u64,
    /// Full prompt token history backing the shipped KV pages.
    pub tokens: Vec<i32>,
    /// Decode steps still owed.
    pub decode: usize,
    pub tenant: usize,
    /// Model family (zero-decode handoffs complete at admission).
    pub family: SimFamily,
    /// Sim time from delivery to prefill completion on the prefill
    /// worker (queue wait + prefill compute); the receiving worker
    /// back-dates the request's TTFT origin by this plus the priced
    /// transfer, so fleet TTFT includes the whole handoff path.
    pub elapsed: f64,
}

/// Per-modality slice of one replay (mixed-fleet mode).
#[derive(Debug, Clone)]
pub struct FamilyStats {
    pub family: SimFamily,
    /// Requests delivered to the worker (fail-over re-deliveries
    /// count again, matching the fleet's routed totals).
    pub requests: usize,
    pub completed: usize,
    /// Simulated TTFT of this family's requests.
    pub ttft: Histogram,
    /// Simulated per-tick latency this family's decoders experienced.
    pub tbt: Histogram,
    /// Simulated compute attributed to this family: its prefill
    /// tokens priced at [`SIM_PREFILL_TOKEN_COST`] plus its share of
    /// every batched decode dispatch it rode.
    pub busy: f64,
    /// Batch-interference idle: tick time this family's decoding
    /// requests sat through that was spent on co-batched work
    /// (`tick cost − own share`, summed over participations).
    pub idle: f64,
}

impl FamilyStats {
    pub fn empty(family: SimFamily) -> FamilyStats {
        FamilyStats {
            family,
            requests: 0,
            completed: 0,
            ttft: Histogram::new(),
            tbt: Histogram::new(),
            busy: 0.0,
            idle: 0.0,
        }
    }
}

/// One replay's outcome.
#[derive(Debug, Clone)]
pub struct ReplayResult {
    pub label: &'static str,
    pub slots: usize,
    pub decode_ticks: u64,
    /// Scheduler ticks taken in total (prefill-only and shed ticks
    /// included — the causal ledger's tick-overhead denominator).
    pub ticks: u64,
    pub completed: usize,
    pub dropped: usize,
    pub tokens_decoded: u64,
    /// Mean live requests per decode tick — the Table-3 headline.
    pub mean_occupancy: f64,
    pub peak_occupancy: usize,
    /// Mean live-page fraction of the budget (paged runs only).
    pub mean_pool_utilization: f64,
    /// Simulated wall clock at drain.
    pub sim_time: f64,
    /// Simulated time-to-first-token per request, measured from its
    /// delivery to the worker (delivery is t = 0 for `replay`).
    pub ttft: Histogram,
    /// Simulated per-tick latency experienced by decoding requests —
    /// the time-between-tokens distribution.
    pub tbt: Histogram,
    /// Largest prompt-token load any single tick carried (the decode
    /// stall bound chunked prefill is for).
    pub max_tick_prefill_tokens: usize,
    /// Mean live-page fraction of each device shard's arena, sampled
    /// per decode tick (length = shard count; len 1 for a monolithic
    /// paged run, empty for dense) — the per-shard occupancy report.
    pub shard_utilization: Vec<f64>,
    /// Simulated time this worker's clock spent on fabric transfers
    /// (swap round trips over the host link, shipped-KV admissions
    /// over the inter-replica link). 0 without a fabric.
    pub transfer_time: f64,
    /// Bytes moved over the fabric (each swap direction and each
    /// handoff counted once). 0 without a fabric.
    pub transfer_bytes: u64,
    /// Pool counters (zeros for the dense baseline).
    pub stats: PoolStats,
    /// Per-modality latency and attribution slices, sorted by family
    /// (a pure-chat replay has a single `Chat` entry).
    pub families: Vec<FamilyStats>,
    /// Decoded token stream per request — the determinism witness the
    /// routing replay compares across policies.
    pub outputs: HashMap<u64, Vec<i32>>,
    /// Per-request TTFT samples (same values `ttft` aggregates) — the
    /// open-loop drivers slice these per rate-curve phase.
    pub ttft_by_request: HashMap<u64, f64>,
}

struct Pending {
    tokens: Vec<i32>,
    remaining: usize,
}

/// One simulated worker: the real scheduling path (unified
/// [`Scheduler`] over [`PagedKvSlots`]) plus its own simulated clock
/// and latency accounting. [`replay`] drives one; the routing replay
/// drives a fleet in lockstep.
pub struct SimWorker {
    kv: PagedKvSlots,
    sched: Scheduler,
    /// Queued (not yet admitted) request payloads, by request id.
    staging: HashMap<u64, Pending>,
    /// Mid-prefill payloads, by request id.
    inflight: HashMap<u64, Pending>,
    /// Decode budgets of fully prefilled requests.
    remaining: HashMap<u64, usize>,
    /// Delivery time on this worker's clock (TTFT origin).
    arrived: HashMap<u64, f64>,
    /// Requests whose TTFT has been recorded: a preemption victim's
    /// re-prefill must not record a second (inflated) sample — the
    /// server keeps the original ttft in the parked `SlotJob` on
    /// resume, and so does the sim.
    ttft_done: HashSet<u64>,
    slots_n: usize,
    now: f64,
    ttft: Histogram,
    /// Per-request TTFT mirror of `ttft` (phase-sliced reporting).
    ttft_by_req: HashMap<u64, f64>,
    tbt: Histogram,
    decode_ticks: u64,
    occupancy_sum: u64,
    peak: usize,
    completed: usize,
    dropped: usize,
    tokens_decoded: u64,
    util_sum: f64,
    /// Per-shard live-fraction sums, sampled with `util_sum`.
    shard_util_sums: Vec<f64>,
    stalled: usize,
    max_tick_prefill: usize,
    outputs: HashMap<u64, Vec<i32>>,
    /// Crashed (fail-over sim): accepts no work, ticks are no-ops.
    dead: bool,
    /// Tenant of each delivered request (TTFT/TBT sketch labels).
    tenant_of: HashMap<u64, usize>,
    /// Live-metrics publication point; pure observation — attaching
    /// one never changes scheduling, clocks, or outputs.
    sampler: Option<WorkerSampler>,
    /// Per-request causal ledger plus the replica id it stamps; the
    /// same pure-observation contract as the sampler.
    ledger: Option<(RequestLedger, u32)>,
    /// Page granularity for the ledger's page-seconds charge.
    page_size: usize,
    /// Ticks taken (the sampler's tick axis; counts no-op ticks too).
    ticks_seen: u64,
    /// Priced transfer fabric (`None` = the unpriced legacy replay).
    fabric: Option<FabricSpec>,
    /// Place in a disaggregated fleet (Colocated outside one).
    role: SimRole,
    /// Remaining decode budgets of swapped-out victims whose KV sits
    /// in the pool's host buffers awaiting a priced swap-in.
    swapped: HashMap<u64, usize>,
    /// Finished prefills awaiting pickup by the routing driver
    /// (prefill role only).
    outbox: Vec<SimHandoff>,
    /// Shipped KV awaiting admission on this worker (decode role).
    inbox: Vec<SimHandoff>,
    /// Transfer cost accrued since the clock last charged it.
    pending_transfer: f64,
    /// Total simulated time spent on fabric transfers.
    transfer_time: f64,
    /// Total bytes moved over the fabric.
    transfer_bytes: u64,
    /// Model family of each delivered request (mixed-fleet replay).
    family_of: HashMap<u64, SimFamily>,
    /// Sibling hypotheses a Seamless request forks per decode tick
    /// (≤ 1 = no forking).
    beam: usize,
    /// Mixed-fleet run: sampler/ledger cohort labels carry the family
    /// instead of the tenant, so `mmserve stats` / `mmserve explain`
    /// break their tables out per modality.
    mixed: bool,
    /// Per-family accumulators folded into [`ReplayResult::families`].
    fam: HashMap<SimFamily, FamilyStats>,
}

impl SimWorker {
    pub fn new(cfg: &ReplayConfig, paged: bool) -> SimWorker {
        let slots_n =
            if paged { cfg.batch_slots } else { cfg.dense_slots() };
        let mut kv = if paged {
            PagedKvSlots::paged(slots_n, cfg.max_seq, KvPoolConfig {
                page_size: cfg.page_size,
                total_pages: cfg.total_pages,
                shards: cfg.shards.max(1),
            })
        } else {
            PagedKvSlots::dense(slots_n, cfg.max_seq)
        };
        if let Some(f) = cfg.fabric {
            kv.set_fabric(f);
        }
        SimWorker {
            kv,
            sched: Scheduler::new(SchedConfig {
                prefill_budget: cfg.prefill_budget,
                chunk: cfg.chunk_prefill,
            }),
            staging: HashMap::new(),
            inflight: HashMap::new(),
            remaining: HashMap::new(),
            arrived: HashMap::new(),
            ttft_done: HashSet::new(),
            slots_n,
            now: 0.0,
            ttft: Histogram::new(),
            ttft_by_req: HashMap::new(),
            tbt: Histogram::new(),
            decode_ticks: 0,
            occupancy_sum: 0,
            peak: 0,
            completed: 0,
            dropped: 0,
            tokens_decoded: 0,
            util_sum: 0.0,
            shard_util_sums: if paged {
                vec![0.0; cfg.shards.max(1)]
            } else {
                Vec::new()
            },
            stalled: 0,
            max_tick_prefill: 0,
            outputs: HashMap::new(),
            dead: false,
            tenant_of: HashMap::new(),
            sampler: None,
            ledger: None,
            page_size: cfg.page_size.max(1),
            ticks_seen: 0,
            fabric: cfg.fabric,
            role: SimRole::Colocated,
            swapped: HashMap::new(),
            outbox: Vec::new(),
            inbox: Vec::new(),
            pending_transfer: 0.0,
            transfer_time: 0.0,
            transfer_bytes: 0,
            family_of: HashMap::new(),
            beam: cfg.mix.map_or(1, |m| m.beam.clamp(1, 32)),
            mixed: cfg.mix.is_some(),
            fam: HashMap::new(),
        }
    }

    /// This request's family (`Chat` if never delivered here).
    fn family(&self, req: u64) -> SimFamily {
        self.family_of.get(&req).copied().unwrap_or_default()
    }

    /// Per-family accumulator, created on first touch.
    fn fam_mut(&mut self, req: u64) -> &mut FamilyStats {
        let f = self.family(req);
        self.fam.entry(f).or_insert_with(|| FamilyStats::empty(f))
    }

    /// Sketch/ledger cohort label: the tenant in the classic replay,
    /// the model family in a mixed-fleet one.
    fn cohort_label(&self, req: u64) -> String {
        if self.mixed {
            self.family(req).label().to_string()
        } else {
            self.tenant_of.get(&req).copied().unwrap_or(0).to_string()
        }
    }

    /// Assign this worker's place in a disaggregated fleet (the
    /// routing replay sets this before delivering work; a standalone
    /// replay stays Colocated).
    pub fn set_role(&mut self, role: SimRole) {
        self.role = role;
    }

    pub fn role(&self) -> SimRole {
        self.role
    }

    /// Attach a live-metrics sampler: every tick publishes queue
    /// depth, pool counters and per-shard pages; TTFT/TBT go into
    /// tenant-labeled streaming sketches; crashes and preemption
    /// storms hit the sampler's flight recorder.
    pub fn attach_sampler(&mut self, sampler: WorkerSampler) {
        let replica = sampler.replica().parse().unwrap_or(0);
        self.sched.attach_live(sampler.live(), replica);
        self.sampler = Some(sampler);
    }

    /// Attach the per-request causal ledger: delivery, admission,
    /// prefill chunks, decode ticks, preemptions, shard spills and
    /// completion are recorded per request with the simulated clock,
    /// and every tick bulk-charges waiting/compute/page-second
    /// buckets. Pure observation, like the sampler.
    pub fn attach_ledger(&mut self, ledger: &RequestLedger,
                         replica: u32) {
        self.ledger = Some((ledger.clone(), replica));
    }

    /// Hand one request to this worker (enqueue + stage), arriving at
    /// the worker's current simulated time.
    pub fn deliver(&mut self, req: &SimRequest) {
        self.sched.enqueue(QueuedRequest {
            id: req.id,
            prompt_len: req.tokens.len(),
            max_new_tokens: req.decode,
        });
        self.staging.insert(req.id, Pending {
            tokens: req.tokens.clone(),
            remaining: req.decode,
        });
        self.arrived.insert(req.id, self.now);
        self.tenant_of.insert(req.id, req.tenant);
        self.family_of.insert(req.id, req.family);
        self.fam_mut(req.id).requests += 1;
        if let Some((led, replica)) = &self.ledger {
            let (led, replica) = (led.clone(), *replica);
            led.enqueued(req.id, replica, &self.cohort_label(req.id),
                         req.tokens.len(), self.now);
        }
    }

    /// Advance this worker's idle clock to `t` (open-loop waiting: no
    /// work arrived yet, the hardware sits and the clock runs). No-op
    /// when the worker is dead or already past `t` — clocks never run
    /// backwards.
    pub fn advance_to(&mut self, t: f64) {
        if self.dead || t <= self.now {
            return;
        }
        self.now = t;
    }

    /// Hand one request to this worker at absolute arrival time `at`
    /// (open-loop delivery). A worker whose clock lags the arrival is
    /// first advanced to it — the request cannot be served before it
    /// exists — and its TTFT origin is the *arrival* time, so queueing
    /// delay on a busy worker (clock already past `at`) is charged to
    /// TTFT exactly like real admission wait.
    pub fn deliver_at(&mut self, req: &SimRequest, at: f64) {
        self.advance_to(at);
        self.deliver(req);
        self.arrived.insert(req.id, at);
    }

    /// Gracefully withdraw everything *queued but never admitted*:
    /// the autoscaler's drain path. In-flight work (mid-prefill and
    /// decoding) stays and runs to completion; only staged queue
    /// entries are withdrawn, their ids returned sorted for
    /// re-routing. The worker keeps ticking — the caller retires it
    /// once `has_work()` clears.
    pub fn drain_queued(&mut self) -> Vec<u64> {
        if let Some(s) = &self.sampler {
            s.recorder().trigger("replica-drain");
        }
        let mut ids = Vec::new();
        while let Some(q) = self.sched.shed_front() {
            let id = q.id;
            self.sched.drop_request(id);
            self.staging.remove(&id);
            self.arrived.remove(&id);
            self.ttft_done.remove(&id);
            // A preemption victim parked back in staging may hold
            // partial outputs; the re-routed request recomputes from
            // scratch (same semantics as crash fail-over).
            self.outputs.remove(&id);
            ids.push(id);
        }
        ids.sort_unstable();
        ids
    }

    /// Cumulative capacity-wait ticks from the pool (0 on dense
    /// pools) — the autoscaler's pressure signal alongside `depth()`.
    pub fn capacity_waits(&self) -> u64 {
        self.kv.stats().map(|s| s.capacity_wait_ticks).unwrap_or(0)
    }

    /// Receive a finished prefill shipped from a prefill worker: the
    /// KV pages travel the inter-replica link (priced at admission),
    /// and the request's TTFT origin is back-dated by the latency it
    /// already accumulated plus the transfer, so the recorded TTFT
    /// covers queue + prefill + handoff + any admission wait here.
    pub fn deliver_handoff(&mut self, h: SimHandoff) {
        let tcost = self.handoff_cost(h.tokens.len());
        self.arrived.insert(h.id, self.now - h.elapsed - tcost);
        self.tenant_of.insert(h.id, h.tenant);
        self.family_of.insert(h.id, h.family);
        self.inbox.push(h);
    }

    /// Inter-replica transfer cost of one handoff (0 with no fabric).
    fn handoff_cost(&self, tokens: usize) -> f64 {
        self.fabric.map_or(0.0, |f| {
            f.transfer_cost(LinkKind::Network, f.bytes_for_tokens(tokens))
        })
    }

    /// Price a fabric movement of `tokens` tokens over `link` into the
    /// next clock charge; returns `(bytes, cost)` for the ledger.
    fn charge_transfer(&mut self, link: LinkKind, tokens: usize)
                       -> (u64, f64) {
        let Some(f) = self.fabric else { return (0, 0.0) };
        let bytes = f.bytes_for_tokens(tokens);
        let cost = f.transfer_cost(link, bytes);
        self.pending_transfer += cost;
        self.transfer_bytes += bytes;
        (bytes, cost)
    }

    /// Drain this worker's handoff outbox (the routing driver ships
    /// these to a decode worker after every tick round).
    pub fn take_handoffs(&mut self) -> Vec<SimHandoff> {
        std::mem::take(&mut self.outbox)
    }

    /// Anything queued, mid-prefill, decoding, swapped out, shipped
    /// here awaiting admission, or finished and awaiting handoff
    /// pickup? (A crashed worker reports idle: its remaining work was
    /// evacuated by `kill`.)
    pub fn has_work(&self) -> bool {
        !self.dead
            && (self.sched.pending() > 0 || self.kv.live_count() > 0
                || !self.inbox.is_empty() || !self.swapped.is_empty()
                || !self.outbox.is_empty())
    }

    /// Routing view: outstanding requests on this worker. Shipped-KV
    /// admissions and their decodes bypass the scheduler, so a decode
    /// worker counts its inbox and live budgets directly.
    pub fn depth(&self) -> usize {
        self.sched.pending() + self.sched.in_flight() + self.inbox.len()
            + if self.role == SimRole::Decode {
                self.remaining.len() + self.swapped.len()
            } else {
                0
            }
    }

    /// Routing view: leading prompt blocks resident in this worker's
    /// pool (the simulated analogue of the live snapshot probe).
    pub fn probe(&self, tokens: &[i32]) -> usize {
        self.kv.probe_prefix(tokens)
    }

    /// Routing view, shard-set form: `(resident leading blocks,
    /// distinct device shards holding them)`.
    pub fn probe_shards(&self, tokens: &[i32]) -> (usize, usize) {
        self.kv.probe_prefix_shards(tokens)
    }

    /// This worker's simulated clock (the routing replay stamps
    /// fleet-level ledger events with the receiving worker's time).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Crashed? (set by [`SimWorker::kill`]).
    pub fn is_dead(&self) -> bool {
        self.dead
    }

    /// Simulate a worker crash mid-workload: every unfinished request
    /// (queued, mid-prefill, or decoding) is withdrawn — its partial
    /// outputs discarded, its slot and pages released — and the sorted
    /// request ids are returned so the router can re-deliver them to
    /// surviving replicas from scratch (the recompute fail-over). The
    /// worker then accepts no more work; counters for requests it
    /// *finished* stay valid for the fleet report. TTFT samples the
    /// dead worker already recorded for unfinished requests remain in
    /// its histogram (the fleet TTFT merge is latency accounting, not
    /// the determinism witness — `outputs` is).
    pub fn kill(&mut self) -> Vec<u64> {
        if let Some(s) = &self.sampler {
            s.recorder().trigger("replica-crash");
        }
        let mut ids: Vec<u64> = self
            .staging
            .keys()
            .chain(self.inflight.keys())
            .chain(self.remaining.keys())
            .chain(self.swapped.keys())
            .copied()
            .chain(self.inbox.iter().map(|h| h.id))
            .chain(self.outbox.iter().map(|h| h.id))
            .collect();
        ids.sort_unstable();
        ids.dedup();
        for (slot, _req, _pos) in self.kv.live_slots() {
            let _ = self.kv.release(slot);
        }
        // Swapped-out victims die with the replica: their host-staged
        // bytes return to the budget (conservation survives crashes),
        // and the requests recompute elsewhere from their prompts.
        self.kv.drain_host_buffers();
        for &id in &ids {
            self.sched.drop_request(id);
            self.outputs.remove(&id);
            self.arrived.remove(&id);
            self.ttft_done.remove(&id);
        }
        while self.sched.shed_front().is_some() {}
        self.staging.clear();
        self.inflight.clear();
        self.remaining.clear();
        self.swapped.clear();
        self.inbox.clear();
        self.outbox.clear();
        self.pending_transfer = 0.0;
        self.dead = true;
        ids
    }

    /// One scheduler tick: plan, shed wedged work, execute prefill
    /// chunks, take one batched decode step, advance the clock.
    pub fn tick(&mut self) {
        if self.dead {
            return;
        }
        self.ticks_seen += 1;
        self.tick_inner();
        self.sample_tick();
    }

    /// End-of-tick live-metrics publication (no-op without a sampler
    /// or with both planes disabled — two relaxed loads).
    fn sample_tick(&mut self) {
        let Some(sampler) = self.sampler.as_mut() else { return };
        let depth = self.sched.pending() + self.sched.in_flight();
        let default_stats = PoolStats::default();
        let stats = self.kv.stats().unwrap_or(&default_stats);
        let shards = self
            .kv
            .pool()
            .map(|p| p.shard_views())
            .unwrap_or_default();
        sampler.sample_tick(self.ticks_seen, depth, stats, &shards);
        sampler.note_progress(self.completed as u64,
                              self.tokens_decoded);
    }

    /// Cross-shard spill counter (0 on dense pools) — the ledger
    /// diffs it around page-claiming calls to attribute spills.
    fn spills_now(&self) -> u64 {
        self.kv.stats().map(|s| s.shard_spills).unwrap_or(0)
    }

    /// Fabric-priced cost of one spilled page's NVLink gather (0.0
    /// without a fabric — the explainer falls back to its flat
    /// per-spill weight). Attribution only: spills hide inside the
    /// tick, so nothing lands on `pending_transfer`.
    fn spill_price(&self) -> f64 {
        self.fabric.map_or(0.0, |f| {
            f.transfer_cost(LinkKind::NvLink,
                            f.bytes_for_pages(1, self.page_size))
        })
    }

    fn tick_inner(&mut self) {
        // Causal-ledger handle for this tick (a cheap Arc clone);
        // None when detached *or disabled*, so the uninstrumented hot
        // path pays one relaxed load per tick and nothing else.
        let ledger = match &self.ledger {
            Some((l, r)) if l.is_enabled() => Some((l.clone(), *r)),
            _ => None,
        };
        // ---- swap-ins: resume staged victims before planning new
        // work (they are the oldest admissions; the swap-in rides the
        // host link instead of re-running their prefill) -----------------
        if !self.swapped.is_empty() {
            let mut ids: Vec<u64> = self.swapped.keys().copied().collect();
            ids.sort_unstable();
            for id in ids {
                match self.kv.resume_swapped(id) {
                    Ok((_slot, _out)) => {
                        let rem = self
                            .swapped
                            .remove(&id)
                            .expect("staged victim");
                        let len = self
                            .kv
                            .slot_of(id)
                            .and_then(|s| self.kv.pos(s).ok())
                            .unwrap_or(0);
                        self.remaining.insert(id, rem);
                        let (bytes, cost) =
                            self.charge_transfer(LinkKind::Pcie, len);
                        if let Some((led, _)) = &ledger {
                            led.transfer(id, bytes, cost, self.now);
                        }
                    }
                    Err(KvError::CapacityExhausted { .. })
                    | Err(KvError::NoFreeSlot) => break,
                    Err(_) => {
                        // Structural refusal: recompute from the
                        // token history instead of waiting forever.
                        let rem = self.swapped.remove(&id).unwrap_or(0);
                        if let Some((tokens, _)) =
                            self.kv.discard_swapped(id)
                        {
                            self.sched.requeue_front(QueuedRequest {
                                id,
                                prompt_len: tokens.len(),
                                max_new_tokens: rem,
                            });
                            self.staging.insert(id, Pending {
                                tokens,
                                remaining: rem,
                            });
                        }
                    }
                }
            }
        }
        // ---- disaggregated admission: land shipped KV (decode role).
        // The prompt arrives over the inter-replica link, not through
        // prefill compute — the tick is charged the priced transfer
        // and zero prefill tokens. ---------------------------------------
        let mut finished_handoff: Vec<u64> = Vec::new();
        while !self.inbox.is_empty() {
            let admitted = self.kv.alloc(self.inbox[0].id,
                                         &self.inbox[0].tokens);
            match admitted {
                Ok(_) => {
                    let h = self.inbox.remove(0);
                    let (bytes, cost) = self
                        .charge_transfer(LinkKind::Network,
                                         h.tokens.len());
                    self.remaining.insert(h.id, h.decode);
                    finished_handoff.push(h.id);
                    if let Some((led, _)) = &ledger {
                        led.admitted(h.id, h.tokens.len(), self.now);
                        led.transfer(h.id, bytes, cost, self.now);
                    }
                }
                Err(KvError::CapacityExhausted { .. })
                | Err(KvError::NoFreeSlot) => {
                    self.kv.note_capacity_wait();
                    break;
                }
                Err(_) => {
                    let h = self.inbox.remove(0);
                    self.arrived.remove(&h.id);
                    self.dropped += 1;
                }
            }
        }
        // ---- plan ------------------------------------------------------
        let view = self.kv.capacity_view();
        let plan = self.sched.plan(&view);
        let blocked = plan.blocked_on_capacity;
        if plan.blocked_on_capacity {
            self.kv.note_capacity_wait();
        }
        // Nothing planned and nothing decoding to free pages: queued
        // or mid-prefill work larger than the pool can ever grant
        // would stall forever — shed it (mirrors the server worker).
        if plan.chunks.is_empty() && self.remaining.is_empty()
            && finished_handoff.is_empty()
            && (self.sched.pending() > 0 || !self.inflight.is_empty()
                || !self.inbox.is_empty() || !self.swapped.is_empty())
        {
            self.stalled += 1;
            if self.stalled > 2 {
                if let Some(req) = self.sched.head_prefilling() {
                    // Wedged chunked prefill: free its slot and pages.
                    self.sched.drop_request(req);
                    if let Some(slot) = self.kv.slot_of(req) {
                        let _ = self.kv.release(slot);
                    }
                    self.inflight.remove(&req);
                    self.dropped += 1;
                } else if let Some(q) = self.sched.shed_front() {
                    self.sched.drop_request(q.id);
                    self.staging.remove(&q.id);
                    self.dropped += 1;
                } else if !self.inbox.is_empty() {
                    // Shipped KV the pool can never admit.
                    let h = self.inbox.remove(0);
                    self.arrived.remove(&h.id);
                    self.dropped += 1;
                } else if let Some(&id) =
                    self.swapped.keys().min()
                {
                    // Wedged swap-in: fall back to recompute.
                    let rem = self.swapped.remove(&id).unwrap_or(0);
                    if let Some((tokens, _)) =
                        self.kv.discard_swapped(id)
                    {
                        self.sched.requeue_front(QueuedRequest {
                            id,
                            prompt_len: tokens.len(),
                            max_new_tokens: rem,
                        });
                        self.staging.insert(id, Pending {
                            tokens,
                            remaining: rem,
                        });
                    }
                }
                self.stalled = 0;
            }
            return;
        }
        self.stalled = 0;

        // ---- execute prefill chunks ------------------------------------
        let mut tick_prefill = 0usize;
        let mut finished_prefill: Vec<u64> = finished_handoff;
        // Finished prefills a prefill-role worker ships instead of
        // decoding (packaged after the clock advances).
        let mut handoff_ready: Vec<(u64, Pending)> = Vec::new();
        let mut requeue: Vec<QueuedRequest> = Vec::new();
        // `(request, prompt tokens fed this tick)` — the ledger's
        // per-request prefill-compute charge (empty when detached).
        let mut fed: Vec<(u64, usize)> = Vec::new();
        for c in &plan.chunks {
            if c.start == 0 {
                let Some(p) = self.staging.remove(&c.request) else {
                    self.sched.drop_request(c.request);
                    continue;
                };
                let len = c.len.min(p.tokens.len());
                let spill0 = ledger.as_ref().map(|_| self.spills_now());
                let allocated = self.kv.alloc(c.request, &p.tokens[..len]);
                if let (Some((led, _)), Some(s0)) = (&ledger, spill0) {
                    let d = self.spills_now().saturating_sub(s0);
                    for _ in 0..d {
                        led.spill(c.request, self.spill_price(),
                                  self.now);
                    }
                }
                match allocated {
                    Ok(_) => {
                        tick_prefill += len;
                        self.sched.chunk_committed(c.request, len);
                        self.fam_mut(c.request).busy +=
                            len as f64 * SIM_PREFILL_TOKEN_COST;
                        if let Some((led, _)) = &ledger {
                            led.admitted(c.request, len, self.now);
                            fed.push((c.request, len));
                        }
                        if len < p.tokens.len() {
                            self.inflight.insert(c.request, p);
                        } else if self.role == SimRole::Prefill {
                            handoff_ready.push((c.request, p));
                        } else {
                            self.remaining.insert(c.request, p.remaining);
                            finished_prefill.push(c.request);
                        }
                    }
                    Err(KvError::CapacityExhausted { .. }) => {
                        // Growth raced the view; retry next tick.
                        requeue.push(QueuedRequest {
                            id: c.request,
                            prompt_len: p.tokens.len(),
                            max_new_tokens: p.remaining,
                        });
                        self.staging.insert(c.request, p);
                    }
                    Err(_) => {
                        self.sched.drop_request(c.request);
                        self.dropped += 1;
                    }
                }
            } else {
                let Some(slot) = self.kv.slot_of(c.request) else {
                    self.sched.drop_request(c.request);
                    self.inflight.remove(&c.request);
                    continue;
                };
                let total = self
                    .inflight
                    .get(&c.request)
                    .map(|p| p.tokens.len())
                    .unwrap_or(0);
                let start = self.kv.pos(slot).unwrap_or(c.start);
                let len = c.len.min(total.saturating_sub(start));
                if len == 0 {
                    continue;
                }
                let chunk: Vec<i32> = self.inflight[&c.request].tokens
                    [start..start + len]
                    .to_vec();
                let spill0 = ledger.as_ref().map(|_| self.spills_now());
                let extended = self.kv.extend_chunk(slot, &chunk);
                if let (Some((led, _)), Some(s0)) = (&ledger, spill0) {
                    let d = self.spills_now().saturating_sub(s0);
                    for _ in 0..d {
                        led.spill(c.request, self.spill_price(),
                                  self.now);
                    }
                }
                match extended {
                    Ok(_) => {
                        tick_prefill += len;
                        self.sched.chunk_committed(c.request, len);
                        self.fam_mut(c.request).busy +=
                            len as f64 * SIM_PREFILL_TOKEN_COST;
                        if let Some((led, _)) = &ledger {
                            led.prefill_chunk(c.request, len, self.now);
                            fed.push((c.request, len));
                        }
                        if start + len >= total {
                            let p = self
                                .inflight
                                .remove(&c.request)
                                .expect("inflight entry");
                            if self.role == SimRole::Prefill {
                                handoff_ready.push((c.request, p));
                            } else {
                                self.remaining
                                    .insert(c.request, p.remaining);
                                finished_prefill.push(c.request);
                            }
                        }
                    }
                    Err(KvError::CapacityExhausted { .. }) => {
                        // Chunk growth raced decode growth: restart
                        // from the queue front (recompute).
                        let p = self
                            .inflight
                            .remove(&c.request)
                            .expect("inflight entry");
                        let _ = self.kv.release(slot);
                        requeue.push(QueuedRequest {
                            id: c.request,
                            prompt_len: p.tokens.len(),
                            max_new_tokens: p.remaining,
                        });
                        self.staging.insert(c.request, p);
                    }
                    Err(_) => {
                        // Structural failure (e.g. the prefix reaches
                        // max_seq): requeueing would fail identically
                        // forever — drop, like the server worker.
                        self.inflight.remove(&c.request);
                        let _ = self.kv.release(slot);
                        self.sched.drop_request(c.request);
                        self.dropped += 1;
                    }
                }
            }
        }
        self.sched.requeue_all(requeue);
        self.max_tick_prefill = self.max_tick_prefill.max(tick_prefill);

        // ---- one batched decode step + the simulated clock -------------
        // Requests with no decode budget (one-shot HSTU scoring) never
        // join the decode dispatch — they complete below, the moment
        // their prefill lands. Pure-chat replays never stage a zero
        // budget, so the extra predicate changes nothing there.
        let decoding: Vec<(usize, u64, usize)> = self
            .kv
            .live_slots()
            .into_iter()
            .filter(|(_, req, _)| {
                self.remaining.get(req).is_some_and(|&r| r > 0)
            })
            .collect();
        // Fabric transfers accrued since the last charge (swap-ins,
        // swap-outs, shipped-KV admissions) ride this tick's clock;
        // 0.0 exactly when nothing priced moved, so the unpriced
        // replay's clock is untouched bit for bit.
        let transfer = self.pending_transfer;
        self.pending_transfer = 0.0;
        self.transfer_time += transfer;
        let tick_cost = tick_prefill as f64 * SIM_PREFILL_TOKEN_COST
            + transfer
            + if decoding.is_empty() { 0.0 } else { SIM_DECODE_COST };
        self.now += tick_cost;
        // First token is sampled from the completing prefill's logits
        // at the end of this tick.
        for req in &finished_prefill {
            if self.ttft_done.insert(*req) {
                let t0 = self.arrived.get(req).copied().unwrap_or(0.0);
                let dt = self.now - t0;
                self.ttft.record(dt);
                self.ttft_by_req.insert(*req, dt);
                self.fam_mut(*req).ttft.record(dt);
                if let Some(s) = &self.sampler {
                    if s.live().is_enabled() {
                        s.observe_ttft_ms(&self.cohort_label(*req), dt);
                    }
                }
                if let Some((led, _)) = &ledger {
                    led.first_token(*req, self.now);
                }
            }
        }
        // ---- per-tick ledger charges -----------------------------------
        // Who waited (and why), whose prefill compute the tick
        // carried, and pages held across it. Placed before the decode
        // loop so prefill-only ticks still charge the waiters;
        // zero-cost shed ticks never reach this point.
        if let Some((led, _)) = &ledger {
            if tick_cost > 0.0 {
                let waiting: Vec<u64> =
                    self.staging.keys().copied().collect();
                let prefill: Vec<(u64, f64)> = fed
                    .iter()
                    .map(|&(id, n)| {
                        (id, n as f64 * SIM_PREFILL_TOKEN_COST)
                    })
                    .collect();
                let pages: Vec<(u64, u64)> = self
                    .kv
                    .live_slots()
                    .into_iter()
                    .map(|(_, req, pos)| {
                        (req, pos.div_ceil(self.page_size) as u64)
                    })
                    .collect();
                led.charge_tick(&TickCharges {
                    dt: tick_cost,
                    blocked_on_capacity: blocked,
                    waiting: &waiting,
                    prefill: &prefill,
                    pages: &pages,
                });
            }
        }
        // ---- ship finished prefills (prefill role) ---------------------
        // Pages return to this worker's pool (full blocks stay cached,
        // so same-tenant prompts keep hitting the warm prefix); the
        // handoff carries the token history and the latency already
        // accumulated. The receiving decode worker prices the actual
        // transfer when it admits the pages.
        for (id, p) in handoff_ready {
            if let Some(slot) = self.kv.slot_of(id) {
                let _ = self.kv.release(slot);
            }
            self.sched.finished(id);
            let t0 = self.arrived.remove(&id).unwrap_or(0.0);
            self.outbox.push(SimHandoff {
                id,
                tokens: p.tokens,
                decode: p.remaining,
                tenant: self.tenant_of.get(&id).copied().unwrap_or(0),
                family: self.family(id),
                elapsed: self.now - t0,
            });
        }
        // ---- zero-decode completion (one-shot scoring families) --------
        // An HSTU request's first token *is* its result: no decode
        // budget means it completes the moment its prefill does
        // (Obs #1) — a prefill-only plan with zero decode ticks.
        for req in finished_prefill {
            if self.remaining.get(&req) != Some(&0) {
                continue;
            }
            self.remaining.remove(&req);
            if let Some(slot) = self.kv.slot_of(req) {
                let _ = self.kv.release(slot);
            }
            self.sched.finished(req);
            self.completed += 1;
            self.fam_mut(req).completed += 1;
            self.outputs.entry(req).or_default();
            if let Some((led, _)) = &ledger {
                led.completed(req, self.now);
            }
        }
        if decoding.is_empty() {
            return;
        }
        self.decode_ticks += 1;
        self.occupancy_sum += decoding.len() as u64;
        self.peak = self.peak.max(decoding.len());
        // A request's own share of the batched dispatch; the rest of
        // its tick latency is batch-interference idle in the ledger.
        let share = SIM_DECODE_COST / decoding.len() as f64;
        if let Some(pool) = self.kv.pool() {
            self.util_sum +=
                pool.live_pages() as f64 / pool.total_pages() as f64;
            // Per-shard occupancy, sampled on the same tick cadence.
            for v in pool.shard_views() {
                if v.total_pages > 0 {
                    self.shard_util_sums[v.shard] +=
                        v.live_pages as f64 / v.total_pages as f64;
                }
            }
        }
        for (slot, req, pos) in decoding {
            // A preemption earlier in this step may have freed the slot.
            if self.kv.slot_of(req) != Some(slot) {
                continue;
            }
            self.tbt.record(tick_cost);
            {
                let f = self.fam_mut(req);
                f.tbt.record(tick_cost);
                f.busy += share;
                f.idle += tick_cost - share;
            }
            if let Some(s) = &self.sampler {
                if s.live().is_enabled() {
                    s.observe_tbt_ms(&self.cohort_label(req), tick_cost);
                }
            }
            if let Some((led, _)) = &ledger {
                led.decoded(req, self.now, tick_cost, share);
            }
            let rem = {
                let r = self.remaining.get_mut(&req).expect("live job");
                *r -= 1;
                *r
            };
            self.tokens_decoded += 1;
            // The emitted token is a pure function of the position, so
            // per-request streams are identical no matter which worker
            // serves the request or how often it is preempted.
            let tok = 900 + (pos as i32 % 50);
            self.outputs.entry(req).or_default().push(tok);
            // Beam expansion (Seamless): fork sibling hypotheses off
            // this request's block table and prune them — beam reorder
            // as page-table fork/prune (Obs #4), never a KV copy. The
            // forks are refcount bumps and the prunes discard without
            // publishing, so pages are conserved, the clock never
            // moves, and streams are identical with beams on or off;
            // only the pool's `beam_forks` counter advances.
            if self.beam > 1 && self.family(req) == SimFamily::Seamless {
                for k in 1..self.beam as u64 {
                    let ghost = GHOST_BASE + req * 64 + k;
                    if self.kv.fork(req, ghost).is_err() {
                        break; // dense mode: nothing to fork
                    }
                    let _ = self.kv.release_discard(ghost);
                }
            }
            if rem == 0 {
                self.kv.release(slot).expect("live slot");
                self.remaining.remove(&req);
                self.sched.finished(req);
                self.completed += 1;
                self.fam_mut(req).completed += 1;
                if let Some((led, _)) = &ledger {
                    led.completed(req, self.now);
                }
                continue;
            }
            let spill0 = ledger.as_ref().map(|_| self.spills_now());
            let advanced = self.kv.advance(slot, tok);
            if let (Some((led, _)), Some(s0)) = (&ledger, spill0) {
                let d = self.spills_now().saturating_sub(s0);
                for _ in 0..d {
                    led.spill(req, self.spill_price(), self.now);
                }
            }
            match advanced {
                Ok(_) => {}
                Err(KvError::MaxSeq { .. }) => {
                    // Sequence cap: finish early, like the server loop.
                    self.kv.release(slot).expect("live slot");
                    self.remaining.remove(&req);
                    self.sched.finished(req);
                    self.completed += 1;
                    self.fam_mut(req).completed += 1;
                    if let Some((led, _)) = &ledger {
                        led.completed(req, self.now);
                    }
                }
                Err(KvError::CapacityExhausted { .. }) => {
                    self.preempt_until_fits(slot, req, tok);
                }
                Err(_) => {
                    self.kv.release(slot).expect("live slot");
                    self.remaining.remove(&req);
                    self.sched.finished(req);
                    self.completed += 1;
                    self.fam_mut(req).completed += 1;
                    if let Some((led, _)) = &ledger {
                        led.completed(req, self.now);
                    }
                }
            }
        }
    }

    /// Decode outgrew the pool: preempt (cost-aware when a fabric is
    /// attached — swap-out vs. recompute by modeled nanoseconds; the
    /// legacy latest-admitted recompute rule otherwise, on a sharded
    /// pool targeting the grower's arena first) until the advance fits
    /// or we evicted ourselves.
    fn preempt_until_fits(&mut self, slot: usize, req: u64, tok: i32) {
        let ledger = match &self.ledger {
            Some((l, r)) if l.is_enabled() => Some((l.clone(), *r)),
            _ => None,
        };
        let prefer = self.kv.growth_shard(req);
        loop {
            let Some((_vslot, pre)) = self.kv.preempt_auto(prefer)
            else {
                break;
            };
            let victim = pre.request;
            if let Some((led, _)) = &ledger {
                led.preempted(victim, self.now);
            }
            if pre.mode == PreemptMode::SwapOut
                && victim != req
                && !self.inflight.contains_key(&victim)
            {
                // The pool staged the victim's KV in a host buffer:
                // pay the swap-out over the host link now; the swap-in
                // pays the return trip at resume. No re-prefill.
                let rem_v = self.remaining.remove(&victim).unwrap_or(0);
                self.swapped.insert(victim, rem_v);
                let (bytes, cost) = self
                    .charge_transfer(LinkKind::Pcie, pre.tokens.len());
                if let Some((led, _)) = &ledger {
                    led.transfer(victim, bytes, cost, self.now);
                }
            } else if let Some(p) = self.inflight.remove(&victim) {
                // Mid-prefill victim restarts its chunks (a host
                // buffer cannot restore the unprefilled suffix — a
                // staged swap is abandoned, bytes back to the budget).
                if pre.mode == PreemptMode::SwapOut {
                    let _ = self.kv.discard_swapped(victim);
                }
                self.sched.requeue_front(QueuedRequest {
                    id: victim,
                    prompt_len: p.tokens.len(),
                    max_new_tokens: p.remaining,
                });
                self.staging.insert(victim, p);
            } else {
                // Self-eviction keeps the just-sampled token with the
                // requeued job, which a host buffer staged before the
                // sample cannot carry — recompute instead.
                if pre.mode == PreemptMode::SwapOut {
                    let _ = self.kv.discard_swapped(victim);
                }
                let rem_v = self.remaining.remove(&victim).unwrap_or(0);
                let mut tokens = pre.tokens;
                if victim == req {
                    // The server keeps the just-sampled token in the
                    // job and re-prefills prompt + all generated
                    // tokens on resume; mirror that here so each
                    // request's output stream is independent of how
                    // often it gets preempted (and therefore of the
                    // routing policy).
                    tokens.push(tok);
                }
                self.sched.requeue_front(QueuedRequest {
                    id: victim,
                    prompt_len: tokens.len(),
                    max_new_tokens: rem_v,
                });
                self.staging.insert(victim, Pending {
                    tokens,
                    remaining: rem_v,
                });
            }
            if victim == req {
                break; // evicted ourselves; resume later
            }
            match self.kv.advance(slot, tok) {
                Ok(_) => break,
                Err(KvError::CapacityExhausted { .. }) => {}
                Err(_) => {
                    self.kv.release(slot).expect("live slot");
                    self.remaining.remove(&req);
                    self.sched.finished(req);
                    self.completed += 1;
                    self.fam_mut(req).completed += 1;
                    if let Some((led, _)) = &ledger {
                        led.completed(req, self.now);
                    }
                    break;
                }
            }
        }
    }

    /// Finish the run: check pool invariants and fold the counters
    /// into a [`ReplayResult`].
    pub fn into_result(self, label: &'static str) -> ReplayResult {
        if let Some(pool) = self.kv.pool() {
            pool.check_invariants()
                .expect("pool invariants after replay");
        }
        let stats = self.kv.stats().cloned().unwrap_or_default();
        let mut families: Vec<FamilyStats> =
            self.fam.into_values().collect();
        families.sort_by_key(|f| f.family);
        ReplayResult {
            label,
            slots: self.slots_n,
            decode_ticks: self.decode_ticks,
            ticks: self.ticks_seen,
            completed: self.completed,
            dropped: self.dropped,
            tokens_decoded: self.tokens_decoded,
            mean_occupancy: if self.decode_ticks == 0 {
                0.0
            } else {
                self.occupancy_sum as f64 / self.decode_ticks as f64
            },
            peak_occupancy: self.peak,
            mean_pool_utilization: if self.decode_ticks == 0 {
                0.0
            } else {
                self.util_sum / self.decode_ticks as f64
            },
            sim_time: self.now,
            ttft: self.ttft,
            tbt: self.tbt,
            max_tick_prefill_tokens: self.max_tick_prefill,
            transfer_time: self.transfer_time,
            transfer_bytes: self.transfer_bytes,
            shard_utilization: if self.decode_ticks == 0 {
                vec![0.0; self.shard_util_sums.len()]
            } else {
                self.shard_util_sums
                    .iter()
                    .map(|s| s / self.decode_ticks as f64)
                    .collect()
            },
            stats,
            families,
            outputs: self.outputs,
            ttft_by_request: self.ttft_by_req,
        }
    }
}

/// Replay the mix through a paged pool (`paged`) or the dense slot
/// baseline under the same byte budget.
pub fn replay(cfg: &ReplayConfig, paged: bool) -> ReplayResult {
    let mut w = SimWorker::new(cfg, paged);
    // Closed-loop arrival: the full mix queues up front (the regime
    // where admission policy, not arrival spacing, bounds occupancy).
    for req in generate_workload(cfg) {
        w.deliver(&req);
    }
    let mut guard = 0u64;
    while w.has_work() && guard < 1_000_000 {
        guard += 1;
        w.tick();
    }
    w.into_result(if paged { "paged" } else { "dense" })
}

/// [`replay`] with the live observability plane attached: the worker
/// publishes per-tick fleet samples into `live` (replica label `0`)
/// and flight-recorder events into `recorder`. Latency sketches carry
/// the simulated clock's unitless values — identical to the raw
/// values in the returned [`ReplayResult`] histograms, which is what
/// the streaming-vs-post-hoc acceptance check compares.
pub fn replay_live(cfg: &ReplayConfig, paged: bool,
                   live: &LiveMetrics, recorder: &FlightRecorder)
                   -> ReplayResult {
    let mut w = SimWorker::new(cfg, paged);
    w.attach_sampler(WorkerSampler::new(live.clone(),
                                        recorder.clone(), 0));
    for req in generate_workload(cfg) {
        w.deliver(&req);
    }
    let mut guard = 0u64;
    while w.has_work() && guard < 1_000_000 {
        guard += 1;
        w.tick();
    }
    w.into_result(if paged { "paged" } else { "dense" })
}

/// [`replay_live`] with the per-request causal ledger attached as
/// well: besides the fleet samples, every request's causal event
/// chain, cost buckets and page-seconds land in `ledger` (replica 0).
/// Pass `LiveMetrics::off()` / `FlightRecorder::disabled()` to run
/// ledger-only. Both planes observe the same run, which is what the
/// ledger-vs-live parity property tests compare.
pub fn replay_instrumented(cfg: &ReplayConfig, paged: bool,
                           live: &LiveMetrics,
                           recorder: &FlightRecorder,
                           ledger: &RequestLedger) -> ReplayResult {
    let mut w = SimWorker::new(cfg, paged);
    w.attach_sampler(WorkerSampler::new(live.clone(),
                                        recorder.clone(), 0));
    w.attach_ledger(ledger, 0);
    for req in generate_workload(cfg) {
        w.deliver(&req);
    }
    let mut guard = 0u64;
    while w.has_work() && guard < 1_000_000 {
        guard += 1;
        w.tick();
    }
    w.into_result(if paged { "paged" } else { "dense" })
}

/// Open-loop single-worker replay: requests are delivered at their
/// [`generate_arrivals`] timestamps instead of all at t = 0, and the
/// worker's clock jumps across idle gaps. TTFT now includes genuine
/// queueing delay — a burst stacks the queue and the tail pays for it
/// — which is the signal the autoscaled fleet replay
/// (`crate::routing::autoscale`) closes the loop on. With
/// `cfg.arrivals == None` every timestamp is 0 and this is exactly
/// [`replay`].
pub fn replay_open_loop(cfg: &ReplayConfig, paged: bool)
                        -> ReplayResult {
    let arrivals = generate_arrivals(cfg);
    let mut w = SimWorker::new(cfg, paged);
    let mut next = 0usize;
    let mut guard = 0u64;
    while (next < arrivals.len() || w.has_work())
        && guard < 2_000_000
    {
        guard += 1;
        // Idle with a future arrival pending: jump the clock to it
        // (open-loop hardware waits; the clock keeps running).
        if !w.has_work() && next < arrivals.len() {
            let t = arrivals[next].at;
            w.advance_to(t);
        }
        // Deliver everything that has arrived by the worker's now.
        while next < arrivals.len() && arrivals[next].at <= w.now() {
            let a = &arrivals[next];
            w.deliver_at(&a.req, a.at);
            next += 1;
        }
        if w.has_work() {
            w.tick();
        }
    }
    w.into_result(if paged { "paged" } else { "dense" })
}

/// Side-by-side table for `mmserve kv`.
pub fn render_comparison(paged: &ReplayResult, dense: &ReplayResult)
                         -> String {
    let mut t = Table::new(&["metric", "paged", "dense (same budget)"]);
    let f2 = |x: f64| format!("{x:.2}");
    t.row(&["slots".into(), paged.slots.to_string(),
            dense.slots.to_string()]);
    t.row(&["mean batch occupancy".into(), f2(paged.mean_occupancy),
            f2(dense.mean_occupancy)]);
    t.row(&["peak batch occupancy".into(),
            paged.peak_occupancy.to_string(),
            dense.peak_occupancy.to_string()]);
    t.row(&["decode ticks".into(), paged.decode_ticks.to_string(),
            dense.decode_ticks.to_string()]);
    t.row(&["requests completed".into(), paged.completed.to_string(),
            dense.completed.to_string()]);
    t.row(&["tokens decoded".into(), paged.tokens_decoded.to_string(),
            dense.tokens_decoded.to_string()]);
    t.row(&["mean pool utilization".into(),
            format!("{:.1}%", paged.mean_pool_utilization * 100.0),
            "-".into()]);
    t.row(&["prefix hit rate".into(),
            format!("{:.1}%", paged.stats.hit_rate() * 100.0),
            "-".into()]);
    t.row(&["preemptions".into(), paged.stats.preemptions.to_string(),
            "0".into()]);
    t.row(&["LRU evictions".into(), paged.stats.evictions.to_string(),
            "0".into()]);
    t.row(&["capacity-wait ticks".into(),
            paged.stats.capacity_wait_ticks.to_string(),
            "0".into()]);
    if paged.stats.beam_forks > 0 {
        // Beam reorder as page fork/prune (Obs #4): only a paged pool
        // can express it — dense slots would have copied the KV.
        t.row(&["beam forks (fork/prune)".into(),
                paged.stats.beam_forks.to_string(), "0".into()]);
    }
    if paged.transfer_bytes > 0 || paged.stats.swap_decisions > 0
        || paged.stats.recompute_decisions > 0
    {
        t.row(&["fabric transfer (sim)".into(),
                f2(paged.transfer_time), "-".into()]);
        t.row(&["fabric bytes moved".into(),
                paged.transfer_bytes.to_string(), "-".into()]);
        t.row(&["swap / recompute decisions".into(),
                format!("{}/{}", paged.stats.swap_decisions,
                        paged.stats.recompute_decisions),
                "0/0".into()]);
    }
    t.render()
}

/// Per-modality latency and attribution table for a mixed-fleet
/// replay (`mmserve kv --mix`): one row per request family with the
/// paper's per-modality lens — TTFT/TBT percentiles (Fig. 6/7), plus
/// simulated busy/idle attribution so batch interference between
/// chat, Seamless, and HSTU cohorts is visible per family.
pub fn render_family_table(r: &ReplayResult) -> String {
    let mut t = Table::new(&[
        "family", "requests", "completed", "mean TTFT", "p99 TTFT",
        "mean TBT", "p99 TBT", "busy (sim)", "batch idle (sim)",
    ]);
    let f2 = |x: f64| format!("{x:.2}");
    for f in &r.families {
        t.row(&[
            f.family.label().into(),
            f.requests.to_string(),
            f.completed.to_string(),
            f2(f.ttft.mean()),
            f2(f.ttft.percentile(99.0)),
            f2(f.tbt.mean()),
            f2(f.tbt.percentile(99.0)),
            f2(f.busy),
            f2(f.idle),
        ]);
    }
    t.render()
}

/// Whole-prompt vs. chunked prefill on the same mix — the simulated
/// TBT/TTFT interference comparison for `mmserve kv --chunk-prefill`.
pub fn render_chunk_comparison(whole: &ReplayResult,
                               chunked: &ReplayResult, chunk: usize)
                               -> String {
    let mut t = Table::new(&[
        "metric",
        "whole-prompt",
        &format!("chunked ({chunk} tok/tick)"),
    ]);
    let f2 = |x: f64| format!("{x:.2}");
    t.row(&["mean TBT (sim)".into(), f2(whole.tbt.mean()),
            f2(chunked.tbt.mean())]);
    t.row(&["p99 TBT (sim)".into(), f2(whole.tbt.percentile(99.0)),
            f2(chunked.tbt.percentile(99.0))]);
    t.row(&["max TBT (sim)".into(), f2(whole.tbt.max()),
            f2(chunked.tbt.max())]);
    t.row(&["p99 TTFT (sim)".into(), f2(whole.ttft.percentile(99.0)),
            f2(chunked.ttft.percentile(99.0))]);
    t.row(&["max prefill tokens / tick".into(),
            whole.max_tick_prefill_tokens.to_string(),
            chunked.max_tick_prefill_tokens.to_string()]);
    t.row(&["requests completed".into(), whole.completed.to_string(),
            chunked.completed.to_string()]);
    t.row(&["sim wall".into(), f2(whole.sim_time),
            f2(chunked.sim_time)]);
    t.render()
}

/// Percent rendering for per-shard utilization vectors ("61.2%/58.9%")
/// — shared with the routing replay's worker-counters table so the two
/// shard-occupancy reports can never format differently.
pub(crate) fn render_shard_util(util: &[f64]) -> String {
    if util.is_empty() {
        return "-".into();
    }
    util.iter()
        .map(|u| format!("{:.1}%", u * 100.0))
        .collect::<Vec<_>>()
        .join("/")
}

/// Sharded vs. monolithic page arena on the same mix — the
/// `mmserve kv --shards D` capacity table: identical aggregate budget,
/// split across `D` device arenas, with per-shard occupancy and the
/// cross-arena spill count.
pub fn render_shard_comparison(mono: &ReplayResult,
                               sharded: &ReplayResult, shards: usize)
                               -> String {
    let mut t = Table::new(&[
        "metric",
        "monolithic (1 arena)",
        &format!("sharded ({shards} arenas)"),
    ]);
    let f2 = |x: f64| format!("{x:.2}");
    t.row(&["mean batch occupancy".into(), f2(mono.mean_occupancy),
            f2(sharded.mean_occupancy)]);
    t.row(&["mean pool utilization".into(),
            format!("{:.1}%", mono.mean_pool_utilization * 100.0),
            format!("{:.1}%", sharded.mean_pool_utilization * 100.0)]);
    t.row(&["per-shard occupancy".into(),
            render_shard_util(&mono.shard_utilization),
            render_shard_util(&sharded.shard_utilization)]);
    t.row(&["shard spills".into(), mono.stats.shard_spills.to_string(),
            sharded.stats.shard_spills.to_string()]);
    t.row(&["prefix hit rate".into(),
            format!("{:.1}%", mono.stats.hit_rate() * 100.0),
            format!("{:.1}%", sharded.stats.hit_rate() * 100.0)]);
    t.row(&["preemptions".into(), mono.stats.preemptions.to_string(),
            sharded.stats.preemptions.to_string()]);
    t.row(&["LRU evictions".into(), mono.stats.evictions.to_string(),
            sharded.stats.evictions.to_string()]);
    t.row(&["requests completed".into(), mono.completed.to_string(),
            sharded.completed.to_string()]);
    t.row(&["sim wall".into(), f2(mono.sim_time),
            f2(sharded.sim_time)]);
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Acceptance criterion: the short-chat-heavy mix with a shared
    /// system prompt sustains strictly higher mean batch occupancy
    /// under paged allocation than dense slots get from the same page
    /// budget, with a nonzero prefix hit rate.
    #[test]
    fn paged_beats_dense_on_shared_prefix_chat_mix() {
        let cfg = ReplayConfig::default();
        let paged = replay(&cfg, true);
        let dense = replay(&cfg, false);
        assert_eq!(paged.completed, cfg.requests, "paged completes all");
        assert_eq!(dense.completed, cfg.requests, "dense completes all");
        assert_eq!(paged.dropped + dense.dropped, 0);
        assert!(
            paged.mean_occupancy > dense.mean_occupancy,
            "paged {:.2} must beat dense {:.2}",
            paged.mean_occupancy,
            dense.mean_occupancy
        );
        assert!(paged.stats.hit_rate() > 0.0, "system prompt must share");
        assert!(paged.stats.prefix_hit_tokens > 0);
        // Paged finishes the same work in fewer scheduler ticks.
        assert!(paged.decode_ticks < dense.decode_ticks);
    }

    #[test]
    fn tight_budget_exercises_preemption_and_still_completes() {
        let cfg = ReplayConfig {
            total_pages: 40,
            batch_slots: 12,
            ..ReplayConfig::default()
        };
        let r = replay(&cfg, true);
        assert_eq!(r.completed, cfg.requests, "no request lost: {r:?}");
        assert_eq!(r.dropped, 0);
        assert!(
            r.stats.preemptions > 0 || r.stats.evictions > 0,
            "a 40-page budget must create pressure: {:?}",
            r.stats
        );
        // Regression (review): a preemption victim's re-prefill must
        // not record a second TTFT sample — exactly one per request.
        assert_eq!(r.ttft.len(), r.completed,
                   "one TTFT sample per completed request");
    }

    #[test]
    fn replay_is_deterministic() {
        let cfg = ReplayConfig::default();
        let a = replay(&cfg, true);
        let b = replay(&cfg, true);
        assert_eq!(a.mean_occupancy, b.mean_occupancy);
        assert_eq!(a.decode_ticks, b.decode_ticks);
        assert_eq!(a.stats.prefix_hits, b.stats.prefix_hits);
        assert_eq!(a.stats.preemptions, b.stats.preemptions);
        assert_eq!(a.outputs, b.outputs);
    }

    /// With no arrival spec every timestamp is 0 — the open-loop
    /// driver must reproduce the closed-loop replay bit for bit.
    #[test]
    fn open_loop_without_arrivals_is_bit_identical_to_closed() {
        let cfg = ReplayConfig::default();
        let closed = replay(&cfg, true);
        let open = replay_open_loop(&cfg, true);
        assert_eq!(open.outputs, closed.outputs);
        assert_eq!(open.completed, closed.completed);
        assert_eq!(open.decode_ticks, closed.decode_ticks);
        assert_eq!(open.sim_time.to_bits(), closed.sim_time.to_bits());
        assert_eq!(open.stats.prefix_hits, closed.stats.prefix_hits);
    }

    /// Open-loop arrivals spread the queue out: the replay completes
    /// every arrival (base + burst + follow-ups), per-request TTFTs
    /// are recorded for all of them, and TTFT origin is the arrival
    /// time — never negative even when the worker's clock lags.
    #[test]
    fn open_loop_replay_serves_the_timestamped_stream() {
        let cfg = ReplayConfig {
            requests: 32,
            tenants: 3,
            arrivals: Some(
                crate::workload::arrivals::ArrivalSpec::parse(
                    "poisson:0.8+burst:20:15:3+followups:30",
                )
                .unwrap(),
            ),
            ..ReplayConfig::default()
        };
        let arrivals = generate_arrivals(&cfg);
        assert!(arrivals.len() > cfg.requests, "bursts + followups");
        let r = replay_open_loop(&cfg, true);
        assert_eq!(r.completed, arrivals.len(), "all arrivals served");
        assert_eq!(r.dropped, 0);
        assert_eq!(r.ttft_by_request.len(), r.completed);
        assert!(r.ttft_by_request.values().all(|&dt| dt >= 0.0),
                "TTFT can never precede arrival");
        // The clock ran at least to the last arrival.
        let last = arrivals.last().unwrap().at;
        assert!(r.sim_time >= last, "{} < {last}", r.sim_time);
        // Determinism holds under open loop too.
        let again = replay_open_loop(&cfg, true);
        assert_eq!(again.outputs, r.outputs);
        assert_eq!(again.sim_time.to_bits(), r.sim_time.to_bits());
    }

    #[test]
    fn workload_generation_is_seeded_and_tenant_aware() {
        let cfg = ReplayConfig::default();
        let a = generate_workload(&cfg);
        let b = generate_workload(&cfg);
        assert_eq!(a.len(), cfg.requests);
        assert!(a.iter().zip(&b).all(|(x, y)| x.tokens == y.tokens
            && x.decode == y.decode));
        // Single tenant: every prompt shares the system prefix.
        let sys = &a[0].tokens[..cfg.system_prompt_len];
        assert!(a.iter().all(|r| &r.tokens[..cfg.system_prompt_len]
            == sys));
        // Multi-tenant: distinct prefixes per tenant, all present.
        let cfg4 = ReplayConfig { tenants: 4, ..cfg };
        let w = generate_workload(&cfg4);
        let mut seen = std::collections::HashSet::new();
        for r in &w {
            assert!(r.tenant < 4);
            seen.insert(r.tenant);
        }
        assert_eq!(seen.len(), 4, "64 draws cover all 4 tenants");
        let p0 = w.iter().find(|r| r.tenant == 0).unwrap();
        let p1 = w.iter().find(|r| r.tenant == 1).unwrap();
        assert_ne!(&p0.tokens[..16], &p1.tokens[..16],
                   "tenants must not share blocks");
    }

    #[test]
    fn outputs_are_a_pure_function_of_the_request() {
        // prompt_len and decode count fully determine the stream.
        let cfg = ReplayConfig::default();
        let r = replay(&cfg, true);
        let w = generate_workload(&cfg);
        assert_eq!(r.outputs.len(), cfg.requests);
        for req in &w {
            let out = &r.outputs[&req.id];
            assert_eq!(out.len(), req.decode);
            let expect: Vec<i32> = (0..req.decode)
                .map(|k| 900 + ((req.tokens.len() + k) as i32 % 50))
                .collect();
            assert_eq!(out, &expect, "request {}", req.id);
        }
    }

    #[test]
    fn comparison_table_renders_counters() {
        let cfg = ReplayConfig { requests: 8, ..ReplayConfig::default() };
        let p = replay(&cfg, true);
        let d = replay(&cfg, false);
        let s = render_comparison(&p, &d);
        assert!(s.contains("mean batch occupancy"));
        assert!(s.contains("prefix hit rate"));
        assert!(s.contains("preemptions"));
    }

    fn long_mix() -> ReplayConfig {
        ReplayConfig {
            requests: 48,
            long_percent: 50,
            long_prompt: (96, 200),
            total_pages: 192,
            batch_slots: 12,
            ..ReplayConfig::default()
        }
    }

    /// Acceptance criterion (tentpole): on a long-prompt mix, chunked
    /// prefill bounds any tick's prefill load by the chunk budget, so
    /// the decode-tick latency tail (TBT) shrinks vs. whole-prompt
    /// admission, and every request still completes.
    #[test]
    fn chunked_prefill_bounds_tbt_tail_on_long_prompt_mix() {
        let chunk = 32usize;
        let whole = replay(&long_mix(), true);
        let chunked = replay(
            &ReplayConfig { chunk_prefill: chunk, ..long_mix() },
            true,
        );
        assert_eq!(whole.completed, 48);
        assert_eq!(chunked.completed, 48, "{chunked:?}");
        assert_eq!(whole.dropped + chunked.dropped, 0);
        // The scheduler property, observed end to end: no tick fed
        // more than the chunk budget.
        assert!(chunked.max_tick_prefill_tokens <= chunk,
                "tick fed {} > chunk {chunk}",
                chunked.max_tick_prefill_tokens);
        // The whole-prompt run stacks ≥ one full long prompt (> 96+48
        // tokens) into a single tick.
        assert!(whole.max_tick_prefill_tokens > chunk * 2,
                "whole mode should stack prompts: {}",
                whole.max_tick_prefill_tokens);
        // Per-tick cost is bounded ⇒ the TBT a decoding request can
        // experience is bounded by decode + chunk·token-cost.
        let bound =
            SIM_DECODE_COST + chunk as f64 * SIM_PREFILL_TOKEN_COST + 1e-9;
        assert!(chunked.tbt.max() <= bound,
                "chunked TBT {} > bound {bound}", chunked.tbt.max());
        assert!(whole.tbt.max() > bound,
                "whole-prompt TBT tail should exceed the chunk bound");
        assert!(chunked.tbt.percentile(99.0) < whole.tbt.percentile(99.0),
                "chunked p99 TBT {} !< whole {}",
                chunked.tbt.percentile(99.0),
                whole.tbt.percentile(99.0));
        let s = render_chunk_comparison(&whole, &chunked, chunk);
        assert!(s.contains("max prefill tokens / tick"));
    }

    /// Regression (review): a chunked prefill whose remaining chunks
    /// can never be granted pages must be shed, not livelock the
    /// scheduler — its first chunk fits, every later plan is blocked,
    /// and no decode work exists to free pages.
    #[test]
    fn wedged_chunked_prefill_is_shed_not_livelocked() {
        let cfg = ReplayConfig {
            requests: 1,
            system_prompt_len: 20,
            short_prompt: (80, 80),
            short_decode: (4, 8),
            long_percent: 0,
            page_size: 4,
            total_pages: 8, // 32 positions: a 100-token prompt never fits
            batch_slots: 2,
            chunk_prefill: 16,
            ..ReplayConfig::default()
        };
        let r = replay(&cfg, true);
        assert_eq!(r.completed, 0);
        assert_eq!(r.dropped, 1, "wedged prefill must be shed: {r:?}");
    }

    /// Acceptance criterion (tentpole): `shards: 1` is bit-identical
    /// to the pre-shard monolithic replay — same outputs, same pool
    /// counters, same clock — because a one-shard pool delegates every
    /// operation to a single arena with no policy branch.
    #[test]
    fn single_shard_replay_is_bit_identical_to_monolithic() {
        // The default config *is* the monolithic path (shards: 1);
        // spelling the flag out must change nothing.
        let mono = replay(&ReplayConfig::default(), true);
        let flagged = replay(
            &ReplayConfig { shards: 1, ..ReplayConfig::default() },
            true,
        );
        assert_eq!(flagged.outputs, mono.outputs, "token streams");
        assert_eq!(flagged.decode_ticks, mono.decode_ticks);
        assert_eq!(flagged.sim_time, mono.sim_time);
        assert_eq!(flagged.completed, mono.completed);
        assert_eq!(flagged.stats.prefix_lookups, mono.stats.prefix_lookups);
        assert_eq!(flagged.stats.prefix_hits, mono.stats.prefix_hits);
        assert_eq!(flagged.stats.blocks_allocated,
                   mono.stats.blocks_allocated);
        assert_eq!(flagged.stats.blocks_freed, mono.stats.blocks_freed);
        assert_eq!(flagged.stats.evictions, mono.stats.evictions);
        assert_eq!(flagged.stats.cow_forks, mono.stats.cow_forks);
        assert_eq!(flagged.stats.preemptions, mono.stats.preemptions);
        assert_eq!(flagged.stats.capacity_wait_ticks,
                   mono.stats.capacity_wait_ticks);
        assert_eq!(flagged.stats.shard_spills, 0, "one arena never spills");
        assert_eq!(flagged.mean_occupancy, mono.mean_occupancy);
        assert_eq!(flagged.mean_pool_utilization,
                   mono.mean_pool_utilization);
    }

    /// Tentpole: splitting the same page budget across device arenas
    /// keeps the workload fully servable — every request completes
    /// with the *same token streams* as the monolithic run (placement
    /// must never change results), per-shard occupancy is reported,
    /// and the per-shard means reconstruct the pool mean exactly when
    /// the arenas are equal-sized.
    #[test]
    fn sharded_replay_completes_with_identical_outputs() {
        let shards = 4; // 96 pages % 4 == 0: equal arenas
        let cfg = ReplayConfig::default();
        let mono = replay(&cfg, true);
        let sharded =
            replay(&ReplayConfig { shards, ..cfg.clone() }, true);
        assert_eq!(sharded.completed, cfg.requests);
        assert_eq!(sharded.dropped, 0);
        assert_eq!(sharded.outputs, mono.outputs,
                   "sharding moves pages, never tokens");
        assert_eq!(sharded.shard_utilization.len(), shards);
        assert!(sharded
            .shard_utilization
            .iter()
            .all(|&u| (0.0..=1.0).contains(&u)));
        let mean_of_shards: f64 = sharded.shard_utilization.iter().sum::<f64>()
            / shards as f64;
        assert!(
            (mean_of_shards - sharded.mean_pool_utilization).abs() < 1e-9,
            "equal arenas: shard means reconstruct the pool mean \
             ({mean_of_shards} vs {})",
            sharded.mean_pool_utilization
        );
        assert_eq!(sharded.stats.shard_allocated.len(), shards);
        assert_eq!(
            sharded.stats.shard_allocated.iter().sum::<u64>(),
            sharded.stats.blocks_allocated,
            "every fresh page lands on exactly one shard"
        );
        let s = render_shard_comparison(&mono, &sharded, shards);
        assert!(s.contains("per-shard occupancy"));
        assert!(s.contains("shard spills"));
        // Determinism of the sharded path.
        let again =
            replay(&ReplayConfig { shards, ..cfg.clone() }, true);
        assert_eq!(again.outputs, sharded.outputs);
        assert_eq!(again.stats.shard_allocated,
                   sharded.stats.shard_allocated);
        assert_eq!(again.stats.shard_spills, sharded.stats.shard_spills);
    }

    /// Satellite: the chunked-prefill page-claim path under real
    /// pressure — continuation chunks race decode growth on a tight
    /// sharded pool, so `extend_chunk` hits `CapacityExhausted`
    /// mid-prefill. That must surface as a structured requeue
    /// (recompute from the queue front), never a panic or a drop:
    /// every request still completes, on the monolithic and the
    /// sharded pool alike, with identical streams.
    #[test]
    fn chunk_exhaustion_mid_prefill_requeues_and_completes() {
        // The proven-tight budget of
        // `tight_budget_exercises_preemption_and_still_completes`,
        // with chunked admission on top: continuation page claims now
        // race decode growth.
        let base = ReplayConfig {
            total_pages: 40,
            batch_slots: 12,
            chunk_prefill: 12,
            ..ReplayConfig::default()
        };
        for shards in [1usize, 2, 3] {
            let r = replay(
                &ReplayConfig { shards, ..base.clone() },
                true,
            );
            assert_eq!(r.completed, base.requests,
                       "shards={shards}: every request completes");
            assert_eq!(r.dropped, 0, "shards={shards}: nothing shed");
            assert!(
                r.stats.preemptions + r.stats.evictions
                    + r.stats.capacity_wait_ticks
                    > 0,
                "shards={shards}: the tight budget must create the \
                 pressure this test is about: {:?}",
                r.stats
            );
            assert!(r.max_tick_prefill_tokens <= 12,
                    "chunk budget respected under pressure");
        }
        // Placement differences across shard counts never leak into
        // the decoded streams.
        let a = replay(&ReplayConfig { shards: 1, ..base.clone() }, true);
        let b = replay(&ReplayConfig { shards: 2, ..base.clone() }, true);
        assert_eq!(a.outputs, b.outputs);
    }

    #[test]
    fn chunked_replay_is_deterministic_and_checks_invariants() {
        let cfg = ReplayConfig {
            chunk_prefill: 24,
            ..ReplayConfig::default()
        };
        let a = replay(&cfg, true);
        let b = replay(&cfg, true);
        assert_eq!(a.completed, cfg.requests);
        assert_eq!(a.decode_ticks, b.decode_ticks);
        assert_eq!(a.sim_time, b.sim_time);
        assert_eq!(a.stats.preemptions, b.stats.preemptions);
    }

    /// Tentpole acceptance: the streaming sketches published mid-run
    /// match the post-hoc histograms of the same run at p50/p99
    /// within the sketch's relative-error bound, the fleet counters
    /// equal the replay's final totals — and the live plane is pure
    /// observation (attaching it changes nothing about the run).
    #[test]
    fn live_plane_matches_posthoc_and_changes_nothing() {
        use crate::telemetry::live::sampler::{
            PREEMPTIONS_TOTAL, QUEUE_DEPTH, REQUESTS_COMPLETED_TOTAL,
            TBT_MS, TOKENS_DECODED_TOTAL, TTFT_MS,
        };
        use crate::telemetry::live::sketch::DEFAULT_ALPHA;
        let cfg = ReplayConfig {
            tenants: 3,
            shards: 2,
            chunk_prefill: 24,
            ..ReplayConfig::default()
        };
        let live = LiveMetrics::new();
        let r = replay_live(&cfg, true, &live,
                            &FlightRecorder::disabled());
        let bare = replay(&cfg, true);
        assert_eq!(r.outputs, bare.outputs, "sampling must not perturb");
        assert_eq!(r.sim_time, bare.sim_time);
        assert_eq!(r.completed, cfg.requests);

        let snap = live.snapshot();
        let l = &[("replica", "0")][..];
        assert_eq!(snap.counter(REQUESTS_COMPLETED_TOTAL, l),
                   Some(r.completed as u64));
        assert_eq!(snap.counter(TOKENS_DECODED_TOTAL, l),
                   Some(r.tokens_decoded));
        assert_eq!(snap.counter(PREEMPTIONS_TOTAL, l),
                   Some(r.stats.preemptions));
        assert_eq!(snap.gauge(QUEUE_DEPTH, l), Some(0.0),
                   "drained at end of run");
        // Every tenant that sent work shows up as a sketch label.
        let mut expect: Vec<String> = generate_workload(&cfg)
            .iter()
            .map(|q| q.tenant.to_string())
            .collect();
        expect.sort();
        expect.dedup();
        assert_eq!(snap.sketch_label_values(TTFT_MS, "tenant"), expect);
        // Streaming quantiles vs the exact histograms of the same run.
        for (name, exact) in [(TTFT_MS, &r.ttft), (TBT_MS, &r.tbt)] {
            let merged = snap.merged_sketch(name, "replica", "0");
            assert_eq!(merged.count, exact.len() as u64, "{name} count");
            for p in [50.0, 99.0] {
                let s = merged.percentile(p);
                let e = exact.percentile(p);
                assert!(
                    (s - e).abs() <= DEFAULT_ALPHA * e + 1e-9,
                    "{name} p{p}: sketch {s} vs exact {e}"
                );
            }
        }
    }

    /// Flight-recorder acceptance: a killed replica dumps its last-N
    /// tick events as valid JSONL under reason `replica-crash`.
    #[test]
    fn killed_worker_dumps_valid_jsonl_flight_record() {
        use crate::substrate::json::Json;
        let live = LiveMetrics::new();
        let rec = FlightRecorder::new(32);
        let cfg = ReplayConfig::default();
        let mut w = SimWorker::new(&cfg, true);
        w.attach_sampler(WorkerSampler::new(live.clone(), rec.clone(),
                                            1));
        for req in generate_workload(&cfg) {
            w.deliver(&req);
        }
        for _ in 0..10 {
            w.tick();
        }
        assert!(rec.buffered() > 0, "tick events recorded");
        let evacuated = w.kill();
        assert!(!evacuated.is_empty(), "mid-run kill evacuates work");
        // Other dump reasons (preempt-storm, a parallel test's
        // sigterm) may coexist; exactly one crash dump.
        let dumps = rec.dumps();
        let crash: Vec<_> = dumps
            .iter()
            .filter(|d| d.reason == "replica-crash")
            .collect();
        assert_eq!(crash.len(), 1);
        let mut lines = crash[0].jsonl.lines();
        let header = Json::parse(lines.next().expect("header line"))
            .expect("header is valid JSON");
        assert_eq!(header.get("flight_dump").and_then(|j| j.as_str()),
                   Some("replica-crash"));
        let mut events = 0usize;
        for line in lines {
            let ev = Json::parse(line).expect("event is valid JSON");
            assert!(ev.get("seq").is_some(), "monotone seq: {line}");
            assert_eq!(ev.get("kind").and_then(|j| j.as_str()),
                       Some("tick"));
            events += 1;
        }
        assert!(events > 0 && events <= 32, "bounded ring: {events}");
    }

    /// Tentpole acceptance: on the proven-tight sharded chunked mix,
    /// the causal ledger tells a complete, internally consistent
    /// story per request — well-formed event chains, cost buckets
    /// that reconcile with the replay's own totals — while remaining
    /// pure observation (identical outputs and clock).
    #[test]
    fn ledger_records_causal_chains_and_cost_buckets() {
        use crate::substrate::json::Json;
        let cfg = ReplayConfig {
            total_pages: 40,
            batch_slots: 12,
            chunk_prefill: 12,
            shards: 2,
            ..ReplayConfig::default()
        };
        let bare = replay(&cfg, true);
        let ledger = RequestLedger::new();
        let r = replay_instrumented(&cfg, true, &LiveMetrics::off(),
                                    &FlightRecorder::disabled(),
                                    &ledger);
        assert_eq!(r.outputs, bare.outputs, "ledger must not perturb");
        assert_eq!(r.sim_time, bare.sim_time);
        assert_eq!(r.completed, cfg.requests);
        assert!(r.ticks >= r.decode_ticks);
        let snap = ledger.snapshot();
        assert_eq!(snap.requests.len(), cfg.requests);
        assert_eq!(snap.completed().len(), cfg.requests);
        let mut decoded_total = 0u64;
        let mut preempt_total = 0u64;
        let mut spill_total = 0u64;
        for rec in &snap.requests {
            let labels: Vec<&str> =
                rec.events.iter().map(|e| e.ev.label()).collect();
            assert_eq!(labels.first(), Some(&"enqueued"),
                       "req {}", rec.id);
            assert_eq!(labels.last(), Some(&"completed"),
                       "req {}", rec.id);
            assert!(labels.contains(&"admitted"));
            assert!(labels.contains(&"first-token"));
            assert_eq!(rec.decoded as usize, r.outputs[&rec.id].len());
            assert!(rec.prefilled_tokens >= rec.prompt_len,
                    "recompute only ever adds prefill work");
            let ttft = rec.ttft().expect("first token recorded");
            let latency = rec.latency().expect("completed");
            assert!(ttft > 0.0 && latency >= ttft, "req {}", rec.id);
            assert!(rec.page_seconds > 0.0, "req {} held pages", rec.id);
            assert!(rec.decode_compute > 0.0);
            assert_eq!(rec.tbt.len(), rec.decoded as usize);
            decoded_total += rec.decoded;
            preempt_total += rec.preemptions;
            spill_total += rec.spills;
        }
        assert_eq!(decoded_total, r.tokens_decoded);
        assert_eq!(preempt_total, r.stats.preemptions,
                   "every pool preemption is attributed to a victim");
        assert!(preempt_total > 0, "the tight budget must preempt");
        assert!(spill_total <= r.stats.shard_spills);
        // The pressured mix must exercise the waiting buckets.
        assert!(snap.requests.iter().any(|rec| rec.queue_time > 0.0
            || rec.capacity_wait_time > 0.0
            || rec.preempted_time > 0.0));
        let jsonl = snap.to_jsonl();
        assert_eq!(jsonl.lines().count(), cfg.requests);
        for line in jsonl.lines() {
            Json::parse(line).expect("valid ledger JSONL");
        }
    }

    /// Satellite: ledger/live parity — on random mixes the two planes
    /// observe the *same* TTFT/TBT samples (equal counts; rank-matched
    /// quantiles within the sketch's relative-error bound) and the
    /// instrumented run is bit-identical to the bare one.
    #[test]
    fn prop_ledger_live_parity() {
        use crate::substrate::prop::prop_check;
        use crate::telemetry::live::sampler::{TBT_MS, TTFT_MS};
        use crate::telemetry::live::sketch::DEFAULT_ALPHA;
        fn exact_pct(vals: &[f64], p: f64) -> f64 {
            let mut v = vals.to_vec();
            v.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
            if v.is_empty() {
                return 0.0;
            }
            let rank =
                ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
            v[rank.min(v.len() - 1)]
        }
        prop_check(
            48,
            0x1ed6e4,
            |rng| {
                ((rng.usize(8, 41), rng.usize(1, 4)),
                 (rng.usize(0, 3) * 12, rng.usize(1, 4)))
            },
            |&((requests, tenants), (chunk, shards))| {
                let cfg = ReplayConfig {
                    requests: requests.max(1),
                    tenants: tenants.max(1),
                    chunk_prefill: chunk,
                    shards: shards.max(1),
                    ..ReplayConfig::default()
                };
                let bare = replay(&cfg, true);
                let live = LiveMetrics::new();
                let ledger = RequestLedger::new();
                let r = replay_instrumented(
                    &cfg, true, &live, &FlightRecorder::disabled(),
                    &ledger);
                if r.outputs != bare.outputs {
                    return Err("instrumented outputs diverged".into());
                }
                if r.sim_time != bare.sim_time {
                    return Err(format!(
                        "clock perturbed: {} vs {}",
                        r.sim_time, bare.sim_time));
                }
                let snap = live.snapshot();
                let led = ledger.snapshot();
                for (name, vals) in [(TTFT_MS, led.ttft_values()),
                                     (TBT_MS, led.tbt_values())] {
                    let merged =
                        snap.merged_sketch(name, "replica", "0");
                    if merged.count != vals.len() as u64 {
                        return Err(format!(
                            "{name}: ledger {} vs live {} samples",
                            vals.len(), merged.count));
                    }
                    for p in [50.0, 99.0] {
                        let s = merged.percentile(p);
                        let e = exact_pct(&vals, p);
                        if (s - e).abs() > DEFAULT_ALPHA * e + 1e-9 {
                            return Err(format!(
                                "{name} p{p}: ledger {e} vs \
                                 sketch {s}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    /// Tentpole: on the proven-tight budget, a paper-priced fabric
    /// turns preemption into a measured swap-vs-recompute decision —
    /// at 7B KV geometry the swap round trip beats recompute, so
    /// victims ride the host link and every reserved host byte is
    /// released by the end (conservation), while the run still
    /// completes everything with the same position-pure streams.
    #[test]
    fn priced_replay_swaps_instead_of_recomputing() {
        let base = ReplayConfig {
            total_pages: 40,
            batch_slots: 12,
            ..ReplayConfig::default()
        };
        let legacy = replay(&base, true);
        assert!(legacy.stats.preemptions > 0, "budget must be tight");
        let priced = replay(
            &ReplayConfig {
                fabric: Some(FabricSpec::paper(524_288.0)),
                ..base
            },
            true,
        );
        assert_eq!(priced.completed, base.requests);
        assert_eq!(priced.dropped, 0);
        assert_eq!(priced.outputs, legacy.outputs,
                   "pricing moves bytes, never tokens");
        assert!(priced.stats.swap_decisions > 0,
                "7B geometry makes swap the cheap eviction: {:?}",
                priced.stats);
        assert!(priced.stats.host_bytes_reserved > 0);
        assert_eq!(priced.stats.host_bytes_reserved,
                   priced.stats.host_bytes_released,
                   "every staged host byte returns to the budget");
        assert!(priced.transfer_bytes > 0);
        assert!(priced.transfer_time > 0.0);
        let s = render_comparison(&priced, &replay(&base, false));
        assert!(s.contains("swap / recompute decisions"));
    }

    /// Satellite (bisimulation guard, spot check — the 512-case
    /// property version lives in `tests/property_kvpool.rs`): the
    /// zero-cost fabric prices every comparison at a tie, ties break
    /// to the legacy rules, so the whole replay is bit-identical.
    #[test]
    fn zero_cost_fabric_replay_is_bit_identical() {
        for shards in [1usize, 2] {
            let base = ReplayConfig {
                total_pages: 40,
                batch_slots: 12,
                shards,
                ..ReplayConfig::default()
            };
            let legacy = replay(&base, true);
            let zero = replay(
                &ReplayConfig {
                    fabric: Some(FabricSpec::zero_cost()),
                    ..base
                },
                true,
            );
            assert_eq!(zero.outputs, legacy.outputs, "shards={shards}");
            assert_eq!(zero.sim_time, legacy.sim_time);
            assert_eq!(zero.decode_ticks, legacy.decode_ticks);
            assert_eq!(zero.stats, legacy.stats,
                       "shards={shards}: counters bit-identical");
            assert_eq!(zero.stats.swap_decisions, 0);
            assert_eq!(zero.transfer_bytes, 0);
            assert_eq!(zero.transfer_time, 0.0);
        }
    }

    /// Tentpole (disaggregation): a prefill worker ships finished
    /// prompts' KV over the priced inter-replica link to a decode
    /// worker. Streams stay position-pure (identical to colocated),
    /// the handoff is explicitly priced (non-zero transfer), and the
    /// decode worker never runs a prefill token.
    #[test]
    fn prefill_worker_ships_kv_and_decode_worker_serves_it() {
        let cfg = ReplayConfig {
            fabric: Some(FabricSpec::paper(524_288.0)),
            ..ReplayConfig::default()
        };
        let mut pre = SimWorker::new(&cfg, true);
        pre.set_role(SimRole::Prefill);
        let mut dec = SimWorker::new(&cfg, true);
        dec.set_role(SimRole::Decode);
        assert_eq!(pre.role(), SimRole::Prefill);
        for req in generate_workload(&cfg) {
            pre.deliver(&req);
        }
        let mut guard = 0u64;
        while (pre.has_work() || dec.has_work()) && guard < 100_000 {
            guard += 1;
            pre.tick();
            dec.tick();
            for h in pre.take_handoffs() {
                dec.deliver_handoff(h);
            }
        }
        let p = pre.into_result("prefill");
        let d = dec.into_result("decode");
        assert_eq!(p.completed, 0, "prefill workers never decode");
        assert_eq!(p.ttft.len(), 0, "first token belongs to decode");
        assert_eq!(d.completed, cfg.requests, "{d:?}");
        assert_eq!(p.dropped + d.dropped, 0);
        assert_eq!(d.max_tick_prefill_tokens, 0,
                   "no prefill compute on the decode worker");
        assert_eq!(d.ttft.len(), cfg.requests);
        // Streams are position-pure: identical to a colocated run.
        let colo = replay(&cfg, true);
        assert_eq!(d.outputs, colo.outputs);
        // The handoff cost is real and explicitly priced.
        assert!(d.transfer_bytes > 0);
        assert!(d.transfer_time > 0.0);
        // TTFT covers queue + prefill + transfer: the fleet's slowest
        // first token is later than a pure prefill would be.
        assert!(d.ttft.percentile(50.0) > 0.0);
    }

    /// Tentpole acceptance: chat + Seamless + HSTU in one replay,
    /// completing deterministically with per-modality TTFT/TBT and
    /// idle attribution.
    #[test]
    fn mixed_fleet_replay_reports_per_modality_latency() {
        let mix = MixSpec::parse("seamless:30,hstu:30", 2).unwrap();
        let cfg = ReplayConfig {
            mix: Some(mix),
            ..ReplayConfig::default()
        };
        let a = replay(&cfg, true);
        let b = replay(&cfg, true);
        assert_eq!(a.outputs, b.outputs, "mixed replay is deterministic");
        assert_eq!(a.sim_time, b.sim_time);
        assert_eq!(a.stats.beam_forks, b.stats.beam_forks);
        assert_eq!(a.completed, cfg.requests);
        // Per-family slices cover the workload exactly.
        let w = generate_workload(&cfg);
        let mut expect: HashMap<SimFamily, usize> = HashMap::new();
        for r in &w {
            *expect.entry(r.family).or_default() += 1;
        }
        assert_eq!(expect.len(), 3, "64 draws cover all three families");
        assert_eq!(a.families.len(), 3);
        for f in &a.families {
            assert_eq!(f.requests, expect[&f.family], "{:?}", f.family);
            assert_eq!(f.completed, f.requests, "{:?}", f.family);
            assert_eq!(f.ttft.len(), f.requests,
                       "one TTFT per request: {:?}", f.family);
        }
        let hstu = a.families.iter()
            .find(|f| f.family == SimFamily::Hstu).unwrap();
        assert!(hstu.tbt.is_empty(), "zero decode ticks (Obs #1)");
        assert_eq!(hstu.idle, 0.0, "no batch interference without decode");
        let seam = a.families.iter()
            .find(|f| f.family == SimFamily::Seamless).unwrap();
        assert!(!seam.tbt.is_empty());
        // Width 2: exactly one fork/prune per Seamless decode
        // participation, and nobody else forks.
        assert_eq!(a.stats.beam_forks, seam.tbt.len() as u64);
        // HSTU streams are empty (first token = result); the
        // autoregressive families decode their full budgets.
        for r in &w {
            match r.family {
                SimFamily::Hstu => assert!(a.outputs[&r.id].is_empty()),
                _ => assert_eq!(a.outputs[&r.id].len(), r.decode,
                                "request {}", r.id),
            }
        }
        let s = render_family_table(&a);
        assert!(s.contains("chat") && s.contains("seamless")
                && s.contains("hstu"));
    }

    /// Obs #4 expressed in pages: beam reorder is refcount fork/prune,
    /// so widening the beam moves *only* the `beam_forks` counter —
    /// streams, clock, completions, and preemptions are bit-identical.
    #[test]
    fn beam_width_never_perturbs_streams_or_clock() {
        let mk = |beam| ReplayConfig {
            mix: Some(MixSpec::parse("seamless:100", beam).unwrap()),
            ..ReplayConfig::default()
        };
        let b1 = replay(&mk(1), true);
        let b4 = replay(&mk(4), true);
        assert_eq!(b1.stats.beam_forks, 0, "width 1 never forks");
        assert!(b4.stats.beam_forks > 0, "width 4 forks siblings");
        assert_eq!(b4.stats.beam_forks % 3, 0,
                   "three siblings per participation");
        assert_eq!(b4.outputs, b1.outputs);
        assert_eq!(b4.sim_time, b1.sim_time);
        assert_eq!(b4.completed, b1.completed);
        assert_eq!(b4.stats.preemptions, b1.stats.preemptions);
    }

    /// Obs #1: an all-HSTU stream is served entirely as prefill-only
    /// plans — the replay completes without a single decode tick.
    #[test]
    fn hstu_only_mix_is_prefill_only() {
        let cfg = ReplayConfig {
            mix: Some(MixSpec::parse("hstu:100", 2).unwrap()),
            ..ReplayConfig::default()
        };
        let r = replay(&cfg, true);
        assert_eq!(r.completed, cfg.requests, "{r:?}");
        assert_eq!(r.decode_ticks, 0, "zero decode ticks");
        assert_eq!(r.tokens_decoded, 0);
        assert_eq!(r.ttft.len(), cfg.requests,
                   "the first token is the result");
        assert!(r.tbt.is_empty());
        assert!(r.outputs.values().all(|o| o.is_empty()));
        assert_eq!(r.stats.beam_forks, 0);
        assert!(r.sim_time > 0.0, "prefill compute still costs");
    }

    #[test]
    fn mix_spec_parses_and_rejects_garbage() {
        let m = MixSpec::parse("seamless:25,hstu:10", 3).unwrap();
        assert_eq!(m, MixSpec {
            seamless_percent: 25,
            hstu_percent: 10,
            beam: 3,
        });
        // Empty spec: pure chat; width clamps into 1..=32.
        let m = MixSpec::parse("", 0).unwrap();
        assert_eq!((m.seamless_percent, m.hstu_percent, m.beam),
                   (0, 0, 1));
        assert_eq!(MixSpec::parse("chat:40,hstu:60", 40).unwrap().beam,
                   32);
        assert!(MixSpec::parse("vision:10", 2).is_err());
        assert!(MixSpec::parse("seamless:999,hstu:0", 2).is_err());
        assert!(MixSpec::parse("seamless", 2).is_err());
    }

    /// Guard for every pre-mix caller: without a [`MixSpec`] the
    /// workload is pure chat (nonzero decode everywhere) and the
    /// result carries a single Chat family slice.
    #[test]
    fn no_mix_keeps_every_request_chat_with_nonzero_decode() {
        let cfg = ReplayConfig::default();
        let w = generate_workload(&cfg);
        assert!(w.iter()
            .all(|r| r.family == SimFamily::Chat && r.decode > 0));
        let r = replay(&cfg, true);
        assert_eq!(r.families.len(), 1);
        assert_eq!(r.families[0].family, SimFamily::Chat);
        assert_eq!(r.families[0].completed, r.completed);
    }
}
