//! Deterministic workload replay: paged pool vs. dense slots under the
//! same page budget.
//!
//! Drives a mixed request stream (short-chat-heavy, shared system
//! prompt, a long-document tail) through the real admission path — the
//! continuous [`Batcher`] over a [`PagedKvSlots`] view — one scheduler
//! tick per batched decode step, exactly like the serving loop but
//! without a device. The dense baseline gets the *same byte budget*
//! expressed as worst-case slots (`pages · page_size / max_seq`); the
//! paged run gets it as pages. The difference in sustained batch
//! occupancy is the paper's Table-3 capacity lever, measured end to
//! end with the pool's own telemetry counters.

use std::collections::HashMap;

use crate::coordinator::batcher::{Batcher, QueuedRequest};
use crate::coordinator::kv::PagedKvSlots;
use crate::substrate::rng::Rng;
use crate::substrate::table::Table;

use super::{KvError, KvPoolConfig, PoolStats, PreemptMode};

/// The replayed request mix (defaults: short-chat-heavy with a shared
/// system prompt — the regime where paging pays most).
#[derive(Debug, Clone)]
pub struct ReplayConfig {
    pub requests: usize,
    /// Shared system-prompt length (tokens) prefixed to every prompt.
    pub system_prompt_len: usize,
    /// Unique prompt-suffix length range for short chats (inclusive).
    pub short_prompt: (usize, usize),
    pub short_decode: (usize, usize),
    /// Long-document tail of the mix.
    pub long_prompt: (usize, usize),
    pub long_decode: (usize, usize),
    /// Percent of requests drawn from the long ranges.
    pub long_percent: usize,
    pub page_size: usize,
    /// The shared capacity budget, in pages.
    pub total_pages: usize,
    /// Decode-graph batch for the paged run (the dense run's slot count
    /// is derived from the page budget instead).
    pub batch_slots: usize,
    pub max_seq: usize,
    pub prefill_budget: usize,
    pub seed: u64,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        ReplayConfig {
            requests: 64,
            system_prompt_len: 48,
            short_prompt: (4, 24),
            short_decode: (8, 32),
            long_prompt: (64, 160),
            long_decode: (32, 96),
            long_percent: 20,
            page_size: 16,
            total_pages: 96,
            batch_slots: 16,
            max_seq: 512,
            prefill_budget: 0,
            seed: 7,
        }
    }
}

impl ReplayConfig {
    /// Worst-case slots the dense baseline gets from the same budget.
    pub fn dense_slots(&self) -> usize {
        (self.total_pages * self.page_size / self.max_seq).max(1)
    }
}

/// One replay's outcome.
#[derive(Debug, Clone)]
pub struct ReplayResult {
    pub label: &'static str,
    pub slots: usize,
    pub decode_ticks: u64,
    pub completed: usize,
    pub dropped: usize,
    pub tokens_decoded: u64,
    /// Mean live requests per decode tick — the Table-3 headline.
    pub mean_occupancy: f64,
    pub peak_occupancy: usize,
    /// Mean live-page fraction of the budget (paged runs only).
    pub mean_pool_utilization: f64,
    /// Pool counters (zeros for the dense baseline).
    pub stats: PoolStats,
}

struct Pending {
    tokens: Vec<i32>,
    remaining: usize,
}

/// Replay the mix through a paged pool (`paged`) or the dense slot
/// baseline under the same byte budget.
pub fn replay(cfg: &ReplayConfig, paged: bool) -> ReplayResult {
    let slots = if paged { cfg.batch_slots } else { cfg.dense_slots() };
    let mut kv = if paged {
        PagedKvSlots::paged(slots, cfg.max_seq, KvPoolConfig {
            page_size: cfg.page_size,
            total_pages: cfg.total_pages,
        })
    } else {
        PagedKvSlots::dense(slots, cfg.max_seq)
    };
    let mut batcher = Batcher::new(cfg.prefill_budget);
    let mut staging: HashMap<u64, Pending> = HashMap::new();
    let mut remaining: HashMap<u64, usize> = HashMap::new();

    // Closed-loop arrival: the full mix queues up front (the regime
    // where admission policy, not arrival spacing, bounds occupancy).
    let mut rng = Rng::new(cfg.seed);
    let sys: Vec<i32> = (0..cfg.system_prompt_len)
        .map(|i| (i % 200) as i32)
        .collect();
    for i in 0..cfg.requests {
        let id = i as u64 + 1;
        let long = rng.usize(0, 100) < cfg.long_percent;
        let (pr, dr) = if long {
            (cfg.long_prompt, cfg.long_decode)
        } else {
            (cfg.short_prompt, cfg.short_decode)
        };
        let extra = rng.usize(pr.0, pr.1 + 1);
        let decode = rng.usize(dr.0, dr.1 + 1).max(1);
        let mut tokens = sys.clone();
        tokens.extend((0..extra).map(|_| rng.range(300, 800) as i32));
        batcher.push(QueuedRequest {
            id,
            prompt_len: tokens.len(),
            max_new_tokens: decode,
        });
        staging.insert(id, Pending { tokens, remaining: decode });
    }

    let mut decode_ticks = 0u64;
    let mut occupancy_sum = 0u64;
    let mut peak = 0usize;
    let mut completed = 0usize;
    let mut dropped = 0usize;
    let mut tokens_decoded = 0u64;
    let mut util_sum = 0.0f64;
    let mut stalled = 0usize;

    while (batcher.pending() > 0 || kv.live_count() > 0)
        && decode_ticks < 1_000_000
    {
        // ---- admission -------------------------------------------------
        let view = kv.capacity_view();
        let adm = batcher.tick(&view);
        if adm.blocked_on_capacity {
            kv.note_capacity_wait();
        }
        if adm.admit.is_empty() && kv.live_count() == 0 {
            // Nothing live and nothing admissible: a request larger
            // than the whole budget would stall forever — drop it.
            stalled += 1;
            if stalled > 2 {
                if let Some(q) = batcher.pop_front() {
                    staging.remove(&q.id);
                    dropped += 1;
                }
                stalled = 0;
            }
            continue;
        }
        stalled = 0;
        for q in adm.admit {
            let Some(p) = staging.remove(&q.id) else { continue };
            match kv.alloc(q.id, &p.tokens) {
                Ok(_) => {
                    remaining.insert(q.id, p.remaining);
                }
                Err(KvError::CapacityExhausted { .. }) => {
                    // Growth raced the view; retry next tick.
                    batcher.push_front(QueuedRequest {
                        id: q.id,
                        prompt_len: p.tokens.len(),
                        max_new_tokens: p.remaining,
                    });
                    staging.insert(q.id, p);
                }
                Err(_) => {
                    dropped += 1;
                }
            }
        }

        // ---- one batched decode step ----------------------------------
        if kv.live_count() == 0 {
            continue;
        }
        decode_ticks += 1;
        let live = kv.live_slots();
        occupancy_sum += live.len() as u64;
        peak = peak.max(live.len());
        if let Some(pool) = kv.pool() {
            util_sum +=
                pool.live_pages() as f64 / pool.total_pages() as f64;
        }
        for (slot, req, pos) in live {
            // A preemption earlier in this step may have freed the slot.
            if kv.slot_of(req) != Some(slot) {
                continue;
            }
            let rem = {
                let r = remaining.get_mut(&req).expect("live job");
                *r -= 1;
                *r
            };
            tokens_decoded += 1;
            if rem == 0 {
                kv.release(slot).expect("live slot");
                remaining.remove(&req);
                completed += 1;
                continue;
            }
            let tok = 900 + (pos as i32 % 50);
            match kv.advance(slot, tok) {
                Ok(_) => {}
                Err(KvError::MaxSeq { .. }) => {
                    // Sequence cap: finish early, like the server loop.
                    kv.release(slot).expect("live slot");
                    remaining.remove(&req);
                    completed += 1;
                }
                Err(KvError::CapacityExhausted { .. }) => {
                    // Decode outgrew the pool: preempt (latest-admitted
                    // first) until the advance fits or we evicted
                    // ourselves.
                    loop {
                        let Some((_vslot, pre)) =
                            kv.preempt(PreemptMode::Recompute)
                        else {
                            break;
                        };
                        let rem_v =
                            remaining.remove(&pre.request).unwrap_or(0);
                        batcher.push_front(QueuedRequest {
                            id: pre.request,
                            prompt_len: pre.tokens.len(),
                            max_new_tokens: rem_v,
                        });
                        staging.insert(pre.request, Pending {
                            tokens: pre.tokens,
                            remaining: rem_v,
                        });
                        if pre.request == req {
                            break; // we evicted ourselves; resume later
                        }
                        match kv.advance(slot, tok) {
                            Ok(_) => break,
                            Err(KvError::CapacityExhausted { .. }) => {}
                            Err(_) => {
                                kv.release(slot).expect("live slot");
                                remaining.remove(&req);
                                completed += 1;
                                break;
                            }
                        }
                    }
                }
                Err(_) => {
                    kv.release(slot).expect("live slot");
                    remaining.remove(&req);
                    completed += 1;
                }
            }
        }
    }

    if let Some(pool) = kv.pool() {
        pool.check_invariants().expect("pool invariants after replay");
    }
    let stats = kv.stats().cloned().unwrap_or_default();
    ReplayResult {
        label: if paged { "paged" } else { "dense" },
        slots,
        decode_ticks,
        completed,
        dropped,
        tokens_decoded,
        mean_occupancy: if decode_ticks == 0 {
            0.0
        } else {
            occupancy_sum as f64 / decode_ticks as f64
        },
        peak_occupancy: peak,
        mean_pool_utilization: if decode_ticks == 0 {
            0.0
        } else {
            util_sum / decode_ticks as f64
        },
        stats,
    }
}

/// Side-by-side table for `mmserve kv`.
pub fn render_comparison(paged: &ReplayResult, dense: &ReplayResult)
                         -> String {
    let mut t = Table::new(&["metric", "paged", "dense (same budget)"]);
    let f2 = |x: f64| format!("{x:.2}");
    t.row(&["slots".into(), paged.slots.to_string(),
            dense.slots.to_string()]);
    t.row(&["mean batch occupancy".into(), f2(paged.mean_occupancy),
            f2(dense.mean_occupancy)]);
    t.row(&["peak batch occupancy".into(),
            paged.peak_occupancy.to_string(),
            dense.peak_occupancy.to_string()]);
    t.row(&["decode ticks".into(), paged.decode_ticks.to_string(),
            dense.decode_ticks.to_string()]);
    t.row(&["requests completed".into(), paged.completed.to_string(),
            dense.completed.to_string()]);
    t.row(&["tokens decoded".into(), paged.tokens_decoded.to_string(),
            dense.tokens_decoded.to_string()]);
    t.row(&["mean pool utilization".into(),
            format!("{:.1}%", paged.mean_pool_utilization * 100.0),
            "-".into()]);
    t.row(&["prefix hit rate".into(),
            format!("{:.1}%", paged.stats.hit_rate() * 100.0),
            "-".into()]);
    t.row(&["preemptions".into(), paged.stats.preemptions.to_string(),
            "0".into()]);
    t.row(&["LRU evictions".into(), paged.stats.evictions.to_string(),
            "0".into()]);
    t.row(&["capacity-wait ticks".into(),
            paged.stats.capacity_wait_ticks.to_string(),
            "0".into()]);
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Acceptance criterion: the short-chat-heavy mix with a shared
    /// system prompt sustains strictly higher mean batch occupancy
    /// under paged allocation than dense slots get from the same page
    /// budget, with a nonzero prefix hit rate.
    #[test]
    fn paged_beats_dense_on_shared_prefix_chat_mix() {
        let cfg = ReplayConfig::default();
        let paged = replay(&cfg, true);
        let dense = replay(&cfg, false);
        assert_eq!(paged.completed, cfg.requests, "paged completes all");
        assert_eq!(dense.completed, cfg.requests, "dense completes all");
        assert_eq!(paged.dropped + dense.dropped, 0);
        assert!(
            paged.mean_occupancy > dense.mean_occupancy,
            "paged {:.2} must beat dense {:.2}",
            paged.mean_occupancy,
            dense.mean_occupancy
        );
        assert!(paged.stats.hit_rate() > 0.0, "system prompt must share");
        assert!(paged.stats.prefix_hit_tokens > 0);
        // Paged finishes the same work in fewer scheduler ticks.
        assert!(paged.decode_ticks < dense.decode_ticks);
    }

    #[test]
    fn tight_budget_exercises_preemption_and_still_completes() {
        let cfg = ReplayConfig {
            total_pages: 40,
            batch_slots: 12,
            ..ReplayConfig::default()
        };
        let r = replay(&cfg, true);
        assert_eq!(r.completed, cfg.requests, "no request lost: {r:?}");
        assert_eq!(r.dropped, 0);
        assert!(
            r.stats.preemptions > 0 || r.stats.evictions > 0,
            "a 40-page budget must create pressure: {:?}",
            r.stats
        );
    }

    #[test]
    fn replay_is_deterministic() {
        let cfg = ReplayConfig::default();
        let a = replay(&cfg, true);
        let b = replay(&cfg, true);
        assert_eq!(a.mean_occupancy, b.mean_occupancy);
        assert_eq!(a.decode_ticks, b.decode_ticks);
        assert_eq!(a.stats.prefix_hits, b.stats.prefix_hits);
        assert_eq!(a.stats.preemptions, b.stats.preemptions);
    }

    #[test]
    fn comparison_table_renders_counters() {
        let cfg = ReplayConfig { requests: 8, ..ReplayConfig::default() };
        let p = replay(&cfg, true);
        let d = replay(&cfg, false);
        let s = render_comparison(&p, &d);
        assert!(s.contains("mean batch occupancy"));
        assert!(s.contains("prefix hit rate"));
        assert!(s.contains("preemptions"));
    }
}
