//! Multi-GPU page sharding: the [`BlockPool`] budget split across `D`
//! simulated device arenas.
//!
//! Tensor-parallel serving needs its KV capacity spread over every
//! device's HBM — a single per-worker arena caps the achievable batch
//! at one device's memory (the capacity half of the paper's
//! multi-device lever; cf. *Inference Optimization of Foundation
//! Models on AI Accelerators*). [`ShardedBlockPool`] models that
//! split: each shard is its own ref-counted [`BlockPool`] arena, and a
//! page's *global* id encodes `(device, page)` — [`locate`] maps a
//! global id to its shard and arena-local index, [`global`] maps back.
//! Block tables keep storing global ids, so one sequence's pages can
//! **span shards**: growth prefers the sequence's current shard (the
//! locality a device-side allocator would want) and *spills* to the
//! emptiest other shard when it runs dry, which keeps the aggregate
//! budget exactly as admissible as a monolithic arena.
//!
//! With `shards == 1` every operation delegates to the single inner
//! arena untouched — the monolithic [`BlockPool`] behavior, bit for
//! bit (the property suite in `rust/tests/property_kvpool.rs` checks
//! this by bisimulation).
//!
//! The shard layer owns only page placement. Hashing, prefix sharing,
//! and eviction policy stay in [`super::prefix`] / [`super::pool`],
//! which see shards through [`ShardView`]s (per-shard capacity, the
//! per-shard half of the routing snapshot) and
//! [`ShardedBlockPool::shard_of`].
//!
//! [`locate`]: ShardedBlockPool::locate
//! [`global`]: ShardedBlockPool::global

use super::block::{BlockPool, PageId, PageState};

/// Index of one simulated device arena.
pub type ShardId = usize;

/// One shard's capacity counters — the per-shard half of the
/// [`CapacityView`](super::CapacityView) the pool publishes (routing
/// snapshots and the `mmserve kv` per-shard occupancy report read
/// these; admission gates on their sum).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardView {
    pub shard: ShardId,
    pub total_pages: usize,
    pub free_pages: usize,
    pub live_pages: usize,
    /// Zero-ref prefix-cached pages (evictable under pressure).
    pub cached_pages: usize,
}

impl ShardView {
    /// Pages obtainable from this shard right now (free + evictable).
    pub fn headroom(&self) -> usize {
        self.free_pages + self.cached_pages
    }
}

/// The page budget split across `D` per-device arenas.
///
/// Page distribution: `total_pages / D` per shard, with the remainder
/// going to the lowest-index shards, so shard sizes differ by at most
/// one page. Global ids are contiguous per shard
/// (`[offset(s), offset(s) + size(s))`), which keeps every existing
/// `0..total()` walk (invariant checks, reports) valid unchanged.
#[derive(Debug, Clone)]
pub struct ShardedBlockPool {
    arenas: Vec<BlockPool>,
    /// Global id of each arena's first page (ascending; an empty
    /// arena shares its successor's offset).
    offsets: Vec<usize>,
    /// Total pages across all arenas (== the last offset + size).
    total: usize,
    page_size: usize,
}

impl ShardedBlockPool {
    pub fn new(total_pages: usize, page_size: usize, shards: usize) -> Self {
        let d = shards.max(1);
        let base = total_pages / d;
        let rem = total_pages % d;
        let mut arenas = Vec::with_capacity(d);
        let mut offsets = Vec::with_capacity(d);
        let mut off = 0usize;
        for s in 0..d {
            let size = base + usize::from(s < rem);
            offsets.push(off);
            arenas.push(BlockPool::new(size, page_size));
            off += size;
        }
        ShardedBlockPool { arenas, offsets, total: off, page_size }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }
    pub fn shards(&self) -> usize {
        self.arenas.len()
    }
    pub fn total(&self) -> usize {
        self.total
    }
    pub fn free_count(&self) -> usize {
        self.arenas.iter().map(|a| a.free_count()).sum()
    }
    pub fn live_count(&self) -> usize {
        self.arenas.iter().map(|a| a.live_count()).sum()
    }
    pub fn cached_count(&self) -> usize {
        self.arenas.iter().map(|a| a.cached_count()).sum()
    }

    /// Shard owning a global page id. Every page operation routes
    /// through here, so this is a binary search over the sorted
    /// offsets, not a scan: the owner is the last arena whose offset
    /// is ≤ `pid` (empty arenas share their successor's offset and own
    /// no pages, and `partition_point` lands past them).
    pub fn shard_of(&self, pid: PageId) -> ShardId {
        assert!(pid < self.total, "page {pid} outside the sharded budget");
        self.offsets.partition_point(|&off| off <= pid) - 1
    }

    /// Global id → `(device, arena-local page)`.
    pub fn locate(&self, pid: PageId) -> (ShardId, PageId) {
        let s = self.shard_of(pid);
        (s, pid - self.offsets[s])
    }

    /// `(device, arena-local page)` → global id.
    pub fn global(&self, shard: ShardId, local: PageId) -> PageId {
        debug_assert!(local < self.arenas[shard].total());
        self.offsets[shard] + local
    }

    pub fn shard_total(&self, s: ShardId) -> usize {
        self.arenas[s].total()
    }
    pub fn shard_free(&self, s: ShardId) -> usize {
        self.arenas[s].free_count()
    }
    pub fn shard_live(&self, s: ShardId) -> usize {
        self.arenas[s].live_count()
    }
    pub fn shard_cached(&self, s: ShardId) -> usize {
        self.arenas[s].cached_count()
    }

    /// Per-shard capacity counters, shard order.
    pub fn views(&self) -> Vec<ShardView> {
        (0..self.arenas.len())
            .map(|s| ShardView {
                shard: s,
                total_pages: self.shard_total(s),
                free_pages: self.shard_free(s),
                live_pages: self.shard_live(s),
                cached_pages: self.shard_cached(s),
            })
            .collect()
    }

    /// Shard with free pages to give, most-free first (ties break to
    /// the lowest index). `None` when every arena is dry.
    pub fn most_free_shard(&self) -> Option<ShardId> {
        (0..self.arenas.len())
            .filter(|&s| self.arenas[s].free_count() > 0)
            .max_by_key(|&s| {
                (self.arenas[s].free_count(), std::cmp::Reverse(s))
            })
    }

    /// Claim a free page (refcount 1), preferring `prefer`'s arena and
    /// spilling to the most-free other shard when it is dry. Returns
    /// the global id; `None` when every arena's free list is empty —
    /// the caller decides whether to evict a cached page.
    ///
    /// With one shard this is exactly [`BlockPool::alloc`].
    pub fn alloc_prefer(&mut self, prefer: Option<ShardId>)
                        -> Option<PageId> {
        if let Some(s) = prefer {
            if let Some(local) = self.arenas[s].alloc() {
                return Some(self.offsets[s] + local);
            }
        }
        let s = self.most_free_shard()?;
        self.arenas[s].alloc().map(|local| self.offsets[s] + local)
    }

    /// Balance-first claim (no placement preference).
    pub fn alloc(&mut self) -> Option<PageId> {
        self.alloc_prefer(None)
    }

    /// Claim a free page on exactly `shard` — no spill. `None` when
    /// that arena's free list is dry; the caller decides whether the
    /// priced fabric makes a home-shard eviction cheaper than the
    /// cross-shard gather a spill would cost.
    pub fn alloc_on(&mut self, shard: ShardId) -> Option<PageId> {
        self.arenas[shard].alloc().map(|local| self.offsets[shard] + local)
    }

    pub fn state(&self, pid: PageId) -> PageState {
        let (s, local) = self.locate(pid);
        self.arenas[s].state(local)
    }
    pub fn refs(&self, pid: PageId) -> usize {
        let (s, local) = self.locate(pid);
        self.arenas[s].refs(local)
    }

    /// Add one reference to a live page (prefix sharing).
    pub fn retain(&mut self, pid: PageId) {
        let (s, local) = self.locate(pid);
        self.arenas[s].retain(local);
    }

    /// Drop one reference; returns the remaining count.
    pub fn release(&mut self, pid: PageId) -> usize {
        let (s, local) = self.locate(pid);
        self.arenas[s].release(local)
    }

    /// Return a zero-ref live page to its arena's free list.
    pub fn free_page(&mut self, pid: PageId) {
        let (s, local) = self.locate(pid);
        self.arenas[s].free_page(local);
    }

    /// Park a zero-ref live page as a cached prefix (evictable).
    pub fn park_cached(&mut self, pid: PageId) {
        let (s, local) = self.locate(pid);
        self.arenas[s].park_cached(local);
    }

    /// Revive a cached page for a new table (refcount 1).
    pub fn unpark(&mut self, pid: PageId) {
        let (s, local) = self.locate(pid);
        self.arenas[s].unpark(local);
    }

    /// Evict a cached page back to its arena's free list.
    pub fn evict_cached(&mut self, pid: PageId) {
        let (s, local) = self.locate(pid);
        self.arenas[s].evict_cached(local);
    }

    /// Pages obtainable right now (free, plus the caller's count of
    /// evictable cached pages) — same contract as
    /// [`BlockPool::available`], summed over shards.
    pub fn available(&self, cached_evictable: usize) -> usize {
        self.free_count() + cached_evictable
    }

    /// Conservation per arena *and* across the split: every shard's
    /// `free + live + cached == shard total`, and the shard sizes
    /// tile the global budget with contiguous, ascending offsets.
    pub fn check_conservation(&self) -> Result<(), String> {
        let mut expect_off = 0usize;
        for (s, a) in self.arenas.iter().enumerate() {
            if self.offsets[s] != expect_off {
                return Err(format!(
                    "shard {s}: offset {} != expected {expect_off}",
                    self.offsets[s]
                ));
            }
            a.check_conservation()
                .map_err(|e| format!("shard {s}: {e}"))?;
            expect_off += a.total();
        }
        if expect_off != self.total() {
            return Err(format!(
                "shard sizes tile {expect_off} pages != total {}",
                self.total()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_splits_evenly_with_remainder_to_low_shards() {
        let p = ShardedBlockPool::new(7, 4, 3);
        assert_eq!(p.shards(), 3);
        assert_eq!(p.total(), 7);
        assert_eq!(p.shard_total(0), 3, "remainder page to shard 0");
        assert_eq!(p.shard_total(1), 2);
        assert_eq!(p.shard_total(2), 2);
        // Contiguous global id ranges per shard.
        assert_eq!(p.locate(0), (0, 0));
        assert_eq!(p.locate(2), (0, 2));
        assert_eq!(p.locate(3), (1, 0));
        assert_eq!(p.locate(5), (2, 0));
        assert_eq!(p.global(2, 1), 6);
        assert_eq!(p.shard_of(6), 2);
        p.check_conservation().unwrap();
    }

    #[test]
    fn single_shard_matches_monolithic_alloc_order() {
        let mut sharded = ShardedBlockPool::new(3, 16, 1);
        let mut mono = BlockPool::new(3, 16);
        // Same lowest-first pop order, same dry-pool refusal.
        for _ in 0..3 {
            assert_eq!(sharded.alloc(), mono.alloc());
        }
        assert_eq!(sharded.alloc(), None);
        assert_eq!(mono.alloc(), None);
        assert_eq!(sharded.release(1), mono.release(1));
        sharded.free_page(1);
        mono.free_page(1);
        assert_eq!(sharded.alloc(), mono.alloc());
        sharded.check_conservation().unwrap();
    }

    #[test]
    fn alloc_prefers_home_shard_then_spills_most_free() {
        let mut p = ShardedBlockPool::new(4, 4, 2); // shards {0,1}, {2,3}
        // No preference: balance picks shard 0 (tie → lowest index).
        let a = p.alloc_prefer(None).unwrap();
        assert_eq!(p.shard_of(a), 0);
        // Home preference sticks while the arena has pages.
        let b = p.alloc_prefer(Some(0)).unwrap();
        assert_eq!(p.shard_of(b), 0);
        // Home dry: spill to the other shard, not a refusal.
        let c = p.alloc_prefer(Some(0)).unwrap();
        assert_eq!(p.shard_of(c), 1);
        let d = p.alloc_prefer(Some(0)).unwrap();
        assert_eq!(p.shard_of(d), 1);
        assert_eq!(p.alloc_prefer(Some(0)), None, "all arenas dry");
        assert_eq!(p.free_count(), 0);
        p.check_conservation().unwrap();
    }

    #[test]
    fn per_shard_state_ops_route_to_the_owning_arena() {
        let mut p = ShardedBlockPool::new(4, 8, 2);
        let a = p.alloc_prefer(Some(1)).unwrap();
        assert_eq!(p.shard_of(a), 1);
        assert_eq!(p.state(a), PageState::Live);
        p.retain(a);
        assert_eq!(p.refs(a), 2);
        assert_eq!(p.release(a), 1);
        assert_eq!(p.release(a), 0);
        p.park_cached(a);
        assert_eq!(p.state(a), PageState::Cached);
        assert_eq!(p.shard_cached(1), 1);
        assert_eq!(p.shard_cached(0), 0);
        p.unpark(a);
        assert_eq!(p.refs(a), 1);
        p.release(a);
        p.park_cached(a);
        p.evict_cached(a);
        assert_eq!(p.state(a), PageState::Free);
        let v = p.views();
        assert_eq!(v.len(), 2);
        assert_eq!(v[1].free_pages, 2);
        assert_eq!(v[1].headroom(), 2);
        p.check_conservation().unwrap();
    }

    #[test]
    fn alloc_on_refuses_instead_of_spilling() {
        let mut p = ShardedBlockPool::new(4, 4, 2); // shards {0,1}, {2,3}
        let a = p.alloc_on(1).unwrap();
        assert_eq!(p.shard_of(a), 1);
        let b = p.alloc_on(1).unwrap();
        assert_eq!(p.shard_of(b), 1);
        assert_eq!(p.alloc_on(1), None, "dry arena refuses, no spill");
        assert_eq!(p.shard_free(0), 2, "other arena untouched");
        p.check_conservation().unwrap();
    }

    #[test]
    fn most_free_shard_tracks_pressure() {
        let mut p = ShardedBlockPool::new(6, 4, 3);
        assert_eq!(p.most_free_shard(), Some(0), "tie breaks low");
        let _ = p.alloc_prefer(Some(0)).unwrap();
        assert_eq!(p.most_free_shard(), Some(1));
        let _ = p.alloc_prefer(Some(1)).unwrap();
        let _ = p.alloc_prefer(Some(2)).unwrap();
        assert_eq!(p.most_free_shard(), Some(0), "all at 1 free");
        for s in 0..3 {
            let _ = p.alloc_prefer(Some(s)).unwrap();
        }
        assert_eq!(p.most_free_shard(), None);
    }
}
