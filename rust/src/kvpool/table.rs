//! Per-request block table: token positions → pages, plus the token
//! history that makes full blocks content-addressable.
//!
//! The table is the request's logical sequence view: `pos` tokens are
//! filled, covered by `pages` (page `i` holds positions
//! `[i·ps, (i+1)·ps)`). Rewind (LayerSkip rollback, §4.3) lowers `pos`
//! without dropping pages — the stale tail is overwritten by later
//! writes, exactly like the dense slot view; the pool's copy-on-write
//! check in `advance` keeps shared pages safe from those overwrites.

use super::block::PageId;

#[derive(Debug, Clone)]
pub struct BlockTable {
    pub request: u64,
    /// Page per block, in position order.
    pages: Vec<PageId>,
    /// Full token history up to `pos` (prompt + decoded).
    tokens: Vec<i32>,
    /// Prompt length at allocation (for preemption/recompute).
    pub prompt_len: usize,
    /// Admission sequence number (monotonic; preemption victims are
    /// chosen latest-first, vLLM-style).
    pub seq: u64,
    /// Leading pages that came from the prefix cache (shared).
    pub shared_prefix_pages: usize,
}

impl BlockTable {
    pub fn new(request: u64, tokens: Vec<i32>, pages: Vec<PageId>,
               seq: u64, shared_prefix_pages: usize) -> Self {
        BlockTable {
            request,
            prompt_len: tokens.len(),
            tokens,
            pages,
            seq,
            shared_prefix_pages,
        }
    }

    /// Filled token count (== next write position).
    pub fn pos(&self) -> usize {
        self.tokens.len()
    }

    pub fn tokens(&self) -> &[i32] {
        &self.tokens
    }

    pub fn pages(&self) -> &[PageId] {
        &self.pages
    }

    pub fn num_pages(&self) -> usize {
        self.pages.len()
    }

    /// Page backing block `idx`, if mapped.
    pub fn page_at(&self, idx: usize) -> Option<PageId> {
        self.pages.get(idx).copied()
    }

    /// Page backing the table's final mapped block — the page whose
    /// shard decode growth prefers (sharded pools keep a sequence's
    /// tail co-located unless its home arena runs dry).
    pub fn last_page(&self) -> Option<PageId> {
        self.pages.last().copied()
    }

    /// Map block `idx` to a new page (copy-on-write fork).
    pub fn remap(&mut self, idx: usize, page: PageId) {
        self.pages[idx] = page;
        if idx < self.shared_prefix_pages {
            self.shared_prefix_pages = idx;
        }
    }

    pub fn push_page(&mut self, page: PageId) {
        self.pages.push(page);
    }

    /// Record one appended token (the pool has already ensured a
    /// writable page backs the position).
    pub fn push_token(&mut self, token: i32) {
        self.tokens.push(token);
    }

    /// Rewind the fill position; pages are kept (overwrite semantics).
    pub fn rewind_to(&mut self, new_pos: usize) {
        debug_assert!(new_pos <= self.tokens.len());
        self.tokens.truncate(new_pos);
    }

    /// Take the pages out (release/preempt teardown).
    pub fn into_parts(self) -> (Vec<PageId>, Vec<i32>, usize) {
        (self.pages, self.tokens, self.prompt_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pos_tracks_tokens_and_rewind_truncates() {
        let mut t = BlockTable::new(7, vec![1, 2, 3], vec![0], 0, 0);
        assert_eq!(t.pos(), 3);
        assert_eq!(t.prompt_len, 3);
        t.push_token(4);
        assert_eq!(t.pos(), 4);
        assert_eq!(t.tokens(), &[1, 2, 3, 4]);
        t.rewind_to(2);
        assert_eq!(t.pos(), 2);
        assert_eq!(t.tokens(), &[1, 2]);
        assert_eq!(t.num_pages(), 1, "rewind keeps pages");
    }

    #[test]
    fn remap_clears_shared_marker() {
        let mut t = BlockTable::new(1, vec![0; 32], vec![4, 5], 0, 2);
        t.remap(1, 9);
        assert_eq!(t.page_at(1), Some(9));
        assert_eq!(t.shared_prefix_pages, 1);
        t.remap(0, 8);
        assert_eq!(t.shared_prefix_pages, 0);
    }
}
