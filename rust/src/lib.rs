//! # mmserve
//!
//! A three-layer Rust + JAX + Pallas serving framework reproducing
//! *"Characterizing and Efficiently Accelerating Multimodal Generation
//! Model Inference"* (Meta AI Research, 2024).
//!
//! Layers:
//! * **L3 (this crate)** — the serving coordinator: request routing,
//!   continuous batching, static KV-cache management, beam search with
//!   cache reorder, contrastive decoding, LayerSkip self-speculative
//!   decoding, plus the paper's analytical A100/H100 device model.
//! * **L2 (python/compile)** — JAX model graphs for the four families
//!   (Llama, Chameleon, Seamless, HSTU), AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels)** — Pallas kernels: flash-style
//!   attention, fused HSTU pointwise attention, int8 matmuls.
//!
//! Cross-cutting the three layers, [`telemetry`] records the live
//! request path: spans around every PJRT dispatch plus the host-side
//! scheduling / tokenization / sampling work, folded into per-tick
//! timelines, an idle-gap attribution (the paper's "GPU idle"
//! decomposition, Obs #2), Chrome-trace JSON export and the serving
//! histograms. `mmserve trace` drives it end to end; tracing is off by
//! default and costs nothing on the serving path when disabled.
//!
//! [`kvpool`] is the capacity layer under the coordinator: a paged
//! KV-cache pool (ref-counted blocks, hash-based prefix sharing with
//! copy-on-write, LRU eviction, preemption) that the batcher admits
//! against and the decode loops advance through — the Table-3
//! capacity bound managed at page granularity instead of worst-case
//! slots. `mmserve kv` replays a workload through it and prints the
//! paged-vs-dense occupancy comparison.
//!
//! [`routing`] sits in front of the coordinator: the `Router` runs N
//! replicated workers per model family (`--replicas`) and a routing
//! policy (`--policy round-robin|least-loaded|prefix-affinity`)
//! steers each request to the replica with the warmest cache, probing
//! per-replica prefix snapshots published from the kvpool every
//! scheduler tick. `mmserve kv --replicas N` replays the policies
//! side by side on the simulated clock.
//!
//! [`workload::arrivals`] turns those replays open-loop: seeded
//! Poisson/diurnal/burst arrival processes over a Zipf-skewed tenant
//! population (with warm-prefix conversation follow-ups) emit
//! timestamped requests the fleet serves as the simulated clock
//! reaches them, and [`routing::autoscale`] closes the loop — an
//! autoscaler watches queue depth and capacity-wait telemetry,
//! spawning replicas under sustained pressure and gracefully draining
//! idle ones (in-flight work finishes; only queued requests
//! re-route). `mmserve kv --arrivals ... --autoscale MIN:MAX` A/Bs
//! the elastic fleet against fixed min/max fleets.
//!
//! [`sched`] sits between the batcher/kvpool and the execution
//! engines: a tick `Scheduler` that turns queue + capacity state into
//! an explicit `TickPlan` (decode batch ∪ prefill *chunks* under a
//! token budget), and the `StepExecutor` trait that all four
//! text-generation paths (batched graph, bs=1 graph, eager, LayerSkip)
//! implement — so per-tick policy like chunked prefill
//! (`--chunk-prefill`) is written once.
//!
//! Python never runs on the request path: `artifacts/` are compiled once
//! by `make artifacts`; this crate loads them via PJRT (`runtime`).

pub mod coordinator;
pub mod kvpool;
pub mod models;
pub mod perfmodel;
pub mod routing;
pub mod runtime;
pub mod sched;
pub mod substrate;
pub mod telemetry;
pub mod workload;

/// Default artifacts directory relative to the repo root.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Resolve the artifacts directory: `$MMSERVE_ARTIFACTS` or `./artifacts`
/// relative to the current working directory (walking up a few parents so
/// tests/benches work from target subdirs).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("MMSERVE_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    for _ in 0..4 {
        let cand = dir.join(ARTIFACTS_DIR);
        if cand.join("llama").join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            break;
        }
    }
    ARTIFACTS_DIR.into()
}
