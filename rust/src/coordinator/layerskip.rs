//! LayerSkip self-speculative decoding (§4.3).
//!
//! Draft tokens are produced by the first E layers + the shared LM head
//! (the `draft_b1` stage); a window of K tokens is then verified in one
//! parallel pass through the full model (`verify_k{K}`), amortizing
//! per-token weight loading exactly as in Elhoushi et al. Greedy
//! longest-prefix acceptance; on partial acceptance the slot position is
//! rewound (stale cache entries beyond the accepted prefix are
//! overwritten by later writes, which is sound because attention masks
//! beyond the fill position).
//!
//! The round loop itself lives in
//! [`crate::sched::exec::generate_speculative`]; this module implements
//! the [`StepExecutor`] hooks: `decode_step` is the cheap *draft* step
//! and `verify` is the full-model window pass.

use anyhow::{Context, Result};

use crate::runtime::engine::{Arg, Engine, StageHandle};
use crate::runtime::tensor::Tensor;
use crate::sched::{ExecDims, SlotFeed, StepExecutor};

use super::decoder_loop::{DecoderDims, DecoderSession, GenResult, KvBufs};
use super::opts::OptConfig;
use super::request::SamplingParams;

/// The self-speculative engine as a [`StepExecutor`] (bs=1): prefill
/// through the baseline bucketed stages, draft through the early-exit
/// head, verify K tokens in one full-model pass. One device-resident
/// KV chain is shared by all three (the cache-reuse property that makes
/// self-speculation cheap).
pub struct LayerSkipExecutor<'e> {
    engine: &'e Engine,
    session: DecoderSession<'e>,
    dims: DecoderDims,
    draft: StageHandle,
    verify: StageHandle,
    k_window: usize,
    kv: Option<KvBufs>,
}

impl<'e> LayerSkipExecutor<'e> {
    pub fn new(engine: &'e Engine, dims: &DecoderDims) -> Result<Self> {
        let k_window = dims.verify_window;
        let draft = engine.stage("draft_b1")?;
        let verify = engine.stage(&format!("verify_k{k_window}"))?;
        // Reuse the session prefills (baseline stages).
        let session = DecoderSession::new(engine, OptConfig::baseline())?;
        Ok(LayerSkipExecutor {
            engine,
            session,
            dims: *dims,
            draft,
            verify,
            k_window,
            kv: None,
        })
    }
}

impl StepExecutor for LayerSkipExecutor<'_> {
    fn plan_dims(&self) -> ExecDims {
        ExecDims {
            batch: 1,
            max_seq: self.dims.max_seq,
            vocab: self.dims.vocab,
        }
    }

    fn prefill_chunk(&mut self, _slot: usize, tokens: &[i32], _start: usize,
                     is_last: bool) -> Result<Option<Vec<f32>>> {
        let (logits, kv) = self.session.prefill(tokens)?;
        self.kv = Some(kv);
        Ok(is_last.then_some(logits))
    }

    /// The draft step: first E layers + shared LM head, writing the
    /// draft's KV into the shared cache.
    fn decode_step(&mut self, feeds: &[SlotFeed]) -> Result<Vec<f32>> {
        let f = feeds.first().context("bs=1 executor needs one feed")?;
        let kv = self.kv.as_mut().context("draft before prefill")?;
        let t_tok = Tensor::from_i32(&[1], &[f.token]);
        let t_pos = Tensor::from_i32(&[1], &[f.pos as i32]);
        let outs = self.engine.run(
            &self.draft,
            &[Arg::Host(&t_tok), Arg::Host(&t_pos), Arg::Dev(&kv.k),
              Arg::Dev(&kv.v)],
        )?;
        let mut it = outs.into_iter();
        let logits_buf = it.next().context("draft logits")?;
        kv.k = it.next().context("draft ck")?;
        kv.v = it.next().context("draft cv")?;
        self.engine.download(&logits_buf)?.as_f32()
    }

    /// The verify pass: all K window tokens through the full model in
    /// one dispatch, overwriting cache positions `start..start+K`.
    fn verify(&mut self, _slot: usize, window: &[i32], start: usize)
              -> Result<Vec<f32>> {
        let kv = self.kv.as_mut().context("verify before prefill")?;
        let t_toks = Tensor::from_i32(&[1, self.k_window], window);
        let t_start = Tensor::from_i32(&[1], &[start as i32]);
        let outs = self.engine.run(
            &self.verify,
            &[Arg::Host(&t_toks), Arg::Host(&t_start), Arg::Dev(&kv.k),
              Arg::Dev(&kv.v)],
        )?;
        let mut it = outs.into_iter();
        let vlogits_buf = it.next().context("verify logits")?;
        kv.k = it.next().context("verify ck")?;
        kv.v = it.next().context("verify cv")?;
        self.engine.download(&vlogits_buf)?.as_f32()
    }

    fn verify_window(&self) -> usize {
        self.k_window
    }
}

/// Generate with the self-speculative loop (bs = 1, greedy acceptance):
/// build the executor, run the shared draft/verify round driver.
pub fn generate_layerskip(engine: &Engine, dims: &DecoderDims,
                          prompt: &[i32], max_new: usize,
                          sp: &SamplingParams) -> Result<GenResult> {
    let mut exec = LayerSkipExecutor::new(engine, dims)?;
    crate::sched::generate_speculative(&mut exec, engine.tracer(), prompt,
                                       max_new, sp)
}

/// Expected speedup of LayerSkip given acceptance rate `a`, draft cost
/// ratio `c = E/L`, and window K — the analytical model used by the
/// Fig-8 bench to cross-check measured numbers.
///
/// Per round: (K-1) drafts at cost c + 1 verify at cost ≈ K·(1/K
/// amortized weight loading → ~1 full step for memory-bound decode),
/// yielding `1 + a·(K-1)` tokens.
pub fn expected_speedup(accept_rate: f64, draft_cost: f64,
                        k_window: usize) -> f64 {
    let k = k_window as f64;
    let tokens_per_round = 1.0 + accept_rate * (k - 1.0);
    let cost_per_round = (k - 1.0) * draft_cost + 1.0;
    tokens_per_round / cost_per_round
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_model_sane() {
        // Perfect acceptance, cheap drafts → large speedup.
        assert!(expected_speedup(1.0, 0.25, 4) > 2.0);
        // Zero acceptance with non-free drafts → slowdown (< 1).
        assert!(expected_speedup(0.0, 0.5, 4) < 1.0);
        // Paper's ≈1.58x regime: moderate acceptance, E/L ≈ 0.25.
        let s = expected_speedup(0.7, 0.25, 4);
        assert!(s > 1.2 && s < 2.2, "{s}");
    }
}
