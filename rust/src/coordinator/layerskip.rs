//! LayerSkip self-speculative decoding (§4.3).
//!
//! Draft tokens are produced by the first E layers + the shared LM head
//! (the `draft_b1` stage); a window of K tokens is then verified in one
//! parallel pass through the full model (`verify_k{K}`), amortizing
//! per-token weight loading exactly as in Elhoushi et al. Greedy
//! longest-prefix acceptance; on partial acceptance the slot position is
//! rewound (stale cache entries beyond the accepted prefix are
//! overwritten by later writes, which is sound because attention masks
//! beyond the fill position).

use std::time::Instant;

use anyhow::{Context, Result};

use crate::kvpool::KvPool;
use crate::models::tokenizer;
use crate::runtime::engine::{Arg, Engine};
use crate::runtime::tensor::Tensor;
use crate::substrate::rng::Rng;
use crate::telemetry::tracer::Cat;

use super::decoder_loop::{DecoderDims, DecoderSession, GenResult, KvBufs};
use super::opts::OptConfig;
use super::request::SamplingParams;
use super::sampling;

/// Generate with the self-speculative loop (bs = 1, greedy acceptance).
pub fn generate_layerskip(engine: &Engine, dims: &DecoderDims,
                          prompt: &[i32], max_new: usize,
                          sp: &SamplingParams) -> Result<GenResult> {
    let t0 = Instant::now();
    let k_window = dims.verify_window;
    let draft_stage = engine.stage("draft_b1")?;
    let verify_stage = engine.stage(&format!("verify_k{k_window}"))?;
    // Reuse the session prefills (baseline stages).
    let session = DecoderSession::new(engine, OptConfig::baseline())?;
    let mut rng = Rng::new(sp.seed);
    let tele = engine.tracer();
    let _tick_scope = tele.map(|t| t.tick_scope());

    let prefill_span = tele.map(|t| t.span(Cat::Prefill, "prefill"));
    let (logits, kv) = session.prefill(prompt)?;
    drop(prefill_span);
    let mut kv: KvBufs = kv;
    let ttft = t0.elapsed().as_secs_f64();

    // Block-table view of the speculative cache: drafts advance it,
    // verification rewinds and overwrites — the same rewind path the
    // dense slot view used, now at page granularity.
    let mut pool = KvPool::solo(dims.max_seq);
    let table_len = prompt.len().min(dims.max_seq - 1);
    pool.alloc(0, &prompt[..table_len])?;

    let mut out: Vec<i32> = Vec::with_capacity(max_new);
    let mut pos = prompt.len();
    // `pending` = last sampled token not yet written into the cache.
    let mut pending = {
        let _s = tele.map(|t| t.span(Cat::Sample, "sample_first"));
        sampling::sample(&logits, sp, &mut rng)
    };
    out.push(pending);

    let mut accepted_total = 0usize;
    let mut rounds = 0usize;

    'outer: while out.len() < max_new && pending != tokenizer::EOS {
        if pos + k_window + 1 >= dims.max_seq {
            break;
        }
        rounds += 1;
        if let Some(t) = tele {
            t.next_tick();
        }
        let _round_span = tele.map(|t| t.span(Cat::Decode, "spec_round"));
        // ---- draft phase: K-1 cheap tokens after `pending` ------------
        let mut window = Vec::with_capacity(k_window);
        window.push(pending);
        let mut dkv_pos = pos;
        for _ in 0..k_window - 1 {
            let fed = *window.last().unwrap();
            let t_tok = Tensor::from_i32(&[1], &[fed]);
            let t_pos = Tensor::from_i32(&[1], &[dkv_pos as i32]);
            let outs = engine.run(
                &draft_stage,
                &[Arg::Host(&t_tok), Arg::Host(&t_pos), Arg::Dev(&kv.k),
                  Arg::Dev(&kv.v)],
            )?;
            let mut it = outs.into_iter();
            let logits_buf = it.next().context("draft logits")?;
            kv.k = it.next().context("draft ck")?;
            kv.v = it.next().context("draft cv")?;
            let dl = engine.download(&logits_buf)?.as_f32()?;
            // Drafts are greedy (standard for self-spec draft phase).
            window.push(sampling::greedy(&dl));
            pool.advance(0, fed)?;
            dkv_pos += 1;
        }
        // ---- verify phase: all K tokens in one full-model pass --------
        // The verify pass overwrites positions pos..pos+K: rewind the
        // block table and replay the window through it.
        pool.rewind_to(0, pos)?;
        for &w in &window {
            pool.advance(0, w)?;
        }
        let t_toks = Tensor::from_i32(&[1, k_window], &window);
        let t_start = Tensor::from_i32(&[1], &[pos as i32]);
        let outs = engine.run(
            &verify_stage,
            &[Arg::Host(&t_toks), Arg::Host(&t_start), Arg::Dev(&kv.k),
              Arg::Dev(&kv.v)],
        )?;
        let mut it = outs.into_iter();
        let vlogits_buf = it.next().context("verify logits")?;
        kv.k = it.next().context("verify ck")?;
        kv.v = it.next().context("verify cv")?;
        let vl = engine.download(&vlogits_buf)?.as_f32()?;
        let vocab = dims.vocab;

        // Longest prefix of drafts matching the full model (greedy).
        // vl[j] is the full model's next-token dist after window[j].
        let _accept_span = tele.map(|t| t.span(Cat::Sample, "accept"));
        let mut accepted = 0usize;
        for j in 1..k_window {
            let full_tok =
                sampling::greedy(&vl[(j - 1) * vocab..j * vocab]);
            if full_tok == window[j] {
                accepted += 1;
            } else {
                break;
            }
        }
        accepted_total += accepted;
        // Emit accepted drafts (window[1..=accepted]).
        for &d in window.iter().skip(1).take(accepted) {
            out.push(d);
            if out.len() >= max_new || d == tokenizer::EOS {
                pos += accepted + 1;
                break 'outer;
            }
        }
        // Bonus token from the verify logits at the last accepted slot.
        let bonus =
            sampling::greedy(&vl[accepted * vocab..(accepted + 1) * vocab]);
        out.push(bonus);
        // Cache now holds correct entries for window[0..=accepted] at
        // pos..pos+accepted; rewind the logical position there.
        pos += accepted + 1;
        pool.rewind_to(0, pos)?;
        pending = bonus;
    }

    pool.release(0)?;
    debug_assert!(pool.check_invariants().is_ok());
    Ok(GenResult {
        prompt_tokens: prompt.len(),
        decode_steps: out.len(),
        tokens: out,
        ttft,
        e2e: t0.elapsed().as_secs_f64(),
        accepted_drafts: accepted_total,
        draft_rounds: rounds,
    })
}

/// Expected speedup of LayerSkip given acceptance rate `a`, draft cost
/// ratio `c = E/L`, and window K — the analytical model used by the
/// Fig-8 bench to cross-check measured numbers.
///
/// Per round: (K-1) drafts at cost c + 1 verify at cost ≈ K·(1/K
/// amortized weight loading → ~1 full step for memory-bound decode),
/// yielding `1 + a·(K-1)` tokens.
pub fn expected_speedup(accept_rate: f64, draft_cost: f64,
                        k_window: usize) -> f64 {
    let k = k_window as f64;
    let tokens_per_round = 1.0 + accept_rate * (k - 1.0);
    let cost_per_round = (k - 1.0) * draft_cost + 1.0;
    tokens_per_round / cost_per_round
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_model_sane() {
        // Perfect acceptance, cheap drafts → large speedup.
        assert!(expected_speedup(1.0, 0.25, 4) > 2.0);
        // Zero acceptance with non-free drafts → slowdown (< 1).
        assert!(expected_speedup(0.0, 0.5, 4) < 1.0);
        // Paper's ≈1.58x regime: moderate acceptance, E/L ≈ 0.25.
        let s = expected_speedup(0.7, 0.25, 4);
        assert!(s > 1.2 && s < 2.2, "{s}");
    }
}
