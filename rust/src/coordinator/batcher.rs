//! Continuous batcher: decode-batch occupancy + prefill admission.
//!
//! Policy (vLLM-flavoured, scaled to the static-batch decode graph):
//! requests queue FCFS; whenever a batch slot is free, the next request
//! is admitted by running its (bucketed) prefill and placing the
//! resulting KV into the free slot; every scheduler tick then runs ONE
//! batched decode step for all live slots. A token budget caps how much
//! prefill work may be admitted per tick so decode latency for running
//! requests stays bounded (the prefill/decode interference knob).

use std::collections::VecDeque;

#[derive(Debug, Clone)]
pub struct QueuedRequest {
    pub id: u64,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
}

/// Admission decision for one scheduler tick.
#[derive(Debug, Default, PartialEq, Eq)]
pub struct Admission {
    /// Requests to prefill this tick (in order).
    pub admit: Vec<QueuedRequest>,
    /// Whether a decode step should run (any live slots after admission).
    pub run_decode: bool,
}

impl PartialEq<QueuedRequest> for QueuedRequest {
    fn eq(&self, other: &QueuedRequest) -> bool {
        self.id == other.id
    }
}
impl Eq for QueuedRequest {}

/// Continuous batcher over a fixed slot count.
#[derive(Debug)]
pub struct Batcher {
    queue: VecDeque<QueuedRequest>,
    /// Max prompt tokens admitted per tick (0 = unlimited).
    pub prefill_token_budget: usize,
    /// Total enqueued ever (stats).
    pub enqueued: u64,
}

impl Batcher {
    pub fn new(prefill_token_budget: usize) -> Self {
        Batcher {
            queue: VecDeque::new(),
            prefill_token_budget,
            enqueued: 0,
        }
    }

    pub fn push(&mut self, r: QueuedRequest) {
        self.enqueued += 1;
        self.queue.push_back(r);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Decide admissions for a tick given `free_slots` and `live_slots`.
    pub fn tick(&mut self, free_slots: usize, live_slots: usize) -> Admission {
        let mut adm = Admission::default();
        let mut budget = self.prefill_token_budget;
        let mut free = free_slots;
        while free > 0 {
            let Some(front) = self.queue.front() else { break };
            if self.prefill_token_budget > 0 && budget < front.prompt_len {
                // Budget exhausted for this tick; FCFS ⇒ stop (no
                // head-of-line bypass, preserving fairness).
                break;
            }
            let r = self.queue.pop_front().unwrap();
            if self.prefill_token_budget > 0 {
                budget -= r.prompt_len;
            }
            adm.admit.push(r);
            free -= 1;
        }
        adm.run_decode = live_slots + adm.admit.len() > 0;
        adm
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prop::prop_check;
    use crate::substrate::rng::Rng;

    fn rq(id: u64, plen: usize) -> QueuedRequest {
        QueuedRequest { id, prompt_len: plen, max_new_tokens: 8 }
    }

    #[test]
    fn admits_up_to_free_slots_fcfs() {
        let mut b = Batcher::new(0);
        for i in 0..5 {
            b.push(rq(i, 10));
        }
        let adm = b.tick(3, 0);
        assert_eq!(
            adm.admit.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![0, 1, 2]
        );
        assert!(adm.run_decode);
        assert_eq!(b.pending(), 2);
    }

    #[test]
    fn token_budget_limits_admission() {
        let mut b = Batcher::new(100);
        b.push(rq(0, 60));
        b.push(rq(1, 60));
        b.push(rq(2, 30));
        let adm = b.tick(3, 0);
        // 60 admitted; next 60 would exceed the 100 budget; FCFS stops
        // (id 2 must NOT jump the queue).
        assert_eq!(adm.admit.len(), 1);
        assert_eq!(adm.admit[0].id, 0);
        assert_eq!(b.pending(), 2);
    }

    #[test]
    fn decode_runs_with_live_only() {
        let mut b = Batcher::new(0);
        let adm = b.tick(4, 2);
        assert!(adm.admit.is_empty());
        assert!(adm.run_decode);
        let adm2 = b.tick(4, 0);
        assert!(!adm2.run_decode);
    }

    /// Properties: (1) never admit more than free slots; (2) budget
    /// respected; (3) FCFS order preserved; (4) no request lost.
    #[test]
    fn prop_batcher_invariants() {
        prop_check(
            150,
            99,
            |r: &mut Rng| {
                let n = r.usize(0, 20);
                let reqs: Vec<usize> =
                    (0..n).map(|_| r.usize(1, 50)).collect();
                let free = r.usize(0, 6);
                let budget = r.usize(0, 120);
                (reqs, (free, budget))
            },
            |(reqs, (free, budget))| {
                let mut b = Batcher::new(*budget);
                for (i, &plen) in reqs.iter().enumerate() {
                    b.push(rq(i as u64, plen));
                }
                let adm = b.tick(*free, 1);
                if adm.admit.len() > *free {
                    return Err("admitted more than free slots".into());
                }
                if *budget > 0 {
                    let tot: usize =
                        adm.admit.iter().map(|r| r.prompt_len).sum();
                    if tot > *budget {
                        return Err(format!("budget {tot} > {budget}"));
                    }
                }
                let ids: Vec<u64> = adm.admit.iter().map(|r| r.id).collect();
                if ids.windows(2).any(|w| w[0] >= w[1]) {
                    return Err("not FCFS".into());
                }
                if adm.admit.len() + b.pending() != reqs.len() {
                    return Err("request lost".into());
                }
                Ok(())
            },
        );
    }
}
