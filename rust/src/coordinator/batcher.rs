//! Continuous batcher: decode-batch occupancy + capacity-aware prefill
//! admission.
//!
//! Policy (vLLM-flavoured, scaled to the static-batch decode graph):
//! requests queue FCFS; whenever a batch slot is free, the next request
//! is admitted by running its (bucketed) prefill and placing the
//! resulting KV into the free slot; every scheduler tick then runs ONE
//! batched decode step for all live slots. A token budget caps how much
//! prefill work may be admitted per tick so decode latency for running
//! requests stays bounded (the prefill/decode interference knob).
//!
//! Admission is driven by a [`CapacityView`]: slots only (the dense
//! seed behavior), or slots *plus* the paged pool's page budget — a
//! request is admitted when its prompt's pages fit the free pages left
//! after a one-page-per-live-sequence growth watermark. That converts
//! the Table-3 capacity bound from "fixed worst-case slots" into "pages
//! actually needed", which is what lets short chats stack deeper than
//! the dense slot count (the paper's biggest idle-time lever).

use std::collections::VecDeque;

use crate::kvpool::CapacityView;
use crate::telemetry::live::sampler::{ADMITTED_TOTAL,
                                      ENQUEUED_TOTAL};
use crate::telemetry::live::{Counter, LiveMetrics};

#[derive(Debug, Clone)]
pub struct QueuedRequest {
    pub id: u64,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
}

/// Admission decision for one scheduler tick.
#[derive(Debug, Default, PartialEq, Eq)]
pub struct Admission {
    /// Requests to prefill this tick (in order).
    pub admit: Vec<QueuedRequest>,
    /// Whether a decode step should run (any live slots after admission).
    pub run_decode: bool,
    /// A free slot existed but the page budget could not cover the next
    /// request — the tick is (partially) blocked on KV capacity. Feeds
    /// the `KvCapacity` idle-attribution bucket.
    pub blocked_on_capacity: bool,
}

impl PartialEq<QueuedRequest> for QueuedRequest {
    fn eq(&self, other: &QueuedRequest) -> bool {
        self.id == other.id
    }
}
impl Eq for QueuedRequest {}

/// Cached live-metrics handles (queue-side counters). Held only when
/// a live plane is attached; every hook checks the registry's enabled
/// flag first (one relaxed load).
#[derive(Debug)]
struct LiveHooks {
    live: LiveMetrics,
    enqueued: Counter,
    admitted: Counter,
}

/// Continuous batcher over a fixed slot count.
#[derive(Debug)]
pub struct Batcher {
    queue: VecDeque<QueuedRequest>,
    /// Max prompt tokens admitted per tick (0 = unlimited).
    pub prefill_token_budget: usize,
    /// Total enqueued ever (stats).
    pub enqueued: u64,
    hooks: Option<LiveHooks>,
}

impl Batcher {
    pub fn new(prefill_token_budget: usize) -> Self {
        Batcher {
            queue: VecDeque::new(),
            prefill_token_budget,
            enqueued: 0,
            hooks: None,
        }
    }

    /// Attach the live-metrics plane: arrivals and per-tick admissions
    /// become replica-labeled counters. Pure observation.
    pub fn attach_live(&mut self, live: &LiveMetrics, replica: usize) {
        let r = replica.to_string();
        let labels = &[("replica", r.as_str())][..];
        self.hooks = Some(LiveHooks {
            enqueued: live.counter(ENQUEUED_TOTAL, labels),
            admitted: live.counter(ADMITTED_TOTAL, labels),
            live: live.clone(),
        });
    }

    pub fn push(&mut self, r: QueuedRequest) {
        self.enqueued += 1;
        if let Some(h) = &self.hooks {
            if h.live.is_enabled() {
                h.enqueued.inc(1);
            }
        }
        self.queue.push_back(r);
    }

    /// Requeue at the head (preemption victims resume FCFS-first).
    pub fn push_front(&mut self, r: QueuedRequest) {
        self.queue.push_front(r);
    }

    /// Requeue a group at the head preserving `rs` order: `rs[0]` ends
    /// up at the front. Calling `push_front` per item in processing
    /// order *reverses* the group — exactly the bug that let a later
    /// admission jump ahead of a requeued preemption victim (and, with
    /// a token budget, let the oversize-alone rule fire for the wrong
    /// request). Always requeue batches through this.
    pub fn requeue_all(&mut self, rs: Vec<QueuedRequest>) {
        for r in rs.into_iter().rev() {
            self.queue.push_front(r);
        }
    }

    /// Head of the queue (the chunked planner peeks before popping).
    pub fn front(&self) -> Option<&QueuedRequest> {
        self.queue.front()
    }

    /// Remove the head request (used to shed work that can never fit).
    pub fn pop_front(&mut self) -> Option<QueuedRequest> {
        self.queue.pop_front()
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Decide admissions for a tick against `cap`.
    ///
    /// FCFS with no head-of-line bypass: the first request that fits
    /// neither the remaining token budget nor the remaining page budget
    /// stops admission for the tick. One exception prevents permanent
    /// starvation: a prompt *larger than the whole per-tick budget*
    /// (which could otherwise never be admitted) is admitted alone when
    /// the tick's budget is still untouched.
    pub fn tick(&mut self, cap: &CapacityView) -> Admission {
        let mut adm = Admission::default();
        let mut budget = self.prefill_token_budget;
        let mut free = cap.free_slots;
        // Pages still grantable this tick (None = dense, unmetered).
        let mut pages_left = cap
            .pages
            .as_ref()
            .map(|p| p.available_pages.saturating_sub(p.reserved_growth));
        while free > 0 {
            let Some(front) = self.queue.front() else { break };
            if self.prefill_token_budget > 0 && budget < front.prompt_len {
                // Oversize prompt on an untouched budget: admit it
                // alone rather than starving it (and everyone behind
                // it) forever.
                let untouched = budget == self.prefill_token_budget;
                let oversize =
                    front.prompt_len > self.prefill_token_budget;
                if !(untouched && oversize) {
                    // Budget exhausted for this tick; FCFS ⇒ stop (no
                    // head-of-line bypass, preserving fairness).
                    break;
                }
            }
            let need = cap.pages_needed(front.prompt_len);
            if let Some(left) = &mut pages_left {
                if need > *left {
                    adm.blocked_on_capacity = true;
                    break;
                }
                *left -= need;
            }
            let r = self.queue.pop_front().unwrap();
            if self.prefill_token_budget > 0 {
                budget = budget.saturating_sub(r.prompt_len);
            }
            adm.admit.push(r);
            free -= 1;
        }
        adm.run_decode = cap.live_slots + adm.admit.len() > 0;
        if let Some(h) = &self.hooks {
            if h.live.is_enabled() && !adm.admit.is_empty() {
                h.admitted.inc(adm.admit.len() as u64);
            }
        }
        adm
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvpool::{KvPool, PageBudget};
    use crate::substrate::prop::prop_check;
    use crate::substrate::rng::Rng;

    fn rq(id: u64, plen: usize) -> QueuedRequest {
        QueuedRequest { id, prompt_len: plen, max_new_tokens: 8 }
    }

    #[test]
    fn admits_up_to_free_slots_fcfs() {
        let mut b = Batcher::new(0);
        for i in 0..5 {
            b.push(rq(i, 10));
        }
        let adm = b.tick(&CapacityView::dense(3, 0));
        assert_eq!(
            adm.admit.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![0, 1, 2]
        );
        assert!(adm.run_decode);
        assert!(!adm.blocked_on_capacity);
        assert_eq!(b.pending(), 2);
    }

    #[test]
    fn token_budget_limits_admission() {
        let mut b = Batcher::new(100);
        b.push(rq(0, 60));
        b.push(rq(1, 60));
        b.push(rq(2, 30));
        let adm = b.tick(&CapacityView::dense(3, 0));
        // 60 admitted; next 60 would exceed the 100 budget; FCFS stops
        // (id 2 must NOT jump the queue).
        assert_eq!(adm.admit.len(), 1);
        assert_eq!(adm.admit[0].id, 0);
        assert_eq!(b.pending(), 2);
    }

    #[test]
    fn decode_runs_with_live_only() {
        let mut b = Batcher::new(0);
        let adm = b.tick(&CapacityView::dense(4, 2));
        assert!(adm.admit.is_empty());
        assert!(adm.run_decode);
        let adm2 = b.tick(&CapacityView::dense(4, 0));
        assert!(!adm2.run_decode);
    }

    /// Regression (satellite): a prompt larger than the whole per-tick
    /// prefill budget used to block the FCFS queue forever. It must be
    /// admitted alone on an untouched budget, and never alongside
    /// other admissions.
    #[test]
    fn oversize_prompt_is_admitted_alone_not_starved() {
        let mut b = Batcher::new(50);
        b.push(rq(0, 120)); // > whole budget
        b.push(rq(1, 10));
        // Untouched budget: the oversize prompt goes in, alone.
        let adm = b.tick(&CapacityView::dense(4, 0));
        assert_eq!(
            adm.admit.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![0],
            "oversize prompt admitted alone"
        );
        // The queue keeps draining normally afterwards.
        let adm2 = b.tick(&CapacityView::dense(4, 0));
        assert_eq!(adm2.admit.len(), 1);
        assert_eq!(adm2.admit[0].id, 1);
        assert_eq!(b.pending(), 0);

        // A touched budget never lets the oversize prompt piggyback.
        let mut b = Batcher::new(50);
        b.push(rq(0, 30));
        b.push(rq(1, 120));
        let adm = b.tick(&CapacityView::dense(4, 0));
        assert_eq!(adm.admit.len(), 1, "only the in-budget prompt");
        assert_eq!(adm.admit[0].id, 0);
        let adm2 = b.tick(&CapacityView::dense(4, 0));
        assert_eq!(adm2.admit.len(), 1, "oversize admitted next tick");
        assert_eq!(adm2.admit[0].id, 1);
    }

    #[test]
    fn page_budget_gates_admission_and_reports_blocking() {
        // 12 available pages, 2 reserved for growth, page_size 4:
        // 10 grantable pages cover all three prompts (4 + 4 + 1).
        let cap = CapacityView {
            free_slots: 4,
            live_slots: 2,
            pages: Some(PageBudget {
                page_size: 4,
                available_pages: 12,
                reserved_growth: 2,
                shards: 1,
            }),
        };
        let mut b = Batcher::new(0);
        b.push(rq(0, 15)); // 15+1 tokens → 4 pages
        b.push(rq(1, 12)); // 12+1 → 4 pages
        b.push(rq(2, 3)); //  3+1 → 1 page
        let adm = b.tick(&cap);
        assert_eq!(adm.admit.len(), 3, "10 pages cover all three");
        assert!(!adm.blocked_on_capacity);

        // A tight tick: a free slot exists but the pages don't cover
        // the prompt → blocked flag raised for the telemetry bucket.
        let tight = CapacityView {
            free_slots: 2,
            live_slots: 4,
            pages: Some(PageBudget {
                page_size: 4,
                available_pages: 4,
                reserved_growth: 4,
                shards: 1,
            }),
        };
        b.push(rq(3, 9)); // 9+1 → 3 pages, 0 grantable
        let adm = b.tick(&tight);
        assert!(adm.admit.is_empty());
        assert!(adm.blocked_on_capacity);
        assert_eq!(b.pending(), 1, "request stays queued, not dropped");
    }

    #[test]
    fn pool_view_drives_admission_end_to_end() {
        // A real pool: 8 pages of 4 tokens, nothing live.
        let pool = KvPool::new(8, 4, 64);
        let cap = pool.capacity_view(4, 0);
        let mut b = Batcher::new(0);
        b.push(rq(0, 11)); // 3 pages
        b.push(rq(1, 11)); // 3 pages
        b.push(rq(2, 11)); // 3 pages — only 2 left
        let adm = b.tick(&cap);
        assert_eq!(adm.admit.len(), 2);
        assert!(adm.blocked_on_capacity);
    }

    /// Regression (satellite): requeueing a *group* of requests with
    /// per-item `push_front` in processing order reverses them, so a
    /// preemption victim admitted earlier could end up behind one
    /// admitted later. `requeue_all` must preserve FCFS order.
    #[test]
    fn requeue_all_preserves_fcfs_order() {
        let mut b = Batcher::new(0);
        b.push(rq(5, 4));
        // Requests 1 and 2 failed admission this tick, in FCFS order.
        b.requeue_all(vec![rq(1, 4), rq(2, 4)]);
        let adm = b.tick(&CapacityView::dense(3, 0));
        assert_eq!(
            adm.admit.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![1, 2, 5],
            "requeued group keeps its internal order ahead of the queue"
        );

        // The buggy pattern for contrast: per-item push_front reverses.
        let mut b = Batcher::new(0);
        b.push_front(rq(1, 4));
        b.push_front(rq(2, 4));
        let adm = b.tick(&CapacityView::dense(2, 0));
        assert_eq!(adm.admit[0].id, 2, "push_front-per-item reverses");
    }

    /// Regression (satellite): a requeued preemption victim whose
    /// recompute prefix exceeds the whole per-tick token budget must
    /// keep its front-of-queue priority — admitted alone via the
    /// oversize exception on the next untouched tick, never starved
    /// behind (or bypassed by) smaller fresh requests.
    #[test]
    fn requeued_oversize_victim_keeps_front_priority() {
        let mut b = Batcher::new(50);
        b.push(rq(1, 10)); // fresh small request already queued
        // Victim 9 was preempted mid-decode; its prompt+generated
        // recompute prefix (120) exceeds the 50-token budget.
        b.requeue_all(vec![rq(9, 120)]);
        let adm = b.tick(&CapacityView::dense(4, 0));
        assert_eq!(
            adm.admit.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![9],
            "oversize victim admitted alone, ahead of the fresh request"
        );
        let adm2 = b.tick(&CapacityView::dense(4, 0));
        assert_eq!(adm2.admit.len(), 1);
        assert_eq!(adm2.admit[0].id, 1);
        assert_eq!(b.pending(), 0);
    }

    /// The attached live plane counts arrivals and admissions without
    /// touching admission decisions; a disabled registry stays at zero.
    #[test]
    fn live_hooks_count_enqueues_and_admissions() {
        let live = LiveMetrics::new();
        let mut b = Batcher::new(0);
        b.attach_live(&live, 2);
        for i in 0..4 {
            b.push(rq(i, 10));
        }
        let adm = b.tick(&CapacityView::dense(3, 0));
        assert_eq!(adm.admit.len(), 3);
        let snap = live.snapshot();
        let l = &[("replica", "2")][..];
        assert_eq!(snap.counter(ENQUEUED_TOTAL, l), Some(4));
        assert_eq!(snap.counter(ADMITTED_TOTAL, l), Some(3));

        let off = LiveMetrics::off();
        let mut b2 = Batcher::new(0);
        b2.attach_live(&off, 0);
        b2.push(rq(9, 10));
        let _ = b2.tick(&CapacityView::dense(1, 0));
        let snap = off.snapshot();
        assert_eq!(snap.counter(ENQUEUED_TOTAL,
                                &[("replica", "0")]),
                   Some(0));
    }

    #[test]
    fn push_front_requeues_ahead_of_fcfs() {
        let mut b = Batcher::new(0);
        b.push(rq(1, 5));
        b.push_front(rq(9, 5)); // preemption victim resumes first
        let adm = b.tick(&CapacityView::dense(2, 0));
        assert_eq!(
            adm.admit.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![9, 1]
        );
    }

    /// Properties: (1) never admit more than free slots; (2) budget
    /// respected (modulo the oversize-alone exception); (3) FCFS order
    /// preserved; (4) no request lost; (5) page budget respected.
    #[test]
    fn prop_batcher_invariants() {
        prop_check(
            150,
            99,
            |r: &mut Rng| {
                let n = r.usize(0, 20);
                let reqs: Vec<usize> =
                    (0..n).map(|_| r.usize(1, 50)).collect();
                let free = r.usize(0, 6);
                let budget = r.usize(0, 120);
                (reqs, (free, budget))
            },
            |(reqs, (free, budget))| {
                let mut b = Batcher::new(*budget);
                for (i, &plen) in reqs.iter().enumerate() {
                    b.push(rq(i as u64, plen));
                }
                let cap = CapacityView {
                    free_slots: *free,
                    live_slots: 1,
                    pages: Some(PageBudget {
                        page_size: 8,
                        available_pages: 12,
                        reserved_growth: 1,
                        shards: 1,
                    }),
                };
                let adm = b.tick(&cap);
                if adm.admit.len() > *free {
                    return Err("admitted more than free slots".into());
                }
                if *budget > 0 {
                    let tot: usize =
                        adm.admit.iter().map(|r| r.prompt_len).sum();
                    let oversize_alone = adm.admit.len() == 1
                        && adm.admit[0].prompt_len > *budget;
                    if tot > *budget && !oversize_alone {
                        return Err(format!("budget {tot} > {budget}"));
                    }
                }
                let pages: usize = adm
                    .admit
                    .iter()
                    .map(|r| cap.pages_needed(r.prompt_len))
                    .sum();
                if pages > 11 {
                    return Err(format!("page budget exceeded: {pages}"));
                }
                let ids: Vec<u64> = adm.admit.iter().map(|r| r.id).collect();
                if ids.windows(2).any(|w| w[0] >= w[1]) {
                    return Err("not FCFS".into());
                }
                if adm.admit.len() + b.pending() != reqs.len() {
                    return Err("request lost".into());
                }
                Ok(())
            },
        );
    }
}
