//! L3 coordinator — the serving system (the paper's system contribution
//! surface).
//!
//! Scheduling is centralized in `crate::sched`, which sits between the
//! queue/capacity layers here and the execution engines:
//!
//! ```text
//!   requests ─► Router ─ routing policy (crate::routing):
//!                 │       round-robin | least-loaded | prefix-affinity,
//!                 │       ranking replicas by probed PrefixSnapshots
//!                 │       (resident block hashes) + queue depth
//!                 ▼
//!        replica worker 0..N per model family, each running:
//!           batcher (FCFS queue, token budget)
//!                   │
//!                   ▼           CapacityView (slots + pages)
//!            sched::Scheduler ◄────────── kv::PagedKvSlots ◄── kvpool
//!                   │ TickPlan (decode set ∪ prefill chunks)
//!                   ▼
//!          server::run_tick(plan, executor)
//!                   │ prefill_chunk / decode_step / verify
//!                   ▼
//!        ┌──────────┴──────────┬───────────────┬───────────────┐
//!   BatchedExecutor      GraphExecutor    EagerExecutor  LayerSkipExecutor
//!   (server, b=N graph)  (decoder_loop)   (eager)        (layerskip)
//!        └─────────────────────┴───────┬───────┴───────────────┘
//!                 SeamlessExecutor            HstuExecutor
//!                 (seamless_pipe: beam        (hstu_loop: one-shot
//!                 fork/prune via kvpool       scoring as a prefill-
//!                 block tables, Obs #4)       only plan, Obs #1)
//! ```
//!
//! Each replica owns its engine and KV pool and republishes its cache
//! warmth (resident prefix-block hashes + counters) into a shared
//! `routing::ReplicaCell` every scheduler tick; the router reads those
//! snapshots lock-free-ish on submit and walks the policy's preference
//! order, failing over past dead replicas.
//!
//! Every generation path — the four text decoders plus Seamless beam
//! search and the HSTU one-shot pass — implements
//! `sched::StepExecutor`; their generate loops live once in the sched
//! drivers (`generate`, `generate_beam`). Chunked prefill
//! (`RouterConfig::chunk_prefill`) is therefore a pure scheduler
//! policy: long prompts split into budget-sized chunks interleaved
//! with decode ticks, pages claimed chunk by chunk. A single `Router`
//! can hold replica sets for several families at once (a mixed fleet);
//! `docs/ARCHITECTURE.md` walks the full request lifecycle including
//! the mixed-fleet and beam-fork branches.
//!
//! * [`request`] — request/response/event types flowing through the stack.
//! * [`sampling`] — greedy / top-k / top-p / temperature samplers.
//! * [`kv`] — KV-cache views: the static slot manager for the compiled
//!   graphs (CUDA-Graph-style fixed buffers, §4.1.2) and the paged
//!   wrapper that meters capacity through `crate::kvpool` (including
//!   `extend_chunk`, the chunked-prefill append).
//! * [`batcher`] — continuous batcher: decode-batch occupancy + prefill
//!   admission under a token budget and the paged pool's capacity view
//!   (whole-prompt mode delegates admission here unchanged).
//! * [`opts`] — the optimization-lever configuration (SDPA / graph mode /
//!   quant / LayerSkip), §4's knobs as a struct.
//! * [`decoder_loop`] — Llama/Chameleon sessions: bucketed prefill,
//!   static-KV decode steps, contrastive decoding for T-I, plus the
//!   bs=1 `GraphExecutor`.
//! * [`eager`] — per-operator dispatch baseline (the launch-overhead
//!   regime of Obs #2) as an executor.
//! * [`layerskip`] — self-speculative draft/verify stages (§4.3) as an
//!   executor.
//! * [`seamless_pipe`] — the four-module Seamless pipeline; its text
//!   decoder runs on the unified core as `SeamlessExecutor`, beam
//!   reorder expressed as block-table fork/prune (Obs #4).
//! * [`hstu_loop`] — non-autoregressive HSTU ranking/retrieval;
//!   `HstuExecutor` schedules the one-shot pass as a prefill-only
//!   plan with zero decode ticks (Obs #1).
//! * [`autoquant`] — per-layer-shape quantization calibration (§4.2).
//! * [`server`] — multi-model router with N replicated engine threads
//!   per model family, prefix-cache-aware replica routing
//!   (`--replicas` / `--policy`), and the generic `run_tick` tick
//!   driver.

pub mod autoquant;
pub mod batcher;
pub mod decoder_loop;
pub mod eager;
pub mod hstu_loop;
pub mod kv;
pub mod layerskip;
pub mod opts;
pub mod request;
pub mod sampling;
pub mod seamless_pipe;
pub mod server;
