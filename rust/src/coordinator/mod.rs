//! L3 coordinator — the serving system (the paper's system contribution
//! surface).
//!
//! * [`request`] — request/response/event types flowing through the stack.
//! * [`sampling`] — greedy / top-k / top-p / temperature samplers.
//! * [`kv`] — KV-cache views: the static slot manager for the compiled
//!   graphs (CUDA-Graph-style fixed buffers, §4.1.2) and the paged
//!   wrapper that meters capacity through `crate::kvpool`.
//! * [`batcher`] — continuous batcher: decode-batch occupancy + prefill
//!   admission under a token budget and the paged pool's capacity view.
//! * [`opts`] — the optimization-lever configuration (SDPA / graph mode /
//!   quant / LayerSkip), §4's knobs as a struct.
//! * [`decoder_loop`] — Llama/Chameleon serving: bucketed prefill,
//!   batched static-KV decode, contrastive decoding for T-I.
//! * [`eager`] — per-operator dispatch baseline (the launch-overhead
//!   regime of Obs #2).
//! * [`layerskip`] — self-speculative decoding (draft E layers, verify K
//!   tokens in parallel), §4.3.
//! * [`seamless_pipe`] — the four-module Seamless pipeline with beam
//!   search and KV reorder (Obs #4).
//! * [`hstu_loop`] — non-autoregressive HSTU ranking/retrieval.
//! * [`autoquant`] — per-layer-shape quantization calibration (§4.2).
//! * [`server`] — multi-model router with per-model engine threads.

pub mod autoquant;
pub mod batcher;
pub mod decoder_loop;
pub mod eager;
pub mod hstu_loop;
pub mod kv;
pub mod layerskip;
pub mod opts;
pub mod request;
pub mod sampling;
pub mod seamless_pipe;
pub mod server;
