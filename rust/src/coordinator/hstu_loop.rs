//! HSTU (gDLRM) inference — non-autoregressive (Obs #1): one forward
//! pass scores the whole user history and produces ranking + retrieval
//! outputs.
//!
//! On the unified serving core the one-shot pass is a *prefill-only*
//! plan: [`HstuExecutor`] implements
//! [`StepExecutor`](crate::sched::StepExecutor) with the whole forward
//! inside `prefill_chunk` and a `decode_step` that refuses to run —
//! `sched::generate` with `max_new == 0` schedules it as zero decode
//! ticks. Timing flows through [`timed`] telemetry spans so the pass
//! appears in `mmserve trace` with idle attribution.

use anyhow::{bail, Context, Result};

use crate::runtime::engine::{Arg, Engine};
use crate::runtime::tensor::Tensor;
use crate::sched::{ExecDims, SlotFeed, StepExecutor};
use crate::telemetry::tracer::{timed, Cat};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HstuAttn {
    Naive,
    /// Fused Pallas kernel (relative bias built in-register, §4.1.1).
    Fused,
}

#[derive(Debug)]
pub struct HstuResult {
    /// Engagement-type argmax for the last `tail` valid positions
    /// (ranking head).
    pub engagement: Vec<i32>,
    /// Top-k next items (retrieval head).
    pub top_items: Vec<i32>,
    pub e2e: f64,
}

pub struct HstuRunner<'e> {
    pub engine: &'e Engine,
    pub attn: HstuAttn,
    pub action_vocab: usize,
    pub item_vocab: usize,
    buckets: Vec<usize>,
    batches: Vec<usize>,
}

impl<'e> HstuRunner<'e> {
    pub fn new(engine: &'e Engine, attn: HstuAttn) -> Result<Self> {
        let mut buckets = vec![];
        let mut batches = vec![];
        for s in engine.manifest.stages_of_kind("forward") {
            if let (Some(sq), Some(b)) =
                (s.meta_usize("seq"), s.meta_usize("batch"))
            {
                buckets.push(sq);
                batches.push(b);
            }
        }
        buckets.sort();
        buckets.dedup();
        batches.sort();
        batches.dedup();
        Ok(HstuRunner {
            engine,
            attn,
            action_vocab: engine.manifest.cfg_usize("action_vocab")?,
            item_vocab: engine.manifest.cfg_usize("item_vocab")?,
            buckets,
            batches,
        })
    }

    fn stage_name(&self, seq: usize, batch: usize) -> String {
        let sfx = if self.attn == HstuAttn::Fused { "_fused" } else { "" };
        format!("forward_s{seq}_b{batch}{sfx}")
    }

    /// Smallest lowered (seq, batch) covering the request.
    pub fn pick_shape(&self, seq_len: usize, batch: usize)
                      -> Result<(usize, usize)> {
        for &s in &self.buckets {
            for &b in &self.batches {
                if s >= seq_len
                    && b >= batch
                    && self.engine.has_stage(&self.stage_name(s, b))
                {
                    return Ok((s, b));
                }
            }
        }
        // fall back to the largest available
        let s = *self.buckets.last().context("no hstu buckets")?;
        let b = *self.batches.last().context("no hstu batches")?;
        Ok((s, b))
    }

    /// Largest lowered sequence bucket (the scheduler's `max_seq`).
    pub fn max_bucket(&self) -> usize {
        self.buckets.last().copied().unwrap_or(1)
    }

    /// Pack + forward + download, with the pass timed by a telemetry
    /// span. Returns (rank logits, retrieval logits, bucket seq).
    fn forward(&self, histories: &[Vec<i32>])
               -> Result<(Vec<f32>, Vec<f32>, usize)> {
        let tele = self.engine.tracer();
        let maxlen = histories.iter().map(|h| h.len()).max().unwrap_or(1);
        let (s, b) = self.pick_shape(maxlen, histories.len())?;
        let pack_span = tele.map(|t| t.span(Cat::Tokenize, "pack_history"));
        let mut ids = vec![0i32; b * s];
        let mut lens = vec![1i32; b];
        for (i, h) in histories.iter().enumerate() {
            let n = h.len().min(s);
            ids[i * s..i * s + n].copy_from_slice(&h[..n]);
            lens[i] = n as i32;
        }
        drop(pack_span);
        let stage = self.engine.stage(&self.stage_name(s, b))?;
        let t_ids = Tensor::from_i32(&[b, s], &ids);
        let t_len = Tensor::from_i32(&[b], &lens);
        let outs = self
            .engine
            .run(&stage, &[Arg::Host(&t_ids), Arg::Host(&t_len)])?;
        let rank = self.engine.download(&outs[0])?.as_f32()?;
        let retr = self.engine.download(&outs[1])?.as_f32()?;
        Ok((rank, retr, s))
    }

    /// Run one batch of user histories. Each history is right-padded to
    /// the bucket; `tail` engagement predictions are returned per user.
    pub fn run_batch(&self, histories: &[Vec<i32>], tail: usize,
                     top_k: usize) -> Result<Vec<HstuResult>> {
        let tele = self.engine.tracer();
        let (fwd, e2e) = timed(tele, Cat::Other, "hstu_forward", || {
            self.forward(histories)
        });
        let (rank, retr, s) = fwd?;

        let _rank_span = tele.map(|t| t.span(Cat::Sample, "rank_retrieve"));
        let mut results = Vec::with_capacity(histories.len());
        for (i, h) in histories.iter().enumerate() {
            let n = h.len().min(s);
            let a = self.action_vocab;
            let mut engagement = Vec::with_capacity(tail.min(n));
            for p in n.saturating_sub(tail)..n {
                let row = &rank[(i * s + p) * a..(i * s + p + 1) * a];
                engagement.push(super::sampling::greedy(row));
            }
            let iv = self.item_vocab;
            let row = &retr[i * iv..(i + 1) * iv];
            let mut idx: Vec<usize> = (0..iv).collect();
            idx.sort_by(|&x, &y| row[y].partial_cmp(&row[x]).unwrap());
            let top_items: Vec<i32> =
                idx.into_iter().take(top_k).map(|x| x as i32).collect();
            results.push(HstuResult { engagement, top_items, e2e });
        }
        Ok(results)
    }
}

/// The HSTU one-shot scoring pass as a [`StepExecutor`].
///
/// The whole request is its prompt (Obs #1): `prefill_chunk` runs the
/// full forward and `decode_step` refuses to run, so
/// `sched::generate` with `max_new == 0` schedules the request as a
/// prefill-only plan with zero decode ticks. The ranking/retrieval
/// outputs land in `last`; the returned "logits" are a one-hot over
/// the retrieval vocabulary peaked at the top item, so a greedy
/// sampler recovers the retrieval argmax if a driver ever asks for a
/// token.
pub struct HstuExecutor<'e> {
    runner: &'e HstuRunner<'e>,
    tail: usize,
    top_k: usize,
    /// Outputs of the most recent one-shot pass.
    pub last: Option<HstuResult>,
}

impl<'e> HstuExecutor<'e> {
    pub fn new(runner: &'e HstuRunner<'e>, tail: usize, top_k: usize)
               -> Self {
        HstuExecutor { runner, tail, top_k, last: None }
    }
}

impl StepExecutor for HstuExecutor<'_> {
    fn plan_dims(&self) -> ExecDims {
        ExecDims {
            batch: 1,
            // +1 so the longest bucketed history fits the block table.
            max_seq: self.runner.max_bucket() + 1,
            vocab: self.runner.item_vocab,
        }
    }

    fn step_span_name(&self) -> &'static str {
        "hstu_score"
    }

    fn prefill_chunk(&mut self, _slot: usize, tokens: &[i32],
                     start: usize, is_last: bool)
                     -> Result<Option<Vec<f32>>> {
        if start != 0 || !is_last {
            bail!("hstu scores the whole history in one pass");
        }
        let mut rs =
            self.runner
                .run_batch(&[tokens.to_vec()], self.tail, self.top_k)?;
        let r = rs.pop().context("hstu result")?;
        let mut logits = vec![0.0f32; self.runner.item_vocab];
        if let Some(&top) = r.top_items.first() {
            logits[top as usize] = 1.0;
        }
        self.last = Some(r);
        Ok(Some(logits))
    }

    fn decode_step(&mut self, _feeds: &[SlotFeed]) -> Result<Vec<f32>> {
        bail!("hstu is non-autoregressive: zero decode ticks")
    }
}
