//! Eager (per-operator dispatch) decode — the paper's unoptimized
//! baseline regime.
//!
//! Each decode step issues ~4·L+2 separate PJRT executions (embed, then
//! per-layer norm+qkv / attention / oproj / ffn, then head). The gap
//! between this and graph mode is the real, measured analogue of
//! Obs #2's "GPU idle time dominated by kernel-launch overhead" and of
//! the torch.compile + CUDA Graph speedups in Figs 5–7.

use std::time::Instant;

use anyhow::{Context, Result};
use xla::PjRtBuffer;

use crate::kvpool::KvPool;
use crate::models::tokenizer;
use crate::runtime::engine::{Arg, Engine, StageHandle};
use crate::runtime::tensor::Tensor;
use crate::substrate::rng::Rng;
use crate::telemetry::tracer::Cat;

use super::decoder_loop::{DecoderDims, GenResult};
use super::request::SamplingParams;
use super::sampling;

struct EagerStages {
    embed: StageHandle,
    norm: StageHandle,
    qkv: StageHandle,
    attn: StageHandle,
    oproj: StageHandle,
    ffn: StageHandle,
    head: StageHandle,
}

impl EagerStages {
    fn load(engine: &Engine) -> Result<Self> {
        Ok(EagerStages {
            embed: engine.stage("eager_embed")?,
            norm: engine.stage("eager_norm")?,
            qkv: engine.stage("eager_qkv")?,
            attn: engine.stage("eager_attn")?,
            oproj: engine.stage("eager_oproj")?,
            ffn: engine.stage("eager_ffn")?,
            head: engine.stage("eager_head")?,
        })
    }
}

/// Per-layer KV device buffers for the eager loop.
struct EagerKv {
    k: Vec<PjRtBuffer>,
    v: Vec<PjRtBuffer>,
}

/// Dispatches per decoded token in eager mode (for overhead accounting):
/// embed + L·(norm + qkv + attn + oproj + ffn) + head.
pub fn dispatches_per_token(n_layers: usize) -> usize {
    2 + n_layers * 5
}

/// Eager generation (bs=1). The prompt is consumed token-by-token
/// through the eager step (no prefill graph — the fully unoptimized
/// pipeline).
pub fn generate_eager(engine: &Engine, dims: &DecoderDims, prompt: &[i32],
                      max_new: usize, sp: &SamplingParams)
                      -> Result<GenResult> {
    let t0 = Instant::now();
    let stages = EagerStages::load(engine)?;
    let mut rng = Rng::new(sp.seed);

    // zero per-layer caches [1, H, S, Dh]
    let kv_shape = [1, dims.n_heads, dims.max_seq, dims.head_dim];
    let zero = Tensor::zeros(crate::runtime::tensor::DType::F32, &kv_shape);
    let mut kv = EagerKv { k: Vec::new(), v: Vec::new() };
    for _ in 0..dims.n_layers {
        kv.k.push(engine.upload(&zero)?);
        kv.v.push(engine.upload(&zero)?);
    }

    let mut logits: Vec<f32> = Vec::new();
    let mut ttft = 0.0;
    // Feed prompt tokens, then generate.
    let tele = engine.tracer();
    let _tick_scope = tele.map(|t| t.tick_scope());
    // Eager consumes the prompt token-by-token, so its block table
    // starts empty and grows with every fed position.
    let mut pool = KvPool::solo(dims.max_seq);
    pool.alloc(0, &[])?;
    let mut out = Vec::with_capacity(max_new);
    let mut pos = 0usize;
    let total = prompt.len() + max_new;
    for step in 0..total {
        if let Some(t) = tele {
            t.next_tick();
        }
        let in_prompt = step < prompt.len();
        let phase = if in_prompt { Cat::Prefill } else { Cat::Decode };
        let _step_span = tele.map(|t| t.span(phase, "eager_step"));
        let token = if in_prompt {
            prompt[step]
        } else {
            let tok = {
                let _s = tele.map(|t| t.span(Cat::Sample, "sample"));
                sampling::sample(&logits, sp, &mut rng)
            };
            out.push(tok);
            if tok == tokenizer::EOS {
                break;
            }
            tok
        };
        if pos + 1 >= dims.max_seq || out.len() >= max_new {
            break;
        }
        logits = eager_step(engine, &stages, dims, token, pos, &mut kv)?;
        if step + 1 == prompt.len() {
            ttft = t0.elapsed().as_secs_f64();
        }
        pos = pool.advance(0, token)?;
    }
    pool.release(0)?;
    debug_assert!(pool.check_invariants().is_ok());
    Ok(GenResult {
        prompt_tokens: prompt.len(),
        decode_steps: out.len(),
        tokens: out,
        ttft,
        e2e: t0.elapsed().as_secs_f64(),
        accepted_drafts: 0,
        draft_rounds: 0,
    })
}

/// One eager decode step: 2 + 5·L separate dispatches.
fn eager_step(engine: &Engine, st: &EagerStages, dims: &DecoderDims,
              token: i32, pos: usize, kv: &mut EagerKv)
              -> Result<Vec<f32>> {
    let t_tok = Tensor::from_i32(&[1], &[token]);
    let t_pos = Tensor::from_i32(&[1], &[pos as i32]);

    // x = embed(token)
    let mut x = engine
        .run(&st.embed, &[Arg::Host(&t_tok)])?
        .into_iter()
        .next()
        .context("embed out")?;

    for l in 0..dims.n_layers {
        let p = |s: &str| format!("layers.{l}.{s}");
        // h = rmsnorm(x)
        let w_norm = engine.weight_buf(&p("attn_norm"))?;
        let h = engine
            .run(&st.norm, &[Arg::Dev(&w_norm), Arg::Dev(&x)])?
            .into_iter()
            .next()
            .context("norm out")?;
        // q, k, v (+rope)
        let wq = engine.weight_buf(&p("wq"))?;
        let wk = engine.weight_buf(&p("wk"))?;
        let wv = engine.weight_buf(&p("wv"))?;
        let qkv = engine.run(
            &st.qkv,
            &[Arg::Dev(&wq), Arg::Dev(&wk), Arg::Dev(&wv), Arg::Dev(&h),
              Arg::Host(&t_pos)],
        )?;
        let (q, k, v) = {
            let mut it = qkv.into_iter();
            (
                it.next().context("q")?,
                it.next().context("k")?,
                it.next().context("v")?,
            )
        };
        // cached attention
        let attn_outs = engine.run(
            &st.attn,
            &[Arg::Dev(&q), Arg::Dev(&k), Arg::Dev(&v), Arg::Host(&t_pos),
              Arg::Dev(&kv.k[l]), Arg::Dev(&kv.v[l])],
        )?;
        let mut it = attn_outs.into_iter();
        let attn_out = it.next().context("attn out")?;
        kv.k[l] = it.next().context("ck'")?;
        kv.v[l] = it.next().context("cv'")?;
        // o-projection + residual
        let wo = engine.weight_buf(&p("wo"))?;
        x = engine
            .run(&st.oproj,
                 &[Arg::Dev(&wo), Arg::Dev(&attn_out), Arg::Dev(&x)])?
            .into_iter()
            .next()
            .context("oproj out")?;
        // ffn block (norm + swiglu + residual)
        let wn = engine.weight_buf(&p("ffn_norm"))?;
        let wg = engine.weight_buf(&p("w_gate"))?;
        let wu = engine.weight_buf(&p("w_up"))?;
        let wd = engine.weight_buf(&p("w_down"))?;
        x = engine
            .run(&st.ffn, &[Arg::Dev(&wn), Arg::Dev(&wg), Arg::Dev(&wu),
                            Arg::Dev(&wd), Arg::Dev(&x)])?
            .into_iter()
            .next()
            .context("ffn out")?;
    }
    // head
    let fnorm = engine.weight_buf("final_norm")?;
    let lm = engine.weight_buf("lm_head")?;
    let logits_buf = engine
        .run(&st.head, &[Arg::Dev(&fnorm), Arg::Dev(&lm), Arg::Dev(&x)])?
        .into_iter()
        .next()
        .context("head out")?;
    engine.download(&logits_buf)?.as_f32()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_count_formula() {
        assert_eq!(dispatches_per_token(4), 22);
        assert_eq!(dispatches_per_token(32), 162);
    }
}
