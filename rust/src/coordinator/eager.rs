//! Eager (per-operator dispatch) decode — the paper's unoptimized
//! baseline regime.
//!
//! Each decode step issues ~4·L+2 separate PJRT executions (embed, then
//! per-layer norm+qkv / attention / oproj / ffn, then head). The gap
//! between this and graph mode is the real, measured analogue of
//! Obs #2's "GPU idle time dominated by kernel-launch overhead" and of
//! the torch.compile + CUDA Graph speedups in Figs 5–7.
//!
//! The generate loop itself lives in [`crate::sched::exec::generate`];
//! this module only implements the [`StepExecutor`] hooks: the prompt
//! is consumed token-by-token through the eager step (no prefill graph
//! — the fully unoptimized pipeline), and each decode step is one
//! `eager_step` dispatch chain.

use anyhow::{Context, Result};
use xla::PjRtBuffer;

use crate::runtime::engine::{Arg, Engine, StageHandle};
use crate::runtime::tensor::Tensor;
use crate::sched::{ExecDims, SlotFeed, StepExecutor};
use crate::telemetry::tracer::Cat;

use super::decoder_loop::{DecoderDims, GenResult};
use super::request::SamplingParams;

struct EagerStages {
    embed: StageHandle,
    norm: StageHandle,
    qkv: StageHandle,
    attn: StageHandle,
    oproj: StageHandle,
    ffn: StageHandle,
    head: StageHandle,
}

impl EagerStages {
    fn load(engine: &Engine) -> Result<Self> {
        Ok(EagerStages {
            embed: engine.stage("eager_embed")?,
            norm: engine.stage("eager_norm")?,
            qkv: engine.stage("eager_qkv")?,
            attn: engine.stage("eager_attn")?,
            oproj: engine.stage("eager_oproj")?,
            ffn: engine.stage("eager_ffn")?,
            head: engine.stage("eager_head")?,
        })
    }
}

/// Per-layer KV device buffers for the eager loop.
struct EagerKv {
    k: Vec<PjRtBuffer>,
    v: Vec<PjRtBuffer>,
}

/// Dispatches per decoded token in eager mode (for overhead accounting):
/// embed + L·(norm + qkv + attn + oproj + ffn) + head.
pub fn dispatches_per_token(n_layers: usize) -> usize {
    2 + n_layers * 5
}

/// The per-operator dispatch pipeline as a [`StepExecutor`] (bs=1).
pub struct EagerExecutor<'e> {
    engine: &'e Engine,
    dims: DecoderDims,
    stages: EagerStages,
    kv: EagerKv,
}

impl<'e> EagerExecutor<'e> {
    pub fn new(engine: &'e Engine, dims: &DecoderDims) -> Result<Self> {
        let stages = EagerStages::load(engine)?;
        // zero per-layer caches [1, H, S, Dh]
        let kv_shape =
            [1, dims.n_heads, dims.max_seq, dims.head_dim];
        let zero =
            Tensor::zeros(crate::runtime::tensor::DType::F32, &kv_shape);
        let mut kv = EagerKv { k: Vec::new(), v: Vec::new() };
        for _ in 0..dims.n_layers {
            kv.k.push(engine.upload(&zero)?);
            kv.v.push(engine.upload(&zero)?);
        }
        Ok(EagerExecutor { engine, dims: *dims, stages, kv })
    }
}

impl StepExecutor for EagerExecutor<'_> {
    fn plan_dims(&self) -> ExecDims {
        ExecDims {
            batch: 1,
            max_seq: self.dims.max_seq,
            vocab: self.dims.vocab,
        }
    }

    fn step_span_name(&self) -> &'static str {
        "eager_step"
    }

    /// Eager has no prefill graph: the prompt is fed one token at a
    /// time through the eager step (one telemetry tick per token).
    /// Stops at the sequence capacity — `Ok(None)` tells the driver
    /// the prompt never finished and nothing can be generated.
    fn prefill_chunk(&mut self, _slot: usize, tokens: &[i32], start: usize,
                     is_last: bool) -> Result<Option<Vec<f32>>> {
        let tele = self.engine.tracer();
        let mut logits = Vec::new();
        for (i, &tok) in tokens.iter().enumerate() {
            let pos = start + i;
            if pos + 1 >= self.dims.max_seq {
                return Ok(None);
            }
            if let Some(t) = tele {
                t.next_tick();
            }
            let _step_span = tele.map(|t| t.span(Cat::Prefill, "eager_step"));
            logits = eager_step(self.engine, &self.stages, &self.dims, tok,
                                pos, &mut self.kv)?;
        }
        Ok((is_last && !logits.is_empty()).then_some(logits))
    }

    fn decode_step(&mut self, feeds: &[SlotFeed]) -> Result<Vec<f32>> {
        let f = feeds.first().context("bs=1 executor needs one feed")?;
        eager_step(self.engine, &self.stages, &self.dims, f.token, f.pos,
                   &mut self.kv)
    }
}

/// Eager generation (bs=1): build the executor, run the shared driver.
pub fn generate_eager(engine: &Engine, dims: &DecoderDims, prompt: &[i32],
                      max_new: usize, sp: &SamplingParams)
                      -> Result<GenResult> {
    let mut exec = EagerExecutor::new(engine, dims)?;
    crate::sched::generate(&mut exec, engine.tracer(), prompt, max_new, sp)
}

/// One eager decode step: 2 + 5·L separate dispatches.
fn eager_step(engine: &Engine, st: &EagerStages, dims: &DecoderDims,
              token: i32, pos: usize, kv: &mut EagerKv)
              -> Result<Vec<f32>> {
    let t_tok = Tensor::from_i32(&[1], &[token]);
    let t_pos = Tensor::from_i32(&[1], &[pos as i32]);

    // x = embed(token)
    let mut x = engine
        .run(&st.embed, &[Arg::Host(&t_tok)])?
        .into_iter()
        .next()
        .context("embed out")?;

    for l in 0..dims.n_layers {
        let p = |s: &str| format!("layers.{l}.{s}");
        // h = rmsnorm(x)
        let w_norm = engine.weight_buf(&p("attn_norm"))?;
        let h = engine
            .run(&st.norm, &[Arg::Dev(&w_norm), Arg::Dev(&x)])?
            .into_iter()
            .next()
            .context("norm out")?;
        // q, k, v (+rope)
        let wq = engine.weight_buf(&p("wq"))?;
        let wk = engine.weight_buf(&p("wk"))?;
        let wv = engine.weight_buf(&p("wv"))?;
        let qkv = engine.run(
            &st.qkv,
            &[Arg::Dev(&wq), Arg::Dev(&wk), Arg::Dev(&wv), Arg::Dev(&h),
              Arg::Host(&t_pos)],
        )?;
        let (q, k, v) = {
            let mut it = qkv.into_iter();
            (
                it.next().context("q")?,
                it.next().context("k")?,
                it.next().context("v")?,
            )
        };
        // cached attention
        let attn_outs = engine.run(
            &st.attn,
            &[Arg::Dev(&q), Arg::Dev(&k), Arg::Dev(&v), Arg::Host(&t_pos),
              Arg::Dev(&kv.k[l]), Arg::Dev(&kv.v[l])],
        )?;
        let mut it = attn_outs.into_iter();
        let attn_out = it.next().context("attn out")?;
        kv.k[l] = it.next().context("ck'")?;
        kv.v[l] = it.next().context("cv'")?;
        // o-projection + residual
        let wo = engine.weight_buf(&p("wo"))?;
        x = engine
            .run(&st.oproj,
                 &[Arg::Dev(&wo), Arg::Dev(&attn_out), Arg::Dev(&x)])?
            .into_iter()
            .next()
            .context("oproj out")?;
        // ffn block (norm + swiglu + residual)
        let wn = engine.weight_buf(&p("ffn_norm"))?;
        let wg = engine.weight_buf(&p("w_gate"))?;
        let wu = engine.weight_buf(&p("w_up"))?;
        let wd = engine.weight_buf(&p("w_down"))?;
        x = engine
            .run(&st.ffn, &[Arg::Dev(&wn), Arg::Dev(&wg), Arg::Dev(&wu),
                            Arg::Dev(&wd), Arg::Dev(&x)])?
            .into_iter()
            .next()
            .context("ffn out")?;
    }
    // head
    let fnorm = engine.weight_buf("final_norm")?;
    let lm = engine.weight_buf("lm_head")?;
    let logits_buf = engine
        .run(&st.head, &[Arg::Dev(&fnorm), Arg::Dev(&lm), Arg::Dev(&x)])?
        .into_iter()
        .next()
        .context("head out")?;
    engine.download(&logits_buf)?.as_f32()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_count_formula() {
        assert_eq!(dispatches_per_token(4), 22);
        assert_eq!(dispatches_per_token(32), 162);
    }
}
