//! Multi-model router + per-model engine workers.
//!
//! `Router` owns one worker thread per model family. Each worker builds
//! its own PJRT `Engine` (engines hold raw PJRT handles and are
//! deliberately thread-local) and serves requests from an mpsc queue:
//!
//! * **Llama / Chameleon text tasks** — continuous batching: free batch
//!   slots are filled by bucketed prefills (`kv_pack` inserts the fresh
//!   KV into the batched cache), then one batched decode step per tick
//!   serves all live slots (vLLM-style, over the static-batch graph).
//! * **Chameleon T-I** — bs=1 contrastive decoding (two decodes/step).
//! * **Seamless** — the four-module pipeline with beam search.
//! * **HSTU** — non-AR batch forward.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};
use xla::PjRtBuffer;

use crate::kvpool::{KvError, KvPoolConfig, PreemptMode};
use crate::models::tokenizer::{self, ImageTokenizer, TextTokenizer};
use crate::models::{ModelKind, TaskKind};
use crate::runtime::engine::{Arg, Engine, StageHandle};
use crate::runtime::tensor::{DType, Tensor};
use crate::substrate::metrics::ServeStats;
use crate::substrate::rng::Rng;
use crate::telemetry::tracer::{Cat, Tracer, WorkerTracer};

use super::batcher::{Batcher, QueuedRequest};
use super::decoder_loop::{encode_prompt, DecoderSession, KvBufs};
use super::hstu_loop::{HstuAttn, HstuRunner};
use super::kv::PagedKvSlots;
use super::opts::{ExecMode, OptConfig};
use super::request::{Request, RequestInput, Response, ResponseOutput};
use super::sampling;
use super::seamless_pipe::{ReorderMode, SeamlessPipeline, SeamlessTask};

pub struct WorkItem {
    pub request: Request,
    pub respond: Sender<Result<Response>>,
}

#[derive(Debug, Clone)]
pub struct RouterConfig {
    pub models: Vec<ModelKind>,
    pub opt: OptConfig,
    pub reorder: ReorderMode,
    /// Decode batch for the continuous batcher (must match a lowered
    /// `decode_b{N}` stage; 1 disables batching).
    pub batch: usize,
    /// Prefill token budget per tick (0 = unlimited).
    pub prefill_budget: usize,
    /// Paged KV pool sizing for the batched decoder: admission meters
    /// pages (with prefix sharing) instead of worst-case slots. The
    /// default is a dense-equivalent page budget; `page_size: 0`
    /// disables paging entirely (the seed's slot-only behavior).
    pub kv: KvPoolConfig,
    /// Request-path tracing: each worker registers itself and records
    /// spans for scheduling, tokenization, dispatch, and sampling.
    /// `None` (the default) keeps the serving path instrumentation-free.
    pub tracer: Option<Tracer>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            models: vec![ModelKind::Llama],
            opt: OptConfig::baseline(),
            reorder: ReorderMode::Fused,
            batch: 4,
            prefill_budget: 0,
            kv: KvPoolConfig::default(),
            tracer: None,
        }
    }
}

/// The multi-model front door.
pub struct Router {
    senders: HashMap<ModelKind, Sender<WorkItem>>,
    handles: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
}

impl Router {
    pub fn start(artifacts: &std::path::Path, cfg: RouterConfig) -> Self {
        let mut senders = HashMap::new();
        let mut handles = Vec::new();
        for model in cfg.models.clone() {
            let (tx, rx) = channel::<WorkItem>();
            senders.insert(model, tx);
            let dir = artifacts.join(model.dir_name());
            let cfg = cfg.clone();
            handles.push(std::thread::spawn(move || {
                if let Err(e) = worker_main(model, &dir, cfg, rx) {
                    eprintln!("[mmserve] {model:?} worker exited: {e:#}");
                }
            }));
        }
        Router { senders, handles, next_id: AtomicU64::new(1) }
    }

    pub fn fresh_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Submit a request; returns the response channel.
    pub fn submit(&self, request: Request) -> Result<Receiver<Result<Response>>> {
        let model = request.task.model();
        let tx = self
            .senders
            .get(&model)
            .with_context(|| format!("model {model:?} not serving"))?;
        let (rtx, rrx) = channel();
        tx.send(WorkItem { request, respond: rtx })
            .map_err(|_| anyhow!("worker for {model:?} is gone"))?;
        Ok(rrx)
    }

    /// Submit and block for the response.
    pub fn call(&self, request: Request) -> Result<Response> {
        let rx = self.submit(request)?;
        rx.recv().context("worker dropped response")?
    }

    /// Drop queues and join workers.
    pub fn shutdown(mut self) {
        self.senders.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

// ==========================================================================
// Workers
// ==========================================================================

fn worker_main(model: ModelKind, dir: &std::path::Path, cfg: RouterConfig,
               rx: Receiver<WorkItem>) -> Result<()> {
    let mut engine = Engine::load(dir)
        .with_context(|| format!("load engine {}", dir.display()))?;
    if let Some(tracer) = &cfg.tracer {
        engine.set_tracer(tracer.worker(&format!("{model:?}")));
    }
    match model {
        ModelKind::Llama | ModelKind::Chameleon => {
            decoder_worker(&engine, cfg, rx)
        }
        ModelKind::Seamless => seamless_worker(&engine, cfg, rx),
        ModelKind::Hstu => hstu_worker(&engine, rx),
    }
}

// ---- Llama / Chameleon ----------------------------------------------------

/// Per-slot in-flight generation state.
struct SlotJob {
    item: WorkItem,
    prompt_len: usize,
    tokens: Vec<i32>,
    rng: Rng,
    started: Instant,
    ttft: f64,
}

/// A request parked in the staging map between scheduler ticks.
enum Staged {
    /// Never admitted yet: tokenize + prefill on admission.
    Fresh(WorkItem),
    /// Preempted mid-decode: re-prefill prompt + generated tokens
    /// (the recompute half of the preemption policy) and continue.
    Resume(SlotJob),
}

/// Outcome of growing a slot's KV when the pool was out of pages.
enum Growth {
    /// A victim was evicted and the advance went through.
    Advanced,
    /// The growing request was itself the preemption victim; it has
    /// been requeued for recompute.
    SelfPreempted,
    /// Nothing left to evict — treat like the sequence cap.
    Capped,
}

/// Insert one prefilled KV into the batched cache at `slot`.
fn pack_slot(engine: &Engine, kv_pack: &StageHandle, ck: &PjRtBuffer,
             cv: &PjRtBuffer, kv1: &KvBufs, slot: usize)
             -> Result<(PjRtBuffer, PjRtBuffer)> {
    let t_slot = Tensor::from_i32(&[1], &[slot as i32]);
    let outs = engine.run(
        kv_pack,
        &[Arg::Dev(ck), Arg::Dev(cv), Arg::Dev(&kv1.k), Arg::Dev(&kv1.v),
          Arg::Host(&t_slot)],
    )?;
    let mut it = outs.into_iter();
    Ok((it.next().context("ck")?, it.next().context("cv")?))
}

/// The pool ran dry while `slot` needed a page for `fed`: preempt
/// latest-admitted sequences (requeueing them for recompute) until the
/// advance fits, we evict ourselves, or nothing is left to evict.
fn preempt_for_growth(slots: &mut PagedKvSlots, batcher: &mut Batcher,
                      staging: &mut HashMap<u64, Staged>,
                      jobs: &mut [Option<SlotJob>], slot: usize, fed: i32)
                      -> Result<Growth> {
    let this_req = slots.request_at(slot)?;
    loop {
        let Some((vslot, pre)) = slots.preempt(PreemptMode::Recompute)
        else {
            return Ok(Growth::Capped);
        };
        let job = jobs[vslot].take().context("preempted slot job")?;
        // Readmission prefills prompt + all-but-pending tokens; the
        // queue entry carries that length for capacity accounting.
        let prefix_len = job.prompt_len + job.tokens.len() - 1;
        let remaining = job
            .item
            .request
            .max_new_tokens
            .saturating_sub(job.tokens.len())
            .max(1);
        batcher.push_front(QueuedRequest {
            id: pre.request,
            prompt_len: prefix_len,
            max_new_tokens: remaining,
        });
        staging.insert(pre.request, Staged::Resume(job));
        if pre.request == this_req {
            return Ok(Growth::SelfPreempted);
        }
        match slots.advance(slot, fed) {
            Ok(_) => return Ok(Growth::Advanced),
            Err(KvError::CapacityExhausted { .. }) => continue,
            Err(_) => return Ok(Growth::Capped),
        }
    }
}

fn decoder_worker(engine: &Engine, cfg: RouterConfig,
                  rx: Receiver<WorkItem>) -> Result<()> {
    let session = DecoderSession::new(engine, cfg.opt)?;
    let dims = session.dims;
    let batch = if cfg.opt.exec == ExecMode::Eager || cfg.opt.layerskip {
        1 // eager / layerskip paths are bs=1 regimes (paper Fig 8)
    } else {
        cfg.batch
    };
    let use_batched = batch > 1
        && engine.has_stage(&format!("kv_pack_b{batch}"))
        && DecoderSession::decode_stage_name(engine, batch, &cfg.opt).is_ok();

    if !use_batched {
        // Sequential (bs=1) serving loop.
        while let Ok(item) = rx.recv() {
            let resp = serve_one_decoder(&session, &item.request);
            let _ = item.respond.send(resp);
        }
        return Ok(());
    }

    // ---- continuous batching loop ------------------------------------
    let decode_name =
        DecoderSession::decode_stage_name(engine, batch, &cfg.opt)?;
    let decode = engine.stage(&decode_name)?;
    let kv_pack = engine.stage(&format!("kv_pack_b{batch}"))?;
    let kv_shape = dims.kv_shape(batch);
    let zero = Tensor::zeros(DType::F32, &kv_shape);
    let mut ck: PjRtBuffer = engine.upload(&zero)?;
    let mut cv: PjRtBuffer = engine.upload(&zero)?;
    // The compiled graph keeps its dense per-slot cache; the paged pool
    // meters capacity (prefix sharing, growth, preemption) under it.
    let mut slots = PagedKvSlots::paged(batch, dims.max_seq, cfg.kv);
    let mut jobs: Vec<Option<SlotJob>> = (0..batch).map(|_| None).collect();
    let mut batcher = Batcher::new(cfg.prefill_budget);
    let mut staging: HashMap<u64, Staged> = HashMap::new();
    let mut closed = false;
    // Consecutive empty ticks with queued work: a request larger than
    // the whole page budget can never be admitted; shed it instead of
    // spinning forever.
    let mut stalled = 0usize;
    let tele = engine.tracer();

    loop {
        // Drain the queue without blocking while work is live.
        loop {
            match rx.try_recv() {
                Ok(item) => intake_decoder_item(item, &session, &mut batcher,
                                                &mut staging, tele)?,
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    closed = true;
                    break;
                }
            }
        }
        if closed && slots.live_count() == 0 && batcher.pending() == 0 {
            return Ok(());
        }
        if slots.live_count() == 0 && batcher.pending() == 0 {
            // Idle: block for the next request.
            match rx.recv() {
                Ok(item) => intake_decoder_item(item, &session, &mut batcher,
                                                &mut staging, tele)?,
                Err(_) => return Ok(()),
            }
            continue;
        }

        // One scheduler tick: admission, then one batched decode step.
        if let Some(t) = tele {
            t.next_tick();
        }

        // Admission: prefill into free slots, against the capacity
        // view (free slots + free pages − growth watermark).
        let adm = {
            let _s = tele.map(|t| t.span(Cat::Schedule, "admission"));
            batcher.tick(&slots.capacity_view())
        };
        // A free slot existed but pages didn't cover the next prompt:
        // count the tick and mark the host window so the idle-gap
        // attribution can bucket it as KvCapacity, not Scheduling. The
        // span is held only when the tick admitted *nothing* — on a
        // partially blocked tick the admitted requests' tokenize /
        // prefill / sample time must keep its own buckets.
        let kv_wait_span = if adm.blocked_on_capacity {
            slots.note_capacity_wait();
            if adm.admit.is_empty() {
                tele.map(|t| t.span(Cat::KvWait, "kv_capacity_wait"))
            } else {
                None
            }
        } else {
            None
        };
        if adm.admit.is_empty() && slots.live_count() == 0
            && batcher.pending() > 0
        {
            stalled += 1;
            if stalled > 2 {
                if let Some(q) = batcher.pop_front() {
                    if let Some(staged) = staging.remove(&q.id) {
                        let item = match staged {
                            Staged::Fresh(item) => item,
                            Staged::Resume(job) => job.item,
                        };
                        let _ = item.respond.send(Err(anyhow!(
                            "request {} exceeds the KV page budget",
                            q.id
                        )));
                    }
                }
                stalled = 0;
            }
        } else {
            stalled = 0;
        }
        for q in adm.admit {
            let staged = staging.remove(&q.id).context("staged item")?;
            let _req_scope = tele.map(|t| t.req_scope(q.id));
            match staged {
                Staged::Fresh(item) => {
                    let prefill_span =
                        tele.map(|t| t.span(Cat::Prefill, "admit"));
                    let started = Instant::now();
                    let prompt = {
                        let _t =
                            tele.map(|t| t.span(Cat::Tokenize, "tokenize"));
                        tokenize_decoder_input(&item.request)?
                    };
                    let (logits, kv1) = session.prefill(&prompt)?;
                    let slot = match slots.alloc(q.id, &prompt) {
                        Ok((slot, _share)) => slot,
                        Err(KvError::CapacityExhausted { .. }) => {
                            // Decode growth raced the admission view;
                            // retry next tick, FCFS position intact.
                            let id = q.id;
                            batcher.push_front(q);
                            staging.insert(id, Staged::Fresh(item));
                            continue;
                        }
                        Err(e) => {
                            // Structural refusal (prompt ≥ max_seq, …):
                            // fail the request, keep the worker alive.
                            let _ = item.respond.send(Err(e.into()));
                            continue;
                        }
                    };
                    let (nck, ncv) =
                        pack_slot(engine, &kv_pack, &ck, &cv, &kv1, slot)?;
                    ck = nck;
                    cv = ncv;
                    // sample the first token from the prefill logits
                    let mut rng =
                        Rng::new(item.request.sampling.seed ^ q.id);
                    let first = {
                        let _s =
                            tele.map(|t| t.span(Cat::Sample, "sample_first"));
                        sampling::sample(&logits, &item.request.sampling,
                                         &mut rng)
                    };
                    let ttft = started.elapsed().as_secs_f64();
                    drop(prefill_span);
                    jobs[slot] = Some(SlotJob {
                        prompt_len: prompt.len(),
                        tokens: vec![first],
                        rng,
                        started,
                        ttft,
                        item,
                    });
                }
                Staged::Resume(job) => {
                    // Recompute half of preemption: re-prefill prompt +
                    // all-but-pending generated tokens, then continue
                    // decoding from the job's saved state.
                    let prefill_span =
                        tele.map(|t| t.span(Cat::Prefill, "resume"));
                    let mut prefix = {
                        let _t =
                            tele.map(|t| t.span(Cat::Tokenize, "tokenize"));
                        tokenize_decoder_input(&job.item.request)?
                    };
                    prefix.extend_from_slice(
                        &job.tokens[..job.tokens.len() - 1],
                    );
                    let (_logits, kv1) = session.prefill(&prefix)?;
                    let slot = match slots.alloc(q.id, &prefix) {
                        Ok((slot, _share)) => slot,
                        Err(KvError::CapacityExhausted { .. }) => {
                            let id = q.id;
                            batcher.push_front(q);
                            staging.insert(id, Staged::Resume(job));
                            continue;
                        }
                        Err(e) => {
                            let _ = job.item.respond.send(Err(e.into()));
                            continue;
                        }
                    };
                    let (nck, ncv) =
                        pack_slot(engine, &kv_pack, &ck, &cv, &kv1, slot)?;
                    ck = nck;
                    cv = ncv;
                    drop(prefill_span);
                    jobs[slot] = Some(job);
                }
            }
        }
        drop(kv_wait_span);

        if slots.live_count() == 0 {
            continue;
        }

        // One batched decode step for all live slots.
        let step_span = tele.map(|t| t.span(Cat::Decode, "decode_step"));
        let mut toks = vec![0i32; batch];
        let mut poss = vec![0i32; batch];
        for (slot, _, pos) in slots.live_slots() {
            let job = jobs[slot].as_ref().unwrap();
            toks[slot] = *job.tokens.last().unwrap();
            poss[slot] = pos as i32;
        }
        let t_toks = Tensor::from_i32(&[batch], &toks);
        let t_poss = Tensor::from_i32(&[batch], &poss);
        let outs = engine.run(
            &decode,
            &[Arg::Host(&t_toks), Arg::Host(&t_poss), Arg::Dev(&ck),
              Arg::Dev(&cv)],
        )?;
        let mut it = outs.into_iter();
        let logits_buf = it.next().context("logits")?;
        ck = it.next().context("ck")?;
        cv = it.next().context("cv")?;
        let logits = engine.download(&logits_buf)?.as_f32()?;

        for (slot, _, _) in slots.live_slots() {
            // A preemption earlier in this pass may have emptied the
            // slot; skip it rather than unwrap.
            let (tok, sampled_done) = {
                let Some(job) = jobs[slot].as_mut() else { continue };
                // Per-slot Sample span carries the request id so the
                // time-between-tokens histogram works in batched mode.
                let _s = tele.map(|t| t.span_req(Cat::Sample, "sample",
                                                 job.item.request.id));
                let row =
                    &logits[slot * dims.vocab..(slot + 1) * dims.vocab];
                let tok = sampling::sample(row, &job.item.request.sampling,
                                           &mut job.rng);
                job.tokens.push(tok);
                (tok, tok == tokenizer::EOS
                    || job.tokens.len() >= job.item.request.max_new_tokens)
            };
            let mut done = sampled_done;
            if !done {
                // The cache now holds the token we just fed; record it
                // in the block table (this is where pages grow).
                let fed = toks[slot];
                match slots.advance(slot, fed) {
                    Ok(_) => {}
                    Err(KvError::CapacityExhausted { .. }) => {
                        match preempt_for_growth(&mut slots, &mut batcher,
                                                 &mut staging, &mut jobs,
                                                 slot, fed)? {
                            Growth::Advanced => {}
                            Growth::SelfPreempted => continue,
                            Growth::Capped => done = true,
                        }
                    }
                    // Sequence cap (max_seq): finish the request.
                    Err(_) => done = true,
                }
            }
            if done {
                let job = jobs[slot].take().unwrap();
                slots.release(slot)?;
                let resp = finish_decoder_response(&job);
                let _ = job.item.respond.send(Ok(resp));
            }
        }
        drop(step_span);
    }
}

/// Take one arriving request into the batched decoder: serve
/// non-batchable tasks inline, otherwise tokenize (traced) and queue.
fn intake_decoder_item(item: WorkItem, session: &DecoderSession,
                       batcher: &mut Batcher,
                       staging: &mut HashMap<u64, Staged>,
                       tele: Option<&WorkerTracer>) -> Result<()> {
    // Non-batchable tasks (T-I contrastive) run inline.
    if item.request.task == TaskKind::TextToImage {
        let resp = serve_one_decoder(session, &item.request);
        let _ = item.respond.send(resp);
        return Ok(());
    }
    let prompt = {
        let _t = tele.map(|t| t.span_req(Cat::Tokenize, "tokenize",
                                         item.request.id));
        tokenize_decoder_input(&item.request)?
    };
    batcher.push(QueuedRequest {
        id: item.request.id,
        prompt_len: prompt.len(),
        max_new_tokens: item.request.max_new_tokens,
    });
    staging.insert(item.request.id, Staged::Fresh(item));
    Ok(())
}

fn tokenize_decoder_input(req: &Request) -> Result<Vec<i32>> {
    Ok(match &req.input {
        RequestInput::Text(t) => encode_prompt(t),
        RequestInput::Tokens(ts) => ts.clone(),
        RequestInput::Image { pixels, h, w } => {
            let mut ids = vec![tokenizer::BOS];
            ids.extend(ImageTokenizer::encode(pixels, *h, *w));
            // "Describe the figure" prompt suffix (paper §3.1, I-T).
            ids.extend(TextTokenizer::new().encode("Describe"));
            ids
        }
        RequestInput::ImageText { pixels, h, w, text } => {
            let mut ids = vec![tokenizer::BOS];
            ids.extend(ImageTokenizer::encode(pixels, *h, *w));
            ids.extend(TextTokenizer::new().encode(text));
            ids
        }
        other => bail!("unsupported decoder input {other:?}"),
    })
}

fn serve_one_decoder(session: &DecoderSession, req: &Request)
                     -> Result<Response> {
    let started = Instant::now();
    let tele = session.engine.tracer();
    let _req_scope = tele.map(|t| t.req_scope(req.id));
    let prompt = {
        let _t = tele.map(|t| t.span(Cat::Tokenize, "tokenize"));
        tokenize_decoder_input(req)?
    };
    if req.task == TaskKind::TextToImage {
        let gen = session.generate_image(&prompt, tokenizer::IMG_TOKENS,
                                         &req.sampling)?;
        return Ok(Response {
            id: req.id,
            task: req.task,
            output: ResponseOutput::Image(ImageTokenizer::decode(&gen.tokens)),
            tokens: gen.tokens.clone(),
            prompt_tokens: gen.prompt_tokens,
            decode_steps: gen.decode_steps,
            ttft: gen.ttft,
            e2e: started.elapsed().as_secs_f64(),
        });
    }
    let gen = session.generate(&prompt, req.max_new_tokens, &req.sampling)?;
    let text = TextTokenizer::new().decode(&gen.tokens);
    Ok(Response {
        id: req.id,
        task: req.task,
        output: ResponseOutput::Text(text),
        tokens: gen.tokens.clone(),
        prompt_tokens: gen.prompt_tokens,
        decode_steps: gen.decode_steps,
        ttft: gen.ttft,
        e2e: started.elapsed().as_secs_f64(),
    })
}

fn finish_decoder_response(job: &SlotJob) -> Response {
    let text = TextTokenizer::new().decode(&job.tokens);
    Response {
        id: job.item.request.id,
        task: job.item.request.task,
        output: ResponseOutput::Text(text),
        tokens: job.tokens.clone(),
        prompt_tokens: job.prompt_len,
        decode_steps: job.tokens.len(),
        ttft: job.ttft,
        e2e: job.started.elapsed().as_secs_f64(),
    }
}

// ---- Seamless ---------------------------------------------------------------

fn seamless_worker(engine: &Engine, cfg: RouterConfig,
                   rx: Receiver<WorkItem>) -> Result<()> {
    let pipe = SeamlessPipeline::new(engine, cfg.reorder)?;
    while let Ok(item) = rx.recv() {
        let resp = serve_one_seamless(&pipe, &item.request);
        let _ = item.respond.send(resp);
    }
    Ok(())
}

fn serve_one_seamless(pipe: &SeamlessPipeline, req: &Request)
                      -> Result<Response> {
    let started = Instant::now();
    let task = match req.task {
        TaskKind::SpeechToText => SeamlessTask::SpeechToText,
        TaskKind::SpeechToSpeech => SeamlessTask::SpeechToSpeech,
        TaskKind::TextToTextTrans => SeamlessTask::TextToText,
        TaskKind::TextToSpeech => SeamlessTask::TextToSpeech,
        t => bail!("not a seamless task: {t}"),
    };
    let (speech, text): (Option<&[f32]>, Option<&str>) = match &req.input {
        RequestInput::Speech(w) => (Some(w.as_slice()), None),
        RequestInput::Text(t) => (None, Some(t.as_str())),
        other => bail!("unsupported seamless input {other:?}"),
    };
    let _req_scope = pipe.engine.tracer().map(|t| t.req_scope(req.id));
    let out = pipe.run(task, speech, text, req.max_new_tokens)?;
    let output = if task.speech_out() {
        ResponseOutput::Speech(out.waveform.clone())
    } else {
        ResponseOutput::Text(out.text.clone())
    };
    Ok(Response {
        id: req.id,
        task: req.task,
        output,
        tokens: out.text_tokens.clone(),
        prompt_tokens: 0,
        decode_steps: out.decode_steps,
        ttft: out.e2e, // beam search emits only on completion
        e2e: started.elapsed().as_secs_f64(),
    })
}

// ---- HSTU --------------------------------------------------------------------

fn hstu_worker(engine: &Engine, rx: Receiver<WorkItem>) -> Result<()> {
    let runner = HstuRunner::new(engine, HstuAttn::Fused)?;
    while let Ok(item) = rx.recv() {
        let resp = serve_one_hstu(&runner, &item.request);
        let _ = item.respond.send(resp);
    }
    Ok(())
}

fn serve_one_hstu(runner: &HstuRunner, req: &Request) -> Result<Response> {
    let started = Instant::now();
    let RequestInput::History(h) = &req.input else {
        bail!("hstu expects History input");
    };
    let _req_scope = runner.engine.tracer().map(|t| t.req_scope(req.id));
    let results = runner.run_batch(std::slice::from_ref(h), 8, 10)?;
    let r = results.into_iter().next().context("hstu result")?;
    Ok(Response {
        id: req.id,
        task: req.task,
        output: ResponseOutput::Actions {
            engagement: r.engagement,
            top_items: r.top_items,
        },
        tokens: vec![],
        prompt_tokens: h.len(),
        decode_steps: 0, // non-autoregressive (Obs #1)
        ttft: r.e2e,
        e2e: started.elapsed().as_secs_f64(),
    })
}

/// Aggregate responses into serving statistics.
pub fn collect_stats(responses: &[Response], wall_secs: f64) -> ServeStats {
    let mut s = ServeStats { wall_secs, ..Default::default() };
    for r in responses {
        s.requests_completed += 1;
        s.tokens_generated += r.decode_steps as u64;
        s.prefill_tokens += r.prompt_tokens as u64;
        s.ttft.record(r.ttft * 1e3);
        s.e2e.record(r.e2e * 1e3);
        if r.decode_steps > 1 {
            s.tpot
                .record(r.e2e * 1e3 / r.decode_steps as f64);
        }
    }
    s
}
