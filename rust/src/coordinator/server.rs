//! Multi-model router + replicated per-model engine workers.
//!
//! `Router` owns `RouterConfig::replicas` worker threads per model
//! family. Each worker builds its own PJRT `Engine` (engines hold raw
//! PJRT handles and are deliberately thread-local) and serves requests
//! from an mpsc queue. A routing policy (`crate::routing`) picks the
//! replica per request: `prefix-affinity` (the default) probes each
//! replica's published cache snapshot for the longest resident prompt
//! prefix — same-system-prompt traffic lands on the worker whose
//! `PrefixCache` is already warm — with queue-depth tie-breaks and a
//! least-loaded fallback; a replica whose channel is gone degrades to
//! the next choice, never dropping the request. Worker loops:
//!
//! * **Llama / Chameleon text tasks** — continuous batching through the
//!   unified tick scheduler: every tick, `sched::Scheduler::plan` turns
//!   the queue + the kvpool capacity view into a `TickPlan` (decode set
//!   ∪ prefill chunks), and [`run_tick`] executes it against the
//!   [`BatchedExecutor`] (vLLM-style, over the static-batch graph).
//!   With `--chunk-prefill` long prompts are fed in budget-sized
//!   chunks interleaved with decode steps: the first chunk goes
//!   through the bucketed prefill + `kv_pack`, continuation tokens
//!   append incrementally through the batched decode graph while the
//!   block tables claim pages chunk by chunk.
//! * **Chameleon T-I** — bs=1 contrastive decoding (two decodes/step).
//! * **Seamless** — the four-module pipeline; its beam search runs on
//!   the unified core (`SeamlessExecutor` + `sched::generate_beam`),
//!   so beam reorder is a block-table fork/prune in the kvpool rather
//!   than a KV copy (Obs #4).
//! * **HSTU** — non-AR one-shot scoring (`HstuExecutor` +
//!   `sched::generate` with `max_new == 0`): a prefill-only plan with
//!   zero decode ticks (Obs #1).
//!
//! A single `Router` can hold replica sets for *several* families at
//! once (a mixed fleet): the dispatch map keys queues by `ModelKind`,
//! so chat, Seamless, and HSTU workers tick side by side in one run
//! while per-family TTFT/TBT and idle attribution flow through the
//! shared telemetry plane.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};
use xla::PjRtBuffer;

use crate::kvpool::{KvError, KvPoolConfig, PreemptMode};
use crate::models::tokenizer::{self, ImageTokenizer, TextTokenizer};
use crate::models::{ModelKind, TaskKind};
use crate::routing::{rank, ReplicaCell, ReplicaView, RoutingPolicy};
use crate::runtime::engine::{Arg, Engine, StageHandle};
use crate::runtime::tensor::{DType, Tensor};
use crate::sched::{generate, ExecDims, PlannedChunk, SchedConfig,
                   Scheduler, SlotFeed, SlotStateError, StepExecutor,
                   TickPlan};
use crate::substrate::metrics::ServeStats;
use crate::substrate::rng::Rng;
use crate::substrate::table::Table;
use crate::telemetry::ledger::{RequestLedger, TickCharges};
use crate::telemetry::live::sampler::ROUTED_TOTAL;
use crate::telemetry::live::{FlightRecorder, LiveMetrics,
                             OnlineAttribution, WorkerSampler};
use crate::telemetry::tracer::{Cat, Tracer, WorkerTracer};

use super::batcher::QueuedRequest;
use super::decoder_loop::{encode_prompt, DecoderSession, KvBufs};
use super::hstu_loop::{HstuAttn, HstuExecutor, HstuRunner};
use super::kv::PagedKvSlots;
use super::opts::{ExecMode, OptConfig};
use super::request::{Request, RequestInput, Response, ResponseOutput};
use super::sampling;
use super::seamless_pipe::{ReorderMode, SeamlessPipeline, SeamlessTask};

pub struct WorkItem {
    pub request: Request,
    pub respond: Sender<Result<Response>>,
}

#[derive(Debug, Clone)]
pub struct RouterConfig {
    pub models: Vec<ModelKind>,
    pub opt: OptConfig,
    pub reorder: ReorderMode,
    /// Decode batch for the continuous batcher (must match a lowered
    /// `decode_b{N}` stage; 1 disables batching).
    pub batch: usize,
    /// Prefill token budget per tick (0 = unlimited).
    pub prefill_budget: usize,
    /// Chunked prefill: max new prompt tokens fed per scheduler tick
    /// (0 = whole-prompt admission, the seed behavior). Long prompts
    /// are split into chunks interleaved with decode steps, bounding
    /// the decode stall any single admission can cause.
    pub chunk_prefill: usize,
    /// Paged KV pool sizing for the batched decoder: admission meters
    /// pages (with prefix sharing) instead of worst-case slots. The
    /// default is a dense-equivalent page budget; `page_size: 0`
    /// disables paging entirely (the seed's slot-only behavior).
    pub kv: KvPoolConfig,
    /// Request-path tracing: each worker registers itself and records
    /// spans for scheduling, tokenization, dispatch, and sampling.
    /// `None` (the default) keeps the serving path instrumentation-free.
    pub tracer: Option<Tracer>,
    /// Live observability plane (`mmserve stats`, `--metrics-out`):
    /// every worker publishes per-tick fleet samples, TTFT/TBT
    /// sketches, and online idle-gap attribution into this shared
    /// registry. `None` (the default) publishes nothing.
    pub live: Option<LiveMetrics>,
    /// Shared flight recorder: bounded ring of per-tick events dumped
    /// on crash, preemption storm, or SIGTERM. `None` disables.
    pub flight: Option<FlightRecorder>,
    /// Per-request causal cost ledger (`mmserve explain`): each
    /// worker records enqueue, admission, prefill chunks, preemptions,
    /// decode ticks, waiting buckets and completion per request,
    /// stamped with wall seconds since the worker started. `None`
    /// (the default) records nothing.
    pub ledger: Option<RequestLedger>,
    /// Worker threads per model family (each with its own engine and
    /// KV pool). 1 (the default) is the seed topology.
    pub replicas: usize,
    /// How the router picks among replicas (ignored with 1 replica).
    pub policy: RoutingPolicy,
    /// Disaggregated topology: with 2+ replicas, the first half of
    /// each model's replicas form the prefill tier — the only targets
    /// arrival routing considers — and the rest the decode tier,
    /// mirroring the modeled split in `routing::replay`. The live
    /// engines here still serve admitted requests end-to-end (the
    /// priced prefill→decode KV handoff runs on the simulated plane,
    /// where the fabric clock lives); this flag pins the fleet
    /// topology and role reporting to match that model. Inert with a
    /// single replica.
    pub disaggregate: bool,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            models: vec![ModelKind::Llama],
            opt: OptConfig::baseline(),
            reorder: ReorderMode::Fused,
            batch: 4,
            prefill_budget: 0,
            chunk_prefill: 0,
            kv: KvPoolConfig::default(),
            tracer: None,
            live: None,
            flight: None,
            ledger: None,
            replicas: 1,
            policy: RoutingPolicy::PrefixAffinity,
            disaggregate: false,
        }
    }
}

/// One replica's routing endpoint: its request channel plus the shared
/// state cell the routing decision reads.
struct ReplicaHandle {
    tx: Sender<WorkItem>,
    cell: Arc<ReplicaCell>,
}

/// All replicas of one model family + the round-robin cursor.
struct ModelReplicas {
    replicas: Vec<ReplicaHandle>,
    rr: AtomicU64,
    /// Replica indices arrival routing may pick: every replica in the
    /// colocated topology, only the prefill tier under
    /// [`RouterConfig::disaggregate`]. Fail-over stays inside this
    /// set — a decode-tier replica never takes arrivals, so a fully
    /// dead prefill tier is a loud routing error, not a silent role
    /// violation.
    arrival: Vec<usize>,
}

/// Per-replica routing counters for reports (`mmserve trace`).
#[derive(Debug, Clone)]
pub struct ReplicaReport {
    pub model: ModelKind,
    pub replica: usize,
    /// Fleet role: `"prefill"` / `"decode"` under disaggregation,
    /// `"-"` in the colocated topology.
    pub role: &'static str,
    /// Requests the router handed to this replica.
    pub routed: u64,
    /// Prefix counters from the replica's last published snapshot.
    pub prefix_lookups: u64,
    pub prefix_hits: u64,
    pub prefix_hit_tokens: u64,
    /// Live pages per device shard at the last publish (empty until a
    /// sharded worker publishes) — the per-shard occupancy gauge.
    pub shard_live_pages: Vec<u64>,
}

impl ReplicaReport {
    pub fn hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            return 0.0;
        }
        self.prefix_hits as f64 / self.prefix_lookups as f64
    }
}

/// Per-worker routing rows + a fleet row with rates from summed
/// counters (never averaged per-worker rates).
pub fn render_replica_reports(reports: &[ReplicaReport]) -> String {
    let mut t = Table::new(&[
        "worker", "role", "routed", "prefix lookups", "prefix hits",
        "hit rate", "hit tokens", "shard pages",
    ]);
    let (mut lookups, mut hits, mut tokens, mut routed) = (0u64, 0u64, 0u64, 0u64);
    for r in reports {
        let shard_pages = if r.shard_live_pages.is_empty() {
            "-".to_string()
        } else {
            r.shard_live_pages
                .iter()
                .map(|p| p.to_string())
                .collect::<Vec<_>>()
                .join("/")
        };
        t.row(&[
            format!("{:?}[{}]", r.model, r.replica),
            r.role.to_string(),
            r.routed.to_string(),
            r.prefix_lookups.to_string(),
            r.prefix_hits.to_string(),
            format!("{:.1}%", r.hit_rate() * 100.0),
            r.prefix_hit_tokens.to_string(),
            shard_pages,
        ]);
        lookups += r.prefix_lookups;
        hits += r.prefix_hits;
        tokens += r.prefix_hit_tokens;
        routed += r.routed;
    }
    let fleet_rate = if lookups == 0 {
        0.0
    } else {
        hits as f64 / lookups as f64
    };
    t.row(&[
        "fleet (summed)".into(),
        "-".into(),
        routed.to_string(),
        lookups.to_string(),
        hits.to_string(),
        format!("{:.1}%", fleet_rate * 100.0),
        tokens.to_string(),
        "-".into(),
    ]);
    t.render()
}

/// The multi-model front door.
pub struct Router {
    models: HashMap<ModelKind, ModelReplicas>,
    handles: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    policy: RoutingPolicy,
    route_tracer: Option<WorkerTracer>,
    live: Option<LiveMetrics>,
}

impl Router {
    pub fn start(artifacts: &std::path::Path, cfg: RouterConfig) -> Self {
        let n = cfg.replicas.max(1);
        let policy = cfg.policy;
        let route_tracer = cfg.tracer.as_ref().map(|t| t.worker("router"));
        let live = cfg.live.clone();
        let mut models = HashMap::new();
        let mut handles = Vec::new();
        for model in cfg.models.clone() {
            let mut replicas = Vec::new();
            for r in 0..n {
                let (tx, rx) = channel::<WorkItem>();
                let cell = Arc::new(ReplicaCell::new());
                let dir = artifacts.join(model.dir_name());
                let cfg = cfg.clone();
                let worker_cell = cell.clone();
                handles.push(std::thread::spawn(move || {
                    if let Err(e) =
                        worker_main(model, r, &dir, cfg, rx, worker_cell)
                    {
                        eprintln!(
                            "[mmserve] {model:?}[{r}] worker exited: {e:#}"
                        );
                    }
                }));
                replicas.push(ReplicaHandle { tx, cell });
            }
            // Disaggregation pins the first half of the fleet as the
            // prefill tier (at least one replica each side).
            let arrival: Vec<usize> = if cfg.disaggregate && n >= 2 {
                (0..(n / 2).max(1)).collect()
            } else {
                (0..n).collect()
            };
            models.insert(model, ModelReplicas {
                replicas,
                rr: AtomicU64::new(0),
                arrival,
            });
        }
        Router {
            models,
            handles,
            next_id: AtomicU64::new(1),
            policy,
            route_tracer,
            live,
        }
    }

    pub fn fresh_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Submit a request; returns the response channel. The routing
    /// policy ranks the model's replicas (prefix warmth / queue depth
    /// / rotation) and the request is offered down that order, so a
    /// dead replica falls through to the next instead of failing the
    /// request while any replica lives.
    pub fn submit(&self, request: Request) -> Result<Receiver<Result<Response>>> {
        let model = request.task.model();
        let set = self
            .models
            .get(&model)
            .with_context(|| format!("model {model:?} not serving"))?;
        let order = {
            let _route_span = self.route_tracer.as_ref().map(|t| {
                t.span_req(Cat::Route, "route", request.id)
            });
            route_order(self.policy, set, &request)
        };
        let (rtx, rrx) = channel();
        let mut item = WorkItem { request, respond: rtx };
        for idx in order {
            let replica = &set.replicas[idx];
            // Count before sending: a fast worker's dequeue must never
            // race ahead of the enqueue accounting (the gauge would
            // saturate at 0 and then drift up one forever).
            replica.cell.note_routed();
            match replica.tx.send(item) {
                Ok(()) => {
                    if let Some(live) = &self.live {
                        if live.is_enabled() {
                            let m = format!("{model:?}");
                            let r = idx.to_string();
                            live.inc(ROUTED_TOTAL,
                                     &[("model", m.as_str()),
                                       ("replica", r.as_str())],
                                     1);
                        }
                    }
                    return Ok(rrx);
                }
                // The replica's worker is gone; undo the accounting,
                // recover the item, and offer it to the next choice.
                Err(send_err) => {
                    replica.cell.note_route_failed();
                    item = send_err.0;
                }
            }
        }
        Err(anyhow!("all workers for {model:?} are gone"))
    }

    /// Submit and block for the response.
    pub fn call(&self, request: Request) -> Result<Response> {
        let rx = self.submit(request)?;
        rx.recv().context("worker dropped response")?
    }

    /// Routing counters per replica, in stable (model, replica) order.
    pub fn replica_reports(&self) -> Vec<ReplicaReport> {
        let mut out = Vec::new();
        for (model, set) in &self.models {
            let split = set.arrival.len() < set.replicas.len();
            for (i, h) in set.replicas.iter().enumerate() {
                let (_, lookups, hits, tokens) = h.cell.counters();
                let role = if !split {
                    "-"
                } else if set.arrival.contains(&i) {
                    "prefill"
                } else {
                    "decode"
                };
                out.push(ReplicaReport {
                    model: *model,
                    replica: i,
                    role,
                    routed: h.cell.routed(),
                    prefix_lookups: lookups,
                    prefix_hits: hits,
                    prefix_hit_tokens: tokens,
                    shard_live_pages: h.cell.shard_occupancy(),
                });
            }
        }
        out.sort_by_key(|r| (format!("{:?}", r.model), r.replica));
        out
    }

    /// Drop queues and join workers.
    pub fn shutdown(mut self) {
        self.models.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Tokens for the routing prefix probe. Must produce the same stream
/// as the worker's `encode_prompt` (BOS + BPE) or probes would never
/// match worker-resident blocks — but through a thread-local
/// tokenizer, so the submit path doesn't rebuild the merge table per
/// request. Only text/token inputs are probed (image/speech
/// featurization is too costly to run on the submit path).
fn probe_tokens_for(input: &RequestInput) -> Option<Vec<i32>> {
    thread_local! {
        static TOKENIZER: TextTokenizer = TextTokenizer::new();
    }
    match input {
        RequestInput::Text(t) => Some(TOKENIZER.with(|tk| {
            let mut ids = vec![tokenizer::BOS];
            ids.extend(tk.encode(t));
            ids
        })),
        RequestInput::Tokens(ts) => Some(ts.clone()),
        _ => None,
    }
}

/// Rank a model's arrival-eligible replicas for one request (the whole
/// fleet, or only the prefill tier under disaggregation); non-probeable
/// inputs rank on depth alone.
fn route_order(policy: RoutingPolicy, set: &ModelReplicas,
               request: &Request) -> Vec<usize> {
    let eligible = &set.arrival;
    if eligible.len() <= 1 {
        return eligible.clone();
    }
    let probe_tokens: Option<Vec<i32>> =
        if policy == RoutingPolicy::PrefixAffinity {
            probe_tokens_for(&request.input)
        } else {
            None
        };
    let views: Vec<ReplicaView> = eligible
        .iter()
        .map(|&i| {
            let h = &set.replicas[i];
            // Shard-set probe: warmth is the union over the replica's
            // device arenas; the spread feeds the depth tie-break.
            let (cached_blocks, shard_spread) = probe_tokens
                .as_deref()
                .map_or((0, 0), |toks| h.cell.probe_shards(toks));
            ReplicaView {
                cached_blocks,
                depth: h.cell.depth(),
                shard_spread,
            }
        })
        .collect();
    let cursor = set.rr.fetch_add(1, Ordering::Relaxed);
    rank(policy, &views, cursor)
        .into_iter()
        .map(|r| eligible[r])
        .collect()
}

// ==========================================================================
// Workers
// ==========================================================================

fn worker_main(model: ModelKind, replica: usize, dir: &std::path::Path,
               cfg: RouterConfig, rx: Receiver<WorkItem>,
               cell: Arc<ReplicaCell>) -> Result<()> {
    let mut engine = Engine::load(dir)
        .with_context(|| format!("load engine {}", dir.display()))?;
    if let Some(tracer) = &cfg.tracer {
        engine.set_tracer(tracer.worker(&format!("{model:?}[{replica}]")));
    }
    match model {
        ModelKind::Llama | ModelKind::Chameleon => {
            decoder_worker(&engine, cfg, rx, &cell, replica)
        }
        ModelKind::Seamless => seamless_worker(&engine, cfg, rx, &cell),
        ModelKind::Hstu => hstu_worker(&engine, rx, &cell),
    }
}

// ---- Llama / Chameleon ----------------------------------------------------

/// Per-slot in-flight generation state.
struct SlotJob {
    item: WorkItem,
    prompt_len: usize,
    tokens: Vec<i32>,
    rng: Rng,
    started: Instant,
    ttft: f64,
}

/// A request parked in the staging map between scheduler ticks.
enum Staged {
    /// Never admitted yet: tokenize + prefill on admission.
    Fresh(WorkItem),
    /// Preempted mid-decode: re-prefill prompt + generated tokens
    /// (the recompute half of the preemption policy) and continue.
    Resume(SlotJob),
}

impl Staged {
    fn into_item(self) -> WorkItem {
        match self {
            Staged::Fresh(item) => item,
            Staged::Resume(job) => job.item,
        }
    }
}

/// A request mid-way through a chunked prefill: it holds a slot and
/// the pages for the tokens fed so far; `tokens` is the full prefill
/// prefix (prompt, plus generated tokens for a preemption resume).
struct PrefillState {
    slot: usize,
    tokens: Vec<i32>,
    staged: Staged,
    started: Instant,
}

/// All mutable bookkeeping of one batched decoder worker.
struct WorkerState {
    /// Per-slot decode jobs (None for free and mid-prefill slots).
    jobs: Vec<Option<SlotJob>>,
    /// Chunked prefills in flight, by request id.
    prefills: HashMap<u64, PrefillState>,
    /// Queued (not yet admitted) request payloads, by request id.
    staging: HashMap<u64, Staged>,
    /// The tick planner (queue + request state machine).
    sched: Scheduler,
}

/// Outcome of growing a slot's KV when the pool was out of pages.
enum Growth {
    /// A victim was evicted and the advance went through.
    Advanced,
    /// The growing request was itself the preemption victim; it has
    /// been requeued for recompute.
    SelfPreempted,
    /// Nothing left to evict — treat like the sequence cap.
    Capped,
}

/// The queue entry a parked request would occupy (for requeues).
fn queue_entry_for(staged: &Staged, prefix_len: usize) -> QueuedRequest {
    match staged {
        Staged::Fresh(item) => QueuedRequest {
            id: item.request.id,
            prompt_len: prefix_len,
            max_new_tokens: item.request.max_new_tokens,
        },
        Staged::Resume(job) => QueuedRequest {
            id: job.item.request.id,
            prompt_len: prefix_len,
            max_new_tokens: job
                .item
                .request
                .max_new_tokens
                .saturating_sub(job.tokens.len())
                .max(1),
        },
    }
}

/// Insert one prefilled KV into the batched cache at `slot`.
fn pack_slot(engine: &Engine, kv_pack: &StageHandle, ck: &PjRtBuffer,
             cv: &PjRtBuffer, kv1: &KvBufs, slot: usize)
             -> Result<(PjRtBuffer, PjRtBuffer)> {
    let t_slot = Tensor::from_i32(&[1], &[slot as i32]);
    let outs = engine.run(
        kv_pack,
        &[Arg::Dev(ck), Arg::Dev(cv), Arg::Dev(&kv1.k), Arg::Dev(&kv1.v),
          Arg::Host(&t_slot)],
    )?;
    let mut it = outs.into_iter();
    Ok((it.next().context("ck")?, it.next().context("cv")?))
}

/// The compiled static-batch graph as a [`StepExecutor`]: first chunks
/// go through the bucketed prefill + `kv_pack`, decode steps (and
/// chunk-continuation feeds) through the batched decode stage, with
/// the device-resident batched KV chained through.
pub struct BatchedExecutor<'s, 'e> {
    session: &'s DecoderSession<'e>,
    decode: StageHandle,
    kv_pack: StageHandle,
    ck: PjRtBuffer,
    cv: PjRtBuffer,
    batch: usize,
}

impl<'s, 'e> BatchedExecutor<'s, 'e> {
    pub fn new(engine: &'e Engine, session: &'s DecoderSession<'e>,
               batch: usize, opt: &OptConfig) -> Result<Self> {
        let decode_name =
            DecoderSession::decode_stage_name(engine, batch, opt)?;
        let decode = engine.stage(&decode_name)?;
        let kv_pack = engine.stage(&format!("kv_pack_b{batch}"))?;
        let kv_shape = session.dims.kv_shape(batch);
        let zero = Tensor::zeros(DType::F32, &kv_shape);
        let ck = engine.upload(&zero)?;
        let cv = engine.upload(&zero)?;
        Ok(BatchedExecutor { session, decode, kv_pack, ck, cv, batch })
    }
}

impl StepExecutor for BatchedExecutor<'_, '_> {
    fn plan_dims(&self) -> ExecDims {
        ExecDims {
            batch: self.batch,
            max_seq: self.session.dims.max_seq,
            vocab: self.session.dims.vocab,
        }
    }

    fn prefill_chunk(&mut self, slot: usize, tokens: &[i32], start: usize,
                     is_last: bool) -> Result<Option<Vec<f32>>> {
        if start != 0 {
            bail!("batched chunk continuations feed through decode_step");
        }
        let (logits, kv1) = self.session.prefill(tokens)?;
        let engine = self.session.engine;
        let (nck, ncv) =
            pack_slot(engine, &self.kv_pack, &self.ck, &self.cv, &kv1,
                      slot)?;
        self.ck = nck;
        self.cv = ncv;
        Ok(is_last.then_some(logits))
    }

    fn decode_step(&mut self, feeds: &[SlotFeed]) -> Result<Vec<f32>> {
        let mut toks = vec![0i32; self.batch];
        let mut poss = vec![0i32; self.batch];
        for f in feeds {
            toks[f.slot] = f.token;
            poss[f.slot] = f.pos as i32;
        }
        let t_toks = Tensor::from_i32(&[self.batch], &toks);
        let t_poss = Tensor::from_i32(&[self.batch], &poss);
        let engine = self.session.engine;
        let outs = engine.run(
            &self.decode,
            &[Arg::Host(&t_toks), Arg::Host(&t_poss), Arg::Dev(&self.ck),
              Arg::Dev(&self.cv)],
        )?;
        let mut it = outs.into_iter();
        let logits_buf = it.next().context("logits")?;
        self.ck = it.next().context("ck")?;
        self.cv = it.next().context("cv")?;
        engine.download(&logits_buf)?.as_f32()
    }
}

/// One worker's view of the shared request ledger: the handle plus
/// the worker's epoch, so every hook is stamped with wall seconds
/// since this worker started (the ledger API takes `f64` seconds,
/// matching the replay drivers' simulated clock).
struct WorkerLedger {
    ledger: RequestLedger,
    epoch: Instant,
    replica: u32,
}

impl WorkerLedger {
    fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }
}

/// End-of-tick ledger charge: split the tick's wall time across the
/// requests still waiting in staging (preempted / capacity-blocked /
/// queued, disambiguated by the ledger's per-request state). The real
/// path charges waiting buckets only — per-request page counts and
/// prefill compute shares are replay-driver refinements.
fn charge_ledger_tick(ledger: Option<&WorkerLedger>,
                      tick_started: Option<Instant>, blocked: bool,
                      st: &WorkerState) {
    let (Some(wl), Some(t0)) = (ledger, tick_started) else {
        return;
    };
    let dt = t0.elapsed().as_secs_f64();
    if dt <= 0.0 {
        return;
    }
    let waiting: Vec<u64> = st.staging.keys().copied().collect();
    wl.ledger.charge_tick(&TickCharges {
        dt,
        blocked_on_capacity: blocked,
        waiting: &waiting,
        prefill: &[],
        pages: &[],
    });
}

/// The pool ran dry while `slot` needed a page for `fed`: preempt
/// latest-admitted sequences (requeueing them for recompute) until the
/// advance fits, we evict ourselves, or nothing is left to evict.
/// Victims can be decoding jobs (requeued as `Resume`) or mid-prefill
/// requests (requeued to restart their chunked prefill).
fn preempt_for_growth(slots: &mut PagedKvSlots, st: &mut WorkerState,
                      slot: usize, fed: i32,
                      ledger: Option<&WorkerLedger>) -> Result<Growth> {
    let this_req = slots.request_at(slot)?;
    // On a sharded pool, target the grower's arena first so the freed
    // pages land where the stalled advance wants them (monolithic
    // pools fall through to the global latest-first rule).
    let prefer = slots.growth_shard(this_req);
    loop {
        let Some((vslot, pre)) =
            slots.preempt_targeted(PreemptMode::Recompute, prefer)
        else {
            return Ok(Growth::Capped);
        };
        if let Some(wl) = ledger {
            wl.ledger.preempted(pre.request, wl.now());
        }
        if let Some(pf) = st.prefills.remove(&pre.request) {
            // Mid-prefill victim: restart its chunked prefill, FCFS
            // position restored at the queue front.
            let q = queue_entry_for(&pf.staged, pf.tokens.len());
            st.sched.requeue_front(q);
            st.staging.insert(pre.request, pf.staged);
        } else if let Some(job) = st.jobs[vslot].take() {
            // Readmission prefills prompt + all-but-pending tokens; the
            // queue entry carries that length for capacity accounting
            // (the `queue_entry_for` Resume arm sizes the decode rest).
            let prefix_len = job.prompt_len + job.tokens.len() - 1;
            let staged = Staged::Resume(job);
            st.sched.requeue_front(queue_entry_for(&staged, prefix_len));
            st.staging.insert(pre.request, staged);
        } else {
            // Inconsistent victim bookkeeping: structured drop, never a
            // worker panic.
            eprintln!(
                "[mmserve] {}",
                SlotStateError::MissingJob { slot: vslot,
                                             request: pre.request }
            );
            st.sched.drop_request(pre.request);
        }
        if pre.request == this_req {
            return Ok(Growth::SelfPreempted);
        }
        match slots.advance(slot, fed) {
            Ok(_) => return Ok(Growth::Advanced),
            Err(KvError::CapacityExhausted { .. }) => continue,
            Err(_) => return Ok(Growth::Capped),
        }
    }
}

/// A live slot whose decode bookkeeping went missing: release it and
/// surface the structured error through any staged response channel
/// (satellite fix — the worker thread must survive, not panic).
fn surface_slot_error(slots: &mut PagedKvSlots, st: &mut WorkerState,
                      slot: usize, request: u64) {
    let err = SlotStateError::MissingJob { slot, request };
    eprintln!("[mmserve] {err}; releasing the slot");
    let _ = slots.release(slot);
    st.sched.drop_request(request);
    if let Some(staged) = st.staging.remove(&request) {
        let _ = staged.into_item().respond.send(Err(err.into()));
    }
}

/// Completed prefill: sample the first token from the final logits
/// (fresh requests) or restore the parked decode job (preemption
/// resumes), making the slot a decoding slot.
fn finish_prefill(st: &mut WorkerState, tele: Option<&WorkerTracer>,
                  pf: PrefillState, logits: &[f32]) {
    match pf.staged {
        Staged::Fresh(item) => {
            let mut rng =
                Rng::new(item.request.sampling.seed ^ item.request.id);
            let first = {
                let _s = tele.map(|t| {
                    t.span_req(Cat::Sample, "sample_first", item.request.id)
                });
                sampling::sample(logits, &item.request.sampling, &mut rng)
            };
            let ttft = pf.started.elapsed().as_secs_f64();
            st.jobs[pf.slot] = Some(SlotJob {
                prompt_len: pf.tokens.len(),
                tokens: vec![first],
                rng,
                started: pf.started,
                ttft,
                item,
            });
        }
        Staged::Resume(job) => {
            // Recompute half of preemption: the prefix (prompt +
            // all-but-pending tokens) is back in the cache; continue
            // decoding from the job's saved state.
            st.jobs[pf.slot] = Some(job);
        }
    }
}

/// One resolved chunk-continuation feed (chunked prefill, start > 0).
struct ChunkRun {
    request: u64,
    slot: usize,
    start: usize,
    len: usize,
    is_last: bool,
}

/// Per-slot feeds for one batched dispatch: free slots write junk at
/// (0, 0) (their rows are rewritten on admission), decoding slots feed
/// their pending token at their position (exactly the write the decode
/// step performs), mid-prefill slots re-feed their last fed token (an
/// idempotent rewrite of the same cache position).
fn build_feeds(batch: usize, slots: &PagedKvSlots, st: &WorkerState)
               -> Vec<SlotFeed> {
    let mut feeds: Vec<SlotFeed> = (0..batch)
        .map(|slot| SlotFeed { slot, token: 0, pos: 0 })
        .collect();
    for (slot, req, pos) in slots.live_slots() {
        if let Some(job) = st.jobs[slot].as_ref() {
            feeds[slot] = SlotFeed {
                slot,
                token: *job.tokens.last().unwrap(),
                pos,
            };
        } else if let Some(pf) = st.prefills.get(&req) {
            if pos > 0 {
                feeds[slot] = SlotFeed {
                    slot,
                    token: pf.tokens[pos - 1],
                    pos: pos - 1,
                };
            }
        }
    }
    feeds
}

/// Execute one scheduler tick against an executor: first chunks
/// (slot + page claim, bucketed prefill, pack), continuation chunks
/// (incremental append through the decode graph + block tables), then
/// one batched decode step for all decoding slots. Written once,
/// generic over the [`StepExecutor`] — this is the loop the five
/// hand-rolled serving loops collapsed into.
fn run_tick<E: StepExecutor>(exec: &mut E, plan: TickPlan,
                             slots: &mut PagedKvSlots,
                             st: &mut WorkerState,
                             tele: Option<&WorkerTracer>,
                             sampler: Option<&WorkerSampler>,
                             ledger: Option<&WorkerLedger>)
                             -> Result<()> {
    let dims = exec.plan_dims();
    // Causal ledger: resolve the enabled gate once per tick (the
    // disabled cost is this one relaxed load) and remember the tick
    // start so waiting requests can be charged the tick's wall time.
    let ledger = ledger.filter(|wl| wl.ledger.is_enabled());
    let tick_started = ledger.map(|_| Instant::now());
    let blocked = plan.blocked_on_capacity;
    // Admission blocked on pages: count the tick and mark the host
    // window so idle-gap attribution buckets it as KvCapacity. The
    // span is held only when the tick planned *no prefill work at
    // all* — on a partially blocked tick the planned chunks' tokenize
    // / prefill / sample time must keep its own buckets.
    let kv_wait_span = if plan.blocked_on_capacity {
        slots.note_capacity_wait();
        if plan.chunks.is_empty() {
            tele.map(|t| t.span(Cat::KvWait, "kv_capacity_wait"))
        } else {
            None
        }
    } else {
        None
    };
    // Decode-ready slots stalled behind this tick's prefill work: the
    // interference window chunked prefill bounds (PrefillStall bucket).
    let stall_span = if !plan.chunks.is_empty()
        && st.jobs.iter().any(|j| j.is_some())
    {
        tele.map(|t| t.span(Cat::PrefillStall, "prefill_stall"))
    } else {
        None
    };

    let mut admitted: HashMap<u64, QueuedRequest> =
        plan.admitted.into_iter().map(|q| (q.id, q)).collect();
    // Requeues collected per phase; continuations are FCFS-older than
    // this tick's admissions, so they requeue ahead.
    let mut requeue_cont: Vec<QueuedRequest> = Vec::new();
    let mut requeue_new: Vec<QueuedRequest> = Vec::new();
    let mut continuations: Vec<PlannedChunk> = Vec::new();

    // ---- first chunks: slot + page claim, bucketed prefill, pack ----
    for c in plan.chunks {
        if c.start > 0 {
            continuations.push(c);
            continue;
        }
        let Some(staged) = st.staging.remove(&c.request) else {
            st.sched.drop_request(c.request);
            admitted.remove(&c.request);
            continue;
        };
        let _req_scope = tele.map(|t| t.req_scope(c.request));
        let started = Instant::now();
        let _prefill_span = tele.map(|t| {
            t.span(Cat::Prefill, match &staged {
                Staged::Fresh(_) => "admit",
                Staged::Resume(_) => "resume",
            })
        });
        // Tokenize the full prefill prefix (prompt, plus generated
        // tokens for a preemption resume).
        let tokens = {
            let _t = tele.map(|t| t.span(Cat::Tokenize, "tokenize"));
            match &staged {
                Staged::Fresh(item) => {
                    tokenize_decoder_input(&item.request)?
                }
                Staged::Resume(job) => {
                    let mut prefix =
                        tokenize_decoder_input(&job.item.request)?;
                    prefix.extend_from_slice(
                        &job.tokens[..job.tokens.len() - 1],
                    );
                    prefix
                }
            }
        };
        let q = admitted
            .remove(&c.request)
            .unwrap_or_else(|| queue_entry_for(&staged, tokens.len()));
        let len = c.len.min(tokens.len());
        let is_last = len >= tokens.len();
        // Claim the slot and the chunk's pages before any device work.
        let slot = {
            let _s = tele.map(|t| t.span(Cat::Schedule, "admit_slot"));
            match slots.alloc(q.id, &tokens[..len]) {
                Ok((slot, _share)) => slot,
                Err(KvError::CapacityExhausted { .. }) => {
                    // Decode growth raced the admission view; retry
                    // next tick, FCFS position intact.
                    st.staging.insert(q.id, staged);
                    requeue_new.push(q);
                    continue;
                }
                Err(e) => {
                    // Structural refusal (prompt ≥ max_seq, …): fail
                    // the request, keep the worker alive.
                    st.sched.drop_request(q.id);
                    let _ = staged.into_item().respond.send(Err(e.into()));
                    continue;
                }
            }
        };
        if let Some(wl) = ledger {
            wl.ledger.admitted(q.id, len, wl.now());
        }
        match exec.prefill_chunk(slot, &tokens[..len], 0, is_last)? {
            Some(logits) => {
                st.sched.chunk_committed(q.id, len);
                finish_prefill(
                    st,
                    tele,
                    PrefillState { slot, tokens, staged, started },
                    &logits,
                );
                if let Some(wl) = ledger {
                    wl.ledger.first_token(q.id, wl.now());
                }
            }
            None => {
                st.sched.chunk_committed(q.id, len);
                st.prefills.insert(
                    q.id,
                    PrefillState { slot, tokens, staged, started },
                );
            }
        }
    }

    // ---- continuation chunks: append through the decode graph -------
    // Each dispatch feeds one chunk token per mid-prefill slot at its
    // position; decoding slots re-feed their pending token (an
    // idempotent pre-write of the position the real decode step will
    // write) and other mid-prefill slots re-feed their last token.
    let mut runs: Vec<ChunkRun> = Vec::new();
    for c in &continuations {
        let Some(pf) = st.prefills.get(&c.request) else {
            eprintln!(
                "[mmserve] {}",
                SlotStateError::MissingPrefill { request: c.request }
            );
            st.sched.drop_request(c.request);
            continue;
        };
        let start = slots.pos(pf.slot).unwrap_or(c.start);
        let len = c.len.min(pf.tokens.len().saturating_sub(start));
        if len == 0 {
            continue;
        }
        runs.push(ChunkRun {
            request: c.request,
            slot: pf.slot,
            start,
            len,
            is_last: start + len >= pf.tokens.len(),
        });
    }
    let n_dispatches = runs.iter().map(|r| r.len).max().unwrap_or(0);
    let mut final_logits: Vec<(usize, Vec<f32>)> = Vec::new();
    for j in 0..n_dispatches {
        let mut feeds = build_feeds(dims.batch, slots, st);
        for r in &runs {
            let pf = &st.prefills[&r.request];
            let i = j.min(r.len - 1);
            feeds[r.slot] = SlotFeed {
                slot: r.slot,
                token: pf.tokens[r.start + i],
                pos: r.start + i,
            };
        }
        let logits = exec.decode_step(&feeds)?;
        for (ri, r) in runs.iter().enumerate() {
            if r.is_last && j + 1 == r.len {
                let row = logits
                    [r.slot * dims.vocab..(r.slot + 1) * dims.vocab]
                    .to_vec();
                final_logits.push((ri, row));
            }
        }
    }
    // Commit the fed chunks into the block tables (page claims happen
    // here, chunk by chunk) and finish completed prefills.
    for (ri, r) in runs.iter().enumerate() {
        let Some(chunk) = st.prefills.get(&r.request).map(|pf| {
            pf.tokens[r.start..r.start + r.len].to_vec()
        }) else {
            continue;
        };
        match slots.extend_chunk(r.slot, &chunk) {
            Ok(_) => {
                st.sched.chunk_committed(r.request, r.len);
                if let Some(wl) = ledger {
                    wl.ledger.prefill_chunk(r.request, r.len, wl.now());
                }
                if r.is_last {
                    let row = final_logits
                        .iter()
                        .find(|(i, _)| *i == ri)
                        .map(|(_, l)| l.clone());
                    let pf = st.prefills.remove(&r.request);
                    match (pf, row) {
                        (Some(pf), Some(row)) => {
                            let _scope =
                                tele.map(|t| t.req_scope(r.request));
                            finish_prefill(st, tele, pf, &row);
                            if let Some(wl) = ledger {
                                wl.ledger.first_token(r.request, wl.now());
                            }
                        }
                        (Some(pf), None) => {
                            // No final logits captured: structural
                            // failure, surfaced through Response.
                            let _ = slots.release(r.slot);
                            st.sched.drop_request(r.request);
                            let err = SlotStateError::MissingPrefill {
                                request: r.request,
                            };
                            let _ = pf
                                .staged
                                .into_item()
                                .respond
                                .send(Err(err.into()));
                        }
                        (None, _) => {}
                    }
                }
            }
            Err(KvError::CapacityExhausted { .. }) => {
                // The chunk's pages raced decode growth: restart this
                // prefill from the queue front (recompute).
                if let Some(pf) = st.prefills.remove(&r.request) {
                    let _ = slots.release(r.slot);
                    let q = queue_entry_for(&pf.staged, pf.tokens.len());
                    st.staging.insert(r.request, pf.staged);
                    requeue_cont.push(q);
                }
            }
            Err(e) => {
                if let Some(pf) = st.prefills.remove(&r.request) {
                    let _ = slots.release(r.slot);
                    st.sched.drop_request(r.request);
                    let _ =
                        pf.staged.into_item().respond.send(Err(e.into()));
                }
            }
        }
    }

    // FCFS-preserving group requeue (per-item push_front would reverse
    // the group — the satellite regression fix).
    requeue_cont.extend(requeue_new);
    st.sched.requeue_all(requeue_cont);
    drop(stall_span);
    drop(kv_wait_span);

    // ---- one batched decode step for all decoding slots -------------
    if st.jobs.iter().all(|j| j.is_none()) {
        charge_ledger_tick(ledger, tick_started, blocked, st);
        return Ok(());
    }
    let step_span = tele.map(|t| t.span(Cat::Decode, "decode_step"));
    let step_started = Instant::now();
    let feeds = build_feeds(dims.batch, slots, st);
    let logits = exec.decode_step(&feeds)?;
    // Ledger TBT: the batched step's wall time is every decoding
    // slot's time-between-tokens; its compute share splits it evenly
    // (matching the live plane's streaming approximation above the
    // exact post-hoc Sample-span histogram).
    let step_dt = ledger.map(|_| step_started.elapsed().as_secs_f64());
    let decoding_n =
        st.jobs.iter().filter(|j| j.is_some()).count().max(1);

    for (slot, req, _) in slots.live_slots() {
        // A preemption earlier in this pass may have freed the slot.
        if slots.slot_of(req) != Some(slot) {
            continue;
        }
        // Mid-prefill slots don't decode yet.
        if st.prefills.contains_key(&req) {
            continue;
        }
        if st.jobs[slot].is_none() {
            // A live, decoding slot must hold a job: structured error
            // surfaced through the response channel, not a panic.
            surface_slot_error(slots, st, slot, req);
            continue;
        }
        let sampled_done = {
            let Some(job) = st.jobs[slot].as_mut() else { continue };
            // Per-slot Sample span carries the request id so the
            // time-between-tokens histogram works in batched mode.
            let _s = tele.map(|t| {
                t.span_req(Cat::Sample, "sample", job.item.request.id)
            });
            let row = &logits[slot * dims.vocab..(slot + 1) * dims.vocab];
            let tok = sampling::sample(row, &job.item.request.sampling,
                                       &mut job.rng);
            job.tokens.push(tok);
            tok == tokenizer::EOS
                || job.tokens.len() >= job.item.request.max_new_tokens
        };
        if let (Some(wl), Some(dt)) = (ledger, step_dt) {
            wl.ledger.decoded(req, wl.now(), dt, dt / decoding_n as f64);
        }
        let mut done = sampled_done;
        if !done {
            // The cache now holds the token we just fed; record it in
            // the block table (this is where pages grow).
            let fed = feeds[slot].token;
            match slots.advance(slot, fed) {
                Ok(_) => {}
                Err(KvError::CapacityExhausted { .. }) => {
                    match preempt_for_growth(slots, st, slot, fed,
                                             ledger)? {
                        Growth::Advanced => {}
                        Growth::SelfPreempted => continue,
                        Growth::Capped => done = true,
                    }
                }
                // Sequence cap (max_seq): finish the request.
                Err(_) => done = true,
            }
        }
        if done {
            let Some(job) = st.jobs[slot].take() else {
                surface_slot_error(slots, st, slot, req);
                continue;
            };
            slots.release(slot)?;
            st.sched.finished(req);
            if let Some(wl) = ledger {
                wl.ledger.completed(req, wl.now());
            }
            if let Some(s) = sampler {
                s.observe_ttft_ms("-", job.ttft * 1e3);
                s.note_completion(job.tokens.len() as u64);
            }
            let resp = finish_decoder_response(&job);
            let _ = job.item.respond.send(Ok(resp));
        }
    }
    // Live TBT: every slot still decoding advanced one token in this
    // step's wall time (the post-hoc Sample-span histogram stays the
    // exact source; this is the streaming approximation).
    if let Some(s) = sampler {
        if s.live().is_enabled() {
            let dt_ms = step_started.elapsed().as_secs_f64() * 1e3;
            let decoding =
                st.jobs.iter().filter(|j| j.is_some()).count();
            for _ in 0..decoding {
                s.observe_tbt_ms("-", dt_ms);
            }
        }
    }
    drop(step_span);
    charge_ledger_tick(ledger, tick_started, blocked, st);
    Ok(())
}

fn decoder_worker(engine: &Engine, cfg: RouterConfig,
                  rx: Receiver<WorkItem>, cell: &ReplicaCell,
                  replica: usize)
                  -> Result<()> {
    let session = DecoderSession::new(engine, cfg.opt)?;
    let dims = session.dims;
    let batch = if cfg.opt.exec == ExecMode::Eager || cfg.opt.layerskip {
        1 // eager / layerskip paths are bs=1 regimes (paper Fig 8)
    } else {
        cfg.batch
    };
    let use_batched = batch > 1
        && engine.has_stage(&format!("kv_pack_b{batch}"))
        && DecoderSession::decode_stage_name(engine, batch, &cfg.opt).is_ok();

    if !use_batched {
        // Sequential (bs=1) serving loop: every request runs through
        // the sched drivers via `DecoderSession::generate`.
        while let Ok(item) = rx.recv() {
            cell.note_dequeued();
            cell.set_backlog(1);
            let resp = serve_one_decoder(&session, &item.request);
            let _ = item.respond.send(resp);
            cell.set_backlog(0);
        }
        return Ok(());
    }

    // ---- continuous batching loop ------------------------------------
    // The compiled graph keeps its dense per-slot cache; the paged pool
    // meters capacity (prefix sharing, growth, preemption) under it.
    let mut exec = BatchedExecutor::new(engine, &session, batch, &cfg.opt)?;
    let mut slots = PagedKvSlots::paged(batch, dims.max_seq, cfg.kv);
    let mut st = WorkerState {
        jobs: (0..batch).map(|_| None).collect(),
        prefills: HashMap::new(),
        staging: HashMap::new(),
        sched: Scheduler::new(SchedConfig {
            prefill_budget: cfg.prefill_budget,
            chunk: cfg.chunk_prefill,
        }),
    };
    let mut closed = false;
    // Consecutive empty ticks with queued work: a request larger than
    // the whole page budget can never be admitted; shed it instead of
    // spinning forever.
    let mut stalled = 0usize;
    // Last published pool-churn fingerprint: steady-state decode-only
    // ticks skip rebuilding an identical snapshot.
    let mut published_stamp: Option<u64> = None;
    let tele = engine.tracer();
    // Live observability plane: per-tick fleet samples, tenant-less
    // TTFT/TBT sketches, and the online idle-gap fold over this
    // worker's spans. Absent (the default) every hook is skipped; a
    // disabled registry costs one relaxed load per hook.
    let mut sampler = cfg.live.as_ref().map(|live| {
        WorkerSampler::new(
            live.clone(),
            cfg.flight
                .clone()
                .unwrap_or_else(FlightRecorder::disabled),
            replica,
        )
    });
    if let Some(s) = &sampler {
        st.sched.attach_live(s.live(), replica);
    }
    // Per-request causal ledger (`mmserve explain`): event stamps are
    // wall seconds since this worker started. Absent (the default),
    // or disabled, every hook costs one relaxed load per tick.
    let wledger = cfg.ledger.as_ref().map(|l| WorkerLedger {
        ledger: l.clone(),
        epoch: Instant::now(),
        replica: replica as u32,
    });
    let mut online = OnlineAttribution::new();
    let mut span_cursor = 0usize;
    let mut tick_no = 0u64;

    loop {
        // Drain the queue without blocking while work is live.
        loop {
            match rx.try_recv() {
                Ok(item) => {
                    cell.note_dequeued();
                    intake_decoder_item(item, &session, &mut st, tele,
                                        wledger.as_ref())?
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    closed = true;
                    break;
                }
            }
        }
        // Publish this replica's routing view: backlog for the depth
        // tie-break, the pool's resident hashes for the prefix probe
        // (rebuilt only when the pool actually churned — the hash-set
        // clone is pointless on decode-only ticks).
        cell.set_backlog(st.sched.pending() + st.sched.in_flight());
        let stamp = slots.churn_stamp();
        if stamp.is_some() && stamp != published_stamp {
            slots.publish_routing_snapshot(cell);
            published_stamp = stamp;
        }
        if closed && slots.live_count() == 0 && st.sched.pending() == 0 {
            return Ok(());
        }
        if slots.live_count() == 0 && st.sched.pending() == 0 {
            // Idle: block for the next request.
            match rx.recv() {
                Ok(item) => {
                    cell.note_dequeued();
                    intake_decoder_item(item, &session, &mut st, tele,
                                        wledger.as_ref())?
                }
                Err(_) => return Ok(()),
            }
            continue;
        }

        // One scheduler tick: plan against the capacity view (free
        // slots + free pages − growth watermark), then execute it.
        if let Some(t) = tele {
            t.next_tick();
        }
        let plan = {
            let _s = tele.map(|t| t.span(Cat::Plan, "plan"));
            st.sched.plan(&slots.capacity_view())
        };
        // No chunk planned and no decode job to free pages: queued or
        // mid-prefill work larger than the pool can ever grant would
        // spin forever — shed it instead (keeping the worker alive).
        let no_progress = plan.chunks.is_empty()
            && st.jobs.iter().all(|j| j.is_none())
            && (st.sched.pending() > 0 || !st.prefills.is_empty());
        if no_progress {
            stalled += 1;
            if stalled > 2 {
                if let Some(req) = st.sched.head_prefilling() {
                    // A wedged chunked prefill holds its slot and
                    // pages; fail it through its response channel.
                    st.sched.drop_request(req);
                    if let Some(pf) = st.prefills.remove(&req) {
                        let _ = slots.release(pf.slot);
                        let _ = pf.staged.into_item().respond.send(Err(
                            anyhow!(
                                "request {req} exceeds the KV page budget \
                                 (chunked prefill cannot be granted pages)"
                            ),
                        ));
                    }
                } else if let Some(q) = st.sched.shed_front() {
                    st.sched.drop_request(q.id);
                    if let Some(staged) = st.staging.remove(&q.id) {
                        let _ = staged.into_item().respond.send(Err(anyhow!(
                            "request {} exceeds the KV page budget",
                            q.id
                        )));
                    }
                }
                stalled = 0;
            }
        } else {
            stalled = 0;
        }
        run_tick(&mut exec, plan, &mut slots, &mut st, tele,
                 sampler.as_ref(), wledger.as_ref())?;
        // End-of-tick publication: fleet sample, then fold the spans
        // this tick produced into the online idle-gap attribution
        // (span batches between ticks are quiescent, so the fold
        // matches the post-hoc `Attribution` exactly).
        if let Some(s) = sampler.as_mut() {
            tick_no += 1;
            let depth = st.sched.pending() + st.sched.in_flight();
            let stats = slots.stats().cloned().unwrap_or_default();
            let shards = slots
                .pool()
                .map(|p| p.shard_views())
                .unwrap_or_default();
            s.sample_tick(tick_no, depth, &stats, &shards);
            if let Some(t) = tele {
                if s.live().is_enabled() {
                    let (cur, spans) = t.spans_since(span_cursor);
                    span_cursor = cur;
                    online.observe(&spans);
                    online.publish(s.live(), s.replica());
                }
            }
        }
    }
}

/// Take one arriving request into the batched decoder: serve
/// non-batchable tasks inline, otherwise tokenize (traced) and queue.
fn intake_decoder_item(item: WorkItem, session: &DecoderSession,
                       st: &mut WorkerState,
                       tele: Option<&WorkerTracer>,
                       ledger: Option<&WorkerLedger>) -> Result<()> {
    // Non-batchable tasks (T-I contrastive) run inline.
    if item.request.task == TaskKind::TextToImage {
        let resp = serve_one_decoder(session, &item.request);
        let _ = item.respond.send(resp);
        return Ok(());
    }
    let prompt = {
        let _t = tele.map(|t| t.span_req(Cat::Tokenize, "tokenize",
                                         item.request.id));
        tokenize_decoder_input(&item.request)?
    };
    st.sched.enqueue(QueuedRequest {
        id: item.request.id,
        prompt_len: prompt.len(),
        max_new_tokens: item.request.max_new_tokens,
    });
    // "-" matches the live plane's tenant-less real-path label.
    if let Some(wl) = ledger {
        if wl.ledger.is_enabled() {
            wl.ledger.enqueued(item.request.id, wl.replica, "-",
                               prompt.len(), wl.now());
        }
    }
    st.staging.insert(item.request.id, Staged::Fresh(item));
    Ok(())
}

fn tokenize_decoder_input(req: &Request) -> Result<Vec<i32>> {
    Ok(match &req.input {
        RequestInput::Text(t) => encode_prompt(t),
        RequestInput::Tokens(ts) => ts.clone(),
        RequestInput::Image { pixels, h, w } => {
            let mut ids = vec![tokenizer::BOS];
            ids.extend(ImageTokenizer::encode(pixels, *h, *w));
            // "Describe the figure" prompt suffix (paper §3.1, I-T).
            ids.extend(TextTokenizer::new().encode("Describe"));
            ids
        }
        RequestInput::ImageText { pixels, h, w, text } => {
            let mut ids = vec![tokenizer::BOS];
            ids.extend(ImageTokenizer::encode(pixels, *h, *w));
            ids.extend(TextTokenizer::new().encode(text));
            ids
        }
        other => bail!("unsupported decoder input {other:?}"),
    })
}

fn serve_one_decoder(session: &DecoderSession, req: &Request)
                     -> Result<Response> {
    let started = Instant::now();
    let tele = session.engine.tracer();
    let _req_scope = tele.map(|t| t.req_scope(req.id));
    let prompt = {
        let _t = tele.map(|t| t.span(Cat::Tokenize, "tokenize"));
        tokenize_decoder_input(req)?
    };
    if req.task == TaskKind::TextToImage {
        let gen = session.generate_image(&prompt, tokenizer::IMG_TOKENS,
                                         &req.sampling)?;
        return Ok(Response {
            id: req.id,
            task: req.task,
            output: ResponseOutput::Image(ImageTokenizer::decode(&gen.tokens)),
            tokens: gen.tokens.clone(),
            prompt_tokens: gen.prompt_tokens,
            decode_steps: gen.decode_steps,
            ttft: gen.ttft,
            e2e: started.elapsed().as_secs_f64(),
        });
    }
    let gen = session.generate(&prompt, req.max_new_tokens, &req.sampling)?;
    let text = TextTokenizer::new().decode(&gen.tokens);
    Ok(Response {
        id: req.id,
        task: req.task,
        output: ResponseOutput::Text(text),
        tokens: gen.tokens.clone(),
        prompt_tokens: gen.prompt_tokens,
        decode_steps: gen.decode_steps,
        ttft: gen.ttft,
        e2e: started.elapsed().as_secs_f64(),
    })
}

fn finish_decoder_response(job: &SlotJob) -> Response {
    let text = TextTokenizer::new().decode(&job.tokens);
    Response {
        id: job.item.request.id,
        task: job.item.request.task,
        output: ResponseOutput::Text(text),
        tokens: job.tokens.clone(),
        prompt_tokens: job.prompt_len,
        decode_steps: job.tokens.len(),
        ttft: job.ttft,
        e2e: job.started.elapsed().as_secs_f64(),
    }
}

// ---- Seamless ---------------------------------------------------------------

fn seamless_worker(engine: &Engine, cfg: RouterConfig,
                   rx: Receiver<WorkItem>, cell: &ReplicaCell)
                   -> Result<()> {
    let pipe = SeamlessPipeline::new(engine, cfg.reorder)?;
    while let Ok(item) = rx.recv() {
        cell.note_dequeued();
        cell.set_backlog(1);
        let resp = serve_one_seamless(&pipe, &item.request);
        let _ = item.respond.send(resp);
        cell.set_backlog(0);
    }
    Ok(())
}

fn serve_one_seamless(pipe: &SeamlessPipeline, req: &Request)
                      -> Result<Response> {
    let started = Instant::now();
    let task = match req.task {
        TaskKind::SpeechToText => SeamlessTask::SpeechToText,
        TaskKind::SpeechToSpeech => SeamlessTask::SpeechToSpeech,
        TaskKind::TextToTextTrans => SeamlessTask::TextToText,
        TaskKind::TextToSpeech => SeamlessTask::TextToSpeech,
        t => bail!("not a seamless task: {t}"),
    };
    let (speech, text): (Option<&[f32]>, Option<&str>) = match &req.input {
        RequestInput::Speech(w) => (Some(w.as_slice()), None),
        RequestInput::Text(t) => (None, Some(t.as_str())),
        other => bail!("unsupported seamless input {other:?}"),
    };
    let _req_scope = pipe.engine.tracer().map(|t| t.req_scope(req.id));
    let out = pipe.run(task, speech, text, req.max_new_tokens)?;
    let output = if task.speech_out() {
        ResponseOutput::Speech(out.waveform.clone())
    } else {
        ResponseOutput::Text(out.text.clone())
    };
    Ok(Response {
        id: req.id,
        task: req.task,
        output,
        tokens: out.text_tokens.clone(),
        prompt_tokens: 0,
        decode_steps: out.decode_steps,
        ttft: out.e2e, // beam search emits only on completion
        e2e: started.elapsed().as_secs_f64(),
    })
}

// ---- HSTU --------------------------------------------------------------------

fn hstu_worker(engine: &Engine, rx: Receiver<WorkItem>,
               cell: &ReplicaCell) -> Result<()> {
    let runner = HstuRunner::new(engine, HstuAttn::Fused)?;
    while let Ok(item) = rx.recv() {
        cell.note_dequeued();
        cell.set_backlog(1);
        let resp = serve_one_hstu(&runner, &item.request);
        let _ = item.respond.send(resp);
        cell.set_backlog(0);
    }
    Ok(())
}

fn serve_one_hstu(runner: &HstuRunner, req: &Request) -> Result<Response> {
    let started = Instant::now();
    let RequestInput::History(h) = &req.input else {
        bail!("hstu expects History input");
    };
    let tele = runner.engine.tracer();
    let _req_scope = tele.map(|t| t.req_scope(req.id));
    // The one-shot scoring pass scheduled as a prefill-only plan
    // (Obs #1): `generate` with `max_new == 0` runs the whole request
    // as its prompt and takes zero decode ticks.
    let mut exec = HstuExecutor::new(runner, 8, 10);
    let gen = generate(&mut exec, tele, h, 0,
                       &crate::coordinator::request::SamplingParams::greedy())?;
    debug_assert_eq!(gen.decode_steps, 0);
    let r = exec.last.take().context("hstu result")?;
    Ok(Response {
        id: req.id,
        task: req.task,
        output: ResponseOutput::Actions {
            engagement: r.engagement,
            top_items: r.top_items,
        },
        tokens: vec![],
        prompt_tokens: h.len(),
        decode_steps: gen.decode_steps, // non-autoregressive (Obs #1)
        ttft: gen.ttft,
        e2e: started.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvpool::prefix::block_hashes;

    fn handle() -> (ReplicaHandle, Receiver<WorkItem>) {
        let (tx, rx) = channel::<WorkItem>();
        (ReplicaHandle { tx, cell: Arc::new(ReplicaCell::new()) }, rx)
    }

    fn token_request(id: u64, tokens: Vec<i32>) -> Request {
        Request {
            id,
            task: TaskKind::TextToText,
            input: RequestInput::Tokens(tokens),
            max_new_tokens: 4,
            sampling: crate::coordinator::request::SamplingParams::greedy(),
        }
    }

    fn router_with(set: ModelReplicas, policy: RoutingPolicy) -> Router {
        let mut models = HashMap::new();
        models.insert(ModelKind::Llama, set);
        Router {
            models,
            handles: Vec::new(),
            next_id: AtomicU64::new(1),
            policy,
            route_tracer: None,
            live: None,
        }
    }

    /// The probe must tokenize exactly like the worker, or prefix
    /// probes could never match worker-resident blocks.
    #[test]
    fn probe_tokens_match_worker_tokenization() {
        let text = "a shared system prompt for routing";
        assert_eq!(
            probe_tokens_for(&RequestInput::Text(text.into())).unwrap(),
            encode_prompt(text)
        );
        let toks = vec![5, 6, 7];
        assert_eq!(
            probe_tokens_for(&RequestInput::Tokens(toks.clone())),
            Some(toks)
        );
        assert!(
            probe_tokens_for(&RequestInput::Speech(vec![0.0; 4])).is_none()
        );
    }

    #[test]
    fn route_order_prefers_warm_replica_for_token_prompts() {
        let (h0, _rx0) = handle();
        let (h1, _rx1) = handle();
        let prompt: Vec<i32> = (0..32).collect();
        // Replica 1 publishes the prompt's two full blocks as resident.
        h1.cell.publish(
            16,
            block_hashes(&prompt, 16).into_iter().collect(),
            4, 2, 32,
        );
        let set = ModelReplicas {
            replicas: vec![h0, h1],
            rr: AtomicU64::new(0),
            arrival: vec![0, 1],
        };
        let req = token_request(1, prompt);
        let order = route_order(RoutingPolicy::PrefixAffinity, &set, &req);
        assert_eq!(order, vec![1, 0], "warm cache wins");
        // Non-probeable input: falls back to depth (tie → index 0).
        let img = Request {
            id: 2,
            task: TaskKind::TextToText,
            input: RequestInput::Image {
                pixels: vec![0.0; 16],
                h: 4,
                w: 4,
            },
            max_new_tokens: 1,
            sampling: crate::coordinator::request::SamplingParams::greedy(),
        };
        let order = route_order(RoutingPolicy::PrefixAffinity, &set, &img);
        assert_eq!(order, vec![0, 1]);
    }

    /// Satellite: a replica whose channel is closed must degrade to
    /// the next choice — the request still routes, it is never lost.
    #[test]
    fn submit_fails_over_dead_replica_and_errors_only_when_all_gone() {
        let (h0, rx0) = handle();
        let (h1, rx1) = handle();
        let cell1 = h1.cell.clone();
        let set = ModelReplicas {
            replicas: vec![h0, h1],
            rr: AtomicU64::new(0),
            arrival: vec![0, 1],
        };
        let router = router_with(set, RoutingPolicy::PrefixAffinity);
        // Cold caches + equal depth rank replica 0 first; kill it.
        drop(rx0);
        let _rrx = router
            .submit(token_request(7, (0..8).collect()))
            .expect("must fail over to the live replica");
        let got = rx1.try_recv().expect("item landed on replica 1");
        assert_eq!(got.request.id, 7);
        assert_eq!(cell1.routed(), 1);
        // All replicas gone: loud error, not a hang or a silent drop.
        drop(rx1);
        let err = router
            .submit(token_request(8, (0..8).collect()))
            .map(|_| ())
            .unwrap_err();
        assert!(err.to_string().contains("all workers"), "{err}");
        // A model that was never started still reports cleanly.
        let err = router
            .submit(Request {
                id: 9,
                task: TaskKind::SpeechToText,
                input: RequestInput::Speech(vec![0.0; 8]),
                max_new_tokens: 1,
                sampling:
                    crate::coordinator::request::SamplingParams::greedy(),
            })
            .map(|_| ())
            .unwrap_err();
        assert!(err.to_string().contains("not serving"), "{err}");
    }

    #[test]
    fn round_robin_rotates_across_submits() {
        let (h0, rx0) = handle();
        let (h1, rx1) = handle();
        let set = ModelReplicas {
            replicas: vec![h0, h1],
            rr: AtomicU64::new(0),
            arrival: vec![0, 1],
        };
        let router = router_with(set, RoutingPolicy::RoundRobin);
        for id in 0..4u64 {
            router.submit(token_request(id, vec![1, 2, 3])).unwrap();
        }
        let on0: Vec<u64> =
            rx0.try_iter().map(|w| w.request.id).collect();
        let on1: Vec<u64> =
            rx1.try_iter().map(|w| w.request.id).collect();
        assert_eq!(on0, vec![0, 2]);
        assert_eq!(on1, vec![1, 3]);
    }

    #[test]
    fn replica_reports_render_fleet_rate_from_summed_counters() {
        let reports = vec![
            ReplicaReport {
                model: ModelKind::Llama,
                replica: 0,
                role: "prefill",
                routed: 10,
                prefix_lookups: 100,
                prefix_hits: 90,
                prefix_hit_tokens: 1440,
                shard_live_pages: vec![5, 3],
            },
            ReplicaReport {
                model: ModelKind::Llama,
                replica: 1,
                role: "decode",
                routed: 2,
                prefix_lookups: 10,
                prefix_hits: 0,
                prefix_hit_tokens: 0,
                shard_live_pages: Vec::new(),
            },
        ];
        assert!((reports[0].hit_rate() - 0.9).abs() < 1e-12);
        let s = render_replica_reports(&reports);
        assert!(s.contains("Llama[0]"));
        assert!(s.contains("Llama[1]"));
        // 90/110 = 81.8%, not the 45.0% a mean-of-rates would print.
        assert!(s.contains("81.8%"), "{s}");
        assert!(s.contains("fleet (summed)"));
        // Per-shard occupancy gauge: published workers show the split,
        // unpublished ones a dash.
        assert!(s.contains("5/3"), "{s}");
        assert!(s.contains("shard pages"), "{s}");
        // The fleet split is visible per worker.
        assert!(s.contains("role"), "{s}");
        assert!(s.contains("prefill"), "{s}");
        assert!(s.contains("decode"), "{s}");
    }

    /// Disaggregated topology: arrivals only ever land on the prefill
    /// tier, and a fully dead prefill tier is a loud error even while
    /// the decode tier lives — fail-over must not violate roles.
    #[test]
    fn disaggregate_routes_arrivals_to_prefill_tier_only() {
        let (h0, rx0) = handle();
        let (h1, rx1) = handle();
        let set = ModelReplicas {
            replicas: vec![h0, h1],
            rr: AtomicU64::new(0),
            arrival: vec![0],
        };
        let router = router_with(set, RoutingPolicy::RoundRobin);
        for id in 0..4u64 {
            router.submit(token_request(id, vec![1, 2, 3])).unwrap();
        }
        assert_eq!(rx0.try_iter().count(), 4, "all arrivals on prefill");
        assert_eq!(rx1.try_iter().count(), 0, "decode tier takes none");
        drop(rx0);
        let err = router
            .submit(token_request(9, vec![1, 2, 3]))
            .map(|_| ())
            .unwrap_err();
        assert!(err.to_string().contains("all workers"), "{err}");
    }
}

/// Aggregate responses into serving statistics.
pub fn collect_stats(responses: &[Response], wall_secs: f64) -> ServeStats {
    let mut s = ServeStats { wall_secs, ..Default::default() };
    for r in responses {
        s.requests_completed += 1;
        s.tokens_generated += r.decode_steps as u64;
        s.prefill_tokens += r.prompt_tokens as u64;
        s.ttft.record(r.ttft * 1e3);
        s.e2e.record(r.e2e * 1e3);
        if r.decode_steps > 1 {
            s.tpot
                .record(r.e2e * 1e3 / r.decode_steps as f64);
        }
    }
    s
}
