//! Next-token samplers: greedy, temperature, top-k, top-p (the paper's
//! "decoding strategy" taxonomy in Obs #4 — Llama/Chameleon use top-p;
//! Seamless uses beam search, implemented in `seamless_pipe`).

use crate::substrate::rng::Rng;

use super::request::SamplingParams;

/// argmax over logits.
pub fn greedy(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as i32
}

/// Numerically-stable softmax (in place on a copy).
pub fn softmax(logits: &[f32]) -> Vec<f32> {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&x| (x - m).exp()).collect();
    let z: f32 = exps.iter().sum();
    exps.into_iter().map(|e| e / z.max(1e-30)).collect()
}

/// Sample a token according to the params.
pub fn sample(logits: &[f32], p: &SamplingParams, rng: &mut Rng) -> i32 {
    if p.greedy || p.temperature <= 0.0 {
        return greedy(logits);
    }
    let scaled: Vec<f32> =
        logits.iter().map(|&x| x / p.temperature).collect();
    let mut probs = softmax(&scaled);

    // top-k: zero everything beyond the k-th largest
    if p.top_k > 0 && p.top_k < probs.len() {
        let mut idx: Vec<usize> = (0..probs.len()).collect();
        idx.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
        for &i in &idx[p.top_k..] {
            probs[i] = 0.0;
        }
    }
    // top-p (nucleus): keep the smallest prefix of the sorted probs whose
    // mass reaches top_p
    if p.top_p > 0.0 && p.top_p < 1.0 {
        let mut idx: Vec<usize> = (0..probs.len()).collect();
        idx.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
        let mut mass = 0.0f32;
        let mut cut = idx.len();
        for (rank, &i) in idx.iter().enumerate() {
            mass += probs[i];
            if mass >= p.top_p {
                cut = rank + 1;
                break;
            }
        }
        for &i in &idx[cut..] {
            probs[i] = 0.0;
        }
    }
    let z: f32 = probs.iter().sum();
    if z <= 0.0 {
        return greedy(logits);
    }
    let mut r = rng.f64() as f32 * z;
    for (i, &q) in probs.iter().enumerate() {
        r -= q;
        if r <= 0.0 {
            return i as i32;
        }
    }
    (probs.len() - 1) as i32
}

/// Contrastive (classifier-free-guidance style) logit mix for Chameleon
/// T-I (§2.1.2): conditioned logits are the "strong" model, unconditional
/// the "weak"; alpha > 1 sharpens toward the conditional distribution.
pub fn contrastive_mix(cond: &[f32], uncond: &[f32], alpha: f32) -> Vec<f32> {
    cond.iter()
        .zip(uncond)
        .map(|(&c, &u)| u + alpha * (c - u))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prop::prop_check;

    fn params(temp: f32, top_p: f32, top_k: usize) -> SamplingParams {
        SamplingParams {
            temperature: temp,
            top_p,
            top_k,
            seed: 0,
            greedy: false,
        }
    }

    #[test]
    fn greedy_picks_max() {
        assert_eq!(greedy(&[0.1, 3.0, 2.0]), 1);
    }

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn zero_temperature_is_greedy() {
        let mut rng = Rng::new(0);
        let l = [0.0, 5.0, 1.0];
        assert_eq!(sample(&l, &params(0.0, 0.9, 0), &mut rng), 1);
    }

    #[test]
    fn top_k_restricts_support() {
        // top_k = 1 must always return the argmax
        let mut rng = Rng::new(1);
        let l = [1.0, 4.0, 2.0, 0.5];
        for _ in 0..50 {
            assert_eq!(sample(&l, &params(1.0, 1.0, 1), &mut rng), 1);
        }
    }

    #[test]
    fn top_p_nucleus_property() {
        // With a sharply peaked distribution, tiny top_p keeps only the
        // argmax.
        let mut rng = Rng::new(2);
        let l = [0.0, 10.0, 0.1, 0.2];
        for _ in 0..50 {
            assert_eq!(sample(&l, &params(1.0, 0.5, 0), &mut rng), 1);
        }
    }

    #[test]
    fn sampling_covers_support_at_high_temp() {
        let mut rng = Rng::new(3);
        let l = [1.0, 1.0, 1.0, 1.0];
        let mut seen = [false; 4];
        for _ in 0..300 {
            seen[sample(&l, &params(1.0, 1.0, 0), &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }

    #[test]
    fn contrastive_alpha_one_is_cond() {
        let m = contrastive_mix(&[1.0, 2.0], &[0.5, 0.5], 1.0);
        assert_eq!(m, vec![1.0, 2.0]);
    }

    #[test]
    fn prop_sample_in_range() {
        prop_check(
            300,
            7,
            |r| {
                let n = r.usize(1, 40);
                (0..n).map(|_| r.usize(0, 1000)).collect::<Vec<_>>()
            },
            |xs| {
                let logits: Vec<f32> =
                    xs.iter().map(|&x| x as f32 / 100.0).collect();
                let mut rng = Rng::new(9);
                let p = params(0.8, 0.9, 3);
                let t = sample(&logits, &p, &mut rng);
                if (t as usize) < logits.len() {
                    Ok(())
                } else {
                    Err(format!("token {t} out of range {}", logits.len()))
                }
            },
        );
    }
}
