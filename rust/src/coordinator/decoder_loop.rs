//! Llama / Chameleon serving sessions over the PJRT engine.
//!
//! Graph-mode execution: one AOT executable per prefill bucket, one per
//! decode step; KV caches stay device-resident and chain across steps
//! (the CUDA-Graph discipline of §4.1.2). Contrastive decoding for
//! Chameleon T-I runs the decode graph twice per step (§2.1.2) with
//! separate conditional/unconditional caches.

use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::PjRtBuffer;

use crate::kvpool::{pages_for, KvPool, DEFAULT_PAGE_SIZE};
use crate::models::tokenizer::{self, TextTokenizer};
use crate::runtime::engine::{Arg, Engine, StageHandle};
use crate::runtime::tensor::Tensor;
use crate::sched::{ExecDims, SlotFeed, StepExecutor};
use crate::substrate::rng::Rng;
use crate::telemetry::tracer::Cat;

use super::opts::{ExecMode, OptConfig};
use super::request::SamplingParams;
use super::sampling;

/// Tiny-config dims read from the manifest.
#[derive(Debug, Clone, Copy)]
pub struct DecoderDims {
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    pub vocab: usize,
    pub early_exit_layer: usize,
    pub verify_window: usize,
}

impl DecoderDims {
    pub fn from_engine(e: &Engine) -> Result<Self> {
        let m = &e.manifest;
        Ok(DecoderDims {
            n_layers: m.cfg_usize("n_layers")?,
            n_heads: m.cfg_usize("n_heads")?,
            head_dim: m.cfg_usize("head_dim")?,
            max_seq: m.cfg_usize("max_seq")?,
            vocab: m.cfg_usize("vocab_size")?,
            early_exit_layer: m.cfg_usize("early_exit_layer")?,
            verify_window: m.cfg_usize("verify_window")?,
        })
    }

    pub fn kv_shape(&self, batch: usize) -> Vec<usize> {
        vec![self.n_layers, batch, self.n_heads, self.max_seq, self.head_dim]
    }
}

/// Device-resident KV pair.
pub struct KvBufs {
    pub k: PjRtBuffer,
    pub v: PjRtBuffer,
}

/// A single-request decoder session (bs = 1).
pub struct DecoderSession<'e> {
    pub engine: &'e Engine,
    pub dims: DecoderDims,
    pub opt: OptConfig,
    prefill_buckets: Vec<usize>,
    decode: StageHandle,
}

/// Result of a generation loop.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub tokens: Vec<i32>,
    pub prompt_tokens: usize,
    pub decode_steps: usize,
    pub ttft: f64,
    pub e2e: f64,
    /// LayerSkip stats (draft acceptance), if the lever was on.
    pub accepted_drafts: usize,
    pub draft_rounds: usize,
}

impl<'e> DecoderSession<'e> {
    pub fn new(engine: &'e Engine, opt: OptConfig) -> Result<Self> {
        let dims = DecoderDims::from_engine(engine)?;
        let mut prefill_buckets: Vec<usize> = engine
            .manifest
            .stages_of_kind("prefill")
            .iter()
            .filter_map(|s| s.meta_usize("bucket"))
            .collect();
        prefill_buckets.sort();
        prefill_buckets.dedup();
        if prefill_buckets.is_empty() {
            bail!("no prefill stages in manifest");
        }
        let decode = engine.stage(&Self::decode_stage_name(engine, 1, &opt)?)?;
        Ok(DecoderSession { engine, dims, opt, prefill_buckets, decode })
    }

    /// Resolve the decode stage for a batch size + levers, falling back
    /// to the baseline variant when a combination wasn't lowered.
    pub fn decode_stage_name(engine: &Engine, batch: usize,
                             opt: &OptConfig) -> Result<String> {
        let want = format!("decode_b{batch}{}", opt.stage_suffix());
        if engine.has_stage(&want) {
            return Ok(want);
        }
        let base = format!("decode_b{batch}");
        if engine.has_stage(&base) {
            return Ok(base);
        }
        bail!("no decode stage for batch {batch}");
    }

    /// Pick the smallest prefill bucket ≥ len (falls back to largest).
    pub fn bucket_for(&self, len: usize) -> usize {
        *self
            .prefill_buckets
            .iter()
            .find(|&&b| b >= len)
            .unwrap_or(self.prefill_buckets.last().unwrap())
    }

    fn prefill_stage_name(&self, bucket: usize) -> String {
        let want = format!("prefill_b{bucket}{}", self.opt.stage_suffix());
        if self.engine.has_stage(&want) {
            want
        } else {
            format!("prefill_b{bucket}")
        }
    }

    /// Run a bucketed prefill; returns (logits, kv) with KV on device.
    pub fn prefill(&self, prompt: &[i32]) -> Result<(Vec<f32>, KvBufs)> {
        let bucket = self.bucket_for(prompt.len());
        let plen = prompt.len().min(bucket);
        let mut toks = vec![0i32; bucket];
        toks[..plen].copy_from_slice(&prompt[..plen]);
        let stage = self.engine.stage(&self.prefill_stage_name(bucket))?;
        let t_tokens = Tensor::from_i32(&[1, bucket], &toks);
        let t_len = Tensor::from_i32(&[1], &[plen as i32]);
        let outs = self.engine.run(
            &stage,
            &[Arg::Host(&t_tokens), Arg::Host(&t_len)],
        )?;
        let mut it = outs.into_iter();
        let logits_buf = it.next().context("logits")?;
        let k = it.next().context("ck")?;
        let v = it.next().context("cv")?;
        let logits = self.engine.download(&logits_buf)?.as_f32()?;
        Ok((logits, KvBufs { k, v }))
    }

    /// One decode step (bs=1): feed token at `pos`, return next logits.
    pub fn decode_step(&self, token: i32, pos: usize, kv: &mut KvBufs)
                       -> Result<Vec<f32>> {
        let t_tok = Tensor::from_i32(&[1], &[token]);
        let t_pos = Tensor::from_i32(&[1], &[pos as i32]);
        let outs = self.engine.run(
            &self.decode,
            &[Arg::Host(&t_tok), Arg::Host(&t_pos), Arg::Dev(&kv.k),
              Arg::Dev(&kv.v)],
        )?;
        let mut it = outs.into_iter();
        let logits_buf = it.next().context("logits")?;
        kv.k = it.next().context("ck")?;
        kv.v = it.next().context("cv")?;
        self.engine.download(&logits_buf)?.as_f32()
    }

    /// Full greedy/sampled generation (bs=1): dispatch to the right
    /// [`StepExecutor`] and run the shared `sched` decode driver. The
    /// loop that used to live here is now written once in
    /// [`crate::sched::exec::generate`].
    pub fn generate(&self, prompt: &[i32], max_new: usize,
                    sp: &SamplingParams) -> Result<GenResult> {
        if self.opt.exec == ExecMode::Eager {
            return super::eager::generate_eager(
                self.engine, &self.dims, prompt, max_new, sp);
        }
        if self.opt.layerskip {
            return super::layerskip::generate_layerskip(
                self.engine, &self.dims, prompt, max_new, sp);
        }
        let mut exec = GraphExecutor::new(self);
        crate::sched::generate(&mut exec, self.engine.tracer(), prompt,
                               max_new, sp)
    }

    /// Chameleon T-I contrastive generation: two caches (conditional on
    /// the prompt, unconditional on BOS), decode both per step, mix
    /// logits with the guidance scale, restrict sampling to image
    /// tokens. Produces exactly `n_image_tokens` tokens.
    pub fn generate_image(&self, prompt: &[i32], n_image_tokens: usize,
                          sp: &SamplingParams) -> Result<GenResult> {
        let t0 = Instant::now();
        let tele = self.engine.tracer();
        let _tick_scope = tele.map(|t| t.tick_scope());
        let mut rng = Rng::new(sp.seed);
        let prefill_span = tele.map(|t| t.span(Cat::Prefill, "prefill"));
        let (cond_logits, mut kv_c) = self.prefill(prompt)?;
        let (uncond_logits, mut kv_u) =
            self.prefill(&[tokenizer::BOS])?;
        drop(prefill_span);
        let ttft = t0.elapsed().as_secs_f64();
        // Two block tables (conditional / unconditional streams) in one
        // pool — the paper's 2× KV footprint for T-I, page-accounted.
        let mut pool = KvPool::new(
            2 * pages_for(self.dims.max_seq, DEFAULT_PAGE_SIZE),
            DEFAULT_PAGE_SIZE,
            self.dims.max_seq,
        );
        let table_len = prompt.len().min(self.dims.max_seq - 1);
        pool.alloc(0, &prompt[..table_len])?;
        pool.alloc(1, &[tokenizer::BOS])?;
        let mut pos_c = prompt.len();
        let mut pos_u = 1usize;
        let mut lc = cond_logits;
        let mut lu = uncond_logits;
        let mut out = Vec::with_capacity(n_image_tokens);
        for _ in 0..n_image_tokens {
            if let Some(t) = tele {
                t.next_tick();
            }
            let _step_span = tele.map(|t| t.span(Cat::Decode, "decode_step"));
            let tok = {
                let _s = tele.map(|t| t.span(Cat::Sample, "sample"));
                let mixed = sampling::contrastive_mix(&lc, &lu,
                                                      self.opt.cfg_alpha);
                sample_image_token(&mixed, sp, &mut rng)
            };
            out.push(tok);
            if out.len() == n_image_tokens {
                break;
            }
            if pos_c + 1 >= self.dims.max_seq
                || pos_u + 1 >= self.dims.max_seq
            {
                break; // sequence cap, as in the text loop
            }
            // Two decodes per step — the paper's 2× decode cost for T-I.
            lc = self.decode_step(tok, pos_c, &mut kv_c)?;
            lu = self.decode_step(tok, pos_u, &mut kv_u)?;
            pos_c = pool.advance(0, tok)?;
            pos_u = pool.advance(1, tok)?;
        }
        pool.release(0)?;
        pool.release(1)?;
        debug_assert!(pool.check_invariants().is_ok());
        Ok(GenResult {
            prompt_tokens: prompt.len(),
            decode_steps: out.len(),
            tokens: out,
            ttft,
            e2e: t0.elapsed().as_secs_f64(),
            accepted_drafts: 0,
            draft_rounds: 0,
        })
    }
}

/// The compiled-graph bs=1 engine as a [`StepExecutor`]: one bucketed
/// prefill consumes the whole prompt, each decode step is one fused
/// dispatch with the device-resident KV chained through.
pub struct GraphExecutor<'s, 'e> {
    session: &'s DecoderSession<'e>,
    kv: Option<KvBufs>,
}

impl<'s, 'e> GraphExecutor<'s, 'e> {
    pub fn new(session: &'s DecoderSession<'e>) -> Self {
        GraphExecutor { session, kv: None }
    }
}

impl StepExecutor for GraphExecutor<'_, '_> {
    fn plan_dims(&self) -> ExecDims {
        ExecDims {
            batch: 1,
            max_seq: self.session.dims.max_seq,
            vocab: self.session.dims.vocab,
        }
    }

    fn prefill_chunk(&mut self, _slot: usize, tokens: &[i32], start: usize,
                     is_last: bool) -> Result<Option<Vec<f32>>> {
        debug_assert_eq!(start, 0, "bs=1 graph prefill is one chunk");
        let (logits, kv) = self.session.prefill(tokens)?;
        self.kv = Some(kv);
        Ok(is_last.then_some(logits))
    }

    fn decode_step(&mut self, feeds: &[SlotFeed]) -> Result<Vec<f32>> {
        let f = feeds.first().context("bs=1 executor needs one feed")?;
        let kv = self.kv.as_mut().context("decode before prefill")?;
        self.session.decode_step(f.token, f.pos, kv)
    }
}

/// Restrict sampling to the image-token slice of the vocab.
fn sample_image_token(logits: &[f32], sp: &SamplingParams,
                      rng: &mut Rng) -> i32 {
    let base = tokenizer::IMG_BASE as usize;
    let slice = &logits[base..base + tokenizer::IMG_TOKENS];
    base as i32 + sampling::sample(slice, sp, rng)
}

/// Tokenize request text for the decoder models.
pub fn encode_prompt(text: &str) -> Vec<i32> {
    let tk = TextTokenizer::new();
    let mut ids = vec![tokenizer::BOS];
    ids.extend(tk.encode(text));
    ids
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_token_sampling_stays_in_range() {
        let mut rng = Rng::new(0);
        let logits = vec![0.0f32; tokenizer::VOCAB];
        let sp = SamplingParams::default();
        for _ in 0..100 {
            let t = sample_image_token(&logits, &sp, &mut rng);
            assert!(t >= tokenizer::IMG_BASE);
            assert!(t < tokenizer::IMG_BASE + tokenizer::IMG_TOKENS as i32);
        }
    }

    #[test]
    fn encode_prompt_starts_with_bos() {
        let ids = encode_prompt("hello");
        assert_eq!(ids[0], tokenizer::BOS);
        assert!(ids.len() > 1);
    }
}
