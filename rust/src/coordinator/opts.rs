//! The optimization-lever configuration — §4's knobs as a struct.
//!
//! Every lever maps to a different set of AOT stages (or a different
//! execution discipline), so flipping a knob changes which executables
//! the decode loop dispatches:
//!
//! | paper lever                | knob            | effect |
//! |----------------------------|-----------------|--------|
//! | SDPA / FlashAttention      | `attn`          | `*_flash` stages (Pallas tiled kernel) |
//! | torch.compile + CUDA Graph | `exec`          | `Graph` = one fused stage per step; `Eager` = per-op dispatch |
//! | AutoQuant                  | `quant`         | `*_int8wo` / `*_int8dyn` stages |
//! | LayerSkip                  | `layerskip`     | draft/verify self-speculative loop |

use std::fmt;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttnImpl {
    /// Baseline: materialized softmax(QKᵀ)V.
    Naive,
    /// Flash-style tiled Pallas kernel (the SDPA lever).
    Flash,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// One AOT-compiled executable per step (torch.compile + CUDA Graph
    /// regime: no per-op dispatch, static shapes).
    Graph,
    /// One dispatch per operator group (the launch-overhead baseline of
    /// Obs #2).
    Eager,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantMode {
    F32,
    Int8WeightOnly,
    Int8Dynamic,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OptConfig {
    pub attn: AttnImpl,
    pub exec: ExecMode,
    pub quant: QuantMode,
    pub layerskip: bool,
    /// Contrastive-decoding guidance scale for Chameleon T-I.
    pub cfg_alpha: f32,
}

impl Default for OptConfig {
    fn default() -> Self {
        OptConfig::baseline()
    }
}

impl OptConfig {
    /// Paper baseline: eager-ish naive attention, f32.
    pub fn baseline() -> Self {
        OptConfig {
            attn: AttnImpl::Naive,
            exec: ExecMode::Graph,
            quant: QuantMode::F32,
            layerskip: false,
            cfg_alpha: 3.0,
        }
    }

    /// The true unoptimized regime (per-op dispatch) for Obs #2 studies.
    pub fn eager_baseline() -> Self {
        OptConfig { exec: ExecMode::Eager, ..Self::baseline() }
    }

    /// +SDPA.
    pub fn sdpa() -> Self {
        OptConfig { attn: AttnImpl::Flash, ..Self::baseline() }
    }

    /// +SDPA +compile (graph) +AutoQuant — the paper's "Sys-Opt" point.
    pub fn sys_opt() -> Self {
        OptConfig {
            attn: AttnImpl::Flash,
            exec: ExecMode::Graph,
            quant: QuantMode::Int8WeightOnly,
            layerskip: false,
            cfg_alpha: 3.0,
        }
    }

    /// Everything incl. LayerSkip — the 3.88× cross-stack point.
    pub fn all_levers() -> Self {
        OptConfig { layerskip: true, ..Self::sys_opt() }
    }

    /// Stage-name suffix selecting the right AOT variant, e.g.
    /// `"_flash_int8wo"`.
    pub fn stage_suffix(&self) -> String {
        let mut s = String::new();
        if self.attn == AttnImpl::Flash {
            s.push_str("_flash");
        }
        match self.quant {
            QuantMode::F32 => {}
            QuantMode::Int8WeightOnly => s.push_str("_int8wo"),
            QuantMode::Int8Dynamic => s.push_str("_int8dyn"),
        }
        s
    }
}

impl fmt::Display for OptConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "attn={:?} exec={:?} quant={:?} layerskip={}",
            self.attn, self.exec, self.quant, self.layerskip
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suffixes() {
        assert_eq!(OptConfig::baseline().stage_suffix(), "");
        assert_eq!(OptConfig::sdpa().stage_suffix(), "_flash");
        assert_eq!(OptConfig::sys_opt().stage_suffix(), "_flash_int8wo");
        let dyn8 = OptConfig {
            quant: QuantMode::Int8Dynamic,
            ..OptConfig::baseline()
        };
        assert_eq!(dyn8.stage_suffix(), "_int8dyn");
    }
}
