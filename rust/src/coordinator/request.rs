//! Request/response types flowing through the coordinator.

use crate::models::TaskKind;

/// Raw request input per modality.
#[derive(Debug, Clone)]
pub enum RequestInput {
    /// Plain text (tokenized by the router).
    Text(String),
    /// Grayscale image (pixels in [0,1], h, w) — Chameleon tasks.
    Image { pixels: Vec<f32>, h: usize, w: usize },
    /// Image + question (IT-T).
    ImageText { pixels: Vec<f32>, h: usize, w: usize, text: String },
    /// Raw waveform (Seamless speech tasks).
    Speech(Vec<f32>),
    /// User interaction history (HSTU): item ids.
    History(Vec<i32>),
    /// Pre-tokenized ids (bench/testing path).
    Tokens(Vec<i32>),
}

/// Sampling configuration.
#[derive(Debug, Clone, Copy)]
pub struct SamplingParams {
    pub temperature: f32,
    pub top_p: f32,
    pub top_k: usize,
    pub seed: u64,
    /// Greedy overrides the stochastic knobs.
    pub greedy: bool,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            temperature: 1.0,
            top_p: 0.9,
            top_k: 0,
            seed: 0,
            greedy: false,
        }
    }
}

impl SamplingParams {
    pub fn greedy() -> Self {
        SamplingParams { greedy: true, ..Default::default() }
    }
}

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub task: TaskKind,
    pub input: RequestInput,
    pub max_new_tokens: usize,
    pub sampling: SamplingParams,
}

impl Request {
    pub fn text(id: u64, task: TaskKind, text: &str, max_new: usize) -> Self {
        Request {
            id,
            task,
            input: RequestInput::Text(text.to_string()),
            max_new_tokens: max_new,
            sampling: SamplingParams::greedy(),
        }
    }
}

/// Output payload per modality.
#[derive(Debug, Clone)]
pub enum ResponseOutput {
    Text(String),
    /// Decoded image thumbnail (grayscale [0,1], 8×8 for the tiny model).
    Image(Vec<f32>),
    /// Waveform samples.
    Speech(Vec<f32>),
    /// HSTU: (engagement-type logits argmax per position tail, top items).
    Actions { engagement: Vec<i32>, top_items: Vec<i32> },
}

/// Completed response with serving metrics.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub task: TaskKind,
    pub output: ResponseOutput,
    pub tokens: Vec<i32>,
    pub prompt_tokens: usize,
    pub decode_steps: usize,
    /// Time to first token (seconds).
    pub ttft: f64,
    /// End-to-end latency (seconds).
    pub e2e: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults() {
        let s = SamplingParams::default();
        assert!(!s.greedy);
        assert!(SamplingParams::greedy().greedy);
        let r = Request::text(1, TaskKind::TextToText, "hi", 4);
        assert_eq!(r.max_new_tokens, 4);
        assert!(matches!(r.input, RequestInput::Text(_)));
    }
}
