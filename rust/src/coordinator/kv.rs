//! Static KV-cache slot manager.
//!
//! The decode graph is compiled for a fixed batch B with a
//! `[L, B, H, max_seq, Dh]` cache (paper §4.1.2: static shapes are what
//! make CUDA-Graph-style AOT execution possible). This module tracks
//! which batch slots are live, each slot's fill position, and the free
//! list — the bookkeeping the scheduler uses for admission.

use anyhow::{bail, Result};

/// State of one batch slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotState {
    Free,
    /// Occupied by request `id` with `pos` tokens already in the cache.
    Live { request: u64, pos: usize },
}

/// Slot bookkeeping for one fixed-batch decode graph.
#[derive(Debug, Clone)]
pub struct KvSlots {
    slots: Vec<SlotState>,
    max_seq: usize,
}

impl KvSlots {
    pub fn new(batch: usize, max_seq: usize) -> Self {
        KvSlots { slots: vec![SlotState::Free; batch], max_seq }
    }

    pub fn batch(&self) -> usize {
        self.slots.len()
    }
    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    pub fn free_count(&self) -> usize {
        self.slots.iter().filter(|s| **s == SlotState::Free).count()
    }
    pub fn live_count(&self) -> usize {
        self.slots.len() - self.free_count()
    }

    /// Claim a free slot for `request`, pre-filled with `pos` tokens.
    pub fn alloc(&mut self, request: u64, pos: usize) -> Result<usize> {
        if pos >= self.max_seq {
            bail!("prompt {pos} tokens >= max_seq {}", self.max_seq);
        }
        if self.slots.iter().any(
            |s| matches!(s, SlotState::Live { request: r, .. } if *r == request),
        ) {
            bail!("request {request} already has a slot");
        }
        for (i, s) in self.slots.iter_mut().enumerate() {
            if *s == SlotState::Free {
                *s = SlotState::Live { request, pos };
                return Ok(i);
            }
        }
        bail!("no free slot");
    }

    pub fn release(&mut self, slot: usize) -> Result<()> {
        match self.slots.get(slot) {
            Some(SlotState::Live { .. }) => {
                self.slots[slot] = SlotState::Free;
                Ok(())
            }
            Some(SlotState::Free) => bail!("slot {slot} already free"),
            None => bail!("slot {slot} out of range"),
        }
    }

    pub fn state(&self, slot: usize) -> SlotState {
        self.slots[slot]
    }

    /// Position of a live slot.
    pub fn pos(&self, slot: usize) -> Result<usize> {
        match self.slots[slot] {
            SlotState::Live { pos, .. } => Ok(pos),
            SlotState::Free => bail!("slot {slot} is free"),
        }
    }

    /// Advance a live slot by one token; errors at capacity.
    pub fn advance(&mut self, slot: usize) -> Result<usize> {
        match &mut self.slots[slot] {
            SlotState::Live { pos, .. } => {
                if *pos + 1 >= self.max_seq {
                    bail!("slot {slot} hit max_seq {}", self.max_seq);
                }
                *pos += 1;
                Ok(*pos)
            }
            SlotState::Free => bail!("slot {slot} is free"),
        }
    }

    /// Rewind (LayerSkip rollback after partial acceptance).
    pub fn rewind_to(&mut self, slot: usize, new_pos: usize) -> Result<()> {
        match &mut self.slots[slot] {
            SlotState::Live { pos, .. } => {
                if new_pos > *pos {
                    bail!("rewind forward ({new_pos} > {pos})");
                }
                *pos = new_pos;
                Ok(())
            }
            SlotState::Free => bail!("slot {slot} is free"),
        }
    }

    pub fn live_slots(&self) -> Vec<(usize, u64, usize)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| match s {
                SlotState::Live { request, pos } => Some((i, *request, *pos)),
                SlotState::Free => None,
            })
            .collect()
    }

    /// KV bytes held live (for the Table-3 capacity accounting).
    pub fn live_kv_bytes(&self, bytes_per_token: usize) -> usize {
        self.live_slots()
            .iter()
            .map(|(_, _, pos)| pos * bytes_per_token)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prop::prop_check;
    use crate::substrate::rng::Rng;

    #[test]
    fn alloc_release_cycle() {
        let mut kv = KvSlots::new(2, 128);
        let a = kv.alloc(10, 5).unwrap();
        let b = kv.alloc(11, 7).unwrap();
        assert_ne!(a, b);
        assert_eq!(kv.free_count(), 0);
        assert!(kv.alloc(12, 1).is_err());
        kv.release(a).unwrap();
        assert_eq!(kv.free_count(), 1);
        let c = kv.alloc(12, 1).unwrap();
        assert_eq!(c, a); // lowest-index reuse
    }

    #[test]
    fn advance_and_capacity() {
        let mut kv = KvSlots::new(1, 4);
        let s = kv.alloc(1, 1).unwrap();
        assert_eq!(kv.advance(s).unwrap(), 2);
        assert_eq!(kv.advance(s).unwrap(), 3);
        assert!(kv.advance(s).is_err()); // 3+1 == max_seq
    }

    #[test]
    fn rewind_only_backward() {
        let mut kv = KvSlots::new(1, 16);
        let s = kv.alloc(1, 8).unwrap();
        kv.rewind_to(s, 4).unwrap();
        assert_eq!(kv.pos(s).unwrap(), 4);
        assert!(kv.rewind_to(s, 10).is_err());
    }

    #[test]
    fn duplicate_request_rejected() {
        let mut kv = KvSlots::new(2, 16);
        kv.alloc(7, 0).unwrap();
        assert!(kv.alloc(7, 0).is_err());
    }

    #[test]
    fn double_release_rejected() {
        let mut kv = KvSlots::new(1, 16);
        let s = kv.alloc(1, 0).unwrap();
        kv.release(s).unwrap();
        assert!(kv.release(s).is_err());
    }

    #[test]
    fn alloc_at_max_seq_rejected() {
        // A prompt that already fills the cache leaves no room for even
        // one decode step — admission must refuse it.
        let mut kv = KvSlots::new(2, 8);
        assert!(kv.alloc(1, 8).is_err());
        assert!(kv.alloc(1, 9).is_err());
        assert_eq!(kv.free_count(), 2, "failed alloc must not leak a slot");
        let s = kv.alloc(1, 7).unwrap(); // last admissible position
        assert_eq!(kv.pos(s).unwrap(), 7);
    }

    #[test]
    fn alloc_when_all_slots_live_rejected() {
        let mut kv = KvSlots::new(3, 16);
        for id in 0..3 {
            kv.alloc(id, 1).unwrap();
        }
        assert_eq!(kv.free_count(), 0);
        let err = kv.alloc(99, 1).unwrap_err();
        assert!(err.to_string().contains("no free slot"), "{err}");
        assert_eq!(kv.live_count(), 3);
    }

    #[test]
    fn release_of_non_live_slot_rejected() {
        let mut kv = KvSlots::new(2, 16);
        // Never-allocated slot (in range) and out-of-range slot.
        assert!(kv.release(0).is_err());
        assert!(kv.release(5).is_err());
        // State queries on a free slot also refuse.
        assert_eq!(kv.state(0), SlotState::Free);
        assert!(kv.pos(0).is_err());
        assert!(kv.advance(0).is_err());
    }

    #[test]
    fn slot_reuse_is_lowest_index_first() {
        let mut kv = KvSlots::new(4, 32);
        let slots: Vec<usize> =
            (0..4).map(|id| kv.alloc(id, 1).unwrap()).collect();
        assert_eq!(slots, vec![0, 1, 2, 3]);
        // Free 2 then 0: reuse must hand out 0 first, then 2, then fail.
        kv.release(2).unwrap();
        kv.release(0).unwrap();
        assert_eq!(kv.alloc(10, 1).unwrap(), 0);
        assert_eq!(kv.alloc(11, 1).unwrap(), 2);
        assert!(kv.alloc(12, 1).is_err());
    }

    /// Property: a random walk of alloc/advance/release never leaks slots
    /// — free + live == batch, and live positions stay < max_seq.
    #[test]
    fn prop_no_slot_leaks() {
        prop_check(
            100,
            42,
            |r: &mut Rng| {
                let n = r.usize(1, 60);
                (0..n).map(|_| r.usize(0, 3)).collect::<Vec<usize>>()
            },
            |ops| {
                let mut kv = KvSlots::new(4, 32);
                let mut next_id = 0u64;
                for &op in ops {
                    match op {
                        0 => {
                            next_id += 1;
                            let _ = kv.alloc(next_id, 1);
                        }
                        1 => {
                            if let Some((s, _, _)) =
                                kv.live_slots().first().copied()
                            {
                                let _ = kv.advance(s);
                            }
                        }
                        _ => {
                            if let Some((s, _, _)) =
                                kv.live_slots().last().copied()
                            {
                                let _ = kv.release(s);
                            }
                        }
                    }
                    if kv.free_count() + kv.live_count() != kv.batch() {
                        return Err("slot leak".into());
                    }
                    for (_, _, pos) in kv.live_slots() {
                        if pos >= kv.max_seq() {
                            return Err(format!("pos {pos} >= max_seq"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
