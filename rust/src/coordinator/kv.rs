//! KV-cache views: the dense slot manager for the compiled decode
//! graphs, and the paged wrapper that layers it over `kvpool`.
//!
//! The decode graph is compiled for a fixed batch B with a
//! `[L, B, H, max_seq, Dh]` cache (paper §4.1.2: static shapes are what
//! make CUDA-Graph-style AOT execution possible). [`KvSlots`] tracks
//! which batch slots are live, each slot's fill position, and the free
//! list — the bookkeeping the scheduler uses for admission.
//!
//! [`PagedKvSlots`] keeps that slot view (the graph still indexes a
//! dense per-slot cache) but meters *capacity* through a
//! [`KvPool`]: admission claims pages for the actual prompt length
//! (sharing cached prefixes), decode grows page by page, and when the
//! pool runs dry the scheduler preempts instead of over-reserving.
//! Errors are the structured [`KvError`] vocabulary — callers match on
//! variants, never on message strings.

use std::collections::HashMap;

use crate::kvpool::{AllocOutcome, CapacityView, KvError, KvPool,
                    KvPoolConfig, PoolStats, Preempted, PreemptMode};
use crate::perfmodel::fabric::FabricSpec;

/// State of one batch slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotState {
    Free,
    /// Occupied by request `id` with `pos` tokens already in the cache.
    Live { request: u64, pos: usize },
}

/// Slot bookkeeping for one fixed-batch decode graph.
#[derive(Debug, Clone)]
pub struct KvSlots {
    slots: Vec<SlotState>,
    /// request → slot, so duplicate checks and preemption lookups are
    /// O(1) instead of an O(B) scan per call.
    by_request: HashMap<u64, usize>,
    max_seq: usize,
}

impl KvSlots {
    pub fn new(batch: usize, max_seq: usize) -> Self {
        KvSlots {
            slots: vec![SlotState::Free; batch],
            by_request: HashMap::new(),
            max_seq,
        }
    }

    pub fn batch(&self) -> usize {
        self.slots.len()
    }
    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    pub fn free_count(&self) -> usize {
        self.slots.len() - self.by_request.len()
    }
    pub fn live_count(&self) -> usize {
        self.by_request.len()
    }

    /// Slot currently held by `request`, if any.
    pub fn slot_of(&self, request: u64) -> Option<usize> {
        self.by_request.get(&request).copied()
    }

    /// Claim a free slot for `request`, pre-filled with `pos` tokens.
    pub fn alloc(&mut self, request: u64, pos: usize)
                 -> Result<usize, KvError> {
        if pos >= self.max_seq {
            return Err(KvError::MaxSeq { pos, max_seq: self.max_seq });
        }
        if self.by_request.contains_key(&request) {
            return Err(KvError::DuplicateRequest(request));
        }
        for (i, s) in self.slots.iter_mut().enumerate() {
            if *s == SlotState::Free {
                *s = SlotState::Live { request, pos };
                self.by_request.insert(request, i);
                return Ok(i);
            }
        }
        Err(KvError::NoFreeSlot)
    }

    pub fn release(&mut self, slot: usize) -> Result<(), KvError> {
        let request = self.request_at(slot)?;
        self.by_request.remove(&request);
        self.slots[slot] = SlotState::Free;
        Ok(())
    }

    pub fn state(&self, slot: usize) -> SlotState {
        self.slots[slot]
    }

    /// Request occupying a live slot.
    pub fn request_at(&self, slot: usize) -> Result<u64, KvError> {
        match self.slots.get(slot) {
            Some(SlotState::Live { request, .. }) => Ok(*request),
            Some(SlotState::Free) => Err(KvError::SlotFree(slot)),
            None => Err(KvError::UnknownSlot(slot)),
        }
    }

    /// Position of a live slot.
    pub fn pos(&self, slot: usize) -> Result<usize, KvError> {
        match self.slots.get(slot) {
            Some(SlotState::Live { pos, .. }) => Ok(*pos),
            Some(SlotState::Free) => Err(KvError::SlotFree(slot)),
            None => Err(KvError::UnknownSlot(slot)),
        }
    }

    /// Advance a live slot by one token; errors at capacity.
    pub fn advance(&mut self, slot: usize) -> Result<usize, KvError> {
        let max_seq = self.max_seq;
        match self.slots.get_mut(slot) {
            Some(SlotState::Live { pos, .. }) => {
                if *pos + 1 >= max_seq {
                    return Err(KvError::MaxSeq { pos: *pos, max_seq });
                }
                *pos += 1;
                Ok(*pos)
            }
            Some(SlotState::Free) => Err(KvError::SlotFree(slot)),
            None => Err(KvError::UnknownSlot(slot)),
        }
    }

    /// Rewind (LayerSkip rollback after partial acceptance).
    pub fn rewind_to(&mut self, slot: usize, new_pos: usize)
                     -> Result<(), KvError> {
        match self.slots.get_mut(slot) {
            Some(SlotState::Live { pos, .. }) => {
                if new_pos > *pos {
                    return Err(KvError::RewindForward {
                        from: *pos,
                        to: new_pos,
                    });
                }
                *pos = new_pos;
                Ok(())
            }
            Some(SlotState::Free) => Err(KvError::SlotFree(slot)),
            None => Err(KvError::UnknownSlot(slot)),
        }
    }

    pub fn live_slots(&self) -> Vec<(usize, u64, usize)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| match s {
                SlotState::Live { request, pos } => Some((i, *request, *pos)),
                SlotState::Free => None,
            })
            .collect()
    }

    /// KV bytes held live (for the Table-3 capacity accounting).
    pub fn live_kv_bytes(&self, bytes_per_token: usize) -> usize {
        self.live_slots()
            .iter()
            .map(|(_, _, pos)| pos * bytes_per_token)
            .sum()
    }
}

// ==========================================================================
// Paged wrapper
// ==========================================================================

/// The compiled-graph slot view layered over the paged pool.
///
/// In dense mode (paging disabled) this is exactly the seed's
/// `KvSlots` behavior. In paged mode every slot operation is mirrored
/// into the pool's block tables, so admission sees real page
/// availability (with prefix sharing) and decode growth can trigger
/// preemption instead of silently over-reserving.
#[derive(Debug, Clone)]
pub struct PagedKvSlots {
    slots: KvSlots,
    pool: Option<KvPool>,
}

impl PagedKvSlots {
    /// Dense slot view only (the seed behavior).
    pub fn dense(batch: usize, max_seq: usize) -> Self {
        PagedKvSlots { slots: KvSlots::new(batch, max_seq), pool: None }
    }

    /// Slot view + paged pool per `cfg` (`cfg.page_size == 0` falls
    /// back to dense).
    pub fn paged(batch: usize, max_seq: usize, cfg: KvPoolConfig) -> Self {
        let pool = if cfg.enabled() {
            Some(KvPool::for_batch(batch, max_seq, cfg))
        } else {
            None
        };
        PagedKvSlots { slots: KvSlots::new(batch, max_seq), pool }
    }

    pub fn is_paged(&self) -> bool {
        self.pool.is_some()
    }
    pub fn batch(&self) -> usize {
        self.slots.batch()
    }
    pub fn max_seq(&self) -> usize {
        self.slots.max_seq()
    }
    pub fn free_count(&self) -> usize {
        self.slots.free_count()
    }
    pub fn live_count(&self) -> usize {
        self.slots.live_count()
    }
    pub fn live_slots(&self) -> Vec<(usize, u64, usize)> {
        self.slots.live_slots()
    }
    pub fn pos(&self, slot: usize) -> Result<usize, KvError> {
        self.slots.pos(slot)
    }
    pub fn slot_of(&self, request: u64) -> Option<usize> {
        self.slots.slot_of(request)
    }
    pub fn request_at(&self, slot: usize) -> Result<u64, KvError> {
        self.slots.request_at(slot)
    }
    pub fn pool(&self) -> Option<&KvPool> {
        self.pool.as_ref()
    }
    pub fn stats(&self) -> Option<&PoolStats> {
        self.pool.as_ref().map(|p| &p.stats)
    }

    /// What the batcher admits against this tick.
    pub fn capacity_view(&self) -> CapacityView {
        match &self.pool {
            Some(p) => p.capacity_view(self.slots.free_count(),
                                       self.slots.live_count()),
            None => CapacityView::dense(self.slots.free_count(),
                                        self.slots.live_count()),
        }
    }

    /// Note a scheduler tick blocked on KV capacity (telemetry).
    pub fn note_capacity_wait(&mut self) {
        if let Some(p) = &mut self.pool {
            p.note_capacity_wait();
        }
    }

    /// Routing probe: leading full blocks of `tokens` resident in the
    /// pool (0 in dense mode — a dense cache has nothing to share).
    pub fn probe_prefix(&self, tokens: &[i32]) -> usize {
        self.pool
            .as_ref()
            .map_or(0, |p| p.probe_prefix(tokens))
    }

    /// Shard-set routing probe: `(resident leading blocks, distinct
    /// device shards holding them)` — `(0, 0)` in dense mode.
    pub fn probe_prefix_shards(&self, tokens: &[i32]) -> (usize, usize) {
        self.pool
            .as_ref()
            .map_or((0, 0), |p| p.probe_prefix_shards(tokens))
    }

    /// Per-shard capacity counters (empty in dense mode) — the
    /// occupancy view the worker republishes and `mmserve kv` prints.
    pub fn shard_views(&self) -> Vec<crate::kvpool::ShardView> {
        self.pool.as_ref().map_or_else(Vec::new, |p| p.shard_views())
    }

    /// A cheap fingerprint of pool activity since start: any page
    /// alloc/free/eviction/admission/preemption moves it. Used to skip
    /// republishing an unchanged routing snapshot on decode-only
    /// ticks. (A sole owner diverging from a cached block mutates the
    /// resident set without moving these counters — the snapshot is
    /// advisory and self-heals on the next counted mutation, which the
    /// divergence's own page growth or release delivers within ticks.)
    pub fn churn_stamp(&self) -> Option<u64> {
        self.pool.as_ref().map(|p| {
            p.stats.blocks_allocated
                + p.stats.blocks_freed
                + p.stats.evictions
                + p.stats.cow_forks
                + p.stats.seqs_admitted
                + p.stats.preemptions
        })
    }

    /// Publish this worker's cache warmth into its routing cell: the
    /// resident hash set *per device shard*, the per-shard live-page
    /// occupancy gauge, and the prefix counters — versioned so the
    /// router can spot a never-published (stale) snapshot.
    pub fn publish_routing_snapshot(
        &self, cell: &crate::routing::ReplicaCell,
    ) {
        if let Some(p) = &self.pool {
            cell.publish_shards(
                p.page_size(),
                p.resident_hashes_by_shard(),
                p.shard_views()
                    .iter()
                    .map(|v| v.live_pages as u64)
                    .collect(),
                p.stats.prefix_lookups,
                p.stats.prefix_hits,
                p.stats.prefix_hit_tokens,
            );
        }
    }

    /// Admit `request` with its prompt tokens: claim pages (sharing
    /// cached prefixes), then a graph slot. No partial state survives
    /// a failure.
    pub fn alloc(&mut self, request: u64, tokens: &[i32])
                 -> Result<(usize, AllocOutcome), KvError> {
        let outcome = match &mut self.pool {
            Some(p) => p.alloc(request, tokens)?,
            None => AllocOutcome { pages: 0, shared_pages: 0,
                                   shared_tokens: 0 },
        };
        match self.slots.alloc(request, tokens.len()) {
            Ok(slot) => Ok((slot, outcome)),
            Err(e) => {
                if let Some(p) = &mut self.pool {
                    // Roll the pool back so the failed admission leaks
                    // nothing.
                    let _ = p.release(request);
                }
                Err(e)
            }
        }
    }

    /// Advance a live slot by the token it just emitted. Pool growth
    /// runs first (it can fail with `CapacityExhausted` → preempt);
    /// the slot position follows in lockstep.
    pub fn advance(&mut self, slot: usize, token: i32)
                   -> Result<usize, KvError> {
        let request = self.slots.request_at(slot)?;
        let pos = self.slots.pos(slot)?;
        if let Some(p) = &mut self.pool {
            p.advance(request, token)?;
            if let Err(e) = self.slots.advance(slot) {
                // Keep the views in lockstep even on the error path.
                let _ = p.rewind_to(request, pos);
                return Err(e);
            }
            Ok(pos + 1)
        } else {
            self.slots.advance(slot)
        }
    }

    /// Chunked-prefill append: extend a live slot by a whole chunk,
    /// claiming pages as blocks fill. All-or-nothing at the position
    /// level (both views rewind to the pre-call position on failure;
    /// pages claimed by the partial extension stay mapped, overwrite
    /// semantics, reclaimed at release/preemption). Returns the new
    /// fill position.
    pub fn extend_chunk(&mut self, slot: usize, tokens: &[i32])
                        -> Result<usize, KvError> {
        let start = self.slots.pos(slot)?;
        for (i, &t) in tokens.iter().enumerate() {
            if let Err(e) = self.advance(slot, t) {
                if i > 0 {
                    let _ = self.rewind_to(slot, start);
                }
                return Err(e);
            }
        }
        Ok(start + tokens.len())
    }

    /// LayerSkip rollback on both views.
    pub fn rewind_to(&mut self, slot: usize, new_pos: usize)
                     -> Result<(), KvError> {
        let request = self.slots.request_at(slot)?;
        self.slots.rewind_to(slot, new_pos)?;
        if let Some(p) = &mut self.pool {
            p.rewind_to(request, new_pos)?;
        }
        Ok(())
    }

    /// Finish a request: free the slot, return its pages (full blocks
    /// stay cached for prefix reuse).
    pub fn release(&mut self, slot: usize) -> Result<(), KvError> {
        let request = self.slots.request_at(slot)?;
        self.slots.release(slot)?;
        if let Some(p) = &mut self.pool {
            p.release(request)?;
        }
        Ok(())
    }

    /// Beam split at the pool layer only: `child` becomes a block-table
    /// fork of `parent`'s pages (refcount bump, no KV copy, no graph
    /// slot — hypotheses share the batch lane of their root request).
    /// Errors `UnknownRequest` in dense mode, where there are no pages
    /// to fork. Returns the shared page count.
    pub fn fork(&mut self, parent: u64, child: u64)
                -> Result<usize, KvError> {
        match &mut self.pool {
            Some(p) => p.fork(parent, child),
            None => Err(KvError::UnknownRequest(parent)),
        }
    }

    /// Prune a dead beam hypothesis: drop its page references without
    /// publishing its blocks (see [`KvPool::release_discard`]). No-op
    /// error in dense mode, mirroring [`PagedKvSlots::fork`].
    pub fn release_discard(&mut self, request: u64) -> Result<(), KvError> {
        match &mut self.pool {
            Some(p) => p.release_discard(request),
            None => Err(KvError::UnknownRequest(request)),
        }
    }

    /// Preempt the latest-admitted live sequence (paged mode only):
    /// frees its slot and pages, returns its slot and token history so
    /// the scheduler can requeue it for recompute / swap-in.
    pub fn preempt(&mut self, mode: PreemptMode)
                   -> Option<(usize, Preempted)> {
        self.preempt_targeted(mode, None)
    }

    /// Preempt with an optional shard preference: on a sharded pool
    /// the victim is the latest admission holding pages on `prefer`
    /// (so the freed capacity lands on the grower's arena); on a
    /// monolithic pool — or with no preference — this is exactly
    /// [`PagedKvSlots::preempt`].
    pub fn preempt_targeted(&mut self, mode: PreemptMode,
                            prefer: Option<crate::kvpool::ShardId>)
                            -> Option<(usize, Preempted)> {
        let p = self.pool.as_mut()?;
        let pre = match prefer {
            Some(s) if p.shards() > 1 => p.preempt_on_shard(mode, s)?,
            _ => p.preempt(mode)?,
        };
        let slot = self
            .slots
            .slot_of(pre.request)
            .expect("preempted request holds a slot");
        self.slots
            .release(slot)
            .expect("victim slot is live");
        Some((slot, pre))
    }

    /// The shard a live request's decode growth prefers (`None` in
    /// dense mode or for an unknown request).
    pub fn growth_shard(&self, request: u64)
                        -> Option<crate::kvpool::ShardId> {
        self.pool.as_ref().and_then(|p| p.growth_shard(request))
    }

    /// Attach a priced transfer fabric to the underlying pool (no-op
    /// in dense mode): spills become byte-costed, swap-outs reserve
    /// host buffers, and [`PagedKvSlots::preempt_auto`] trades swap
    /// against recompute by modeled nanoseconds.
    pub fn set_fabric(&mut self, fabric: FabricSpec) {
        if let Some(p) = &mut self.pool {
            p.set_fabric(fabric);
        }
    }

    /// The attached fabric, if any (copy — `FabricSpec` is plain data).
    pub fn fabric(&self) -> Option<FabricSpec> {
        self.pool.as_ref().and_then(|p| p.fabric().copied())
    }

    /// Cost-aware preemption: the pool picks victim *and* mode by
    /// modeled eviction cost (swap round-trip vs. recompute); the slot
    /// view frees the victim's slot in lockstep, exactly like
    /// [`PagedKvSlots::preempt_targeted`]. Without a (non-free)
    /// fabric this *is* `preempt_targeted(Recompute, prefer)`.
    pub fn preempt_auto(&mut self, prefer: Option<crate::kvpool::ShardId>)
                        -> Option<(usize, Preempted)> {
        let p = self.pool.as_mut()?;
        let pre = p.preempt_auto(prefer)?;
        let slot = self
            .slots
            .slot_of(pre.request)
            .expect("preempted request holds a slot");
        self.slots
            .release(slot)
            .expect("victim slot is live");
        Some((slot, pre))
    }

    /// Is `request` staged host-side awaiting a swap-in?
    pub fn has_swapped(&self, request: u64) -> bool {
        self.pool.as_ref().is_some_and(|p| p.has_swapped(request))
    }

    /// Tokens a swapped-out request would resume with.
    pub fn swapped_tokens(&self, request: u64) -> Option<usize> {
        self.pool.as_ref().and_then(|p| p.swapped_tokens(request))
    }

    /// Swap a staged sequence back in: the pool reallocates its pages
    /// from the host buffer (sharing surviving prefix blocks), then a
    /// graph slot is claimed in lockstep. Capacity failures leave the
    /// buffer staged for a later retry; structural failures (no slot
    /// could ever fit) surface without touching the buffer either.
    pub fn resume_swapped(&mut self, request: u64)
                         -> Result<(usize, AllocOutcome), KvError> {
        let pool = self
            .pool
            .as_mut()
            .ok_or(KvError::UnknownRequest(request))?;
        let len = pool
            .swapped_tokens(request)
            .ok_or(KvError::UnknownRequest(request))?;
        // Pre-flight the slot view so a slot-side refusal never costs
        // the already-released host buffer.
        if len >= self.slots.max_seq() {
            return Err(KvError::MaxSeq { pos: len,
                                         max_seq: self.slots.max_seq() });
        }
        if self.slots.free_count() == 0 {
            return Err(KvError::NoFreeSlot);
        }
        let out = pool.resume_swapped(request)?;
        let slot = self
            .slots
            .alloc(request, len)
            .expect("pre-flighted slot claim");
        Ok((slot, out))
    }

    /// Abandon a staged swap and take the token history back (the
    /// caller recomputes instead). `None` when nothing is staged.
    pub fn discard_swapped(&mut self, request: u64)
                           -> Option<(Vec<i32>, usize)> {
        self.pool.as_mut().and_then(|p| p.discard_swapped(request))
    }

    /// Crash teardown: drop every staged host buffer (fail-over
    /// re-routes swapped requests from their prompts). Returns the
    /// bytes returned to the host budget.
    pub fn drain_host_buffers(&mut self) -> u64 {
        self.pool.as_mut().map_or(0, |p| p.drain_host_buffers())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvpool::DEFAULT_PAGE_SIZE;
    use crate::substrate::prop::prop_check;
    use crate::substrate::rng::Rng;

    #[test]
    fn alloc_release_cycle() {
        let mut kv = KvSlots::new(2, 128);
        let a = kv.alloc(10, 5).unwrap();
        let b = kv.alloc(11, 7).unwrap();
        assert_ne!(a, b);
        assert_eq!(kv.free_count(), 0);
        assert_eq!(kv.alloc(12, 1).unwrap_err(), KvError::NoFreeSlot);
        kv.release(a).unwrap();
        assert_eq!(kv.free_count(), 1);
        let c = kv.alloc(12, 1).unwrap();
        assert_eq!(c, a); // lowest-index reuse
    }

    #[test]
    fn advance_and_capacity() {
        let mut kv = KvSlots::new(1, 4);
        let s = kv.alloc(1, 1).unwrap();
        assert_eq!(kv.advance(s).unwrap(), 2);
        assert_eq!(kv.advance(s).unwrap(), 3);
        // 3+1 == max_seq
        assert_eq!(kv.advance(s).unwrap_err(),
                   KvError::MaxSeq { pos: 3, max_seq: 4 });
    }

    #[test]
    fn rewind_only_backward() {
        let mut kv = KvSlots::new(1, 16);
        let s = kv.alloc(1, 8).unwrap();
        kv.rewind_to(s, 4).unwrap();
        assert_eq!(kv.pos(s).unwrap(), 4);
        assert_eq!(kv.rewind_to(s, 10).unwrap_err(),
                   KvError::RewindForward { from: 4, to: 10 });
    }

    #[test]
    fn duplicate_request_rejected() {
        let mut kv = KvSlots::new(2, 16);
        kv.alloc(7, 0).unwrap();
        assert_eq!(kv.alloc(7, 0).unwrap_err(),
                   KvError::DuplicateRequest(7));
    }

    #[test]
    fn double_release_rejected() {
        let mut kv = KvSlots::new(1, 16);
        let s = kv.alloc(1, 0).unwrap();
        kv.release(s).unwrap();
        assert_eq!(kv.release(s).unwrap_err(), KvError::SlotFree(s));
    }

    #[test]
    fn alloc_at_max_seq_rejected() {
        // A prompt that already fills the cache leaves no room for even
        // one decode step — admission must refuse it.
        let mut kv = KvSlots::new(2, 8);
        assert_eq!(kv.alloc(1, 8).unwrap_err(),
                   KvError::MaxSeq { pos: 8, max_seq: 8 });
        assert!(kv.alloc(1, 9).is_err());
        assert_eq!(kv.free_count(), 2, "failed alloc must not leak a slot");
        let s = kv.alloc(1, 7).unwrap(); // last admissible position
        assert_eq!(kv.pos(s).unwrap(), 7);
    }

    #[test]
    fn alloc_when_all_slots_live_rejected() {
        let mut kv = KvSlots::new(3, 16);
        for id in 0..3 {
            kv.alloc(id, 1).unwrap();
        }
        assert_eq!(kv.free_count(), 0);
        assert_eq!(kv.alloc(99, 1).unwrap_err(), KvError::NoFreeSlot);
        assert_eq!(kv.live_count(), 3);
    }

    #[test]
    fn release_of_non_live_slot_rejected() {
        let mut kv = KvSlots::new(2, 16);
        // Never-allocated slot (in range) and out-of-range slot.
        assert_eq!(kv.release(0).unwrap_err(), KvError::SlotFree(0));
        assert_eq!(kv.release(5).unwrap_err(), KvError::UnknownSlot(5));
        // State queries on a free slot also refuse.
        assert_eq!(kv.state(0), SlotState::Free);
        assert_eq!(kv.pos(0).unwrap_err(), KvError::SlotFree(0));
        assert_eq!(kv.advance(0).unwrap_err(), KvError::SlotFree(0));
    }

    #[test]
    fn slot_reuse_is_lowest_index_first() {
        let mut kv = KvSlots::new(4, 32);
        let slots: Vec<usize> =
            (0..4).map(|id| kv.alloc(id, 1).unwrap()).collect();
        assert_eq!(slots, vec![0, 1, 2, 3]);
        // Free 2 then 0: reuse must hand out 0 first, then 2, then fail.
        kv.release(2).unwrap();
        kv.release(0).unwrap();
        assert_eq!(kv.alloc(10, 1).unwrap(), 0);
        assert_eq!(kv.alloc(11, 1).unwrap(), 2);
        assert_eq!(kv.alloc(12, 1).unwrap_err(), KvError::NoFreeSlot);
    }

    #[test]
    fn slot_of_tracks_alloc_and_release() {
        let mut kv = KvSlots::new(3, 32);
        assert_eq!(kv.slot_of(7), None);
        let s = kv.alloc(7, 1).unwrap();
        assert_eq!(kv.slot_of(7), Some(s));
        kv.alloc(8, 1).unwrap();
        kv.release(s).unwrap();
        assert_eq!(kv.slot_of(7), None);
        assert!(kv.slot_of(8).is_some());
    }

    /// Property: a random walk of alloc/advance/release never leaks slots
    /// — free + live == batch, live positions stay < max_seq, and the
    /// request→slot map mirrors the slot array exactly.
    #[test]
    fn prop_no_slot_leaks() {
        prop_check(
            100,
            42,
            |r: &mut Rng| {
                let n = r.usize(1, 60);
                (0..n).map(|_| r.usize(0, 3)).collect::<Vec<usize>>()
            },
            |ops| {
                let mut kv = KvSlots::new(4, 32);
                let mut next_id = 0u64;
                for &op in ops {
                    match op {
                        0 => {
                            next_id += 1;
                            let _ = kv.alloc(next_id, 1);
                        }
                        1 => {
                            if let Some((s, _, _)) =
                                kv.live_slots().first().copied()
                            {
                                let _ = kv.advance(s);
                            }
                        }
                        _ => {
                            if let Some((s, _, _)) =
                                kv.live_slots().last().copied()
                            {
                                let _ = kv.release(s);
                            }
                        }
                    }
                    if kv.free_count() + kv.live_count() != kv.batch() {
                        return Err("slot leak".into());
                    }
                    for (s, req, pos) in kv.live_slots() {
                        if pos >= kv.max_seq() {
                            return Err(format!("pos {pos} >= max_seq"));
                        }
                        if kv.slot_of(req) != Some(s) {
                            return Err(format!(
                                "map drift: request {req} slot {s}"
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    // ---- PagedKvSlots ------------------------------------------------

    fn small_cfg() -> KvPoolConfig {
        KvPoolConfig { page_size: 4, total_pages: 8, shards: 1 }
    }

    #[test]
    fn paged_alloc_mirrors_slot_and_pool() {
        let mut kv = PagedKvSlots::paged(2, 64, small_cfg());
        assert!(kv.is_paged());
        let (slot, out) = kv.alloc(1, &[1, 2, 3, 4, 5]).unwrap();
        assert_eq!(out.pages, 2);
        assert_eq!(kv.pos(slot).unwrap(), 5);
        assert_eq!(kv.pool().unwrap().pos(1).unwrap(), 5);
        kv.advance(slot, 6).unwrap();
        assert_eq!(kv.pos(slot).unwrap(), 6);
        assert_eq!(kv.pool().unwrap().pos(1).unwrap(), 6);
        kv.rewind_to(slot, 5).unwrap();
        assert_eq!(kv.pool().unwrap().pos(1).unwrap(), 5);
        kv.release(slot).unwrap();
        assert_eq!(kv.live_count(), 0);
        assert_eq!(kv.pool().unwrap().live_pages(), 0);
        kv.pool().unwrap().check_invariants().unwrap();
    }

    #[test]
    fn paged_alloc_slot_failure_rolls_back_pool() {
        let mut kv = PagedKvSlots::paged(1, 64, small_cfg());
        kv.alloc(1, &[1, 2, 3]).unwrap();
        // Pool has pages, but the single slot is taken.
        let err = kv.alloc(2, &[4, 5, 6]).unwrap_err();
        assert_eq!(err, KvError::NoFreeSlot);
        assert!(!kv.pool().unwrap().has_table(2), "pool rolled back");
        kv.pool().unwrap().check_invariants().unwrap();
    }

    #[test]
    fn paged_preempt_frees_slot_and_pages() {
        // 4 pages of 4 tokens: two 2-page sequences fill the pool.
        let cfg = KvPoolConfig { page_size: 4, total_pages: 4, shards: 1 };
        let mut kv = PagedKvSlots::paged(2, 64, cfg);
        let (s1, _) = kv.alloc(1, &[1, 2, 3, 4, 5]).unwrap();
        let (s2, _) = kv.alloc(2, &[9, 8, 7, 6, 5]).unwrap();
        // Growing request 1 past its partial page needs a 5th page.
        for t in 0..3 {
            kv.advance(s1, t).unwrap(); // fills the partial page
        }
        let err = kv.advance(s1, 99).unwrap_err();
        assert!(matches!(err, KvError::CapacityExhausted { .. }), "{err}");
        let (slot, pre) = kv.preempt(PreemptMode::Recompute).unwrap();
        assert_eq!(slot, s2);
        assert_eq!(pre.request, 2);
        assert_eq!(pre.tokens, vec![9, 8, 7, 6, 5]);
        assert_eq!(kv.live_count(), 1);
        // The freed capacity lets the stalled advance proceed.
        kv.advance(s1, 99).unwrap();
        kv.pool().unwrap().check_invariants().unwrap();
    }

    /// Chunked prefill: `extend_chunk` keeps the slot view and the
    /// pool's block table in lockstep, and rolls both back when the
    /// chunk cannot be covered.
    #[test]
    fn extend_chunk_mirrors_both_views_and_rolls_back() {
        let cfg = KvPoolConfig { page_size: 4, total_pages: 3, shards: 1 };
        let mut kv = PagedKvSlots::paged(1, 64, cfg);
        let (slot, _) = kv.alloc(1, &[1, 2, 3]).unwrap();
        assert_eq!(kv.extend_chunk(slot, &[4, 5, 6, 7, 8]).unwrap(), 8);
        assert_eq!(kv.pos(slot).unwrap(), 8);
        assert_eq!(kv.pool().unwrap().pos(1).unwrap(), 8);
        let err = kv.extend_chunk(slot, &[9; 9]).unwrap_err();
        assert!(matches!(err, KvError::CapacityExhausted { .. }), "{err}");
        assert_eq!(kv.pos(slot).unwrap(), 8, "slot view rolled back");
        assert_eq!(kv.pool().unwrap().pos(1).unwrap(), 8,
                   "block table rolled back");
        kv.pool().unwrap().check_invariants().unwrap();

        // Dense mode: the slot position alone advances and rewinds.
        let mut kv = PagedKvSlots::dense(1, 8);
        let (s, _) = kv.alloc(2, &[1, 2]).unwrap();
        assert_eq!(kv.extend_chunk(s, &[3, 4, 5]).unwrap(), 5);
        let err = kv.extend_chunk(s, &[6, 7, 8, 9]).unwrap_err();
        assert_eq!(err, KvError::MaxSeq { pos: 7, max_seq: 8 });
        assert_eq!(kv.pos(s).unwrap(), 5, "dense rollback");
    }

    #[test]
    fn probe_and_snapshot_reflect_pool_warmth() {
        let mut kv = PagedKvSlots::paged(2, 64, small_cfg());
        let sys: Vec<i32> = (0..8).collect();
        let mut prompt = sys.clone();
        prompt.extend([42, 43]);
        kv.alloc(1, &prompt).unwrap();
        assert_eq!(kv.probe_prefix(&sys), 2);
        // The churn stamp moves with pool activity (publish skip key).
        let stamp = kv.churn_stamp().unwrap();
        assert!(stamp > 0);
        kv.advance(0, 99).unwrap(); // within the partial page: no churn
        assert_eq!(kv.churn_stamp().unwrap(), stamp);
        let cell = crate::routing::ReplicaCell::new();
        kv.publish_routing_snapshot(&cell);
        assert_eq!(cell.probe(&sys), 2, "snapshot mirrors the pool");
        let (version, ..) = cell.counters();
        assert_eq!(version, 1);
        // Dense mode: no pool, probe 0, nothing published.
        let dense = PagedKvSlots::dense(2, 64);
        assert_eq!(dense.probe_prefix(&sys), 0);
        let cell2 = crate::routing::ReplicaCell::new();
        dense.publish_routing_snapshot(&cell2);
        assert_eq!(cell2.counters().0, 0, "dense never publishes");
    }

    /// Tentpole: the slot view over a *sharded* pool — pages span
    /// arenas, the published snapshot carries per-shard buckets and
    /// the occupancy gauge, and targeted preemption frees the grower's
    /// arena. Chunked appends roll back across shards too.
    #[test]
    fn sharded_paged_slots_publish_and_preempt_per_shard() {
        let cfg = KvPoolConfig { page_size: 4, total_pages: 8, shards: 2 };
        let mut kv = PagedKvSlots::paged(2, 64, cfg);
        assert_eq!(kv.pool().unwrap().shards(), 2);
        // Request 1 fills shard 0, request 2 fills shard 1 (4-page
        // arenas each): the pool is completely full.
        let (s1, _) = kv.alloc(1, &[1; 13]).unwrap();
        let (s2, _) = kv.alloc(2, &[2; 13]).unwrap();
        let views = kv.shard_views();
        assert_eq!(views.len(), 2);
        assert_eq!(views[0].live_pages, 4);
        assert_eq!(views[1].live_pages, 4);
        assert_eq!(kv.growth_shard(1), Some(0));
        assert_eq!(kv.growth_shard(2), Some(1));
        // The published snapshot buckets hashes per shard and carries
        // the occupancy gauge.
        let cell = crate::routing::ReplicaCell::new();
        kv.publish_routing_snapshot(&cell);
        assert_eq!(cell.shard_occupancy(), vec![4, 4]);
        let (blocks, spread) = kv.probe_prefix_shards(&[1; 12]);
        assert_eq!((blocks, spread), (3, 1), "request 1's blocks, shard 0");
        // Request 1 outgrew the (full) pool: a preempt targeted at its
        // growth shard must evict *it* — the only shard-0 holder —
        // where the global latest-first rule would pick request 2.
        for t in 0..3 {
            kv.advance(s1, t).unwrap(); // fills the partial page
        }
        let err = kv.advance(s1, 99).unwrap_err();
        assert!(matches!(err, KvError::CapacityExhausted { .. }), "{err}");
        let prefer = kv.growth_shard(1);
        assert_eq!(prefer, Some(0));
        let (slot, pre) =
            kv.preempt_targeted(PreemptMode::Recompute, prefer).unwrap();
        assert_eq!(slot, s1);
        assert_eq!(pre.request, 1);
        assert_eq!(kv.live_count(), 1);
        assert_eq!(kv.slot_of(2), Some(s2));
        kv.pool().unwrap().check_invariants().unwrap();
        // Chunked append on the survivor: shard 1 is dry, so growth
        // spills into the shard-0 capacity the eviction freed (cached
        // victim blocks are LRU-evicted page by page).
        let pos = kv.extend_chunk(s2, &[3; 14]).unwrap();
        assert_eq!(pos, 27);
        assert!(kv.pool().unwrap().stats.shard_spills > 0,
                "growth crossed an arena boundary");
        kv.pool().unwrap().check_invariants().unwrap();
    }

    #[test]
    fn dense_mode_matches_seed_semantics() {
        let mut kv = PagedKvSlots::dense(2, 8);
        assert!(!kv.is_paged());
        let (s, out) = kv.alloc(1, &[1, 2, 3]).unwrap();
        assert_eq!(out.shared_tokens, 0);
        for t in 0..4 {
            kv.advance(s, t).unwrap();
        }
        assert_eq!(kv.advance(s, 9).unwrap_err(),
                   KvError::MaxSeq { pos: 7, max_seq: 8 });
        let view = kv.capacity_view();
        assert_eq!(view.pages, None);
        assert_eq!(view.free_slots, 1);
        assert!(kv.preempt(PreemptMode::Recompute).is_none());
        kv.release(s).unwrap();
    }

    #[test]
    fn paged_default_budget_is_dense_equivalent() {
        let cfg = KvPoolConfig { page_size: DEFAULT_PAGE_SIZE,
                                 total_pages: 0, shards: 1 };
        let kv = PagedKvSlots::paged(4, 512, cfg);
        let pool = kv.pool().unwrap();
        assert_eq!(pool.total_pages(), 4 * 512 / DEFAULT_PAGE_SIZE);
    }

    /// Priced fabric at the slot layer: `preempt_auto` swaps the
    /// cheapest victim out (slot freed in lockstep), the host buffer
    /// holds it, and `resume_swapped` brings it back into a fresh slot
    /// with its fill position intact. Dense mode prices nothing.
    #[test]
    fn fabric_swap_round_trip_keeps_views_in_lockstep() {
        let cfg = KvPoolConfig { page_size: 4, total_pages: 4, shards: 1 };
        let mut kv = PagedKvSlots::paged(2, 64, cfg);
        kv.set_fabric(FabricSpec::paper(524_288.0));
        assert!(kv.fabric().is_some());
        let (s1, _) = kv.alloc(1, &[1, 2, 3, 4, 5]).unwrap();
        let (s2, _) = kv.alloc(2, &[9, 8, 7, 6, 5]).unwrap();
        for t in 0..3 {
            kv.advance(s1, t).unwrap();
        }
        let err = kv.advance(s1, 99).unwrap_err();
        assert!(matches!(err, KvError::CapacityExhausted { .. }), "{err}");
        // Request 2 (5 tokens) is the cheaper eviction than request 1
        // (8): at 7B geometry its swap round-trip beats recompute.
        let (slot, pre) = kv.preempt_auto(None).unwrap();
        assert_eq!(slot, s2);
        assert_eq!(pre.request, 2);
        assert_eq!(pre.mode, PreemptMode::SwapOut);
        assert!(kv.has_swapped(2));
        assert_eq!(kv.swapped_tokens(2), Some(5));
        assert_eq!(kv.live_count(), 1);
        kv.advance(s1, 99).unwrap();
        // No room yet: the resume fails cleanly, the buffer stays.
        assert!(matches!(kv.resume_swapped(2),
                         Err(KvError::CapacityExhausted { .. })));
        assert!(kv.has_swapped(2));
        kv.release(s1).unwrap();
        let (slot2, _) = kv.resume_swapped(2).unwrap();
        assert_eq!(kv.pos(slot2).unwrap(), 5);
        assert!(!kv.has_swapped(2));
        assert!(kv.pool().unwrap().host_buffers().is_empty());
        kv.pool().unwrap().check_invariants().unwrap();
        // Discard + drain paths: stage another swap, then abandon it.
        kv.advance(slot2, 1).unwrap();
        let (_, pre) = kv.preempt_auto(None).unwrap();
        assert_eq!(pre.mode, PreemptMode::SwapOut);
        let (tokens, prompt_len) = kv.discard_swapped(2).unwrap();
        assert_eq!(tokens.len(), 6);
        assert_eq!(prompt_len, 5);
        assert_eq!(kv.drain_host_buffers(), 0, "nothing left staged");
        kv.pool().unwrap().check_invariants().unwrap();
        // Dense mode: no fabric, no swap machinery.
        let mut dense = PagedKvSlots::dense(2, 8);
        dense.set_fabric(FabricSpec::paper(1.0));
        assert!(dense.fabric().is_none());
        assert!(dense.preempt_auto(None).is_none());
        assert!(!dense.has_swapped(1));
        assert_eq!(dense.drain_host_buffers(), 0);
    }

    /// Beam forks live at the pool layer: a hypothesis shares its
    /// root's pages without claiming a graph slot, and pruning it
    /// leaves the slot view untouched.
    #[test]
    fn fork_and_discard_are_pool_only() {
        let mut kv = PagedKvSlots::paged(2, 64, small_cfg());
        let (slot, _) = kv.alloc(1, &[1, 2, 3, 4, 5]).unwrap();
        assert_eq!(kv.fork(1, 100).unwrap(), 2, "shares both pages");
        assert_eq!(kv.live_count(), 1, "no slot claimed");
        assert_eq!(kv.pool().unwrap().live_seqs(), 2);
        kv.release_discard(100).unwrap();
        assert_eq!(kv.pool().unwrap().live_seqs(), 1);
        assert_eq!(kv.pos(slot).unwrap(), 5, "root untouched");
        kv.pool().unwrap().check_invariants().unwrap();
        // Dense mode has no pages to fork.
        let mut dense = PagedKvSlots::dense(1, 8);
        dense.alloc(1, &[1, 2]).unwrap();
        assert!(dense.fork(1, 2).is_err());
        assert!(dense.release_discard(1).is_err());
    }
}
