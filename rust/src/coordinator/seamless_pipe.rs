//! Seamless four-module pipeline with beam search (paper §2.1.3, Obs #4).
//!
//! S-T / S-S: speech features → conformer encoder → cross-KV → AR text
//! decoder with beam search → (speech tasks) NAR T2U → vocoder.
//! T-T / T-S: text → text encoder → same tail.
//!
//! Beam-search KV reorder is the paper's Seamless bottleneck (Obs #4);
//! both disciplines are implemented:
//! * `ReorderMode::HostCopy` — the baseline `index_select`-style copy:
//!   download the whole self-KV, gather on host, upload (new memory each
//!   step, exactly the pattern the paper calls out).
//! * `ReorderMode::Fused` — the `torch.compile`d fix: a device-side
//!   gather stage, buffers swapped in place.

use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::PjRtBuffer;

use crate::models::tokenizer::{SpeechFeaturizer, TextTokenizer, BOS, EOS};
use crate::runtime::engine::{Arg, Engine};
use crate::runtime::tensor::{DType, Tensor};
use crate::substrate::metrics::OpTimes;
use crate::telemetry::tracer::Cat;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReorderMode {
    /// Baseline: host-side gather copy of the self-KV each step.
    HostCopy,
    /// Optimized: on-device gather stage (compile'd copy_).
    Fused,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeamlessTask {
    SpeechToText,
    SpeechToSpeech,
    TextToText,
    TextToSpeech,
}

impl SeamlessTask {
    pub fn speech_in(self) -> bool {
        matches!(self, SeamlessTask::SpeechToText | SeamlessTask::SpeechToSpeech)
    }
    pub fn speech_out(self) -> bool {
        matches!(self, SeamlessTask::SpeechToSpeech | SeamlessTask::TextToSpeech)
    }
}

/// Pipeline configuration read from the manifest.
#[derive(Debug, Clone, Copy)]
pub struct SeamlessDims {
    pub d_model: usize,
    pub dec_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub max_tgt: usize,
    pub beam: usize,
    pub text_vocab: usize,
    pub enc_subsample: usize,
    pub t2u_upsample: usize,
    pub voc_rate: usize,
}

impl SeamlessDims {
    pub fn from_engine(e: &Engine) -> Result<Self> {
        let m = &e.manifest;
        let voc_rate = {
            let up = m.cfg_usize("voc_upsample")?;
            let st = m.cfg_usize("voc_stages")?;
            up.pow(st as u32)
        };
        Ok(SeamlessDims {
            d_model: m.cfg_usize("d_model")?,
            dec_layers: m.cfg_usize("dec_layers")?,
            n_heads: m.cfg_usize("n_heads")?,
            head_dim: m.cfg_usize("head_dim")?,
            max_tgt: m.cfg_usize("max_tgt")?,
            beam: m.cfg_usize("beam_size")?,
            text_vocab: m.cfg_usize("text_vocab")?,
            enc_subsample: m.cfg_usize("enc_subsample")?,
            t2u_upsample: m.cfg_usize("t2u_upsample")?,
            voc_rate,
        })
    }

    pub fn self_kv_shape(&self, beams: usize) -> Vec<usize> {
        vec![self.dec_layers, beams, self.n_heads, self.max_tgt,
             self.head_dim]
    }
}

/// Result of a pipeline run with per-module timings (Fig 7's ladder).
#[derive(Debug)]
pub struct PipelineResult {
    pub text_tokens: Vec<i32>,
    pub text: String,
    pub units: Vec<i32>,
    pub waveform: Vec<f32>,
    pub decode_steps: usize,
    pub times: OpTimes,
    pub e2e: f64,
}

pub struct SeamlessPipeline<'e> {
    pub engine: &'e Engine,
    pub dims: SeamlessDims,
    pub reorder: ReorderMode,
    /// Beam length-penalty exponent (GNMT-style).
    pub len_penalty: f32,
}

impl<'e> SeamlessPipeline<'e> {
    pub fn new(engine: &'e Engine, reorder: ReorderMode) -> Result<Self> {
        let dims = SeamlessDims::from_engine(engine)?;
        Ok(SeamlessPipeline { engine, dims, reorder, len_penalty: 1.0 })
    }

    /// Encoder bucket (speech frames) for an input of `n` frames.
    fn enc_bucket(&self, frames: usize) -> Result<usize> {
        let mut buckets: Vec<usize> = self
            .engine
            .manifest
            .stages_of_kind("encoder")
            .iter()
            .filter_map(|s| s.meta_usize("bucket"))
            .collect();
        buckets.sort();
        buckets
            .iter()
            .find(|&&b| b >= frames)
            .or(buckets.last())
            .copied()
            .context("no encoder buckets")
    }

    /// Run the full pipeline on a speech waveform or text input.
    pub fn run(&self, task: SeamlessTask, speech: Option<&[f32]>,
               text: Option<&str>, max_text: usize) -> Result<PipelineResult> {
        let t0 = Instant::now();
        let mut times = OpTimes::new();

        // ---- encoder ----------------------------------------------------
        let (enc_out, enc_len_buf, src_len) = if task.speech_in() {
            let wav = speech.context("speech input required")?;
            let sf = SpeechFeaturizer::default();
            let frames = (wav.len() / sf.frame).max(1);
            let bucket = self.enc_bucket(frames)?;
            let (feats, n) = {
                let _t = self.engine.tracer()
                    .map(|t| t.span(Cat::Tokenize, "featurize"));
                sf.featurize(wav, bucket)
            };
            let t = Instant::now();
            let stage = self.engine.stage(&format!("encoder_t{bucket}"))?;
            let t_len = Tensor::from_i32(&[1], &[n as i32]);
            let outs = self
                .engine
                .run(&stage, &[Arg::Host(&feats), Arg::Host(&t_len)])?;
            times.add("SpeechEncoder", t.elapsed().as_secs_f64());
            let mut it = outs.into_iter();
            (
                it.next().context("enc_out")?,
                it.next().context("enc_len")?,
                bucket / self.dims.enc_subsample,
            )
        } else {
            let txt = text.context("text input required")?;
            let tk = TextTokenizer::new();
            let ids = {
                let _t = self.engine.tracer()
                    .map(|t| t.span(Cat::Tokenize, "tokenize"));
                tk.encode(txt)
            };
            let mut buckets: Vec<usize> = self
                .engine
                .manifest
                .stages_of_kind("text_encoder")
                .iter()
                .filter_map(|s| s.meta_usize("bucket"))
                .collect();
            buckets.sort();
            let bucket = *buckets
                .iter()
                .find(|&&b| b >= ids.len())
                .or(buckets.last())
                .context("no text_encoder buckets")?;
            let n = ids.len().min(bucket);
            let mut toks = vec![0i32; bucket];
            toks[..n].copy_from_slice(&ids[..n]);
            let t = Instant::now();
            let stage =
                self.engine.stage(&format!("text_encoder_t{bucket}"))?;
            let t_toks = Tensor::from_i32(&[1, bucket], &toks);
            let t_len = Tensor::from_i32(&[1], &[n as i32]);
            let outs = self
                .engine
                .run(&stage, &[Arg::Host(&t_toks), Arg::Host(&t_len)])?;
            times.add("TextEncoder", t.elapsed().as_secs_f64());
            let mut it = outs.into_iter();
            (
                it.next().context("enc_out")?,
                it.next().context("enc_len")?,
                bucket,
            )
        };

        // ---- cross-KV (once per request) ---------------------------------
        let t = Instant::now();
        let ckv_stage = self.engine.stage(&format!("cross_kv_s{src_len}"))?;
        let outs = self.engine.run(&ckv_stage, &[Arg::Dev(&enc_out)])?;
        let mut it = outs.into_iter();
        let cross_k = it.next().context("cross_k")?;
        let cross_v = it.next().context("cross_v")?;
        times.add("CrossKV", t.elapsed().as_secs_f64());

        // ---- beam-search text decoding ------------------------------------
        let (text_tokens, steps) = self.beam_decode(
            src_len, &cross_k, &cross_v, &enc_len_buf, max_text, &mut times,
        )?;
        let tk = TextTokenizer::new();
        let text_out = tk.decode(&text_tokens);

        // ---- speech tail ---------------------------------------------------
        let (units, waveform) = if task.speech_out() {
            let units = self.t2u(&text_tokens, &mut times)?;
            let wav = self.vocode(&units, &mut times)?;
            (units, wav)
        } else {
            (vec![], vec![])
        };

        Ok(PipelineResult {
            text_tokens,
            text: text_out,
            units,
            waveform,
            decode_steps: steps,
            times,
            e2e: t0.elapsed().as_secs_f64(),
        })
    }

    /// Beam search over the AR text decoder.
    fn beam_decode(&self, src_len: usize, cross_k: &PjRtBuffer,
                   cross_v: &PjRtBuffer, enc_len: &PjRtBuffer,
                   max_text: usize, times: &mut OpTimes)
                   -> Result<(Vec<i32>, usize)> {
        let bm = self.dims.beam;
        let dec_stage = self
            .engine
            .stage(&format!("dec_step_b{bm}_s{src_len}"))?;
        let reorder_stage = self.engine.stage(&format!("kv_reorder_b{bm}"))?;

        let kv_shape = self.dims.self_kv_shape(bm);
        let zero = Tensor::zeros(DType::F32, &kv_shape);
        let mut ck = self.engine.upload(&zero)?;
        let mut cv = self.engine.upload(&zero)?;

        // Beam state on host.
        let mut tokens = vec![BOS; bm];
        let mut seqs: Vec<Vec<i32>> = vec![vec![]; bm];
        let mut scores = vec![f32::NEG_INFINITY; bm];
        scores[0] = 0.0; // only beam 0 live initially
        let mut finished: Vec<(Vec<i32>, f32)> = Vec::new();
        let mut steps = 0usize;

        let tele = self.engine.tracer();
        let _tick_scope = tele.map(|t| t.tick_scope());
        for pos in 0..max_text.min(self.dims.max_tgt - 1) {
            if let Some(t) = tele {
                t.next_tick();
            }
            let _step_span = tele.map(|t| t.span(Cat::Decode, "beam_step"));
            // one batched decode step over the beams
            let t = Instant::now();
            let t_toks = Tensor::from_i32(&[bm], &tokens);
            let t_pos = Tensor::from_i32(&[bm], &vec![pos as i32; bm]);
            let outs = self.engine.run(
                &dec_stage,
                &[Arg::Host(&t_toks), Arg::Host(&t_pos), Arg::Dev(&ck),
                  Arg::Dev(&cv), Arg::Dev(cross_k), Arg::Dev(cross_v),
                  Arg::Dev(enc_len)],
            )?;
            let mut it = outs.into_iter();
            let logits_buf = it.next().context("logits")?;
            ck = it.next().context("self_ck")?;
            cv = it.next().context("self_cv")?;
            times.add("TextDecoder", t.elapsed().as_secs_f64());
            steps += 1;

            let logits = self.engine.download(&logits_buf)?.as_f32()?;
            let v = self.dims.text_vocab;

            // expand: per live beam, top candidates by logprob
            let beam_span = tele.map(|t| t.span(Cat::Sample, "beam_expand"));
            let mut cands: Vec<(f32, usize, i32)> = Vec::new();
            for b in 0..bm {
                if scores[b] == f32::NEG_INFINITY {
                    continue;
                }
                let lp = log_softmax(&logits[b * v..(b + 1) * v]);
                for (tok, &l) in top_n(&lp, bm + 1) {
                    cands.push((scores[b] + l, b, tok as i32));
                }
            }
            cands.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

            let mut new_scores = vec![f32::NEG_INFINITY; bm];
            let mut new_tokens = vec![EOS; bm];
            let mut beam_idx = vec![0i32; bm];
            let mut new_seqs: Vec<Vec<i32>> = vec![vec![]; bm];
            let mut filled = 0usize;
            for (score, src, tok) in cands {
                if filled == bm {
                    break;
                }
                if tok == EOS {
                    let seq = seqs[src].clone();
                    let norm = score
                        / ((seq.len() + 1) as f32).powf(self.len_penalty);
                    finished.push((seq, norm));
                    continue;
                }
                new_scores[filled] = score;
                new_tokens[filled] = tok;
                beam_idx[filled] = src as i32;
                let mut s = seqs[src].clone();
                s.push(tok);
                new_seqs[filled] = s;
                filled += 1;
            }
            if filled == 0 {
                break; // all beams finished
            }
            drop(beam_span);

            // ---- KV reorder (the Obs #4 operation) ------------------
            let t = Instant::now();
            match self.reorder {
                ReorderMode::Fused => {
                    let t_idx = Tensor::from_i32(&[bm], &beam_idx);
                    let outs = self.engine.run(
                        &reorder_stage,
                        &[Arg::Dev(&ck), Arg::Dev(&cv), Arg::Host(&t_idx)],
                    )?;
                    let mut it = outs.into_iter();
                    ck = it.next().context("ck")?;
                    cv = it.next().context("cv")?;
                }
                ReorderMode::HostCopy => {
                    // Baseline: full round-trip + host gather — the
                    // `index_select` allocation pattern.
                    let hk = self.engine.download(&ck)?;
                    let hv = self.engine.download(&cv)?;
                    let gk = gather_beams(&hk, &beam_idx)?;
                    let gv = gather_beams(&hv, &beam_idx)?;
                    ck = self.engine.upload(&gk)?;
                    cv = self.engine.upload(&gv)?;
                }
            }
            times.add("KV_Cache_Reorder", t.elapsed().as_secs_f64());

            scores = new_scores;
            tokens = new_tokens;
            seqs = new_seqs;
        }
        drop(_tick_scope);

        // pick best finished (or best live) sequence
        for b in 0..bm {
            if scores[b] > f32::NEG_INFINITY {
                let norm = scores[b]
                    / (seqs[b].len().max(1) as f32).powf(self.len_penalty);
                finished.push((seqs[b].clone(), norm));
            }
        }
        finished.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let best = finished.into_iter().next().map(|(s, _)| s)
            .unwrap_or_default();
        Ok((best, steps))
    }

    /// NAR text-to-unit.
    fn t2u(&self, text_tokens: &[i32], times: &mut OpTimes)
           -> Result<Vec<i32>> {
        let mut buckets: Vec<usize> = self
            .engine
            .manifest
            .stages_of_kind("t2u")
            .iter()
            .filter_map(|s| s.meta_usize("bucket"))
            .collect();
        buckets.sort();
        if buckets.is_empty() {
            bail!("no t2u stages");
        }
        let n = text_tokens.len().max(1);
        let bucket = *buckets.iter().find(|&&b| b >= n)
            .unwrap_or(buckets.last().unwrap());
        let n = n.min(bucket);
        let mut toks = vec![0i32; bucket];
        toks[..n].copy_from_slice(&text_tokens[..n]);
        let t = Instant::now();
        let stage = self.engine.stage(&format!("t2u_t{bucket}"))?;
        let t_toks = Tensor::from_i32(&[1, bucket], &toks);
        let t_len = Tensor::from_i32(&[1], &[n as i32]);
        let outs = self
            .engine
            .run(&stage, &[Arg::Host(&t_toks), Arg::Host(&t_len)])?;
        let mut it = outs.into_iter();
        let logits = self.engine.download(&it.next().context("t2u")?)?;
        times.add("T2U", t.elapsed().as_secs_f64());
        let l = logits.as_f32()?;
        let uv = self.engine.manifest.cfg_usize("unit_vocab")?;
        let n_units = n * self.dims.t2u_upsample;
        let mut units = Vec::with_capacity(n_units);
        for u in 0..n_units {
            units.push(crate::coordinator::sampling::greedy(
                &l[u * uv..(u + 1) * uv]));
        }
        Ok(units)
    }

    /// HiFi-GAN-style vocoder.
    fn vocode(&self, units: &[i32], times: &mut OpTimes) -> Result<Vec<f32>> {
        let mut buckets: Vec<usize> = self
            .engine
            .manifest
            .stages_of_kind("vocoder")
            .iter()
            .filter_map(|s| s.meta_usize("bucket"))
            .collect();
        buckets.sort();
        if buckets.is_empty() {
            bail!("no vocoder stages");
        }
        let n = units.len().max(1);
        let bucket = *buckets.iter().find(|&&b| b >= n)
            .unwrap_or(buckets.last().unwrap());
        let n = n.min(bucket);
        let mut u = vec![0i32; bucket];
        u[..n].copy_from_slice(&units[..n]);
        let t = Instant::now();
        let stage = self.engine.stage(&format!("vocoder_u{bucket}"))?;
        let t_units = Tensor::from_i32(&[1, bucket], &u);
        let outs = self.engine.run(&stage, &[Arg::Host(&t_units)])?;
        let wav = self.engine.download(&outs[0])?.as_f32()?;
        times.add("Vocoder", t.elapsed().as_secs_f64());
        Ok(wav[..n * self.dims.voc_rate].to_vec())
    }
}

fn log_softmax(logits: &[f32]) -> Vec<f32> {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let z: f32 = logits.iter().map(|&x| (x - m).exp()).sum();
    let lz = z.ln() + m;
    logits.iter().map(|&x| x - lz).collect()
}

/// Top-n (index, value) pairs by value, descending.
fn top_n(xs: &[f32], n: usize) -> Vec<(usize, &f32)> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap());
    idx.into_iter().take(n).map(|i| (i, &xs[i])).collect()
}

/// Host-side beam gather of a [L, B, H, S, Dh] tensor along axis 1.
fn gather_beams(t: &Tensor, beam_idx: &[i32]) -> Result<Tensor> {
    let l = t.shape[0];
    let b = t.shape[1];
    let inner: usize = t.shape[2..].iter().product();
    let row = inner * 4; // f32 bytes per (l, b)
    let mut out = vec![0u8; t.data.len()];
    for li in 0..l {
        for (bi, &src) in beam_idx.iter().enumerate() {
            let s = (li * b + src as usize) * row;
            let d = (li * b + bi) * row;
            out[d..d + row].copy_from_slice(&t.data[s..s + row]);
        }
    }
    Tensor::new(t.dtype, t.shape.clone(), out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_softmax_normalizes() {
        let lp = log_softmax(&[1.0, 2.0, 3.0]);
        let z: f32 = lp.iter().map(|x| x.exp()).sum();
        assert!((z - 1.0).abs() < 1e-5);
    }

    #[test]
    fn top_n_ordering() {
        let xs = [0.1f32, 5.0, 3.0, 4.0];
        let t = top_n(&xs, 2);
        assert_eq!(t[0].0, 1);
        assert_eq!(t[1].0, 3);
    }

    #[test]
    fn gather_beams_permutes() {
        // L=1, B=2, inner=2
        let t = Tensor::from_f32(&[1, 2, 2], &[1., 2., 3., 4.]);
        let g = gather_beams(&t, &[1, 0]).unwrap();
        assert_eq!(g.as_f32().unwrap(), vec![3., 4., 1., 2.]);
    }

    #[test]
    fn task_modality_flags() {
        assert!(SeamlessTask::SpeechToSpeech.speech_in());
        assert!(SeamlessTask::SpeechToSpeech.speech_out());
        assert!(!SeamlessTask::TextToText.speech_out());
        assert!(SeamlessTask::TextToSpeech.speech_out());
        assert!(!SeamlessTask::TextToSpeech.speech_in());
    }
}
