//! Seamless four-module pipeline with beam search (paper §2.1.3, Obs #4).
//!
//! S-T / S-S: speech features → conformer encoder → cross-KV → AR text
//! decoder with beam search → (speech tasks) NAR T2U → vocoder.
//! T-T / T-S: text → text encoder → same tail.
//!
//! Beam-search KV reorder is the paper's Seamless bottleneck (Obs #4);
//! both disciplines are implemented:
//! * `ReorderMode::HostCopy` — the baseline `index_select`-style copy:
//!   download the whole self-KV, gather on host, upload (new memory each
//!   step, exactly the pattern the paper calls out).
//! * `ReorderMode::Fused` — the `torch.compile`d fix: a device-side
//!   gather stage, buffers swapped in place.
//!
//! The decoder half runs on the unified serving core: the AR text
//! decoder is a [`SeamlessExecutor`] (a
//! [`StepExecutor`](crate::sched::StepExecutor)) driven by
//! [`generate_beam`] — each hypothesis is a kvpool block table, a beam
//! reorder is fork + prune in pages, and the executor only performs
//! the per-step device gather through its `reorder_slots` hook. All
//! per-module timing flows through [`timed`] telemetry spans, so the
//! pipeline appears in `mmserve trace` with idle attribution like
//! every other path.

use anyhow::{bail, Context, Result};
use xla::PjRtBuffer;

use crate::models::tokenizer::{SpeechFeaturizer, TextTokenizer, BOS, EOS};
use crate::runtime::engine::{Arg, Engine, StageHandle};
use crate::runtime::tensor::{DType, Tensor};
use crate::sched::{generate_beam, BeamConfig, ExecDims, SlotFeed,
                   StepExecutor};
use crate::substrate::metrics::OpTimes;
use crate::telemetry::tracer::{timed, Cat};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReorderMode {
    /// Baseline: host-side gather copy of the self-KV each step.
    HostCopy,
    /// Optimized: on-device gather stage (compile'd copy_).
    Fused,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeamlessTask {
    SpeechToText,
    SpeechToSpeech,
    TextToText,
    TextToSpeech,
}

impl SeamlessTask {
    pub fn speech_in(self) -> bool {
        matches!(self, SeamlessTask::SpeechToText | SeamlessTask::SpeechToSpeech)
    }
    pub fn speech_out(self) -> bool {
        matches!(self, SeamlessTask::SpeechToSpeech | SeamlessTask::TextToSpeech)
    }
}

/// Pipeline configuration read from the manifest.
#[derive(Debug, Clone, Copy)]
pub struct SeamlessDims {
    pub d_model: usize,
    pub dec_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub max_tgt: usize,
    pub beam: usize,
    pub text_vocab: usize,
    pub enc_subsample: usize,
    pub t2u_upsample: usize,
    pub voc_rate: usize,
}

impl SeamlessDims {
    pub fn from_engine(e: &Engine) -> Result<Self> {
        let m = &e.manifest;
        let voc_rate = {
            let up = m.cfg_usize("voc_upsample")?;
            let st = m.cfg_usize("voc_stages")?;
            up.pow(st as u32)
        };
        Ok(SeamlessDims {
            d_model: m.cfg_usize("d_model")?,
            dec_layers: m.cfg_usize("dec_layers")?,
            n_heads: m.cfg_usize("n_heads")?,
            head_dim: m.cfg_usize("head_dim")?,
            max_tgt: m.cfg_usize("max_tgt")?,
            beam: m.cfg_usize("beam_size")?,
            text_vocab: m.cfg_usize("text_vocab")?,
            enc_subsample: m.cfg_usize("enc_subsample")?,
            t2u_upsample: m.cfg_usize("t2u_upsample")?,
            voc_rate,
        })
    }

    pub fn self_kv_shape(&self, beams: usize) -> Vec<usize> {
        vec![self.dec_layers, beams, self.n_heads, self.max_tgt,
             self.head_dim]
    }
}

/// Result of a pipeline run with per-module timings (Fig 7's ladder).
#[derive(Debug)]
pub struct PipelineResult {
    pub text_tokens: Vec<i32>,
    pub text: String,
    pub units: Vec<i32>,
    pub waveform: Vec<f32>,
    pub decode_steps: usize,
    pub times: OpTimes,
    pub e2e: f64,
}

pub struct SeamlessPipeline<'e> {
    pub engine: &'e Engine,
    pub dims: SeamlessDims,
    pub reorder: ReorderMode,
    /// Beam length-penalty exponent (GNMT-style).
    pub len_penalty: f32,
}

impl<'e> SeamlessPipeline<'e> {
    pub fn new(engine: &'e Engine, reorder: ReorderMode) -> Result<Self> {
        let dims = SeamlessDims::from_engine(engine)?;
        Ok(SeamlessPipeline { engine, dims, reorder, len_penalty: 1.0 })
    }

    /// Encoder bucket (speech frames) for an input of `n` frames.
    fn enc_bucket(&self, frames: usize) -> Result<usize> {
        let mut buckets: Vec<usize> = self
            .engine
            .manifest
            .stages_of_kind("encoder")
            .iter()
            .filter_map(|s| s.meta_usize("bucket"))
            .collect();
        buckets.sort();
        buckets
            .iter()
            .find(|&&b| b >= frames)
            .or(buckets.last())
            .copied()
            .context("no encoder buckets")
    }

    /// Run the full pipeline on a speech waveform or text input.
    /// End-to-end time is measured by the wrapping telemetry span, so
    /// the whole request shows up in `mmserve trace`.
    pub fn run(&self, task: SeamlessTask, speech: Option<&[f32]>,
               text: Option<&str>, max_text: usize) -> Result<PipelineResult> {
        let tele = self.engine.tracer();
        let (res, e2e) = timed(tele, Cat::Other, "seamless_pipeline", || {
            self.run_inner(task, speech, text, max_text)
        });
        let mut r = res?;
        r.e2e = e2e;
        Ok(r)
    }

    fn run_inner(&self, task: SeamlessTask, speech: Option<&[f32]>,
                 text: Option<&str>, max_text: usize)
                 -> Result<PipelineResult> {
        let mut times = OpTimes::new();
        let tele = self.engine.tracer();

        // ---- encoder ----------------------------------------------------
        let (enc_out, enc_len_buf, src_len) = if task.speech_in() {
            let wav = speech.context("speech input required")?;
            let sf = SpeechFeaturizer::default();
            let frames = (wav.len() / sf.frame).max(1);
            let bucket = self.enc_bucket(frames)?;
            let (feats, n) = {
                let _t = tele.map(|t| t.span(Cat::Tokenize, "featurize"));
                sf.featurize(wav, bucket)
            };
            let (outs, secs) = timed(tele, Cat::Other, "SpeechEncoder", || {
                let stage =
                    self.engine.stage(&format!("encoder_t{bucket}"))?;
                let t_len = Tensor::from_i32(&[1], &[n as i32]);
                self.engine
                    .run(&stage, &[Arg::Host(&feats), Arg::Host(&t_len)])
            });
            times.add("SpeechEncoder", secs);
            let mut it = outs?.into_iter();
            (
                it.next().context("enc_out")?,
                it.next().context("enc_len")?,
                bucket / self.dims.enc_subsample,
            )
        } else {
            let txt = text.context("text input required")?;
            let tk = TextTokenizer::new();
            let ids = {
                let _t = tele.map(|t| t.span(Cat::Tokenize, "tokenize"));
                tk.encode(txt)
            };
            let mut buckets: Vec<usize> = self
                .engine
                .manifest
                .stages_of_kind("text_encoder")
                .iter()
                .filter_map(|s| s.meta_usize("bucket"))
                .collect();
            buckets.sort();
            let bucket = *buckets
                .iter()
                .find(|&&b| b >= ids.len())
                .or(buckets.last())
                .context("no text_encoder buckets")?;
            let n = ids.len().min(bucket);
            let mut toks = vec![0i32; bucket];
            toks[..n].copy_from_slice(&ids[..n]);
            let (outs, secs) = timed(tele, Cat::Other, "TextEncoder", || {
                let stage =
                    self.engine.stage(&format!("text_encoder_t{bucket}"))?;
                let t_toks = Tensor::from_i32(&[1, bucket], &toks);
                let t_len = Tensor::from_i32(&[1], &[n as i32]);
                self.engine
                    .run(&stage, &[Arg::Host(&t_toks), Arg::Host(&t_len)])
            });
            times.add("TextEncoder", secs);
            let mut it = outs?.into_iter();
            (
                it.next().context("enc_out")?,
                it.next().context("enc_len")?,
                bucket,
            )
        };

        // ---- cross-KV (once per request) ---------------------------------
        let (outs, secs) = timed(tele, Cat::Other, "CrossKV", || {
            let ckv_stage =
                self.engine.stage(&format!("cross_kv_s{src_len}"))?;
            self.engine.run(&ckv_stage, &[Arg::Dev(&enc_out)])
        });
        times.add("CrossKV", secs);
        let mut it = outs?.into_iter();
        let cross_k = it.next().context("cross_k")?;
        let cross_v = it.next().context("cross_v")?;

        // ---- beam-search text decoding ------------------------------------
        let (text_tokens, steps) = self.beam_decode(
            src_len, cross_k, cross_v, enc_len_buf, max_text, &mut times,
        )?;
        let tk = TextTokenizer::new();
        let text_out = tk.decode(&text_tokens);

        // ---- speech tail ---------------------------------------------------
        let (units, waveform) = if task.speech_out() {
            let units = self.t2u(&text_tokens, &mut times)?;
            let wav = self.vocode(&units, &mut times)?;
            (units, wav)
        } else {
            (vec![], vec![])
        };

        Ok(PipelineResult {
            text_tokens,
            text: text_out,
            units,
            waveform,
            decode_steps: steps,
            times,
            e2e: 0.0, // overwritten by `run`'s wrapping span
        })
    }

    /// Beam search over the AR text decoder, run by the generic
    /// [`generate_beam`] driver: each hypothesis is a kvpool block
    /// table (a reorder is fork + prune, no KV copy), and the
    /// [`SeamlessExecutor`] below only performs the per-step device
    /// gather through its `reorder_slots` hook.
    fn beam_decode(&self, src_len: usize, cross_k: PjRtBuffer,
                   cross_v: PjRtBuffer, enc_len: PjRtBuffer,
                   max_text: usize, times: &mut OpTimes)
                   -> Result<(Vec<i32>, usize)> {
        let mut exec = SeamlessExecutor::new(self, src_len, cross_k,
                                             cross_v, enc_len)?;
        let cfg = BeamConfig {
            beams: self.dims.beam,
            max_steps: max_text,
            len_penalty: self.len_penalty,
            bos: BOS,
            eos: EOS,
        };
        let r = generate_beam(&mut exec, self.engine.tracer(), &[], &cfg)?;
        times.merge(&exec.times);
        Ok((r.tokens, r.decode_steps))
    }

    /// NAR text-to-unit.
    fn t2u(&self, text_tokens: &[i32], times: &mut OpTimes)
           -> Result<Vec<i32>> {
        let mut buckets: Vec<usize> = self
            .engine
            .manifest
            .stages_of_kind("t2u")
            .iter()
            .filter_map(|s| s.meta_usize("bucket"))
            .collect();
        buckets.sort();
        if buckets.is_empty() {
            bail!("no t2u stages");
        }
        let n = text_tokens.len().max(1);
        let bucket = *buckets.iter().find(|&&b| b >= n)
            .unwrap_or(buckets.last().unwrap());
        let n = n.min(bucket);
        let mut toks = vec![0i32; bucket];
        toks[..n].copy_from_slice(&text_tokens[..n]);
        let (logits, secs) =
            timed(self.engine.tracer(), Cat::Other, "T2U", || {
                let stage = self.engine.stage(&format!("t2u_t{bucket}"))?;
                let t_toks = Tensor::from_i32(&[1, bucket], &toks);
                let t_len = Tensor::from_i32(&[1], &[n as i32]);
                let outs = self
                    .engine
                    .run(&stage, &[Arg::Host(&t_toks), Arg::Host(&t_len)])?;
                let mut it = outs.into_iter();
                self.engine.download(&it.next().context("t2u")?)
            });
        times.add("T2U", secs);
        let l = logits?.as_f32()?;
        let uv = self.engine.manifest.cfg_usize("unit_vocab")?;
        let n_units = n * self.dims.t2u_upsample;
        let mut units = Vec::with_capacity(n_units);
        for u in 0..n_units {
            units.push(crate::coordinator::sampling::greedy(
                &l[u * uv..(u + 1) * uv]));
        }
        Ok(units)
    }

    /// HiFi-GAN-style vocoder.
    fn vocode(&self, units: &[i32], times: &mut OpTimes) -> Result<Vec<f32>> {
        let mut buckets: Vec<usize> = self
            .engine
            .manifest
            .stages_of_kind("vocoder")
            .iter()
            .filter_map(|s| s.meta_usize("bucket"))
            .collect();
        buckets.sort();
        if buckets.is_empty() {
            bail!("no vocoder stages");
        }
        let n = units.len().max(1);
        let bucket = *buckets.iter().find(|&&b| b >= n)
            .unwrap_or(buckets.last().unwrap());
        let n = n.min(bucket);
        let mut u = vec![0i32; bucket];
        u[..n].copy_from_slice(&units[..n]);
        let (wav, secs) =
            timed(self.engine.tracer(), Cat::Other, "Vocoder", || {
                let stage =
                    self.engine.stage(&format!("vocoder_u{bucket}"))?;
                let t_units = Tensor::from_i32(&[1, bucket], &u);
                let outs =
                    self.engine.run(&stage, &[Arg::Host(&t_units)])?;
                self.engine.download(&outs[0])?.as_f32()
            });
        times.add("Vocoder", secs);
        let wav = wav?;
        Ok(wav[..n * self.dims.voc_rate].to_vec())
    }
}

/// The Seamless AR text decoder as a [`StepExecutor`].
///
/// `decode_step` is one batched decode over all beams (the
/// `dec_step_b{B}_s{S}` stage), `reorder_slots` is the Obs #4 KV
/// gather in the configured [`ReorderMode`]. The executor owns the
/// dense per-slot device state — the self-KV ring plus the request's
/// cross-KV — while the paging half of beam search (hypothesis fork /
/// prune) lives in [`generate_beam`]'s block tables. Per-module
/// timings accumulate in `times` (the Fig. 7 ladder keys) through
/// [`timed`] spans, so the decoder also shows up in `mmserve trace`.
pub struct SeamlessExecutor<'e> {
    engine: &'e Engine,
    dims: SeamlessDims,
    reorder: ReorderMode,
    dec_stage: StageHandle,
    reorder_stage: StageHandle,
    /// Self-attention KV ring `[L, B, H, S, Dh]` (and its V half).
    ck: PjRtBuffer,
    cv: PjRtBuffer,
    cross_k: PjRtBuffer,
    cross_v: PjRtBuffer,
    enc_len: PjRtBuffer,
    /// Per-module wall time: `TextDecoder` + `KV_Cache_Reorder`.
    pub times: OpTimes,
}

impl<'e> SeamlessExecutor<'e> {
    pub fn new(pipe: &SeamlessPipeline<'e>, src_len: usize,
               cross_k: PjRtBuffer, cross_v: PjRtBuffer,
               enc_len: PjRtBuffer) -> Result<Self> {
        let dims = pipe.dims;
        let bm = dims.beam;
        let zero = Tensor::zeros(DType::F32, &dims.self_kv_shape(bm));
        Ok(SeamlessExecutor {
            engine: pipe.engine,
            dims,
            reorder: pipe.reorder,
            dec_stage: pipe
                .engine
                .stage(&format!("dec_step_b{bm}_s{src_len}"))?,
            reorder_stage: pipe
                .engine
                .stage(&format!("kv_reorder_b{bm}"))?,
            ck: pipe.engine.upload(&zero)?,
            cv: pipe.engine.upload(&zero)?,
            cross_k,
            cross_v,
            enc_len,
            times: OpTimes::new(),
        })
    }
}

impl StepExecutor for SeamlessExecutor<'_> {
    fn plan_dims(&self) -> ExecDims {
        ExecDims {
            batch: self.dims.beam,
            max_seq: self.dims.max_tgt,
            vocab: self.dims.text_vocab,
        }
    }

    fn step_span_name(&self) -> &'static str {
        "beam_step"
    }

    /// The decoder has no prompt side — encoder and cross-KV run
    /// before the executor is built — so prefill is a no-op.
    fn prefill_chunk(&mut self, _slot: usize, _tokens: &[i32],
                     _start: usize, _is_last: bool)
                     -> Result<Option<Vec<f32>>> {
        Ok(None)
    }

    fn decode_step(&mut self, feeds: &[SlotFeed]) -> Result<Vec<f32>> {
        let bm = self.dims.beam;
        let tokens: Vec<i32> = feeds.iter().map(|f| f.token).collect();
        let pos = feeds.first().map(|f| f.pos as i32).unwrap_or(0);
        let tele = self.engine.tracer();
        let (outs, secs) = timed(tele, Cat::Other, "TextDecoder", || {
            let t_toks = Tensor::from_i32(&[bm], &tokens);
            let t_pos = Tensor::from_i32(&[bm], &vec![pos; bm]);
            self.engine.run(
                &self.dec_stage,
                &[Arg::Host(&t_toks), Arg::Host(&t_pos),
                  Arg::Dev(&self.ck), Arg::Dev(&self.cv),
                  Arg::Dev(&self.cross_k), Arg::Dev(&self.cross_v),
                  Arg::Dev(&self.enc_len)],
            )
        });
        self.times.add("TextDecoder", secs);
        let mut it = outs?.into_iter();
        let logits_buf = it.next().context("logits")?;
        self.ck = it.next().context("self_ck")?;
        self.cv = it.next().context("self_cv")?;
        self.engine.download(&logits_buf)?.as_f32()
    }

    /// The Obs #4 operation: gather the dense self-KV ring so new slot
    /// `b` continues hypothesis `src[b]`. Fused mode runs the compiled
    /// device gather; HostCopy reproduces the baseline
    /// download→gather→upload round trip the paper calls out.
    fn reorder_slots(&mut self, src: &[i32]) -> Result<()> {
        let bm = self.dims.beam;
        let reorder = self.reorder;
        let tele = self.engine.tracer();
        let (res, secs) = timed(
            tele,
            Cat::Other,
            "KV_Cache_Reorder",
            || -> Result<(PjRtBuffer, PjRtBuffer)> {
                match reorder {
                    ReorderMode::Fused => {
                        let t_idx = Tensor::from_i32(&[bm], src);
                        let outs = self.engine.run(
                            &self.reorder_stage,
                            &[Arg::Dev(&self.ck), Arg::Dev(&self.cv),
                              Arg::Host(&t_idx)],
                        )?;
                        let mut it = outs.into_iter();
                        Ok((it.next().context("ck")?,
                            it.next().context("cv")?))
                    }
                    ReorderMode::HostCopy => {
                        // Baseline: full round-trip + host gather —
                        // the `index_select` allocation pattern.
                        let hk = self.engine.download(&self.ck)?;
                        let hv = self.engine.download(&self.cv)?;
                        let gk = gather_beams(&hk, src)?;
                        let gv = gather_beams(&hv, src)?;
                        Ok((self.engine.upload(&gk)?,
                            self.engine.upload(&gv)?))
                    }
                }
            },
        );
        self.times.add("KV_Cache_Reorder", secs);
        let (ck, cv) = res?;
        self.ck = ck;
        self.cv = cv;
        Ok(())
    }
}

/// Host-side beam gather of a [L, B, H, S, Dh] tensor along axis 1.
fn gather_beams(t: &Tensor, beam_idx: &[i32]) -> Result<Tensor> {
    let l = t.shape[0];
    let b = t.shape[1];
    let inner: usize = t.shape[2..].iter().product();
    let row = inner * 4; // f32 bytes per (l, b)
    let mut out = vec![0u8; t.data.len()];
    for li in 0..l {
        for (bi, &src) in beam_idx.iter().enumerate() {
            let s = (li * b + src as usize) * row;
            let d = (li * b + bi) * row;
            out[d..d + row].copy_from_slice(&t.data[s..s + row]);
        }
    }
    Tensor::new(t.dtype, t.shape.clone(), out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{log_softmax, top_n};

    #[test]
    fn log_softmax_normalizes() {
        let lp = log_softmax(&[1.0, 2.0, 3.0]);
        let z: f32 = lp.iter().map(|x| x.exp()).sum();
        assert!((z - 1.0).abs() < 1e-5);
    }

    #[test]
    fn top_n_ordering() {
        let xs = [0.1f32, 5.0, 3.0, 4.0];
        let t = top_n(&xs, 2);
        assert_eq!(t[0].0, 1);
        assert_eq!(t[1].0, 3);
    }

    #[test]
    fn gather_beams_permutes() {
        // L=1, B=2, inner=2
        let t = Tensor::from_f32(&[1, 2, 2], &[1., 2., 3., 4.]);
        let g = gather_beams(&t, &[1, 0]).unwrap();
        assert_eq!(g.as_f32().unwrap(), vec![3., 4., 1., 2.]);
    }

    #[test]
    fn task_modality_flags() {
        assert!(SeamlessTask::SpeechToSpeech.speech_in());
        assert!(SeamlessTask::SpeechToSpeech.speech_out());
        assert!(!SeamlessTask::TextToText.speech_out());
        assert!(SeamlessTask::TextToSpeech.speech_out());
        assert!(!SeamlessTask::TextToSpeech.speech_in());
    }
}
