//! AutoQuant calibration (§4.2): measure the lowered quantization
//! variants on representative inputs and pick the fastest — torchao
//! AutoQuant's decision loop ported to the AOT-stage world.
//!
//! torchao decides per *layer shape*; in the tiny configs every decode
//! layer shares one shape, so the decision granularity here is per
//! (model, stage-kind): f32 vs int8 weight-only vs int8 dynamic decode
//! executables are timed head-to-head and the winner becomes the
//! serving default (DESIGN.md §Substitutions).

use std::time::Instant;

use anyhow::Result;

use crate::runtime::engine::{Arg, Engine};
use crate::runtime::tensor::{DType, Tensor};

use super::opts::QuantMode;

#[derive(Debug, Clone)]
pub struct QuantTiming {
    pub mode: QuantMode,
    pub stage: String,
    pub mean_s: f64,
}

#[derive(Debug, Clone)]
pub struct CalibrationReport {
    pub timings: Vec<QuantTiming>,
    pub chosen: QuantMode,
}

/// Time candidate decode variants (bs=1) and pick the fastest.
pub fn calibrate_decode(engine: &Engine, iters: usize)
                        -> Result<CalibrationReport> {
    let candidates = [
        (QuantMode::F32, "decode_b1"),
        (QuantMode::Int8WeightOnly, "decode_b1_int8wo"),
        (QuantMode::Int8Dynamic, "decode_b1_int8dyn"),
    ];
    let dims = super::decoder_loop::DecoderDims::from_engine(engine)?;
    let kv_shape = dims.kv_shape(1);
    let zero = Tensor::zeros(DType::F32, &kv_shape);
    let t_tok = Tensor::from_i32(&[1], &[5]);
    let t_pos = Tensor::from_i32(&[1], &[3]);

    let mut timings = Vec::new();
    for (mode, stage) in candidates {
        if !engine.has_stage(stage) {
            continue;
        }
        let h = engine.stage(stage)?;
        let mut ck = engine.upload(&zero)?;
        let mut cv = engine.upload(&zero)?;
        // warmup
        for _ in 0..2 {
            let outs = engine.run(&h, &[Arg::Host(&t_tok), Arg::Host(&t_pos),
                                        Arg::Dev(&ck), Arg::Dev(&cv)])?;
            let mut it = outs.into_iter();
            let _ = it.next();
            ck = it.next().unwrap();
            cv = it.next().unwrap();
        }
        let t0 = Instant::now();
        for _ in 0..iters {
            let outs = engine.run(&h, &[Arg::Host(&t_tok), Arg::Host(&t_pos),
                                        Arg::Dev(&ck), Arg::Dev(&cv)])?;
            let mut it = outs.into_iter();
            let _ = it.next();
            ck = it.next().unwrap();
            cv = it.next().unwrap();
        }
        timings.push(QuantTiming {
            mode,
            stage: stage.to_string(),
            mean_s: t0.elapsed().as_secs_f64() / iters.max(1) as f64,
        });
    }
    let chosen = timings
        .iter()
        .min_by(|a, b| a.mean_s.partial_cmp(&b.mean_s).unwrap())
        .map(|t| t.mode)
        .unwrap_or(QuantMode::F32);
    Ok(CalibrationReport { timings, chosen })
}
