//! Prefix-cache-aware replica routing.
//!
//! PR 2 gave each worker a paged KV pool with hash-based prefix
//! sharing; PR 3 centralized per-tick planning. Both left the biggest
//! cross-worker lever on the table: with one worker per model family,
//! the `PrefixCache` hit rate is per-worker luck. This subsystem makes
//! the `Router` replica-aware — N workers per model family
//! (`RouterConfig::replicas`) — and steers each request to the replica
//! whose cache is already warm for its prompt:
//!
//! * [`RoutingPolicy`] — the selection policies: `RoundRobin` (spray),
//!   `LeastLoaded` (shortest queue), and `PrefixAffinity` (longest
//!   cached prefix wins; ties broken by queue depth; when no replica
//!   holds any of the prompt's blocks it degrades to least-loaded).
//! * [`rank`] — the pure decision function: per-replica
//!   [`ReplicaView`]s in, a full preference *order* out. The router
//!   walks the order so a dead replica (closed channel) degrades to
//!   the next choice instead of dropping the request.
//! * [`ReplicaCell`] — the shared per-replica state the router reads
//!   without touching worker-owned engines: lock-free depth counters
//!   plus a mutex-protected [`PrefixSnapshot`] (the resident
//!   block-hash set from `KvPool::resident_hashes`, republished every
//!   scheduler tick). A sharded worker publishes the set *per device
//!   shard* plus a per-shard live-page occupancy gauge
//!   ([`ReplicaCell::publish_shards`]); the probe then scores the
//!   replica's whole shard set — warmth is the union across its
//!   arenas, and among warmth/depth ties a prefix concentrated on
//!   fewer shards wins ([`PrefixSnapshot::probe_shards`]). A stale or
//!   never-published snapshot probes as zero blocks — routing falls
//!   back to least-loaded, it never blocks and never errors.
//! * [`replay`] — the deviceless multi-worker replay that compares
//!   policies on the simulated clock (`mmserve kv --replicas N`).
//! * [`autoscale`] — the open-loop elastic-fleet replay: arrivals
//!   from `workload::arrivals` route as they occur, and an
//!   autoscaler adds replicas under sustained queue pressure and
//!   gracefully drains idle ones (`mmserve kv --arrivals ...
//!   --autoscale min:max`).
//!
//! The probe itself is `PrefixCache` chain hashes
//! ([`crate::kvpool::prefix::block_hashes`]): equal hashes imply an
//! identical token prefix, so "how many leading full blocks of this
//! prompt are resident on replica R" is a set lookup per block — no
//! tokens are shipped to workers and no worker locks are taken on the
//! submit path.

pub mod autoscale;
pub mod replay;

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};

use crate::kvpool::prefix::block_hashes;

/// How the router picks a replica for each request.
///
/// # Examples
///
/// The policy only chooses *how* [`rank`] orders the per-replica
/// views; the decision itself is a pure function:
///
/// ```
/// use mmserve::routing::{rank, ReplicaView, RoutingPolicy};
///
/// // Replica 0: cold cache, short queue. Replica 1: four cached
/// // prompt blocks, longer queue.
/// let views = [
///     ReplicaView { cached_blocks: 0, depth: 1, shard_spread: 0 },
///     ReplicaView { cached_blocks: 4, depth: 3, shard_spread: 1 },
/// ];
/// // Prefix affinity pays the deeper queue to reuse the warm cache;
/// // least-loaded ignores warmth and takes the short queue.
/// assert_eq!(rank(RoutingPolicy::PrefixAffinity, &views, 0)[0], 1);
/// assert_eq!(rank(RoutingPolicy::LeastLoaded, &views, 0)[0], 0);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutingPolicy {
    /// Rotate through replicas regardless of state.
    RoundRobin,
    /// Fewest outstanding requests (queued + in flight) wins.
    LeastLoaded,
    /// Longest cached prompt prefix wins; ties broken by queue depth,
    /// then by replica index. With zero cached blocks everywhere this
    /// is exactly least-loaded.
    #[default]
    PrefixAffinity,
}

impl RoutingPolicy {
    pub fn parse(s: &str) -> Option<RoutingPolicy> {
        match s {
            "round-robin" => Some(RoutingPolicy::RoundRobin),
            "least-loaded" => Some(RoutingPolicy::LeastLoaded),
            "prefix-affinity" => Some(RoutingPolicy::PrefixAffinity),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            RoutingPolicy::RoundRobin => "round-robin",
            RoutingPolicy::LeastLoaded => "least-loaded",
            RoutingPolicy::PrefixAffinity => "prefix-affinity",
        }
    }

    /// All policies, in comparison-table order.
    pub const ALL: [RoutingPolicy; 3] = [
        RoutingPolicy::RoundRobin,
        RoutingPolicy::LeastLoaded,
        RoutingPolicy::PrefixAffinity,
    ];
}

impl std::fmt::Display for RoutingPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// What the router knows about one replica at decision time.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReplicaView {
    /// Leading full blocks of the prompt resident in the replica's
    /// prefix cache — the union over its device shards (0 when
    /// unknown: dense pool, stale snapshot, or a non-probeable input).
    pub cached_blocks: usize,
    /// Outstanding requests: channel-queued + worker backlog.
    pub depth: usize,
    /// Distinct device shards holding the matched blocks (0 when
    /// nothing matched, 1 on a monolithic pool). Among replicas tied
    /// on warmth *and* depth, the one whose warm prefix sits on fewer
    /// devices wins — its admission reads fewer arenas.
    pub shard_spread: usize,
}

/// Full preference order over replicas for one request.
///
/// Always a permutation of `0..views.len()`, so a caller that walks it
/// trying each replica in turn is guaranteed to offer the request to
/// every live replica before giving up — requests route or fail
/// loudly, they are never silently dropped. Deterministic: ties break
/// by replica index.
pub fn rank(policy: RoutingPolicy, views: &[ReplicaView], cursor: u64)
            -> Vec<usize> {
    let n = views.len();
    let mut order: Vec<usize> = (0..n).collect();
    match policy {
        RoutingPolicy::RoundRobin => {
            if n > 0 {
                order.rotate_left((cursor % n as u64) as usize);
            }
        }
        RoutingPolicy::LeastLoaded => {
            order.sort_by_key(|&i| (views[i].depth, i));
        }
        RoutingPolicy::PrefixAffinity => {
            // Reverse(cached_blocks) ranks the warmest cache first —
            // warmth is the *shard-set* score (blocks resident across
            // the replica's arenas, union) — then queue depth, then
            // shard spread (a prefix concentrated on fewer devices
            // beats one scattered across the set), then index. With
            // all-zero probes the key degenerates to (depth, index) —
            // the least-loaded fallback; on monolithic pools spread is
            // uniform and the pre-shard ordering is unchanged.
            order.sort_by_key(|&i| {
                (std::cmp::Reverse(views[i].cached_blocks),
                 views[i].depth, views[i].shard_spread, i)
            });
        }
    }
    order
}

/// One replica's published cache-warmth view: which full-block hashes
/// its pool currently holds (live or parked), refreshed by the worker
/// each scheduler tick. Counters ride along so `mmserve trace` /
/// `mmserve kv` can label per-worker prefix-hit rows.
#[derive(Debug, Clone, Default)]
pub struct PrefixSnapshot {
    /// Tokens per KV page (0 = never published / dense pool).
    pub page_size: usize,
    /// Chain hashes of resident full blocks — the union over the
    /// worker's device shards (sharing inside one worker crosses its
    /// shards, so the union is the warmth admission actually gets).
    pub resident: HashSet<u64>,
    /// Resident hashes bucketed per device shard (length = the
    /// worker's shard count; a monolithic pool publishes one bucket).
    /// Deliberately stored alongside the aggregate `resident` set:
    /// the union answers the hot membership probe in one lookup, the
    /// buckets answer spread; a merged hash→shard map would halve the
    /// memory but is not worth it at snapshot scale (a few hundred
    /// hashes, single-digit shards).
    pub per_shard: Vec<HashSet<u64>>,
    /// Live pages per device shard at publish time — the per-shard
    /// occupancy gauge `mmserve trace` prints per worker.
    pub shard_live_pages: Vec<u64>,
    /// Publish generation (monotonic; 0 = never published).
    pub version: u64,
    /// The worker pool's prefix counters at publish time.
    pub prefix_lookups: u64,
    pub prefix_hits: u64,
    pub prefix_hit_tokens: u64,
}

impl PrefixSnapshot {
    /// Leading full blocks of `tokens` resident in this snapshot.
    /// Chain hashing means the first miss ends the shared prefix, so
    /// the walk stops there. An unpublished snapshot probes as 0.
    /// Defined as the block count of [`probe_shards`](Self::probe_shards)
    /// so the scalar and shard-set probes can never disagree.
    pub fn probe(&self, tokens: &[i32]) -> usize {
        self.probe_shards(tokens).0
    }

    /// Shard-set probe: `(leading resident blocks, distinct shards
    /// holding them)`. The block count matches [`probe`](Self::probe);
    /// the spread feeds the prefix-affinity depth tie-break (fewer
    /// devices = cheaper reuse). A legacy single-set publish reports
    /// spread 1 for any match.
    pub fn probe_shards(&self, tokens: &[i32]) -> (usize, usize) {
        if self.page_size == 0 || self.resident.is_empty() {
            return (0, 0);
        }
        let mut n = 0;
        let mut shards = HashSet::new();
        for h in block_hashes(tokens, self.page_size) {
            if !self.resident.contains(&h) {
                break;
            }
            if let Some(s) =
                self.per_shard.iter().position(|set| set.contains(&h))
            {
                shards.insert(s);
            }
            n += 1;
        }
        (n, shards.len().max(usize::from(n > 0)))
    }
}

/// Shared per-replica state cell: written by the router (dispatch
/// counters) and the worker (drain counter, backlog, snapshot), read
/// on every routing decision. Plain atomics for the depth so the
/// submit path takes no lock unless it needs a prefix probe.
#[derive(Debug, Default)]
pub struct ReplicaCell {
    /// Submitted but not yet pulled off the channel by the worker.
    queued: AtomicUsize,
    /// Worker-reported backlog (its queue + in-flight requests).
    backlog: AtomicUsize,
    /// Requests ever routed here (report counter).
    routed: AtomicU64,
    snapshot: Mutex<PrefixSnapshot>,
}

impl ReplicaCell {
    pub fn new() -> Self {
        ReplicaCell::default()
    }

    /// Router-side: a request is about to be handed to this replica's
    /// channel. Called *before* the send so the worker's matching
    /// [`note_dequeued`](Self::note_dequeued) can never land first and
    /// leave the gauge permanently inflated; a failed send must be
    /// undone with [`note_route_failed`](Self::note_route_failed).
    pub fn note_routed(&self) {
        self.routed.fetch_add(1, Ordering::Relaxed);
        self.queued.fetch_add(1, Ordering::Relaxed);
    }

    /// Router-side: the send to this replica failed (worker gone);
    /// roll back the counters [`note_routed`](Self::note_routed) took.
    pub fn note_route_failed(&self) {
        let _ = self.routed.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |r| r.checked_sub(1),
        );
        let _ = self.queued.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |q| q.checked_sub(1),
        );
    }

    /// Worker-side: a request was pulled off the channel.
    pub fn note_dequeued(&self) {
        // Saturating: a racing shutdown must never wrap the gauge.
        let _ = self.queued.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |q| q.checked_sub(1),
        );
    }

    /// Worker-side: current internal backlog (queue + in flight).
    pub fn set_backlog(&self, n: usize) {
        self.backlog.store(n, Ordering::Relaxed);
    }

    /// Outstanding requests from the router's point of view.
    pub fn depth(&self) -> usize {
        self.queued.load(Ordering::Relaxed)
            + self.backlog.load(Ordering::Relaxed)
    }

    pub fn routed(&self) -> u64 {
        self.routed.load(Ordering::Relaxed)
    }

    /// Worker-side: republish the pool's resident-hash set + counters
    /// (monolithic form — one shard bucket, no occupancy gauge).
    pub fn publish(&self, page_size: usize, resident: HashSet<u64>,
                   lookups: u64, hits: u64, hit_tokens: u64) {
        self.publish_shards(page_size, vec![resident], Vec::new(),
                            lookups, hits, hit_tokens);
    }

    /// Worker-side: republish per-shard resident hashes + per-shard
    /// live-page occupancy + counters. The union of the shard buckets
    /// becomes the snapshot's aggregate resident set.
    pub fn publish_shards(&self, page_size: usize,
                          per_shard: Vec<HashSet<u64>>,
                          shard_live_pages: Vec<u64>, lookups: u64,
                          hits: u64, hit_tokens: u64) {
        let resident: HashSet<u64> = per_shard
            .iter()
            .flat_map(|set| set.iter().copied())
            .collect();
        let mut s = self.lock();
        s.page_size = page_size;
        s.resident = resident;
        s.per_shard = per_shard;
        s.shard_live_pages = shard_live_pages;
        s.version += 1;
        s.prefix_lookups = lookups;
        s.prefix_hits = hits;
        s.prefix_hit_tokens = hit_tokens;
    }

    /// Router-side probe: cached leading blocks for `tokens`.
    pub fn probe(&self, tokens: &[i32]) -> usize {
        self.lock().probe(tokens)
    }

    /// Router-side shard-set probe: `(cached leading blocks, distinct
    /// shards holding them)`.
    pub fn probe_shards(&self, tokens: &[i32]) -> (usize, usize) {
        self.lock().probe_shards(tokens)
    }

    /// Last published per-shard live-page occupancy (empty until a
    /// sharded worker publishes).
    pub fn shard_occupancy(&self) -> Vec<u64> {
        self.lock().shard_live_pages.clone()
    }

    /// Snapshot copy for reports (version, lookups, hits, hit tokens).
    pub fn counters(&self) -> (u64, u64, u64, u64) {
        let s = self.lock();
        (s.version, s.prefix_lookups, s.prefix_hits, s.prefix_hit_tokens)
    }

    /// A poisoned mutex (worker panicked mid-publish) yields the last
    /// snapshot instead of propagating the panic: routing degrades to
    /// stale data, it never takes the router down.
    fn lock(&self) -> MutexGuard<'_, PrefixSnapshot> {
        self.snapshot
            .lock()
            .unwrap_or_else(|poison| poison.into_inner())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(cached_blocks: usize, depth: usize) -> ReplicaView {
        ReplicaView { cached_blocks, depth, shard_spread: 0 }
    }

    #[test]
    fn round_robin_rotates_with_cursor() {
        let views = [v(0, 0), v(0, 0), v(0, 0)];
        assert_eq!(rank(RoutingPolicy::RoundRobin, &views, 0),
                   vec![0, 1, 2]);
        assert_eq!(rank(RoutingPolicy::RoundRobin, &views, 1),
                   vec![1, 2, 0]);
        assert_eq!(rank(RoutingPolicy::RoundRobin, &views, 5),
                   vec![2, 0, 1]);
    }

    #[test]
    fn least_loaded_orders_by_depth_then_index() {
        let views = [v(9, 3), v(0, 1), v(0, 3)];
        // cached_blocks is ignored; equal depths tie-break by index.
        assert_eq!(rank(RoutingPolicy::LeastLoaded, &views, 7),
                   vec![1, 0, 2]);
    }

    #[test]
    fn prefix_affinity_longest_prefix_wins() {
        let views = [v(1, 0), v(3, 9), v(2, 0)];
        // The warmest cache wins even with the deepest queue.
        assert_eq!(rank(RoutingPolicy::PrefixAffinity, &views, 0),
                   vec![1, 2, 0]);
    }

    #[test]
    fn prefix_affinity_ties_break_by_queue_depth_then_index() {
        let views = [v(2, 5), v(2, 1), v(2, 5), v(0, 0)];
        // Equal warmth → shallower queue first; equal depth → index.
        assert_eq!(rank(RoutingPolicy::PrefixAffinity, &views, 0),
                   vec![1, 0, 2, 3]);
    }

    /// Tentpole: among replicas tied on warmth and depth, the one
    /// whose warm prefix is concentrated on fewer device shards wins;
    /// warmth and depth still dominate spread.
    #[test]
    fn prefix_affinity_scores_shard_sets_behind_warmth_and_depth() {
        let spread = |cached, depth, shard_spread| ReplicaView {
            cached_blocks: cached,
            depth,
            shard_spread,
        };
        // Equal warmth + depth: spread 1 beats spread 3.
        let views = [spread(4, 2, 3), spread(4, 2, 1), spread(4, 2, 2)];
        assert_eq!(rank(RoutingPolicy::PrefixAffinity, &views, 0),
                   vec![1, 2, 0]);
        // Depth dominates spread; warmth dominates both.
        let views = [spread(4, 5, 1), spread(4, 2, 3), spread(5, 9, 4)];
        assert_eq!(rank(RoutingPolicy::PrefixAffinity, &views, 0),
                   vec![2, 1, 0]);
        // Monolithic pools (uniform spread) keep the pre-shard order.
        let views = [spread(2, 5, 1), spread(2, 1, 1), spread(0, 0, 0)];
        assert_eq!(rank(RoutingPolicy::PrefixAffinity, &views, 0),
                   vec![1, 0, 2]);
    }

    #[test]
    fn snapshot_probe_shards_counts_device_spread() {
        let tokens: Vec<i32> = (0..20).collect();
        let hashes = block_hashes(&tokens, 4); // 5 full blocks
        let snap = PrefixSnapshot {
            page_size: 4,
            resident: hashes[..4].iter().copied().collect(),
            per_shard: vec![
                hashes[..2].iter().copied().collect(),
                hashes[2..4].iter().copied().collect(),
            ],
            version: 1,
            ..PrefixSnapshot::default()
        };
        assert_eq!(snap.probe(&tokens), 4);
        assert_eq!(snap.probe_shards(&tokens), (4, 2),
                   "four blocks across two shards");
        assert_eq!(snap.probe_shards(&tokens[..8]), (2, 1),
                   "short prompt stays on shard 0");
        assert_eq!(snap.probe_shards(&[9; 8]), (0, 0));
        // A legacy publish (no shard buckets) still reports spread 1.
        let legacy = PrefixSnapshot {
            page_size: 4,
            resident: hashes[..2].iter().copied().collect(),
            version: 1,
            ..PrefixSnapshot::default()
        };
        assert_eq!(legacy.probe_shards(&tokens), (2, 1));
    }

    #[test]
    fn cell_publish_shards_unions_buckets_and_reports_occupancy() {
        let cell = ReplicaCell::new();
        let tokens: Vec<i32> = (0..16).collect();
        let hashes = block_hashes(&tokens, 4); // 4 full blocks
        cell.publish_shards(
            4,
            vec![
                hashes[..3].iter().copied().collect(),
                hashes[3..].iter().copied().collect(),
            ],
            vec![7, 2],
            10, 6, 24,
        );
        assert_eq!(cell.probe(&tokens), 4, "probe sees the union");
        assert_eq!(cell.probe_shards(&tokens), (4, 2));
        assert_eq!(cell.shard_occupancy(), vec![7, 2]);
        assert_eq!(cell.counters(), (1, 10, 6, 24));
        // The monolithic publish keeps working (one bucket, no gauge).
        cell.publish(4, hashes.iter().copied().collect(), 11, 7, 28);
        assert_eq!(cell.probe_shards(&tokens), (4, 1));
        assert!(cell.shard_occupancy().is_empty());
        assert_eq!(cell.counters(), (2, 11, 7, 28));
    }

    #[test]
    fn prefix_affinity_zero_blocks_falls_back_to_least_loaded() {
        let views = [v(0, 4), v(0, 2), v(0, 2)];
        let affinity = rank(RoutingPolicy::PrefixAffinity, &views, 3);
        let least = rank(RoutingPolicy::LeastLoaded, &views, 3);
        assert_eq!(affinity, least, "cold caches degrade to least-loaded");
        assert_eq!(affinity, vec![1, 2, 0]);
    }

    #[test]
    fn rank_is_always_a_full_permutation() {
        // The failover walk relies on every replica appearing once.
        for policy in RoutingPolicy::ALL {
            for cursor in 0..5u64 {
                let views = [v(3, 1), v(0, 0), v(3, 1), v(1, 7)];
                let mut order = rank(policy, &views, cursor);
                order.sort_unstable();
                assert_eq!(order, vec![0, 1, 2, 3], "{policy} c{cursor}");
            }
        }
        assert!(rank(RoutingPolicy::RoundRobin, &[], 0).is_empty());
    }

    #[test]
    fn snapshot_probe_walks_chain_until_first_miss() {
        let tokens: Vec<i32> = (0..20).collect();
        let hashes = block_hashes(&tokens, 4); // 5 full blocks
        let mut snap = PrefixSnapshot {
            page_size: 4,
            resident: hashes[..3].iter().copied().collect(),
            version: 1,
            ..PrefixSnapshot::default()
        };
        assert_eq!(snap.probe(&tokens), 3);
        // A hole in the chain ends the match even if later blocks are
        // resident (chain hashes make later matches impossible anyway).
        snap.resident = [hashes[0], hashes[2]].into_iter().collect();
        assert_eq!(snap.probe(&tokens), 1);
        // Prompts shorter than a block never match.
        assert_eq!(snap.probe(&tokens[..3]), 0);
    }

    #[test]
    fn stale_or_unpublished_snapshot_probes_zero_and_routes() {
        // Never-published cell: probe is 0, rank still yields an
        // order covering every replica (graceful degradation).
        let cell = ReplicaCell::new();
        assert_eq!(cell.probe(&[1, 2, 3, 4, 5, 6, 7, 8]), 0);
        let views = [v(cell.probe(&[1; 16]), cell.depth()), v(0, 3)];
        let order = rank(RoutingPolicy::PrefixAffinity, &views, 0);
        assert_eq!(order, vec![0, 1]);
    }

    #[test]
    fn cell_depth_tracks_routed_dequeued_backlog() {
        let cell = ReplicaCell::new();
        assert_eq!(cell.depth(), 0);
        cell.note_routed();
        cell.note_routed();
        assert_eq!(cell.depth(), 2);
        cell.note_dequeued();
        cell.set_backlog(3);
        assert_eq!(cell.depth(), 4, "1 queued + 3 backlog");
        assert_eq!(cell.routed(), 2);
        // Underflow (shutdown race) saturates at zero.
        cell.note_dequeued();
        cell.note_dequeued();
        cell.set_backlog(0);
        assert_eq!(cell.depth(), 0);
        // A failed send rolls back both counters.
        cell.note_routed();
        cell.note_route_failed();
        assert_eq!(cell.depth(), 0);
        assert_eq!(cell.routed(), 2, "failed route not counted");
    }

    #[test]
    fn cell_publish_updates_probe_and_counters() {
        let cell = ReplicaCell::new();
        let tokens: Vec<i32> = (100..116).collect();
        let hashes: HashSet<u64> =
            block_hashes(&tokens, 4).into_iter().collect();
        cell.publish(4, hashes, 10, 7, 28);
        assert_eq!(cell.probe(&tokens), 4);
        assert_eq!(cell.counters(), (1, 10, 7, 28));
        cell.publish(4, HashSet::new(), 12, 8, 32);
        assert_eq!(cell.probe(&tokens), 0, "republish replaces the set");
        assert_eq!(cell.counters(), (2, 12, 8, 32));
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in RoutingPolicy::ALL {
            assert_eq!(RoutingPolicy::parse(p.as_str()), Some(p));
        }
        assert_eq!(RoutingPolicy::parse("warmest"), None);
        assert_eq!(RoutingPolicy::default(),
                   RoutingPolicy::PrefixAffinity);
    }
}
