//! Elastic autoscaling over the open-loop fleet replay: replicas are
//! added under sustained queue pressure and gracefully drained when
//! idle, on the same simulated clock the workers tick on.
//!
//! The closed loop the ISSUE names: `workload::arrivals` generates a
//! timestamped request stream (Poisson/diurnal curves, flash-crowd
//! bursts, Zipf tenants, warm-prefix follow-ups), this driver routes
//! each arrival through the usual [`RoutingPolicy`] machinery as it
//! occurs, and an [`AutoscaleSpec`] watches two per-round telemetry
//! signals — outstanding-request depth per accepting replica and the
//! pool's cumulative capacity-wait ticks — to decide when the fleet
//! grows or shrinks:
//!
//! * **Scale up** after `sustain` consecutive pressured rounds (depth
//!   per replica above `high_depth`, or capacity waits still rising
//!   while depth sits above `low_depth`), bounded by `max` and a
//!   `cooldown` between scale events. A new worker spawns with its
//!   clock advanced to the fleet's now — replica-seconds start
//!   accruing at spawn, not at t = 0.
//! * **Drain** after `idle_sustain` consecutive idle rounds (depth per
//!   replica below `low_depth`), never below `min`. A drain reuses
//!   the crash fail-over path for *queued* work only
//!   ([`SimWorker::drain_queued`] → re-route through the policy), but
//!   unlike [`SimWorker::kill`] the replica keeps ticking until its
//!   in-flight prefills and decodes complete, and only then retires
//!   ([`ScaleKind::DrainDone`]). Drain drops nothing; crash recomputes
//!   — the drain-vs-crash regression test pins the exact relation
//!   (`crash reroutes == drain reroutes + in-flight kept`).
//!
//! Every decision lands in a [`ScaleEvent`] timeline (rendered by
//! `mmserve kv --autoscale`), and the comparison that CI gates runs
//! the same arrival stream through three arms: autoscaled, fixed
//! fleet at `min`, fixed fleet at `max`. The scaler must beat the min
//! fleet on burst-phase p99 TTFT *and* spend fewer replica-seconds
//! than the max fleet while staying within goodput tolerance of it.

use std::collections::HashMap;

use crate::kvpool::replay::{ReplayConfig, ReplayResult, SimWorker};
use crate::kvpool::PoolStats;
use crate::substrate::metrics::Histogram;
use crate::substrate::table::Table;
use crate::workload::arrivals::{generate_arrivals, ArrivalPhase,
                                TimedArrival};

use super::replay::{route_one, KillSpec};
use super::RoutingPolicy;

/// Autoscaling policy knobs (`--autoscale min:max` with defaults for
/// the thresholds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoscaleSpec {
    /// Fleet floor (also the starting size).
    pub min: usize,
    /// Fleet ceiling.
    pub max: usize,
    /// Depth-per-replica above which a round counts as pressured.
    pub high_depth: f64,
    /// Depth-per-replica below which a round counts as idle; between
    /// the two thresholds, rising capacity waits still count as
    /// pressure (the pool is thrashing even if the queue looks sane).
    pub low_depth: f64,
    /// Consecutive pressured rounds before a scale-up.
    pub sustain: usize,
    /// Consecutive idle rounds before a drain.
    pub idle_sustain: usize,
    /// Minimum rounds between any two scale events.
    pub cooldown: usize,
}

impl AutoscaleSpec {
    /// `min:max` with default thresholds.
    pub fn new(min: usize, max: usize) -> AutoscaleSpec {
        AutoscaleSpec {
            min: min.max(1),
            max: max.max(min.max(1)),
            high_depth: 6.0,
            low_depth: 2.0,
            sustain: 3,
            idle_sustain: 5,
            cooldown: 6,
        }
    }

    /// Parse the CLI's `--autoscale min:max`.
    pub fn parse(spec: &str) -> Result<AutoscaleSpec, String> {
        let (lo, hi) = spec.split_once(':').ok_or_else(|| {
            format!("autoscale spec {spec:?}: want min:max")
        })?;
        let min: usize = lo.trim().parse().map_err(|_| {
            format!("autoscale spec {spec:?}: bad min")
        })?;
        let max: usize = hi.trim().parse().map_err(|_| {
            format!("autoscale spec {spec:?}: bad max")
        })?;
        if min == 0 {
            return Err(format!("autoscale spec {spec:?}: min must be \
                                ≥ 1"));
        }
        if max < min {
            return Err(format!("autoscale spec {spec:?}: max {max} < \
                                min {min}"));
        }
        Ok(AutoscaleSpec::new(min, max))
    }
}

/// What happened at one point of the scale timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleKind {
    /// A replica spawned under sustained pressure.
    Up,
    /// A replica began draining: queued work re-routed, in-flight
    /// kept; the event's `depth` is the in-flight count it keeps.
    DrainStart,
    /// A draining replica finished its in-flight work and retired.
    DrainDone,
    /// A replica crashed ([`KillSpec`]): everything re-routed.
    Crash,
}

impl ScaleKind {
    pub fn label(&self) -> &'static str {
        match self {
            ScaleKind::Up => "scale-up",
            ScaleKind::DrainStart => "drain-start",
            ScaleKind::DrainDone => "drain-done",
            ScaleKind::Crash => "crash",
        }
    }
}

/// One entry of the scale-event timeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScaleEvent {
    /// Fleet simulated time of the decision.
    pub at: f64,
    /// Driver round of the decision.
    pub round: u64,
    pub kind: ScaleKind,
    pub replica: usize,
    /// Kind-specific depth: fleet outstanding requests for `Up`, the
    /// drained replica's kept in-flight count for `DrainStart`,
    /// orphans re-routed for `Crash`, 0 for `DrainDone`.
    pub depth: usize,
    /// Accepting replicas *after* the event took effect.
    pub live: usize,
}

/// Gracefully drain one replica mid-run (the manual counterpart of
/// the autoscaler's idle drain, and the graceful sibling of
/// [`KillSpec`]): after `after_delivered` first-time arrivals have
/// been routed fleet-wide, `replica` stops accepting work, its queued
/// requests re-route through the policy, and it retires once its
/// in-flight work completes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DrainSpec {
    pub replica: usize,
    pub after_delivered: usize,
}

/// Knobs of one autoscaled (or fixed-fleet) open-loop replay.
#[derive(Debug, Clone)]
pub struct AutoscaleReplayConfig {
    /// Per-worker sizing + the arrival process
    /// ([`ReplayConfig::arrivals`]).
    pub base: ReplayConfig,
    pub policy: RoutingPolicy,
    /// Fixed fleet size when `autoscale` is `None` (ignored otherwise
    /// — an autoscaled fleet starts at `min`).
    pub replicas: usize,
    pub autoscale: Option<AutoscaleSpec>,
    /// Optional mid-run graceful drain (regression testing).
    pub drain: Option<DrainSpec>,
    /// Optional mid-run crash (regression testing).
    pub kill: Option<KillSpec>,
}

impl Default for AutoscaleReplayConfig {
    fn default() -> Self {
        AutoscaleReplayConfig {
            base: ReplayConfig::default(),
            policy: RoutingPolicy::default(),
            replicas: 2,
            autoscale: None,
            drain: None,
            kill: None,
        }
    }
}

/// Outcome of one open-loop fleet replay.
#[derive(Debug, Clone)]
pub struct AutoscaleReplayResult {
    pub policy: RoutingPolicy,
    /// Per-worker results, index = replica id (spawn order).
    pub per_worker: Vec<ReplayResult>,
    /// First-time deliveries routed to each replica.
    pub routed: Vec<usize>,
    /// Fleet-wide pool counters (summed).
    pub fleet: PoolStats,
    /// TTFT/TBT merged across workers.
    pub ttft: Histogram,
    pub tbt: Histogram,
    /// TTFT sliced by the rate-curve phase each request *arrived* in
    /// (report order: base, peak, burst).
    pub phase_ttft: Vec<(ArrivalPhase, Histogram)>,
    pub completed: usize,
    pub dropped: usize,
    /// Fleet makespan (slowest worker's clock at drain).
    pub sim_time: f64,
    /// Scheduler ticks summed across workers.
    pub ticks: u64,
    pub tokens_decoded: u64,
    /// Per-request decoded streams, merged across workers.
    pub outputs: HashMap<u64, Vec<i32>>,
    /// The scale-event timeline, in decision order.
    pub events: Vec<ScaleEvent>,
    /// Σ over replicas of (retire time − spawn time): the paid
    /// capacity. A fixed fleet pays `replicas × sim_time`.
    pub replica_seconds: f64,
    /// Most replicas ever accepting work at once.
    pub peak_replicas: usize,
    /// Requests re-routed by drains and crashes.
    pub reroutes: usize,
    /// Arrivals the run served (base + bursts + follow-ups).
    pub arrivals: usize,
}

impl AutoscaleReplayResult {
    /// Decoded tokens per replica-second — the efficiency headline
    /// the CI gate tracks (0.0 on a degenerate zero-duration run).
    pub fn goodput_per_replica(&self) -> f64 {
        if self.replica_seconds <= 0.0 {
            return 0.0;
        }
        let g = self.tokens_decoded as f64 / self.replica_seconds;
        if g.is_finite() { g } else { 0.0 }
    }

    /// p99 TTFT of requests that arrived in `phase` (0.0 when the
    /// phase saw no arrivals).
    pub fn phase_p99(&self, phase: ArrivalPhase) -> f64 {
        self.phase_ttft
            .iter()
            .find(|(p, _)| *p == phase)
            .map(|(_, h)| h.percentile(99.0))
            .unwrap_or(0.0)
    }

    pub fn scale_ups(&self) -> usize {
        self.events
            .iter()
            .filter(|e| e.kind == ScaleKind::Up)
            .count()
    }

    pub fn drains(&self) -> usize {
        self.events
            .iter()
            .filter(|e| e.kind == ScaleKind::DrainStart)
            .count()
    }
}

/// Per-replica lifecycle bookkeeping the workers themselves don't
/// carry.
struct Meta {
    spawned_at: f64,
    retired_at: Option<f64>,
    draining: bool,
}

/// Run the open-loop arrival stream of `cfg.base` through an elastic
/// (or fixed) fleet under `cfg.policy`. Deterministic: same config ⇒
/// same scale-event timeline, same per-request outputs, same
/// counters, bit for bit.
pub fn autoscale_replay(cfg: &AutoscaleReplayConfig)
                        -> AutoscaleReplayResult {
    let arrivals = generate_arrivals(&cfg.base);
    let by_id: HashMap<u64, &TimedArrival> =
        arrivals.iter().map(|a| (a.req.id, a)).collect();
    let start = match cfg.autoscale {
        Some(a) => a.min,
        None => cfg.replicas.max(1),
    };
    if let Some(k) = cfg.kill {
        assert!(k.replica < start, "kill target out of range");
    }
    if let Some(d) = cfg.drain {
        assert!(d.replica < start, "drain target out of range");
    }
    let mut workers: Vec<SimWorker> =
        (0..start).map(|_| SimWorker::new(&cfg.base, true)).collect();
    let mut meta: Vec<Meta> = (0..start)
        .map(|_| Meta {
            spawned_at: 0.0,
            retired_at: None,
            draining: false,
        })
        .collect();
    let mut routed = vec![0usize; start];
    let mut events: Vec<ScaleEvent> = Vec::new();
    let mut orphans: Vec<u64> = Vec::new();
    let mut reroutes = 0usize;
    let mut cursor = 0u64;
    let mut next = 0usize;
    let mut killed = false;
    let mut drained = false;
    let mut hot = 0usize;
    let mut cold = 0usize;
    let mut last_scale_round: Option<u64> = None;
    let mut prev_cap_waits = 0u64;
    let mut peak = start;
    let mut round = 0u64;
    let mut guard = 0u64;

    // Accepting = can take new deliveries: alive and not on the way
    // out. Live = alive (draining replicas still tick and count for
    // the fleet clock).
    let accepting = |workers: &[SimWorker], meta: &[Meta]| -> Vec<usize> {
        (0..workers.len())
            .filter(|&i| {
                !workers[i].is_dead()
                    && meta[i].retired_at.is_none()
                    && !meta[i].draining
            })
            .collect()
    };
    let fleet_now = |workers: &[SimWorker], meta: &[Meta]| -> f64 {
        (0..workers.len())
            .filter(|&i| {
                !workers[i].is_dead() && meta[i].retired_at.is_none()
            })
            .map(|i| workers[i].now())
            .fold(0.0f64, f64::max)
    };

    while (next < arrivals.len()
        || !orphans.is_empty()
        || workers.iter().any(|w| w.has_work()))
        && guard < 4_000_000
    {
        guard += 1;
        let mut now = fleet_now(&workers, &meta);
        let any_work = workers.iter().any(|w| w.has_work());
        // Idle with the next arrival in the future: jump the fleet
        // clock to it (open-loop time passes whether or not anyone
        // works).
        if !any_work && orphans.is_empty() && next < arrivals.len() {
            now = now.max(arrivals[next].at);
        }

        // ---- deliveries: everything due by the fleet clock --------
        let elig = accepting(&workers, &meta);
        while next < arrivals.len() && arrivals[next].at <= now {
            let a = &arrivals[next];
            let t = route_one(&workers, cfg.policy, &a.req.tokens,
                              cursor, &elig)
                .expect("an accepting replica always exists");
            workers[t].deliver_at(&a.req, a.at);
            routed[t] += 1;
            cursor += 1;
            next += 1;
        }
        // Orphans of drains/crashes re-enter through the same policy
        // at the fleet's now (they cannot re-arrive in the past).
        if !orphans.is_empty() {
            let pending = std::mem::take(&mut orphans);
            for id in pending {
                let a = by_id[&id];
                let t = route_one(&workers, cfg.policy, &a.req.tokens,
                                  cursor, &elig)
                    .expect("an accepting replica always exists");
                workers[t].deliver_at(&a.req, now);
                cursor += 1;
            }
        }

        // ---- injected failure / manual drain triggers -------------
        if let Some(k) = cfg.kill {
            if !killed && next >= k.after_delivered {
                killed = true;
                let ids = workers[k.replica].kill();
                meta[k.replica].retired_at =
                    Some(workers[k.replica].now());
                reroutes += ids.len();
                let live = accepting(&workers, &meta).len();
                events.push(ScaleEvent {
                    at: now,
                    round,
                    kind: ScaleKind::Crash,
                    replica: k.replica,
                    depth: ids.len(),
                    live,
                });
                orphans.extend(ids);
            }
        }
        if let Some(d) = cfg.drain {
            if !drained && next >= d.after_delivered {
                drained = true;
                let ids = workers[d.replica].drain_queued();
                meta[d.replica].draining = true;
                reroutes += ids.len();
                let kept = workers[d.replica].depth();
                let live = accepting(&workers, &meta).len();
                events.push(ScaleEvent {
                    at: now,
                    round,
                    kind: ScaleKind::DrainStart,
                    replica: d.replica,
                    depth: kept,
                    live,
                });
                orphans.extend(ids);
            }
        }

        // ---- autoscaler decision ----------------------------------
        if let Some(spec) = cfg.autoscale {
            let acc = accepting(&workers, &meta);
            let n_acc = acc.len().max(1);
            let depth_total: usize =
                acc.iter().map(|&i| workers[i].depth()).sum();
            let depth_per = depth_total as f64 / n_acc as f64;
            // Capacity waits are monotone per worker (retired clocks
            // freeze), so the fleet sum is monotone and the delta is
            // a per-round pressure signal.
            let cap_now: u64 =
                workers.iter().map(|w| w.capacity_waits()).sum();
            let cap_rising = cap_now > prev_cap_waits;
            prev_cap_waits = cap_now;
            let pressured = depth_per > spec.high_depth
                || (cap_rising && depth_per > spec.low_depth);
            hot = if pressured { hot + 1 } else { 0 };
            cold = if depth_per < spec.low_depth { cold + 1 } else { 0 };
            let cooled = last_scale_round
                .map_or(true, |r| round - r >= spec.cooldown as u64);
            if hot >= spec.sustain && cooled && acc.len() < spec.max {
                let mut w = SimWorker::new(&cfg.base, true);
                w.advance_to(now);
                workers.push(w);
                meta.push(Meta {
                    spawned_at: now,
                    retired_at: None,
                    draining: false,
                });
                routed.push(0);
                let live = accepting(&workers, &meta).len();
                events.push(ScaleEvent {
                    at: now,
                    round,
                    kind: ScaleKind::Up,
                    replica: workers.len() - 1,
                    depth: depth_total,
                    live,
                });
                hot = 0;
                last_scale_round = Some(round);
                peak = peak.max(live);
            } else if cold >= spec.idle_sustain
                && cooled
                && acc.len() > spec.min
            {
                // Shallowest accepting replica retires first; ties
                // break toward the newest (keep the original floor
                // fleet stable).
                let victim = *acc
                    .iter()
                    .min_by_key(|&&i| (workers[i].depth(),
                                       std::cmp::Reverse(i)))
                    .expect("accepting set non-empty");
                let ids = workers[victim].drain_queued();
                meta[victim].draining = true;
                reroutes += ids.len();
                let kept = workers[victim].depth();
                let live = accepting(&workers, &meta).len();
                events.push(ScaleEvent {
                    at: now,
                    round,
                    kind: ScaleKind::DrainStart,
                    replica: victim,
                    depth: kept,
                    live,
                });
                orphans.extend(ids);
                cold = 0;
                last_scale_round = Some(round);
            }
        }

        // ---- tick every live worker that has work -----------------
        for i in 0..workers.len() {
            if !workers[i].is_dead()
                && meta[i].retired_at.is_none()
                && workers[i].has_work()
            {
                workers[i].tick();
            }
        }

        // ---- retire finished drains -------------------------------
        for i in 0..workers.len() {
            if meta[i].draining
                && meta[i].retired_at.is_none()
                && !workers[i].has_work()
            {
                // A drained replica that sat idle has a stale clock;
                // it existed until the fleet's now, so that is what
                // its replica-seconds (and the timeline) charge.
                let at = workers[i].now().max(now);
                meta[i].retired_at = Some(at);
                meta[i].draining = false;
                let live = accepting(&workers, &meta).len();
                events.push(ScaleEvent {
                    at,
                    round,
                    kind: ScaleKind::DrainDone,
                    replica: i,
                    depth: 0,
                    live,
                });
            }
        }
        round += 1;
    }
    assert!(guard < 4_000_000, "autoscale replay wedged");

    // ---- aggregate ------------------------------------------------
    let end = workers.iter().map(|w| w.now()).fold(0.0f64, f64::max);
    let replica_seconds: f64 = meta
        .iter()
        .map(|m| (m.retired_at.unwrap_or(end) - m.spawned_at).max(0.0))
        .sum();
    let per_worker: Vec<ReplayResult> = workers
        .into_iter()
        .map(|w| w.into_result("paged"))
        .collect();
    let fleet = PoolStats::aggregate(per_worker.iter().map(|r| &r.stats));
    let mut ttft = Histogram::new();
    let mut tbt = Histogram::new();
    let mut outputs: HashMap<u64, Vec<i32>> = HashMap::new();
    let mut phase_ttft: Vec<(ArrivalPhase, Histogram)> = ArrivalPhase::ALL
        .iter()
        .map(|&p| (p, Histogram::new()))
        .collect();
    let mut completed = 0usize;
    let mut dropped = 0usize;
    let mut ticks = 0u64;
    let mut tokens = 0u64;
    for r in &per_worker {
        for &s in r.ttft.samples() {
            ttft.record(s);
        }
        for &s in r.tbt.samples() {
            tbt.record(s);
        }
        for (&id, &dt) in &r.ttft_by_request {
            if let Some(a) = by_id.get(&id) {
                if let Some((_, h)) = phase_ttft
                    .iter_mut()
                    .find(|(p, _)| *p == a.phase)
                {
                    h.record(dt);
                }
            }
        }
        outputs.extend(r.outputs.iter()
            .map(|(k, v)| (*k, v.clone())));
        completed += r.completed;
        dropped += r.dropped;
        ticks += r.ticks;
        tokens += r.tokens_decoded;
    }
    AutoscaleReplayResult {
        policy: cfg.policy,
        routed,
        fleet,
        ttft,
        tbt,
        phase_ttft,
        completed,
        dropped,
        sim_time: end,
        ticks,
        tokens_decoded: tokens,
        outputs,
        events,
        replica_seconds,
        peak_replicas: peak,
        reroutes,
        arrivals: arrivals.len(),
        per_worker,
    }
}

/// The three-arm comparison CI gates: the autoscaled fleet vs fixed
/// fleets pinned at the scaler's floor and ceiling, all serving the
/// identical arrival stream.
#[derive(Debug, Clone)]
pub struct AutoscaleComparison {
    pub autoscaled: AutoscaleReplayResult,
    pub fixed_min: AutoscaleReplayResult,
    pub fixed_max: AutoscaleReplayResult,
}

/// Run the comparison for an autoscaled config (panics without an
/// [`AutoscaleSpec`] — the fixed arms are derived from its bounds).
pub fn compare_autoscale(cfg: &AutoscaleReplayConfig)
                         -> AutoscaleComparison {
    let spec = cfg.autoscale
        .expect("compare_autoscale needs an AutoscaleSpec");
    let fixed = |n: usize| AutoscaleReplayConfig {
        autoscale: None,
        replicas: n,
        ..cfg.clone()
    };
    AutoscaleComparison {
        autoscaled: autoscale_replay(cfg),
        fixed_min: autoscale_replay(&fixed(spec.min)),
        fixed_max: autoscale_replay(&fixed(spec.max)),
    }
}

/// Side-by-side table of the three arms for `mmserve kv`.
pub fn render_autoscale_comparison(c: &AutoscaleComparison) -> String {
    let mut t = Table::new(&["metric", "autoscaled", "fixed-min",
                             "fixed-max"]);
    let f2 = |x: f64| format!("{x:.2}");
    let row3 =
        |t: &mut Table, name: &str,
         f: &dyn Fn(&AutoscaleReplayResult) -> String| {
            t.row(&[name.to_string(), f(&c.autoscaled),
                    f(&c.fixed_min), f(&c.fixed_max)]);
        };
    row3(&mut t, "arrivals served",
         &|r| r.completed.to_string());
    row3(&mut t, "dropped", &|r| r.dropped.to_string());
    row3(&mut t, "p50 TTFT", &|r| f2(r.ttft.percentile(50.0)));
    row3(&mut t, "p99 TTFT", &|r| f2(r.ttft.percentile(99.0)));
    row3(&mut t, "burst p99 TTFT",
         &|r| f2(r.phase_p99(ArrivalPhase::Burst)));
    row3(&mut t, "replica-seconds", &|r| f2(r.replica_seconds));
    row3(&mut t, "goodput/replica-s",
         &|r| format!("{:.3}", r.goodput_per_replica()));
    row3(&mut t, "peak replicas",
         &|r| r.peak_replicas.to_string());
    row3(&mut t, "scale-ups", &|r| r.scale_ups().to_string());
    row3(&mut t, "drains", &|r| r.drains().to_string());
    row3(&mut t, "sim time", &|r| f2(r.sim_time));
    t.render()
}

/// The scale-event timeline for `mmserve kv` (empty string when no
/// events fired).
pub fn render_scale_timeline(r: &AutoscaleReplayResult) -> String {
    if r.events.is_empty() {
        return String::new();
    }
    let mut t = Table::new(&["time", "round", "event", "replica",
                             "depth", "live"]);
    for e in &r.events {
        t.row(&[format!("{:.2}", e.at), e.round.to_string(),
                e.kind.label().to_string(), e.replica.to_string(),
                e.depth.to_string(), e.live.to_string()]);
    }
    t.render()
}

/// Per-rate-curve-phase TTFT table for `mmserve kv`.
pub fn render_phase_ttft(r: &AutoscaleReplayResult) -> String {
    let mut t = Table::new(&["phase", "requests", "p50 TTFT",
                             "p99 TTFT"]);
    for (p, h) in &r.phase_ttft {
        t.row(&[p.label().to_string(), h.len().to_string(),
                format!("{:.2}", h.percentile(50.0)),
                format!("{:.2}", h.percentile(99.0))]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::arrivals::ArrivalSpec;

    fn open_base(spec: &str, requests: usize, tenants: usize)
                 -> ReplayConfig {
        ReplayConfig {
            requests,
            tenants,
            arrivals: Some(ArrivalSpec::parse(spec).unwrap()),
            ..ReplayConfig::default()
        }
    }

    #[test]
    fn autoscale_spec_parses_and_rejects_garbage() {
        let s = AutoscaleSpec::parse("1:4").unwrap();
        assert_eq!((s.min, s.max), (1, 4));
        assert!(s.high_depth > s.low_depth);
        for bad in ["", "4", "0:4", "4:2", "a:b", "1:"] {
            assert!(AutoscaleSpec::parse(bad).is_err(), "{bad:?}");
        }
    }

    /// Satellite: graceful drain vs crash on the same seeded
    /// workload. Drain drops nothing and completes its in-flight
    /// decodes on the draining replica; a crash at the same trigger
    /// re-routes *everything* the replica held — so its re-route
    /// count exceeds the drain's by exactly the in-flight work the
    /// drain kept.
    #[test]
    fn drain_completes_in_flight_while_crash_reroutes_it() {
        let base = open_base("poisson:0.9+followups:0", 48, 2);
        let mk = |drain, kill| AutoscaleReplayConfig {
            base: base.clone(),
            policy: RoutingPolicy::LeastLoaded,
            replicas: 3,
            autoscale: None,
            drain,
            kill,
        };
        let baseline = autoscale_replay(&mk(None, None));
        let drain = autoscale_replay(&mk(
            Some(DrainSpec { replica: 1, after_delivered: 20 }),
            None,
        ));
        let crash = autoscale_replay(&mk(
            None,
            Some(KillSpec { replica: 1, after_delivered: 20 }),
        ));
        let n = baseline.arrivals;
        for (name, r) in [("baseline", &baseline), ("drain", &drain),
                          ("crash", &crash)] {
            assert_eq!(r.completed, n, "{name} completes all");
            assert_eq!(r.dropped, 0, "{name} drops none");
            assert_eq!(r.outputs.len(), n);
        }
        // Scheduling moves *where* requests run, never *what* they
        // decode: all three runs agree token-for-token.
        assert_eq!(drain.outputs, baseline.outputs);
        assert_eq!(crash.outputs, baseline.outputs);
        // Drain timeline: start (with kept in-flight) then done.
        let start = drain
            .events
            .iter()
            .find(|e| e.kind == ScaleKind::DrainStart)
            .expect("drain-start event");
        assert_eq!(start.replica, 1);
        let done = drain
            .events
            .iter()
            .find(|e| e.kind == ScaleKind::DrainDone)
            .expect("drain-done event");
        assert_eq!(done.replica, 1);
        assert!(done.at >= start.at);
        assert!(start.depth > 0,
                "trigger mid-run must catch in-flight work");
        // Crash timeline mirrors it with a crash event.
        let boom = crash
            .events
            .iter()
            .find(|e| e.kind == ScaleKind::Crash)
            .expect("crash event");
        assert_eq!(boom.replica, 1);
        // The exact relation: the crash re-routes the drain's
        // re-routed queue *plus* the in-flight work the drain kept.
        assert_eq!(crash.reroutes, drain.reroutes + start.depth,
                   "crash orphans = drained queue + kept in-flight");
        assert!(crash.reroutes > drain.reroutes);
    }

    /// Acceptance criterion: on a diurnal + flash-crowd stream the
    /// autoscaler absorbs the burst — strictly better burst-phase p99
    /// TTFT than the fixed floor fleet, strictly fewer
    /// replica-seconds than the fixed ceiling fleet, within goodput
    /// tolerance of it, with both scale directions on the timeline.
    #[test]
    fn autoscaler_absorbs_burst_cheaper_than_fixed_fleets() {
        let cfg = AutoscaleReplayConfig {
            base: open_base("diurnal:0.25:0.9:180+burst:60:30:4", 96,
                            4),
            policy: RoutingPolicy::LeastLoaded,
            replicas: 1,
            autoscale: Some(AutoscaleSpec::new(1, 4)),
            drain: None,
            kill: None,
        };
        let c = compare_autoscale(&cfg);
        let (auto_, min_, max_) =
            (&c.autoscaled, &c.fixed_min, &c.fixed_max);
        for (name, r) in
            [("auto", auto_), ("min", min_), ("max", max_)]
        {
            assert_eq!(r.completed, r.arrivals,
                       "{name} serves every arrival");
            assert_eq!(r.dropped, 0, "{name} drops none");
        }
        assert!(auto_.scale_ups() >= 1, "burst must trigger scale-up");
        assert!(auto_.drains() >= 1,
                "the post-burst tail must trigger a drain");
        assert!(auto_.peak_replicas > 1);
        // Latency: the scaler beats the floor fleet where it hurts.
        let a99 = auto_.phase_p99(ArrivalPhase::Burst);
        let m99 = min_.phase_p99(ArrivalPhase::Burst);
        assert!(a99 < m99,
                "burst p99 TTFT: autoscaled {a99:.2} vs fixed-min \
                 {m99:.2}");
        assert!(auto_.ttft.percentile(99.0)
                    < min_.ttft.percentile(99.0));
        // Cost: strictly cheaper than pinning the ceiling.
        assert!(auto_.replica_seconds < max_.replica_seconds,
                "replica-seconds: autoscaled {:.1} vs fixed-max {:.1}",
                auto_.replica_seconds, max_.replica_seconds);
        // Efficiency: the same decoded streams from less capacity ⇒
        // goodput at least within tolerance of (in practice above)
        // the ceiling fleet. (`tokens_decoded` may differ slightly
        // across arms: recompute preemption re-decodes, and arms
        // preempt differently.)
        assert_eq!(auto_.outputs, max_.outputs);
        assert!(auto_.goodput_per_replica()
                    >= 0.9 * max_.goodput_per_replica());
    }

    /// Same seed + config ⇒ bit-identical timeline, outputs and
    /// counters (the non-property smoke of the 512-case prop test).
    #[test]
    fn autoscaled_replay_is_deterministic() {
        let cfg = AutoscaleReplayConfig {
            base: open_base("diurnal:0.3:1.0:120+burst:40:20:3", 48,
                            3),
            policy: RoutingPolicy::PrefixAffinity,
            replicas: 1,
            autoscale: Some(AutoscaleSpec::new(1, 3)),
            drain: None,
            kill: None,
        };
        let a = autoscale_replay(&cfg);
        let b = autoscale_replay(&cfg);
        assert_eq!(a.events, b.events);
        assert_eq!(a.outputs, b.outputs);
        assert_eq!(a.routed, b.routed);
        assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits());
        assert_eq!(a.replica_seconds.to_bits(),
                   b.replica_seconds.to_bits());
        assert_eq!(format!("{:?}", a.fleet), format!("{:?}", b.fleet));
    }

    /// The renderers include every arm / event / phase.
    #[test]
    fn renderers_cover_timeline_and_phases() {
        let cfg = AutoscaleReplayConfig {
            base: open_base("diurnal:0.25:0.9:180+burst:60:30:4", 64,
                            2),
            policy: RoutingPolicy::LeastLoaded,
            replicas: 1,
            autoscale: Some(AutoscaleSpec::new(1, 3)),
            drain: None,
            kill: None,
        };
        let c = compare_autoscale(&cfg);
        let cmp = render_autoscale_comparison(&c);
        for needle in ["autoscaled", "fixed-min", "fixed-max",
                       "burst p99 TTFT", "replica-seconds",
                       "goodput/replica-s"] {
            assert!(cmp.contains(needle), "{needle:?} in\n{cmp}");
        }
        let tl = render_scale_timeline(&c.autoscaled);
        assert!(tl.contains("scale-up"), "timeline:\n{tl}");
        let ph = render_phase_ttft(&c.autoscaled);
        for needle in ["base", "peak", "burst"] {
            assert!(ph.contains(needle), "{needle:?} in\n{ph}");
        }
        // A fixed fleet has no events — the timeline renders empty.
        assert!(render_scale_timeline(&c.fixed_min).is_empty());
    }
}
