//! Deviceless replica-routing replay: N simulated workers under a
//! routing policy, on the kvpool replay's simulated clock.
//!
//! Reuses [`SimWorker`] — the exact single-worker scheduling path of
//! `mmserve kv` — and runs a fleet of them in lockstep rounds. Each
//! round delivers the next few arrivals through the policy (probing
//! every worker's pool for the prompt's resident prefix blocks, the
//! simulated analogue of the live snapshot probe) and then ticks every
//! worker once on its own clock. TTFT/TBT are measured on the serving
//! worker's clock from delivery time, so policies are compared on the
//! same workload with the same per-worker hardware model.
//!
//! The headline comparison: with multiple shared system prompts
//! ("tenants"), `RoundRobin` makes every replica pay its own cold
//! prefill (and cache copy) per tenant, while `PrefixAffinity` pins
//! each tenant to the replica that already holds its blocks — the
//! aggregate prefix hit rate is strictly higher, with identical
//! per-request token outputs (scheduling must never change what a
//! request decodes, only when).

use std::collections::HashMap;

use crate::kvpool::replay::{generate_workload, FamilyStats,
                            ReplayConfig, ReplayResult, SimFamily,
                            SimRequest, SimRole, SimWorker};
use crate::kvpool::PoolStats;
use crate::substrate::metrics::Histogram;
use crate::substrate::table::Table;
use crate::telemetry::ledger::RequestLedger;
use crate::telemetry::live::{FlightRecorder, LiveMetrics,
                             WorkerSampler};

use super::{rank, ReplicaView, RoutingPolicy};

/// Kill one replica mid-run — the fail-over simulation hook. After
/// `after_delivered` requests have been routed fleet-wide, `replica`
/// crashes ([`SimWorker::kill`]): its unfinished requests are
/// withdrawn (partial outputs discarded) and re-routed through the
/// policy over the survivors, restarting from scratch — the recompute
/// fail-over. Deterministic for a fixed seed and spec.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KillSpec {
    pub replica: usize,
    pub after_delivered: usize,
}

/// The multi-worker replay knobs.
#[derive(Debug, Clone)]
pub struct RoutingReplayConfig {
    /// Per-worker workload/pool sizing (each replica gets its own page
    /// budget — the N-GPU model; `base.shards` splits each budget
    /// across device arenas, making the workers sharded).
    pub base: ReplayConfig,
    pub replicas: usize,
    /// Arrivals routed per lockstep round. Spacing arrivals out is
    /// what gives the prefix probe warm state to read; the historical
    /// closed-loop replay (everything at t = 0) would reduce every
    /// policy to its cold-start tie-break. At the default of 1, a
    /// tenant's first admission lands (and publishes its blocks)
    /// before the tenant's next request routes, so affinity pays at
    /// most one cold prefill per tenant instead of one per
    /// (tenant, replica) pair.
    pub arrivals_per_round: usize,
    /// Optional mid-run replica crash (fail-over testing).
    pub kill: Option<KillSpec>,
    /// Disaggregated serving: split the fleet into prefill workers
    /// (the first `replicas / 2`, min 1) and decode workers (the
    /// rest). Arrivals route over the prefill set only; each finished
    /// prefill ships its KV over the priced inter-replica link to a
    /// decode worker picked by the same policy. Ignored with fewer
    /// than 2 replicas.
    pub disaggregate: bool,
}

impl Default for RoutingReplayConfig {
    fn default() -> Self {
        RoutingReplayConfig {
            // Two tenants: by pigeonhole the larger one covers ≥ 50%
            // of requests — the acceptance regime ("≥ 50% share a
            // system prompt"). More tenants widens the affinity win.
            base: ReplayConfig {
                tenants: 2,
                ..ReplayConfig::default()
            },
            replicas: 2,
            arrivals_per_round: 1,
            kill: None,
            disaggregate: false,
        }
    }
}

/// Fleet-level outcome of one policy run.
#[derive(Debug, Clone)]
pub struct RoutingReplayResult {
    pub policy: RoutingPolicy,
    pub replicas: usize,
    /// Per-worker results, index = replica id.
    pub per_worker: Vec<ReplayResult>,
    /// Requests routed to each replica.
    pub routed: Vec<usize>,
    /// Fleet-wide pool counters (summed, never averaged rates).
    pub fleet: PoolStats,
    /// TTFT/TBT merged across workers.
    pub ttft: Histogram,
    pub tbt: Histogram,
    pub completed: usize,
    pub dropped: usize,
    /// Slowest worker's drain time (fleet makespan).
    pub sim_time: f64,
    /// Scheduler ticks summed across workers (the ledger's
    /// tick-overhead denominator).
    pub ticks: u64,
    /// Per-request decoded streams, merged across workers.
    pub outputs: HashMap<u64, Vec<i32>>,
    /// Each worker's place in the fleet (all Colocated unless the run
    /// was disaggregated), index = replica id.
    pub roles: Vec<SimRole>,
    /// Simulated time the fleet's clocks spent on fabric transfers
    /// (summed across workers; 0 without a fabric).
    pub transfer_time: f64,
    /// Bytes moved over the fabric fleet-wide.
    pub transfer_bytes: u64,
    /// Per-modality slices merged across workers (sorted by family;
    /// counts summed, latency histograms merged sample-by-sample) —
    /// the mixed-fleet lens on a replicated run.
    pub families: Vec<FamilyStats>,
}

impl RoutingReplayResult {
    /// Aggregate prefix hit rate from summed fleet counters. An empty
    /// fleet (zero lookups — e.g. a `requests: 0` replay or an
    /// all-dead fleet) is 0.0, never NaN: the CI gates divide by and
    /// compare against this.
    pub fn agg_hit_rate(&self) -> f64 {
        if self.fleet.prefix_lookups == 0 {
            return 0.0;
        }
        let r = self.fleet.hit_rate();
        if r.is_finite() { r } else { 0.0 }
    }

    /// Fraction of the fleet makespan the fabric links spent busy
    /// (summed link time over the slowest worker's drain; can exceed
    /// 1.0 when several links run in parallel). A zero-duration replay
    /// (instant completion — nothing ever ticked) is 0.0, never
    /// NaN/inf, even if transfer time was somehow recorded.
    pub fn link_utilization(&self) -> f64 {
        if self.sim_time <= 0.0 {
            return 0.0;
        }
        let u = self.transfer_time / self.sim_time;
        if u.is_finite() { u } else { 0.0 }
    }
}

/// Rank the `eligible` subset of the fleet for one request and pick
/// the first *live* replica — the simulated analogue of the router's
/// dead-channel fail-over walk (`rank` is a full permutation of the
/// subset, so any live eligible replica is reachable). Colocated runs
/// pass every index; disaggregated runs route arrivals over the
/// prefill set and handoffs over the decode set.
pub(crate) fn route_one(workers: &[SimWorker], policy: RoutingPolicy,
                        tokens: &[i32], cursor: u64,
                        eligible: &[usize]) -> Option<usize> {
    let views: Vec<ReplicaView> = eligible
        .iter()
        .map(|&i| {
            let w = &workers[i];
            let (cached_blocks, shard_spread) = if w.is_dead() {
                (0, 0)
            } else {
                w.probe_shards(tokens)
            };
            ReplicaView {
                cached_blocks,
                depth: w.depth(),
                shard_spread,
            }
        })
        .collect();
    rank(policy, &views, cursor)
        .into_iter()
        .map(|r| eligible[r])
        .find(|&i| !workers[i].is_dead())
}

/// Run the workload through `cfg.replicas` simulated workers under
/// `policy`. Deterministic: same config + policy → same result.
pub fn routing_replay(cfg: &RoutingReplayConfig, policy: RoutingPolicy)
                      -> RoutingReplayResult {
    routing_replay_inner(cfg, policy, None, None)
}

/// [`routing_replay`] with the live observability plane attached:
/// every replica gets a [`WorkerSampler`] publishing into the shared
/// `live` registry (replica label = index) and the shared flight
/// `recorder` — a [`KillSpec`] crash triggers a `replica-crash` dump
/// of the fleet's last-N tick events. Attaching the plane never
/// changes routing, scheduling, or outputs.
pub fn routing_replay_live(cfg: &RoutingReplayConfig,
                           policy: RoutingPolicy,
                           live: &LiveMetrics,
                           recorder: &FlightRecorder)
                           -> RoutingReplayResult {
    routing_replay_inner(cfg, policy, Some((live, recorder)), None)
}

/// [`routing_replay_live`] with the per-request causal ledger
/// attached fleet-wide: the router stamps a `routed` event (with the
/// chosen replica, on that replica's clock) before every delivery —
/// including fail-over re-deliveries — and each worker records its
/// admission/tick/preemption/spill chain into the shared `ledger`.
/// Pure observation, like the live plane.
pub fn routing_replay_instrumented(cfg: &RoutingReplayConfig,
                                   policy: RoutingPolicy,
                                   live: &LiveMetrics,
                                   recorder: &FlightRecorder,
                                   ledger: &RequestLedger)
                                   -> RoutingReplayResult {
    routing_replay_inner(cfg, policy, Some((live, recorder)),
                         Some(ledger))
}

fn routing_replay_inner(cfg: &RoutingReplayConfig,
                        policy: RoutingPolicy,
                        plane: Option<(&LiveMetrics, &FlightRecorder)>,
                        ledger: Option<&RequestLedger>)
                        -> RoutingReplayResult {
    let n = cfg.replicas.max(1);
    let per_round = cfg.arrivals_per_round.max(1);
    let mut workers: Vec<SimWorker> = (0..n)
        .map(|i| {
            let mut w = SimWorker::new(&cfg.base, true);
            if let Some((live, rec)) = plane {
                w.attach_sampler(WorkerSampler::new(live.clone(),
                                                    rec.clone(), i));
            }
            if let Some(led) = ledger {
                w.attach_ledger(led, i as u32);
            }
            w
        })
        .collect();
    // Disaggregation: the first half prefills, the rest decode.
    // Arrivals route over the prefill set; shipped KV routes over the
    // decode set. A 1-replica "fleet" cannot split — stay colocated.
    let disagg = cfg.disaggregate && n >= 2;
    let (arrival_set, decode_set): (Vec<usize>, Vec<usize>) = if disagg
    {
        let pn = (n / 2).max(1);
        ((0..pn).collect(), (pn..n).collect())
    } else {
        ((0..n).collect(), Vec::new())
    };
    if disagg {
        for &i in &arrival_set {
            workers[i].set_role(SimRole::Prefill);
        }
        for &i in &decode_set {
            workers[i].set_role(SimRole::Decode);
        }
    }
    let mut routed = vec![0usize; n];
    let mut dropped_unroutable = 0usize;
    let requests: Vec<SimRequest> = generate_workload(&cfg.base);
    let mut next = 0usize;
    let mut cursor = 0u64;
    let mut guard = 0u64;
    let mut killed = false;

    while (next < requests.len()
        || workers.iter().any(|w| w.has_work()))
        && guard < 2_000_000
    {
        guard += 1;
        // ---- route this round's arrivals ---------------------------
        for _ in 0..per_round {
            if next >= requests.len() {
                break;
            }
            let req = &requests[next];
            next += 1;
            let pick = route_one(&workers, policy, &req.tokens, cursor,
                                 &arrival_set);
            cursor += 1;
            match pick {
                Some(i) => {
                    if let Some(led) = ledger {
                        led.routed(req.id, i as u32,
                                   workers[i].now());
                    }
                    workers[i].deliver(req);
                    routed[i] += 1;
                }
                None => dropped_unroutable += 1,
            }
        }
        // ---- mid-run crash (fail-over sim) -------------------------
        if let Some(k) = cfg.kill {
            // A spec naming a replica that does not exist — or a
            // trigger point the workload never reaches — would make
            // the "crash" a silent no-op and the fail-over assertions
            // vacuous — reject both loudly instead.
            assert!(
                k.replica < workers.len(),
                "KillSpec.replica {} out of range for {} replicas",
                k.replica,
                workers.len()
            );
            assert!(
                k.after_delivered <= requests.len(),
                "KillSpec.after_delivered {} can never fire: only {} \
                 requests in the workload",
                k.after_delivered,
                requests.len()
            );
            if !killed && next >= k.after_delivered {
                killed = true;
                if !workers[k.replica].is_dead() {
                    let orphans = workers[k.replica].kill();
                    // Re-route every withdrawn request over the
                    // survivors; it restarts from scratch there (the
                    // recompute fail-over — no request is dropped
                    // while any replica lives).
                    for id in orphans {
                        let Some(req) =
                            requests.iter().find(|r| r.id == id)
                        else {
                            continue;
                        };
                        // Orphans restart from their prompt, so they
                        // re-route over the arrival set (a decode
                        // worker must never run prefill compute).
                        let pick = route_one(&workers, policy,
                                             &req.tokens, cursor,
                                             &arrival_set);
                        cursor += 1;
                        match pick {
                            Some(i) => {
                                if let Some(led) = ledger {
                                    led.routed(req.id, i as u32,
                                               workers[i].now());
                                }
                                workers[i].deliver(req);
                                routed[i] += 1;
                            }
                            None => dropped_unroutable += 1,
                        }
                    }
                }
            }
        }
        // ---- one lockstep tick per busy worker ---------------------
        for w in workers.iter_mut() {
            if w.has_work() {
                w.tick();
            }
        }
        // ---- ship finished prefills to decode workers --------------
        // Each handoff carries the KV's token history over the priced
        // inter-replica link; the receiving worker pays the transfer
        // on its clock at admission.
        if disagg {
            for pi in 0..n {
                if workers[pi].role() != SimRole::Prefill {
                    continue;
                }
                let handoffs = workers[pi].take_handoffs();
                for h in handoffs {
                    let pick = route_one(&workers, policy, &h.tokens,
                                         cursor, &decode_set);
                    cursor += 1;
                    match pick {
                        Some(i) => {
                            if let Some(led) = ledger {
                                led.routed(h.id, i as u32,
                                           workers[i].now());
                            }
                            routed[i] += 1;
                            workers[i].deliver_handoff(h);
                        }
                        None => dropped_unroutable += 1,
                    }
                }
            }
        }
    }

    let roles: Vec<SimRole> =
        workers.iter().map(|w| w.role()).collect();
    let per_worker: Vec<ReplayResult> = workers
        .into_iter()
        .map(|w| w.into_result("routed"))
        .collect();
    let fleet =
        PoolStats::aggregate(per_worker.iter().map(|r| &r.stats));
    let mut ttft = Histogram::new();
    let mut tbt = Histogram::new();
    let mut outputs = HashMap::new();
    let mut completed = 0;
    // Requests no live replica could take (whole fleet dead) count as
    // dropped — they must never vanish silently.
    let mut dropped = dropped_unroutable;
    let mut sim_time = 0.0f64;
    let mut ticks = 0u64;
    let mut transfer_time = 0.0f64;
    let mut transfer_bytes = 0u64;
    let mut fam: HashMap<SimFamily, FamilyStats> = HashMap::new();
    for r in &per_worker {
        for &v in r.ttft.samples() {
            ttft.record(v);
        }
        for &v in r.tbt.samples() {
            tbt.record(v);
        }
        for f in &r.families {
            let e = fam
                .entry(f.family)
                .or_insert_with(|| FamilyStats::empty(f.family));
            e.requests += f.requests;
            e.completed += f.completed;
            for &v in f.ttft.samples() {
                e.ttft.record(v);
            }
            for &v in f.tbt.samples() {
                e.tbt.record(v);
            }
            e.busy += f.busy;
            e.idle += f.idle;
        }
        outputs.extend(
            r.outputs.iter().map(|(k, v)| (*k, v.clone())),
        );
        completed += r.completed;
        dropped += r.dropped;
        sim_time = sim_time.max(r.sim_time);
        ticks += r.ticks;
        transfer_time += r.transfer_time;
        transfer_bytes += r.transfer_bytes;
    }
    let mut families: Vec<FamilyStats> = fam.into_values().collect();
    families.sort_by_key(|f| f.family);
    RoutingReplayResult {
        policy,
        replicas: n,
        per_worker,
        routed,
        fleet,
        ttft,
        tbt,
        completed,
        dropped,
        sim_time,
        ticks,
        outputs,
        roles,
        transfer_time,
        transfer_bytes,
        families,
    }
}

/// Run all three policies on the same workload (the `mmserve kv
/// --replicas N` comparison).
pub fn compare_policies(cfg: &RoutingReplayConfig)
                        -> Vec<RoutingReplayResult> {
    RoutingPolicy::ALL
        .iter()
        .map(|&p| routing_replay(cfg, p))
        .collect()
}

/// A/B the same workload colocated vs. disaggregated at equal replica
/// count under one policy (the `mmserve kv --disaggregate` engine).
/// Returns `(colocated, disaggregated)`.
pub fn compare_disaggregation(cfg: &RoutingReplayConfig,
                              policy: RoutingPolicy)
                              -> (RoutingReplayResult,
                                  RoutingReplayResult) {
    let colo = routing_replay(
        &RoutingReplayConfig { disaggregate: false, ..cfg.clone() },
        policy,
    );
    let disagg = routing_replay(
        &RoutingReplayConfig { disaggregate: true, ..cfg.clone() },
        policy,
    );
    (colo, disagg)
}

/// Colocated vs. disaggregated table: TTFT (which now explicitly
/// prices the KV handoff), decode-side TBT (every TBT sample in a
/// disaggregated fleet comes from a decode worker), and the fabric's
/// link traffic.
pub fn render_disagg_comparison(colo: &RoutingReplayResult,
                                disagg: &RoutingReplayResult)
                                -> String {
    let prefill_n = disagg
        .roles
        .iter()
        .filter(|&&r| r == SimRole::Prefill)
        .count();
    let mut t =
        Table::new(&["metric", "colocated", "disaggregated"]);
    let f2 = |x: f64| format!("{x:.2}");
    t.row(&["fleet split".into(),
            format!("{} colocated", colo.replicas),
            format!("{} prefill + {} decode", prefill_n,
                    disagg.replicas - prefill_n)]);
    t.row(&["p50 TTFT (sim)".into(),
            f2(colo.ttft.percentile(50.0)),
            f2(disagg.ttft.percentile(50.0))]);
    t.row(&["p99 TTFT (sim)".into(),
            f2(colo.ttft.percentile(99.0)),
            f2(disagg.ttft.percentile(99.0))]);
    t.row(&["mean TBT (decode, sim)".into(), f2(colo.tbt.mean()),
            f2(disagg.tbt.mean())]);
    t.row(&["p99 TBT (decode, sim)".into(),
            f2(colo.tbt.percentile(99.0)),
            f2(disagg.tbt.percentile(99.0))]);
    t.row(&["fabric transfer (sim)".into(), f2(colo.transfer_time),
            f2(disagg.transfer_time)]);
    t.row(&["fabric bytes moved".into(),
            colo.transfer_bytes.to_string(),
            disagg.transfer_bytes.to_string()]);
    t.row(&["link utilization".into(),
            format!("{:.1}%", colo.link_utilization() * 100.0),
            format!("{:.1}%", disagg.link_utilization() * 100.0)]);
    t.row(&["swap / recompute decisions".into(),
            format!("{}/{}", colo.fleet.swap_decisions,
                    colo.fleet.recompute_decisions),
            format!("{}/{}", disagg.fleet.swap_decisions,
                    disagg.fleet.recompute_decisions)]);
    t.row(&["preemptions".into(), colo.fleet.preemptions.to_string(),
            disagg.fleet.preemptions.to_string()]);
    t.row(&["requests completed".into(), colo.completed.to_string(),
            disagg.completed.to_string()]);
    t.row(&["fleet sim wall".into(), f2(colo.sim_time),
            f2(disagg.sim_time)]);
    t.render()
}

/// Policy comparison table: aggregate hit rate + simulated latency.
pub fn render_policy_comparison(results: &[RoutingReplayResult])
                                -> String {
    let mut t = Table::new(&[
        "metric",
        "round-robin",
        "least-loaded",
        "prefix-affinity",
    ]);
    let find = |p: RoutingPolicy| {
        results
            .iter()
            .find(|r| r.policy == p)
            .expect("policy result present")
    };
    let cols: [&RoutingReplayResult; 3] = [
        find(RoutingPolicy::RoundRobin),
        find(RoutingPolicy::LeastLoaded),
        find(RoutingPolicy::PrefixAffinity),
    ];
    let row3 = |label: &str, f: &dyn Fn(&RoutingReplayResult) -> String| {
        [label.to_string(), f(cols[0]), f(cols[1]), f(cols[2])]
    };
    t.row(&row3("aggregate prefix hit rate", &|r| {
        format!("{:.1}%", r.agg_hit_rate() * 100.0)
    }));
    t.row(&row3("prefix hit tokens", &|r| {
        r.fleet.prefix_hit_tokens.to_string()
    }));
    t.row(&row3("mean TTFT (sim)", &|r| {
        format!("{:.2}", r.ttft.mean())
    }));
    t.row(&row3("p99 TTFT (sim)", &|r| {
        format!("{:.2}", r.ttft.percentile(99.0))
    }));
    t.row(&row3("mean TBT (sim)", &|r| {
        format!("{:.2}", r.tbt.mean())
    }));
    t.row(&row3("p99 TBT (sim)", &|r| {
        format!("{:.2}", r.tbt.percentile(99.0))
    }));
    t.row(&row3("preemptions", &|r| {
        r.fleet.preemptions.to_string()
    }));
    t.row(&row3("LRU evictions", &|r| {
        r.fleet.evictions.to_string()
    }));
    t.row(&row3("requests completed", &|r| r.completed.to_string()));
    t.row(&row3("requests routed per worker", &|r| {
        r.routed
            .iter()
            .map(|c| c.to_string())
            .collect::<Vec<_>>()
            .join("/")
    }));
    t.row(&row3("fleet sim wall", &|r| format!("{:.2}", r.sim_time)));
    t.render()
}

/// Per-worker pool counters, labeled, plus the fleet aggregate —
/// fleet rates come from summed counters, never from averaging
/// per-worker rates (the `mmserve kv` labeling fix).
pub fn render_worker_counters(result: &RoutingReplayResult) -> String {
    let mut headers: Vec<String> = vec!["counter".into()];
    for i in 0..result.per_worker.len() {
        headers.push(format!("worker {i}"));
    }
    headers.push("fleet (summed)".into());
    let hdr_refs: Vec<&str> =
        headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr_refs);
    let row = |label: &str, f: &dyn Fn(&PoolStats) -> String| {
        let mut cells = vec![label.to_string()];
        for r in &result.per_worker {
            cells.push(f(&r.stats));
        }
        cells.push(f(&result.fleet));
        cells
    };
    t.row(&row("prefix lookups", &|s| s.prefix_lookups.to_string()));
    t.row(&row("prefix hits", &|s| s.prefix_hits.to_string()));
    t.row(&row("prefix hit rate", &|s| {
        format!("{:.1}%", s.hit_rate() * 100.0)
    }));
    t.row(&row("prefix hit tokens", &|s| {
        s.prefix_hit_tokens.to_string()
    }));
    t.row(&row("blocks allocated", &|s| {
        s.blocks_allocated.to_string()
    }));
    t.row(&row("evictions (LRU)", &|s| s.evictions.to_string()));
    t.row(&row("preemptions", &|s| s.preemptions.to_string()));
    t.row(&row("capacity-wait ticks", &|s| {
        s.capacity_wait_ticks.to_string()
    }));
    t.row(&row("sequences admitted", &|s| s.seqs_admitted.to_string()));
    t.row(&row("shard spills", &|s| s.shard_spills.to_string()));
    // Per-shard occupancy (mean live fraction per arena), per worker.
    let mut cells = vec!["mean shard occupancy".to_string()];
    for r in &result.per_worker {
        cells.push(crate::kvpool::replay::render_shard_util(
            &r.shard_utilization,
        ));
    }
    cells.push("-".into());
    t.row(&cells);
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::kvpool::replay::MixSpec;

    fn cfg2() -> RoutingReplayConfig {
        RoutingReplayConfig::default()
    }

    /// Mixed fleet behind one router: the per-worker family slices
    /// reassemble exactly into the fleet's per-modality view.
    #[test]
    fn fleet_merges_per_family_slices() {
        let cfg = RoutingReplayConfig {
            base: ReplayConfig {
                mix: Some(MixSpec::parse("seamless:30,hstu:30", 2)
                    .unwrap()),
                ..ReplayConfig::default()
            },
            ..RoutingReplayConfig::default()
        };
        let r = routing_replay(&cfg, RoutingPolicy::RoundRobin);
        assert_eq!(r.completed, cfg.base.requests);
        assert_eq!(r.families.len(), 3, "all three families served");
        let mut sum: HashMap<SimFamily, (usize, usize, usize)> =
            HashMap::new();
        for w in &r.per_worker {
            for f in &w.families {
                let e = sum.entry(f.family).or_default();
                e.0 += f.requests;
                e.1 += f.completed;
                e.2 += f.ttft.len();
            }
        }
        let mut completed = 0;
        for f in &r.families {
            let e = sum[&f.family];
            assert_eq!(f.requests, e.0, "{:?}", f.family);
            assert_eq!(f.completed, e.1, "{:?}", f.family);
            assert_eq!(f.ttft.len(), e.2, "{:?}", f.family);
            completed += f.completed;
        }
        assert_eq!(completed, r.completed,
                   "family slices partition the fleet's completions");
        let hstu = r.families.iter()
            .find(|f| f.family == SimFamily::Hstu).unwrap();
        assert!(hstu.tbt.is_empty(), "zero decode ticks fleet-wide");
    }

    /// Acceptance criterion (tentpole): on a workload where every
    /// request shares one of a few system prompts (≥50% share one),
    /// PrefixAffinity achieves a strictly higher aggregate prefix hit
    /// rate than RoundRobin with 2+ replicas — and the per-request
    /// token outputs are identical across policies for a fixed seed
    /// (routing moves work, it must never change results).
    #[test]
    fn prefix_affinity_beats_round_robin_with_identical_outputs() {
        let cfg = cfg2();
        // Precondition of the criterion: ≥ 50% of requests share one
        // system prompt (2 tenants ⇒ the larger covers ≥ half).
        let w = generate_workload(&cfg.base);
        let shared = (0..cfg.base.tenants)
            .map(|t| w.iter().filter(|r| r.tenant == t).count())
            .max()
            .unwrap();
        assert!(shared * 2 >= cfg.base.requests,
                "workload precondition: {shared}/{} share a prompt",
                cfg.base.requests);
        let rr = routing_replay(&cfg, RoutingPolicy::RoundRobin);
        let pa = routing_replay(&cfg, RoutingPolicy::PrefixAffinity);
        let n = cfg.base.requests;
        assert_eq!(rr.completed + rr.dropped, n);
        assert_eq!(pa.completed + pa.dropped, n);
        assert_eq!(rr.dropped, 0, "{rr:?}");
        assert_eq!(pa.dropped, 0, "{pa:?}");
        assert!(
            pa.agg_hit_rate() > rr.agg_hit_rate(),
            "prefix-affinity {:.3} must strictly beat round-robin {:.3}",
            pa.agg_hit_rate(),
            rr.agg_hit_rate()
        );
        // More shared tokens never re-prefilled, fleet-wide.
        assert!(pa.fleet.prefix_hit_tokens > rr.fleet.prefix_hit_tokens);
        // Identical token outputs: same requests, same streams.
        assert_eq!(pa.outputs.len(), n);
        assert_eq!(pa.outputs, rr.outputs,
                   "routing must not change decoded tokens");
    }

    #[test]
    fn routing_replay_is_deterministic() {
        let cfg = cfg2();
        for policy in RoutingPolicy::ALL {
            let a = routing_replay(&cfg, policy);
            let b = routing_replay(&cfg, policy);
            assert_eq!(a.routed, b.routed, "{policy}");
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.sim_time, b.sim_time);
            assert_eq!(a.fleet.prefix_hits, b.fleet.prefix_hits);
            assert_eq!(a.outputs, b.outputs);
        }
    }

    #[test]
    fn round_robin_spreads_and_affinity_concentrates_tenants() {
        let cfg = cfg2();
        let rr = routing_replay(&cfg, RoutingPolicy::RoundRobin);
        // Round-robin alternates exactly.
        let total: usize = rr.routed.iter().sum();
        assert_eq!(total, cfg.base.requests);
        assert!(rr.routed.iter().all(|&c| c > 0));
        let spread =
            rr.routed.iter().max().unwrap() - rr.routed.iter().min().unwrap();
        assert!(spread <= 1, "round-robin must balance: {:?}", rr.routed);
        // Every worker routed to under affinity still completes work
        // (no starvation), and all requests land somewhere.
        let pa = routing_replay(&cfg, RoutingPolicy::PrefixAffinity);
        assert_eq!(pa.routed.iter().sum::<usize>(), cfg.base.requests);
    }

    #[test]
    fn single_replica_reduces_to_plain_replay_counters() {
        let cfg = RoutingReplayConfig {
            replicas: 1,
            ..RoutingReplayConfig::default()
        };
        let r = routing_replay(&cfg, RoutingPolicy::PrefixAffinity);
        assert_eq!(r.per_worker.len(), 1);
        assert_eq!(r.routed, vec![cfg.base.requests]);
        assert_eq!(r.completed, cfg.base.requests);
        // Fleet aggregate of one worker is that worker's counters.
        assert_eq!(r.fleet.prefix_hits, r.per_worker[0].stats.prefix_hits);
    }

    /// Satellite: kill a replica mid-workload — no request may be
    /// dropped (orphans re-route to survivors and restart from
    /// scratch), and the decoded streams stay exactly the no-kill
    /// streams (seeded, deterministic): fail-over moves work, it must
    /// never change results.
    #[test]
    fn replica_crash_fails_over_without_losing_requests() {
        let cfg = RoutingReplayConfig {
            kill: Some(KillSpec { replica: 1, after_delivered: 20 }),
            ..RoutingReplayConfig::default()
        };
        let baseline =
            routing_replay(&RoutingReplayConfig::default(),
                           RoutingPolicy::PrefixAffinity);
        let crashed =
            routing_replay(&cfg, RoutingPolicy::PrefixAffinity);
        let n = cfg.base.requests;
        assert_eq!(crashed.completed, n, "no request lost to the crash");
        assert_eq!(crashed.dropped, 0);
        assert_eq!(crashed.outputs.len(), n);
        assert_eq!(crashed.outputs, baseline.outputs,
                   "fail-over must not change decoded tokens");
        // The survivor carried the evacuated work.
        assert!(crashed.per_worker[0].completed
                    > baseline.per_worker[0].completed,
                "survivor picked up the dead replica's requests");
        // Deterministic: same spec, same result.
        let again = routing_replay(&cfg, RoutingPolicy::PrefixAffinity);
        assert_eq!(again.outputs, crashed.outputs);
        assert_eq!(again.routed, crashed.routed);
    }

    /// Fail-over under every policy, over *sharded* workers: the
    /// lockstep sim keeps all requests and streams intact regardless
    /// of how the policy spreads them.
    #[test]
    fn replica_crash_fails_over_under_every_policy_sharded() {
        let cfg = RoutingReplayConfig {
            base: ReplayConfig {
                tenants: 2,
                shards: 2,
                ..ReplayConfig::default()
            },
            replicas: 3,
            kill: Some(KillSpec { replica: 0, after_delivered: 30 }),
            ..RoutingReplayConfig::default()
        };
        let n = cfg.base.requests;
        let mut streams: Option<HashMap<u64, Vec<i32>>> = None;
        for policy in RoutingPolicy::ALL {
            let r = routing_replay(&cfg, policy);
            assert_eq!(r.completed, n, "{policy}");
            assert_eq!(r.dropped, 0, "{policy}");
            if let Some(s) = &streams {
                assert_eq!(&r.outputs, s, "{policy} changed streams");
            } else {
                streams = Some(r.outputs);
            }
        }
    }

    /// Tentpole: the lockstep comparison over sharded workers — the
    /// policy ranking runs on shard-set probes, every worker reports
    /// per-shard occupancy, and prefix-affinity still strictly beats
    /// round-robin on the aggregate hit rate with identical outputs.
    #[test]
    fn sharded_workers_keep_the_affinity_win_and_report_occupancy() {
        let cfg = RoutingReplayConfig {
            base: ReplayConfig {
                tenants: 2,
                shards: 2,
                ..ReplayConfig::default()
            },
            ..RoutingReplayConfig::default()
        };
        let rr = routing_replay(&cfg, RoutingPolicy::RoundRobin);
        let pa = routing_replay(&cfg, RoutingPolicy::PrefixAffinity);
        assert_eq!(rr.dropped + pa.dropped, 0);
        assert_eq!(rr.completed, cfg.base.requests);
        assert_eq!(pa.completed, cfg.base.requests);
        assert!(
            pa.agg_hit_rate() > rr.agg_hit_rate(),
            "sharded workers: affinity {:.3} !> round-robin {:.3}",
            pa.agg_hit_rate(),
            rr.agg_hit_rate()
        );
        assert_eq!(pa.outputs, rr.outputs);
        for w in &pa.per_worker {
            assert_eq!(w.shard_utilization.len(), 2,
                       "per-shard occupancy per worker");
        }
        let table = render_worker_counters(&pa);
        assert!(table.contains("mean shard occupancy"));
        assert!(table.contains("shard spills"));
    }

    /// Tentpole acceptance (fleet form): the live plane on a sharded
    /// multi-replica replay exposes one TTFT/TBT sketch row per
    /// replica and per tenant whose merged totals equal the post-hoc
    /// fleet histograms, per-shard page gauges per replica, and — on
    /// an injected [`KillSpec`] crash — a `replica-crash` flight dump;
    /// routing and outputs are untouched by observation.
    #[test]
    fn fleet_live_plane_matches_posthoc_and_dumps_on_crash() {
        use crate::telemetry::live::sampler::{LIVE_PAGES, TBT_MS,
                                              TTFT_MS};
        let cfg = RoutingReplayConfig {
            base: ReplayConfig {
                tenants: 2,
                shards: 2,
                ..ReplayConfig::default()
            },
            replicas: 3,
            kill: Some(KillSpec { replica: 1, after_delivered: 20 }),
            ..RoutingReplayConfig::default()
        };
        let live = LiveMetrics::new();
        let rec = FlightRecorder::new(64);
        let r = routing_replay_live(&cfg, RoutingPolicy::PrefixAffinity,
                                    &live, &rec);
        let bare =
            routing_replay(&cfg, RoutingPolicy::PrefixAffinity);
        assert_eq!(r.outputs, bare.outputs, "observation must not route");
        assert_eq!(r.routed, bare.routed);
        assert_eq!(r.completed, cfg.base.requests);

        let snap = live.snapshot();
        // Per-replica rows: the two survivors sampled TTFT; fleet
        // merge equals the post-hoc fleet histogram exactly in count.
        let replicas = snap.sketch_label_values(TTFT_MS, "replica");
        assert!(replicas.len() >= 2, "live replicas publish: {replicas:?}");
        let mut fleet_ttft = 0u64;
        for rep in &replicas {
            fleet_ttft +=
                snap.merged_sketch(TTFT_MS, "replica", rep).count;
        }
        assert_eq!(fleet_ttft, r.ttft.len() as u64);
        // Per-tenant rows cover both tenants.
        assert_eq!(snap.sketch_label_values(TBT_MS, "tenant").len(),
                   cfg.base.tenants);
        // Per-shard page gauges exist for each live replica's shards.
        for rep in &replicas {
            for shard in ["0", "1"] {
                assert!(snap
                            .gauge(LIVE_PAGES,
                                   &[("replica", rep.as_str()),
                                     ("shard", shard)])
                            .is_some(),
                        "live_pages{{replica={rep},shard={shard}}}");
            }
        }
        // The injected crash dumped the flight ring as valid JSONL.
        let dumps = rec.dumps();
        let crash: Vec<_> = dumps
            .iter()
            .filter(|d| d.reason == "replica-crash")
            .collect();
        assert_eq!(crash.len(), 1, "one crash, one dump");
        for line in crash[0].jsonl.lines() {
            crate::substrate::json::Json::parse(line)
                .expect("flight dump line is valid JSON");
        }
    }

    /// Tentpole (fleet form): with a mid-run crash, the causal ledger
    /// follows every request across the router — evacuated requests
    /// carry a second `routed` event to a survivor and restart their
    /// TTFT clock there — while the instrumented run stays
    /// bit-identical to the bare one.
    #[test]
    fn ledger_follows_requests_across_failover() {
        let cfg = RoutingReplayConfig {
            kill: Some(KillSpec { replica: 1, after_delivered: 20 }),
            ..RoutingReplayConfig::default()
        };
        let bare = routing_replay(&cfg, RoutingPolicy::PrefixAffinity);
        let ledger = RequestLedger::new();
        let r = routing_replay_instrumented(
            &cfg, RoutingPolicy::PrefixAffinity, &LiveMetrics::off(),
            &FlightRecorder::disabled(), &ledger);
        assert_eq!(r.outputs, bare.outputs, "ledger must not route");
        assert_eq!(r.routed, bare.routed);
        assert_eq!(r.sim_time, bare.sim_time);
        assert_eq!(r.completed, cfg.base.requests);
        assert!(r.ticks > 0);

        let snap = ledger.snapshot();
        assert_eq!(snap.completed().len(), cfg.base.requests);
        let mut deliveries = 0usize;
        let mut rerouted = 0usize;
        for rec in &snap.requests {
            let labels: Vec<&str> =
                rec.events.iter().map(|e| e.ev.label()).collect();
            assert_eq!(labels.first(), Some(&"routed"),
                       "req {} chain starts at the router", rec.id);
            assert_eq!(labels.last(), Some(&"completed"));
            let routes =
                labels.iter().filter(|&&l| l == "routed").count();
            deliveries += routes;
            if routes > 1 {
                rerouted += 1;
                // The record's final replica is a survivor.
                assert_ne!(rec.replica, 1, "req {} must not end on \
                                            the dead replica", rec.id);
            }
            assert_eq!(rec.decoded as usize, r.outputs[&rec.id].len());
        }
        assert_eq!(deliveries, r.routed.iter().sum::<usize>(),
                   "one routed event per delivery, fleet-wide");
        assert!(rerouted > 0, "the crash must re-route someone");
    }

    /// Satellite: ledger/live parity on the fleet — identical sample
    /// counts and rank-matched quantiles between the shared ledger
    /// and the fleet-merged live sketches, on random replica/tenant
    /// mixes (no kill: a crash legitimately desyncs the planes'
    /// sample sets mid-flight).
    #[test]
    fn prop_ledger_live_parity_routing() {
        use crate::substrate::prop::prop_check;
        use crate::telemetry::live::sampler::{TBT_MS, TTFT_MS};
        use crate::telemetry::live::sketch::{SketchSnapshot,
                                             DEFAULT_ALPHA};
        fn exact_pct(vals: &[f64], p: f64) -> f64 {
            let mut v = vals.to_vec();
            v.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
            if v.is_empty() {
                return 0.0;
            }
            let rank =
                ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
            v[rank.min(v.len() - 1)]
        }
        prop_check(
            24,
            0xf1ee7,
            |rng| (rng.usize(2, 4), rng.usize(1, 4)),
            |&(replicas, tenants)| {
                let cfg = RoutingReplayConfig {
                    base: ReplayConfig {
                        requests: 32,
                        tenants: tenants.max(1),
                        ..ReplayConfig::default()
                    },
                    replicas: replicas.max(1),
                    ..RoutingReplayConfig::default()
                };
                let bare =
                    routing_replay(&cfg, RoutingPolicy::PrefixAffinity);
                let live = LiveMetrics::new();
                let ledger = RequestLedger::new();
                let r = routing_replay_instrumented(
                    &cfg, RoutingPolicy::PrefixAffinity, &live,
                    &FlightRecorder::disabled(), &ledger);
                if r.outputs != bare.outputs || r.routed != bare.routed
                {
                    return Err("instrumented fleet diverged".into());
                }
                let snap = live.snapshot();
                let led = ledger.snapshot();
                for (name, vals) in [(TTFT_MS, led.ttft_values()),
                                     (TBT_MS, led.tbt_values())] {
                    let mut merged = SketchSnapshot::empty();
                    for rep in
                        snap.sketch_label_values(name, "replica")
                    {
                        merged.merge(&snap.merged_sketch(
                            name, "replica", &rep));
                    }
                    if merged.count != vals.len() as u64 {
                        return Err(format!(
                            "{name}: ledger {} vs fleet {} samples",
                            vals.len(), merged.count));
                    }
                    for p in [50.0, 99.0] {
                        let s = merged.percentile(p);
                        let e = exact_pct(&vals, p);
                        if (s - e).abs() > DEFAULT_ALPHA * e + 1e-9 {
                            return Err(format!(
                                "{name} p{p}: ledger {e} vs \
                                 sketch {s}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    /// Long-prompt, shared-prefix mix at paper-scale fabric pricing —
    /// the regime the disaggregation acceptance criterion names.
    fn disagg_cfg() -> RoutingReplayConfig {
        use crate::perfmodel::fabric::FabricSpec;
        RoutingReplayConfig {
            base: ReplayConfig {
                requests: 48,
                tenants: 2,
                long_percent: 50,
                long_prompt: (96, 200),
                total_pages: 192,
                batch_slots: 12,
                fabric: Some(FabricSpec::paper(524_288.0)),
                ..ReplayConfig::default()
            },
            replicas: 2,
            ..RoutingReplayConfig::default()
        }
    }

    /// Tentpole acceptance: on a long-prompt shared-prefix workload,
    /// splitting the same 2 replicas into 1 prefill + 1 decode worker
    /// strictly improves decode-worker TBT p99 over colocated —
    /// prefill compute never lands on the decode clock — while the
    /// KV handoff is explicitly priced (non-zero fleet transfer) and
    /// the decoded streams are bit-identical.
    #[test]
    fn disaggregation_improves_decode_tbt_tail_at_equal_replicas() {
        let cfg = disagg_cfg();
        let (colo, disagg) =
            compare_disaggregation(&cfg, RoutingPolicy::LeastLoaded);
        let n = cfg.base.requests;
        assert_eq!(colo.completed, n, "{colo:?}");
        assert_eq!(disagg.completed, n, "{disagg:?}");
        assert_eq!(colo.dropped + disagg.dropped, 0);
        assert_eq!(disagg.outputs, colo.outputs,
                   "disaggregation moves KV, never tokens");
        assert_eq!(disagg.roles,
                   vec![SimRole::Prefill, SimRole::Decode]);
        assert!(colo.roles.iter().all(|&r| r == SimRole::Colocated));
        // The split is real: the decode worker ran zero prefill
        // compute and the prefill worker decoded nothing.
        assert_eq!(disagg.per_worker[1].max_tick_prefill_tokens, 0);
        assert_eq!(disagg.per_worker[0].completed, 0);
        // Acceptance: decode-side TBT p99 improves at equal replicas.
        assert!(
            disagg.tbt.percentile(99.0) < colo.tbt.percentile(99.0),
            "disaggregated p99 TBT {:.2} !< colocated {:.2}",
            disagg.tbt.percentile(99.0),
            colo.tbt.percentile(99.0)
        );
        // The handoff cost is real: priced, non-zero link traffic.
        assert!(disagg.transfer_bytes > 0);
        assert!(disagg.transfer_time > 0.0);
        assert!(disagg.link_utilization() > 0.0);
        // One TTFT sample per request, measured across the whole
        // queue + prefill + handoff + admission path.
        assert_eq!(disagg.ttft.len(), n);
        let table = render_disagg_comparison(&colo, &disagg);
        assert!(table.contains("link utilization"));
        assert!(table.contains("p99 TBT (decode, sim)"));
        assert!(table.contains("1 prefill + 1 decode"));
    }

    #[test]
    fn disaggregated_replay_is_deterministic_and_needs_two_replicas() {
        let cfg = RoutingReplayConfig {
            disaggregate: true,
            ..disagg_cfg()
        };
        let a = routing_replay(&cfg, RoutingPolicy::LeastLoaded);
        let b = routing_replay(&cfg, RoutingPolicy::LeastLoaded);
        assert_eq!(a.outputs, b.outputs);
        assert_eq!(a.sim_time, b.sim_time);
        assert_eq!(a.routed, b.routed);
        assert_eq!(a.transfer_bytes, b.transfer_bytes);
        // A 1-replica fleet cannot split: the flag is inert and the
        // run stays a plain colocated replay.
        let one = routing_replay(
            &RoutingReplayConfig { replicas: 1, ..cfg.clone() },
            RoutingPolicy::LeastLoaded,
        );
        assert!(one.roles.iter().all(|&r| r == SimRole::Colocated));
        assert_eq!(one.completed, cfg.base.requests);
    }

    /// Tentpole acceptance (ledger form): every disaggregated request
    /// carries a non-zero, byte-sized `transfer` phase in its causal
    /// chain, and the ledger's per-request transfer bytes reconcile
    /// exactly with the fleet total.
    #[test]
    fn disaggregated_ledger_records_priced_transfers() {
        let cfg = RoutingReplayConfig {
            disaggregate: true,
            ..disagg_cfg()
        };
        let ledger = RequestLedger::new();
        let r = routing_replay_instrumented(
            &cfg, RoutingPolicy::LeastLoaded, &LiveMetrics::off(),
            &FlightRecorder::disabled(), &ledger);
        assert_eq!(r.completed, cfg.base.requests);
        let snap = ledger.snapshot();
        let mut bytes = 0u64;
        let mut with_transfer = 0usize;
        for rec in &snap.requests {
            bytes += rec.transfer_bytes;
            if rec.transfer_bytes > 0 {
                with_transfer += 1;
                assert!(rec.transfer_time > 0.0, "req {}", rec.id);
                assert!(rec.events.iter()
                            .any(|e| e.ev.label() == "transfer"),
                        "req {} chain has the transfer phase", rec.id);
            }
        }
        assert_eq!(with_transfer, cfg.base.requests,
                   "every handoff is priced in the ledger");
        assert_eq!(bytes, r.transfer_bytes,
                   "ledger bytes reconcile with the fleet total");
    }

    /// Satellite (zero-denominator guards): an empty-fleet replay —
    /// zero requests, so zero prefix lookups, zero ticks, zero
    /// duration — must report 0.0 aggregates, never NaN (the CI gate
    /// compares these values numerically).
    #[test]
    fn empty_fleet_aggregates_are_zero_not_nan() {
        let cfg = RoutingReplayConfig {
            base: ReplayConfig {
                requests: 0,
                ..ReplayConfig::default()
            },
            ..RoutingReplayConfig::default()
        };
        let r = routing_replay(&cfg, RoutingPolicy::PrefixAffinity);
        assert_eq!(r.completed, 0);
        assert_eq!(r.fleet.prefix_lookups, 0);
        assert_eq!(r.agg_hit_rate(), 0.0, "no lookups ⇒ 0.0, not NaN");
        assert!(r.agg_hit_rate().is_finite());
        assert_eq!(r.sim_time, 0.0);
        assert_eq!(r.link_utilization(), 0.0,
                   "zero duration ⇒ 0.0, not NaN");
        assert!(r.link_utilization().is_finite());
    }

    /// Satellite (zero-denominator guards): instant completion — a
    /// synthetic zero-duration result that somehow carries transfer
    /// time must still divide to 0.0, not inf.
    #[test]
    fn instant_completion_link_utilization_is_finite() {
        let cfg = RoutingReplayConfig {
            base: ReplayConfig {
                requests: 0,
                ..ReplayConfig::default()
            },
            ..RoutingReplayConfig::default()
        };
        let mut r = routing_replay(&cfg, RoutingPolicy::LeastLoaded);
        r.sim_time = 0.0;
        r.transfer_time = 3.5;
        assert_eq!(r.link_utilization(), 0.0);
        // And a degenerate negative-duration clock (can only come
        // from a future accounting bug) still never divides.
        r.sim_time = -1.0;
        assert_eq!(r.link_utilization(), 0.0);
    }

    #[test]
    fn comparison_tables_render() {
        let cfg = RoutingReplayConfig {
            base: ReplayConfig {
                requests: 16,
                tenants: 2,
                ..ReplayConfig::default()
            },
            ..RoutingReplayConfig::default()
        };
        let results = compare_policies(&cfg);
        assert_eq!(results.len(), 3);
        let s = render_policy_comparison(&results);
        assert!(s.contains("aggregate prefix hit rate"));
        assert!(s.contains("prefix-affinity"));
        assert!(s.contains("requests routed per worker"));
        let w = render_worker_counters(&results[2]);
        assert!(w.contains("worker 0"));
        assert!(w.contains("worker 1"));
        assert!(w.contains("fleet (summed)"));
    }
}
