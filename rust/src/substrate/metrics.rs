//! Latency histograms, percentile summaries, and scoped timers.
//!
//! The paper's characterization methodology (Figs 3–4) is built on
//! per-operator wall-time accounting and end-to-end latency
//! distributions; this module is the measurement substrate for both.

use std::collections::BTreeMap;
use std::time::Instant;

/// Reservoir of raw samples with percentile queries (exact, sorted on
/// demand — sample counts here are small enough that this is fine).
#[derive(Default, Clone, Debug)]
pub struct Histogram {
    samples: Vec<f64>,
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
    }
    pub fn len(&self) -> usize {
        self.samples.len()
    }
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }
    /// Smallest sample (0.0 on an empty reservoir, matching `mean()`).
    pub fn min(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }
    /// Largest sample (0.0 on an empty reservoir, matching `mean()`).
    pub fn max(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }
    pub fn stddev(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m).powi(2)).sum::<f64>()
            / self.samples.len() as f64)
            .sqrt()
    }
    /// p in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.3} p50={:.3} p95={:.3} p99={:.3} min={:.3} max={:.3}",
            self.len(),
            self.mean(),
            self.percentile(50.0),
            self.percentile(95.0),
            self.percentile(99.0),
            self.min(),
            self.max()
        )
    }
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// Named wall-time accumulators — the operator-breakdown collector.
/// Keys are operator categories ("Linear", "Attention", "KV_Reorder",
/// "Idle", …) exactly as in the paper's Figure 4.
#[derive(Default, Clone, Debug)]
pub struct OpTimes {
    acc: BTreeMap<String, f64>,
}

impl OpTimes {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn add(&mut self, key: &str, secs: f64) {
        *self.acc.entry(key.to_string()).or_insert(0.0) += secs;
    }
    pub fn merge(&mut self, other: &OpTimes) {
        for (k, v) in &other.acc {
            self.add(k, *v);
        }
    }
    pub fn get(&self, key: &str) -> f64 {
        self.acc.get(key).copied().unwrap_or(0.0)
    }
    pub fn total(&self) -> f64 {
        self.acc.values().sum()
    }
    pub fn entries(&self) -> impl Iterator<Item = (&str, f64)> {
        self.acc.iter().map(|(k, v)| (k.as_str(), *v))
    }
    /// Fractions summing to 1 (empty → empty).
    pub fn fractions(&self) -> Vec<(String, f64)> {
        let t = self.total();
        if t == 0.0 {
            return vec![];
        }
        self.acc.iter().map(|(k, v)| (k.clone(), v / t)).collect()
    }
}

/// RAII timer recording into an `OpTimes` on drop.
pub struct ScopedTimer<'a> {
    times: &'a mut OpTimes,
    key: &'a str,
    start: Instant,
}

impl<'a> ScopedTimer<'a> {
    pub fn new(times: &'a mut OpTimes, key: &'a str) -> Self {
        ScopedTimer { times, key, start: Instant::now() }
    }
}

impl Drop for ScopedTimer<'_> {
    fn drop(&mut self) {
        self.times.add(self.key, self.start.elapsed().as_secs_f64());
    }
}

/// Throughput/latency counters for a serving run.
#[derive(Default, Debug, Clone)]
pub struct ServeStats {
    pub requests_completed: u64,
    pub tokens_generated: u64,
    pub prefill_tokens: u64,
    pub wall_secs: f64,
    pub ttft: Histogram,
    pub tpot: Histogram,
    pub e2e: Histogram,
}

impl ServeStats {
    pub fn throughput_tok_s(&self) -> f64 {
        if self.wall_secs == 0.0 {
            return 0.0;
        }
        self.tokens_generated as f64 / self.wall_secs
    }
    pub fn requests_per_s(&self) -> f64 {
        if self.wall_secs == 0.0 {
            return 0.0;
        }
        self.requests_completed as f64 / self.wall_secs
    }
    pub fn report(&self) -> String {
        format!(
            "requests={} tokens={} wall={:.2}s thpt={:.1} tok/s \
             ttft(ms) [{}] tpot(ms) [{}] e2e(ms) [{}]",
            self.requests_completed,
            self.tokens_generated,
            self.wall_secs,
            self.throughput_tok_s(),
            self.ttft.summary(),
            self.tpot.summary(),
            self.e2e.summary(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.percentile(0.0), 1.0);
        assert_eq!(h.percentile(100.0), 100.0);
        assert!((h.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((h.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn op_times_accumulate_and_fraction() {
        let mut t = OpTimes::new();
        t.add("Linear", 3.0);
        t.add("Attention", 1.0);
        t.add("Linear", 1.0);
        assert_eq!(t.get("Linear"), 4.0);
        assert_eq!(t.total(), 5.0);
        let f = t.fractions();
        let lin = f.iter().find(|(k, _)| k == "Linear").unwrap().1;
        assert!((lin - 0.8).abs() < 1e-12);
    }

    #[test]
    fn scoped_timer_records() {
        let mut t = OpTimes::new();
        {
            let _g = ScopedTimer::new(&mut t, "op");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(t.get("op") >= 0.004);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Histogram::new();
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn empty_histogram_min_max_finite() {
        // Regression: these used to return ±INFINITY on an empty
        // reservoir, leaking "inf" into report strings.
        let h = Histogram::new();
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert!(!h.summary().contains("inf"), "{}", h.summary());
        let mut h = Histogram::new();
        h.record(-2.5);
        h.record(4.0);
        assert_eq!(h.min(), -2.5);
        assert_eq!(h.max(), 4.0);
    }
}
