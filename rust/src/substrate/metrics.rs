//! Latency histograms, percentile summaries, and scoped timers.
//!
//! The paper's characterization methodology (Figs 3–4) is built on
//! per-operator wall-time accounting and end-to-end latency
//! distributions; this module is the measurement substrate for both.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::time::Instant;

/// Reservoir of raw samples with percentile queries (exact). The
/// sorted view is computed once and cached until the next `record` —
/// `summary()` used to clone-and-sort three times — and min/max are
/// tracked as running values, O(1) per query.
#[derive(Default, Clone, Debug)]
pub struct Histogram {
    samples: Vec<f64>,
    min: f64,
    max: f64,
    /// Sorted copy of `samples`; valid iff same length (records only
    /// append, so a length match means nothing changed).
    sorted: RefCell<Vec<f64>>,
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn record(&mut self, v: f64) {
        if self.samples.is_empty() {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.samples.push(v);
    }
    pub fn len(&self) -> usize {
        self.samples.len()
    }
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }
    /// Smallest sample (0.0 on an empty reservoir, matching `mean()`).
    pub fn min(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.min
    }
    /// Largest sample (0.0 on an empty reservoir, matching `mean()`).
    pub fn max(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.max
    }
    pub fn stddev(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m).powi(2)).sum::<f64>()
            / self.samples.len() as f64)
            .sqrt()
    }
    /// p in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut cache = self.sorted.borrow_mut();
        if cache.len() != self.samples.len() {
            cache.clear();
            cache.extend_from_slice(&self.samples);
            cache.sort_by(|a, b| a.total_cmp(b));
        }
        let idx =
            ((p / 100.0) * (cache.len() - 1) as f64).round() as usize;
        cache[idx.min(cache.len() - 1)]
    }
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.3} p50={:.3} p95={:.3} p99={:.3} min={:.3} max={:.3}",
            self.len(),
            self.mean(),
            self.percentile(50.0),
            self.percentile(95.0),
            self.percentile(99.0),
            self.min(),
            self.max()
        )
    }
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// Named wall-time accumulators — the operator-breakdown collector.
/// Keys are operator categories ("Linear", "Attention", "KV_Reorder",
/// "Idle", …) exactly as in the paper's Figure 4.
#[derive(Default, Clone, Debug)]
pub struct OpTimes {
    acc: BTreeMap<String, f64>,
}

impl OpTimes {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn add(&mut self, key: &str, secs: f64) {
        *self.acc.entry(key.to_string()).or_insert(0.0) += secs;
    }
    pub fn merge(&mut self, other: &OpTimes) {
        for (k, v) in &other.acc {
            self.add(k, *v);
        }
    }
    pub fn get(&self, key: &str) -> f64 {
        self.acc.get(key).copied().unwrap_or(0.0)
    }
    pub fn total(&self) -> f64 {
        self.acc.values().sum()
    }
    pub fn entries(&self) -> impl Iterator<Item = (&str, f64)> {
        self.acc.iter().map(|(k, v)| (k.as_str(), *v))
    }
    /// Fractions summing to 1 (empty → empty).
    pub fn fractions(&self) -> Vec<(String, f64)> {
        let t = self.total();
        if t == 0.0 {
            return vec![];
        }
        self.acc.iter().map(|(k, v)| (k.clone(), v / t)).collect()
    }
}

/// RAII timer recording into an `OpTimes` on drop.
pub struct ScopedTimer<'a> {
    times: &'a mut OpTimes,
    key: &'a str,
    start: Instant,
}

impl<'a> ScopedTimer<'a> {
    pub fn new(times: &'a mut OpTimes, key: &'a str) -> Self {
        ScopedTimer { times, key, start: Instant::now() }
    }
}

impl Drop for ScopedTimer<'_> {
    fn drop(&mut self) {
        self.times.add(self.key, self.start.elapsed().as_secs_f64());
    }
}

/// Throughput/latency counters for a serving run.
#[derive(Default, Debug, Clone)]
pub struct ServeStats {
    pub requests_completed: u64,
    pub tokens_generated: u64,
    pub prefill_tokens: u64,
    pub wall_secs: f64,
    pub ttft: Histogram,
    pub tpot: Histogram,
    pub e2e: Histogram,
}

impl ServeStats {
    pub fn throughput_tok_s(&self) -> f64 {
        if self.wall_secs == 0.0 {
            return 0.0;
        }
        self.tokens_generated as f64 / self.wall_secs
    }
    pub fn requests_per_s(&self) -> f64 {
        if self.wall_secs == 0.0 {
            return 0.0;
        }
        self.requests_completed as f64 / self.wall_secs
    }
    pub fn report(&self) -> String {
        format!(
            "requests={} tokens={} wall={:.2}s thpt={:.1} tok/s \
             ttft(ms) [{}] tpot(ms) [{}] e2e(ms) [{}]",
            self.requests_completed,
            self.tokens_generated,
            self.wall_secs,
            self.throughput_tok_s(),
            self.ttft.summary(),
            self.tpot.summary(),
            self.e2e.summary(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.percentile(0.0), 1.0);
        assert_eq!(h.percentile(100.0), 100.0);
        assert!((h.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((h.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn op_times_accumulate_and_fraction() {
        let mut t = OpTimes::new();
        t.add("Linear", 3.0);
        t.add("Attention", 1.0);
        t.add("Linear", 1.0);
        assert_eq!(t.get("Linear"), 4.0);
        assert_eq!(t.total(), 5.0);
        let f = t.fractions();
        let lin = f.iter().find(|(k, _)| k == "Linear").unwrap().1;
        assert!((lin - 0.8).abs() < 1e-12);
    }

    #[test]
    fn scoped_timer_records() {
        let mut t = OpTimes::new();
        {
            let _g = ScopedTimer::new(&mut t, "op");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(t.get("op") >= 0.004);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Histogram::new();
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    /// Regression for the cached sorted view: percentile queries
    /// interleaved with records must always see the latest samples,
    /// and min/max (now running values) must match a full fold.
    #[test]
    fn percentile_cache_invalidates_on_record() {
        let mut h = Histogram::new();
        for i in 1..=10 {
            h.record(i as f64);
        }
        assert_eq!(h.percentile(100.0), 10.0);
        assert_eq!(h.percentile(0.0), 1.0);
        // Records after a cached query must be visible.
        h.record(100.0);
        h.record(-7.0);
        assert_eq!(h.percentile(100.0), 100.0);
        assert_eq!(h.percentile(0.0), -7.0);
        assert_eq!(h.min(), -7.0);
        assert_eq!(h.max(), 100.0);
        // Repeated queries (cache hits) stay consistent, and the
        // clone carries valid state.
        assert_eq!(h.percentile(50.0), h.clone().percentile(50.0));
        let brute_min =
            h.samples().iter().cloned().fold(f64::INFINITY, f64::min);
        let brute_max = h
            .samples()
            .iter()
            .cloned()
            .fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(h.min(), brute_min);
        assert_eq!(h.max(), brute_max);
    }

    #[test]
    fn empty_histogram_min_max_finite() {
        // Regression: these used to return ±INFINITY on an empty
        // reservoir, leaking "inf" into report strings.
        let h = Histogram::new();
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert!(!h.summary().contains("inf"), "{}", h.summary());
        let mut h = Histogram::new();
        h.record(-2.5);
        h.record(4.0);
        assert_eq!(h.min(), -2.5);
        assert_eq!(h.max(), 4.0);
    }
}
