//! Mini-proptest: seeded randomized property testing with shrinking.
//!
//! `prop_check(cases, gen, prop)` draws `cases` random inputs from `gen`,
//! asserts `prop` on each, and on failure greedily shrinks the input via
//! `Shrink` before panicking with the minimal counterexample. Used for
//! the coordinator invariants in DESIGN.md §7.

use super::rng::Rng;
use std::fmt::Debug;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone {
    /// Candidate strictly-smaller values (empty when minimal).
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![0, self / 2, self - 1]
        }
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![0, self / 2, self - 1]
        }
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = vec![];
        if self.is_empty() {
            return out;
        }
        // drop halves, drop one element, shrink one element
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        if self.len() > 1 {
            let mut v = self.clone();
            v.pop();
            out.push(v);
        }
        for (i, x) in self.iter().enumerate().take(4) {
            for sx in x.shrink().into_iter().take(2) {
                let mut v = self.clone();
                v[i] = sx;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Run a property over `cases` random inputs. `gen` draws an input from
/// the RNG; `prop` returns Err(reason) on violation.
///
/// The `PROPTEST_CASES` environment variable overrides `cases` when it
/// parses to a positive integer — CI runs the property suites at 512
/// cases in a dedicated step while local runs keep the fast defaults.
pub fn prop_check<T, G, P>(cases: usize, seed: u64, mut gen: G, mut prop: P)
where
    T: Shrink + Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let cases = std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(cases);
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(reason) = prop(&input) {
            // shrink
            let mut best = input;
            let mut best_reason = reason;
            let mut improved = true;
            let mut budget = 200usize;
            while improved && budget > 0 {
                improved = false;
                for cand in best.shrink() {
                    budget -= 1;
                    if let Err(r) = prop(&cand) {
                        best = cand;
                        best_reason = r;
                        improved = true;
                        break;
                    }
                    if budget == 0 {
                        break;
                    }
                }
            }
            panic!(
                "property failed (case {case}, seed {seed}):\n  \
                 input: {best:?}\n  reason: {best_reason}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        prop_check(
            200,
            1,
            |r| r.usize(0, 100),
            |&x| {
                if x < 100 {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        prop_check(
            100,
            2,
            |r| r.usize(0, 1000),
            |&x| {
                if x < 5 {
                    Ok(())
                } else {
                    Err(format!("{x} >= 5"))
                }
            },
        );
    }

    #[test]
    fn shrinking_minimizes() {
        // Capture the panic message and check the counterexample shrank
        // to something small.
        let res = std::panic::catch_unwind(|| {
            prop_check(
                100,
                3,
                |r| r.usize(0, 10_000),
                |&x| if x < 50 { Ok(()) } else { Err("big".into()) },
            )
        });
        let msg = match res {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(_) => panic!("expected failure"),
        };
        // greedy shrink should land on exactly 50
        assert!(msg.contains("input: 50"), "msg: {msg}");
    }

    #[test]
    fn vec_shrink_produces_smaller() {
        let v = vec![5usize, 6, 7, 8];
        for s in v.shrink() {
            assert!(
                s.len() < v.len() || s.iter().sum::<usize>() < v.iter().sum()
            );
        }
    }
}
