//! Std-only infrastructure substrates.
//!
//! The build environment has no network access to crates.io, so the
//! conveniences a serving framework would normally pull in (serde_json,
//! clap, criterion, proptest, rand) are implemented here from scratch
//! (DESIGN.md §Substitutions). Each module is small, tested, and scoped
//! to exactly what the coordinator needs.

pub mod bench;
pub mod cli;
pub mod json;
pub mod metrics;
pub mod prop;
pub mod rng;
pub mod table;
