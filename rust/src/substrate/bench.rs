//! Mini-criterion: a timing harness for `cargo bench` targets
//! (`harness = false`).
//!
//! Each bench binary builds a `BenchSuite`, registers closures, and calls
//! `run()`, which warms up, samples wall time, and prints
//! mean/stddev/min plus a throughput column — enough statistical
//! discipline for the paper-reproduction tables without criterion.

use std::time::{Duration, Instant};

use super::metrics::Histogram;

#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub sample_iters: usize,
    /// Hard cap per benchmark so slow cases don't stall the suite.
    pub max_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            sample_iters: 10,
            max_time: Duration::from_secs(20),
        }
    }
}

pub struct BenchResult {
    pub name: String,
    pub mean_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
    pub samples: usize,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }
}

pub struct BenchSuite {
    pub title: String,
    cfg: BenchConfig,
    pub results: Vec<BenchResult>,
}

impl BenchSuite {
    pub fn new(title: &str) -> Self {
        let mut cfg = BenchConfig::default();
        // MMSERVE_BENCH_FAST=1 trims iterations (CI smoke).
        if std::env::var("MMSERVE_BENCH_FAST").is_ok() {
            cfg.warmup_iters = 1;
            cfg.sample_iters = 3;
            cfg.max_time = Duration::from_secs(5);
        }
        println!("\n=== {title} ===");
        BenchSuite { title: title.to_string(), cfg, results: vec![] }
    }

    pub fn with_config(mut self, cfg: BenchConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Time `f` and record under `name`. Returns mean seconds.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> f64 {
        for _ in 0..self.cfg.warmup_iters {
            f();
        }
        let mut h = Histogram::new();
        let t_suite = Instant::now();
        for _ in 0..self.cfg.sample_iters {
            let t = Instant::now();
            f();
            h.record(t.elapsed().as_secs_f64());
            if t_suite.elapsed() > self.cfg.max_time {
                break;
            }
        }
        let r = BenchResult {
            name: name.to_string(),
            mean_s: h.mean(),
            stddev_s: h.stddev(),
            min_s: h.min(),
            samples: h.len(),
        };
        println!(
            "  {:<44} {:>10.3} ms ±{:>7.3} (min {:>9.3}, n={})",
            r.name,
            r.mean_s * 1e3,
            r.stddev_s * 1e3,
            r.min_s * 1e3,
            r.samples
        );
        let mean = r.mean_s;
        self.results.push(r);
        mean
    }

    /// Record an externally-measured value (e.g. model-derived time).
    pub fn record(&mut self, name: &str, secs: f64) {
        println!("  {:<44} {:>10.3} ms  (derived)", name, secs * 1e3);
        self.results.push(BenchResult {
            name: name.to_string(),
            mean_s: secs,
            stddev_s: 0.0,
            min_s: secs,
            samples: 1,
        });
    }

    pub fn result(&self, name: &str) -> Option<&BenchResult> {
        self.results.iter().find(|r| r.name == name)
    }

    /// Print a speedup line of `base / opt`.
    pub fn speedup(&self, label: &str, base: &str, opt: &str) -> Option<f64> {
        let b = self.result(base)?.mean_s;
        let o = self.result(opt)?.mean_s;
        let s = b / o;
        println!("  speedup [{label}]: {s:.2}x  ({base} / {opt})");
        Some(s)
    }
}

/// Geometric mean of speedups — the paper's cross-task aggregate.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Keep the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleep() {
        let mut s = BenchSuite::new("test").with_config(BenchConfig {
            warmup_iters: 0,
            sample_iters: 3,
            max_time: Duration::from_secs(5),
        });
        let m = s.bench("sleep2ms", || {
            std::thread::sleep(Duration::from_millis(2))
        });
        assert!(m >= 0.002);
    }

    #[test]
    fn speedup_math() {
        let mut s = BenchSuite::new("t2");
        s.record("slow", 0.2);
        s.record("fast", 0.1);
        let sp = s.speedup("x", "slow", "fast").unwrap();
        assert!((sp - 2.0).abs() < 1e-9);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-9);
        assert_eq!(geomean(&[]), 0.0);
    }
}
