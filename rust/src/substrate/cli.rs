//! Tiny declarative CLI argument parser (clap substitute).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args and
//! subcommands; generates usage text from the declared options.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Opt {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }
    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }
    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Command spec: name, help, options.
pub struct Command {
    pub name: &'static str,
    pub help: &'static str,
    pub opts: Vec<Opt>,
}

impl Command {
    pub fn new(name: &'static str, help: &'static str) -> Self {
        Command { name, help, opts: vec![] }
    }
    pub fn opt(mut self, name: &'static str, help: &'static str,
               default: Option<&'static str>) -> Self {
        self.opts.push(Opt { name, help, default, is_flag: false });
        self
    }
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n  options:\n", self.name, self.help);
        for o in &self.opts {
            let kind = if o.is_flag { "" } else { " <value>" };
            let def = o
                .default
                .map(|d| format!(" (default {d})"))
                .unwrap_or_default();
            s.push_str(&format!("    --{}{kind}  {}{def}\n", o.name, o.help));
        }
        s
    }

    /// Parse argv (after the subcommand name).
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        for o in &self.opts {
            if let Some(d) = o.default {
                out.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline) = match body.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (body, None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n{}", self.usage()))?;
                if opt.is_flag {
                    if inline.is_some() {
                        return Err(format!("--{key} takes no value"));
                    }
                    out.flags.push(key.to_string());
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} needs a value"))?
                        }
                    };
                    out.values.insert(key.to_string(), v);
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("serve", "run the server")
            .opt("model", "model name", Some("llama"))
            .opt("batch", "batch size", Some("4"))
            .flag("verbose", "chatty")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cmd().parse(&sv(&["--batch", "8"])).unwrap();
        assert_eq!(a.get("model"), Some("llama"));
        assert_eq!(a.get_usize("batch", 0), 8);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn equals_syntax_and_flags() {
        let a = cmd().parse(&sv(&["--model=hstu", "--verbose", "pos1"])).unwrap();
        assert_eq!(a.get("model"), Some("hstu"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cmd().parse(&sv(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cmd().parse(&sv(&["--batch"])).is_err());
    }
}
