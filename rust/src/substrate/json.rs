//! Minimal JSON parser/serializer (serde_json substitute).
//!
//! Supports the full JSON grammar minus exotic escapes (`\u` surrogate
//! pairs are decoded; other escapes pass through). Numbers are f64;
//! object key order is preserved (manifests are read positionally in
//! places).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
    /// `get` that errors with the key name — manifest parsing helper.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing field {key:?}"))
    }
    pub fn obj_entries(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn from_obj(entries: Vec<(String, Json)>) -> Json {
        Json::Obj(entries)
    }

    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing bytes at {}", p.i));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at {}", c as char, self.i))
        }
    }
    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at {}", other, self.i)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected , or }} at {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected , or ] at {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or("eof in string")?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or("eof in escape")?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let h = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )
                            .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(h, 16)
                                .map_err(|_| "bad \\u hex")?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at {}", self.i)),
                    }
                }
                _ => {
                    // Continue a UTF-8 multibyte sequence verbatim.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "bad utf8")?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at {start}"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\t' => write!(f, "\\t")?,
            '\r' => write!(f, "\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Sorted-key map → Json object (stable output for tests).
pub fn obj_from_map(m: &BTreeMap<String, Json>) -> Json {
    Json::Obj(m.iter().map(|(k, v)| (k.clone(), v.clone())).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":{}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert!(j.get("c").unwrap().obj_entries().unwrap().is_empty());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"mmserve","n":42,"xs":[1.5,true,null],"s":"q\"uote"}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_and_whitespace() {
        let j = Json::parse(" { \"k\" : \"héllo \\u00e9\" } ").unwrap();
        assert_eq!(j.get("k").unwrap().as_str(), Some("héllo é"));
    }

    #[test]
    fn preserves_key_order() {
        let j = Json::parse(r#"{"z":1,"a":2}"#).unwrap();
        let keys: Vec<_> = j
            .obj_entries()
            .unwrap()
            .iter()
            .map(|(k, _)| k.as_str())
            .collect();
        assert_eq!(keys, vec!["z", "a"]);
    }
}
