//! Plain-text table rendering for bench/characterization reports —
//! prints the same rows/series the paper's tables and figures show.

pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count");
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (c, cell) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", cell, w = widths[c]));
            }
            s.push('\n');
            s
        };
        let mut out = line(&self.headers);
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}-|", "-".repeat(w + 2 - 1)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&line(row));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format seconds adaptively (ns/µs/ms/s).
pub fn fmt_time(secs: f64) -> String {
    let a = secs.abs();
    if a < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if a < 1e-3 {
        format!("{:.1}µs", secs * 1e6)
    } else if a < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{secs:.2}s")
    }
}

/// Format byte counts adaptively.
pub fn fmt_bytes(b: f64) -> String {
    const K: f64 = 1024.0;
    if b < K {
        format!("{b:.0}B")
    } else if b < K * K {
        format!("{:.1}KiB", b / K)
    } else if b < K * K * K {
        format!("{:.1}MiB", b / K / K)
    } else {
        format!("{:.2}GiB", b / K / K / K)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["task", "ms"]);
        t.rowf(&["T-T", "1.5"]);
        t.rowf(&["longer-task-name", "100.25"]);
        let r = t.render();
        assert!(r.contains("| task "));
        assert!(r.contains("| longer-task-name |"));
        assert_eq!(r.lines().count(), 4);
    }

    #[test]
    #[should_panic]
    fn wrong_arity_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.rowf(&["only-one"]);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_time(0.0025), "2.50ms");
        assert_eq!(fmt_time(2.5), "2.50s");
        assert_eq!(fmt_bytes(2048.0), "2.0KiB");
    }
}
