//! Deterministic PRNG (splitmix64 + xoshiro256**), rand substitute.
//!
//! Used by workload generators (Table-2 calibrated distributions), the
//! property-testing harness, and sampling. Seeded → fully reproducible
//! benches.

/// xoshiro256** seeded via splitmix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [lo, hi) — hi exclusive, lo < hi.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range");
        lo + self.next_u64() % (hi - lo)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given *underlying* mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.range(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..20000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
